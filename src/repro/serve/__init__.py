"""Continuous-batching ensemble service (docs/architecture.md, "Serving").

Async submit/poll serving of DE ensemble solves over fixed-shape resumable
slots: finished lanes retire early and are refilled from the request queue
without recompilation, so heterogeneous small requests share one compiled
program at full lane occupancy.
"""
from .service import (Backpressure, EnsembleService, ServeResult,
                      SolveRequest, Ticket)
from .slots import BatchPool, SlotPool

__all__ = ["Backpressure", "EnsembleService", "ServeResult", "SolveRequest",
           "Ticket", "BatchPool", "SlotPool"]
