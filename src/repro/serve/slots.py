"""Slot pools — the execution layer of the continuous-batching service.

Two pool kinds, one per capability class (`MethodSpec.resumable`):

`SlotPool` (resumable methods: erk, fixed-dt sde)
    B fixed-shape lane slots stepped by ONE compiled resumable program
    (`repro.core.ensemble.ResumableEngine`).  Each slot holds one lane of one
    request; per-lane constants (p, tf / n_steps, lane index) live in the
    carry, so a retired slot is refilled with a DIFFERENT request's lane via
    a full-width masked merge — no recompilation, ever.  Progress happens in
    bounded segments; between segments the pool harvests done lanes, enforces
    per-request attempt budgets, and admits staged lanes into free slots.
    Lane results are bitwise-identical to a fresh
    `solve_ensemble_local(..., ensemble="kernel", backend="xla")` of the same
    request (same loop body, per-lane control, counter-RNG streams keyed by
    GLOBAL lane index).

`BatchPool` (non-resumable methods: rosenbrock, adaptive sde)
    Requests sharing the FULL solver signature are concatenated and solved in
    one `solve_ensemble_local` call per pump.  Rosenbrock's lazy-W refresh
    gates are batch-reduced predicates (they couple lanes), so its lanes
    cannot retire early — coalescing into one batch is the right serving
    shape there.  Adaptive SDE additionally keys on the request's
    `lane_offset` (its Brownian streams are globally indexed), so those
    requests ride the same machinery uncoalesced.  The solve returns
    ensemble-total nf/njac/nfact; they are attributed to requests
    proportionally to per-lane attempt counts (documented estimate — the
    engines do not track per-lane RHS totals on these paths).
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Optional

import jax
import numpy as np

from repro.core.ensemble import make_resumable_engine, solve_ensemble_local
from repro.core.problem import EnsembleProblem


def _finalize_status(status: int, done: bool) -> int:
    # mirror the front door: carried status wins; else 0 if done, 1 if not
    return int(status) if status > 0 else (0 if done else 1)


class SlotPool:
    """Continuous batching over B fixed slots of one resumable engine."""

    def __init__(self, spec, prob, *, n: int, n_params: int, dtype,
                 width: int = 8, segment_steps: int = 64, adaptive=None,
                 rtol: float = 1e-6, atol: float = 1e-6, event=None,
                 seed: int = 0,
                 on_complete: Optional[Callable] = None):
        self.family = spec.family
        self.B = int(width)
        self.n = int(n)
        self.dtype = np.dtype(dtype)
        self.on_complete = on_complete
        self.engine = make_resumable_engine(
            spec, prob, adaptive=adaptive, rtol=rtol, atol=atol, event=event,
            seed=seed, segment_steps=segment_steps)
        B = self.B
        # persistent host-side staging buffers (full width; non-refilled
        # columns carry stale-but-finite filler values that the masked merge
        # discards).  Fillers retire in one iteration: tf == t0 (erk) /
        # n_steps == 0 (sde), so untouched columns never cost segment work.
        self._stage_u0 = np.ones((n, B), self.dtype)
        self._stage_p = np.ones((n_params, B), self.dtype)
        self._stage_t0 = np.zeros(B, self.dtype)
        if self.family == "sde":
            self._stage_dt = np.ones(B, self.dtype)
            self._stage_nsteps = np.zeros(B, np.int32)
            self._stage_lane = np.zeros(B, np.uint32)
        else:
            self._stage_tf = np.zeros(B, self.dtype)
            self._stage_dt0 = np.ones(B, self.dtype)
        self.slots = [None] * B          # slot -> (request, row) | None
        self.staged = deque()            # lanes awaiting a free slot
        self.carry = None
        self._scrub = set()              # budget-evicted slots to force-done

    # -- request admission ----------------------------------------------------

    def admit(self, req) -> None:
        for row in range(req.n_lanes):
            self.staged.append((req, row))

    @property
    def busy(self) -> bool:
        return bool(self.staged) or any(s is not None for s in self.slots)

    def inflight_requests(self) -> list:
        """Distinct requests with lanes in slots or staged (failure
        attribution — see EnsembleService._record_pool_failure)."""
        seen, out = set(), []
        for entry in list(self.slots) + list(self.staged):
            if entry is None:
                continue
            req = entry[0]
            if id(req) not in seen:
                seen.add(id(req))
                out.append(req)
        return out

    def evict(self, req) -> None:
        """Drop every lane of `req` from the pool (permanent failure):
        staged lanes vanish, occupied slots are freed and scheduled for a
        filler scrub so their carry columns stop costing segment work."""
        self.staged = deque(e for e in self.staged if e[0] is not req)
        for slot in range(self.B):
            if self.slots[slot] is not None and self.slots[slot][0] is req:
                self.slots[slot] = None
                if self.carry is not None:
                    self._scrub.add(slot)

    # -- one scheduling round -------------------------------------------------

    def _stage_lane_cols(self, slot: int, req, row: int) -> None:
        self._stage_u0[:, slot] = req.u0s[row]
        self._stage_p[:, slot] = req.ps[row]
        self._stage_t0[slot] = req.t0
        if self.family == "sde":
            self._stage_dt[slot] = req.dt0
            self._stage_nsteps[slot] = req.n_steps
            self._stage_lane[slot] = req.lane_offset + row
        else:
            self._stage_tf[slot] = req.tf
            self._stage_dt0[slot] = req.dt0

    def _stage_filler(self, slot: int) -> None:
        self._stage_t0[slot] = 0.0
        if self.family == "sde":
            self._stage_nsteps[slot] = 0
        else:
            self._stage_tf[slot] = 0.0

    def _fresh(self):
        if self.family == "sde":
            return self.engine.fresh(self._stage_u0, self._stage_p,
                                     self._stage_t0, self._stage_dt,
                                     self._stage_nsteps, self._stage_lane)
        return self.engine.fresh(self._stage_u0, self._stage_p,
                                 self._stage_t0, self._stage_tf,
                                 self._stage_dt0)

    def pump(self) -> bool:
        """Refill free slots from the staged queue, advance one segment,
        harvest retired lanes.  Returns True if the pool did work."""
        if not self.busy:
            return False
        mask = np.zeros(self.B, bool)
        for slot in range(self.B):
            if self.slots[slot] is not None:
                continue
            if self.staged:
                req, row = self.staged.popleft()
                self.slots[slot] = (req, row)
                self._stage_lane_cols(slot, req, row)
                mask[slot] = True
            elif slot in self._scrub:
                # budget-evicted column with no refill available this round:
                # stage a one-iteration filler so the never-done carry column
                # stops consuming full segments
                self._stage_filler(slot)
                mask[slot] = True
            self._scrub.discard(slot)
        refill = self._fresh() if mask.any() or self.carry is None \
            else self.carry
        if self.carry is None:
            self.carry = refill
            mask = np.zeros(self.B, bool)
            refill = self.carry
        self.carry = self.engine.step_segment(self.carry, mask, refill)
        self._harvest()
        return True

    def _harvest(self) -> None:
        h = jax.device_get(self.carry)
        for slot in range(self.B):
            if self.slots[slot] is None:
                continue
            req, row = self.slots[slot]
            done = bool(h["done"][slot])
            attempts = int(h["naccept"][slot]) + int(h.get(
                "nreject", np.zeros(self.B, np.int32))[slot])
            if not done and attempts < req.max_iters:
                continue
            row_res = dict(
                u_final=np.asarray(h["u"][:, slot]),
                t_final=float(h["t_out"][slot] if "t_out" in h
                              else h["t"][slot]),
                naccept=int(h["naccept"][slot]),
                nreject=int(h["nreject"][slot]) if "nreject" in h else 0,
                nf=int(h["nf"][slot]),
                status=_finalize_status(int(h["status"][slot]), done),
                event_t=float(h["event_t"][slot]),
                event_count=int(h["event_count"][slot]),
            )
            finished = req.record_row(row, row_res)
            self.slots[slot] = None
            if not done:
                # over-budget lane: free the slot now, force-retire the
                # carry column next pump so it stops consuming segment work
                self._scrub.add(slot)
            if finished and self.on_complete is not None:
                self.on_complete(req)


class BatchPool:
    """Coalesced one-shot batches for non-resumable methods."""

    def __init__(self, spec, prob, *, solve_kwargs: dict,
                 on_complete: Optional[Callable] = None):
        self.spec = spec
        self.prob = prob
        self.solve_kwargs = dict(solve_kwargs)
        self.on_complete = on_complete
        self.staged = []

    def admit(self, req) -> None:
        self.staged.append(req)

    @property
    def busy(self) -> bool:
        return bool(self.staged)

    def inflight_requests(self) -> list:
        return list(self.staged)

    def evict(self, req) -> None:
        self.staged = [r for r in self.staged if r is not req]

    def pump(self) -> bool:
        if not self.staged:
            return False
        # staged is cleared only after the solve succeeds: a pump exception
        # leaves the batch intact for the service's retry/fail ladder
        reqs = list(self.staged)
        u0s = np.concatenate([r.u0s for r in reqs], axis=0)
        ps = np.concatenate([r.ps for r in reqs], axis=0)
        ep = EnsembleProblem(self.prob, u0s.shape[0], u0s=u0s, ps=ps)
        res = solve_ensemble_local(ep, alg=self.spec.name,
                                   **self.solve_kwargs)
        self.staged = []
        naccept = np.broadcast_to(np.asarray(res.naccept), (u0s.shape[0],))
        nreject = np.broadcast_to(np.asarray(res.nreject), (u0s.shape[0],))
        attempts = naccept.astype(np.int64) + nreject.astype(np.int64)
        total_att = max(int(attempts.sum()), 1)
        u_final = np.asarray(res.u_final)
        t_final = np.broadcast_to(np.asarray(res.t_final), (u0s.shape[0],))
        # per-lane when the engine reports it: one tenant's failing lane must
        # not mark the whole coalesced batch failed
        status_rows = np.broadcast_to(np.asarray(res.status), (u0s.shape[0],))
        nf, njac, nfact = (int(np.asarray(v)) for v in
                           (res.nf, res.njac, res.nfact))
        off = 0
        for req in reqs:
            k = req.n_lanes
            sl = slice(off, off + k)
            # ensemble-total counters attributed by attempt share (estimate)
            share = int(attempts[sl].sum()) / total_att
            for row in range(k):
                req.record_row(row, dict(
                    u_final=u_final[off + row],
                    t_final=float(t_final[off + row]),
                    naccept=int(naccept[off + row]),
                    nreject=int(nreject[off + row]),
                    nf=int(round(nf * share / k)),
                    status=int(status_rows[off + row]),
                    event_t=float("inf"), event_count=0,
                ))
            req.njac = int(round(njac * share))
            req.nfact = int(round(nfact * share))
            off += k
            if self.on_complete is not None:
                self.on_complete(req)
        return True
