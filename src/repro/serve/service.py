"""The serving front end: async submit/poll over the slot pools.

`EnsembleService` is a single-process continuous-batching server for DE
ensembles (the "solver as a service" shape of the paper's throughput story):

* **submit** is non-blocking: it validates the request, assigns a GLOBAL
  `lane_offset` (the counter-RNG stream base — results are bitwise those of a
  fresh `solve_ensemble_local(..., seed=service.seed, lane_offset=<assigned>)`),
  pushes the request onto the hardened `repro.dist.fault.WorkQueue` (leases +
  generation tokens: a pump that dies mid-request loses its lease and the
  request is re-served), and returns a `Ticket`.
* **coalescing**: requests are routed to pools by capability key.  Resumable
  methods (erk, fixed-dt sde) share a `SlotPool` per
  (problem, method, n, n_params, dtype, adaptive, rtol, atol, event) — time
  spans, step sizes and step counts ride IN the carry, so heterogeneous
  requests fill the same compiled slots.  Non-resumable methods coalesce into
  one-shot `BatchPool` solves keyed on the full solver signature.
* **pump/drain** advance the pools: `pump()` runs one scheduling round
  (admit staged requests, one bounded segment per busy slot pool, one batch
  per staged batch pool); `drain()` pumps until quiet.  `start()` runs the
  pump loop on a background thread for true submit-from-anywhere serving.
* **backpressure**: `submit` raises `Backpressure` once `max_pending`
  requests are in flight — callers retry after polling tickets.
* **accounting**: per-tenant nf/njac/nfact and lane totals, folded from the
  same per-lane kernel stats rows every engine already reports — plus a
  `failures` counter and `last_error` string per tenant, so an operator can
  tell degraded-but-serving (failures climbing, requests still completing)
  from healthy without scraping logs.
* **failure isolation**: a pool pump that raises (bad RHS, trace-time error)
  marks the affected requests failed-once and retries them on later pumps;
  past `max_request_retries` the request is failed PERMANENTLY — its ticket
  gets `error` set (result stays None), capacity is released, and the other
  tenants' requests keep serving.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import numpy as np

from repro.core.methods import get_method
from repro.dist.fault import WorkQueue


class Backpressure(RuntimeError):
    """Raised by submit() when the service is at max_pending requests."""


@dataclass
class ServeResult:
    """Final-state result of one served request (serving has no dense-output
    path: snapshots belong to offline solves — see docs/architecture.md)."""
    u_final: np.ndarray      # (N, n)
    t_final: np.ndarray      # (N,)
    naccept: np.ndarray      # (N,)
    nreject: np.ndarray      # (N,)
    nf: int
    njac: int
    nfact: int
    status: int              # max over lanes (0 ok, 1 budget, 2 dtmin)
    event_t: np.ndarray      # (N,) located event times (inf = no event)
    event_count: np.ndarray  # (N,)


@dataclass
class SolveRequest:
    """One ensemble solve in flight.  Internal to the service."""
    prob: Any
    alg: str
    u0s: np.ndarray
    ps: np.ndarray
    t0: float
    tf: float
    dt0: float
    n_steps: Optional[int]
    adaptive: Optional[bool]
    rtol: float
    atol: float
    max_iters: int
    event: Any
    tenant: str
    lane_offset: int
    n_lanes: int
    njac: int = 0
    nfact: int = 0
    failures: int = 0        # pump exceptions that hit this request
    _rows: dict = field(default_factory=dict)
    _wq_lease: Optional[tuple] = None

    def record_row(self, row: int, res: dict) -> bool:
        """Store one finished lane; True when the request is complete."""
        self._rows[row] = res
        return len(self._rows) == self.n_lanes

    def assemble(self) -> ServeResult:
        rows = [self._rows[i] for i in range(self.n_lanes)]
        return ServeResult(
            u_final=np.stack([r["u_final"] for r in rows]),
            t_final=np.asarray([r["t_final"] for r in rows]),
            naccept=np.asarray([r["naccept"] for r in rows], np.int64),
            nreject=np.asarray([r["nreject"] for r in rows], np.int64),
            nf=int(sum(r["nf"] for r in rows)),
            njac=self.njac, nfact=self.nfact,
            status=max(r["status"] for r in rows),
            event_t=np.asarray([r["event_t"] for r in rows]),
            event_count=np.asarray([r["event_count"] for r in rows],
                                   np.int64))


class Ticket:
    """Async handle returned by submit(): poll `done`, read `result`."""

    def __init__(self, req: SolveRequest):
        self._req = req
        self._event = threading.Event()
        self.result: Optional[ServeResult] = None
        self.error: Optional[str] = None
        self.submitted_at = time.monotonic()
        self.completed_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the request completes (background-thread serving)."""
        return self._event.wait(timeout)

    @property
    def latency(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    def _complete(self, result: ServeResult) -> None:
        self.result = result
        self.completed_at = time.monotonic()
        self._event.set()

    def _fail(self, error: str) -> None:
        """Permanent failure: `done` goes True with `result` None and
        `error` holding the last pump exception."""
        self.error = error
        self.completed_at = time.monotonic()
        self._event.set()


class EnsembleService:
    """Continuous-batching DE ensemble server (single device, many tenants).

    seed          — the service-global RNG seed: every SDE request draws the
                    (seed; step, global lane, row) Threefry stream at its
                    assigned lane_offset, so any served result can be
                    reproduced offline bitwise.
    max_pending   — in-flight request cap; submit raises Backpressure beyond.
    slot_width    — lanes per SlotPool (fixed compiled width; multiples of 4
                    keep XLA codegen width-compatible with the fresh kernel
                    paths — see docs/architecture.md).
    segment_steps — solver attempts per pump segment: the
                    retire-latency / dispatch-overhead knob.
    """

    def __init__(self, seed: int = 0, max_pending: int = 64,
                 slot_width: int = 8, segment_steps: int = 64,
                 queue_timeout: float = 300.0, max_request_retries: int = 2):
        self.seed = int(seed)
        self.max_pending = int(max_pending)
        self.slot_width = int(slot_width)
        self.segment_steps = int(segment_steps)
        self.max_request_retries = int(max_request_retries)
        self._wq = WorkQueue(timeout=queue_timeout)
        self._pools: Dict[tuple, Any] = {}
        self._tickets: Dict[int, Ticket] = {}   # id(req) -> ticket
        self._inflight: Dict[int, SolveRequest] = {}  # admitted, not finished
        self._lane_counter = 0
        self._pending = 0
        self._lock = threading.Lock()
        self._pump_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.accounting: Dict[str, Dict[str, Any]] = {}

    def _acct(self, tenant: str) -> Dict[str, Any]:
        return self.accounting.setdefault(
            tenant, dict(requests=0, lanes=0, nf=0, njac=0, nfact=0,
                         failures=0, last_error=None))

    # -- submission -----------------------------------------------------------

    def submit(self, eprob, alg: str = "tsit5", *, tenant: str = "default",
               t0=None, tf=None, dt0: float = 1e-2,
               n_steps: Optional[int] = None, adaptive: Optional[bool] = None,
               rtol: float = 1e-6, atol: float = 1e-6,
               max_iters: int = 100_000, event=None,
               ensemble: str = "kernel", backend: str = "xla") -> Ticket:
        """Enqueue one ensemble solve; returns immediately with a Ticket.

        eprob: `EnsembleProblem` (u0s/ps materialized host-side).  Defaults
        mirror `solve_ensemble_local`; fixed-dt SDE requests take
        n_steps (default round((tf-t0)/dt0)).

        Validation (unknown method, materialization failure) happens BEFORE
        the request occupies a pending slot, so rejected submits never eat
        service capacity.
        """
        spec = get_method(alg)
        prob = eprob.prob
        u0s, ps = (np.asarray(a) for a in eprob.materialize())
        t0 = float(prob.tspan[0] if t0 is None else t0)
        tf = float(prob.tspan[1] if tf is None else tf)
        if adaptive is None:
            adaptive = spec.adaptive if spec.family != "sde" else False
        if spec.family == "sde" and not adaptive and n_steps is None:
            n_steps = int(round((tf - t0) / dt0))
        with self._lock:
            if self._pending >= self.max_pending:
                raise Backpressure(
                    f"{self._pending} requests in flight (max_pending="
                    f"{self.max_pending}); poll tickets and retry")
            self._pending += 1
            lane_offset = self._lane_counter
            self._lane_counter += u0s.shape[0]
        req = SolveRequest(
            prob=prob, alg=spec.name, u0s=u0s, ps=ps, t0=t0, tf=tf,
            dt0=float(dt0), n_steps=n_steps, adaptive=adaptive,
            rtol=float(rtol), atol=float(atol), max_iters=int(max_iters),
            event=event, tenant=tenant, lane_offset=lane_offset,
            n_lanes=u0s.shape[0])
        ticket = Ticket(req)
        with self._lock:
            self._tickets[id(req)] = ticket
        self._wq.push(req)
        return ticket

    # -- routing --------------------------------------------------------------

    def _resumable(self, spec, req) -> bool:
        if not spec.resumable:
            return False
        if spec.family == "sde" and req.adaptive:
            return False  # Brownian-tree state is dt-path dependent
        return True

    def _pool_for(self, req) -> Any:
        from .slots import BatchPool, SlotPool
        spec = get_method(req.alg)
        dtype = req.u0s.dtype
        if self._resumable(spec, req):
            key = ("slot", id(req.prob), spec.name, req.u0s.shape[1],
                   req.ps.shape[1], dtype.str, bool(req.adaptive),
                   req.rtol, req.atol, id(req.event) if req.event else None)
            if key not in self._pools:
                self._pools[key] = SlotPool(
                    spec, req.prob, n=req.u0s.shape[1],
                    n_params=req.ps.shape[1], dtype=dtype,
                    width=self.slot_width, segment_steps=self.segment_steps,
                    adaptive=req.adaptive, rtol=req.rtol, atol=req.atol,
                    event=req.event, seed=self.seed,
                    on_complete=self._finish)
            return self._pools[key]
        # full-signature coalescing; adaptive SDE keys on lane_offset too
        # (globally indexed Brownian streams must not be re-based)
        key = ("batch", id(req.prob), spec.name, req.u0s.shape[1],
               req.ps.shape[1], dtype.str, req.t0, req.tf, req.dt0,
               req.n_steps, bool(req.adaptive), req.rtol, req.atol,
               req.max_iters, id(req.event) if req.event else None,
               req.lane_offset if spec.family == "sde" else None)
        if key not in self._pools:
            kw = dict(ensemble="kernel", backend="xla", t0=req.t0, tf=req.tf,
                      dt0=req.dt0, n_steps=req.n_steps,
                      adaptive=req.adaptive, rtol=req.rtol, atol=req.atol,
                      max_iters=req.max_iters, event=req.event)
            if spec.family == "sde":
                kw.update(adaptive=True, seed=self.seed,
                          lane_offset=req.lane_offset)
            self._pools[key] = BatchPool(spec, req.prob, solve_kwargs=kw,
                                         on_complete=self._finish)
        return self._pools[key]

    # -- completion -----------------------------------------------------------

    def _finish(self, req: SolveRequest) -> None:
        # idempotent: a duplicate completion (defensive — e.g. a re-admitted
        # request under a mis-set queue_timeout) must not double-account,
        # double-decrement _pending, or KeyError the pump thread
        with self._lock:
            ticket = self._tickets.pop(id(req), None)
            if ticket is None:
                return
            self._inflight.pop(id(req), None)
            self._pending -= 1
        result = req.assemble()
        acct = self._acct(req.tenant)
        acct["requests"] += 1
        acct["lanes"] += req.n_lanes
        acct["nf"] += result.nf
        acct["njac"] += result.njac
        acct["nfact"] += result.nfact
        if req._wq_lease is not None:
            idx, tok = req._wq_lease
            self._wq.complete(idx, tok)
        ticket._complete(result)

    def _fail_request(self, req: SolveRequest, error: str) -> None:
        """Permanently fail a request (retry budget exhausted): release its
        capacity and lease, set the ticket's error.  Idempotent like
        `_finish`."""
        with self._lock:
            ticket = self._tickets.pop(id(req), None)
            if ticket is None:
                return
            self._inflight.pop(id(req), None)
            self._pending -= 1
        if req._wq_lease is not None:
            idx, tok = req._wq_lease
            self._wq.complete(idx, tok)
        ticket._fail(error)

    def _record_pool_failure(self, pool, exc: Exception) -> None:
        """A pool pump raised: charge the failure to every affected tenant,
        then retry or permanently fail the affected requests."""
        error = f"{type(exc).__name__}: {exc}"
        reqs = pool.inflight_requests()
        for req in reqs:
            req.failures += 1
            acct = self._acct(req.tenant)
            acct["failures"] += 1
            acct["last_error"] = error
        for req in reqs:
            if req.failures > self.max_request_retries:
                pool.evict(req)
                self._fail_request(req, error)

    # -- scheduling -----------------------------------------------------------

    def pump(self) -> bool:
        """One scheduling round; True if any pool still has or did work.

        Serialized: a concurrent caller (inline poll racing the background
        thread) waits for the round in progress instead of double-advancing
        the pools."""
        with self._pump_lock:
            return self._pump_locked()

    def _pump_locked(self) -> bool:
        # keep in-flight leases alive: a request being actively solved must
        # not expire (and get re-admitted) just because its solve outlasts
        # queue_timeout
        for req in list(self._inflight.values()):
            if req._wq_lease is not None:
                self._wq.renew(*req._wq_lease)
        seen = set()
        while (claim := self._wq.claim()) is not None:
            idx, req, tok = claim
            req._wq_lease = (idx, tok)
            if id(req) not in self._inflight:
                self._inflight[id(req)] = req
                self._pool_for(req).admit(req)
            elif idx in seen:
                # queue_timeout shorter than this claim loop: every claim
                # re-leases the same in-flight item — stop; the token stored
                # above is already the freshest generation
                break
            seen.add(idx)
        worked = False
        for key, pool in list(self._pools.items()):
            try:
                worked = pool.pump() or worked
            except Exception as exc:     # degraded, not down: other pools
                self._record_pool_failure(pool, exc)   # keep serving
                worked = True
            if key[0] == "batch" and not pool.busy:
                # batch pools are one-shot; drop them so per-request keys
                # (adaptive-SDE lane_offset) don't accumulate forever
                del self._pools[key]
        return worked or any(p.busy for p in self._pools.values()) \
            or not self._wq.finished

    def drain(self) -> None:
        """Pump until every submitted request has completed."""
        while self.pump():
            pass

    def poll(self, ticket: Ticket) -> Optional[ServeResult]:
        """Non-blocking result check (pump once if serving inline)."""
        if not ticket.done and self._thread is None:
            self.pump()
        return ticket.result

    # -- background serving ---------------------------------------------------

    def start(self) -> None:
        """Serve on a background thread: submit from anywhere, wait() tickets."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if not self.pump():
                    time.sleep(0.002)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
