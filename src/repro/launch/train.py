"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

On real hardware this builds the production mesh and pjits the step over it;
on this CPU container it falls back to single-device (use --smoke to select
the reduced config). Fault-tolerant by construction: resumes from the latest
checkpoint, data cursor included (dist/fault.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.archs import get_arch
from repro.data.pipeline import DataPipeline
from repro.dist.fault import TrainSupervisor
from repro.models.model import build_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.trainer import make_train_step, pick_accum


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=0, help="0 = auto")
    ap.add_argument("--shard-mode", default="fsdp",
                    choices=["fsdp", "zero1", "tp"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--production-mesh", action="store_true",
                    help="build the 16x16 mesh (requires 256 devices)")
    args = ap.parse_args()

    cfg = get_arch(args.arch + ("-smoke" if args.smoke else ""))
    mesh = None
    if args.production_mesh:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
    model = build_model(cfg, dtype=jnp.float32 if mesh is None
                        else jnp.bfloat16, remat=mesh is not None)
    accum = args.accum or pick_accum(cfg, args.batch, args.seq)
    opt = AdamW(lr=cosine_schedule(args.lr, 20, args.steps))
    plan = make_train_step(model, opt, mesh=mesh, accum=accum, donate=False,
                           shard_mode=args.shard_mode)

    sup = TrainSupervisor(args.ckpt_dir + "/" + cfg.name,
                          save_every=args.save_every)
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    start, state, extra = sup.resume_or_init(
        lambda: {"params": params, "opt": opt_state},
        {"params": params, "opt": opt_state})
    params, opt_state = state["params"], state["opt"]
    pipe = DataPipeline(cfg, batch=args.batch, seq_len=args.seq,
                        start_step=extra.get("cursor", 0))
    print(f"training {cfg.name} from step {start} "
          f"(accum={accum}, shard={args.shard_mode}, mesh={mesh})")
    for step in range(start + 1, args.steps + 1):
        t0 = time.perf_counter()
        params, opt_state, m = plan.step_fn(params, opt_state, next(pipe))
        if step % 10 == 0 or step == 1:
            print(f"step {step:5d}  loss {float(m['loss']):.4f}  "
                  f"{time.perf_counter() - t0:.2f}s/step", flush=True)
        sup.maybe_save(step, {"params": params, "opt": opt_state},
                       {"cursor": pipe.cursor()})
    pipe.close()


if __name__ == "__main__":
    main()
