"""input_specs(): ShapeDtypeStruct stand-ins for every model input — weak-type
correct, shardable, ZERO device allocation. The dry-run lowers against these.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import SHAPES, ModelConfig, ShapeConfig


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, T = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        # image tokens + text tokens = seq_len total positions
        Tt = T - cfg.vis_seq
        return {"tokens": sds((B, Tt), jnp.int32),
                "labels": sds((B, Tt), jnp.int32),
                "patches": sds((B, cfg.vis_seq, cfg.vis_dim), jnp.float32)}
    if cfg.family == "encdec":
        return {"tokens": sds((B, T), jnp.int32),
                "labels": sds((B, T), jnp.int32),
                "frames": sds((B, cfg.enc_seq, cfg.d_model), jnp.float32)}
    return {"tokens": sds((B, T), jnp.int32),
            "labels": sds((B, T), jnp.int32)}


def decode_token_specs(shape: ShapeConfig):
    return sds((shape.global_batch, 1), jnp.int32)


def abstract_params(model):
    return jax.eval_shape(model.init_params, jax.random.PRNGKey(0))


def abstract_cache(model, batch: int, cache_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, cache_len))


def input_specs(cfg: ModelConfig, shape_name: str, model=None):
    """Full input pytree (abstract) for the given cell, per shape kind."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return train_batch_specs(cfg, shape)
    if shape.kind == "decode":
        assert model is not None
        return {"tokens": decode_token_specs(shape),
                "cache": abstract_cache(model, shape.global_batch,
                                        shape.seq_len)}
    raise ValueError(shape.kind)
