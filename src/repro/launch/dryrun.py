import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks the device count on first
# init). This process-level override exists ONLY for the dry-run: smoke tests
# and benchmarks see the real single device.

"""Multi-pod dry-run (deliverable e): .lower().compile() every
(architecture x input-shape x mesh) cell against the production mesh and
record memory/cost/collective analysis for §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k \
      --mesh single --out results/
  python -m repro.launch.dryrun --all --mesh both --out results/
  python -m repro.launch.dryrun --ode     # the paper's 2^30-trajectory cell
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.archs import ARCHS, LONG_CONTEXT_SKIP, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_params, input_specs,
                                train_batch_specs)
from repro.models.config import SHAPES
from repro.models.model import build_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.serve import make_serve_plan
from repro.train.trainer import make_train_step, pick_accum

# --------------------------------------------------------------------------
# HLO collective accounting (roofline input; see launch/roofline.py)
# --------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"^\s*(?:%?[\w.\-]+\s*=\s*)?"
    r"((?:[a-z0-9]+\[[^\]]*\](?:,\s*)?)+|\(.*?\))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
    re.M)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s8|u8|pred)\[([0-9,]*)\]")

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "u64": 8, "u32": 4, "s8": 1, "u8": 1, "pred": 1}


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in post-SPMD HLO.
    Returns (total_bytes_per_device, counts_by_op)."""
    total = 0
    counts = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes_str, op = m.group(1), m.group(2)
        sz = 0
        for sm in _SHAPE_RE.finditer(shapes_str):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            sz += n * _BYTES[dt]
        total += sz
        counts[op] = counts.get(op, 0) + 1
    return total, counts


def analyze(lowered, compiled):
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    cbytes, ccounts = collective_bytes(hlo)
    out = {
        "flops": float(cost.get("flops", -1.0)),
        "hlo_bytes": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes": cbytes,
        "collective_counts": ccounts,
    }
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            try:
                out[k] = int(getattr(mem, k))
            except Exception:
                pass
    return out


# --------------------------------------------------------------------------
# per-cell lowering
# --------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             fsdp: bool = True, extra_tag: str = "",
             scan_unroll: bool = False, shard_mode: str = None,
             remat_mode="full") -> dict:
    """Lower + compile one cell.

    scan_unroll=True is the roofline-calibration mode: layer scans are fully
    unrolled (XLA cost analysis counts a rolled scan body only once) and
    gradient accumulation is forced to 1 (its scan would hide flops the same
    way). Used ONLY with shallow depth overrides (launch/roofline.py).
    """
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "tag": extra_tag, "ok": False}
    unroll = True if scan_unroll else 1
    remat = "dots" if remat_mode == "dots" else True
    t0 = time.time()
    try:
        if shape.kind == "train":
            model = build_model(cfg, dtype=jnp.bfloat16, remat=remat,
                                unroll=unroll)
            nd = mesh.devices.size // mesh.shape["model"]
            per_dev = shape.global_batch // nd
            accum = 1 if scan_unroll else pick_accum(cfg, per_dev,
                                                     shape.seq_len)
            rec["accum"] = accum
            opt = AdamW(lr=cosine_schedule(3e-4, 100, 10_000))
            batch = train_batch_specs(cfg, shape)
            plan = make_train_step(model, opt, mesh=mesh, accum=accum,
                                   fsdp=fsdp, abstract_batch=batch,
                                   shard_mode=shard_mode)
            lowered = plan.step_fn.lower(plan.abstract_params,
                                         plan.abstract_opt, batch)
        elif shape.kind == "prefill":
            model = build_model(cfg, dtype=jnp.bfloat16, remat=True,
                                unroll=unroll)
            batch = train_batch_specs(cfg, shape)
            plan = make_serve_plan(model, mesh, shape.global_batch,
                                   shape.seq_len, fsdp=fsdp,
                                   abstract_batch=batch)
            lowered = plan.prefill_fn.lower(plan.abstract_params, batch)
        else:  # decode
            model = build_model(cfg, dtype=jnp.bfloat16, unroll=unroll)
            plan = make_serve_plan(model, mesh, shape.global_batch,
                                   shape.seq_len, fsdp=fsdp)
            toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            lowered = plan.decode_fn.lower(plan.abstract_params,
                                           plan.abstract_cache, toks)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        rec.update(analyze(lowered, compiled))
        rec["n_devices"] = int(mesh.devices.size)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue the batch
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def run_ode_cell(multi_pod: bool, n_traj: int = 2 ** 30) -> dict:
    """The paper's §6.3 scaling demo as a dry-run: 2^30 Lorenz trajectories
    sharded over the production mesh (ensemble axis = pod x data)."""
    from repro.core.api import solve_ensemble
    from repro.core.problem import EnsembleProblem
    from repro.configs.de_problems import lorenz_problem
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": "lorenz-ensemble", "shape": f"traj_{n_traj}",
           "mesh": "multi" if multi_pod else "single", "ok": False}
    t0 = time.time()
    try:
        prob = lorenz_problem(jnp.float32)
        ep = EnsembleProblem(prob, n_traj)

        def solve(u0s, ps):
            ep2 = EnsembleProblem(prob, n_traj, u0s=u0s, ps=ps)
            res = solve_ensemble(ep2, mesh=mesh, ensemble="kernel",
                                 backend="xla", adaptive=False, dt0=1e-3,
                                 t0=0.0, tf=1.0, save_every=1000,
                                 lane_tile=4096)
            return res.u_final

        u0s = jax.ShapeDtypeStruct((n_traj, 3), jnp.float32)
        ps = jax.ShapeDtypeStruct((n_traj, 3), jnp.float32)
        lowered = jax.jit(solve).lower(u0s, ps)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        rec.update(analyze(lowered, compiled))
        rec["n_devices"] = int(mesh.devices.size)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def cells(include_skipped=False):
    for arch in ARCHS:
        for shape in SHAPES:
            if shape == "long_500k" and arch in LONG_CONTEXT_SKIP \
                    and not include_skipped:
                continue
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ode", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    todo = []
    if args.ode:
        todo = [("__ode__", None)]
    elif args.all:
        todo = list(cells())
    else:
        todo = [(args.arch, args.shape)]

    for arch, shape in todo:
        for mp in meshes:
            if arch == "__ode__":
                rec = run_ode_cell(mp)
                name = f"ode_{'multi' if mp else 'single'}"
            else:
                rec = run_cell(arch, shape, mp, fsdp=not args.no_fsdp)
                name = f"{arch}_{shape}_{'multi' if mp else 'single'}"
            path = os.path.join(args.out, name + ".json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = "OK" if rec["ok"] else f"FAIL: {rec.get('error')}"
            print(f"[dryrun] {name}: {status} ({rec['total_s']}s)",
                  flush=True)


if __name__ == "__main__":
    main()
