import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: re-measure the three chosen cells under named
variants (hypothesis -> change -> measure; log consumed by EXPERIMENTS.md).

  python -m repro.launch.perf --cell qwen_prefill --variant a1_bf16_scores
  python -m repro.launch.perf --all
"""
import argparse
import functools
import json

from repro.launch.roofline import calibrate_cell, roofline_row

CELLS = {
    "qwen_prefill": ("qwen2.5-32b", "prefill_32k"),
    "grok_train": ("grok-1-314b", "train_4k"),
    "mamba_decode": ("mamba2-2.7b", "decode_32k"),
}

# variant name -> run_cell kwargs (the code change itself lives in the repo;
# variants toggle config-level switches where applicable)
VARIANTS = {
    "baseline": {},
    "a1a2_bf16_pipeline": {},       # code-level: bf16 scores + bf16 logits
    "b1_remat_dots": {"remat_mode": "dots"},
    "b2_zero1": {"shard_mode": "zero1"},
    "c1_state_sharding": {},        # code-level: cache_specs model sharding
}


def measure(cell_key: str, variant: str, out_dir: str):
    from repro.launch.dryrun import run_cell
    arch, shape = CELLS[cell_key]
    fn = functools.partial(run_cell, **VARIANTS[variant])
    cal = calibrate_cell(arch, shape, fn)
    if not cal.get("ok"):
        rec = {"cell": cell_key, "variant": variant,
               "error": cal.get("error")}
    else:
        rec = roofline_row(arch, shape, cal)
        rec.update({"cell": cell_key, "variant": variant})
    path = os.path.join(out_dir, f"perf_{cell_key}_{variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if "error" in rec:
        print(f"[perf] {cell_key}/{variant}: FAIL {rec['error']}", flush=True)
    else:
        print(f"[perf] {cell_key}/{variant}: "
              f"t_c={rec['t_compute_s']:.3g} t_m={rec['t_memory_s']:.3g} "
              f"t_x={rec['t_collective_s']:.3g} "
              f"bneck={rec['bottleneck']} frac={rec['roofline_fraction']:.3f}",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS))
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    if args.all:
        plan = [("qwen_prefill", "a1a2_bf16_pipeline"),
                ("grok_train", "a1a2_bf16_pipeline"),
                ("grok_train", "b1_remat_dots"),
                ("mamba_decode", "c1_state_sharding")]
        for c, v in plan:
            measure(c, v, args.out)
    else:
        measure(args.cell, args.variant, args.out)


if __name__ == "__main__":
    main()
