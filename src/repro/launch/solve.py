"""Ensemble-solve launcher: `python -m repro.launch.solve --problem lorenz
--n 100000 --ensemble kernel` — the production entry for the paper's workload.

With --mesh local the trajectory axis is shard_mapped over every available
device (the MPI composition of §6.3); straggler mitigation via the
over-decomposed WorkQueue is exercised with --work-queue.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.de_problems import (crn_problem, gbm_problem,
                                       lorenz_ensemble)
from repro.core import EnsembleProblem
from repro.core.api import ensemble_moments, solve_ensemble
from repro.core.sde import solve_sde_ensemble
from repro.dist.fault import WorkQueue
from repro.launch.mesh import make_local_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="lorenz",
                    choices=["lorenz", "gbm", "crn"])
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--ensemble", default="kernel",
                    choices=["kernel", "vmap", "array"])
    ap.add_argument("--backend", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--adaptive", action="store_true")
    ap.add_argument("--dt", type=float, default=1e-3)
    ap.add_argument("--lane-tile", type=int, default=1024)
    ap.add_argument("--mesh", default="none", choices=["none", "local"])
    ap.add_argument("--work-queue", action="store_true")
    args = ap.parse_args()

    t0 = time.perf_counter()
    if args.problem == "lorenz":
        ep = lorenz_ensemble(args.n, dtype=jnp.float32)
        mesh = make_local_mesh() if args.mesh == "local" else None
        if args.work_queue:
            # straggler-tolerant tiling: stateless tiles, safe re-execution
            q = WorkQueue(args.n, tile=args.lane_tile * 8)
            outs = np.zeros((args.n, 3), np.float32)
            while not q.finished:
                claim = q.claim()
                if claim is None:
                    break
                idx, (start, size), tok = claim
                u0s, ps = ep.materialize()
                sub = EnsembleProblem(ep.prob, size,
                                      u0s=u0s[start:start + size],
                                      ps=ps[start:start + size])
                res = solve_ensemble(sub, mesh=None, ensemble=args.ensemble,
                                     adaptive=args.adaptive, dt0=args.dt,
                                     t0=0.0, tf=1.0, save_every=1000,
                                     lane_tile=args.lane_tile)
                outs[start:start + size] = np.asarray(res.u_final)
                q.complete(idx, tok)
            u_final = outs
        else:
            res = solve_ensemble(ep, mesh=mesh, ensemble=args.ensemble,
                                 backend=args.backend,
                                 adaptive=args.adaptive, dt0=args.dt, t0=0.0,
                                 tf=1.0, save_every=1000,
                                 lane_tile=args.lane_tile,
                                 **({"saveat": jnp.asarray([1.0])}
                                    if args.adaptive else {}))
            u_final = np.asarray(res.u_final)
        print(f"{args.n:,} trajectories in {time.perf_counter()-t0:.2f}s "
              f"({args.n/(time.perf_counter()-t0):,.0f} traj/s)  "
              f"mean |u_f| = {np.abs(u_final).mean():.4f}")
    else:
        prob = gbm_problem() if args.problem == "gbm" else crn_problem(
            tspan=(0.0, 10.0))
        ep = EnsembleProblem(prob, args.n)
        res = solve_sde_ensemble(ep, jax.random.PRNGKey(0), args.dt,
                                 int(round(prob.tspan[1] / args.dt)),
                                 ensemble="kernel",
                                 save_every=int(round(prob.tspan[1]
                                                      / args.dt)))
        mean, var = ensemble_moments(res.u_final)
        print(f"{args.n:,} SDE paths in {time.perf_counter()-t0:.2f}s  "
              f"E[X_T] = {np.asarray(mean)}  Var = {np.asarray(var)}")


if __name__ == "__main__":
    main()
