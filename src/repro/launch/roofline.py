import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# (safe: only ever run as a standalone module, like dryrun.py)

"""Roofline analysis (deliverable g).

Terms per (arch x shape) on the single-pod mesh, TPU v5e constants:
    t_compute    = HLO_FLOPs_per_device   / 197e12
    t_memory     = HLO_bytes_per_device   / 819e9
    t_collective = collective_bytes_per_device / 50e9
(cost_analysis is the per-device SPMD module, so dividing per-device numbers
by per-chip peaks equals the spec's global/(chips*peak) form.)

KNOWN XLA PITFALL (measured, see EXPERIMENTS.md §Roofline-method): XLA's
cost_analysis counts a scan/while body ONCE, so any layer-scanned model
under-reports by ~L×. We therefore lower each cell at two shallow depths
(multiples of the architecture's block pattern), fit
    f(d) = base + d * per_layer
and reconstruct full-depth FLOPs/bytes/collective-bytes. The same fit is
applied to all three terms. MODEL_FLOPS is analytic (6·N_active·tokens for
training + exact attention/SSM terms), giving the MODEL/HLO "useful compute"
ratio the spec asks for.
"""
import argparse
import dataclasses
import json

from repro.configs.archs import ARCHS, LONG_CONTEXT_SKIP, get_arch
from repro.models.config import SHAPES, ModelConfig

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # B/s / chip
ICI_BW = 50e9           # B/s / link


# --------------------------------------------------------------------------
# analytic model FLOPs (the MODEL_FLOPS numerator)
# --------------------------------------------------------------------------

def _attn_flops(cfg: ModelConfig, B, T, decode_S=None):
    """QK^T + AV einsum flops, all layers, full (unmasked-dense) compute as
    implemented. Window layers use T*W."""
    H, hd = cfg.n_heads, cfg.hd
    if cfg.family == "ssm" or H == 0:
        return 0
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("R", "R", "A")
        n_att = sum(1 for i in range(cfg.n_layers)
                    if pat[i % len(pat)] == "A")
    else:
        n_att = cfg.n_layers
    if decode_S is not None:
        per = 4 * B * H * hd * min(decode_S, cfg.window or decode_S) \
            if (cfg.family == "hybrid") else 4 * B * H * hd * decode_S
        # gemma3: local layers only see the window
        if cfg.global_every:
            n_glob = cfg.n_layers // cfg.global_every
            n_loc = cfg.n_layers - n_glob
            return (n_glob * 4 * B * H * hd * decode_S
                    + n_loc * 4 * B * H * hd * min(cfg.window, decode_S))
        return n_att * per
    # full-sequence compute
    if cfg.global_every:
        n_glob = cfg.n_layers // cfg.global_every
        n_loc = cfg.n_layers - n_glob
        return (n_glob * 4 * B * H * hd * T * T
                + n_loc * 4 * B * H * hd * T * min(cfg.window, T))
    if cfg.family == "hybrid":
        return n_att * 4 * B * H * hd * T * min(cfg.window or T, T)
    extra = 0
    if cfg.family == "encdec":
        # encoder self (enc_seq^2) + cross (T*enc_seq)
        extra = (cfg.enc_layers * 4 * B * H * hd * cfg.enc_seq ** 2
                 + cfg.n_layers * 4 * B * H * hd * T * cfg.enc_seq)
    return n_att * 4 * B * H * hd * T * T + extra


def _matmul_params(cfg: ModelConfig):
    """Active params participating in matmuls per token (embed gather
    excluded; logits matmul included)."""
    n = cfg.n_params_active()
    n -= cfg.vocab_size * cfg.d_model          # embedding gather
    if cfg.tie_embeddings:
        n += cfg.vocab_padded * cfg.d_model    # tied logits matmul
    else:
        n += (cfg.vocab_padded - cfg.vocab_size) * cfg.d_model  # padding
    return n


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    s = SHAPES[shape_name]
    B, T = s.global_batch, s.seq_len
    N = _matmul_params(cfg)
    if s.kind == "train":
        return 6.0 * N * B * T + 3.0 * _attn_flops(cfg, B, T)
    if s.kind == "prefill":
        return 2.0 * N * B * T + _attn_flops(cfg, B, T)
    # decode: one token against an S-long cache
    return 2.0 * N * B + _attn_flops(cfg, B, 1, decode_S=T)


# --------------------------------------------------------------------------
# depth-calibrated HLO totals
# --------------------------------------------------------------------------

def with_depth(cfg: ModelConfig, d: int) -> ModelConfig:
    kw = {"n_layers": d}
    if cfg.family == "encdec":
        kw["enc_layers"] = d
    return dataclasses.replace(cfg, **kw)


def depth_pair(cfg: ModelConfig):
    period = (cfg.global_every or
              (len(cfg.block_pattern) if cfg.block_pattern else 0) or 1)
    d1 = period if period > 1 else 2
    return d1, 2 * d1


def calibrate_cell(arch: str, shape_name: str, run_cell_fn) -> dict:
    """Two shallow lowers -> per-layer slopes -> full-depth reconstruction."""
    cfg = get_arch(arch)
    d1, d2 = depth_pair(cfg)
    recs = {}
    for d in (d1, d2):
        sub = with_depth(cfg, d)
        # register the shallow config temporarily
        name = f"{arch}@d{d}"
        ARCHS[name] = dataclasses.replace(sub, name=name)
        try:
            # scan_unroll: XLA counts rolled scan bodies once — unroll the
            # shallow model so both depths carry their true totals.
            recs[d] = run_cell_fn(name, shape_name, False, scan_unroll=True)
        finally:
            del ARCHS[name]
        if not recs[d]["ok"]:
            return {"ok": False, "error": recs[d].get("error"),
                    "which": f"depth {d}"}
    out = {"ok": True, "d1": d1, "d2": d2}
    L = cfg.n_layers
    for k in ("flops", "hlo_bytes", "collective_bytes"):
        f1, f2 = recs[d1][k], recs[d2][k]
        per_layer = (f2 - f1) / (d2 - d1)
        base = f1 - d1 * per_layer
        if per_layer < 0 or base < 0:
            # fusion variance between depths can produce a (small) negative
            # fit component; fall back to the conservative through-origin
            # slope so the reconstruction stays positive
            per_layer = max(f2, f1) / d2
            base = 0.0
        out[k] = base + L * per_layer
        out[k + "_per_layer"] = per_layer
        out[k + "_base"] = base
    out["accum"] = recs[d1].get("accum", 1)
    return out


def roofline_row(arch: str, shape_name: str, cal: dict,
                 n_devices: int = 256) -> dict:
    cfg = get_arch(arch)
    t_c = cal["flops"] / PEAK_FLOPS
    t_m = cal["hlo_bytes"] / HBM_BW
    t_x = cal["collective_bytes"] / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    mf = model_flops(cfg, shape_name)
    hlo_global = cal["flops"] * n_devices
    return {
        "arch": arch, "shape": shape_name,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "bottleneck": dom[1],
        "model_flops_global": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else float("nan"),
        "roofline_bound_s": max(t_c, t_m, t_x),
        "roofline_fraction": t_c / max(t_c, t_m, t_x),
        "accum": cal.get("accum", 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    args = ap.parse_args()
    from repro.launch.dryrun import run_cell

    if args.arch:
        cells = [(args.arch, args.shape)]
    else:
        cells = [(a, s) for a in ARCHS for s in SHAPES
                 if not (s == "long_500k" and a in LONG_CONTEXT_SKIP)]
    rows = []
    for arch, shape in cells:
        cal = calibrate_cell(arch, shape, run_cell)
        if cal.get("ok"):
            row = roofline_row(arch, shape, cal)
            row.update({k: cal[k] for k in cal if k.endswith("_per_layer")})
        else:
            row = {"arch": arch, "shape": shape, "error": cal.get("error")}
        rows.append(row)
        with open(os.path.join(args.out, f"roofline_{arch}_{shape}.json"),
                  "w") as f:
            json.dump(row, f, indent=1)
        print(f"[roofline] {arch} x {shape}: "
              + (f"bottleneck={row.get('bottleneck')} "
                 f"frac={row.get('roofline_fraction', 0):.3f}"
                 if "error" not in row else f"FAIL {row['error']}"),
              flush=True)
    agg = "roofline_all.json" if not args.arch else \
        f"roofline_run_{args.arch}_{args.shape}.json"
    with open(os.path.join(args.out, agg), "w") as f:
        json.dump(rows, f, indent=1)
    if args.arch:
        # refresh the full aggregate from per-cell files if it exists
        full = os.path.join(args.out, "roofline_all.json")
        if os.path.exists(full):
            old = json.load(open(full))
            for i, r in enumerate(old):
                pc = os.path.join(args.out,
                                  f"roofline_{r['arch']}_{r['shape']}.json")
                if os.path.exists(pc):
                    old[i] = json.load(open(pc))
            json.dump(old, open(full, "w"), indent=1)


if __name__ == "__main__":
    main()
