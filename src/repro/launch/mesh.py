"""Production mesh construction.

IMPORTANT: functions only — importing this module must never touch jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else sees
the real single device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 ("data","model") single-pod (256 chips) or 2x16x16
    ("pod","data","model") multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist, as a 1D 'data' mesh (CPU tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
