"""Public wrapper for the fused explicit-RK ensemble Pallas kernel.

All padding / grid / stats plumbing lives in the generic factory
(`repro.kernels.ensemble_kernel.run_ensemble_kernel`); this wrapper only
instantiates the ERK loop body on the problem — and, when the save grid is
too large for the VMEM budget, routes through the double-buffered staged
driver (`run_ensemble_kernel_staged`) instead of over-subscribing VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ensemble import EnsembleResult
from repro.core.interp import data_flatten, data_words
from repro.core.tableaus import Tableau
from repro.kernels.ensemble_kernel import (erk_body, erk_work_words,
                                           run_ensemble_kernel,
                                           run_ensemble_kernel_staged,
                                           save_chunk_count)


def solve_ensemble_pallas(prob, u0s, ps, tab: Tableau, t0, tf, dt0, saveat,
                          rtol, atol, adaptive, lane_tile=None,
                          max_iters=100_000, event=None,
                          interpret=None, save_chunks=None,
                          data=None) -> EnsembleResult:
    """EnsembleGPUKernel entry point (called via ensemble="kernel",
    backend="pallas"). lane_tile=None derives the tile from the §5.2 VMEM
    formula.

    `save_chunks=None` auto-activates the double-buffered save staging
    (`run_ensemble_kernel_staged`) when the whole (S, n, B) output block
    exceeds the VMEM budget even at the minimum lane tile; pass an explicit
    count to force (or `1` to forbid) staging.  Staging needs a concrete,
    ascending, post-t0 save grid and no event (event counters cannot thread
    across segment boundaries) — anything else falls back to the single
    launch unchanged.

    `data` is the problem's dataset pytree (tables): its leaves ride "table"
    BlockSpecs into VMEM (appended LAST in the extras — the factory
    convention), the body re-binds `f(u, p, t, data)` over the rebuilt
    tables, and the broadcast footprint is charged to the VMEM budget as
    `fixed_words` so auto lane_tile and staging stay honest.
    """
    saveat = jnp.asarray(saveat, u0s.dtype)
    work_words = erk_work_words(u0s.shape[1], ps.shape[1], tab.stages)
    fixed_words = data_words(data)
    data_extras = [("table", leaf) for leaf in data_flatten(data)[0]]
    if save_chunks is None:
        save_chunks = save_chunk_count(u0s.shape[1], ps.shape[1],
                                       int(saveat.shape[0]),
                                       itemsize=u0s.dtype.itemsize,
                                       work_words=work_words,
                                       fixed_words=fixed_words)

    def mk_body(t_start, t_end):
        return erk_body(prob.f, tab, t0=float(t_start), tf=float(t_end),
                        dt0=float(dt0), rtol=float(rtol), atol=float(atol),
                        adaptive=adaptive, max_iters=max_iters, event=event,
                        data=data)

    stageable = (save_chunks > 1 and event is None
                 and not isinstance(saveat, jax.core.Tracer)
                 and saveat.shape[0] > 1
                 and bool(saveat[0] > t0)
                 and bool(jnp.all(jnp.diff(saveat) > 0)))
    if stageable:
        def body_factory(t_start, seg_ts, last):
            seg_t0 = t0 if t_start is None else t_start
            seg_tf = tf if last else float(seg_ts[-1])
            sv = jnp.asarray(seg_ts, u0s.dtype)
            return mk_body(seg_t0, seg_tf), [("broadcast", sv)] + data_extras

        return run_ensemble_kernel_staged(
            body_factory, u0s, ps, ts=saveat, save_chunks=save_chunks,
            lane_tile=lane_tile, work_words=work_words, interpret=interpret,
            fixed_words=fixed_words)

    return run_ensemble_kernel(
        mk_body(t0, tf), u0s, ps, ts=saveat,
        extras=[("broadcast", saveat)] + data_extras,
        lane_tile=lane_tile, work_words=work_words, interpret=interpret,
        fixed_words=fixed_words)
