"""Public wrapper for the fused explicit-RK ensemble Pallas kernel.

All padding / grid / stats plumbing lives in the generic factory
(`repro.kernels.ensemble_kernel.run_ensemble_kernel`); this wrapper only
instantiates the ERK loop body on the problem.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.ensemble import EnsembleResult
from repro.core.tableaus import Tableau
from repro.kernels.ensemble_kernel import (erk_body, erk_work_words,
                                           run_ensemble_kernel)


def solve_ensemble_pallas(prob, u0s, ps, tab: Tableau, t0, tf, dt0, saveat,
                          rtol, atol, adaptive, lane_tile=None,
                          max_iters=100_000, event=None,
                          interpret=None) -> EnsembleResult:
    """EnsembleGPUKernel entry point (called via ensemble="kernel",
    backend="pallas"). lane_tile=None derives the tile from the §5.2 VMEM
    formula."""
    saveat = jnp.asarray(saveat, u0s.dtype)
    body = erk_body(prob.f, tab, t0=float(t0), tf=float(tf), dt0=float(dt0),
                    rtol=float(rtol), atol=float(atol), adaptive=adaptive,
                    max_iters=max_iters, event=event)
    return run_ensemble_kernel(
        body, u0s, ps, ts=saveat, extras=[("broadcast", saveat)],
        lane_tile=lane_tile,
        work_words=erk_work_words(u0s.shape[1], ps.shape[1], tab.stages),
        interpret=interpret)
