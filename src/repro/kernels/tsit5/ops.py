"""Jit'd public wrapper for the fused-integration Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ensemble import EnsembleResult
from repro.core.tableaus import Tableau

from .kernel import tsit5_pallas_call


def _pad_lanes(x, B):
    N = x.shape[-1]
    pad = (-N) % B
    if pad == 0:
        return x, N
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)], mode="edge"), N


def solve_ensemble_pallas(prob, u0s, ps, tab: Tableau, t0, tf, dt0, saveat,
                          rtol, atol, adaptive, lane_tile=128,
                          max_iters=100_000, event=None,
                          interpret=None) -> EnsembleResult:
    """EnsembleGPUKernel entry point (called via ensemble="kernel",
    backend="pallas"). Pads the trajectory axis to the lane tile, launches the
    grid, unpads, and returns the standard EnsembleResult."""
    u0_l, N = _pad_lanes(u0s.T, lane_tile)
    p_l, _ = _pad_lanes(ps.T, lane_tile)
    us, uf, t_fin, stats = tsit5_pallas_call(
        prob.f, tab, u0_l, p_l, t0=t0, tf=tf, dt0=dt0, saveat=saveat,
        rtol=rtol, atol=atol, adaptive=adaptive, max_iters=max_iters,
        lane_tile=lane_tile, event=event, interpret=interpret)
    us = jnp.moveaxis(us, -1, 0)[:N]          # (N, S, n)
    return EnsembleResult(
        ts=jnp.asarray(saveat, u0s.dtype), us=us, u_final=uf.T[:N],
        t_final=t_fin[:N], naccept=stats[0, :N], nreject=stats[1, :N],
        nf=jnp.sum(stats[3, :N]), status=jnp.max(stats[2, :N]))
