"""Pure-jnp oracle for the fused-integration kernel: vmap of the scalar-mode
reference solver (independent control-flow path from the lanes engine)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.solvers import solve_one
from repro.core.tableaus import Tableau


def ref_solve(f, tab: Tableau, u0s, ps, t0, tf, dt0, saveat, rtol, atol,
              adaptive=True, max_iters=100_000, event=None):
    """u0s (N,n), ps (N,m) -> (us (N,S,n), uf (N,n), t_final (N,),
    naccept (N,), nreject (N,))."""

    def one(u0, p):
        r = solve_one(f, tab, u0, p, t0, tf, dt0, saveat=saveat, rtol=rtol,
                      atol=atol, adaptive=adaptive, max_iters=max_iters,
                      event=event)
        if event is not None:
            r, _ = r
        return r

    res = jax.vmap(one)(u0s, ps)
    return res.us, res.u_final, res.t_final, res.naccept, res.nreject
