"""EnsembleGPUKernel for TPU: whole-ODE-integration kernel (paper §5.2).

This module used to own a bespoke `pallas_call` factory (`build_ode_kernel`:
grid/BlockSpec plumbing, padding, stats assembly) specialized to explicit-RK
tableaus.  All of that plumbing now lives exactly once in the generic factory
`repro.kernels.ensemble_kernel`; the ERK loop body (`erk_body`) IS the shared
lanes-mode solver engine (`repro.core.solvers.solve_adaptive(lanes=True)`),
specialized (closure/JIT) on the user's RHS and tableau — the paper's
"automated translation" of one problem definition into a device kernel.

TPU mapping (unchanged): VREG lane <- 1 trajectory; pallas grid over lane
tiles; loop-carried VMEM state; whole `while t < tf` in one grid cell with
per-lane dt/accept masks; (S, n, LANES) output block flushed once at the end.

See `ops.solve_ensemble_pallas` for the public entry point.
"""
from __future__ import annotations

from repro.kernels.ensemble_kernel import erk_body, erk_work_words  # noqa: F401
