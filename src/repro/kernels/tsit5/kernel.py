"""EnsembleGPUKernel for TPU: whole-ODE-integration Pallas kernel (paper §5.2).

Mapping of the paper's CUDA design onto the TPU:

  CUDA thread <- 1 trajectory          =>  VREG lane <- 1 trajectory
  grid of thread blocks                =>  pallas grid over lane tiles (LANES)
  registers / stack-allocated arrays   =>  loop-carried VMEM values (never HBM)
  whole `while t < tf` in one launch   =>  whole lax.while_loop in one grid cell
  per-thread divergent adaptive dt     =>  per-lane dt + accept masks
  coalesced writes of the solution     =>  (S, n, LANES) VMEM output block,
                                           flushed once at kernel end

The kernel body *is* the shared lanes-mode solver engine
(`repro.core.solvers.solve_adaptive(lanes=True)`): the paper's "automated
translation" — the same user RHS and the same numerical engine are instantiated
inside the device kernel, specialized (JIT) on the problem. BlockSpecs tile the
trajectory axis; each tile's integration runs to completion independently
(tile-local termination — no global synchronization, §5.1.4's drawback removed).

VMEM budget per tile:  4B * LANES * (S*n [output] + ~3n [state+stages]
+ m [params] + ~8 [control]) — e.g. S=100, n=3, m=3, LANES=256 ≈ 0.4 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.controller import PIController
from repro.core.solvers import AdaptiveOptions, Event, solve_adaptive
from repro.core.tableaus import Tableau


def build_ode_kernel(f, tab: Tableau, *, n_state: int, n_param: int,
                     t0: float, tf: float, dt0: float, saveat, rtol: float,
                     atol: float, adaptive: bool, max_iters: int,
                     event: Optional[Event] = None):
    """Return the Pallas kernel body specialized on (f, tableau, constants).

    Constants are baked into the kernel (closure specialization) exactly as the
    paper's kernel generator compiles the problem definition into the kernel.
    """
    def kernel(u0_ref, p_ref, saveat_ref, us_ref, uf_ref, tfin_ref,
               stats_ref):
        u0 = u0_ref[...]                       # (n, LANES) VMEM block
        p = p_ref[...]                         # (m, LANES)
        dtype = u0.dtype
        saveat_v = saveat_ref[0]               # (S,) broadcast to every tile
        opts = AdaptiveOptions(rtol=rtol, atol=atol, max_iters=max_iters,
                               adaptive=adaptive)
        res = solve_adaptive(f, tab, u0, p, t0, tf, dt0, saveat=saveat_v,
                             opts=opts, event=event, lanes=True)
        if event is not None:
            res, _ = res
        us_ref[...] = res.us                   # (S, n, LANES): one HBM flush
        uf_ref[...] = res.u_final
        tfin_ref[...] = res.t_final[None]
        stats_ref[...] = jnp.stack([res.naccept, res.nreject,
                                    res.status * jnp.ones_like(res.naccept),
                                    res.nf])

    return kernel


def tsit5_pallas_call(f, tab: Tableau, u0_lanes, p_lanes, *, t0, tf, dt0,
                      saveat, rtol=1e-6, atol=1e-6, adaptive=True,
                      max_iters=100_000, lane_tile=128, event=None,
                      interpret=None):
    """pallas_call wrapper: grid over trajectory tiles with explicit BlockSpecs.

    u0_lanes: (n, N), p_lanes: (m, N); N must be a multiple of lane_tile
    (ops.py pads). Returns (us (S,n,N), uf (n,N), t_final (N,), stats (4,N)).
    """
    n, N = u0_lanes.shape
    m = p_lanes.shape[0]
    S = len(saveat)
    assert N % lane_tile == 0, "pad N to a multiple of lane_tile"
    T = N // lane_tile
    B = lane_tile
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    dtype = u0_lanes.dtype

    kernel = build_ode_kernel(
        f, tab, n_state=n, n_param=m, t0=float(t0), tf=float(tf),
        dt0=float(dt0), saveat=saveat, rtol=float(rtol), atol=float(atol),
        adaptive=adaptive, max_iters=max_iters, event=event)

    out_shape = [
        jax.ShapeDtypeStruct((S, n, N), dtype),         # us
        jax.ShapeDtypeStruct((n, N), dtype),            # u_final
        jax.ShapeDtypeStruct((1, N), dtype),            # t_final
        jax.ShapeDtypeStruct((4, N), jnp.int32),        # naccept/nreject/status/nf
    ]
    grid = (T,)
    in_specs = [
        pl.BlockSpec((n, B), lambda i: (0, i)),
        pl.BlockSpec((m, B), lambda i: (0, i)),
        pl.BlockSpec((1, S), lambda i: (0, 0)),   # saveat: same for all tiles
    ]
    out_specs = [
        pl.BlockSpec((S, n, B), lambda i: (0, 0, i)),
        pl.BlockSpec((n, B), lambda i: (0, i)),
        pl.BlockSpec((1, B), lambda i: (0, i)),
        pl.BlockSpec((4, B), lambda i: (0, i)),
    ]
    fn = pl.pallas_call(kernel, grid=grid, in_specs=in_specs,
                        out_specs=out_specs, out_shape=out_shape,
                        interpret=interpret)
    saveat_arr = jnp.asarray(saveat, dtype)[None, :]
    us, uf, t_fin, stats = fn(u0_lanes, p_lanes, saveat_arr)
    return us, uf, t_fin[0], stats
