"""Jit'd wrapper for the SDE ensemble Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.sde import EnsembleSDEResult


def _pad_lanes(x, B):
    N = x.shape[-1]
    pad = (-N) % B
    if pad == 0:
        return x, N
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)], mode="edge"), N


def solve_sde_ensemble_pallas(prob, u0s, ps, key, t0, dt, n_steps,
                              method="em", save_every=1, lane_tile=128,
                              seed=None, noise_table=None,
                              interpret=None) -> EnsembleSDEResult:
    from .kernel import em_pallas_call
    if seed is None:
        seed = int(jnp.asarray(key)[-1]) if key is not None else 0
    u0_l, N = _pad_lanes(u0s.T, lane_tile)
    p_l, _ = _pad_lanes(ps.T, lane_tile)
    if noise_table is not None:
        noise_table, _ = _pad_lanes(noise_table, lane_tile)
    us, uf = em_pallas_call(
        prob.f, prob.g, u0_l, p_l, noise=prob.noise, method=method, t0=t0,
        dt=dt, n_steps=n_steps, save_every=save_every,
        m_noise=prob.noise_dim(), seed=seed, noise_table=noise_table,
        lane_tile=lane_tile, interpret=interpret)
    ts = jnp.asarray(t0, u0s.dtype) + dt * save_every * jnp.arange(
        1, n_steps // save_every + 1, dtype=u0s.dtype)
    return EnsembleSDEResult(ts=ts, us=jnp.moveaxis(us, -1, 0)[:N],
                             u_final=uf.T[:N],
                             nf=jnp.asarray(n_steps * N))
