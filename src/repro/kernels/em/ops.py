"""Public wrapper for the fused SDE ensemble Pallas kernel.

Padding / grid / stats plumbing lives in the generic factory
(`repro.kernels.ensemble_kernel.run_ensemble_kernel`); this wrapper
instantiates the SDE loop body (counter-RNG or noise-table flavour) on the
problem and adapts the unified EnsembleResult to the SDE-facing result type.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.interp import data_flatten, data_words
from repro.core.sde import (SDE_STEPPERS, EnsembleSDEResult, sde_nf_per_step,
                            sde_save_grid)
from repro.kernels.ensemble_kernel import (run_ensemble_kernel, sde_body,
                                           sde_work_words)


def solve_sde_ensemble_pallas(prob, u0s, ps, key, t0, dt, n_steps,
                              method="em", save_every=1, lane_tile=None,
                              seed=None, noise_table=None,
                              interpret=None) -> EnsembleSDEResult:
    if seed is None:
        seed = int(jnp.asarray(key)[-1]) if key is not None else 0
    res = solve_sde_ensemble_kernel(
        prob, u0s, ps, t0=t0, dt=dt, n_steps=n_steps, method=method,
        save_every=save_every, lane_tile=lane_tile, seed=seed,
        noise_table=noise_table, interpret=interpret)
    return EnsembleSDEResult(ts=res.ts, us=res.us, u_final=res.u_final,
                             nf=res.nf)


def solve_sde_ensemble_kernel(prob, u0s, ps, *, t0, dt, n_steps,
                              method="em", save_every=1, lane_tile=None,
                              seed=0, noise_table=None, interpret=None,
                              event=None, lane_offset=0, data=None):
    """Unified-result SDE kernel entry (returns an EnsembleResult).

    noise_table: optional (n_steps, m, N) pre-drawn N(0,1), tiled over the
    trajectory axis alongside the state. lane_tile=None derives the tile from
    the §5.2 VMEM formula.  lane_offset shifts the counter-RNG lane indices to
    this shard's GLOBAL trajectory indices (mesh-sharded ensembles).
    `data` (the problem's dataset pytree) broadcasts its table leaves into
    VMEM as trailing "table" extras, charged to the budget as fixed_words."""
    assert n_steps % save_every == 0
    m_noise = prob.noise_dim()
    body = sde_body(prob.f, prob.g, SDE_STEPPERS[method], prob.noise,
                    t0=float(t0), dt=float(dt), n_steps=n_steps,
                    save_every=save_every, m_noise=m_noise, seed=seed,
                    use_table=noise_table is not None,
                    nf_per_step=sde_nf_per_step(method), event=event,
                    data=data)
    ts = sde_save_grid(t0, dt, n_steps, save_every, u0s.dtype)
    extras = [("broadcast", jnp.asarray([lane_offset], jnp.uint32))]
    if noise_table is not None:
        extras.append(("lanes", noise_table))
    extras += [("table", leaf) for leaf in data_flatten(data)[0]]
    return run_ensemble_kernel(
        body, u0s, ps, ts=ts, extras=extras, lane_tile=lane_tile,
        work_words=sde_work_words(u0s.shape[1], ps.shape[1], m_noise),
        interpret=interpret, fixed_words=data_words(data))
