"""GPUEM / GPUSIEA: fixed-step SDE ensemble Pallas kernel (paper §5.2.2, §6.8).

Same TPU mapping as the tsit5 kernel (lane = trajectory, whole integration in
one grid cell, VMEM-resident state). Noise is generated *inside* the kernel
from a counter-based Threefry RNG keyed by (seed; step, noise-row, global
lane) — the kernel needs no noise storage and any step is replayable (the
paper's per-thread cuRAND design). A pre-drawn noise table can be passed
instead for pathwise validation against the oracle.

Steppers are the shared `repro.core.sde` definitions — the kernel is the same
math as the XLA path, specialized and tiled.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sde import SDE_STEPPERS
from repro.kernels.rng import counter_normals_threefry


def build_em_kernel(f, g, noise: str, method: str, *, t0: float, dt: float,
                    n_steps: int, save_every: int, n_state: int, m_noise: int,
                    lane_tile: int, seed: int, use_table: bool):
    stepper = SDE_STEPPERS[method]
    S = n_steps // save_every
    B = lane_tile

    def body_with(noise_fn, u0_ref, p_ref, us_ref, uf_ref):
        u0 = u0_ref[...]                  # (n, B)
        p = p_ref[...]
        dtype = u0.dtype
        sdt = jnp.sqrt(jnp.asarray(dt, dtype))
        tile = pl.program_id(0)
        lane = (jnp.uint32(tile) * jnp.uint32(B)
                + jax.lax.broadcasted_iota(jnp.uint32, (m_noise, B), 1))
        rows = jax.lax.broadcasted_iota(jnp.uint32, (m_noise, B), 0)

        def step(k, carry):
            u, us = carry
            z = noise_fn(k, lane, rows, dtype)
            t = t0 + k * jnp.asarray(dt, dtype)
            u = stepper(f, g, u, p, t, jnp.asarray(dt, dtype), z * sdt, noise)
            s = (k + 1) // save_every - 1
            write = (k + 1) % save_every == 0
            us = jax.lax.cond(
                write,
                lambda us: jax.lax.dynamic_update_slice(us, u[None], (s, 0, 0)),
                lambda us: us, us)
            return (u, us)

        us0 = jnp.zeros((S, n_state, B), dtype)
        u_f, us = jax.lax.fori_loop(0, n_steps, step, (u0, us0))
        us_ref[...] = us
        uf_ref[...] = u_f

    if use_table:
        def kernel(u0_ref, p_ref, table_ref, us_ref, uf_ref):
            def noise_fn(k, lane, rows, dtype):
                return jax.lax.dynamic_slice(
                    table_ref[...], (k, 0, 0),
                    (1, m_noise, B))[0].astype(dtype)
            body_with(noise_fn, u0_ref, p_ref, us_ref, uf_ref)
    else:
        def kernel(u0_ref, p_ref, us_ref, uf_ref):
            def noise_fn(k, lane, rows, dtype):
                return counter_normals_threefry(seed, k, lane, rows, dtype)
            body_with(noise_fn, u0_ref, p_ref, us_ref, uf_ref)

    return kernel


def em_pallas_call(f, g, u0_lanes, p_lanes, *, noise="diagonal", method="em",
                   t0=0.0, dt=1e-3, n_steps=1000, save_every=1000,
                   m_noise=None, seed=0, noise_table=None, lane_tile=128,
                   interpret=None):
    """u0_lanes (n, N), p_lanes (m, N); N % lane_tile == 0 (ops.py pads).
    noise_table: optional (n_steps, m_noise, N) pre-drawn N(0,1)."""
    n, N = u0_lanes.shape
    mp = p_lanes.shape[0]
    if m_noise is None:
        m_noise = n
    assert N % lane_tile == 0
    assert n_steps % save_every == 0
    S = n_steps // save_every
    T = N // lane_tile
    B = lane_tile
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    dtype = u0_lanes.dtype

    kernel = build_em_kernel(
        f, g, noise, method, t0=float(t0), dt=float(dt), n_steps=n_steps,
        save_every=save_every, n_state=n, m_noise=m_noise, lane_tile=B,
        seed=seed, use_table=noise_table is not None)

    in_specs = [
        pl.BlockSpec((n, B), lambda i: (0, i)),
        pl.BlockSpec((mp, B), lambda i: (0, i)),
    ]
    args = [u0_lanes, p_lanes]
    if noise_table is not None:
        in_specs.append(pl.BlockSpec((n_steps, m_noise, B),
                                     lambda i: (0, 0, i)))
        args.append(noise_table)
    out_shape = [
        jax.ShapeDtypeStruct((S, n, N), dtype),
        jax.ShapeDtypeStruct((n, N), dtype),
    ]
    out_specs = [
        pl.BlockSpec((S, n, B), lambda i: (0, 0, i)),
        pl.BlockSpec((n, B), lambda i: (0, i)),
    ]
    fn = pl.pallas_call(kernel, grid=(T,), in_specs=in_specs,
                        out_specs=out_specs, out_shape=out_shape,
                        interpret=interpret)
    us, uf = fn(*args)
    return us, uf
