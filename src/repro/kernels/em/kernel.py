"""GPUEM / GPUSIEA: fixed-step SDE ensemble kernel (paper §5.2.2, §6.8).

The bespoke `pallas_call` plumbing that used to live here (grid, BlockSpecs,
padding, table wiring) is now the generic factory
`repro.kernels.ensemble_kernel`; the SDE loop body (`sde_body`) keeps the
exact same semantics:

  * steppers are the shared `repro.core.sde` definitions — the kernel is the
    same math as the XLA path, specialized and tiled;
  * noise is generated *inside* the kernel from a counter-based Threefry RNG
    keyed by (seed; step, noise-row, global lane) — no noise storage, any
    step replayable (the paper's per-thread cuRAND design);
  * a pre-drawn noise table can be passed instead for pathwise validation.

See `ops.solve_sde_ensemble_pallas` for the public entry point.
"""
from __future__ import annotations

from repro.kernels.ensemble_kernel import sde_body, sde_work_words  # noqa: F401
