"""Pure-jnp oracle for the SDE kernel: lanes-mode scan using the SAME stepper
definitions and (optionally) the SAME counter RNG, so pathwise comparison is
exact — not just statistical."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.loops import checkpointed_fori
from repro.core.sde import (SDE_STEPPERS, sde_event_state0, sde_step_and_save,
                            sde_step_save_event)
from repro.kernels.rng import counter_normals_threefry


def ref_solve(prob, u0s, ps, *, t0, dt, n_steps, method="em", save_every=1,
              seed=0, noise_table=None, event=None, lane_offset=0,
              remat=False, checkpoint_every=None):
    """u0s (N, n), ps (N, m). Replays the kernel's exact noise stream
    (threefry counters over GLOBAL lane indices: local index + lane_offset)
    or a supplied table.  With an event, runs the shared event-aware loop
    body (per-lane termination masks).
    remat=True swaps the step loop for `repro.core.loops.checkpointed_fori`:
    the identical index sequence (bitwise-equal primal), but reverse-mode AD
    stores one carry per `checkpoint_every` steps and replays the counter-RNG
    noise inside segments — the memory-bounded pathwise adjoint.
    Returns (us (S, n, N), uf (n, N), estate-or-None)."""
    stepper = SDE_STEPPERS[method]
    u0 = u0s.T
    p = ps.T
    n, N = u0.shape
    m = prob.noise_dim()
    dtype = u0.dtype
    S = n_steps // save_every
    gl = jnp.arange(N, dtype=jnp.uint32) + jnp.asarray(lane_offset, jnp.uint32)
    lane = jnp.broadcast_to(gl[None], (m, N))
    rows = jnp.broadcast_to(jnp.arange(m, dtype=jnp.uint32)[:, None], (m, N))

    if remat:
        def loop(lo, hi, body, init):
            return checkpointed_fori(lo, hi, body, init,
                                     checkpoint_every=checkpoint_every)
    else:
        loop = jax.lax.fori_loop

    def noise(k):
        if noise_table is not None:
            z = jax.lax.dynamic_slice(noise_table, (k, 0, 0), (1, m, N))[0]
            return z.astype(dtype)
        return counter_normals_threefry(seed, k, lane, rows, dtype)

    us0 = jnp.zeros((S, n, N), dtype)
    if event is None:
        def step(k, carry):
            u, us = carry
            return sde_step_and_save(stepper, prob.f, prob.g, prob.noise, u,
                                     us, p, t0, dt, k, noise(k), save_every)

        u_f, us = loop(0, n_steps, step, (u0, us0))
        return us, u_f, None

    def step(k, carry):
        u, us, estate = carry
        return sde_step_save_event(stepper, prob.f, prob.g, prob.noise, event,
                                   u, us, estate, p, t0, dt, k, noise(k),
                                   save_every)

    estate0 = sde_event_state0((N,), t0, dtype)
    u_f, us, estate = loop(0, n_steps, step, (u0, us0, estate0))
    return us, u_f, estate
