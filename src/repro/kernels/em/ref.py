"""Pure-jnp oracle for the SDE kernel: lanes-mode scan using the SAME stepper
definitions and (optionally) the SAME counter RNG, so pathwise comparison is
exact — not just statistical."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sde import SDE_STEPPERS, sde_step_and_save
from repro.kernels.rng import counter_normals_threefry


def ref_solve(prob, u0s, ps, *, t0, dt, n_steps, method="em", save_every=1,
              seed=0, noise_table=None):
    """u0s (N, n), ps (N, m). Replays the kernel's exact noise stream
    (threefry counters over global lane indices) or a supplied table.
    Returns (us (S, n, N), uf (n, N))."""
    stepper = SDE_STEPPERS[method]
    u0 = u0s.T
    p = ps.T
    n, N = u0.shape
    m = prob.noise_dim()
    dtype = u0.dtype
    S = n_steps // save_every
    lane = jnp.broadcast_to(jnp.arange(N, dtype=jnp.uint32)[None], (m, N))
    rows = jnp.broadcast_to(jnp.arange(m, dtype=jnp.uint32)[:, None], (m, N))

    def step(k, carry):
        u, us = carry
        if noise_table is not None:
            z = jax.lax.dynamic_slice(noise_table, (k, 0, 0), (1, m, N))[0]
            z = z.astype(dtype)
        else:
            z = counter_normals_threefry(seed, k, lane, rows, dtype)
        return sde_step_and_save(stepper, prob.f, prob.g, prob.noise, u, us,
                                 p, t0, dt, k, z, save_every)

    us0 = jnp.zeros((S, n, N), dtype)
    u_f, us = jax.lax.fori_loop(0, n_steps, step, (u0, us0))
    return us, u_f
