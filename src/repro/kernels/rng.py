"""Counter-based RNG for inside-kernel noise generation (paper §6.8).

The paper's GPU kernels draw per-thread noise from a counter-based PRNG; the
TPU-native equivalent is `pltpu.prng_seed`/`prng_random_bits`, but that
primitive has no CPU/interpret lowering, so kernels default to a hand-rolled
**Threefry-2x32 (20 rounds)** — the same generator JAX itself uses — built from
32-bit adds/xors/rotates only (TPU-friendly, identical bits on every backend,
replayable from (seed, lane, step) counters).  `impl="tpu"` switches to the
hardware PRNG on real TPUs.
"""
from __future__ import annotations

import jax.numpy as jnp

_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = 0x1BD11BDA  # python int: kernels may not capture array constants


def _rotl(x, r):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0, k1, c0, c1):
    """Threefry-2x32, 20 rounds. All args uint32 arrays (broadcastable).
    Returns two uint32 arrays of the broadcast shape."""
    ks0 = jnp.uint32(k0)
    ks1 = jnp.uint32(k1)
    ks2 = ks0 ^ ks1 ^ jnp.uint32(_PARITY)
    x0 = jnp.asarray(c0, jnp.uint32) + ks0
    x1 = jnp.asarray(c1, jnp.uint32) + ks1
    subkeys = ((ks1, ks2), (ks2, ks0), (ks0, ks1), (ks1, ks2), (ks2, ks0))
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        a, b = subkeys[i]
        x0 = x0 + a
        x1 = x1 + b + jnp.uint32(i + 1)
    return x0, x1


def _to_unit(bits):
    """uint32 -> float in (0, 1): (bits + 0.5) / 2^32, exact in f32 range."""
    return (bits.astype(jnp.float32) + 0.5) * jnp.float32(2.0 ** -32)


def counter_normals_threefry(seed, step, lane_idx, row_idx, dtype=jnp.float32):
    """N(0,1) draws indexed by (seed; step, noise-row, lane) — one value per
    (row_idx, lane_idx) element via Box-Muller on two threefry words.

    lane_idx: (…,) global trajectory indices (uint32-able)
    row_idx:  (…,) noise-component indices, broadcastable against lane_idx.
    """
    c0 = (jnp.asarray(step, jnp.uint32) * jnp.uint32(0x9E3779B9)
          + jnp.asarray(row_idx, jnp.uint32))
    c1 = jnp.asarray(lane_idx, jnp.uint32)
    x0, x1 = threefry2x32(jnp.uint32(seed), jnp.uint32(0x243F6A88), c0, c1)
    u1 = _to_unit(x0)
    u2 = _to_unit(x1)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    return z.astype(dtype)
