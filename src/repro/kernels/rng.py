"""Counter-based RNG for inside-kernel noise generation (paper §6.8).

The paper's GPU kernels draw per-thread noise from a counter-based PRNG; the
TPU-native equivalent is `pltpu.prng_seed`/`prng_random_bits`, but that
primitive has no CPU/interpret lowering, so kernels default to a hand-rolled
**Threefry-2x32 (20 rounds)** — the same generator JAX itself uses — built from
32-bit adds/xors/rotates only (TPU-friendly, identical bits on every backend,
replayable from (seed, lane, step) counters).  `impl="tpu"` switches to the
hardware PRNG on real TPUs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = 0x1BD11BDA  # python int: kernels may not capture array constants


def _rotl(x, r):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0, k1, c0, c1):
    """Threefry-2x32, 20 rounds. All args uint32 arrays (broadcastable).
    Returns two uint32 arrays of the broadcast shape."""
    ks0 = jnp.uint32(k0)
    ks1 = jnp.uint32(k1)
    ks2 = ks0 ^ ks1 ^ jnp.uint32(_PARITY)
    x0 = jnp.asarray(c0, jnp.uint32) + ks0
    x1 = jnp.asarray(c1, jnp.uint32) + ks1
    subkeys = ((ks1, ks2), (ks2, ks0), (ks0, ks1), (ks1, ks2), (ks2, ks0))
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        a, b = subkeys[i]
        x0 = x0 + a
        x1 = x1 + b + jnp.uint32(i + 1)
    return x0, x1


def _to_unit(bits):
    """uint32 -> float in (0, 1): (bits + 0.5) / 2^32, exact in f32 range."""
    return (bits.astype(jnp.float32) + 0.5) * jnp.float32(2.0 ** -32)


def bridge_normals(seed, node, lane_idx, row_idx, dtype=jnp.float32):
    """N(0,1) draws for the virtual Brownian bridge, indexed by
    (seed; tree-node, noise-row, lane).

    Same Threefry core as `counter_normals_threefry` but keyed with a
    different second key word, so the bridge stream is independent of the
    fixed-dt per-step stream under the same seed.
    """
    c0 = (jnp.asarray(node, jnp.uint32) * jnp.uint32(0x9E3779B9)
          + jnp.asarray(row_idx, jnp.uint32))
    c1 = jnp.asarray(lane_idx, jnp.uint32)
    x0, x1 = threefry2x32(jnp.uint32(seed), jnp.uint32(0x85A308D3), c0, c1)
    u1 = _to_unit(x0)
    u2 = _to_unit(x1)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    return z.astype(dtype)


def brownian_bridge_point(seed, idx, lane_idx, row_idx, *, depth, t_total,
                          dtype=jnp.float32):
    """W(idx * t_total / 2**depth) of a standard Wiener path on [0, t_total].

    The path is a *virtual Brownian tree* (Levy bridge construction, cf.
    RSwM / torchsde's BrownianTree): W is a pure function of
    (seed; lane, row, dyadic index), evaluated by descending `depth` levels of
    midpoint-conditioned draws.  Because the value at a grid point never
    depends on the *step sequence* that queried it, a rejected step replays
    exactly the same increments when retried with a smaller dt — bitwise, on
    every strategy and backend.  That is the property that makes adaptive SDE
    stepping cross-backend deterministic.

    idx: integer array (broadcastable against lane_idx/row_idx) in
         [0, 2**depth]; each element may name a different grid point (per-lane
         adaptive dt).
    Cost: `depth` Threefry evaluations per point.

    **Rejection/replay contract** (what the adaptive SDE engine and the
    property tests in `tests/test_bridge_props.py` rely on):

    1. W(idx) depends ONLY on (seed; lane, row, idx, depth, t_total) — never
       on query order, query shape, or any other index queried before or
       after.  Any reject -> shrink -> redraw sequence therefore replays the
       sub-interval increments bitwise, on every strategy and backend.
    2. W(0) == 0 exactly, and increments telescope exactly: for any grid
       partition i0 < i1 < ... < ik, sum of W(i_{j+1}) - W(i_j) equals
       W(ik) - W(i0) in floating point up to associativity of the sum.
    3. Conditionally on W(l) and W(r) for an enclosing dyadic interval
       [l, r], the midpoint is N((W(l)+W(r))/2, (t_r - t_l)/4) — the Levy
       bridge construction, which is what makes per-lane step sequences
       statistically consistent regardless of accept/reject history.
    """
    idx = jnp.asarray(idx, jnp.uint32)
    shape = jnp.broadcast_shapes(jnp.shape(idx), jnp.shape(lane_idx),
                                 jnp.shape(row_idx))
    idx = jnp.broadcast_to(idx, shape)
    lane_idx = jnp.broadcast_to(jnp.asarray(lane_idx, jnp.uint32), shape)
    row_idx = jnp.broadcast_to(jnp.asarray(row_idx, jnp.uint32), shape)
    t_total = jnp.asarray(t_total, dtype)
    h_res = t_total / (2 ** depth)           # grid resolution in time units
    # endpoint draw: W(t_total) ~ N(0, t_total), tree node 0
    w_l = jnp.zeros(shape, dtype)
    w_r = jnp.sqrt(t_total) * bridge_normals(seed, jnp.zeros(shape, jnp.uint32),
                                             lane_idx, row_idx, dtype)
    l = jnp.zeros(shape, jnp.uint32)
    r = jnp.full(shape, 2 ** depth, jnp.uint32)
    nid = jnp.ones(shape, jnp.uint32)        # heap id of the interval [l, r)

    def body(_, carry):
        l, r, nid, w_l, w_r = carry
        mid = (l + r) >> 1
        h = (r - l).astype(dtype) * h_res
        z = bridge_normals(seed, nid, lane_idx, row_idx, dtype)
        # midpoint conditioned on the endpoints: var = h/4
        w_mid = 0.5 * (w_l + w_r) + (0.5 * jnp.sqrt(h)) * z
        go_left = idx <= mid
        w_r = jnp.where(go_left, w_mid, w_r)
        w_l = jnp.where(go_left, w_l, w_mid)
        r = jnp.where(go_left, mid, r)
        l = jnp.where(go_left, l, mid)
        nid = 2 * nid + (~go_left).astype(jnp.uint32)
        return l, r, nid, w_l, w_r

    l, r, nid, w_l, w_r = jax.lax.fori_loop(0, depth, body,
                                            (l, r, nid, w_l, w_r))
    return jnp.where(idx == l, w_l, w_r)


def counter_normals_threefry(seed, step, lane_idx, row_idx, dtype=jnp.float32):
    """N(0,1) draws indexed by (seed; step, noise-row, lane) — one value per
    (row_idx, lane_idx) element via Box-Muller on two threefry words.

    lane_idx: (…,) global trajectory indices (uint32-able)
    row_idx:  (…,) noise-component indices, broadcastable against lane_idx.
    """
    c0 = (jnp.asarray(step, jnp.uint32) * jnp.uint32(0x9E3779B9)
          + jnp.asarray(row_idx, jnp.uint32))
    c1 = jnp.asarray(lane_idx, jnp.uint32)
    x0, x1 = threefry2x32(jnp.uint32(seed), jnp.uint32(0x243F6A88), c0, c1)
    u1 = _to_unit(x0)
    u2 = _to_unit(x1)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    return z.astype(dtype)
