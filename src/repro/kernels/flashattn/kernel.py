"""Flash attention (fwd) Pallas TPU kernel — §Perf Cell-A iteration A4.

The roofline analysis showed the T² attention-score tensors dominate the
memory term of every long-context cell; at HLO level two chained dots always
materialize the (T,S) intermediate. The fix is the same one the paper applies
to ODE solving: fuse the WHOLE computation into one kernel so the intermediate
state (here: score blocks + online-softmax statistics, there: RK stages)
lives in VMEM only. HBM traffic drops from O(T·S) to O(T·hd + S·hd) per head.

Grid: (batch, q-head, T/block_q). Each cell loads its q block, streams K/V
blocks from a VMEM-resident (S, hd) slice, and carries the online-softmax
running (max m, sum l, accumulator acc) in registers — the standard
[Dao et al.] recurrence:
    m' = max(m, rowmax(s));  p = exp(s - m')
    l' = l·exp(m - m') + rowsum(p);  acc' = acc·exp(m - m') + p @ V
GQA: kv head = q head // (H/KV) via the BlockSpec index map. Causal masking
per block; strictly-upper K/V blocks are skipped entirely (2× work saving).

VMEM per cell ≈ S·hd·2·2B [K,V bf16] + block_q·(hd+block_k)·4B ≈ 17 MB at
S=32k, hd=128 — fits v5e VMEM with bf16 K/V residency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
                  scale: float, causal: bool):
    # q_ref: (1, block_q, 1, hd); k_ref/v_ref: (1, S, 1, hd)
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale    # (bq, hd)
    S = k_ref.shape[1]
    hd = q.shape[-1]
    nblk = S // block_k

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(j * block_k, block_k), 0, :] \
            .astype(jnp.float32)                          # (bk, hd)
        v = v_ref[0, pl.dslice(j * block_k, block_k), 0, :] \
            .astype(jnp.float32)
        s = q @ k.T                                       # (bq, bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (q.shape[0], block_k), 0)
            cols = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (q.shape[0], block_k), 1)
            s = jnp.where(cols <= rows, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    bq = q.shape[0]
    m0 = jnp.full((bq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    if causal:
        # K/V block j contributes only if j*block_k <= (qi+1)*block_q - 1
        upper = jnp.minimum((qi * block_q + block_q + block_k - 1)
                            // block_k, nblk)
    else:
        upper = nblk
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, a0))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[:, None]).astype(o_ref.dtype)
    o_ref[0, :, 0, :] = out


def flash_attention_pallas(q, k, v, *, causal=True, block_q=128, block_k=128,
                           interpret=None):
    """q (B, T, H, hd); k/v (B, S, KV, hd) -> (B, T, H, hd).

    T % block_q == 0, S % block_k == 0 (ops.py pads). GQA by head mapping.
    """
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    assert T % block_q == 0 and S % block_k == 0
    g = H // KV
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    scale = 1.0 / float(hd) ** 0.5

    kern = functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                             scale=scale, causal=causal)
    grid = (B, H, T // block_q)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, S, 1, hd), lambda b, h, i: (b, 0, h // g, 0)),
            pl.BlockSpec((1, S, 1, hd), lambda b, h, i: (b, 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out
