"""Public wrapper: pads T/S to block multiples, restores shapes."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention_pallas


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret=None):
    """q (B,T,H,hd); k/v (B,S,KV,hd). Pads T and S up to block multiples
    (padded keys are masked out by causality / a length mask)."""
    B, T, H, hd = q.shape
    S = k.shape[1]
    bq = min(block_q, max(16, T))
    bk = min(block_k, max(16, S))
    pt = (-T) % bq
    ps = (-S) % bk
    if pt:
        q = jnp.pad(q, ((0, 0), (0, pt), (0, 0), (0, 0)))
    if ps:
        k = jnp.pad(k, ((0, 0), (0, ps), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, ps), (0, 0), (0, 0)))
    if ps and not causal:
        raise NotImplementedError("non-causal padding needs a length mask")
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=interpret)
    return out[:, :T]
