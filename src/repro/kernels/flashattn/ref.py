"""Dense-attention oracle for the flash kernel (f32 math, explicit softmax)."""
from __future__ import annotations

import jax.numpy as jnp


def ref_attention(q, k, v, causal=True):
    """q (B,T,H,hd); k/v (B,S,KV,hd) -> (B,T,H,hd), GQA by head grouping."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    qf = q.astype(jnp.float32).reshape(B, T, KV, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kf) / jnp.sqrt(float(hd))
    if causal:
        mask = jnp.arange(S)[None, :] <= jnp.arange(T)[:, None]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, vf)
    return o.reshape(B, T, H, hd).astype(q.dtype)
