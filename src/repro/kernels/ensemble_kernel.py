"""Generic fused-ensemble Pallas kernel factory (paper §5.2, all families).

One factory replaces the per-method kernels (the old tsit5-only
`build_ode_kernel` and the bespoke EM kernel): the TPU mapping —

  VREG lane <- 1 trajectory
  pallas grid over lane tiles (LANES); tiles retire independently
  loop-carried VMEM values (never HBM inside the integration)
  whole integration in one grid cell; one HBM flush at kernel end

— is method-independent, so it lives HERE exactly once: BlockSpec/grid
construction, trajectory-axis padding, output/stats assembly, and the
VMEM-budget-aware `lane_tile` selection (§5.2's occupancy formula).  What
varies per method family is only the *loop body*, supplied as a callback:

  body(ctx, u0 (n, B), p (m, B), extras) ->
      (us (S, n, B), u_final (n, B), t_final (B,), stats (6, B) int32)

with stats rows (naccept, nreject, status, nf, njac, nfact) — the last two
report the stiff family's Jacobian-evaluation and W-factorization work
(zero for erk/sde).  Bodies for the three
registered families (erk / rosenbrock / sde) are provided below; they reuse
the shared numerical engines (`core.solvers`, `core.rosenbrock`, `core.sde`)
unchanged — the paper's "automated translation": the same user RHS and the
same stepper run vmapped, lane-fused in XLA, and inside the device kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

Array = Any

# ---------------------------------------------------------------------------
# VMEM-aware lane-tile selection (paper §5.2 occupancy formula)
# ---------------------------------------------------------------------------

# ~16 MB VMEM/core on current TPUs; budget half of it for the kernel's
# loop-carried state + output block, leaving headroom for pipelining/spills.
VMEM_BYTES_PER_CORE = 16 * 1024 * 1024
DEFAULT_VMEM_BUDGET = VMEM_BYTES_PER_CORE // 2

# TPU vector-lane width: tiles should be multiples of this.
LANE_WIDTH = 128


def auto_lane_tile(n_state: int, n_param: int, n_save: int, *,
                   itemsize: int = 4, work_words: Optional[int] = None,
                   vmem_budget: Optional[int] = None,
                   max_tile: int = 4096, fixed_words: int = 0) -> int:
    """Largest 128-multiple tile whose per-lane VMEM footprint fits the budget.

    Per-lane bytes ≈ itemsize * (2*S*n  [output block + loop-carried copy]
                                 + work_words [state, stages, params, control]).
    `work_words` defaults to a generic ERK estimate; family-specific callers
    (Rosenbrock carries an n×n Jacobian per lane) pass their own.
    `fixed_words` is the tile-resident footprint SHARED by all lanes —
    broadcast dataset tables ("table" extras: one VMEM copy per grid cell,
    not per lane) — charged against the budget before the per-lane division
    so data-driven kernels don't over-subscribe VMEM.
    """
    if work_words is None:
        work_words = 12 * n_state + n_param + 16
    per_lane = itemsize * (2 * n_save * n_state + work_words)
    budget = DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    budget = max(0, budget - itemsize * fixed_words)
    tile = (budget // per_lane) // LANE_WIDTH * LANE_WIDTH
    return int(max(LANE_WIDTH, min(tile, max_tile)))


def lane_tile_ladder(n_state: int, n_param: int, n_save: int, *,
                     itemsize: int = 4, work_words: Optional[int] = None,
                     vmem_budget: Optional[int] = None, max_tile: int = 4096,
                     N: Optional[int] = None,
                     fixed_words: int = 0) -> Tuple[int, ...]:
    """Candidate lane tiles bracketing the §5.2 VMEM-optimal tile.

    The occupancy formula (`auto_lane_tile`) yields ONE tile; the real
    optimum depends on effects the formula cannot see (pipeline depth,
    spill behaviour, interpret-mode overhead), so the autotuner
    (`repro.core.autotune`) *times* a small ladder around it instead of
    trusting the formula blindly: {minimum LANE_WIDTH tile, half the
    formula's tile, the formula's tile, double it} — deduplicated, clamped
    to the padded ensemble width when `N` is given, sorted ascending.
    """
    auto = auto_lane_tile(n_state, n_param, n_save, itemsize=itemsize,
                          work_words=work_words, vmem_budget=vmem_budget,
                          max_tile=max_tile, fixed_words=fixed_words)
    half = max(LANE_WIDTH, (auto // 2) // LANE_WIDTH * LANE_WIDTH)
    cand = {LANE_WIDTH, half, auto, min(max_tile, 2 * auto)}
    if N is not None:
        cand = {padded_lane_width(N, t) for t in cand}
    return tuple(sorted(cand))


def erk_work_words(n_state: int, n_param: int, stages: int) -> int:
    return (stages + 4) * n_state + n_param + 16


def rosenbrock_work_words(n_state: int, n_param: int, stages: int = 2,
                          w_reuse: bool = False) -> int:
    # J and W are (n, n) PER LANE — the dominant term for stiff kernels —
    # plus one stage vector U_i per tableau stage (Rodas5P carries 8).
    # The lazy-W hot path (w_reuse) additionally CARRIES the Jacobian, the
    # factored W rows and the pivot/multiplier state across steps
    # (≈ 3·n² per lane in total); the §5.2 VMEM formula must know, or the
    # automatic lane_tile over-subscribes VMEM exactly when the stiff kernel
    # is at its most memory-hungry.
    nn = n_state * n_state
    return ((3 * nn + nn // 2 if w_reuse else 2 * nn)
            + (stages + 6) * n_state + n_param + 16)


def sde_work_words(n_state: int, n_param: int, m_noise: int) -> int:
    return 4 * n_state + m_noise + n_param + 8


# ---------------------------------------------------------------------------
# shared trajectory-axis padding / layout helpers (single home; the ops
# wrappers and the XLA lanes path all use these)
# ---------------------------------------------------------------------------

def padded_lane_width(N: int, lane_tile: int) -> int:
    """Vector width B actually run by `run_ensemble_kernel`.

    The tile is clamped to the ensemble size — but for ensembles LARGER than
    one `LANE_WIDTH`, rounded UP to a 128 multiple: TPU vector lanes come in
    128s, and the naive ``min(lane_tile, N)`` yields a ragged width whenever
    an explicit ``lane_tile > N`` is passed with ``N % 128 != 0`` (e.g.
    N=130, lane_tile=256 used to run a 130-wide kernel).  Ensembles with
    ``N <= LANE_WIDTH`` keep their exact width: Mosaic pads sub-128 widths
    internally on hardware, while the interpret/CPU test and benchmark paths
    pay real per-lane cost — rounding a 3-trajectory parity test up to 128
    lanes would be a 40x compute regression for zero hardware benefit.
    Explicit tiles smaller than the (rounded) ensemble size are honoured
    unchanged (tests drive 3-5-lane tiles through the interpreter)."""
    if N <= LANE_WIDTH:
        return int(max(1, min(lane_tile, N)))
    return int(max(1, min(lane_tile, -(-N // LANE_WIDTH) * LANE_WIDTH)))


def pad_lanes(x: Array, lane_tile: int) -> Tuple[Array, int]:
    """Pad the trailing (lane) axis to a multiple of `lane_tile` (edge mode
    keeps padded lanes numerically well-behaved). Returns (padded, orig_N)."""
    N = x.shape[-1]
    pad = (-N) % lane_tile
    if pad == 0:
        return x, N
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)], mode="edge"), N


def lanes_to_traj(us: Array, N: int) -> Array:
    """(..., LANES_padded) lane-major solution block -> (N, ...) trajectory-major."""
    return jnp.moveaxis(us, -1, 0)[:N]


class KernelContext(NamedTuple):
    """Static + grid information handed to the family loop body."""
    tile: Array        # pl.program_id(0) — this grid cell's tile index
    lane_tile: int     # B
    n_state: int
    n_param: int
    n_save: int


# extras are (kind, array) with kind:
#   "broadcast" — (K,) array identical for every tile (e.g. the saveat grid)
#   "lanes"     — (..., N) array tiled over the trajectory axis (noise tables)
#   "table"     — any-rank array identical for every tile (dataset table
#                 values: `prob.data` leaves).  Broadcast like "broadcast"
#                 but rank-preserving: the leaf rides its own BlockSpec into
#                 VMEM once per grid cell (the texture-memory economy) and
#                 the body sees it in its natural shape.  Convention: data
#                 leaves are always appended LAST in an extras list, so the
#                 family bodies can peel `extras[-n_leaves:]` off the tail.
Extra = Tuple[str, Array]


def run_ensemble_kernel(body: Callable, u0s: Array, ps: Array, *, ts: Array,
                        extras: Sequence[Extra] = (),
                        lane_tile: Optional[int] = None,
                        work_words: Optional[int] = None,
                        vmem_budget: Optional[int] = None,
                        interpret: Optional[bool] = None,
                        fixed_words: int = 0):
    """Launch `body` over the ensemble and assemble an EnsembleResult.

    u0s (N, n), ps (N, m) trajectory-major; ts (S,) save-time grid for the
    result. All grid/BlockSpec plumbing, padding and stats assembly for every
    method family happens here — once.
    """
    from repro.core.ensemble import EnsembleResult

    N, n = u0s.shape
    m = ps.shape[1]
    S = int(ts.shape[0])
    dtype = u0s.dtype
    if lane_tile is None:
        lane_tile = auto_lane_tile(n, m, S, itemsize=dtype.itemsize,
                                   work_words=work_words,
                                   vmem_budget=vmem_budget,
                                   fixed_words=fixed_words)
    # clamp to the ensemble size (no point padding a small ensemble up to the
    # VMEM-optimal tile); large ragged ensembles round up to a LANE_WIDTH
    # multiple.  The XLA lanes path (`core.ensemble._tile_lanes`) derives its
    # width from the SAME helper: XLA codegen is width-sensitive at the ulp
    # level, so equal widths are what keep the two backends bitwise-comparable
    B = padded_lane_width(N, lane_tile)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    u0_l, _ = pad_lanes(u0s.T, B)
    p_l, _ = pad_lanes(ps.T, B)
    Np = u0_l.shape[-1]
    T = Np // B

    in_specs = [pl.BlockSpec((n, B), lambda i: (0, i)),
                pl.BlockSpec((m, B), lambda i: (0, i))]
    args = [u0_l, p_l]
    unwrap = []  # how the kernel recovers each extra's natural shape
    for kind, arr in extras:
        if kind == "broadcast":
            args.append(jnp.asarray(arr)[None, :])
            K = args[-1].shape[1]
            in_specs.append(pl.BlockSpec((1, K), lambda i: (0, 0)))
            unwrap.append(lambda v: v[0])
        elif kind == "lanes":
            padded, _ = pad_lanes(jnp.asarray(arr), B)
            args.append(padded)
            blk = padded.shape[:-1] + (B,)
            nd = padded.ndim
            in_specs.append(pl.BlockSpec(
                blk, lambda i, _nd=nd: (0,) * (_nd - 1) + (i,)))
            unwrap.append(lambda v: v)
        elif kind == "table":
            # dataset leaf: flatten to one VMEM row broadcast to every grid
            # cell, restore the natural shape inside the kernel
            a = jnp.asarray(arr)
            sh = a.shape
            flat = a.reshape(1, -1)
            K = flat.shape[1]
            args.append(flat)
            in_specs.append(pl.BlockSpec((1, K), lambda i: (0, 0)))
            unwrap.append(lambda v, _sh=sh: v.reshape(_sh))
        else:
            raise ValueError(f"unknown extra kind {kind!r}")

    out_shape = [
        jax.ShapeDtypeStruct((S, n, Np), dtype),      # us
        jax.ShapeDtypeStruct((n, Np), dtype),         # u_final
        jax.ShapeDtypeStruct((1, Np), dtype),         # t_final
        # naccept / nreject / status / nf / njac / nfact
        jax.ShapeDtypeStruct((6, Np), jnp.int32),
    ]
    out_specs = [
        pl.BlockSpec((S, n, B), lambda i: (0, 0, i)),
        pl.BlockSpec((n, B), lambda i: (0, i)),
        pl.BlockSpec((1, B), lambda i: (0, i)),
        pl.BlockSpec((6, B), lambda i: (0, i)),
    ]

    n_in = len(args)

    def kernel(*refs):
        u0 = refs[0][...]
        p = refs[1][...]
        ex = tuple(fn(r[...]) for fn, r in zip(unwrap, refs[2:n_in]))
        us_ref, uf_ref, tfin_ref, stats_ref = refs[n_in:]
        ctx = KernelContext(tile=pl.program_id(0), lane_tile=B, n_state=n,
                            n_param=m, n_save=S)
        us, uf, t_final, stats = body(ctx, u0, p, ex)
        us_ref[...] = us                  # (S, n, B): one HBM flush
        uf_ref[...] = uf
        tfin_ref[...] = t_final[None]
        stats_ref[...] = stats.astype(jnp.int32)

    fn = pl.pallas_call(kernel, grid=(T,), in_specs=in_specs,
                        out_specs=out_specs, out_shape=out_shape,
                        interpret=interpret)
    us, uf, t_fin, stats = fn(*args)
    return EnsembleResult(
        ts=jnp.asarray(ts, dtype), us=lanes_to_traj(us, N),
        u_final=uf.T[:N], t_final=t_fin[0, :N],
        naccept=stats[0, :N], nreject=stats[1, :N],
        nf=jnp.sum(stats[3, :N]), status=jnp.max(stats[2, :N]),
        njac=jnp.sum(stats[4, :N]), nfact=jnp.sum(stats[5, :N]))


def kernel_adjoint(primal_fn: Callable, replay_fn: Callable) -> Callable:
    """Reverse-mode AD across the Pallas kernel boundary.

    ``pallas_call`` has no transpose rule, so the fused kernels cannot be
    vjp'd directly.  This factory keeps the FORWARD solve on the kernel
    (``primal_fn``) and installs a `jax.custom_vjp` whose backward pass
    re-runs the kernel's XLA twin (``replay_fn`` — the bounded, checkpointed
    `repro.core.loops.solver_loop` path of the same family) under `jax.vjp`.
    The forward pass stores only the (u0s, ps) residuals; the replay's
    checkpointed segments bound the reverse-pass memory (periodic carry
    checkpoints — u, t, dt, RNG counters, J/LU freshness — with recompute
    inside segments), so peak memory stays O(sqrt-steps), never O(steps).
    SDE replays are exact: the counter-RNG noise is a pure function of
    (seed; step/grid index, row, global lane), so the recomputed path is the
    path the kernel integrated, bitwise.

    Both callables map ``(u0s, ps, *extra) -> EnsembleResult``; the variadic
    tail exists for data-driven problems, whose dataset leaves must be REAL
    custom_vjp arguments (a custom_vjp closure must not capture tracers — the
    way a bound closure would under `jax.grad` of table values), so gradients
    flow to the tables too: calibrating a forcing curve from data is just
    `jax.grad` over the leaf arguments.  Gradients flow through the
    continuous state outputs ``us`` and ``u_final``; solver statistics,
    snapshot times and event locations are non-differentiable outputs (their
    cotangents are dropped).
    """

    @jax.custom_vjp
    def run(u0s, ps, *extra):
        return primal_fn(u0s, ps, *extra)

    def fwd(u0s, ps, *extra):
        return primal_fn(u0s, ps, *extra), (u0s, ps, extra)

    def bwd(residuals, ct):
        u0s, ps, extra = residuals

        def states(u, p, *ex):
            res = replay_fn(u, p, *ex)
            return res.us, res.u_final

        _, vjp = jax.vjp(states, u0s, ps, *extra)
        return vjp((ct.us, ct.u_final))

    run.defvjp(fwd, bwd)
    return run


# ---------------------------------------------------------------------------
# double-buffered HBM<->VMEM save staging (large save grids / large n)
# ---------------------------------------------------------------------------

def save_chunk_count(n_state: int, n_param: int, n_save: int, *,
                     itemsize: int = 4, work_words: Optional[int] = None,
                     vmem_budget: Optional[int] = None,
                     fixed_words: int = 0) -> int:
    """How many saveat segments the staged driver needs (1 = no staging).

    `run_ensemble_kernel` keeps the whole (S, n, B) output block VMEM-resident
    for the kernel's lifetime; when S·n is large the §5.2 formula can only
    shrink the tile down to its LANE_WIDTH floor, and past that the footprint
    simply does not fit the budget.  This computes, at that minimum tile, the
    number of saves one segment can afford, and hence the segment count
    `run_ensemble_kernel_staged` should split the grid into.
    """
    if work_words is None:
        work_words = 12 * n_state + n_param + 16
    budget = DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    # broadcast tables are tile-resident in every segment — same charge as
    # auto_lane_tile, or staging re-over-subscribes exactly what it fixes
    budget = max(0, budget - itemsize * fixed_words)
    per_lane_words = budget // (LANE_WIDTH * itemsize)
    max_saves = (per_lane_words - work_words) // (2 * n_state)
    if max_saves >= n_save:
        return 1
    return int(-(-n_save // max(1, max_saves)))


def run_ensemble_kernel_staged(body_factory: Callable, u0s: Array, ps: Array,
                               *, ts: Array, save_chunks: int,
                               lane_tile: Optional[int] = None,
                               work_words: Optional[int] = None,
                               vmem_budget: Optional[int] = None,
                               interpret: Optional[bool] = None,
                               fixed_words: int = 0):
    """Segmented launch: double-buffer the save block between HBM and VMEM.

    The save grid `ts` (concrete, ascending, all > t0) is split into
    `save_chunks` segments; each segment runs ONE `run_ensemble_kernel`
    launch whose (S_seg, n, B) output block fits the VMEM budget, flushing to
    HBM at segment end while the next launch re-stages only the (n, B) final
    state — the classic two-buffers-in-flight staging pattern at saveat
    granularity, which is the coarsest (and therefore cheapest) place to cut.
    `u_final`/`t_final` and the step counters thread between segments at the
    JAX level; `body_factory(t_start, seg_ts, last)` builds each segment's
    loop body + extras (the erk wrapper `repro.kernels.tsit5.ops` supplies
    one that restarts integration at the previous segment's endpoint).

    Numerics: fixed-dt runs whose segment boundaries land on the step grid
    are bitwise-identical to the unstaged kernel; adaptive runs restart the
    controller (dt0, PI history) at each boundary, so they agree to solver
    accuracy, not bitwise (see docs/kernels.md).
    """
    from repro.core.ensemble import EnsembleResult

    ts_np = np.asarray(ts)
    S = int(ts_np.shape[0])
    save_chunks = int(max(1, min(save_chunks, S)))
    segs = [idx for idx in np.array_split(np.arange(S), save_chunks)
            if idx.size]

    u_cur = u0s
    parts, acc = [], None
    for k, idx in enumerate(segs):
        seg_ts = ts_np[idx]
        t_start = float(ts_np[idx[0] - 1]) if k else None  # None: problem t0
        body, extras = body_factory(t_start, seg_ts, k == len(segs) - 1)
        res = run_ensemble_kernel(
            body, u_cur, ps, ts=jnp.asarray(seg_ts, u0s.dtype),
            extras=extras, lane_tile=lane_tile, work_words=work_words,
            vmem_budget=vmem_budget, interpret=interpret,
            fixed_words=fixed_words)
        u_cur = res.u_final
        parts.append(res.us)
        if acc is None:
            acc = res
        else:
            acc = acc._replace(
                u_final=res.u_final, t_final=res.t_final,
                naccept=acc.naccept + res.naccept,
                nreject=acc.nreject + res.nreject,
                nf=acc.nf + res.nf, njac=acc.njac + res.njac,
                nfact=acc.nfact + res.nfact,
                status=jnp.maximum(acc.status, res.status))
    return acc._replace(ts=jnp.asarray(ts_np, u0s.dtype),
                        us=jnp.concatenate(parts, axis=1))


# ---------------------------------------------------------------------------
# family loop bodies — each is the shared numerical engine in lanes mode,
# specialized (closure/JIT) on the problem, exactly as the paper's kernel
# generator compiles the problem definition into the device kernel.
# ---------------------------------------------------------------------------

def _data_binder(data):
    """Plumbing for data-driven problems inside kernel bodies.

    `data` is the problem's dataset pytree, used as a TEMPLATE (treedef +
    leaf count) only: the actual table values arrive as the trailing "table"
    extras (the extras-last convention above), so they are real kernel
    arguments — VMEM-resident, and differentiable through `kernel_adjoint`'s
    variadic tail.  Returns `rebind(extras) -> (core_extras, d)` peeling the
    leaf tail off and rebuilding the dataset pytree, or None without data.
    """
    if data is None:
        return None
    leaves, treedef = jax.tree_util.tree_flatten(data)
    k = len(leaves)

    def rebind(extras):
        split = len(extras) - k
        d = jax.tree_util.tree_unflatten(treedef, list(extras[split:]))
        return extras[:split], d

    return rebind


def erk_body(f, tab, *, t0: float, tf: float, dt0: float, rtol: float,
             atol: float, adaptive: bool, max_iters: int, event=None,
             data=None):
    """Adaptive embedded-RK integration; extras[0] = saveat grid (S,);
    data-driven problems append their table leaves last (see _data_binder)."""
    from repro.core.solvers import AdaptiveOptions, solve_adaptive

    rebind = _data_binder(data)

    def body(ctx, u0, p, extras):
        fb = f
        if rebind is not None:
            extras, d = rebind(extras)
            fb = lambda u_, p_, t_: f(u_, p_, t_, d)
        saveat_v = extras[0]
        opts = AdaptiveOptions(rtol=rtol, atol=atol, max_iters=max_iters,
                               adaptive=adaptive)
        res = solve_adaptive(fb, tab, u0, p, t0, tf, dt0, saveat=saveat_v,
                             opts=opts, event=event, lanes=True)
        if event is not None:
            res, _ = res
        zero = jnp.zeros_like(res.naccept)
        stats = jnp.stack([res.naccept, res.nreject,
                           res.status * jnp.ones_like(res.naccept), res.nf,
                           zero, zero])
        return res.us, res.u_final, res.t_final, stats

    return body


def rosenbrock_body(f, rtab, *, jac=None, t0: float, tf: float, dt0: float,
                    rtol: float, atol: float, max_iters: int, event=None,
                    w_reuse=None, data=None):
    """s-stage Rosenbrock stiff integration (any `RosenbrockTableau`:
    Rosenbrock23 / Rodas4 / Rodas5P) with the batched-LU W-solves *inlined*
    (linsolve="lanes": paper §5.1.3 inside the fused kernel, lanes-wide
    partial pivoting).  `jac` is the analytic-Jacobian hook (None: jacfwd
    inside the kernel).  `w_reuse` enables the lazy-W hot path: the Jacobian,
    the factored LU(W) (rows/swaps/multipliers of the lanes LU) and the dt it
    was factored at ride the while_loop carry in VMEM, refreshed per lane
    only when the `WReusePolicy` freshness controller asks — the fused
    kernel's dominant per-step cost (jacfwd + O(n³) elimination) is then paid
    only on refresh steps.  Events run the shared per-lane machinery
    (`repro.core.events`) inside the fused loop.  extras[0] = saveat grid
    (S,); data-driven problems append their table leaves last."""
    from repro.core.rosenbrock import solve_rosenbrock

    rebind = _data_binder(data)

    def body(ctx, u0, p, extras):
        fb, jb = f, jac
        if rebind is not None:
            extras, d = rebind(extras)
            fb = lambda u_, p_, t_: f(u_, p_, t_, d)
            if jac is not None:
                jb = lambda u_, p_, t_: jac(u_, p_, t_, d)
        saveat_v = extras[0]
        res = solve_rosenbrock(fb, rtab, u0, p, t0, tf, dt0, rtol=rtol,
                               atol=atol, saveat=saveat_v,
                               max_iters=max_iters, lanes=True,
                               linsolve="lanes", jac=jb, event=event,
                               w_reuse=w_reuse)
        if event is not None:
            res, _ = res
        stats = jnp.stack([res.naccept, res.nreject, res.status, res.nf,
                           jnp.broadcast_to(res.njac, res.naccept.shape),
                           jnp.broadcast_to(res.nfact, res.naccept.shape)])
        return res.us, res.u_final, res.t_final, stats

    return body


def sde_body(f, g, stepper, noise: str, *, t0: float, dt: float,
             n_steps: int, save_every: int, m_noise: int, seed: int,
             use_table: bool, nf_per_step: int = 1, event=None, data=None):
    """Fixed-dt SDE integration with in-kernel counter RNG (threefry keyed by
    (seed; step, noise-row, GLOBAL lane) — replayable, no noise storage), or a
    pre-drawn table via extras[1] ("lanes" kind, (n_steps, m, N)).

    extras[0] ("broadcast", (1,)) is the shard's global lane offset;
    data-driven problems append their dataset table leaves LAST (after the
    optional noise table — the extras-last convention).  Events run the
    shared per-lane machinery (`repro.core.events`) inside the fused loop,
    with termination masks freezing finished lanes."""
    from repro.core.sde import (sde_event_state0, sde_step_and_save,
                                sde_step_save_event)
    from repro.kernels.rng import counter_normals_threefry

    S = n_steps // save_every
    rebind = _data_binder(data)

    def body(ctx, u0, p, extras):
        f_, g_ = f, g
        if rebind is not None:
            extras, d = rebind(extras)
            f_ = lambda u_, p_, t_: f(u_, p_, t_, d)
            g_ = lambda u_, p_, t_: g(u_, p_, t_, d)
        B = ctx.lane_tile
        dtype = u0.dtype
        offset = jnp.asarray(extras[0], jnp.uint32)[0]
        lane = (offset + jnp.uint32(ctx.tile) * jnp.uint32(B)
                + jax.lax.broadcasted_iota(jnp.uint32, (m_noise, B), 1))
        rows = jax.lax.broadcasted_iota(jnp.uint32, (m_noise, B), 0)
        table = extras[1] if use_table else None

        def noise_fn(k):
            if use_table:
                return jax.lax.dynamic_slice(
                    table, (k, 0, 0), (1, m_noise, B))[0].astype(dtype)
            return counter_normals_threefry(seed, k, lane, rows, dtype)

        us0 = jnp.zeros((S, ctx.n_state, B), dtype)
        i32 = lambda v: jnp.full((B,), v, jnp.int32)
        if event is None:
            def step(k, carry):
                u, us = carry
                return sde_step_and_save(stepper, f_, g_, noise, u, us, p, t0,
                                         dt, k, noise_fn(k), save_every)

            u_f, us = jax.lax.fori_loop(0, n_steps, step, (u0, us0))
            t_final = jnp.full((B,), t0 + n_steps * dt, dtype)
            naccept = i32(n_steps)
        else:
            def step(k, carry):
                u, us, estate = carry
                return sde_step_save_event(stepper, f_, g_, noise, event, u,
                                           us, estate, p, t0, dt, k,
                                           noise_fn(k), save_every)

            estate0 = sde_event_state0((B,), t0, dtype)
            u_f, us, estate = jax.lax.fori_loop(0, n_steps, step,
                                                (u0, us0, estate0))
            t_final = estate["t_out"].astype(dtype)
            naccept = estate["naccept"]
        stats = jnp.stack([naccept, i32(0), i32(0),
                           i32(n_steps * nf_per_step), i32(0), i32(0)])
        return us, u_f, t_final, stats

    return body


def sde_adaptive_body(f, g, stepper, noise: str, *, t0: float, tf: float,
                      dt0: float, rtol: float, atol: float, max_iters: int,
                      m_noise: int, seed: int, depth: int, order: float,
                      nf_per_step: int, event=None, error_est: str = "doubling",
                      embedded=None, est_order=None, nf_per_attempt=None,
                      data=None):
    """Adaptive SDE integration fused into the kernel: embedded-pair or
    step-doubling error control with virtual-Brownian-tree noise
    (rejection-safe: the SAME (seed; lane, row, dyadic-time) stream on every
    strategy/backend — see `repro.core.sde.sde_solve_adaptive`, which this
    body wraps unchanged, so estimator choice cannot split the backends).
    extras[0] = saveat grid (S,), extras[1] = ("broadcast", (1,)) global lane
    offset; data-driven problems append their table leaves last."""
    from repro.core.sde import sde_solve_adaptive

    rebind = _data_binder(data)

    def body(ctx, u0, p, extras):
        f_, g_ = f, g
        if rebind is not None:
            extras, d = rebind(extras)
            f_ = lambda u_, p_, t_: f(u_, p_, t_, d)
            g_ = lambda u_, p_, t_: g(u_, p_, t_, d)
        B = ctx.lane_tile
        saveat_v = extras[0]
        offset = jnp.asarray(extras[1], jnp.uint32)[0]
        lane = (offset + jnp.uint32(ctx.tile) * jnp.uint32(B)
                + jax.lax.broadcasted_iota(jnp.uint32, (B,), 0))
        res = sde_solve_adaptive(f_, g_, stepper, noise, u0, p, t0, tf, dt0,
                                 seed=seed, lane_idx=lane, m_noise=m_noise,
                                 saveat=saveat_v, rtol=rtol, atol=atol,
                                 max_iters=max_iters, event=event, lanes=True,
                                 depth=depth, order=order,
                                 nf_per_step=nf_per_step, error_est=error_est,
                                 embedded=embedded, est_order=est_order,
                                 nf_per_attempt=nf_per_attempt)
        if event is not None:
            res, _ = res
        zero = jnp.zeros_like(res.naccept)
        stats = jnp.stack([res.naccept, res.nreject,
                           res.status * jnp.ones_like(res.naccept), res.nf,
                           zero, zero])
        return res.us, res.u_final, res.t_final, stats

    return body
