"""Public batched-LU entry: (N, n, n) systems; pads the batch, picks backend.

`lane_tile=None` derives the tile from the same VMEM-budget formula the
ensemble kernel uses (paper §5.2, `repro.kernels.ensemble_kernel
.auto_lane_tile`) so large-`n` systems shrink the tile instead of blowing
VMEM; singular systems (a pivot that is exactly zero even after partial
pivoting) are detected from the kernel's per-lane min-|pivot| output and
routed to the jnp reference solve.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import lu_solve_pallas


def lu_lane_tile(n: int, itemsize: int = 4) -> int:
    """§5.2 VMEM-budget tile for a standalone batched LU: per-lane words are
    the W block (n²) + factorization copy (n²) + rhs/x/scratch (≈4n)."""
    from repro.kernels.ensemble_kernel import auto_lane_tile
    return auto_lane_tile(n, 0, 0, itemsize=itemsize,
                          work_words=2 * n * n + 4 * n)


def batched_solve(W, b, lane_tile=None, backend="pallas", interpret=None,
                  pivot=True):
    """Solve W[i] x[i] = b[i] for all i. W (N, n, n), b (N, n) -> (N, n).

    Partial (row) pivoting is on by default; systems whose pivot is exactly
    zero even after pivoting (numerically singular) fall back to the jnp
    reference solve, so the kernel's contract matches its docstring.
    `lane_tile=None` picks the VMEM-budget-aware tile (`lu_lane_tile`).
    """
    N, n, _ = W.shape
    if backend == "jnp":
        return jnp.linalg.solve(W, b[..., None])[..., 0]
    if lane_tile is None:
        from repro.kernels.ensemble_kernel import LANE_WIDTH
        lane_tile = min(lu_lane_tile(n, W.dtype.itemsize),
                        -(-N // LANE_WIDTH) * LANE_WIDTH)
    pad = (-N) % lane_tile
    Wl = jnp.moveaxis(W, 0, -1)          # (n, n, N)
    bl = b.T                             # (n, N)
    if pad:
        eye = jnp.broadcast_to(jnp.eye(n, dtype=W.dtype)[..., None],
                               (n, n, pad))
        Wl = jnp.concatenate([Wl, eye], axis=-1)
        bl = jnp.concatenate([bl, jnp.zeros((n, pad), b.dtype)], axis=-1)
    x, pivmin = lu_solve_pallas(Wl, bl, lane_tile=lane_tile,
                                interpret=interpret, pivot=pivot)
    x = x.T[:N]
    # a zero pivot mid-elimination poisons the later rows (inf·0 = NaN), so
    # the reported min-|pivot| of a singular lane is 0 OR NaN — ~(pivmin > 0)
    # catches both (`pivmin == 0` alone would miss the NaN case)
    singular = ~(pivmin[:N] > 0.0)

    def _with_fallback(_):
        ref = jnp.linalg.solve(W, b[..., None])[..., 0]
        return jnp.where(singular[:, None], ref, x)

    return jax.lax.cond(jnp.any(singular), _with_fallback, lambda _: x,
                        operand=None)
