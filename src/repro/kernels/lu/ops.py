"""Public batched-LU entry: (N, n, n) systems; pads the batch, picks backend."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import lu_solve_pallas


def batched_solve(W, b, lane_tile=128, backend="pallas", interpret=None):
    """Solve W[i] x[i] = b[i] for all i. W (N, n, n), b (N, n) -> (N, n)."""
    N, n, _ = W.shape
    if backend == "jnp":
        return jnp.linalg.solve(W, b[..., None])[..., 0]
    pad = (-N) % lane_tile
    Wl = jnp.moveaxis(W, 0, -1)          # (n, n, N)
    bl = b.T                             # (n, N)
    if pad:
        eye = jnp.broadcast_to(jnp.eye(n, dtype=W.dtype)[..., None],
                               (n, n, pad))
        Wl = jnp.concatenate([Wl, eye], axis=-1)
        bl = jnp.concatenate([bl, jnp.zeros((n, pad), b.dtype)], axis=-1)
    x = lu_solve_pallas(Wl, bl, lane_tile=lane_tile, interpret=interpret)
    return x.T[:N]
