"""Batched small-matrix LU solve (paper §5.1.3): W x = b for N independent
systems, W = I - γh·J block-diagonal over the ensemble.

TPU mapping: lanes are systems — W is laid out (n, n, LANES) so every
elimination/back-substitution scalar op is a (LANES,)-wide vector op; the
whole factorization is an unrolled register-level computation per tile with
zero HBM traffic between steps (the GPU version's per-thread LU in registers).

Pivoting: partial (row) pivoting, lanes-wide — at elimination step k every
lane independently selects its own pivot row by max |column-k| magnitude and
the swap is a masked select, so the factorization stays a branch-free vector
computation.  This is what keeps non-diagonally-dominant W = I − γh·J systems
(large γh·J entries off the diagonal) from silently producing NaNs; the
`pivot=False` escape hatch preserves the old no-pivot behaviour for
diagonally-dominant fast paths and for tests that demonstrate the failure
mode.  A pivot that is exactly zero after row selection means the lane's
matrix is numerically singular: the kernel reports min-|pivot| per lane and
the ops layer (`repro.kernels.lu.ops.batched_solve`) falls back to the jnp
reference solve for exactly those systems.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def lu_factor_lanes(W, pivot=True):
    """Lanes-mode LU factorization: W (n, n, B) -> opaque factor tuple.

    Unrolled Gaussian elimination with lanes-wide partial pivoting; every
    scalar op is a (B,)-wide vector op.  Returns (rows, swaps, mults,
    pivmin): the eliminated rows (upper triangle), the per-step pivot-row
    selections and elimination multipliers (everything `lu_resolve_lanes`
    needs to replay the factorization on a new right-hand side in O(n²)
    per lane), and the per-lane minimum |pivot| (0 or NaN ⇔ singular).
    The Rosenbrock engine factors W = I − γh·J ONCE per step and
    back-substitutes once per stage (paper §5.1.3 / Hairer-Wanner IV.7).
    """
    n = W.shape[0]
    rows = [W[i] for i in range(n)]   # each (n, B)
    swaps = []                        # per step k: pivot row index (B,)
    mults = []                        # per step k: multipliers for rows k+1..
    pivmin = jnp.full(W.shape[-1:], jnp.inf, W.dtype)
    for k in range(n):
        if pivot and k < n - 1:
            # per-lane pivot row: argmax |column k| over rows k..n-1
            mag = jnp.stack([jnp.abs(rows[i][k]) for i in range(k, n)])
            piv = jnp.argmax(mag, axis=0) + k          # (B,)
            for i in range(k + 1, n):
                sel_r = (piv == i)[None]
                rows[k], rows[i] = (jnp.where(sel_r, rows[i], rows[k]),
                                    jnp.where(sel_r, rows[k], rows[i]))
            swaps.append(piv)
        pivmin = jnp.minimum(pivmin, jnp.abs(rows[k][k]))
        inv = 1.0 / rows[k][k]
        mk = []
        for i in range(k + 1, n):
            m = rows[i][k] * inv
            rows[i] = rows[i] - m * rows[k]
            mk.append(m)
        mults.append(mk)
    return rows, swaps, mults, pivmin


def lu_resolve_lanes(fac, b):
    """Back-substitution against a `lu_factor_lanes` factorization:
    b (n, B) -> x (n, B), replaying the stored row swaps and multipliers."""
    rows, swaps, mults, _ = fac
    n = len(rows)
    rhs = [b[i] for i in range(n)]    # each (B,)
    for k in range(n):
        if swaps and k < n - 1:
            piv = swaps[k]
            for i in range(k + 1, n):
                sel = piv == i
                rhs[k], rhs[i] = (jnp.where(sel, rhs[i], rhs[k]),
                                  jnp.where(sel, rhs[k], rhs[i]))
        for i in range(k + 1, n):
            rhs[i] = rhs[i] - mults[k][i - k - 1] * rhs[k]
    xs = [None] * n
    for i in reversed(range(n)):
        acc = rhs[i]
        for j in range(i + 1, n):
            acc = acc - rows[i][j] * xs[j]
        xs[i] = acc / rows[i][i]
    return jnp.stack(xs)


def lu_solve_lanes(W, b, pivot=True, with_pivmin=False):
    """One-shot lanes-mode LU solve: W (n, n, B), b (n, B) -> x (n, B).

    `lu_factor_lanes` + `lu_resolve_lanes` in one call.  This is the kernel
    *body* — it runs both under `pallas_call` (below) and inlined inside
    other fused kernels.  with_pivmin=True additionally returns the per-lane
    minimum |pivot| encountered (0 or NaN ⇔ singular system).
    """
    fac = lu_factor_lanes(W, pivot=pivot)
    x = lu_resolve_lanes(fac, b)
    if with_pivmin:
        return x, fac[3]
    return x


def build_lu_kernel(n: int, pivot: bool = True):
    def kernel(W_ref, b_ref, x_ref, pivmin_ref):
        x, pivmin = lu_solve_lanes(W_ref[...], b_ref[...], pivot=pivot,
                                   with_pivmin=True)
        x_ref[...] = x
        pivmin_ref[...] = pivmin[None]

    return kernel


def lu_solve_pallas(W_lanes, b_lanes, lane_tile=128, interpret=None,
                    pivot=True):
    """W_lanes (n, n, N), b_lanes (n, N) -> (x (n, N), pivmin (N,)).

    N % lane_tile == 0.  pivmin is the per-system minimum |pivot| — 0 (or
    NaN, once a zero pivot has poisoned the remaining elimination rows)
    marks a singular system whose x column is garbage (inf/nan); the ops
    layer tests ~(pivmin > 0) to route those systems to the jnp reference
    solve.
    """
    n = W_lanes.shape[0]
    N = W_lanes.shape[-1]
    assert W_lanes.shape == (n, n, N) and b_lanes.shape == (n, N)
    assert N % lane_tile == 0
    B = lane_tile
    T = N // B
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    fn = pl.pallas_call(
        build_lu_kernel(n, pivot),
        grid=(T,),
        in_specs=[pl.BlockSpec((n, n, B), lambda i: (0, 0, i)),
                  pl.BlockSpec((n, B), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((n, B), lambda i: (0, i)),
                   pl.BlockSpec((1, B), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((n, N), W_lanes.dtype),
                   jax.ShapeDtypeStruct((1, N), W_lanes.dtype)],
        interpret=interpret)
    x, pivmin = fn(W_lanes, b_lanes)
    return x, pivmin[0]
