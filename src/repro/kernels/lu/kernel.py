"""Batched small-matrix LU solve (paper §5.1.3): W x = b for N independent
systems, W = -γI + J block-diagonal over the ensemble.

TPU mapping: lanes are systems — W is laid out (n, n, LANES) so every
elimination/back-substitution scalar op is a (LANES,)-wide vector op; the
whole factorization is an unrolled register-level computation per tile with
zero HBM traffic between steps (the GPU version's per-thread LU in registers).
No pivoting: the paper's W = -γI + J systems are diagonally dominated for the
step sizes where stiff solvers operate (standard in Rosenbrock GPU solvers);
the ops-layer falls back to the jnp reference on singular pivots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def lu_solve_lanes(W, b):
    """Pure lanes-mode LU solve: W (n, n, B), b (n, B) -> x (n, B).

    Unrolled no-pivot Gaussian elimination; every scalar op is a (B,)-wide
    vector op.  This is the kernel *body* — it runs both under `pallas_call`
    (below) and inlined inside other fused kernels (the Rosenbrock ensemble
    kernel calls it per step for the W = I - γh·J solves, paper §5.1.3).
    """
    n = W.shape[0]
    rows = [W[i] for i in range(n)]   # each (n, B)
    rhs = [b[i] for i in range(n)]    # each (B,)
    # forward elimination (unrolled; every op is lane-vectorized)
    for k in range(n):
        inv = 1.0 / rows[k][k]
        for i in range(k + 1, n):
            m = rows[i][k] * inv
            rows[i] = rows[i] - m * rows[k]
            rhs[i] = rhs[i] - m * rhs[k]
    # back substitution
    xs = [None] * n
    for i in reversed(range(n)):
        acc = rhs[i]
        for j in range(i + 1, n):
            acc = acc - rows[i][j] * xs[j]
        xs[i] = acc / rows[i][i]
    return jnp.stack(xs)


def build_lu_kernel(n: int):
    def kernel(W_ref, b_ref, x_ref):
        x_ref[...] = lu_solve_lanes(W_ref[...], b_ref[...])

    return kernel


def lu_solve_pallas(W_lanes, b_lanes, lane_tile=128, interpret=None):
    """W_lanes (n, n, N), b_lanes (n, N) -> x (n, N). N % lane_tile == 0."""
    n = W_lanes.shape[0]
    N = W_lanes.shape[-1]
    assert W_lanes.shape == (n, n, N) and b_lanes.shape == (n, N)
    assert N % lane_tile == 0
    B = lane_tile
    T = N // B
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    fn = pl.pallas_call(
        build_lu_kernel(n),
        grid=(T,),
        in_specs=[pl.BlockSpec((n, n, B), lambda i: (0, 0, i)),
                  pl.BlockSpec((n, B), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, B), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, N), W_lanes.dtype),
        interpret=interpret)
    return fn(W_lanes, b_lanes)
