"""Oracle: vmapped dense solve via jnp.linalg (LAPACK on CPU, partial pivoting)."""
import jax.numpy as jnp


def ref_solve(W, b):
    """W (N, n, n), b (N, n) -> (N, n)."""
    return jnp.linalg.solve(W, b[..., None])[..., 0]
