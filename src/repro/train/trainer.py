"""Training step factory: pjit/GSPMD, microbatch gradient accumulation,
bf16 compute + f32 optimizer, remat via scan-over-layers checkpointing.

`make_train_step` returns a jit'd (params, opt_state, batch) -> (params,
opt_state, metrics) with NamedShardings attached — the object the multi-pod
dry-run lowers and the CPU examples execute (mesh=None => single device).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.sharding import batch_spec, param_specs
from repro.optim.adamw import AdamW, AdamWState

Array = Any


@dataclasses.dataclass
class TrainPlan:
    """Everything the launcher / dry-run needs for one training setup."""
    step_fn: Any              # jit'd step
    params_sharding: Any
    opt_sharding: Any
    batch_sharding: Any
    abstract_params: Any
    abstract_opt: Any


def pick_accum(cfg: ModelConfig, per_dev_batch: int, seq: int,
               budget_bytes: float = 8e9) -> int:
    """Gradient-accumulation factor so the two dominant per-microbatch
    residents fit the budget:
      * layer-boundary activations remat keeps: L * mb * T * D * 2B
      * full-vocab logits (+grad +exp):       ~3 * mb * T * Vp * 2B
    (the logits term dominates for small-D/large-V archs — gemma3, whisper)."""
    per_mb = (cfg.n_layers * per_dev_batch * seq * cfg.d_model * 2
              + 3 * per_dev_batch * seq * cfg.vocab_padded * 2)
    accum = 1
    while per_mb / accum > budget_bytes and accum < per_dev_batch:
        accum *= 2
    return min(accum, per_dev_batch)


def batch_shardings(mesh, abstract_batch):
    """Batch-leading sharding for every leaf of a batch dict."""
    spec = batch_spec(mesh)

    def one(x):
        return NamedSharding(mesh, P(*(list(spec) + [None] * (x.ndim - 1))))

    return jax.tree.map(one, abstract_batch)


def make_train_step(model, opt: AdamW, mesh: Optional[Mesh] = None,
                    accum: int = 1, donate: bool = True,
                    fsdp: bool = True, abstract_batch=None,
                    shard_mode: Optional[str] = None):
    """Build the jit'd train step (+ sharding trees when mesh is given).

    shard_mode (overrides `fsdp` when set):
      "fsdp"  — params AND optimizer state sharded over (model, data):
                minimum memory, per-layer weight all-gathers in fwd/bwd.
      "zero1" — params TP-only (replicated over data), optimizer state
                sharded over data (ZeRO-1): no per-layer weight gathers —
                trades param memory for gather traffic (§Perf hillclimb).
      "tp"    — everything TP-only (small models).
    """
    cfg = model.cfg
    if shard_mode is None:
        shard_mode = "fsdp" if fsdp else "tp"

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def step_fn(params, opt_state, batch):
        if accum > 1:
            # microbatch scan: grads accumulate in f32, constant memory
            def micro(carry, mb):
                gsum, msum = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, msum + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, om = opt.update(grads, opt_state, params)
        out = {"loss": loss, **{k: v for k, v in metrics.items()}, **om}
        return new_params, new_opt, out

    if mesh is None:
        return TrainPlan(jax.jit(step_fn, donate_argnums=(0, 1) if donate
                                 else ()),
                         None, None, None, None, None)

    key = jax.random.PRNGKey(0)
    # anchor batch sharding at block boundaries (§Perf A3: GSPMD otherwise
    # may replicate the batch and shard attention by heads instead)
    from repro.models.lm import ActivationSharding
    model.act_shard = ActivationSharding(mesh)
    if hasattr(model, "lm"):
        model.lm.act_shard = model.act_shard
    if getattr(model, "q_chunk", None) == 0 and cfg.n_heads \
            and cfg.n_heads % 16 != 0:
        # heads can't shard over `model` => the (T,T) score tensor stays
        # whole per device; chunk queries to bound the peak (gemma3/whisper
        # train cells otherwise exceed HBM)
        model.q_chunk = 1024
        if hasattr(model, "lm"):
            model.lm.q_chunk = 1024
    abstract_params = jax.eval_shape(model.init_params, key)
    fsdp_kw = dict(fsdp_axis="data", fsdp_size=mesh.shape.get("data", 1))
    pspecs = param_specs(abstract_params, cfg,
                         **(fsdp_kw if shard_mode == "fsdp" else
                            {"fsdp_axis": None}))
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    abstract_opt = jax.eval_shape(opt.init, abstract_params)
    ospecs = (param_specs(abstract_params, cfg, **fsdp_kw)
              if shard_mode in ("fsdp", "zero1") else pspecs)
    o_specs = AdamWState(step=P(), mu=ospecs, nu=ospecs)
    o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                           is_leaf=lambda x: isinstance(x, P))
    if abstract_batch is None:
        abstract_batch = {"tokens": jax.ShapeDtypeStruct((8, 8), jnp.int32),
                          "labels": jax.ShapeDtypeStruct((8, 8), jnp.int32)}
    b_shard = batch_shardings(mesh, abstract_batch)
    m_rep = NamedSharding(mesh, P())

    step = jax.jit(
        step_fn,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, m_rep),
        donate_argnums=(0, 1) if donate else (),
    )
    return TrainPlan(step, p_shard, o_shard, b_shard, abstract_params,
                     abstract_opt)
