"""Serving step factories: prefill (prompt -> cache) and decode (one token).

These are the objects the dry-run lowers for the `prefill_32k`, `decode_32k`
and `long_500k` cells; on CPU the examples drive them directly (mesh=None).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.sharding import batch_spec, cache_specs, param_specs

Array = Any


@dataclasses.dataclass
class ServePlan:
    prefill_fn: Any
    decode_fn: Any
    params_sharding: Any
    cache_sharding: Any
    abstract_params: Any
    abstract_cache: Any


def make_serve_plan(model, mesh: Optional[Mesh], batch: int, cache_len: int,
                    fsdp: bool = True, abstract_batch=None):
    cfg = model.cfg

    def prefill_fn(params, b):
        return model.prefill(params, b, cache_len=cache_len)

    def decode_fn(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    if mesh is None:
        return ServePlan(jax.jit(prefill_fn), jax.jit(decode_fn),
                         None, None, None, None)

    key = jax.random.PRNGKey(0)
    # anchor batch sharding at block boundaries (§Perf A3) — only when the
    # batch actually divides over the data axes (not long_500k batch=1)
    from repro.models.lm import ActivationSharding
    daxes_n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            daxes_n *= mesh.shape[a]
    if batch % daxes_n == 0:
        model.act_shard = ActivationSharding(mesh)
        if hasattr(model, "lm"):
            model.lm.act_shard = model.act_shard
    if cache_len >= 8192:
        # memory-efficient attention: the (T, S) prefill score tensor at 32k+
        # otherwise exceeds HBM (§Dry-run memory proof)
        model.q_chunk = 512
        if hasattr(model, "lm"):
            model.lm.q_chunk = 512
    if getattr(model, "moe_inference_cf", "x") is None:
        model.moe_inference_cf = 2.0  # finite serving capacity (drops rare)
    abstract_params = jax.eval_shape(model.init_params, key)
    pspecs = param_specs(abstract_params, cfg,
                         fsdp_axis="data" if fsdp else None,
                         fsdp_size=mesh.shape.get("data", 1))
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    abstract_cache = jax.eval_shape(
        lambda: model.init_cache(batch, cache_len))
    cspecs = cache_specs(abstract_cache, cfg, mesh, batch)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                           is_leaf=lambda x: isinstance(x, P))
    bspec = batch_spec(mesh)
    daxes = bspec[0]
    nb = 1
    for a, sz in mesh.shape.items():
        if a in (daxes if isinstance(daxes, tuple) else (daxes,)):
            nb *= sz
    batch_ok = batch % max(nb, 1) == 0

    def bshard(x):
        if not batch_ok:
            return NamedSharding(mesh, P(*([None] * x.ndim)))
        return NamedSharding(mesh,
                             P(*([daxes] + [None] * (x.ndim - 1))))

    if abstract_batch is None:
        abstract_batch = {
            "tokens": jax.ShapeDtypeStruct((batch, 8), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, 8), jnp.int32)}
    pre_b_shard = jax.tree.map(bshard, abstract_batch)
    tok_shard = bshard(jax.ShapeDtypeStruct((batch, 1), jnp.int32))
    # logits leave the step batch-sharded (replicating them costs a
    # full-vocab all-gather per decode step — §Perf iteration C2)
    logit_shard = (NamedSharding(mesh, P(daxes, None, None)) if batch_ok
                   else NamedSharding(mesh, P()))

    prefill = jax.jit(prefill_fn,
                      in_shardings=(p_shard, pre_b_shard),
                      out_shardings=(logit_shard, c_shard))
    decode = jax.jit(decode_fn,
                     in_shardings=(p_shard, c_shard, tok_shard),
                     out_shardings=(logit_shard, c_shard),
                     donate_argnums=(1,))
    return ServePlan(prefill, decode, p_shard, c_shard, abstract_params,
                     abstract_cache)
