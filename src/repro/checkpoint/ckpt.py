"""Sharded, step-addressed, async checkpointing with elastic restore.

Layout: <dir>/step_<N>/
  arrays.npz       — flattened pytree leaves (host-gathered numpy)
  meta.json        — treedef repr, step, data cursor, rng key, mesh shape

Fault-tolerance contract (DESIGN.md §5):
  * save is atomic (write to a uniquely-named tmp dir, fsync the payload,
    then publish with one rename) — a crash mid-save never corrupts the
    latest checkpoint; a crash between writing and publishing leaves an
    invisible tmp dir and `restore_latest` falls back to the previous
    complete step (tested under SIGKILL in tests/test_checkpoint_fault.py);
  * `restore_latest` finds the newest complete step — restart-after-failure
    is just rerunning the launcher;
  * arrays are saved UNSHARDED (host-gathered), so restore may apply ANY new
    sharding/mesh — elastic rescale (tests/test_checkpoint.py) and
    re-sharding onto a different shard count after a failure
    (repro.dist.elastic);
  * async mode snapshots to host memory synchronously (cheap) and writes to
    disk on a background thread (training continues).

This module is the ONE checkpoint writer in the repo: `dist/fault.py`'s
`TrainSupervisor` and `dist/elastic.py`'s snapshot loop both delegate here
rather than carrying their own (corruptible) save paths.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from typing import Any, Dict, Optional

import jax
import numpy as np

Array = Any

# Test/chaos injection point (see repro.dist.chaos.install_ckpt_write_crash):
# called as _crash_hook(stage_name, tmp_dir) at "arrays" (payload written),
# "meta"/"pre_rename" (tmp complete, publish pending).  None in production.
_crash_hook = None


def _stage(name: str, tmp_dir: str) -> None:
    if _crash_hook is not None:
        _crash_hook(name, tmp_dir)


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None,
         async_write: bool = False):
    """Checkpoint `tree` at `step`. Returns a join() handle in async mode."""
    flat, treedef = _flatten_with_names(tree)
    # snapshot to host synchronously (device buffers may be donated next step)
    host = [np.asarray(x) for x in flat]
    meta = {"step": int(step), "n_leaves": len(host),
            "treedef": str(treedef), "extra": extra or {}}

    def write():
        # unique tmp name: concurrent/crashed writers of the same step can
        # never interleave inside one tmp dir
        tmp = os.path.join(
            ckpt_dir, f".tmp_step_{step}_{os.getpid()}_{uuid.uuid4().hex[:8]}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        arrays_path = os.path.join(tmp, "arrays.npz")
        with open(arrays_path, "wb") as fh:
            np.savez(fh, **{f"leaf_{i}": a for i, a in enumerate(host)})
            fh.flush()
            os.fsync(fh.fileno())
        _stage("arrays", tmp)
        meta_path = os.path.join(tmp, "meta.json")
        with open(meta_path, "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        _stage("meta", tmp)
        if os.path.exists(final):
            # swap, don't rmtree-then-rename: a crash between the two renames
            # hides step N but the OLDER steps stay restorable (the previous
            # scheme had a window where step N was deleted and its
            # replacement not yet published, with nothing in between)
            old = os.path.join(
                ckpt_dir, f".old_step_{step}_{uuid.uuid4().hex[:8]}")
            os.rename(final, old)
        else:
            old = None
        _stage("pre_rename", tmp)
        os.rename(tmp, final)
        if old is not None:
            shutil.rmtree(old, ignore_errors=True)

    if async_write:
        t = threading.Thread(target=write)
        t.start()
        return t
    write()
    return None


def available_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_"):
            continue
        try:
            step = int(d.split("_", 1)[1])
        except ValueError:          # foreign/garbage entry — not a checkpoint
            continue
        if os.path.exists(os.path.join(ckpt_dir, d, "meta.json")):
            steps.append(step)
    return sorted(steps)


def prune(ckpt_dir: str, keep: int = 2) -> None:
    """Drop all but the newest `keep` complete steps, plus any stale tmp/old
    dirs left behind by crashed writers (their unique names make them dead
    the moment their writer is)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = available_steps(ckpt_dir)
    drop = steps[:-keep] if keep > 0 else steps
    for s in drop:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
    for d in os.listdir(ckpt_dir):
        if d.startswith(".tmp_step_") or d.startswith(".old_step_"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree` (abstract ok). `shardings`:
    optional matching tree of jax.sharding.Sharding for elastic re-placement."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten(like_tree)
    assert meta["n_leaves"] == len(flat_like), (
        f"checkpoint has {meta['n_leaves']} leaves, model expects "
        f"{len(flat_like)} — architecture mismatch")
    arrays = [data[f"leaf_{i}"] for i in range(len(flat_like))]
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        out = [jax.device_put(a, s) for a, s in zip(arrays, flat_sh)]
    else:
        out = [jax.numpy.asarray(a) for a in arrays]
    return treedef.unflatten(out), meta["extra"]


def restore_latest(ckpt_dir: str, like_tree, shardings=None):
    steps = available_steps(ckpt_dir)
    if not steps:
        return None
    tree, extra = restore(ckpt_dir, steps[-1], like_tree, shardings)
    return steps[-1], tree, extra
