"""Sharded, step-addressed, async checkpointing with elastic restore.

Layout: <dir>/step_<N>/
  arrays.npz       — flattened pytree leaves (host-gathered numpy)
  meta.json        — treedef repr, step, data cursor, rng key, mesh shape

Fault-tolerance contract (DESIGN.md §5):
  * save is atomic (write to tmp dir, rename) — a crash mid-save never
    corrupts the latest checkpoint;
  * `restore_latest` finds the newest complete step — restart-after-failure
    is just rerunning the launcher;
  * arrays are saved UNSHARDED (host-gathered), so restore may apply ANY new
    sharding/mesh — elastic rescale (tested in tests/test_checkpoint.py);
  * async mode snapshots to host memory synchronously (cheap) and writes to
    disk on a background thread (training continues).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

Array = Any


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None,
         async_write: bool = False):
    """Checkpoint `tree` at `step`. Returns a join() handle in async mode."""
    flat, treedef = _flatten_with_names(tree)
    # snapshot to host synchronously (device buffers may be donated next step)
    host = [np.asarray(x) for x in flat]
    meta = {"step": int(step), "n_leaves": len(host),
            "treedef": str(treedef), "extra": extra or {}}

    def write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_write:
        t = threading.Thread(target=write)
        t.start()
        return t
    write()
    return None


def available_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "meta.json")):
            steps.append(int(d.split("_")[1]))
    return sorted(steps)


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree` (abstract ok). `shardings`:
    optional matching tree of jax.sharding.Sharding for elastic re-placement."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten(like_tree)
    assert meta["n_leaves"] == len(flat_like), (
        f"checkpoint has {meta['n_leaves']} leaves, model expects "
        f"{len(flat_like)} — architecture mismatch")
    arrays = [data[f"leaf_{i}"] for i in range(len(flat_like))]
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        out = [jax.device_put(a, s) for a, s in zip(arrays, flat_sh)]
    else:
        out = [jax.numpy.asarray(a) for a in arrays]
    return treedef.unflatten(out), meta["extra"]


def restore_latest(ckpt_dir: str, like_tree, shardings=None):
    steps = available_steps(ckpt_dir)
    if not steps:
        return None
    tree, extra = restore(ckpt_dir, steps[-1], like_tree, shardings)
    return steps[-1], tree, extra
