"""Architecture configuration schema for the assigned model zoo.

One frozen dataclass describes every family (dense / MoE / SSM / hybrid /
enc-dec / VLM); `src/repro/configs/<id>.py` instantiates the exact published
numbers. Vocabularies are padded to a multiple of 2048 so the vocab dim always
shards over the 16-way `model` mesh axis (logits are masked back to the true
vocab; see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def pad_vocab(v: int, multiple: int = 2048) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // n_heads
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_softcap: float = 0.0      # grok-style logit soft cap (0 = off)
    window: int = 0                # sliding-window size for local layers
    global_every: int = 0          # gemma3: 1 global layer per this many
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    topk: int = 0
    moe_d_ff: int = 0              # per-expert FFN width
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    # hybrid (recurrentgemma): repeating block pattern, e.g. ("R","R","A")
    block_pattern: Tuple[str, ...] = ()
    rnn_width: int = 0
    # encoder-decoder (whisper backbone)
    enc_layers: int = 0
    enc_seq: int = 1500            # precomputed frame embeddings (stub frontend)
    # VLM (internvl backbone)
    vis_seq: int = 0               # image tokens after pixel shuffle
    vis_dim: int = 0               # frontend embedding width (stub)
    # training
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def d_inner(self) -> int:      # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True iff decode memory/compute is sub-quadratic-friendly at 512k:
        SSM, RG-LRU hybrid, or mostly-local attention (gemma3 5:1)."""
        return self.family in ("ssm", "hybrid") or self.global_every > 0

    def n_params(self) -> int:
        """Total parameter count (true vocab, untied unless tied)."""
        D, L = self.d_model, self.n_layers
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encdec"):
            att = D * self.n_heads * self.hd + 2 * D * self.n_kv_heads * self.hd \
                + self.n_heads * self.hd * D
            per_layer += att + 2 * D
            if self.family == "moe":
                per_layer += (self.n_experts + self.n_shared_experts) * \
                    3 * D * self.moe_d_ff + D * self.n_experts
            else:
                per_layer += 3 * D * self.d_ff
        if self.family == "ssm":
            din, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer += D * (2 * din) + 2 * D * N + D * H \
                + din * self.ssm_conv + din * D + 2 * D + H
        if self.family == "hybrid":
            W = self.rnn_width or D
            att = D * self.n_heads * self.hd + 2 * D * self.n_kv_heads * self.hd \
                + self.n_heads * self.hd * D
            rec = 2 * D * W + 2 * W * W + W * D + W * self.ssm_conv
            mlp = 3 * D * self.d_ff
            pat = self.block_pattern or ("R", "R", "A")
            n_att = sum(1 for i in range(L) if pat[i % len(pat)] == "A")
            per_layer = 0
            total = n_att * (att + mlp + 2 * D) + (L - n_att) * (rec + mlp + 2 * D)
            return emb + total + D
        total = emb + L * per_layer + D
        if self.family == "encdec":
            # encoder stack + cross-attention in decoder
            att = 4 * D * self.n_heads * self.hd
            total += self.enc_layers * (att + 3 * D * self.d_ff + 2 * D)
            total += L * att  # cross-attn
        if self.family == "vlm":
            total += self.vis_dim * D  # projector
        return total

    def n_params_active(self) -> int:
        """Active params per token (MoE routing)."""
        if self.family != "moe":
            return self.n_params()
        D, L = self.d_model, self.n_layers
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        att = D * self.n_heads * self.hd + 2 * D * self.n_kv_heads * self.hd \
            + self.n_heads * self.hd * D
        act = att + 2 * D + (self.topk + self.n_shared_experts) * \
            3 * D * self.moe_d_ff + D * self.n_experts
        return emb + L * act + D


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input-shape cells."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
