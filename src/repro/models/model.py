"""Model factory: config -> model instance (family dispatch)."""
from __future__ import annotations

import jax.numpy as jnp

from .config import ModelConfig
from .encdec import EncDecLM
from .lm import DecoderLM, HybridLM, Mamba2LM
from .vlm import VLM


def build_model(cfg: ModelConfig, dtype=jnp.bfloat16, remat=False,
                unroll=1, **kw):
    if cfg.family in ("dense", "moe"):
        return DecoderLM(cfg, dtype=dtype, remat=remat, unroll=unroll, **kw)
    if cfg.family == "ssm":
        return Mamba2LM(cfg, dtype=dtype, remat=remat, unroll=unroll, **kw)
    if cfg.family == "hybrid":
        return HybridLM(cfg, dtype=dtype, remat=remat, unroll=unroll)
    if cfg.family == "encdec":
        return EncDecLM(cfg, dtype=dtype, remat=remat, unroll=unroll)
    if cfg.family == "vlm":
        return VLM(cfg, dtype=dtype, remat=remat, unroll=unroll)
    raise ValueError(f"unknown family {cfg.family!r}")
