"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The gated diagonal linear recurrence
    a_t = exp(-c · softplus(Λ) · r_t),   r_t, i_t = σ(linear(x_t))
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
is a per-channel one-step ODE integrator — training parallelizes it with an
associative scan over time (the lanes-style treatment of the paper's fused
time loop), decode is the O(1) recurrence.

Block structure (Griffin): y = W_out[ RG-LRU(conv4(W_x x)) ⊙ GeLU(W_g x) ].
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import dense_init
from .ssm import _causal_conv

_C = 8.0


def rglru_params(key, D, W, K, dtype):
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], (D, W), dtype),
        "w_gate": dense_init(ks[1], (D, W), dtype),
        "w_r": dense_init(ks[2], (W, W), dtype),
        "w_i": dense_init(ks[3], (W, W), dtype),
        "b_r": jnp.zeros((W,), jnp.float32),
        "b_i": jnp.zeros((W,), jnp.float32),
        "lam": jnp.full((W,), 0.65, jnp.float32),  # a ~ 0.94^r at init
        "conv_w": dense_init(ks[4], (K, W), dtype, scale=0.5),
        "w_out": dense_init(ks[5], (W, D), dtype),
    }


def _gates(xb, p):
    f32 = jnp.float32
    r = jax.nn.sigmoid((xb @ p["w_r"]).astype(f32) + p["b_r"])
    i = jax.nn.sigmoid((xb @ p["w_i"]).astype(f32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xb.astype(f32))
    return a, gated


def rglru_train(x, p, state=None):
    """x (B,T,D) -> (y (B,T,D), state dict(h (B,W) f32, conv))."""
    xb = x @ p["w_x"]
    conv_state = None if state is None else state["conv"]
    xb, conv_new = _causal_conv(xb, p["conv_w"], conv_state)
    a, gated = _gates(xb, p)               # (B,T,W) f32
    if state is not None:
        # fold carried state into step 0: h_0 = a_0 h_in + gated_0
        gated = gated.at[:, 0].add(a[:, 0] * state["h"])

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h_fin = hh[:, -1]
    y = (hh.astype(x.dtype) * jax.nn.gelu(x @ p["w_gate"])) @ p["w_out"]
    return y, {"h": h_fin, "conv": conv_new}


def rglru_decode(x, p, state):
    """x (B,1,D), state dict(h (B,W) f32, conv (B,K-1,W))."""
    xb = x @ p["w_x"]
    xb, conv_new = _causal_conv(xb, p["conv_w"], state["conv"])
    a, gated = _gates(xb, p)               # (B,1,W)
    h = a[:, 0] * state["h"] + gated[:, 0]
    y = (h[:, None].astype(x.dtype) * jax.nn.gelu(x @ p["w_gate"])) @ p["w_out"]
    return y, {"h": h, "conv": conv_new}
