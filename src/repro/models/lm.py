"""Model classes for the assigned architecture zoo.

  DecoderLM  — dense / MoE / gemma3-style local:global patterns (uniform scan
               over layers: compile size O(1) in depth).
  Mamba2LM   — attention-free SSD stack.
  HybridLM   — recurrentgemma (R,R,A period scan: RG-LRU + local attention).
  EncDecLM   — whisper backbone (bidirectional encoder + cross-attn decoder;
               conv/mel frontend STUBBED: input_specs provides frame embeds).
  VLM        — internvl backbone (patch-embedding stub -> projector -> LM).

Common interface:
  init_params(key)          -> pytree (stacked per-layer leaves)
  loss(params, batch)       -> (scalar, metrics)   [train_4k]
  prefill(params, batch)    -> (last_logits, cache) [prefill_32k]
  decode_step(params, cache, tokens) -> (logits, cache) [decode_32k/long_500k]
  init_cache(batch, cache_len, dtype) -> pytree
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (apply_rope, attention_decode, attention_train,
                     attn_params, cross_attention, dense_init, mlp_params,
                     rmsnorm, rope_freqs, swiglu)
from .moe import moe_ffn, moe_params
from .rglru import rglru_decode, rglru_params, rglru_train
from .ssm import ssd_layer_decode, ssd_layer_train, ssd_params

Array = Any


def _embed_params(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    p = {"embed": dense_init(ks[0], (cfg.vocab_padded, cfg.d_model), dtype,
                             scale=0.02),
         "final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_padded),
                                  dtype)
    return p


def _logits(x, params, cfg):
    """Full-vocab logits in the COMPUTE dtype with the pad mask fused as an
    additive min-value (not an f32 where): the (B,T,Vp) tensor dominates HBM
    bytes for big-vocab training cells, so it stays bf16 end-to-end in
    deployment (§Perf iteration A2); f32/f64 in tests."""
    if cfg.tie_embeddings:
        lg = x @ params["embed"].T
    else:
        lg = x @ params["unembed"]
    V = cfg.vocab_size
    col = jnp.arange(cfg.vocab_padded)
    neg = jnp.asarray(jnp.finfo(lg.dtype).min / 8, lg.dtype)
    return jnp.where(col[None, None, :] < V, lg, neg)


class ActivationSharding:
    """Batch-dim sharding constraint applied at block boundaries.

    Without it GSPMD may trade batch sharding away (measured on
    qwen prefill_32k: the partitioner replicated the global batch over `data`
    and sharded attention over kv-heads => 16x redundant T^2 compute+bytes;
    §Perf iteration A3). Factories (train/serve) attach an instance to the
    model; mesh=None (tests/CPU) is a no-op.
    """

    def __init__(self, mesh=None):
        self.mesh = mesh
        if mesh is not None:
            self.daxes = tuple(a for a in ("pod", "data")
                               if a in mesh.axis_names)

    def __call__(self, x):
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(*([self.daxes] + [None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def xent_loss(logits, labels):
    """logits (B,T,Vp) any float dtype, labels (B,T). Max/sum statistics are
    accumulated in f32; the big tensors are never upcast."""
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    e = jnp.exp(logits - m)
    s = jnp.sum(e.astype(jnp.float32), axis=-1)
    lse = jnp.log(s) + m[..., 0].astype(jnp.float32)
    tgt = jnp.take_along_axis(logits, labels[..., None],
                              axis=-1)[..., 0].astype(jnp.float32)
    return jnp.mean(lse - tgt)


# ===========================================================================
# DecoderLM: dense / moe / gemma3 local-global
# ===========================================================================

class DecoderLM:
    def __init__(self, cfg: ModelConfig, dtype=jnp.bfloat16, remat=False,
                 moe_group=4096, moe_cf=1.25, unroll=1):
        self.cfg = cfg
        self.dtype = dtype
        self.remat = remat
        self.moe_group = moe_group
        self.moe_cf = moe_cf  # None => no-drop (used by inference paths)
        # inference capacity: None = no-drop exactness (tests); the serve
        # factory sets a finite factor (2.0) for deployment shapes — no-drop
        # dispatch buffers at 32k prefill are E/topk-times over-provisioned
        # (grok: 8/2 = 4x, measured 52 GiB/device)
        self.moe_inference_cf = None
        # unroll=True: unroll layer scans (roofline analysis mode — XLA cost
        # analysis counts a rolled scan body only ONCE; see launch/roofline)
        self.unroll = unroll
        self.act_shard = ActivationSharding(None)
        # q_chunk>0: memory-efficient attention over query blocks (set by the
        # serve/train factories for long-context deployment shapes)
        self.q_chunk = 0
        # per-layer is_global flags (gemma3 pattern; all-global otherwise)
        if cfg.global_every:
            flags = [(i + 1) % cfg.global_every == 0
                     for i in range(cfg.n_layers)]
        else:
            flags = [True] * cfg.n_layers
        self.layer_global = jnp.asarray(flags)

    # ---- params ----
    def _block_params(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p = {"attn": attn_params(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.hd, self.dtype, cfg.qkv_bias),
             "ln1": jnp.zeros((cfg.d_model,), self.dtype),
             "ln2": jnp.zeros((cfg.d_model,), self.dtype)}
        if cfg.family == "moe":
            p["moe"] = moe_params(k2, cfg.d_model, cfg.moe_d_ff,
                                  cfg.n_experts, cfg.n_shared_experts,
                                  self.dtype)
        else:
            p["mlp"] = mlp_params(k2, cfg.d_model, cfg.d_ff, self.dtype)
        return p

    def init_params(self, key):
        cfg = self.cfg
        ke, kb = jax.random.split(key)
        params = _embed_params(ke, cfg, self.dtype)
        params["blocks"] = jax.vmap(self._block_params)(
            jax.random.split(kb, cfg.n_layers))
        return params

    # ---- blocks ----
    def _attn_kwargs(self):
        cfg = self.cfg
        return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
                    rope_theta=cfg.rope_theta, window=cfg.window,
                    softcap=cfg.attn_softcap, q_chunk=self.q_chunk)

    def _block_train(self, p, x, is_global, aux):
        cfg = self.cfg
        x = self.act_shard(x)
        bias = ({k: p["attn"][k] for k in ("bq", "bk", "bv")}
                if cfg.qkv_bias else None)
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = x + attention_train(h, p["attn"], is_global=is_global, bias=bias,
                                **self._attn_kwargs())
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, a = moe_ffn(h, p["moe"], topk=cfg.topk,
                           n_experts=cfg.n_experts,
                           capacity_factor=self.moe_cf,
                           group_size=self.moe_group)
            aux = aux + a
        else:
            y = swiglu(h, p["mlp"])
        return x + y, aux

    def forward(self, params, tokens, h0=None):
        """Full-sequence compute (train / prefill). Returns (x, aux, kv):
        kv = (k, v) stacked (L, B, T, KV, hd) for cache building."""
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.dtype) if h0 is None else h0

        def body(carry, xs):
            x, aux = carry
            p, is_global = xs
            x, aux = block_fn(p, x, is_global, aux)
            return (x, aux), None

        block_fn = self._block_train
        if self.remat:
            # remat="dots": save matmul outputs (incl. FSDP-gathered weight
            # products) so the backward pass re-gathers nothing — trades
            # activation memory for ~1/3 of the gather collective traffic
            # (§Perf iteration B1). remat=True: full recompute (min memory).
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if self.remat == "dots" else None)
            block_fn = jax.checkpoint(block_fn, policy=policy)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.asarray(0.0, jnp.float32)),
                                   (params["blocks"], self.layer_global),
                                   unroll=self.unroll)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, aux

    def loss(self, params, batch):
        x, aux = self.forward(params, batch["tokens"])
        logits = _logits(x, params, self.cfg)
        ce = xent_loss(logits[:, :-1], batch["labels"][:, 1:])
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    # ---- serving ----
    def init_cache(self, batch, cache_len, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.dtype
        L = cfg.n_layers
        shape = (L, batch, cache_len, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, batch, cache_len=None):
        """Prompt pass: returns (last-position logits, filled cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        cache_len = cache_len or T
        x = params["embed"][tokens].astype(self.dtype)

        def body(carry, xs):
            x, aux = carry
            p, is_global = xs
            x = self.act_shard(x)
            bias = ({k: p["attn"][k] for k in ("bq", "bk", "bv")}
                    if cfg.qkv_bias else None)
            h = rmsnorm(x, p["ln1"], cfg.norm_eps)
            # recompute k/v for cache (train attention already rope-encodes)
            k = h @ p["attn"]["wk"]
            v = h @ p["attn"]["wv"]
            if bias is not None:
                k = k + bias["bk"]
                v = v + bias["bv"]
            k = k.reshape(B, T, cfg.n_kv_heads, cfg.hd)
            v = v.reshape(B, T, cfg.n_kv_heads, cfg.hd)
            cos, sin = rope_freqs(cfg.hd, cfg.rope_theta, jnp.arange(T))
            k = apply_rope(k, cos, sin)
            x = x + attention_train(h, p["attn"], is_global=is_global,
                                    bias=bias, **self._attn_kwargs())
            h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, a = moe_ffn(h2, p["moe"], topk=cfg.topk,
                               n_experts=cfg.n_experts,
                               capacity_factor=self.moe_inference_cf,
                               group_size=self.moe_group)
                aux = aux + a
            else:
                y = swiglu(h2, p["mlp"])
            return (x + y, aux), (k, v)

        (x, aux), (ks, vs) = jax.lax.scan(
            body, (x, jnp.asarray(0.0, jnp.float32)),
            (params["blocks"], self.layer_global), unroll=self.unroll)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = _logits(x[:, -1:], params, cfg)
        pad = cache_len - T
        if pad > 0:
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {"k": ks, "v": vs, "pos": jnp.asarray(T, jnp.int32)}
        return logits, cache

    def decode_step(self, params, cache, tokens):
        """tokens (B, 1) -> (logits (B,1,Vp), new cache)."""
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.dtype)
        pos = cache["pos"]

        def body(x, xs):
            p, is_global, ck, cv = xs
            bias = ({k: p["attn"][k] for k in ("bq", "bk", "bv")}
                    if cfg.qkv_bias else None)
            h = rmsnorm(x, p["ln1"], cfg.norm_eps)
            lc = {"k": ck, "v": cv, "pos": pos}
            a, lc = attention_decode(h, p["attn"], lc, is_global=is_global,
                                     bias=bias, **self._attn_kwargs())
            x = x + a
            h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, _ = moe_ffn(h2, p["moe"], topk=cfg.topk,
                               n_experts=cfg.n_experts,
                               capacity_factor=self.moe_inference_cf,
                               group_size=x.shape[0])
            else:
                y = swiglu(h2, p["mlp"])
            return x + y, (lc["k"], lc["v"])

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["blocks"], self.layer_global,
                      cache["k"], cache["v"]), unroll=self.unroll)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return _logits(x, params, cfg), {"k": ks, "v": vs, "pos": pos + 1}


# ===========================================================================
# Mamba2LM
# ===========================================================================

class Mamba2LM:
    def __init__(self, cfg: ModelConfig, dtype=jnp.bfloat16, remat=False,
                 ssd_chunk=256, unroll=1):
        self.cfg = cfg
        self.dtype = dtype
        self.remat = remat
        self.ssd_chunk = ssd_chunk
        self.unroll = unroll
        self.act_shard = ActivationSharding(None)
        self.q_chunk = 0  # inert (attention-free)

    def init_params(self, key):
        cfg = self.cfg
        ke, kb = jax.random.split(key)
        params = _embed_params(ke, cfg, self.dtype)

        def one(k):
            return {"ssd": ssd_params(k, cfg, self.dtype),
                    "ln": jnp.zeros((cfg.d_model,), self.dtype)}

        params["blocks"] = jax.vmap(one)(jax.random.split(kb, cfg.n_layers))
        return params

    def forward(self, params, tokens):
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.dtype)

        def block(p, x):
            x = self.act_shard(x)
            h = rmsnorm(x, p["ln"], cfg.norm_eps)
            y, _ = ssd_layer_train(h, p["ssd"], cfg, chunk=self.ssd_chunk)
            return x + y

        if self.remat:
            block = jax.checkpoint(block)

        def body(x, p):
            return block(p, x), None

        x, _ = jax.lax.scan(lambda c, p: (block(p, c), None), x,
                            params["blocks"], unroll=self.unroll)
        return rmsnorm(x, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch):
        x = self.forward(params, batch["tokens"])
        logits = _logits(x, params, self.cfg)
        ce = xent_loss(logits[:, :-1], batch["labels"][:, 1:])
        return ce, {"ce": ce, "aux": jnp.asarray(0.0)}

    def init_cache(self, batch, cache_len, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.dtype
        L = cfg.n_layers
        din, N = cfg.d_inner, cfg.ssm_state
        H, P = cfg.ssm_heads, cfg.ssm_head_dim
        K = cfg.ssm_conv
        return {"h": jnp.zeros((L, batch, H, P, N), jnp.float32),
                "conv": jnp.zeros((L, batch, K - 1, din + 2 * N), dtype),
                "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, batch, cache_len=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"][tokens].astype(self.dtype)

        def body(x, p):
            x = self.act_shard(x)
            h = rmsnorm(x, p["ln"], cfg.norm_eps)
            y, st = ssd_layer_train(h, p["ssd"], cfg, chunk=self.ssd_chunk)
            return x + y, (st["h"], st["conv"])

        x, (hs, convs) = jax.lax.scan(body, x, params["blocks"],
                                      unroll=self.unroll)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = _logits(x[:, -1:], params, cfg)
        cache = {"h": hs, "conv": convs,
                 "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
        return logits, cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.dtype)

        def body(x, xs):
            p, h, conv = xs
            hh = rmsnorm(x, p["ln"], cfg.norm_eps)
            y, st = ssd_layer_decode(hh, p["ssd"], cfg,
                                     {"h": h, "conv": conv})
            return x + y, (st["h"], st["conv"])

        x, (hs, convs) = jax.lax.scan(body, x, (params["blocks"], cache["h"],
                                                cache["conv"]),
                                      unroll=self.unroll)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return _logits(x, params, cfg), {"h": hs, "conv": convs,
                                         "pos": cache["pos"] + 1}


# ===========================================================================
# HybridLM (recurrentgemma): period pattern (R, R, A)
# ===========================================================================

class HybridLM:
    def __init__(self, cfg: ModelConfig, dtype=jnp.bfloat16, remat=False,
                 unroll=1):
        self.cfg = cfg
        self.dtype = dtype
        self.remat = remat
        self.unroll = unroll
        self.act_shard = ActivationSharding(None)
        self.q_chunk = 0
        pat = cfg.block_pattern or ("R", "R", "A")
        self.pattern = pat
        self.period = len(pat)
        self.n_periods = cfg.n_layers // self.period
        self.rem = tuple(pat[:cfg.n_layers % self.period])
        self.W = cfg.rnn_width or cfg.d_model

    def _slot_params(self, key, kind):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p = {"ln1": jnp.zeros((cfg.d_model,), self.dtype),
             "ln2": jnp.zeros((cfg.d_model,), self.dtype),
             "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, self.dtype)}
        if kind == "A":
            p["attn"] = attn_params(k1, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.hd, self.dtype)
        else:
            p["rglru"] = rglru_params(k1, cfg.d_model, self.W, cfg.ssm_conv,
                                      self.dtype)
        return p

    def init_params(self, key):
        cfg = self.cfg
        ke, kb, kr = jax.random.split(key, 3)
        params = _embed_params(ke, cfg, self.dtype)
        slot_stacks = []
        for s, kind in enumerate(self.pattern):
            keys = jax.random.split(jax.random.fold_in(kb, s),
                                    self.n_periods)
            slot_stacks.append(jax.vmap(
                partial(self._slot_params, kind=kind))(keys))
        params["periods"] = tuple(slot_stacks)
        params["rem"] = tuple(
            self._slot_params(jax.random.fold_in(kr, i), kind)
            for i, kind in enumerate(self.rem))
        return params

    def _apply_slot(self, p, x, kind, mode, state=None):
        """mode: train|prefill|decode. Returns (x, new_state)."""
        cfg = self.cfg
        x = self.act_shard(x)
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        if kind == "A":
            if mode == "decode":
                # ring-buffer window cache: eviction IS the sliding window
                a, state = attention_decode(
                    h, p["attn"], state, n_heads=cfg.n_heads,
                    n_kv=cfg.n_kv_heads, hd=cfg.hd,
                    rope_theta=cfg.rope_theta, window=0, is_global=True)
            else:
                a = attention_train(h, p["attn"], n_heads=cfg.n_heads,
                                    n_kv=cfg.n_kv_heads, hd=cfg.hd,
                                    rope_theta=cfg.rope_theta,
                                    window=cfg.window, is_global=False,
                                    q_chunk=self.q_chunk)
                if mode == "prefill":
                    B, T, _ = h.shape
                    k = (h @ p["attn"]["wk"]).reshape(B, T, cfg.n_kv_heads,
                                                      cfg.hd)
                    v = (h @ p["attn"]["wv"]).reshape(B, T, cfg.n_kv_heads,
                                                      cfg.hd)
                    cos, sin = rope_freqs(cfg.hd, cfg.rope_theta,
                                          jnp.arange(T))
                    k = apply_rope(k, cos, sin)
                    state = {"k": k, "v": v}
        else:
            if mode == "decode":
                a, state = rglru_decode(h, p["rglru"], state)
            else:
                a, state = rglru_train(h, p["rglru"],
                                       state if mode == "prefill" else None)
        x = x + a
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + swiglu(h2, p["mlp"]), state

    def forward(self, params, tokens):
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.dtype)

        def period_fn(x, slot_params):
            for s, kind in enumerate(self.pattern):
                x, _ = self._apply_slot(
                    jax.tree.map(lambda a: a, slot_params[s]), x, kind,
                    "train")
            return x

        if self.remat:
            period_fn = jax.checkpoint(period_fn)

        def body(x, slot_params):
            return period_fn(x, slot_params), None

        x, _ = jax.lax.scan(body, x, params["periods"],
                            unroll=self.unroll)
        for i, kind in enumerate(self.rem):
            x, _ = self._apply_slot(params["rem"][i], x, kind, "train")
        return rmsnorm(x, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch):
        x = self.forward(params, batch["tokens"])
        logits = _logits(x, params, self.cfg)
        ce = xent_loss(logits[:, :-1], batch["labels"][:, 1:])
        return ce, {"ce": ce, "aux": jnp.asarray(0.0)}

    # serving: caches per slot kind. Attention slots keep a WINDOW-sized
    # cache (ring buffer semantics via position clamp) — RG-LRU state is O(1):
    # this is what makes long_500k run for this family.
    def init_cache(self, batch, cache_len, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.dtype
        wlen = min(cache_len, cfg.window) if cfg.window else cache_len
        K = cfg.ssm_conv
        caches = []
        for s, kind in enumerate(self.pattern):
            if kind == "A":
                caches.append({
                    "k": jnp.zeros((self.n_periods, batch, wlen,
                                    cfg.n_kv_heads, cfg.hd), dtype),
                    "v": jnp.zeros((self.n_periods, batch, wlen,
                                    cfg.n_kv_heads, cfg.hd), dtype)})
            else:
                caches.append({
                    "h": jnp.zeros((self.n_periods, batch, self.W),
                                   jnp.float32),
                    "conv": jnp.zeros((self.n_periods, batch, K - 1, self.W),
                                      dtype)})
        rem = []
        for kind in self.rem:
            if kind == "A":
                rem.append({"k": jnp.zeros((batch, wlen, cfg.n_kv_heads,
                                            cfg.hd), dtype),
                            "v": jnp.zeros((batch, wlen, cfg.n_kv_heads,
                                            cfg.hd), dtype)})
            else:
                rem.append({"h": jnp.zeros((batch, self.W), jnp.float32),
                            "conv": jnp.zeros((batch, K - 1, self.W), dtype)})
        return {"slots": tuple(caches), "rem": tuple(rem),
                "pos": jnp.zeros((), jnp.int32)}

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.dtype)
        pos = cache["pos"]
        wlen = cache["slots"][self.pattern.index("A")]["k"].shape[2] \
            if "A" in self.pattern else 0

        def body(x, xs):
            slot_params = xs[0]
            slot_caches = xs[1]
            new_caches = []
            for s, kind in enumerate(self.pattern):
                st = dict(slot_caches[s])
                if kind == "A":
                    st["pos"] = pos                      # absolute (rope)
                    st["write_idx"] = pos % wlen         # ring slot
                x, st = self._apply_slot(slot_params[s], x, kind, "decode",
                                         state=st)
                if kind == "A":
                    st = {"k": st["k"], "v": st["v"]}
                new_caches.append(st)
            return x, tuple(new_caches)

        x, new_slots = jax.lax.scan(body, x,
                                    (params["periods"], cache["slots"]),
                                    unroll=self.unroll)
        rem_new = []
        for i, kind in enumerate(self.rem):
            st = dict(cache["rem"][i])
            if kind == "A":
                st["pos"] = pos
                st["write_idx"] = pos % wlen
            x, st = self._apply_slot(params["rem"][i], x, kind, "decode",
                                     state=st)
            if kind == "A":
                st = {"k": st["k"], "v": st["v"]}
            rem_new.append(st)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return _logits(x, params, cfg), {"slots": new_slots,
                                         "rem": tuple(rem_new),
                                         "pos": pos + 1}

    def prefill(self, params, batch, cache_len=None):
        # prefill = forward + state capture; window caches keep the LAST
        # `wlen` keys placed at their ring slots (slot = position % wlen).
        cfg = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        cache_len = cache_len or T
        x = params["embed"][tokens].astype(self.dtype)
        wlen = min(cache_len, cfg.window) if cfg.window else cache_len

        def to_ring(k):
            """(B, T, KV, hd) -> (B, wlen, KV, hd) at ring slots."""
            if T >= wlen:
                kept = k[:, -wlen:]
                return jnp.roll(kept, T % wlen, axis=1)
            pad = [(0, 0), (0, wlen - T)] + [(0, 0)] * (k.ndim - 2)
            return jnp.pad(k, pad)

        def run_slot(x, p, kind):
            return self._apply_slot(p, x, kind, "prefill")

        x_cur = x
        collected = [[] for _ in self.pattern]
        for c in range(self.n_periods):
            for s, kind in enumerate(self.pattern):
                p = jax.tree.map(lambda a: a[c], params["periods"][s])
                x_cur, st = run_slot(x_cur, p, kind)
                if kind == "A":
                    st = {"k": to_ring(st["k"]), "v": to_ring(st["v"])}
                collected[s].append(st)
        rem_states = []
        for i, kind in enumerate(self.rem):
            x_cur, st = run_slot(x_cur, params["rem"][i], kind)
            if kind == "A":
                st = {"k": to_ring(st["k"]), "v": to_ring(st["v"])}
            rem_states.append(st)
        slots = tuple(jax.tree.map(lambda *xs: jnp.stack(xs), *col)
                      for col in collected)
        x_cur = rmsnorm(x_cur, params["final_norm"], cfg.norm_eps)
        logits = _logits(x_cur[:, -1:], params, cfg)
        return logits, {"slots": slots, "rem": tuple(rem_states),
                        "pos": jnp.asarray(T, jnp.int32)}
