"""Shared transformer building blocks: RMSNorm, RoPE, GQA attention (global /
sliding-window, optional softcap and bias), SwiGLU MLP.

Conventions:
  activations  x: (B, T, D), computed in the param dtype (bf16 target),
  softmax/norm statistics in f32.
  attention weights: wq (D, H*hd), wk/wv (D, KV*hd), wo (H*hd, D).
  KV cache: dict(k=(B, S, KV, hd), v=(B, S, KV, hd), pos=()) — pos is the
  current fill level (static-shape cache, masked reads).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = Any


# ---------------------------------------------------------------------------
# norms & positional encoding
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_freqs(hd: int, theta: float, positions):
    """positions (…,) -> cos/sin (…, hd/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(q, cos, sin):
    """q (B, T, H, hd); cos/sin (T, hd/2) or (B, T, hd/2)."""
    q1, q2 = jnp.split(q, 2, axis=-1)
    cos = cos[..., None, :]          # head axis
    sin = sin[..., None, :]
    while cos.ndim < q1.ndim:        # leading batch axes
        cos = cos[None]
        sin = sin[None]
    out = jnp.concatenate([q1 * cos - q2 * sin, q1 * sin + q2 * cos], axis=-1)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _soft_cap(logits, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


def attention_train(x, w, *, n_heads, n_kv, hd, rope_theta, window=0,
                    softcap=0.0, is_global=True, bias=None, positions=None,
                    causal=True, q_chunk=0):
    """Self-attention over a full sequence (training / prefill compute).

    w: dict(wq, wk, wv, wo [, bq, bk, bv]). window>0 & not is_global =>
    sliding-window causal mask; causal=False => bidirectional (encoders).
    q_chunk>0 => memory-efficient attention: scan over query blocks so the
    peak score tensor is (…, q_chunk, S) instead of (…, T, S) — required for
    the 32k prefill cells to fit HBM (§Dry-run memory proof); 0 => dense.
    Returns (B, T, D).
    """
    B, T, D = x.shape
    q = x @ w["wq"]
    k = x @ w["wk"]
    v = x @ w["wv"]
    if bias is not None:
        q = q + bias["bq"]
        k = k + bias["bk"]
        v = v + bias["bv"]
    q = q.reshape(B, T, n_heads, hd)
    k = k.reshape(B, T, n_kv, hd)
    v = v.reshape(B, T, n_kv, hd)
    if positions is None:
        positions = jnp.arange(T)
    cos, sin = rope_freqs(hd, rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    g = n_heads // n_kv
    q = q.reshape(B, T, n_kv, g, hd)
    # score pipeline stays in the compute dtype (bf16 deployment / f32+f64
    # tests): the T^2 tensors dominate HBM bytes at long context, and bf16
    # scores with f32-accumulated softmax sums are the standard accuracy
    # trade (§Perf iteration A1 — halves-to-thirds the memory roofline term).
    dt = x.dtype
    neg = jnp.asarray(jnp.finfo(dt).min / 8, dt)
    si = jnp.arange(T)[None, :]

    def block(qb, q0):
        """qb: (B, C, KV, g, hd) starting at global row q0. -> (B, C, H*hd)"""
        C = qb.shape[1]
        logits = jnp.einsum("bqkgh,bskh->bkgqs", qb, k)
        logits = logits * (1.0 / float(hd) ** 0.5)
        logits = _soft_cap(logits, softcap)
        qi = q0 + jnp.arange(C)[:, None]
        mask = (si <= qi) if causal else jnp.ones((C, T), bool)
        if window:
            # is_global may be a traced per-layer flag (gemma3 5:1 pattern)
            wmask = mask & (si > qi - window)
            mask = jnp.where(jnp.asarray(is_global), mask, wmask)
        logits = jnp.where(mask, logits, neg)
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        e = jnp.exp(logits - m)
        s = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
        probs = (e / s.astype(dt))
        ob = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
        return ob.reshape(B, C, n_heads * hd)

    if q_chunk and T > q_chunk and T % q_chunk == 0:
        nc = T // q_chunk
        qc = q.reshape(B, nc, q_chunk, n_kv, g, hd)

        def body(_, idx):
            qb = qc[:, idx]
            return None, block(qb, idx * q_chunk)

        _, blocks = jax.lax.scan(body, None, jnp.arange(nc))
        out = jnp.moveaxis(blocks, 0, 1).reshape(B, T, n_heads * hd)
    else:
        out = block(q, 0)
    return out @ w["wo"]


def attention_decode(x, w, cache: Dict[str, Array], *, n_heads, n_kv, hd,
                     rope_theta, window=0, softcap=0.0, is_global=True,
                     bias=None, q_chunk=0):  # q_chunk ignored (single token)
    """One-token decode against a static-shape KV cache.

    x: (B, 1, D); cache k/v: (B, S, KV, hd), cache["pos"]: scalar int32
    absolute position of the NEW token. Two cache layouts:
      absolute — slot i holds position i (default); causal mask si <= pos,
                 optional sliding-window mask.
      ring     — cache["write_idx"] present: slot = position % S (window-sized
                 caches for local-attention layers; rope stays absolute so
                 relative geometry is preserved, eviction is automatic).
    Returns (out (B,1,D), new_cache).
    """
    B, T, D = x.shape
    assert T == 1
    S = cache["k"].shape[1]
    pos = cache["pos"]
    write_idx = cache.get("write_idx", pos)
    q = x @ w["wq"]
    k = x @ w["wk"]
    v = x @ w["wv"]
    if bias is not None:
        q = q + bias["bq"]
        k = k + bias["bk"]
        v = v + bias["bv"]
    q = q.reshape(B, 1, n_heads, hd)
    k = k.reshape(B, 1, n_kv, hd)
    v = v.reshape(B, 1, n_kv, hd)
    cos, sin = rope_freqs(hd, rope_theta, pos[None].astype(jnp.float32))
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    zero = jnp.zeros((), write_idx.dtype)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (zero, write_idx, zero, zero))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (zero, write_idx, zero, zero))

    g = n_heads // n_kv
    qh = q.reshape(B, n_kv, g, hd)
    dt = x.dtype
    logits = jnp.einsum("bkgh,bskh->bkgs", qh, ck)
    logits = logits * (1.0 / float(hd) ** 0.5)
    logits = _soft_cap(logits, softcap)
    si = jnp.arange(S)
    valid = si <= pos
    if window and "write_idx" not in cache:
        wvalid = valid & (si > pos - window)
        valid = jnp.where(jnp.asarray(is_global), valid, wvalid)
    neg = jnp.asarray(jnp.finfo(dt).min / 8, dt)
    logits = jnp.where(valid[None, None, None, :], logits, neg)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    s = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
    probs = e / s.astype(dt)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, cv)
    out = out.reshape(B, 1, n_heads * hd)
    return out @ w["wo"], {"k": ck, "v": cv, "pos": pos + 1}


def cross_attention(x, w, kv_k, kv_v, *, n_heads, n_kv, hd):
    """Decoder→encoder cross-attention (whisper). kv_k/kv_v: (B, Senc, KV, hd)
    precomputed from encoder output; no mask, no rope (absolute content)."""
    B, T, D = x.shape
    q = (x @ w["wq"]).reshape(B, T, n_heads, hd)
    g = n_heads // n_kv
    qh = q.reshape(B, T, n_kv, g, hd)
    dt = x.dtype
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qh, kv_k)
    logits = logits * (1.0 / float(hd) ** 0.5)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    s = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
    probs = e / s.astype(dt)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, kv_v).reshape(B, T,
                                                               n_heads * hd)
    return out @ w["wo"]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu(x, w):
    """w: dict(wi, wg, wo): (D,F), (D,F), (F,D)."""
    return (jax.nn.silu(x @ w["wg"]) * (x @ w["wi"])) @ w["wo"]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def attn_params(key, D, n_heads, n_kv, hd, dtype, qkv_bias=False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, n_heads * hd), dtype),
        "wk": dense_init(ks[1], (D, n_kv * hd), dtype),
        "wv": dense_init(ks[2], (D, n_kv * hd), dtype),
        "wo": dense_init(ks[3], (n_heads * hd, D), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((n_kv * hd,), dtype)
        p["bv"] = jnp.zeros((n_kv * hd,), dtype)
    return p


def mlp_params(key, D, F, dtype):
    ks = jax.random.split(key, 3)
    return {"wi": dense_init(ks[0], (D, F), dtype),
            "wg": dense_init(ks[1], (D, F), dtype),
            "wo": dense_init(ks[2], (F, D), dtype)}
