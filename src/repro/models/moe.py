"""Mixture-of-Experts FFN: grouped capacity-based top-k dispatch (GShard/GSPMD
style) + always-on shared experts.

Covers both assigned MoE architectures:
  grok-1        — 8 experts, top-2, no shared experts (expert d_ff 32768).
  deepseek-moe  — 64 fine-grained routed experts top-6 + 2 shared experts
                  (expert d_ff 1408).

Dispatch: tokens are processed in groups of `group_size`; within a group each
token's top-k experts get a slot up to capacity C = ceil(g·topk/E · cf)
(overflow soft-drops, standard Switch behaviour). Expert compute is then
exactly E·C ≈ topk·cf tokens' worth of FFN — the compiled FLOPs track ACTIVE
parameters (6·N_active·D), not total, which the roofline §MODEL/HLO ratio
checks. Under GSPMD the expert axis shards over `model` when divisible
(deepseek 64/16: expert parallelism; dispatch einsums become the all-to-all
exchange), otherwise the FFN width shards (grok: 8 experts, TP within expert).
Router runs in f32 and returns the Switch load-balance aux loss.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import dense_init

Array = Any


def moe_params(key, D, F, n_experts, n_shared, dtype):
    ks = jax.random.split(key, 7)

    def stack(k, shape):
        return dense_init(k, shape, dtype, scale=1.0 / jnp.sqrt(shape[-2]))

    p = {
        "router": dense_init(ks[0], (D, n_experts), jnp.float32),
        "wi": stack(ks[1], (n_experts, D, F)),
        "wg": stack(ks[2], (n_experts, D, F)),
        "wo": stack(ks[3], (n_experts, F, D)),
    }
    if n_shared:
        p["s_wi"] = stack(ks[4], (n_shared, D, F))
        p["s_wg"] = stack(ks[5], (n_shared, D, F))
        p["s_wo"] = stack(ks[6], (n_shared, F, D))
    return p


def moe_ffn(x, p, *, topk: int, n_experts: int,
            capacity_factor: float = 1.25, group_size: int = 4096):
    """x (B, T, D) -> (out (B, T, D), aux_loss scalar).

    capacity_factor=None => no-drop (C = g): used for inference paths where
    token dropping would make prefill/decode inconsistent."""
    B, T, D = x.shape
    N = B * T
    g = min(group_size, N)
    assert N % g == 0, f"tokens {N} not divisible by MoE group size {g}"
    G = N // g
    E = n_experts
    if capacity_factor is None:
        C = g
    else:
        C = max(1, int((g * topk / E) * capacity_factor))
    xf = x.reshape(G, g, D)

    logits = jnp.einsum("Ggd,de->Gge", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, g, E)
    topv, topi = jax.lax.top_k(probs, topk)                  # (G, g, k)
    topv = topv / (jnp.sum(topv, axis=-1, keepdims=True) + 1e-9)
    oh = jax.nn.one_hot(topi, E, dtype=jnp.float32)          # (G, g, k, E)

    # position of each (token, k-slot) in its expert queue (token-major order)
    ohf = oh.reshape(G, g * topk, E)
    pos = jnp.cumsum(ohf, axis=1) - ohf                      # (G, g*k, E)
    pos_slot = jnp.sum(pos * ohf, axis=-1).reshape(G, g, topk)
    pos_slot = pos_slot.astype(jnp.int32)
    keep = (pos_slot < C).astype(jnp.float32)                # capacity drop
    pos_oh = jax.nn.one_hot(pos_slot, C, dtype=jnp.float32)  # (G, g, k, C)

    dispatch = jnp.einsum("Ggke,Ggkc,Ggk->Ggec", oh, pos_oh, keep)
    combine = jnp.einsum("Ggec,Ggk,Ggke->Ggec", dispatch, topv, oh)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)

    xe = jnp.einsum("Ggec,Ggd->Gecd", dispatch, xf)          # (G, E, C, D)
    hg = jnp.einsum("Gecd,edf->Gecf", xe, p["wg"])
    hi = jnp.einsum("Gecd,edf->Gecf", xe, p["wi"])
    he = jax.nn.silu(hg) * hi
    ye = jnp.einsum("Gecf,efd->Gecd", he, p["wo"])
    y = jnp.einsum("Gecd,Ggec->Ggd", ye, combine)

    if "s_wi" in p:   # shared experts: always-on, plain FFN sum
        sg = jnp.einsum("Ggd,sdf->Ggsf", xf, p["s_wg"])
        si = jnp.einsum("Ggd,sdf->Ggsf", xf, p["s_wi"])
        y = y + jnp.einsum("Ggsf,sfd->Ggd", jax.nn.silu(sg) * si, p["s_wo"])

    # Switch-style load-balance aux: E * sum_e f_e * P_e
    f_e = jnp.mean(jnp.sum(oh, axis=2), axis=(0, 1))
    P_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e * P_e)
    return y.reshape(B, T, D), aux
