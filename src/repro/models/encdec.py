"""Whisper-style encoder-decoder BACKBONE (paper pool entry: whisper-tiny).

Per the assignment the conv/mel audio frontend is a STUB: `input_specs()`
provides precomputed frame embeddings (B, enc_seq, D). The backbone is real:
bidirectional transformer encoder + causal decoder with cross-attention.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (apply_rope, attention_decode, attention_train,
                     attn_params, cross_attention, mlp_params, rmsnorm,
                     rope_freqs, swiglu)
from .lm import _embed_params, _logits, xent_loss

Array = Any


class EncDecLM:
    def __init__(self, cfg: ModelConfig, dtype=jnp.bfloat16, remat=False,
                 unroll=1):
        self.cfg = cfg
        self.dtype = dtype
        self.remat = remat
        self.unroll = unroll
        from .lm import ActivationSharding
        self.act_shard = ActivationSharding(None)
        self.q_chunk = 0

    def _enc_block_params(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {"attn": attn_params(k1, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.hd, self.dtype),
                "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, self.dtype),
                "ln1": jnp.zeros((cfg.d_model,), self.dtype),
                "ln2": jnp.zeros((cfg.d_model,), self.dtype)}

    def _dec_block_params(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        p = self._enc_block_params(jax.random.fold_in(key, 0))
        p["xattn"] = attn_params(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.hd, self.dtype)
        p["lnx"] = jnp.zeros((cfg.d_model,), self.dtype)
        return p

    def init_params(self, key):
        cfg = self.cfg
        ke, k1, k2 = jax.random.split(key, 3)
        params = _embed_params(ke, cfg, self.dtype)
        params["enc_blocks"] = jax.vmap(self._enc_block_params)(
            jax.random.split(k1, cfg.enc_layers))
        params["dec_blocks"] = jax.vmap(self._dec_block_params)(
            jax.random.split(k2, cfg.n_layers))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), self.dtype)
        return params

    def _attn_kwargs(self):
        cfg = self.cfg
        return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
                    rope_theta=cfg.rope_theta, q_chunk=self.q_chunk)

    def encode(self, params, frames):
        """frames: (B, enc_seq, D) stub embeddings -> encoder states."""
        cfg = self.cfg
        x = frames.astype(self.dtype)

        def block(p, x):
            x = self.act_shard(x)
            h = rmsnorm(x, p["ln1"], cfg.norm_eps)
            a = attention_train(h, p["attn"], causal=False,
                                **self._attn_kwargs())
            x = x + a
            h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
            return x + swiglu(h2, p["mlp"])

        if self.remat:
            block = jax.checkpoint(block)
        x, _ = jax.lax.scan(lambda c, p: (block(p, c), None), x,
                            params["enc_blocks"], unroll=self.unroll)
        return rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    def _xkv(self, p, enc):
        cfg = self.cfg
        B, S, D = enc.shape
        k = (enc @ p["xattn"]["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        v = (enc @ p["xattn"]["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        return k, v

    def _dec_block(self, p, x, enc, mode, cache=None):
        cfg = self.cfg
        x = self.act_shard(x)
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        if mode == "decode":
            a, cache = attention_decode(h, p["attn"], cache,
                                        **self._attn_kwargs())
        else:
            a = attention_train(h, p["attn"], **self._attn_kwargs())
        x = x + a
        h = rmsnorm(x, p["lnx"], cfg.norm_eps)
        xk, xv = self._xkv(p, enc)
        x = x + cross_attention(h, p["xattn"], xk, xv, n_heads=cfg.n_heads,
                                n_kv=cfg.n_kv_heads, hd=cfg.hd)
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + swiglu(h, p["mlp"]), cache

    def forward(self, params, tokens, frames):
        cfg = self.cfg
        enc = self.encode(params, frames)
        x = params["embed"][tokens].astype(self.dtype)

        def body(x, p):
            y, _ = self._dec_block(p, x, enc, "train")
            return y, None

        x, _ = jax.lax.scan(body, x, params["dec_blocks"],
                            unroll=self.unroll)
        return rmsnorm(x, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch):
        x = self.forward(params, batch["tokens"], batch["frames"])
        logits = _logits(x, params, self.cfg)
        ce = xent_loss(logits[:, :-1], batch["labels"][:, 1:])
        return ce, {"ce": ce, "aux": jnp.asarray(0.0)}

    def init_cache(self, batch, cache_len, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.dtype
        L = cfg.n_layers
        return {
            "k": jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, cfg.hd),
                           dtype),
            "v": jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, cfg.hd),
                           dtype),
            "enc": jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dtype),
            "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, batch, cache_len=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        frames = batch["frames"]
        B, T = tokens.shape
        cache_len = cache_len or T
        enc = self.encode(params, frames)
        x = params["embed"][tokens].astype(self.dtype)

        def body(x, p):
            h = rmsnorm(x, p["ln1"], cfg.norm_eps)
            k = (h @ p["attn"]["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.hd)
            v = (h @ p["attn"]["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.hd)
            cos, sin = rope_freqs(cfg.hd, cfg.rope_theta, jnp.arange(T))
            k = apply_rope(k, cos, sin)
            y, _ = self._dec_block(p, x, enc, "train")
            return y, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, params["dec_blocks"],
                                   unroll=self.unroll)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        pad = cache_len - T
        if pad > 0:
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return _logits(x[:, -1:], params, cfg), {
            "k": ks, "v": vs, "enc": enc, "pos": jnp.asarray(T, jnp.int32)}

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.dtype)
        pos = cache["pos"]
        enc = cache["enc"]

        def body(x, xs):
            p, ck, cv = xs
            lc = {"k": ck, "v": cv, "pos": pos}
            y, lc = self._dec_block(p, x, enc, "decode", cache=lc)
            return y, (lc["k"], lc["v"])

        x, (ks, vs) = jax.lax.scan(body, x, (params["dec_blocks"],
                                             cache["k"], cache["v"]),
                                   unroll=self.unroll)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return _logits(x, params, cfg), {"k": ks, "v": vs, "enc": enc,
                                         "pos": pos + 1}
