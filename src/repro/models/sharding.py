"""GSPMD sharding rules for the model zoo on the production mesh.

Axis convention (launch/mesh.py):
  "pod"   — cross-pod data parallelism (outermost; DCN-class links)
  "data"  — in-pod data parallelism (batch axis / ensemble axis / cache-seq)
  "model" — tensor/expert parallelism (16-way)

Parameter rules are matched by leaf *path name* over the abstract param tree,
so one matcher covers every family (stacked layer axes are skipped
automatically: any leading axes beyond the rule's rank get None).

Key choices (see DESIGN.md §4/§5):
  embeddings      vocab-sharded over `model` (vocabs padded to /2048)
  attention       fused head*head_dim feature dim over `model` (works for
                  head counts not divisible by 16 — GSPMD propagates through
                  the reshape)
  MLP             F over `model` both directions (megatron pattern)
  MoE             expert axis over `model` when divisible (deepseek 64/16 →
                  EP), else F within expert (grok 8 experts → TP)
  SSM / RG-LRU    inner width / rnn width over `model`
  KV caches       batch over (pod, data) when divisible, else cache SEQUENCE
                  over `data` (long_500k batch=1 → sequence-parallel decode)
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

from .config import ModelConfig


def _rule_for(path_names, shape, cfg: ModelConfig, mdl="model"):
    """Return a PartitionSpec for a parameter leaf."""
    name = path_names[-1]
    parent = path_names[-2] if len(path_names) > 1 else ""
    rank = len(shape)

    def spec(*tail):
        # left-pad with None for stacked layer/period axes
        pad = rank - len(tail)
        return P(*([None] * pad + list(tail)))

    ep = cfg.n_experts > 0 and cfg.n_experts % 16 == 0

    if name == "embed":
        return P(mdl, None)
    if name == "unembed":
        return P(None, mdl)
    if name in ("wq", "wk", "wv"):
        return spec(None, mdl)
    if name in ("bq", "bk", "bv"):
        return spec(mdl)
    if name == "wo" and parent in ("attn", "xattn"):
        return spec(mdl, None)
    if name in ("wi", "wg") and parent == "moe" or name in ("s_wi", "s_wg"):
        if ep and not name.startswith("s_"):
            return spec(mdl, None, None)      # (E, D, F): expert parallel
        return spec(None, None, mdl)          # TP within expert / shared
    if name == "wo" and parent == "moe" or name == "s_wo":
        if ep and not name.startswith("s_"):
            return spec(mdl, None, None)
        return spec(None, mdl, None)
    if name == "router":
        return spec(None, None)
    if name in ("wi", "wg"):                   # dense mlp
        return spec(None, mdl)
    if name == "wo":                           # dense mlp out
        return spec(mdl, None)
    if name in ("w_x", "w_z", "w_dt", "w_gate"):
        return spec(None, mdl)
    if name in ("w_B", "w_C"):
        return spec(None, None)
    if name in ("w_r", "w_i"):
        return spec(None, mdl)
    if name in ("A_log", "dt_bias", "D_skip"):
        return spec(mdl)
    if name in ("b_r", "b_i", "lam", "gate_norm"):
        return spec(mdl)
    if name == "w_out":
        return spec(mdl, None)
    if name == "conv_w":
        return spec(None, None)
    if name in ("w1",):                        # vlm projector in
        return P(None, mdl)
    if name in ("w2",):
        return P(None, mdl)
    # norms, biases, scalars
    return P(*([None] * rank))


def param_specs(abstract_params, cfg: ModelConfig, mdl="model",
                fsdp_axis: Optional[str] = None, fsdp_size: int = 16,
                min_fsdp_elems: int = 2 ** 22):
    """PartitionSpec tree matching an (abstract) parameter tree.

    fsdp_axis: additionally shard each LARGE (>= min_fsdp_elems) >=2D weight's
    biggest still-unsharded divisible dim over this axis (ZeRO-3/FSDP-style
    storage sharding; GSPMD inserts the per-layer all-gathers). Required to
    fit grok-1-scale params+optimizer in 16 GB/chip; small leaves stay
    replicated over `data` to avoid pointless gather latency.
    """

    def visit(path, leaf):
        names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        names = [str(n) for n in names if n is not None]
        spec = _rule_for(names, leaf.shape, cfg, mdl)
        if fsdp_axis is None or len(leaf.shape) < 2:
            return spec
        import numpy as _np
        if _np.prod(leaf.shape) < min_fsdp_elems:
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        # pick the largest unsharded dim divisible by the fsdp axis size
        cands = [(d, i) for i, d in enumerate(leaf.shape)
                 if entries[i] is None and d % fsdp_size == 0]
        if not cands:
            return spec
        _, idx = max(cands)
        entries[idx] = fsdp_axis
        return P(*entries)

    return jax.tree_util.tree_map_with_path(visit, abstract_params)


def batch_spec(mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes)


def cache_specs(abstract_cache, cfg: ModelConfig, mesh, batch: int):
    """Shard KV caches / recurrent states.

    batch divisible by the data axes => shard batch; otherwise (long_500k,
    batch=1) shard the cache SEQUENCE axis over `data` (sequence-parallel
    decode) and recurrent-state width over `model`.
    """
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nbatch = 1
    for a in daxes:
        nbatch *= mesh.shape[a]
    batch_ok = batch % nbatch == 0

    def visit(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
        name = names[-1] if names else ""
        rank = len(leaf.shape)
        if name in ("k", "v"):
            # (L?, B, S, KV, hd). Batch over data; ALSO shard the model axis:
            # kv-heads when divisible (deepseek 16), else head_dim (128/256/64
            # all divide 16) — without this a 32k cache is 64 GiB/device and
            # does not fit HBM (measured; §Dry-run memory proof).
            pad = rank - 4
            kv_n, hd_n = leaf.shape[-2], leaf.shape[-1]
            kvs, hds = (("model", None) if kv_n % 16 == 0 else
                        (None, "model") if hd_n % 16 == 0 else (None, None))
            if batch_ok:
                return P(*([None] * pad + [daxes, None, kvs, hds]))
            return P(*([None] * pad + [None, "data", kvs, hds]))
        if name == "h":
            # ssm (L,B,H,P,N) / rglru (P?,B,W). The head/width axis follows
            # the params' `model` sharding — otherwise GSPMD re-gathers the
            # state every layer (measured: dominates mamba2 decode traffic,
            # §Perf iteration C1).
            if rank == 5:      # ssm: (L, B, H, P, N)
                hs = "model" if cfg.ssm_heads % 16 == 0 else None
                return P(None, daxes if batch_ok else None, hs, None, None)
            if rank >= 2:      # rglru: (..., B, W)
                ws = "model" if (cfg.rnn_width or cfg.d_model) % 16 == 0 \
                    else None
                return P(*([None] * (rank - 2)
                           + [daxes if batch_ok else None, ws]))
            return P(*([None] * rank))
        if name == "conv":
            # (L?, B, K-1, C): C = din+2N (ssm) or W (rglru)
            cs = "model" if leaf.shape[-1] % 16 == 0 else None
            return P(*([None] * (rank - 3)
                       + [daxes if batch_ok else None, None, cs]))
        if name == "enc":
            return P(daxes if batch_ok else None, None, None)
        return P(*([None] * rank))

    return jax.tree_util.tree_map_with_path(visit, abstract_cache)
