"""InternVL2-style VLM BACKBONE (paper pool entry: internvl2-26b).

Per the assignment the InternViT frontend is a STUB: `input_specs()` provides
precomputed patch embeddings (B, vis_seq, vis_dim). The backbone is real: an
MLP projector into the LM width + the InternLM2 decoder; the image tokens are
prepended to the text sequence, loss is computed on text positions only.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, rmsnorm
from .lm import DecoderLM, _logits, xent_loss

Array = Any


class VLM:
    def __init__(self, cfg: ModelConfig, dtype=jnp.bfloat16, remat=False,
                 unroll=1):
        self.cfg = cfg
        self.dtype = dtype
        self.lm = DecoderLM(cfg, dtype=dtype, remat=remat, unroll=unroll)
        # share the LM's activation-sharding hook (set by train/serve plans)
        self.act_shard = self.lm.act_shard

    def init_params(self, key):
        k1, k2 = jax.random.split(key)
        params = self.lm.init_params(k1)
        ks = jax.random.split(k2, 2)
        params["projector"] = {
            "w1": dense_init(ks[0], (self.cfg.vis_dim, self.cfg.d_model),
                             self.dtype),
            "w2": dense_init(ks[1], (self.cfg.d_model, self.cfg.d_model),
                             self.dtype),
        }
        return params

    def _embed_multimodal(self, params, tokens, patches):
        vis = jax.nn.gelu(patches.astype(self.dtype)
                          @ params["projector"]["w1"])
        vis = vis @ params["projector"]["w2"]               # (B, Tv, D)
        txt = params["embed"][tokens].astype(self.dtype)    # (B, Tt, D)
        return jnp.concatenate([vis, txt], axis=1)

    def loss(self, params, batch):
        """batch: tokens (B, Tt), labels (B, Tt), patches (B, Tv, vis_dim)."""
        h0 = self._embed_multimodal(params, batch["tokens"], batch["patches"])
        x, aux = self.lm.forward(params, None, h0=h0)
        Tv = batch["patches"].shape[1]
        logits = _logits(x[:, Tv:], params, self.cfg)       # text positions
        ce = xent_loss(logits[:, :-1], batch["labels"][:, 1:])
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    def init_cache(self, batch, cache_len, dtype=None):
        return self.lm.init_cache(batch, cache_len, dtype)

    def prefill(self, params, batch, cache_len=None):
        """Image + prompt prefill. tokens (B,Tt), patches (B,Tv,vis_dim)."""
        # Project and embed jointly, then run the LM prefill path on embeds:
        h0 = self._embed_multimodal(params, batch["tokens"], batch["patches"])
        B, T, _ = h0.shape
        cache_len = cache_len or T
        # reuse DecoderLM.prefill via a token-free variant: temporarily treat
        # h0 as the embedded stream
        return _prefill_from_embeds(self.lm, params, h0, cache_len)

    def decode_step(self, params, cache, tokens):
        return self.lm.decode_step(params, cache, tokens)


def _prefill_from_embeds(lm: DecoderLM, params, h0, cache_len):
    """DecoderLM.prefill generalized to a precomputed embedding stream."""
    import jax.numpy as jnp
    from .layers import apply_rope, attention_train, rope_freqs, swiglu
    from .moe import moe_ffn
    cfg = lm.cfg
    B, T, _ = h0.shape
    x = h0

    def body(carry, xs):
        x, aux = carry
        p, is_global = xs
        x = lm.act_shard(x)   # batch-sharding anchor (§Perf A3)
        bias = ({k: p["attn"][k] for k in ("bq", "bk", "bv")}
                if cfg.qkv_bias else None)
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        k = h @ p["attn"]["wk"]
        v = h @ p["attn"]["wv"]
        if bias is not None:
            k = k + bias["bk"]
            v = v + bias["bv"]
        k = k.reshape(B, T, cfg.n_kv_heads, cfg.hd)
        v = v.reshape(B, T, cfg.n_kv_heads, cfg.hd)
        cos, sin = rope_freqs(cfg.hd, cfg.rope_theta, jnp.arange(T))
        k = apply_rope(k, cos, sin)
        x = x + attention_train(h, p["attn"], is_global=is_global, bias=bias,
                                **lm._attn_kwargs())
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.family in ("moe",):
            y, a = moe_ffn(h2, p["moe"], topk=cfg.topk,
                           n_experts=cfg.n_experts, capacity_factor=None,
                           group_size=lm.moe_group)
            aux = aux + a
        else:
            y = swiglu(h2, p["mlp"])
        return (x + y, aux), (k, v)

    (x, aux), (ks, vs) = jax.lax.scan(
        body, (x, jnp.asarray(0.0, jnp.float32)),
        (params["blocks"], lm.layer_global), unroll=lm.unroll)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(x[:, -1:], params, cfg)
    pad = cache_len - T
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits, {"k": ks, "v": vs, "pos": jnp.asarray(T, jnp.int32)}
