"""Mamba-2 / SSD (state-space duality) layer [arXiv:2405.21060].

Training/prefill uses the chunked block decomposition: quadratic attention-like
compute *within* chunks (MXU-friendly einsums) + a linear recurrence *across*
chunk states — the TPU-native analogue of the paper's fused time-stepping: the
recurrent state H advances chunk-to-chunk without leaving the device, exactly
like the ODE kernel's loop-carried state. Decode is the O(1) recurrent step
h' = exp(dt·A) h + dt·x⊗B, y = C·h.

Shapes: x (B, T, D); inner width d_in = expand·D split into H heads of P=64;
state N per head shared B/C (multi-value attention analogy).
Validated against a naive per-step recurrence oracle (tests/test_models_ssm).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm

Array = Any


def ssd_params(key, cfg, dtype):
    D = cfg.d_model
    din = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    K = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    return {
        "w_x": dense_init(ks[0], (D, din), dtype),
        "w_z": dense_init(ks[1], (D, din), dtype),
        "w_B": dense_init(ks[2], (D, N), dtype),
        "w_C": dense_init(ks[3], (D, N), dtype),
        "w_dt": dense_init(ks[4], (D, H), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1
        "D_skip": jnp.ones((H,), jnp.float32),
        "conv_w": dense_init(ks[5], (K, din + 2 * N), dtype, scale=0.5),
        "gate_norm": jnp.zeros((din,), dtype),
        "w_out": dense_init(ks[6], (din, D), dtype),
    }


def _causal_conv(u, w, state=None):
    """Depthwise causal conv. u (B, T, C), w (K, C). state: (B, K-1, C) tail of
    the previous tokens (decode) or None (train: left-pad zeros).
    Returns (y (B,T,C), new_state (B, K-1, C))."""
    K = w.shape[0]
    B, T, C = u.shape
    if state is None:
        state = jnp.zeros((B, K - 1, C), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)          # (B, K-1+T, C)
    y = jnp.zeros_like(u)
    for k in range(K):
        y = y + ext[:, k:k + T, :] * w[k]
    new_state = ext[:, T:, :] if K > 1 else state
    return y, new_state


def ssd_chunked(xh, dt, B_in, C_in, A, chunk: int, h0=None):
    """Chunked SSD scan.

    xh (B,T,H,P), dt (B,T,H) [post-softplus], B_in/C_in (B,T,N), A (H,) (<0).
    h0: initial state (B,H,P,N) or None. Returns (y (B,T,H,P), h_final).
    """
    Bsz, T, H, P = xh.shape
    N = B_in.shape[-1]
    L = min(chunk, T)
    assert T % L == 0, f"seq {T} % chunk {L} != 0"
    nc = T // L
    # state/decay math in >= f32 (f64 when the caller is f64 — oracle tests)
    f32 = jnp.result_type(jnp.float32, xh.dtype)

    xc = xh.reshape(Bsz, nc, L, H, P)
    dtc = dt.reshape(Bsz, nc, L, H).astype(f32)
    Bc = B_in.reshape(Bsz, nc, L, N)
    Cc = C_in.reshape(Bsz, nc, L, N)

    dA = dtc * A  # (B,c,L,H) log-decay per step (negative)
    lcum = jnp.cumsum(dA, axis=2)                       # inclusive
    # ---- intra-chunk (attention-like) ----
    # decay[l,s] = exp(lcum[l] - lcum[s]) for s<=l else 0
    dec = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]   # (B,c,L,S,H)
    mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])
    dec = jnp.where(mask[None, None, :, :, None], jnp.exp(dec), 0.0)
    cb = jnp.einsum("bcln,bcsn->bcls", Cc.astype(f32), Bc.astype(f32))
    scores = cb[..., None] * dec * dtc[:, :, None, :, :]    # (B,c,L,S,H)
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", scores,
                         xc.astype(f32))

    # ---- chunk states ----
    last = lcum[:, :, -1:, :]                                # (B,c,1,H)
    decay_to_end = jnp.exp(last - lcum)                      # (B,c,L,H)
    S_c = jnp.einsum("bclh,bcln,bclhp->bchpn",
                     decay_to_end * dtc, Bc.astype(f32), xc.astype(f32))

    # ---- inter-chunk recurrence (scan over chunks) ----
    chunk_decay = jnp.exp(last[:, :, 0, :])                  # (B,c,H)

    def step(h, inp):
        cd, sc = inp                       # (B,H), (B,H,P,N)
        h_new = h * cd[..., None, None] + sc
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), f32)
    h_fin, h_starts = jax.lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_c, 1, 0)))
    h_starts = jnp.moveaxis(h_starts, 0, 1)                  # (B,c,H,P,N)

    # ---- contribution of carried-in state ----
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp",
                         Cc.astype(f32), jnp.exp(lcum), h_starts)
    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    return y.astype(xh.dtype), h_fin


def ssd_layer_train(x, p, cfg, chunk=256, state=None):
    """Full mamba2 block. x (B,T,D) -> (y (B,T,D), new_state dict or None)."""
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    B, T, D = x.shape
    xi = x @ p["w_x"]
    z = x @ p["w_z"]
    Bv = x @ p["w_B"]
    Cv = x @ p["w_C"]
    conv_in = jnp.concatenate([xi, Bv, Cv], axis=-1)
    conv_state = None if state is None else state["conv"]
    cy, conv_state_new = _causal_conv(conv_in, p["conv_w"], conv_state)
    cy = jax.nn.silu(cy)
    xi, Bv, Cv = cy[..., :din], cy[..., din:din + N], cy[..., din + N:]
    dt = jax.nn.softplus(
        (x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(B, T, H, P)
    h0 = None if state is None else state["h"]
    y, h_fin = ssd_chunked(xh, dt, Bv, Cv, A, chunk, h0=h0)
    y = y + (p["D_skip"].astype(x.dtype))[None, None, :, None] * xh
    y = y.reshape(B, T, din)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    new_state = {"h": h_fin, "conv": conv_state_new}
    return out, new_state


def ssd_layer_decode(x, p, cfg, state):
    """One-token decode. x (B,1,D); state dict(h (B,H,P,N) f32, conv)."""
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    B = x.shape[0]
    xi = x @ p["w_x"]
    z = x @ p["w_z"]
    Bv = x @ p["w_B"]
    Cv = x @ p["w_C"]
    conv_in = jnp.concatenate([xi, Bv, Cv], axis=-1)
    cy, conv_new = _causal_conv(conv_in, p["conv_w"], state["conv"])
    cy = jax.nn.silu(cy)
    xi, Bv, Cv = cy[..., :din], cy[..., din:din + N], cy[..., din + N:]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(B, 1, H, P).astype(jnp.float32)
    dA = jnp.exp(dt[:, 0] * A)                                # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xh[:, 0], Bv[:, 0]
                     .astype(jnp.float32))
    h = state["h"] * dA[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cv[:, 0].astype(jnp.float32), h)
    y = y + p["D_skip"][None, :, None] * xh[:, 0]
    y = y.reshape(B, 1, din).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["w_out"], {"h": h, "conv": conv_new}
