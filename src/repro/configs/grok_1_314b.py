"""--arch config module (see archs.py for the exact numbers)."""
from .archs import GROK_1_314B as CONFIG
from .archs import reduced

SMOKE = reduced(CONFIG)
