"""--arch config module (see archs.py for the exact numbers)."""
from .archs import RECURRENTGEMMA_9B as CONFIG
from .archs import reduced

SMOKE = reduced(CONFIG)
