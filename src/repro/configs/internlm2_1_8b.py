"""--arch config module (see archs.py for the exact numbers)."""
from .archs import INTERNLM2_1_8B as CONFIG
from .archs import reduced

SMOKE = reduced(CONFIG)
