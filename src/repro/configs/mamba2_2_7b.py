"""--arch config module (see archs.py for the exact numbers)."""
from .archs import MAMBA2_2_7B as CONFIG
from .archs import reduced

SMOKE = reduced(CONFIG)
