"""--arch config module (see archs.py for the exact numbers)."""
from .archs import GEMMA3_1B as CONFIG
from .archs import reduced

SMOKE = reduced(CONFIG)
