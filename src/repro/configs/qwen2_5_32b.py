"""--arch config module (see archs.py for the exact numbers)."""
from .archs import QWEN2_5_32B as CONFIG
from .archs import reduced

SMOKE = reduced(CONFIG)
