"""--arch config module (see archs.py for the exact numbers)."""
from .archs import INTERNVL2_26B as CONFIG
from .archs import reduced

SMOKE = reduced(CONFIG)
