"""--arch config module (see archs.py for the exact numbers)."""
from .archs import WHISPER_TINY as CONFIG
from .archs import reduced

SMOKE = reduced(CONFIG)
