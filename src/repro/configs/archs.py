"""The 10 assigned architectures — exact published configs [source; tier in
the assignment]. Each is selectable via --arch <id> in the launchers; a
REDUCED same-family config (for CPU smoke tests) sits beside each full one.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# full configs (exercised via the dry-run only — no allocation)
# ---------------------------------------------------------------------------

GROK_1_314B = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144, n_heads=48,
    n_kv_heads=8, head_dim=128, d_ff=32768, vocab_size=131072,
    n_experts=8, topk=2, moe_d_ff=32768, attn_softcap=30.0,
)  # [hf:xai-org/grok-1; unverified]

DEEPSEEK_MOE_16B = ModelConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab_size=102400,
    n_experts=64, n_shared_experts=2, topk=6, moe_d_ff=1408,
)  # [arXiv:2401.06066; hf]

COMMAND_R_35B = ModelConfig(
    name="command-r-35b", family="dense", n_layers=40, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=22528, vocab_size=256000,
)  # GQA, no bias [hf:CohereForAI/c4ai-command-r-v01; unverified]

QWEN2_5_32B = ModelConfig(
    name="qwen2.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6,
)  # GQA + QKV bias [hf:Qwen; hf]

INTERNLM2_1_8B = ModelConfig(
    name="internlm2-1.8b", family="dense", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=92544,
)  # [arXiv:2403.17297; hf]

GEMMA3_1B = ModelConfig(
    name="gemma3-1b", family="dense", n_layers=26, d_model=1152, n_heads=4,
    n_kv_heads=1, head_dim=256, d_ff=6912, vocab_size=262144,
    window=1024, global_every=6, rope_theta=1e6, tie_embeddings=True,
)  # 5:1 local:global, 128k target [hf:google/gemma-3-1b-pt; unverified]

MAMBA2_2_7B = ModelConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560, n_heads=0,
    n_kv_heads=0, d_ff=0, vocab_size=50280, ssm_state=128, ssm_conv=4,
    ssm_head_dim=64, ssm_expand=2, tie_embeddings=True,
)  # SSD [arXiv:2405.21060; unverified]

RECURRENTGEMMA_9B = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12288, vocab_size=256000,
    window=2048, block_pattern=("R", "R", "A"), rnn_width=4096, ssm_conv=4,
    tie_embeddings=True,
)  # RG-LRU + local attn 1:2 [arXiv:2402.19427; unverified]

INTERNVL2_26B = ModelConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384, vocab_size=92553,
    vis_seq=256, vis_dim=3200,
)  # InternViT (stub) + InternLM2 [arXiv:2404.16821; hf]

WHISPER_TINY = ModelConfig(
    name="whisper-tiny", family="encdec", n_layers=4, d_model=384, n_heads=6,
    n_kv_heads=6, head_dim=64, d_ff=1536, vocab_size=51865, enc_layers=4,
    enc_seq=1500,
)  # enc-dec, conv frontend stubbed [arXiv:2212.04356; unverified]


ARCHS = {c.name: c for c in [
    GROK_1_314B, DEEPSEEK_MOE_16B, COMMAND_R_35B, QWEN2_5_32B,
    INTERNLM2_1_8B, GEMMA3_1B, MAMBA2_2_7B, RECURRENTGEMMA_9B,
    INTERNVL2_26B, WHISPER_TINY,
]}

# archs for which long_500k is skipped (pure full attention; see DESIGN.md §4)
LONG_CONTEXT_SKIP = {
    "grok-1-314b", "deepseek-moe-16b", "command-r-35b", "qwen2.5-32b",
    "internlm2-1.8b", "internvl2-26b", "whisper-tiny",
}


# ---------------------------------------------------------------------------
# reduced same-family configs for CPU smoke tests (few layers, thin dims)
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig) -> ModelConfig:
    r = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 5),
        d_model=128, d_ff=256 if cfg.d_ff else 0, vocab_size=512,
        head_dim=32)
    if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
        r["n_heads"] = 4
        r["n_kv_heads"] = min(cfg.n_kv_heads, 2) or 2
        if cfg.n_kv_heads == 1:
            r["n_kv_heads"] = 1
    if cfg.family == "moe":
        r["n_experts"] = 8
        r["topk"] = min(cfg.topk, 2)
        r["moe_d_ff"] = 64
        r["n_shared_experts"] = cfg.n_shared_experts and 1
    if cfg.family == "ssm":
        r["ssm_state"] = 16
        r["ssm_head_dim"] = 16
        r["n_heads"] = 0
        r["head_dim"] = 0
    if cfg.family == "hybrid":
        r["rnn_width"] = 128
        r["window"] = 32
    if cfg.family == "dense" and cfg.global_every:
        r["window"] = 16
    if cfg.family == "vlm":
        r["vis_seq"] = 8
        r["vis_dim"] = 64
    if cfg.family == "encdec":
        r["enc_layers"] = 2
        r["enc_seq"] = 16
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **r)


def get_arch(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced(ARCHS[name[:-len("-smoke")]])
    return ARCHS[name]
