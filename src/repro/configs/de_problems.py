"""The paper's benchmark differential-equation models (Appendix A).

All RHS functions are written in component style (index u[0], ..., combine with
jnp.stack) so the SAME definition runs per-trajectory, array-ensembled, lane-
vectorized, and inside the Pallas kernel — the "automated translation" property.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.problem import EnsembleProblem, ODEProblem, SDEProblem
from repro.core.solvers import Event


# ---------------------------------------------------------------------------
# A.1.1 Lorenz attractor — the headline ODE benchmark (Figs. 4-7)
# ---------------------------------------------------------------------------

def lorenz_rhs(u, p, t):
    sigma, rho, beta = p[0], p[1], p[2]
    x, y, z = u[0], u[1], u[2]
    return jnp.stack([
        sigma * (y - x),
        rho * x - y - x * z,
        x * y - beta * z,
    ])


def lorenz_problem(dtype=jnp.float32) -> ODEProblem:
    u0 = jnp.asarray([1.0, 0.0, 0.0], dtype)
    p = jnp.asarray([10.0, 21.0, 8.0 / 3.0], dtype)
    return ODEProblem(lorenz_rhs, u0, p, (0.0, 1.0), name="lorenz")


def lorenz_ensemble(n_trajectories: int, dtype=jnp.float32,
                    rho_range=(0.0, 21.0)) -> EnsembleProblem:
    """The paper's sweep: rho uniform over (0, 21), sigma=10, beta=8/3 fixed."""
    prob = lorenz_problem(dtype)
    rho = jnp.linspace(rho_range[0], rho_range[1], n_trajectories, dtype=dtype)
    ps = jnp.stack([jnp.full_like(rho, 10.0), rho,
                    jnp.full_like(rho, 8.0 / 3.0)], axis=1)
    return EnsembleProblem(prob, n_trajectories, ps=ps)


# ---------------------------------------------------------------------------
# A.1.2 Bouncing ball — the event-handling demo (Fig. 8)
# ---------------------------------------------------------------------------

def bouncing_ball_rhs(u, p, t):
    # u = [x, v]; p = [g, e]
    return jnp.stack([u[1], -p[0] * jnp.ones_like(u[1])])


def bouncing_ball_event() -> Event:
    def condition(u, p, t):
        return u[0]

    def affect(u, p, t):
        # flip velocity by the coefficient of restitution e = p[1]
        return jnp.stack([jnp.zeros_like(u[0]), -p[1] * u[1]])

    return Event(condition=condition, affect=affect, terminal=False,
                 direction=-1)


def bouncing_ball_problem(e=0.9, x0=10.0, dtype=jnp.float64) -> ODEProblem:
    u0 = jnp.asarray([x0, 0.0], dtype)
    p = jnp.asarray([9.8, e], dtype)
    return ODEProblem(bouncing_ball_rhs, u0, p, (0.0, 15.0),
                      name="bouncing_ball")


# ---------------------------------------------------------------------------
# Simple analytic test problems (used by convergence/order tests)
# ---------------------------------------------------------------------------

def linear_decay_rhs(u, p, t):
    return -p[0] * u


def linear_decay_problem(lam=1.0, dtype=jnp.float64) -> ODEProblem:
    return ODEProblem(linear_decay_rhs,
                      jnp.asarray([1.0], dtype), jnp.asarray([lam], dtype),
                      (0.0, 2.0), name="linear_decay")


def sho_rhs(u, p, t):
    # harmonic oscillator, omega = p[0]
    return jnp.stack([u[1], -(p[0] ** 2) * u[0]])


def sho_problem(omega=2.0, dtype=jnp.float64) -> ODEProblem:
    return ODEProblem(sho_rhs, jnp.asarray([1.0, 0.0], dtype),
                      jnp.asarray([omega], dtype), (0.0, 3.0), name="sho")


# ---------------------------------------------------------------------------
# Forced oscillator — the data-driven demo problem (paper §6.7): the drive
# term is a UniformTable1D riding `prob.data` into every dispatch path
# ---------------------------------------------------------------------------

def forced_oscillator_rhs(u, p, t, data):
    # u'' + p[1] u' + p[0] u = F(t), F interpolated from the dataset
    from repro.core.interp import interp1d
    return jnp.stack([u[1], -p[0] * u[0] - p[1] * u[1]
                      + interp1d(data["force"], t)])


def forced_oscillator_problem(K=65, t_max=10.0, tspan=(0.0, 5.0),
                              dtype=jnp.float64) -> ODEProblem:
    """Damped oscillator driven by a K-knot force table over [0, t_max]."""
    import numpy as _np
    from repro.core.interp import UniformTable1D
    xs = _np.linspace(0.0, t_max, K)
    F = _np.sin(1.3 * xs) + 0.5 * _np.cos(0.4 * xs)
    tab = UniformTable1D(jnp.asarray(F, dtype), 0.0, float(xs[1] - xs[0]))
    return ODEProblem(forced_oscillator_rhs, jnp.asarray([1.0, 0.0], dtype),
                      jnp.asarray([2.0, 0.1], dtype), tspan,
                      data={"force": tab}, name="forced_oscillator")


# ---------------------------------------------------------------------------
# Van der Pol — the standard stiff benchmark (paper §7's missing frontier,
# served here by the rosenbrock23 registry method + batched-LU W solves)
# ---------------------------------------------------------------------------

def vdp_rhs(u, p, t):
    mu = p[0]
    return jnp.stack([u[1], mu * ((1.0 - u[0] ** 2) * u[1]) - u[0]])


def vdp_problem(mu=10.0, tspan=(0.0, 1.0), dtype=jnp.float64) -> ODEProblem:
    return ODEProblem(vdp_rhs, jnp.asarray([2.0, 0.0], dtype),
                      jnp.asarray([mu], dtype), tspan, name="vdp")


def vdp_ensemble(n_trajectories: int, mu_range=(5.0, 20.0),
                 tspan=(0.0, 1.0), dtype=jnp.float64) -> EnsembleProblem:
    """Stiffness sweep: mu uniform over mu_range (larger mu = stiffer)."""
    prob = vdp_problem(tspan=tspan, dtype=dtype)
    mus = jnp.linspace(mu_range[0], mu_range[1], n_trajectories, dtype=dtype)
    return EnsembleProblem(prob, n_trajectories, ps=mus[:, None])


# ---------------------------------------------------------------------------
# A.2.1 Linear SDE (geometric Brownian motion) — asset-price model (Fig. 9)
# ---------------------------------------------------------------------------

def gbm_drift(u, p, t):
    return p[0] * u


def gbm_diffusion(u, p, t):
    return p[1] * u


def gbm_problem(r=1.5, v=0.01, dtype=jnp.float32) -> SDEProblem:
    u0 = jnp.asarray([0.1, 0.1, 0.1], dtype)
    p = jnp.asarray([r, v], dtype)
    return SDEProblem(gbm_drift, gbm_diffusion, u0, p, (0.0, 1.0),
                      noise="diagonal", name="gbm")


# ---------------------------------------------------------------------------
# A.2.2 Chemical-reaction-network sigma-factor stress-response model (Fig. 10/11)
# 4 states, 8 Wiener processes (general noise), 6 parameters.
# ---------------------------------------------------------------------------

def crn_drift(u, p, t):
    S, D, tau, v0, n, eta = p[0], p[1], p[2], p[3], p[4], p[5]
    sig, A1, A2, A3 = u[0], u[1], u[2], u[3]
    hill = (S * sig) ** n / ((S * sig) ** n + (D * A3) ** n + 1.0)
    return jnp.stack([
        v0 + hill - sig,
        (sig - A1) / tau,
        (A1 - A2) / tau,
        (A2 - A3) / tau,
    ])


def crn_diffusion(u, p, t):
    """(4, 8) noise matrix (or (4, 8, B) lane-batched): CLE birth/death terms."""
    S, D, tau, v0, n, eta = p[0], p[1], p[2], p[3], p[4], p[5]
    sig, A1, A2, A3 = u[0], u[1], u[2], u[3]
    pos = lambda x: jnp.sqrt(jnp.maximum(x, 0.0))
    hill = (S * sig) ** n / ((S * sig) ** n + (D * A3) ** n + 1.0)
    z = jnp.zeros_like(sig)
    rows = [
        [eta * pos(v0 + hill), -eta * pos(sig), z, z, z, z, z, z],
        [z, z, eta * pos(sig / tau), -eta * pos(A1 / tau), z, z, z, z],
        [z, z, z, z, eta * pos(A1 / tau), -eta * pos(A2 / tau), z, z],
        [z, z, z, z, z, z, eta * pos(A2 / tau), -eta * pos(A3 / tau)],
    ]
    return jnp.stack([jnp.stack(r) for r in rows])


def crn_problem(S=10.0, D=10.0, tau=10.0, v0=0.1, n=3.0, eta=0.01,
                tspan=(0.0, 1000.0), dtype=jnp.float32) -> SDEProblem:
    p = jnp.asarray([S, D, tau, v0, n, eta], dtype)
    u0 = jnp.full((4,), v0, dtype)
    return SDEProblem(crn_drift, crn_diffusion, u0, p, tspan,
                      noise="general", n_noise=8, name="crn")


# ---------------------------------------------------------------------------
# ROBER — Robertson's chemical kinetics, THE classic stiff benchmark
# (paper §5.1.3's GPURodas4/GPURodas5P target; rate constants span 9 orders
# of magnitude, so it is meaningless in float32 — run with jax_enable_x64).
# Ships an analytic Jacobian to exercise the ODEProblem.jac hook; drop the
# jac= argument and every solver falls back to jacfwd with identical results.
# ---------------------------------------------------------------------------

def rober_rhs(u, p, t):
    k1, k2, k3 = p[0], p[1], p[2]
    y1, y2, y3 = u[0], u[1], u[2]
    return jnp.stack([
        -k1 * y1 + k3 * y2 * y3,
        k1 * y1 - k2 * y2 * y2 - k3 * y2 * y3,
        k2 * y2 * y2,
    ])


def rober_jac(u, p, t):
    """Analytic ∂f/∂u in component style: (3, 3) scalar / (3, 3, B) lanes."""
    k1, k2, k3 = p[0], p[1], p[2]
    y1, y2, y3 = u[0], u[1], u[2]
    z = jnp.zeros_like(y1)
    return jnp.stack([
        jnp.stack([-k1 + z, k3 * y3, k3 * y2]),
        jnp.stack([k1 + z, -2.0 * k2 * y2 - k3 * y3, -k3 * y2]),
        jnp.stack([z, 2.0 * k2 * y2, z]),
    ])


def rober_problem(k1=0.04, k2=3e7, k3=1e4, tspan=(0.0, 1e5),
                  dtype=jnp.float64, analytic_jac=True) -> ODEProblem:
    u0 = jnp.asarray([1.0, 0.0, 0.0], dtype)
    p = jnp.asarray([k1, k2, k3], dtype)
    return ODEProblem(rober_rhs, u0, p, tspan, name="rober",
                      jac=rober_jac if analytic_jac else None)


def rober_ensemble(n_trajectories: int, k1_range=(0.01, 0.1),
                   tspan=(0.0, 1e5), dtype=jnp.float64,
                   analytic_jac=True) -> EnsembleProblem:
    """Rate-constant sweep: k1 log-uniform over k1_range (k2, k3 fixed)."""
    prob = rober_problem(tspan=tspan, dtype=dtype, analytic_jac=analytic_jac)
    k1s = jnp.exp(jnp.linspace(jnp.log(k1_range[0]), jnp.log(k1_range[1]),
                               n_trajectories)).astype(dtype)
    ps = jnp.stack([k1s, jnp.full_like(k1s, 3e7), jnp.full_like(k1s, 1e4)],
                   axis=1)
    return EnsembleProblem(prob, n_trajectories, ps=ps)


# ---------------------------------------------------------------------------
# OREGO — the Oregonator (Belousov-Zhabotinsky reaction), a stiff limit-cycle
# oscillator (Hairer-Wanner's second standard stiff benchmark).
# ---------------------------------------------------------------------------

def orego_rhs(u, p, t):
    s, q, w = p[0], p[1], p[2]
    y1, y2, y3 = u[0], u[1], u[2]
    return jnp.stack([
        s * (y2 + y1 * (1.0 - q * y1 - y2)),
        (y3 - (1.0 + y1) * y2) / s,
        w * (y1 - y3),
    ])


def orego_problem(s=77.27, q=8.375e-6, w=0.161, tspan=(0.0, 360.0),
                  dtype=jnp.float64) -> ODEProblem:
    u0 = jnp.asarray([1.0, 2.0, 3.0], dtype)
    p = jnp.asarray([s, q, w], dtype)
    return ODEProblem(orego_rhs, u0, p, tspan, name="orego")


DE_PROBLEMS = {
    "lorenz": lorenz_problem,
    "bouncing_ball": bouncing_ball_problem,
    "linear_decay": linear_decay_problem,
    "sho": sho_problem,
    "vdp": vdp_problem,
    "rober": rober_problem,
    "orego": orego_problem,
    "gbm": gbm_problem,
    "crn": crn_problem,
}
