"""--arch config module (see archs.py for the exact numbers)."""
from .archs import DEEPSEEK_MOE_16B as CONFIG
from .archs import reduced

SMOKE = reduced(CONFIG)
