"""--arch config module (see archs.py for the exact numbers)."""
from .archs import COMMAND_R_35B as CONFIG
from .archs import reduced

SMOKE = reduced(CONFIG)
