"""Deterministic fault injection for elastic ensemble runs.

The elastic supervisor (`repro.dist.elastic`) exposes two chaos points per
run — once per (epoch, shard) before that shard's tile work, and once per
snapshot before the checkpoint write.  `ChaosMonkey` drives them from a
deterministic schedule (explicit ``(epoch, shard, kind)`` triples) and/or a
seed-driven random process whose draws are keyed on ``(seed, epoch, shard)``
— NOT on call order — so the same failure sequence replays bitwise across
runs, re-shards and processes.

Failure kinds:

``"kill"``
    Raise `ShardFailure` — models a clean shard loss (host OOM, preemption
    notice, network partition detected by the supervisor).  The supervisor's
    retry ladder catches it, discards the shard's in-memory tile state, and
    re-shards the surviving lanes from the last snapshot.
``"sigkill"``
    SIGKILL the current PROCESS — models an uncatchable hard kill.  Only
    meaningful from a subprocess harness: the parent observes returncode -9
    and relaunches with ``resume=True`` (see tests/test_elastic.py).
``"ckpt_crash"``
    Raise `CheckpointWriteCrash` from the snapshot chaos point — models a
    crash while checkpointing.  The atomic tmp-dir-rename layer guarantees
    the previous complete snapshot survives; the supervisor records the
    failure and carries on with the old snapshot as its restore point.

For crash-at-the-syscall-level coverage, `install_ckpt_write_crash` arms the
checkpoint layer's stage hook so the next `ckpt.save` SIGKILLs itself
mid-write (optionally tearing the half-written arrays file first) — used by
the crash-mid-save atomicity tests in tests/test_checkpoint_fault.py.

`force_lease_expiry` ages every live lease in a `WorkQueue` to simulate a
lease-expiry storm (mass worker death) without sleeping through timeouts.
"""
from __future__ import annotations

import os
import signal
from typing import Iterable, List, Optional, Tuple


class ShardFailure(RuntimeError):
    """A shard died (injected or real); its in-memory tile state is lost."""

    def __init__(self, shard: int, kind: str = "kill", detail: str = ""):
        msg = f"shard {shard} failed ({kind})"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)
        self.shard = int(shard)
        self.kind = kind
        self.detail = detail


class CheckpointWriteCrash(RuntimeError):
    """Injected crash during a snapshot write (previous snapshot survives)."""


def _hash_draw(seed: int, epoch: int, shard: int) -> float:
    """Deterministic uniform in [0, 1) keyed on (seed, epoch, shard).

    Integer mixing (splitmix64-style) rather than `hash(tuple)` so draws are
    stable across processes regardless of PYTHONHASHSEED.
    """
    x = (seed * 0x9E3779B97F4A7C15 + epoch * 0xBF58476D1CE4E5B9
         + shard * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    x ^= x >> 31
    return x / 2.0 ** 64


class ChaosMonkey:
    """Seed-driven failure schedules for the elastic supervisor.

    Args:
      seed: base seed for the random failure process.
      schedule: explicit ``(epoch, shard, kind)`` triples; each entry fires
        at most ONCE (a failure rolls the epoch back for the dead shard's
        tiles, so without one-shot semantics a scheduled kill would re-fire
        forever on the retried epoch).  ``kind == "ckpt_crash"`` entries fire
        from the snapshot chaos point (their shard field is ignored).
      p_kill: per-(epoch, shard) probability of a random ``"kill"``.
      p_ckpt_crash: per-epoch probability of a random ``"ckpt_crash"``.
      max_failures: cap on TOTAL fired events (None = unlimited).
    """

    def __init__(self, seed: int = 0,
                 schedule: Iterable[Tuple[int, int, str]] = (),
                 p_kill: float = 0.0, p_ckpt_crash: float = 0.0,
                 max_failures: Optional[int] = None):
        self.seed = int(seed)
        self._schedule: List[Tuple[int, int, str]] = [
            (int(e), int(s), str(k)) for e, s, k in schedule]
        self.p_kill = float(p_kill)
        self.p_ckpt_crash = float(p_ckpt_crash)
        self.max_failures = max_failures
        self.fired: List[Tuple[int, int, str]] = []
        self._rolled = set()            # (epoch, shard) random draws consumed

    def _exhausted(self) -> bool:
        return (self.max_failures is not None
                and len(self.fired) >= self.max_failures)

    def _fire(self, epoch: int, shard: int, kind: str):
        self.fired.append((epoch, shard, kind))
        if kind == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)
        if kind == "ckpt_crash":
            raise CheckpointWriteCrash(
                f"injected checkpoint-write crash at epoch {epoch}")
        raise ShardFailure(shard, kind, detail=f"injected at epoch {epoch}")

    def on_tile(self, epoch: int, shard: int, tile: int) -> None:
        """Chaos point before shard `shard` works its tiles in `epoch`."""
        if self._exhausted():
            return
        for entry in self._schedule:
            e, s, k = entry
            if e == epoch and s == shard and k != "ckpt_crash":
                self._schedule.remove(entry)
                self._fire(epoch, shard, k)
        key = (epoch, shard)
        if self.p_kill > 0.0 and key not in self._rolled:
            self._rolled.add(key)
            if _hash_draw(self.seed, epoch, shard) < self.p_kill:
                self._fire(epoch, shard, "kill")

    def on_snapshot(self, epoch: int) -> None:
        """Chaos point immediately before a snapshot write."""
        if self._exhausted():
            return
        for entry in self._schedule:
            e, _s, k = entry
            if e == epoch and k == "ckpt_crash":
                self._schedule.remove(entry)
                self._fire(epoch, -1, k)
        key = (epoch, -1)
        if self.p_ckpt_crash > 0.0 and key not in self._rolled:
            self._rolled.add(key)
            if _hash_draw(self.seed ^ 0x5DEECE66D, epoch, -1) \
                    < self.p_ckpt_crash:
                self._fire(epoch, -1, "ckpt_crash")


def install_ckpt_write_crash(stage: str = "pre_rename",
                             tear_arrays: bool = False) -> None:
    """Arm `repro.checkpoint.ckpt` so the NEXT save SIGKILLs itself at
    `stage` ("arrays" — payload written, meta/rename pending; "meta" — tmp
    dir complete, publish rename pending; "pre_rename" — immediately before
    the publish rename, after any same-step predecessor was moved aside).
    With ``tear_arrays`` the
    half-written ``arrays.npz`` is truncated first, simulating a torn write.
    Process-fatal by design — only call from a sacrificial subprocess.
    """
    from repro.checkpoint import ckpt as ckpt_lib

    def hook(name: str, tmp_dir: str) -> None:
        if name != stage:
            return
        if tear_arrays:
            path = os.path.join(tmp_dir, "arrays.npz")
            if os.path.exists(path):
                with open(path, "r+b") as fh:
                    fh.truncate(max(os.path.getsize(path) // 2, 1))
        os.kill(os.getpid(), signal.SIGKILL)

    ckpt_lib._crash_hook = hook


def force_lease_expiry(queue) -> int:
    """Age every live lease in a `WorkQueue` so it is immediately
    reclaimable (a lease-expiry storm: all workers presumed dead at once).
    Backoff state is preserved — reclaim pacing still applies on repeated
    storms.  Returns the number of leases expired."""
    n = 0
    with queue._lock:
        for off, leased in enumerate(queue._leased_at):
            if leased is not None and not queue._done[off]:
                queue._leased_at[off] = -1.0e18
                n += 1
    return n
