"""Elastic fault-tolerant sharded ensemble runs (ROADMAP item 5).

`ElasticSupervisor` wraps an ensemble solve in bounded segments so the run
can survive shard loss:

* the N requested lanes are packed into tiles of a FIXED width B
  (``tile_width``; the last tile is padded with one-iteration filler
  columns, the `repro.serve.slots.SlotPool` convention).  B is part of the
  run identity: XLA codegen is width-sensitive at the ulp level (see
  `repro.core.ensemble._tile_lanes`), so elasticity NEVER changes compiled
  widths — failures redistribute whole tiles across shards, they never
  repartition lanes;
* resumable methods (erk, fixed-dt sde) advance through ONE compiled
  `ResumableEngine` program per epoch (`segment_steps` attempts per lane);
  non-resumable methods (rosenbrock's batch-coupled lazy-W gates, adaptive
  SDE's dt-path-dependent Brownian-tree state) run tiles as one-shot
  `solve_ensemble_local` calls instead — a lost shard re-runs its
  in-flight tile from scratch, which is bitwise harmless because the tile's
  lane content is fixed;
* every ``snapshot_every`` epochs the supervisor host-gathers all tile
  carries (u, t, dt, naccept/nreject, per-lane constants, RNG lane indices
  — the COMPLETE restart state) and writes them through the atomic
  checkpoint layer (`repro.checkpoint.ckpt`).  Snapshots are unsharded, so
  a restore may re-shard onto ANY shard count — including a different
  process after SIGKILL (``run(resume=True)``);
* on a shard failure (injected via `repro.dist.chaos` or a real exception
  from tile work) the dead shard's in-memory tile state is discarded, its
  tiles are restored from the last snapshot (or fresh state before the
  first snapshot), and the unfinished tiles are re-dealt over the
  survivors through a `WorkQueue` ordered by per-tile straggler pressure
  (active lanes + accept/reject attempt deltas since the last snapshot);
* retry follows a degradation ladder: jittered exponential backoff per
  failure, fewer shards → a single revived host when every shard has died,
  and — past ``max_failures`` — a PARTIAL result in which unfinished lanes
  carry ``status == STATUS_SHARD_LOST`` instead of the run aborting.

Bitwise-resume contract: a lane's trajectory is the body-application
sequence of its own column, and applying the body to a done lane is an
exact no-op — so WHICH epochs advanced a lane, which shard held it, and how
often it was rolled back to a snapshot and replayed are all invisible in
the final state.  Because the counter-RNG stream (and the virtual Brownian
tree above it) is a pure function of (seed; step, GLOBAL lane index, row),
this holds across re-sharding too: a killed-and-resumed run is bitwise
identical to an uninterrupted one (tests/test_elastic.py SIGKILLs a run
mid-flight and diffs trajectories).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.core.ensemble import (export_resume_carry, import_resume_carry,
                                 make_resumable_engine, solve_ensemble_local)
from repro.core.methods import get_method
from repro.core.problem import EnsembleProblem
from repro.dist.chaos import CheckpointWriteCrash, ShardFailure, _hash_draw
from repro.dist.fault import WorkQueue

#: Per-lane status for lanes the degradation ladder could not finish
#: (supervisor bailed past max_failures / ran out of epochs while degraded).
#: Extends the solver vocabulary {0: success, 1: iter budget, 2: dt_min}.
STATUS_SHARD_LOST = 3


@dataclass
class ElasticResult:
    """Per-lane final states + stats of an elastic run (host numpy).

    `report` documents the run's fault history: epochs, failures (with
    epoch/shard/kind), re-shard events, snapshot count, degradation-ladder
    steps, and whether the run bailed to a partial result.  One-shot mode
    also returns dense saves (`us`, `ts`) when every tile completed in this
    process (tiles restored from a process-level resume carry final states
    only).
    """
    u_final: np.ndarray          # (N, n)
    t_final: np.ndarray          # (N,)
    naccept: np.ndarray          # (N,)
    nreject: np.ndarray          # (N,)
    status: np.ndarray           # (N,) int32
    event_t: np.ndarray          # (N,)
    event_count: np.ndarray      # (N,)
    nf: int
    njac: int
    nfact: int
    report: Dict[str, Any] = field(default_factory=dict)
    us: Optional[np.ndarray] = None     # (N, S, n) one-shot mode only
    ts: Optional[np.ndarray] = None     # (S,)


def _finalize_status(status, done, bailed: bool):
    undone_code = STATUS_SHARD_LOST if bailed else 1
    return np.where(status > 0, status,
                    np.where(done, 0, undone_code)).astype(np.int32)


class ElasticSupervisor:
    """Segmented, snapshotting, re-sharding ensemble run driver.

    Args:
      eprob: `EnsembleProblem` (lane content is materialized once, up
        front — tile membership never changes, which is what makes re-runs
        and re-shards bitwise-invisible).
      alg: registry method name / MethodSpec / Tableau.
      ckpt_dir: snapshot directory (atomic step-addressed layout).  A fresh
        run (``resume=False``) clears prior steps in it; ``resume=True``
        restores the newest complete snapshot — with THIS supervisor's
        ``n_shards``, which may differ from the writer's.
      n_shards: worker count to deal tiles over.  This is a scheduling
        property only; results are independent of it.
      tile_width: compiled lane width B (fixed for the run's lifetime).
      segment_steps: solver attempts per lane per epoch (segment mode).
      snapshot_every: epochs between snapshots.
      max_failures: failures tolerated before bailing to a partial result.
      backoff_base/backoff_factor/backoff_max/backoff_jitter: retry-delay
        ladder (seconds; deterministic jitter).  ``backoff_base=0`` never
        sleeps (tests).
      chaos: optional `repro.dist.chaos.ChaosMonkey`.
      solver knobs (t0, tf, dt0, n_steps, adaptive, rtol, atol, event,
        seed, lane_offset, max_iters, **solve_kwargs) mirror
        `solve_ensemble_local`; extra kwargs are passed through to one-shot
        tile solves (error_est, w_reuse, linsolve, saveat, ...).
    """

    def __init__(self, eprob: EnsembleProblem, alg="tsit5", *, ckpt_dir: str,
                 n_shards: int = 2, tile_width: int = 8,
                 segment_steps: int = 64, snapshot_every: int = 1,
                 keep_snapshots: int = 2, max_epochs: int = 100_000,
                 max_failures: int = 8, backoff_base: float = 0.01,
                 backoff_factor: float = 2.0, backoff_max: float = 2.0,
                 backoff_jitter: float = 0.25, chaos=None, rebalance=True,
                 t0=None, tf=None, dt0: float = 1e-2,
                 n_steps: Optional[int] = None, adaptive=None,
                 rtol: float = 1e-6, atol: float = 1e-6, event=None,
                 seed: int = 0, lane_offset: int = 0,
                 max_iters: int = 100_000, **solve_kwargs):
        self.spec = get_method(alg)
        self.prob = eprob.prob
        u0s, ps = eprob.materialize()
        self._u0s = np.asarray(u0s)
        self._ps = np.asarray(ps)
        self.N = int(self._u0s.shape[0])
        self.n = int(self._u0s.shape[1])
        self.dtype = self._u0s.dtype
        self.ckpt_dir = ckpt_dir
        self.n_shards = int(n_shards)
        self.B = int(tile_width)
        self.T = -(-self.N // self.B)                 # ceil
        self.segment_steps = int(segment_steps)
        self.snapshot_every = max(int(snapshot_every), 1)
        self.keep_snapshots = int(keep_snapshots)
        self.max_epochs = int(max_epochs)
        self.max_failures = int(max_failures)
        self.backoff_base = float(backoff_base)
        self.backoff_factor = float(backoff_factor)
        self.backoff_max = float(backoff_max)
        self.backoff_jitter = float(backoff_jitter)
        self.chaos = chaos
        self.rebalance = bool(rebalance)

        tspan = getattr(self.prob, "tspan", (0.0, 1.0))
        self.t0 = float(tspan[0] if t0 is None else t0)
        self.tf = float(tspan[1] if tf is None else tf)
        self.dt0 = float(dt0)
        self.rtol, self.atol = float(rtol), float(atol)
        self.event = event
        self.seed = int(seed)
        self.lane_offset = int(lane_offset)
        self.max_iters = int(max_iters)
        self.solve_kwargs = dict(solve_kwargs)

        if self.spec.family == "sde":
            self.adaptive = bool(adaptive) if adaptive is not None else False
            if not self.adaptive and n_steps is None:
                n_steps = int(round((self.tf - self.t0) / self.dt0))
        else:
            self.adaptive = (self.spec.adaptive if adaptive is None
                             else bool(adaptive))
        self.n_steps = None if n_steps is None else int(n_steps)

        self.mode = ("segment" if self.spec.resumable
                     and not (self.spec.family == "sde" and self.adaptive)
                     else "oneshot")
        if self.mode == "segment":
            self.engine = make_resumable_engine(
                self.spec, self.prob, adaptive=self.adaptive, rtol=self.rtol,
                atol=self.atol, event=self.event, seed=self.seed,
                segment_steps=self.segment_steps)
            # edge-padded lane content: padded columns are fillers that
            # retire in one iteration (tf == t0 / n_steps == 0) and are
            # dropped at assembly
            padn = self.T * self.B - self.N
            self._u0p = np.concatenate(
                [self._u0s, np.repeat(self._u0s[-1:], padn, axis=0)])
            self._psp = np.concatenate(
                [self._ps, np.repeat(self._ps[-1:], padn, axis=0)])
            self._nofill = np.zeros(self.B, bool)
        self._real = [
            np.arange(self.B) < min(self.B, self.N - t * self.B)
            for t in range(self.T)]

    # -- tile state -----------------------------------------------------------

    def _fresh_tile(self, t: int):
        """Fresh device carry for tile `t` (segment mode)."""
        cols = slice(t * self.B, (t + 1) * self.B)
        u0 = np.ascontiguousarray(self._u0p[cols].T)        # (n, B)
        p = np.ascontiguousarray(self._psp[cols].T)         # (k, B)
        real = self._real[t]
        t0v = np.full(self.B, self.t0, self.dtype)
        if self.spec.family == "sde":
            dtv = np.full(self.B, self.dt0, self.dtype)
            nsv = np.where(real, self.n_steps, 0).astype(np.int32)
            lanev = (self.lane_offset + t * self.B
                     + np.minimum(np.arange(self.B), real.sum() - 1)
                     ).astype(np.uint32)
            return self.engine.fresh(u0, p, t0v, dtv, nsv, lanev)
        tfv = np.where(real, self.tf, self.t0).astype(self.dtype)
        dtv = np.full(self.B, self.dt0, self.dtype)
        return self.engine.fresh(u0, p, t0v, tfv, dtv)

    def _tile_stats(self, t: int) -> None:
        """Refresh the host-side done/attempt caches for tile `t`."""
        c = self._carries[t]
        keys = ["done", "naccept"] + (["nreject"] if "nreject" in c else [])
        h = jax.device_get({k: c[k] for k in keys})
        att = np.asarray(h["naccept"], np.int64)
        if "nreject" in h:
            att = att + np.asarray(h["nreject"], np.int64)
        self._done_host[t] = np.asarray(h["done"])
        self._att_host[t] = att

    def _tile_finished(self, t: int) -> bool:
        if self.mode == "oneshot":
            return bool(self._tile_done[t])
        return bool(self._done_host[t][self._real[t]].all())

    def _enforce_budget(self) -> None:
        """Force-retire lanes past max_iters (status 1), segment mode.

        Runs at epoch boundaries only, where every lane's attempt count is a
        deterministic multiple of segment_steps — so the forced-done
        decision replays identically after any rollback/re-shard."""
        if self.spec.family == "sde":
            return                       # bounded by n_steps per lane
        import jax.numpy as jnp
        for t in range(self.T):
            over = (~self._done_host[t]) & (self._att_host[t]
                                            >= self.max_iters)
            if not over.any():
                continue
            c = dict(self._carries[t])
            overd = jnp.asarray(over)
            c["status"] = jnp.where(overd & (c["status"] == 0),
                                    jnp.asarray(1, c["status"].dtype),
                                    c["status"])
            c["done"] = c["done"] | overd
            self._carries[t] = c
            self._done_host[t] = self._done_host[t] | over

    # -- snapshots ------------------------------------------------------------

    def _like_tree(self) -> Dict[str, np.ndarray]:
        if self.mode == "oneshot":
            return self._oneshot_like_tree()
        probe = export_resume_carry(self._fresh_tile(0))
        return {k: np.zeros((self.T,) + v.shape, v.dtype)
                for k, v in probe.items()}

    def _snapshot(self, epoch: int) -> None:
        if self.chaos is not None:
            self.chaos.on_snapshot(epoch)
        if self.mode == "oneshot":
            tree = self._oneshot_tree()
        else:
            host = {t: export_resume_carry(self._carries[t])
                    for t in range(self.T)}
            tree = {k: np.stack([host[t][k] for t in range(self.T)])
                    for k in host[0]}
            self._snap_host = host
        extra = dict(mode=self.mode, epoch=int(epoch), n_lanes=self.N,
                     tile_width=self.B, n_tiles=self.T,
                     alg=self.spec.name, failures=self._failures)
        ckpt_lib.save(self.ckpt_dir, int(epoch), tree, extra=extra)
        ckpt_lib.prune(self.ckpt_dir, keep=self.keep_snapshots)
        self.report["snapshots"] += 1
        # straggler pressure resets at the snapshot boundary
        if self.mode == "segment":
            self._att_prev = {t: self._att_host[t].copy()
                              for t in range(self.T)}

    def _restore_shard_tiles(self, shard: int) -> int:
        """Discard the dead shard's in-memory tile state; roll its tiles
        back to the last snapshot (fresh state before the first one)."""
        if self.mode == "oneshot":
            return 0                     # completed tiles live on the driver
        n = 0
        for t in range(self.T):
            if self._owner[t] != shard:
                continue
            if self._snap_host is not None:
                self._carries[t] = import_resume_carry(self._snap_host[t])
            else:
                self._carries[t] = self._fresh_tile(t)
            self._tile_stats(t)
            n += 1
        self.report["restored_tiles"] += n
        return n

    # -- scheduling -----------------------------------------------------------

    def _rebalance(self, reason: str) -> None:
        """Re-deal unfinished tiles over the alive shards.

        Tiles are pushed into a `WorkQueue` ordered by straggler pressure —
        active lane count plus the tile's accept/reject attempt delta since
        the last snapshot (normalized by segment_steps) — and dealt
        greedily to the least-loaded shard, so hot tiles spread first."""
        unfinished = [t for t in range(self.T) if not self._tile_finished(t)]
        if not unfinished or not self._alive:
            return
        cost: Dict[int, float] = {}
        for t in unfinished:
            if self.mode == "oneshot":
                cost[t] = 1.0
                continue
            active = float((~self._done_host[t] & self._real[t]).sum())
            delta = float((self._att_host[t]
                           - self._att_prev.get(t, 0)).sum())
            cost[t] = 1.0 + active + delta / float(self.segment_steps)
        q = WorkQueue(timeout=3600.0)
        for t in sorted(unfinished, key=lambda t: (-cost[t], t)):
            q.push(t)
        load = {s: 0.0 for s in sorted(self._alive)}
        while (got := q.claim()) is not None:
            idx, tile, tok = got
            s = min(sorted(load), key=lambda k: (load[k], k))
            self._owner[tile] = s
            load[s] += cost[tile]
            q.complete(idx, tok)
        self.report["reshards"] += 1
        self.report["reshard_events"].append(dict(
            reason=reason, shards=sorted(self._alive),
            tiles=len(unfinished)))

    def _handle_failure(self, err: ShardFailure) -> None:
        self._failures += 1
        self.report["failures"].append(dict(
            epoch=self._epoch + 1, shard=err.shard, kind=err.kind))
        if self._failures > self.max_failures:
            self._bailed = True
            self._restore_shard_tiles(err.shard)
            return
        delay = min(self.backoff_max,
                    self.backoff_base
                    * self.backoff_factor ** (self._failures - 1))
        delay *= 1.0 + self.backoff_jitter * _hash_draw(
            self.seed, self._failures, err.shard)
        if delay > 0.0:
            time.sleep(delay)
        self._alive.discard(err.shard)
        if not self._alive:
            # bottom of the ladder: relaunch a single fresh worker
            self._alive = {0}
            self.report["degraded_single_host"] = True
        self.report["ladder"].append(len(self._alive))
        self._restore_shard_tiles(err.shard)
        self._rebalance("failure")

    # -- run loop -------------------------------------------------------------

    def _init_state(self, resume: bool) -> None:
        self._alive = set(range(self.n_shards))
        self._owner = {t: t % self.n_shards for t in range(self.T)}
        self._failures = 0
        self._bailed = False
        self._epoch = 0
        self._snap_host = None
        self.report: Dict[str, Any] = dict(
            mode=self.mode, alg=self.spec.name, n_lanes=self.N,
            tile_width=self.B, n_tiles=self.T, n_shards=self.n_shards,
            epochs=0, snapshots=0, reshards=0, restored_tiles=0,
            failures=[], reshard_events=[], ladder=[],
            degraded_single_host=False, bailed=False,
            resumed_from_epoch=None)
        if self.mode == "oneshot":
            self._tile_done = np.zeros(self.T, bool)
            self._results: Dict[int, Dict[str, Any]] = {}
        else:
            self._done_host: Dict[int, np.ndarray] = {}
            self._att_host: Dict[int, np.ndarray] = {}
            self._att_prev: Dict[int, np.ndarray] = {}
        restored = False
        if resume:
            restored = self._restore_from_disk()
        if not restored:
            ckpt_lib.prune(self.ckpt_dir, keep=0)   # fresh run owns the dir
            if self.mode == "segment":
                self._carries = {t: self._fresh_tile(t)
                                 for t in range(self.T)}
                for t in range(self.T):
                    self._tile_stats(t)
        self._rebalance("initial")
        self.report["reshards"] = 0        # initial deal isn't a re-shard
        self.report["reshard_events"].clear()

    def _restore_from_disk(self) -> bool:
        latest = ckpt_lib.restore_latest(self.ckpt_dir, self._like_tree())
        if latest is None:
            return False
        step, tree, extra = latest
        for key, want in (("mode", self.mode), ("n_lanes", self.N),
                          ("tile_width", self.B), ("alg", self.spec.name)):
            if extra.get(key) != want:
                raise ValueError(
                    f"snapshot {key}={extra.get(key)!r} does not match this "
                    f"supervisor ({want!r}) — tile width, lane set and "
                    "method are part of the run identity")
        host_tree = {k: np.asarray(v) for k, v in tree.items()}
        if self.mode == "oneshot":
            self._restore_oneshot(host_tree)
        else:
            self._snap_host = {
                t: {k: host_tree[k][t] for k in host_tree}
                for t in range(self.T)}
            self._carries = {t: import_resume_carry(self._snap_host[t])
                             for t in range(self.T)}
            for t in range(self.T):
                self._tile_stats(t)
        self._epoch = int(step)
        self.report["resumed_from_epoch"] = int(step)
        return True

    def run(self, resume: bool = False) -> ElasticResult:
        """Drive the run to completion (or a partial result) and assemble.

        Re-runnable: each call starts from fresh state (``resume=False``)
        or the newest on-disk snapshot (``resume=True``) while reusing the
        compiled engine, so an uninterrupted reference run and a
        chaos-interrupted run can share one supervisor instance."""
        self._init_state(resume)
        wall0 = time.perf_counter()
        while self.report["epochs"] < self.max_epochs and not self._bailed:
            if all(self._tile_finished(t) for t in range(self.T)):
                break
            epoch = self._epoch + 1
            try:
                for s in sorted(self._alive):
                    self._work_shard(epoch, s)
                self._epoch = epoch
                self.report["epochs"] += 1
                if self.mode == "segment":
                    self._enforce_budget()
                if epoch % self.snapshot_every == 0:
                    self._snapshot(epoch)
                    if self.rebalance:
                        self._rebalance("snapshot")
            except ShardFailure as exc:
                self._handle_failure(exc)
            except CheckpointWriteCrash:
                # snapshot write died; the previous snapshot is still the
                # restore point (atomic layer) — count it and keep solving
                self._epoch = epoch  # tile work of this epoch DID commit
                self._failures += 1
                self.report["failures"].append(dict(
                    epoch=epoch, shard=-1, kind="ckpt_crash"))
                if self._failures > self.max_failures:
                    self._bailed = True
        if self._bailed:
            self.report["bailed"] = True
        self.report["wall_s"] = time.perf_counter() - wall0
        self.report["alive_shards"] = sorted(self._alive)
        return self._assemble()

    def _work_shard(self, epoch: int, shard: int) -> None:
        mine = [t for t in sorted(self._owner)
                if self._owner[t] == shard and not self._tile_finished(t)]
        if self.mode == "oneshot":
            mine = mine[:1]              # one tile per shard per epoch
        for t in mine:
            if self.chaos is not None:
                self.chaos.on_tile(epoch, shard, t)
            try:
                if self.mode == "oneshot":
                    self._results[t] = self._solve_tile(t)
                    self._tile_done[t] = True
                else:
                    self._carries[t] = self.engine.step_segment(
                        self._carries[t], self._nofill, self._carries[t])
                    self._tile_stats(t)
            except (ShardFailure, CheckpointWriteCrash):
                raise
            except Exception as exc:     # real failure rides the same ladder
                raise ShardFailure(shard, "error", repr(exc)) from exc

    # -- one-shot mode --------------------------------------------------------

    def _solve_tile(self, t: int) -> Dict[str, Any]:
        lo = t * self.B
        hi = min(lo + self.B, self.N)
        nb = hi - lo
        ep = EnsembleProblem(self.prob, nb, u0s=self._u0s[lo:hi],
                             ps=self._ps[lo:hi])
        kw = dict(t0=self.t0, tf=self.tf, dt0=self.dt0, rtol=self.rtol,
                  atol=self.atol, adaptive=self.adaptive,
                  max_iters=self.max_iters, event=self.event,
                  lane_tile=self.B, lane_offset=self.lane_offset + lo)
        if self.spec.family == "sde":
            kw.update(seed=self.seed, n_steps=self.n_steps)
        kw.update(self.solve_kwargs)
        res = solve_ensemble_local(ep, alg=self.spec, ensemble="kernel",
                                   backend="xla", **kw)
        return dict(
            u_final=np.asarray(res.u_final),
            t_final=np.broadcast_to(np.asarray(res.t_final), (nb,)).copy(),
            naccept=np.broadcast_to(np.asarray(res.naccept), (nb,)).copy(),
            nreject=np.broadcast_to(np.asarray(res.nreject), (nb,)).copy(),
            status=np.broadcast_to(np.asarray(res.status), (nb,)).copy(),
            nf=int(np.asarray(res.nf)), njac=int(np.asarray(res.njac)),
            nfact=int(np.asarray(res.nfact)),
            us=np.asarray(res.us), ts=np.asarray(res.ts))

    def _oneshot_like_tree(self) -> Dict[str, np.ndarray]:
        T, B, n = self.T, self.B, self.n
        return dict(
            u_final=np.zeros((T, B, n), self.dtype),
            t_final=np.zeros((T, B), self.dtype),
            naccept=np.zeros((T, B), np.int64),
            nreject=np.zeros((T, B), np.int64),
            status=np.zeros((T, B), np.int32),
            nf=np.zeros(T, np.int64), njac=np.zeros(T, np.int64),
            nfact=np.zeros(T, np.int64), tile_done=np.zeros(T, bool))

    def _oneshot_tree(self) -> Dict[str, np.ndarray]:
        tree = self._oneshot_like_tree()
        for t, r in self._results.items():
            nb = int(self._real[t].sum())
            tree["u_final"][t, :nb] = r["u_final"]
            tree["t_final"][t, :nb] = r["t_final"]
            tree["naccept"][t, :nb] = r["naccept"]
            tree["nreject"][t, :nb] = r["nreject"]
            tree["status"][t, :nb] = r["status"]
            tree["nf"][t] = r["nf"]
            tree["njac"][t] = r["njac"]
            tree["nfact"][t] = r["nfact"]
            tree["tile_done"][t] = True
        return tree

    def _restore_oneshot(self, tree: Dict[str, np.ndarray]) -> None:
        self._tile_done = np.asarray(tree["tile_done"]).copy()
        for t in range(self.T):
            if not self._tile_done[t]:
                continue
            nb = int(self._real[t].sum())
            self._results[t] = dict(
                u_final=tree["u_final"][t, :nb],
                t_final=tree["t_final"][t, :nb],
                naccept=tree["naccept"][t, :nb],
                nreject=tree["nreject"][t, :nb],
                status=tree["status"][t, :nb],
                nf=int(tree["nf"][t]), njac=int(tree["njac"][t]),
                nfact=int(tree["nfact"][t]), us=None, ts=None)

    # -- assembly -------------------------------------------------------------

    def _assemble(self) -> ElasticResult:
        if self.mode == "oneshot":
            return self._assemble_oneshot()
        fields = {k: [] for k in ("u", "t", "naccept", "nreject", "nf",
                                  "status", "done", "event_t", "event_count")}
        for t in range(self.T):
            h = export_resume_carry(self._carries[t])
            real = self._real[t]
            fields["u"].append(h["u"][:, real].T)
            fields["t"].append((h["t_out"] if "t_out" in h
                                else h["t"])[real])
            fields["naccept"].append(h["naccept"][real])
            fields["nreject"].append(h["nreject"][real] if "nreject" in h
                                     else np.zeros(real.sum(), np.int32))
            fields["nf"].append(h["nf"][real])
            fields["status"].append(h["status"][real])
            fields["done"].append(h["done"][real])
            fields["event_t"].append(h["event_t"][real])
            fields["event_count"].append(h["event_count"][real])
        cat = {k: np.concatenate(v) for k, v in fields.items()}
        status = _finalize_status(cat["status"], cat["done"], self._bailed)
        return ElasticResult(
            u_final=cat["u"], t_final=cat["t"], naccept=cat["naccept"],
            nreject=cat["nreject"], status=status, event_t=cat["event_t"],
            event_count=cat["event_count"], nf=int(cat["nf"].sum()),
            njac=0, nfact=0, report=dict(self.report))

    def _assemble_oneshot(self) -> ElasticResult:
        N, n = self.N, self.n
        u_final = np.array(self._u0s, copy=True)       # unstarted lanes
        t_final = np.full(N, self.t0, self.dtype)
        naccept = np.zeros(N, np.int64)
        nreject = np.zeros(N, np.int64)
        status = np.zeros(N, np.int32)
        done = np.zeros(N, bool)
        nf = njac = nfact = 0
        us_parts: List[Optional[np.ndarray]] = []
        ts = None
        for t in range(self.T):
            lo = t * self.B
            nb = int(self._real[t].sum())
            r = self._results.get(t)
            if r is None:
                us_parts.append(None)
                continue
            sl = slice(lo, lo + nb)
            u_final[sl] = r["u_final"]
            t_final[sl] = r["t_final"]
            naccept[sl] = r["naccept"]
            nreject[sl] = r["nreject"]
            status[sl] = r["status"]
            done[sl] = True
            nf += r["nf"]
            njac += r["njac"]
            nfact += r["nfact"]
            us_parts.append(r.get("us"))
            if r.get("ts") is not None:
                ts = r["ts"]
        status = _finalize_status(status, done, self._bailed)
        have_us = (all(p is not None for p in us_parts)
                   and len(us_parts) == self.T and self.T > 0)
        us = np.concatenate(us_parts, axis=0) if have_us else None
        return ElasticResult(
            u_final=u_final, t_final=t_final, naccept=naccept,
            nreject=nreject, status=status,
            event_t=np.full(N, np.inf, self.dtype),
            event_count=np.zeros(N, np.int64), nf=nf, njac=njac,
            nfact=nfact, report=dict(self.report), us=us,
            ts=None if us is None else ts)
