# Distributed-training support: gradient compression/bucketing collectives,
# fault-tolerance (checkpoint supervision, straggler work queues), the
# elastic ensemble-run supervisor and its chaos fault-injection harness.
from . import chaos, collectives, elastic, fault

__all__ = ["chaos", "collectives", "elastic", "fault"]
