# Distributed-training support: gradient compression/bucketing collectives
# and fault-tolerance (checkpoint supervision, straggler work queues).
from . import collectives, fault

__all__ = ["collectives", "fault"]
