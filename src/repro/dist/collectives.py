"""Gradient-compression collectives: int8 quantization with error feedback
and fixed-size gradient bucketing.

These are the communication-volume levers for the distributed training loop:
int8 all-reduce payloads are 4x smaller than f32, error feedback (EF) carries
the quantization residual forward so the *sum* of updates stays unbiased, and
bucketing packs a parameter pytree into equal-size flat segments so collective
launches amortize over many small leaves.
"""
from __future__ import annotations

from typing import Any, Callable, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = Any


class EFState(NamedTuple):
    """Error-feedback residual, one leaf per parameter leaf."""
    residual: Any


def ef_init(params: Any) -> EFState:
    """Zero residuals shaped like `params`."""
    return EFState(residual=jax.tree.map(jnp.zeros_like, params))


def _quant_int8(x: Array) -> Tuple[Array, Array]:
    """Symmetric round-to-nearest int8 quantization.

    Returns (q int8, scale) with x ≈ q * scale and max error ≤ scale/2
    (the round-to-nearest bound the tests assert).
    """
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, jnp.asarray(1.0, x.dtype))
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(x.dtype)


def ef_compress(grads: Any, state: EFState) -> Tuple[Any, EFState]:
    """Quantize (grads + residual) leafwise; return dequantized updates and the
    new residual state. sum(updates) over steps converges to sum(grads)."""
    def one(g, r):
        x = g + r
        q, s = _quant_int8(x)
        deq = q.astype(x.dtype) * s
        return deq, x - deq

    flat = jax.tree.map(one, grads, state.residual)
    deq = jax.tree.map(lambda pr: pr[0], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda pr: pr[1], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, EFState(residual=res)


def bucketize(tree: Any, bucket_bytes: int
              ) -> Tuple[List[Array], Callable[[List[Array]], Any]]:
    """Pack a pytree into ~`bucket_bytes` flat 1-D buckets.

    Returns (buckets, unpack) where `unpack(buckets)` restores the original
    tree structure/shapes/dtypes. Buckets split on element boundaries of the
    flattened concatenation (a leaf may span buckets), so every bucket except
    the last has exactly `bucket_bytes // itemsize` elements — the fixed-size
    payload a fused all-reduce wants.
    """
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    sizes = [int(l.size) for l in leaves]
    ctype = jnp.result_type(*dtypes)
    flat = jnp.concatenate([jnp.ravel(l).astype(ctype) for l in leaves])
    per = max(1, bucket_bytes // flat.dtype.itemsize)
    buckets = [flat[i:i + per] for i in range(0, flat.shape[0], per)]

    def unpack(bs: List[Array]) -> Any:
        whole = jnp.concatenate(list(bs))
        out, off = [], 0
        for shape, dtype, size in zip(shapes, dtypes, sizes):
            out.append(whole[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    return buckets, unpack
