"""Fault tolerance: checkpoint supervision and straggler work reassignment.

`TrainSupervisor` wraps the atomic step-addressed checkpointer
(`repro.checkpoint.ckpt`) with the restart contract: crash-and-rerun resumes
from the newest complete checkpoint, and periodic saves are one call in the
training loop.  `WorkQueue` is the ensemble-tile analogue of a straggler-
tolerant scheduler: tiles of the trajectory axis are leased to workers and
become reassignable when a lease times out (a dead worker never wedges the
sweep — the same tile-local-termination property the fused kernel has on
device, at the job level).  It is also the request scheduler behind
`repro.serve`: requests are `push()`-ed as work items, pool pumps `claim()`
them under lease, and a pump that dies mid-request simply lets the lease
expire so the next pump retries the request.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


def _mix_unit(seed: int, idx: int, n: int) -> float:
    """Deterministic uniform in [0, 1) from (seed, item index, reclaim
    count) — splitmix64-style integer mixing, stable across processes."""
    x = (seed * 0x9E3779B97F4A7C15 + idx * 0xBF58476D1CE4E5B9
         + n * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    x ^= x >> 31
    return x / 2.0 ** 64


class TrainSupervisor:
    """Periodic-checkpoint + resume-from-latest supervision for a train loop.

    There is deliberately no checkpoint writer here: `_save` delegates to
    `repro.checkpoint.ckpt.save` — the repo's single atomic
    tmp-dir-fsync-rename path — so a crash mid-save can never corrupt this
    supervisor's latest checkpoint either (crash-mid-save coverage for both
    sync and async write modes lives in tests/test_checkpoint_fault.py).
    """

    def __init__(self, ckpt_dir: str, save_every: int = 1000,
                 async_save: bool = False):
        self.ckpt_dir = ckpt_dir
        self.save_every = int(save_every)
        self.async_save = async_save
        self._pending = None
        self._last_saved: Optional[int] = None

    def resume_or_init(self, init_fn: Callable[[], Any], like_tree: Any
                       ) -> Tuple[int, Any, Dict]:
        """Restore the newest checkpoint into `like_tree`'s structure, or call
        `init_fn` for a fresh start. Returns (step, state, extra)."""
        from repro.checkpoint import ckpt as ckpt_lib
        latest = ckpt_lib.restore_latest(self.ckpt_dir, like_tree)
        if latest is None:
            return 0, init_fn(), {}
        step, state, extra = latest
        return step, state, extra

    def maybe_save(self, step: int, state: Any,
                   extra: Optional[Dict] = None) -> bool:
        """Checkpoint when `step` lands on the save_every grid.

        Step 0 is skipped: `0 % save_every == 0` used to write a pointless
        checkpoint of the exact init state every run (and, worse, a restart
        would then "resume" from step 0 instead of calling init_fn fresh).
        The final, possibly off-grid state is the loop's responsibility —
        call `finalize(step, state)` at loop exit.
        """
        if step == 0 or step % self.save_every != 0:
            return False
        return self._save(step, state, extra)

    def finalize(self, step: int, state: Any,
                 extra: Optional[Dict] = None) -> bool:
        """Checkpoint the loop-exit state (even off the save_every grid) and
        join any in-flight async write.  No-op when `step` was already saved
        by `maybe_save` (exit step on the grid)."""
        if step == self._last_saved or step == 0:
            self.flush()
            return False
        saved = self._save(step, state, extra)
        self.flush()
        return saved

    def _save(self, step: int, state: Any, extra: Optional[Dict]) -> bool:
        from repro.checkpoint import ckpt as ckpt_lib
        self.flush()
        self._pending = ckpt_lib.save(self.ckpt_dir, step, state, extra=extra,
                                      async_write=self.async_save)
        self._last_saved = step
        return True

    def flush(self):
        """Join any in-flight async write (call before exit/restore)."""
        if self._pending is not None:
            self._pending.join()
            self._pending = None


class WorkQueue:
    """Lease-based tile queue with straggler reassignment.

    `n_items` units are split into `tile`-sized work units. `claim()` leases
    the first tile that is unfinished and either unclaimed or past its lease
    `timeout` (seconds) — a crashed/straggling worker's tile is simply handed
    to the next claimer.

    Concurrency contract (this is what makes the queue safe as the
    `repro.serve` scheduler):

    * every method takes an internal `threading.Lock`, so claims from
      concurrent pump threads never hand the same lease out twice;
    * `claim()` returns ``(idx, span, token)`` where `token` is the lease
      *generation* for that tile — re-leasing an expired tile bumps the
      generation, so a timed-out straggler that wakes up late and calls
      `complete(idx, token)` with its stale token is a no-op instead of
      retiring work that a live worker re-claimed (and may be mid-flight
      on, or may have claimed a *different attempt* of).
    * `push(payload)` appends a work item dynamically (request arrival);
    * `renew(idx, token)` refreshes a live lease's clock — a worker actively
      solving an item keeps calling it so in-flight work is never re-leased
      just because it outlasts `timeout`;
    * retired items are garbage-collected: the done prefix is dropped from
      the internal lists (indices stay valid — they are global, offset by an
      internal base) and retired payloads are released immediately, so a
      long-running service neither retains every request ever served nor
      scans the full history on each `claim()`;
    * expiry-reclaim backs off: the FIRST expiry of a lease reclaims at the
      base `timeout`, but every further expiry of the SAME item multiplies
      its effective lease timeout by `backoff_factor` (capped at
      `backoff_max_mult` × base) plus a deterministic per-(item, attempt)
      jitter of up to `backoff_jitter` × the backed-off timeout — so a dead
      worker's items don't thrash between survivors under tiny timeouts,
      and a thundering herd of claimers doesn't resynchronize on the same
      expiry instant.  A voluntary `release` resets the item's backoff (the
      worker was alive; nothing expired), as does a successful re-lease
      followed by `complete`.  ``timeout == 0`` stays immediate at every
      attempt (0 × anything = 0) — the serve layer's "every lease already
      expired" test mode keeps working.

    `clock` is injectable (defaults to `time.monotonic`) so backoff
    schedules are testable without sleeping (tests/test_workqueue_props.py).
    """

    def __init__(self, n_items: int = 0, tile: int = 1,
                 timeout: float = 60.0, *, backoff_factor: float = 2.0,
                 backoff_max_mult: float = 8.0, backoff_jitter: float = 0.25,
                 jitter_seed: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        self.tiles: List[Any] = [
            (lo, min(lo + tile, n_items)) for lo in range(0, n_items, tile)]
        self.timeout = float(timeout)
        self.backoff_factor = float(backoff_factor)
        self.backoff_max_mult = float(backoff_max_mult)
        self.backoff_jitter = float(backoff_jitter)
        self._jitter_seed = int(jitter_seed)
        self._clock = clock
        self._done = [False] * len(self.tiles)
        self._leased_at: List[Optional[float]] = [None] * len(self.tiles)
        self._gen = [0] * len(self.tiles)
        self._expiries = [0] * len(self.tiles)   # expiry-reclaims per item
        self._base = 0                      # global index of tiles[0]
        self._n_pushed = len(self.tiles)
        self._n_done = 0
        self._lock = threading.Lock()

    def _lease_timeout_locked(self, off: int) -> float:
        """Effective lease timeout for item `off`'s CURRENT lease: base
        timeout, exponentially backed off by prior expiry-reclaims, with
        deterministic jitter keyed on (item, attempt)."""
        n = self._expiries[off]
        if n == 0:
            return self.timeout
        mult = min(self.backoff_factor ** n, self.backoff_max_mult)
        jit = self.backoff_jitter * _mix_unit(
            self._jitter_seed, self._base + off, n)
        return self.timeout * mult * (1.0 + jit)

    def push(self, payload: Any) -> int:
        """Append one work item (any payload; tile spans are just the
        original payload shape). Returns its (global) index."""
        with self._lock:
            self.tiles.append(payload)
            self._done.append(False)
            self._leased_at.append(None)
            self._gen.append(0)
            self._expiries.append(0)
            self._n_pushed += 1
            return self._base + len(self.tiles) - 1

    def _compact_locked(self) -> None:
        # drop the retired prefix; global indices stay valid via _base
        k = 0
        while k < len(self._done) and self._done[k]:
            k += 1
        if k:
            del self.tiles[:k]
            del self._done[:k]
            del self._leased_at[:k]
            del self._gen[:k]
            del self._expiries[:k]
            self._base += k

    def claim(self) -> Optional[Tuple[int, Any, int]]:
        """Lease the first available item: (idx, payload, lease token).

        An unclaimed item leases immediately.  A leased item is reclaimable
        only once its CURRENT lease has outlived its effective timeout —
        base `timeout` on the first expiry, jittered-exponentially larger on
        each subsequent expiry of the same item (see class docstring)."""
        now = self._clock()
        with self._lock:
            self._compact_locked()
            for off, done in enumerate(self._done):
                if done:
                    continue
                leased = self._leased_at[off]
                if leased is None:
                    self._leased_at[off] = now
                    self._gen[off] += 1
                    return self._base + off, self.tiles[off], self._gen[off]
                if now - leased >= self._lease_timeout_locked(off):
                    self._expiries[off] += 1
                    self._leased_at[off] = now
                    self._gen[off] += 1
                    return self._base + off, self.tiles[off], self._gen[off]
        return None

    def complete(self, idx: int, token: int) -> bool:
        """Retire item `idx` iff `token` is its *current* lease generation.

        Returns True when the completion was accepted; False for a stale
        token (the lease expired and the item was re-leased — the caller's
        result must be discarded, the live claimer owns the item now)."""
        with self._lock:
            off = idx - self._base
            if off < 0 or off >= len(self._done) or self._done[off]:
                return False
            if token != self._gen[off]:
                return False
            self._done[off] = True
            self._leased_at[off] = None
            self.tiles[off] = None          # release the payload now
            self._n_done += 1
            return True

    def release(self, idx: int, token: int) -> bool:
        """Voluntarily return a leased item to the pool (still unfinished).
        Stale tokens are ignored, like `complete`.  Resets the item's
        expiry backoff: the worker proved alive, so the next lease runs on
        the base timeout again."""
        with self._lock:
            off = idx - self._base
            if off < 0 or off >= len(self._done) or self._done[off] \
                    or token != self._gen[off]:
                return False
            self._leased_at[off] = None
            self._expiries[off] = 0
            return True

    def renew(self, idx: int, token: int) -> bool:
        """Refresh a live lease's clock (worker still actively on the item),
        so in-flight work outlasting `timeout` is not handed to another
        claimer.  Stale tokens are ignored, like `complete`."""
        with self._lock:
            off = idx - self._base
            if off < 0 or off >= len(self._done) or self._done[off] \
                    or token != self._gen[off]:
                return False
            self._leased_at[off] = self._clock()
            return True

    @property
    def finished(self) -> bool:
        with self._lock:
            return self._n_done == self._n_pushed

    @property
    def pending(self) -> int:
        """Items not yet retired (leased or not)."""
        with self._lock:
            return self._n_pushed - self._n_done
