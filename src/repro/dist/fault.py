"""Fault tolerance: checkpoint supervision and straggler work reassignment.

`TrainSupervisor` wraps the atomic step-addressed checkpointer
(`repro.checkpoint.ckpt`) with the restart contract: crash-and-rerun resumes
from the newest complete checkpoint, and periodic saves are one call in the
training loop.  `WorkQueue` is the ensemble-tile analogue of a straggler-
tolerant scheduler: tiles of the trajectory axis are leased to workers and
become reassignable when a lease times out (a dead worker never wedges the
sweep — the same tile-local-termination property the fused kernel has on
device, at the job level).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint import ckpt as ckpt_lib


class TrainSupervisor:
    """Periodic-checkpoint + resume-from-latest supervision for a train loop."""

    def __init__(self, ckpt_dir: str, save_every: int = 1000,
                 async_save: bool = False):
        self.ckpt_dir = ckpt_dir
        self.save_every = int(save_every)
        self.async_save = async_save
        self._pending = None

    def resume_or_init(self, init_fn: Callable[[], Any], like_tree: Any
                       ) -> Tuple[int, Any, Dict]:
        """Restore the newest checkpoint into `like_tree`'s structure, or call
        `init_fn` for a fresh start. Returns (step, state, extra)."""
        latest = ckpt_lib.restore_latest(self.ckpt_dir, like_tree)
        if latest is None:
            return 0, init_fn(), {}
        step, state, extra = latest
        return step, state, extra

    def maybe_save(self, step: int, state: Any,
                   extra: Optional[Dict] = None) -> bool:
        """Checkpoint when `step` lands on the save_every grid."""
        if step % self.save_every != 0:
            return False
        self.flush()
        self._pending = ckpt_lib.save(self.ckpt_dir, step, state, extra=extra,
                                      async_write=self.async_save)
        return True

    def flush(self):
        """Join any in-flight async write (call before exit/restore)."""
        if self._pending is not None:
            self._pending.join()
            self._pending = None


class WorkQueue:
    """Lease-based tile queue with straggler reassignment.

    `n_items` units are split into `tile`-sized work units. `claim()` leases
    the first tile that is unfinished and either unclaimed or past its lease
    `timeout` (seconds) — a crashed/straggling worker's tile is simply handed
    to the next claimer. `complete(idx)` retires a tile.
    """

    def __init__(self, n_items: int, tile: int, timeout: float = 60.0):
        self.tiles: List[Tuple[int, int]] = [
            (lo, min(lo + tile, n_items)) for lo in range(0, n_items, tile)]
        self.timeout = float(timeout)
        self._done = [False] * len(self.tiles)
        self._leased_at: List[Optional[float]] = [None] * len(self.tiles)

    def claim(self) -> Optional[Tuple[int, Tuple[int, int]]]:
        now = time.monotonic()
        for idx, done in enumerate(self._done):
            if done:
                continue
            leased = self._leased_at[idx]
            if leased is None or now - leased >= self.timeout:
                self._leased_at[idx] = now
                return idx, self.tiles[idx]
        return None

    def complete(self, idx: int):
        self._done[idx] = True
        self._leased_at[idx] = None

    @property
    def finished(self) -> bool:
        return all(self._done)
