"""Fault tolerance: checkpoint supervision and straggler work reassignment.

`TrainSupervisor` wraps the atomic step-addressed checkpointer
(`repro.checkpoint.ckpt`) with the restart contract: crash-and-rerun resumes
from the newest complete checkpoint, and periodic saves are one call in the
training loop.  `WorkQueue` is the ensemble-tile analogue of a straggler-
tolerant scheduler: tiles of the trajectory axis are leased to workers and
become reassignable when a lease times out (a dead worker never wedges the
sweep — the same tile-local-termination property the fused kernel has on
device, at the job level).  It is also the request scheduler behind
`repro.serve`: requests are `push()`-ed as work items, pool pumps `claim()`
them under lease, and a pump that dies mid-request simply lets the lease
expire so the next pump retries the request.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


class TrainSupervisor:
    """Periodic-checkpoint + resume-from-latest supervision for a train loop."""

    def __init__(self, ckpt_dir: str, save_every: int = 1000,
                 async_save: bool = False):
        self.ckpt_dir = ckpt_dir
        self.save_every = int(save_every)
        self.async_save = async_save
        self._pending = None
        self._last_saved: Optional[int] = None

    def resume_or_init(self, init_fn: Callable[[], Any], like_tree: Any
                       ) -> Tuple[int, Any, Dict]:
        """Restore the newest checkpoint into `like_tree`'s structure, or call
        `init_fn` for a fresh start. Returns (step, state, extra)."""
        from repro.checkpoint import ckpt as ckpt_lib
        latest = ckpt_lib.restore_latest(self.ckpt_dir, like_tree)
        if latest is None:
            return 0, init_fn(), {}
        step, state, extra = latest
        return step, state, extra

    def maybe_save(self, step: int, state: Any,
                   extra: Optional[Dict] = None) -> bool:
        """Checkpoint when `step` lands on the save_every grid.

        Step 0 is skipped: `0 % save_every == 0` used to write a pointless
        checkpoint of the exact init state every run (and, worse, a restart
        would then "resume" from step 0 instead of calling init_fn fresh).
        The final, possibly off-grid state is the loop's responsibility —
        call `finalize(step, state)` at loop exit.
        """
        if step == 0 or step % self.save_every != 0:
            return False
        return self._save(step, state, extra)

    def finalize(self, step: int, state: Any,
                 extra: Optional[Dict] = None) -> bool:
        """Checkpoint the loop-exit state (even off the save_every grid) and
        join any in-flight async write.  No-op when `step` was already saved
        by `maybe_save` (exit step on the grid)."""
        if step == self._last_saved or step == 0:
            self.flush()
            return False
        saved = self._save(step, state, extra)
        self.flush()
        return saved

    def _save(self, step: int, state: Any, extra: Optional[Dict]) -> bool:
        from repro.checkpoint import ckpt as ckpt_lib
        self.flush()
        self._pending = ckpt_lib.save(self.ckpt_dir, step, state, extra=extra,
                                      async_write=self.async_save)
        self._last_saved = step
        return True

    def flush(self):
        """Join any in-flight async write (call before exit/restore)."""
        if self._pending is not None:
            self._pending.join()
            self._pending = None


class WorkQueue:
    """Lease-based tile queue with straggler reassignment.

    `n_items` units are split into `tile`-sized work units. `claim()` leases
    the first tile that is unfinished and either unclaimed or past its lease
    `timeout` (seconds) — a crashed/straggling worker's tile is simply handed
    to the next claimer.

    Concurrency contract (this is what makes the queue safe as the
    `repro.serve` scheduler):

    * every method takes an internal `threading.Lock`, so claims from
      concurrent pump threads never hand the same lease out twice;
    * `claim()` returns ``(idx, span, token)`` where `token` is the lease
      *generation* for that tile — re-leasing an expired tile bumps the
      generation, so a timed-out straggler that wakes up late and calls
      `complete(idx, token)` with its stale token is a no-op instead of
      retiring work that a live worker re-claimed (and may be mid-flight
      on, or may have claimed a *different attempt* of).
    * `push(payload)` appends a work item dynamically (request arrival);
    * `renew(idx, token)` refreshes a live lease's clock — a worker actively
      solving an item keeps calling it so in-flight work is never re-leased
      just because it outlasts `timeout`;
    * retired items are garbage-collected: the done prefix is dropped from
      the internal lists (indices stay valid — they are global, offset by an
      internal base) and retired payloads are released immediately, so a
      long-running service neither retains every request ever served nor
      scans the full history on each `claim()`.
    """

    def __init__(self, n_items: int = 0, tile: int = 1,
                 timeout: float = 60.0):
        self.tiles: List[Any] = [
            (lo, min(lo + tile, n_items)) for lo in range(0, n_items, tile)]
        self.timeout = float(timeout)
        self._done = [False] * len(self.tiles)
        self._leased_at: List[Optional[float]] = [None] * len(self.tiles)
        self._gen = [0] * len(self.tiles)
        self._base = 0                      # global index of tiles[0]
        self._n_pushed = len(self.tiles)
        self._n_done = 0
        self._lock = threading.Lock()

    def push(self, payload: Any) -> int:
        """Append one work item (any payload; tile spans are just the
        original payload shape). Returns its (global) index."""
        with self._lock:
            self.tiles.append(payload)
            self._done.append(False)
            self._leased_at.append(None)
            self._gen.append(0)
            self._n_pushed += 1
            return self._base + len(self.tiles) - 1

    def _compact_locked(self) -> None:
        # drop the retired prefix; global indices stay valid via _base
        k = 0
        while k < len(self._done) and self._done[k]:
            k += 1
        if k:
            del self.tiles[:k]
            del self._done[:k]
            del self._leased_at[:k]
            del self._gen[:k]
            self._base += k

    def claim(self) -> Optional[Tuple[int, Any, int]]:
        """Lease the first available item: (idx, payload, lease token)."""
        now = time.monotonic()
        with self._lock:
            self._compact_locked()
            for off, done in enumerate(self._done):
                if done:
                    continue
                leased = self._leased_at[off]
                if leased is None or now - leased >= self.timeout:
                    self._leased_at[off] = now
                    self._gen[off] += 1
                    return self._base + off, self.tiles[off], self._gen[off]
        return None

    def complete(self, idx: int, token: int) -> bool:
        """Retire item `idx` iff `token` is its *current* lease generation.

        Returns True when the completion was accepted; False for a stale
        token (the lease expired and the item was re-leased — the caller's
        result must be discarded, the live claimer owns the item now)."""
        with self._lock:
            off = idx - self._base
            if off < 0 or off >= len(self._done) or self._done[off]:
                return False
            if token != self._gen[off]:
                return False
            self._done[off] = True
            self._leased_at[off] = None
            self.tiles[off] = None          # release the payload now
            self._n_done += 1
            return True

    def release(self, idx: int, token: int) -> bool:
        """Voluntarily return a leased item to the pool (still unfinished).
        Stale tokens are ignored, like `complete`."""
        with self._lock:
            off = idx - self._base
            if off < 0 or off >= len(self._done) or self._done[off] \
                    or token != self._gen[off]:
                return False
            self._leased_at[off] = None
            return True

    def renew(self, idx: int, token: int) -> bool:
        """Refresh a live lease's clock (worker still actively on the item),
        so in-flight work outlasting `timeout` is not handed to another
        claimer.  Stale tokens are ignored, like `complete`."""
        with self._lock:
            off = idx - self._base
            if off < 0 or off >= len(self._done) or self._done[off] \
                    or token != self._gen[off]:
                return False
            self._leased_at[off] = time.monotonic()
            return True

    @property
    def finished(self) -> bool:
        with self._lock:
            return self._n_done == self._n_pushed

    @property
    def pending(self) -> int:
        """Items not yet retired (leased or not)."""
        with self._lock:
            return self._n_pushed - self._n_done
