"""Generic explicit Runge-Kutta engine (tableau-driven), three execution shapes.

One engine serves every strategy in the paper:

  * scalar mode   — ``u: (n,)``, scalar ``t/dt``: the per-trajectory reference
                    solver (`solve_one`); `vmap`-ing it reproduces the JAX/Diffrax
                    baseline the paper benchmarks against (EnsembleVmap).
  * array mode    — ``u: (N, n)``, scalar ``t/dt`` and an ensemble-wide error
                    norm: bitwise-faithful EnsembleGPUArray semantics (§5.1) —
                    one lock-step dt for the whole ensemble.
  * lanes mode    — ``u: (n, B)``, per-lane ``t/dt/accept`` masks: the structure
                    of the paper's EnsembleGPUKernel (§5.2) adapted to TPU vector
                    lanes; this exact loop body is also what the Pallas kernel
                    runs per tile (kernels/tsit5).

All of it is pure ``jax.lax`` control flow (while_loop / scan / cond) — no
Python-level stepping — so each solve lowers to a single XLA computation
("one kernel launch" in the paper's terms).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .controller import (STATUS_DTMIN_EXHAUSTED, PIController, hairer_norm,
                         pi_propose)
from .events import Event, handle_event
from .loops import checkpointed_fori, solver_loop
from .tableaus import Tableau

Array = Any


class SolveResult(NamedTuple):
    ts: Array        # (S,) save times (the common saveat grid)
    us: Array        # scalar/array mode: (S, n)/(S, N, n); lanes: (S, n, B)
    t_final: Array
    u_final: Array
    naccept: Array
    nreject: Array
    status: Array    # 0 = success, 1 = max_iters exhausted,
    #                  2 = dt pinned at dtmin while rejecting (see
    #                  repro.core.controller.STATUS_DTMIN_EXHAUSTED)
    nf: Array        # number of RHS evaluations (per control element)
    njac: Array = 0  # Jacobian evaluations (stiff family; 0 elsewhere)
    nfact: Array = 0  # W = I − γh·J factorizations (stiff family)


# ----------------------------------------------------------------------------
# single embedded RK step
# ----------------------------------------------------------------------------

def _bc(v, u):
    """Broadcast a control value (scalar or (B,)) against state u ((n,)/(N,n)/(n,B))."""
    return v if jnp.ndim(v) == 0 else v[None]


def rk_step(f, tab: Tableau, u, p, t, dt, k1):
    """One embedded step. Returns (u_new, err, ks).

    k1 must be f(u, p, t) (caller owns FSAL reuse). The stage loop is a static
    Python unroll — 6-16 fused vector ops, no dynamic control flow.
    """
    s = tab.stages
    dtb = _bc(dt, u)
    ks = [k1]
    # NOTE: tableau entries are converted to python floats (weak-typed) so the
    # state dtype (f32 on accelerators, f64 reference) is never upcast.
    for i in range(1, s):
        acc = None
        for j in range(i):
            aij = float(tab.a[i, j])
            if aij == 0.0:
                continue
            term = aij * ks[j]
            acc = term if acc is None else acc + term
        ui = u if acc is None else u + dtb * acc
        ks.append(f(ui, p, t + float(tab.c[i]) * dt))
    unew_acc = None
    err_acc = None
    for i in range(s):
        if tab.b[i] != 0.0:
            term = float(tab.b[i]) * ks[i]
            unew_acc = term if unew_acc is None else unew_acc + term
        if tab.btilde[i] != 0.0:
            term = float(tab.btilde[i]) * ks[i]
            err_acc = term if err_acc is None else err_acc + term
    u_new = u + dtb * unew_acc
    err = dtb * err_acc if err_acc is not None else jnp.zeros_like(u)
    return u_new, err, ks


def interp_step(f, tab: Tableau, u_old, u_new, ks, p, t, dt, theta,
                lanes: bool = False):
    """Dense output u(t + theta*dt), theta in [0,1].

    Uses the tableau's free interpolant when available (Tsit5: 4th order),
    otherwise cubic Hermite on (u_old, k1, u_new, f(u_new)).

    Shape contract:
      lanes=False: u (n,)/(N,n), dt scalar, theta scalar or (S,)
                   -> u-shaped or (S, *ushape).
      lanes=True : u (n,B), dt (B,), theta (B,) or (S,B) — the LAST theta axis
                   is the lane axis -> (n,B) or (S,n,B).
    """
    th_nd = jnp.ndim(theta)
    u_nd = jnp.ndim(u_old)

    def expand_w(w):
        """Align a (*theta.shape) weight against the state axes."""
        if th_nd == 0:
            return w
        if lanes:
            # (..., B) -> (..., 1, B); state (n, B) broadcasts in.
            return jnp.expand_dims(w, axis=-2)
        return w.reshape(jnp.shape(w) + (1,) * u_nd)

    def expand_u(x):
        """Align a state against leading (non-lane) theta axes."""
        lead = th_nd - (1 if lanes else 0)
        if lead <= 0:
            return x
        return x.reshape((1,) * lead + jnp.shape(x))

    dtb = _bc(dt, u_old)  # scalar or (1, B)

    if tab.interp_bpoly is not None:
        bw = tab.interp_bpoly(theta)          # (s, *theta.shape)
        incr = None
        for i, k in enumerate(ks):
            term = expand_w(bw[i]) * expand_u(k)
            incr = term if incr is None else incr + term
        return expand_u(u_old) + dtb * incr
    # Hermite cubic
    f_old = ks[0]
    f_new = ks[-1] if tab.fsal else f(u_new, p, t + dt)
    the = theta
    h00 = expand_w((1 + 2 * the) * (1 - the) ** 2)
    h10 = expand_w(the * (1 - the) ** 2)
    h01 = expand_w(the ** 2 * (3 - 2 * the))
    h11 = expand_w(the ** 2 * (the - 1))
    return (h00 * expand_u(u_old) + h10 * dtb * expand_u(f_old)
            + h01 * expand_u(u_new) + h11 * dtb * expand_u(f_new))


# ----------------------------------------------------------------------------
# fixed-step fast path (scan): the throughput shape of the paper's kernels
# ----------------------------------------------------------------------------

def solve_fixed(f, tab: Tableau, u0, p, t0, dt, n_steps: int,
                save_every: int = 1, remat: bool = False,
                checkpoint_every: Optional[int] = None):
    """Fixed-dt integration as scan(fori(rk_step)). Differentiable (fwd+rev).

    Saves every `save_every`-th step => S = n_steps // save_every snapshots.
    Works for any state shape (scalar/array/lanes).  ``remat=True`` wraps each
    save chunk in `jax.checkpoint` and segments the chunk's step loop with
    `repro.core.loops.checkpointed_fori` (``checkpoint_every`` steps per
    segment, default sqrt(save_every)) — the primal is bitwise-unchanged, but
    the reverse pass stores one (u, t) carry per snapshot plus one per
    segment and recomputes stages inside segments, bounding adjoint memory at
    O(S + save_every/ck + ck) states instead of O(n_steps).
    """
    assert n_steps % save_every == 0, "n_steps must be divisible by save_every"
    S = n_steps // save_every
    dt = jnp.asarray(dt, dtype=u0.dtype)
    t0 = jnp.asarray(t0, dtype=u0.dtype)

    def inner(carry, _):
        u, t = carry

        def one(i, uk):
            u, t = uk
            k1 = f(u, p, t)
            u_new, _, _ = rk_step(f, tab, u, p, t, dt, k1)
            return (u_new, t + dt)

        if remat:
            u, t = checkpointed_fori(0, save_every, one, (u, t),
                                     checkpoint_every=checkpoint_every)
        else:
            u, t = jax.lax.fori_loop(0, save_every, one, (u, t))
        return (u, t), u

    if remat:
        inner = jax.checkpoint(inner)
    (u_f, t_f), us = jax.lax.scan(inner, (u0, t0), None, length=S)
    ts = t0 + dt * save_every * jnp.arange(1, S + 1, dtype=u0.dtype)
    nf = jnp.asarray(n_steps * (tab.stages - (1 if tab.fsal else 0)) + (1 if tab.fsal else 0))
    return SolveResult(ts=ts, us=us, t_final=t_f, u_final=u_f,
                       naccept=jnp.asarray(n_steps), nreject=jnp.asarray(0),
                       status=jnp.asarray(0), nf=nf)


# ----------------------------------------------------------------------------
# adaptive driver (while_loop), scalar/array/lanes via shape polymorphism
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdaptiveOptions:
    rtol: float = 1e-6
    atol: float = 1e-6
    max_iters: int = 100_000
    controller: Optional[PIController] = None
    adaptive: bool = True            # False => accept every step at fixed dt
    save: str = "grid"               # "grid" | "final"
    norm_axes: Optional[Any] = "auto"  # "auto": lanes->0, else None
    # Reverse-mode AD (repro.core.loops / repro.core.sensitivity): replace the
    # while_loop with bounded_steps checkpointed scan segments and freeze the
    # step-size controller out of the autodiff graph (discrete adjoint of the
    # realized step sequence).  Whenever the bound covers the true iteration
    # count (too small => status == 1) the accept/step sequence is identical
    # to the while path; values agree to ulp (the adjoint-safe probe changes
    # XLA fusion, so exact bits may differ — see docs/architecture.md).
    bounded_steps: Optional[int] = None
    checkpoint_every: Optional[int] = None


def _grid_save(f, tab, us, saveat, u_old, u_new, ks, p, t_old, dt_step,
               t_new, active):
    """Masked write of every save point crossed by this step (vectorized over S).

    saveat: (S,). lanes mode: t_old/t_new (B,), us (S,n,B); scalar/array:
    t_old scalar, us (S,*ushape). Cost is O(S) vector ops but only paid on
    steps that cross a save point (guarded by lax.cond in the caller).
    """
    lanes = jnp.ndim(t_old) == 1
    eps = jnp.asarray(1e-7, us.dtype) * jnp.maximum(jnp.abs(t_new), 1.0)
    if lanes:
        cross = ((saveat[:, None] > t_old[None, :])
                 & (saveat[:, None] <= t_new[None, :] + eps[None, :])
                 & active[None, :])                       # (S, B)
        theta = jnp.clip((saveat[:, None] - t_old[None, :])
                         / jnp.where(dt_step[None, :] == 0, 1.0, dt_step[None, :]),
                         0.0, 1.0)                        # (S, B)
        vals = interp_step(f, tab, u_old, u_new, ks, p, t_old, dt_step, theta,
                           lanes=True)
        # vals: (S, n, B); cross -> (S, 1, B)
        return jnp.where(cross[:, None, :], vals, us)
    else:
        cross = ((saveat > t_old) & (saveat <= t_new + eps) & active)  # (S,)
        theta = jnp.clip((saveat - t_old) / jnp.where(dt_step == 0, 1.0, dt_step),
                         0.0, 1.0)
        vals = interp_step(f, tab, u_old, u_new, ks, p, t_old, dt_step, theta)
        cross_e = cross.reshape(cross.shape + (1,) * (us.ndim - 1))
        return jnp.where(cross_e, vals, us)


def _make_adaptive_body(f, tab: Tableau, opts: AdaptiveOptions, ctrl, event,
                        lanes: bool, dtype, cshape, axes, saveat, save_grid,
                        bounded, p=None, tf=None):
    """The adaptive loop body, shared by `solve_adaptive` (p/tf closed over)
    and the resumable segment engine (`erk_resume_body`: p/tf read from the
    carry, so every per-lane constant travels WITH the lane and a slot can be
    refilled with a different request's problem without recompiling).  In
    closure mode the emitted expressions are identical to the historical
    inline body — bitwise-stable refactor."""
    per_lane_consts = p is None

    def body(c):
        p_ = c["p"] if per_lane_consts else p
        tf_ = c["tf"] if per_lane_consts else tf
        t, u, dt, k1 = c["t"], c["u"], c["dt"], c["k1"]
        active = ~c["done"]
        remaining = tf_ - t
        dt_step = jnp.minimum(dt, remaining)
        # done lanes step at dt = 0: the stage cascade is an exact no-op on
        # them (any value is output-invariant — every write is accept-masked —
        # but a nonzero dt lets finished stiff lanes synthesize inf/NaN
        # candidates, which poisons the reverse pass via 0 * inf cotangents)
        dt_step = jnp.where(active, dt_step, jnp.asarray(0.0, dtype))

        u_cand, err, ks = rk_step(f, tab, u, p_, t, dt_step, k1)

        if opts.adaptive:
            enorm = hairer_norm(err, u, u_cand, opts.atol, opts.rtol, axes=axes)
            finite = jnp.isfinite(u_cand)
            if lanes:
                finite = jnp.all(finite, axis=0)
            else:
                finite = jnp.all(finite)
            accept = (enorm <= 1.0) & finite
            if bounded:
                # Frozen-step discrete adjoint: the controller chain (enorm ->
                # dt) is severed from the autodiff graph — we differentiate
                # the realized step sequence, not the step-size policy.  This
                # also keeps hairer_norm's sqrt out of the transposed graph.
                enorm = jax.lax.stop_gradient(enorm)
            dt_next, enorm_prev = pi_propose(ctrl, dt, enorm, c["enorm_prev"],
                                             accept)
        else:
            enorm = jnp.zeros(cshape, dtype)
            accept = jnp.ones(cshape, bool)
            dt_next, enorm_prev = dt, c["enorm_prev"]

        accept = accept & active
        dt_try = dt_step   # pre-adjoint-mask attempt size (dtmin-floor check)
        if bounded and opts.adaptive:
            # Adjoint-safe second pass: the first cascade above was a primal-
            # only probe (its only consumers are the frozen accept/controller
            # values); re-run it at where(accept, dt, 0) so the DIFFERENTIATED
            # stage cascade is an exact no-op on rejected attempts.  Accepted
            # lanes recompute bit-identical values; the reverse pass never
            # transposes an f evaluation at an off-trajectory (possibly
            # overflowed) rejected candidate.
            dt_step = jnp.where(accept, dt_step, jnp.asarray(0.0, dtype))
            u_cand, err, ks = rk_step(f, tab, u, p_, t, dt_step, k1)
        t_new = jnp.where(accept, t + dt_step, t)

        # ---- events: detect/locate/apply via the shared machinery ----------
        if event is not None:
            def interp_fn(theta):
                return interp_step(f, tab, u, u_cand, ks, p_, t, dt_step,
                                   theta, lanes=lanes)

            u_next, t_new, ev_t, ev_n, term = handle_event(
                event, interp_fn, u, u_cand, p_, t, dt_step, t_new, accept,
                c["event_t"], c["event_count"], lanes=lanes)
        else:
            u_next = u_cand
            ev_t, ev_n = c["event_t"], c["event_count"]
            term = jnp.zeros(cshape, bool)

        acc_e = _bc(accept, u) if lanes else accept
        u_new = jnp.where(acc_e, u_next, u)
        # FSAL: reuse last stage; recompute after an event modified the state
        if tab.fsal and event is None:
            k1_new = jnp.where(acc_e, ks[-1], k1)
            nf_inc = jnp.where(active, tab.stages - 1, 0)
        else:
            k1_new = jnp.where(acc_e, f(u_new, p_, t_new), k1)
            nf_inc = jnp.where(active, tab.stages, 0)

        # ---- dense save -----------------------------------------------------
        if save_grid:
            def do_save(us):
                return _grid_save(f, tab, us, saveat, u, u_cand, ks, p_, t,
                                  dt_step, t_new, accept)

            any_cross = jnp.any(
                accept & (jnp.max(saveat) > (t.min() if lanes else t)))
            us = jax.lax.cond(any_cross, do_save, lambda x: x, c["us"])
        else:
            us = c.get("us")

        # dt pinned at the controller floor and still rejecting: retrying the
        # identical step is a deterministic live-lock — terminate the lane
        # with a distinct status instead of spinning to max_iters
        hopeless = active & ~accept & ~(dt_try > ctrl.dtmin) if opts.adaptive \
            else jnp.zeros(cshape, bool)
        statusv = jnp.where(hopeless,
                            jnp.asarray(STATUS_DTMIN_EXHAUSTED, jnp.int32),
                            c["status"])
        eps_end = 1e-7 * jnp.maximum(jnp.abs(tf_), 1.0)
        done = c["done"] | (t_new >= tf_ - eps_end) | term | hopeless

        out = dict(
            t=t_new, u=u_new, dt=dt_next, k1=k1_new,
            enorm_prev=enorm_prev, done=done,
            naccept=c["naccept"] + accept.astype(jnp.int32),
            nreject=c["nreject"] + (active & ~accept).astype(jnp.int32),
            nf=c["nf"] + nf_inc.astype(jnp.int32),
            status=statusv, iters=c["iters"] + 1,
            event_t=ev_t, event_count=ev_n,
        )
        if us is not None:
            out["us"] = us
        if per_lane_consts:
            out["p"], out["tf"] = c["p"], c["tf"]
        return out

    return body


def solve_adaptive(f, tab: Tableau, u0, p, t0, tf, dt0,
                   saveat: Optional[Array] = None,
                   opts: AdaptiveOptions = AdaptiveOptions(),
                   event: Optional[Event] = None,
                   lanes: bool = False):
    """Adaptive (or fixed-accept) integration with optional events.

    lanes=False, u0 (n,)   : per-trajectory (scalar control).
    lanes=False, u0 (N, n) : EnsembleGPUArray lock-step semantics (scalar
                             control, ensemble-wide norm).
    lanes=True,  u0 (n, B) : per-lane control — EnsembleGPUKernel structure.
    """
    dtype = u0.dtype
    ctrl = opts.controller or PIController.for_order(tab.embedded_order)
    cshape = (u0.shape[-1],) if lanes else ()
    axes = (0 if lanes else None) if opts.norm_axes == "auto" else opts.norm_axes

    t0 = jnp.asarray(t0, dtype)
    tf = jnp.asarray(tf, dtype)
    tv = jnp.broadcast_to(t0, cshape).astype(dtype)
    dtv = jnp.broadcast_to(jnp.asarray(dt0, dtype), cshape).astype(dtype)

    if saveat is None:
        saveat = jnp.asarray([tf], dtype)
    saveat = jnp.asarray(saveat, dtype)
    S = saveat.shape[0]
    save_grid = opts.save == "grid"
    us0 = jnp.zeros((S,) + u0.shape, dtype)
    # prefill save points at/before t0 with u0
    pre = (saveat <= t0).reshape((S,) + (1,) * u0.ndim)
    us0 = jnp.where(pre, u0[None], us0)

    k0 = f(u0, p, tv)
    carry0 = dict(
        t=tv, u=u0, dt=dtv, k1=k0,
        enorm_prev=jnp.ones(cshape, dtype),
        done=jnp.zeros(cshape, bool),
        us=us0,
        naccept=jnp.zeros(cshape, jnp.int32),
        nreject=jnp.zeros(cshape, jnp.int32),
        nf=jnp.ones(cshape, jnp.int32),
        status=jnp.zeros(cshape, jnp.int32),
        iters=jnp.asarray(0, jnp.int32),
        event_t=jnp.full(cshape, jnp.inf, dtype),
        event_count=jnp.zeros(cshape, jnp.int32),
    )

    def cond(c):
        return (c["iters"] < opts.max_iters) & jnp.any(~c["done"])

    bounded = opts.bounded_steps is not None
    body = _make_adaptive_body(f, tab, opts, ctrl, event, lanes, dtype,
                               cshape, axes, saveat, save_grid, bounded,
                               p=p, tf=tf)
    out = solver_loop(cond, body, carry0, bounded_steps=opts.bounded_steps,
                      checkpoint_every=opts.checkpoint_every)
    status = jnp.where(out["status"] > 0, out["status"],
                       jnp.where(out["done"], 0, 1)).astype(jnp.int32)
    res = SolveResult(ts=saveat, us=out["us"], t_final=out["t"],
                      u_final=out["u"], naccept=out["naccept"],
                      nreject=out["nreject"], status=status, nf=out["nf"])
    if event is not None:
        return res, dict(event_t=out["event_t"], event_count=out["event_count"])
    return res


# ----------------------------------------------------------------------------
# resumable per-lane carry (the serving engine's substrate)
# ----------------------------------------------------------------------------

def erk_resume_init(f, tab: Tableau, u0, p, t0, tf, dt0):
    """Fresh per-lane resume carry — lanes mode only: u0 (n, B), p (k, B),
    t0/tf/dt0 scalars or (B,).

    Field-for-field identical to `solve_adaptive`'s initial carry minus the
    dense save buffer, plus carry-resident p/tf: a lane stepped to completion
    by `erk_resume_body` realizes the exact accept/step sequence of a fresh
    `solve_adaptive(..., lanes=True)` on the same column — bitwise (the loop
    body is the same shared `_make_adaptive_body`; per-lane control never
    couples lanes outside no-op iterations).
    """
    dtype = u0.dtype
    cshape = (u0.shape[-1],)
    tv = jnp.broadcast_to(jnp.asarray(t0, dtype), cshape).astype(dtype)
    tfv = jnp.broadcast_to(jnp.asarray(tf, dtype), cshape).astype(dtype)
    dtv = jnp.broadcast_to(jnp.asarray(dt0, dtype), cshape).astype(dtype)
    k0 = f(u0, p, tv)
    return dict(
        t=tv, u=u0, dt=dtv, k1=k0,
        enorm_prev=jnp.ones(cshape, dtype),
        done=jnp.zeros(cshape, bool),
        naccept=jnp.zeros(cshape, jnp.int32),
        nreject=jnp.zeros(cshape, jnp.int32),
        nf=jnp.ones(cshape, jnp.int32),
        status=jnp.zeros(cshape, jnp.int32),
        iters=jnp.asarray(0, jnp.int32),
        event_t=jnp.full(cshape, jnp.inf, dtype),
        event_count=jnp.zeros(cshape, jnp.int32),
        p=p, tf=tfv,
    )


def erk_resume_body(f, tab: Tableau, opts: AdaptiveOptions = AdaptiveOptions(),
                    event: Optional[Event] = None):
    """Build the per-lane resumable step body (lanes mode) over the carry from
    `erk_resume_init`: the exact `solve_adaptive` loop body with p/tf read
    from the carry instead of closed over, so ONE compiled body serves every
    request with this (method, n, dtype) signature — slot refill never
    recompiles.  Applying it to a done lane is an exact no-op (dt_step = 0,
    every write accept/active-masked), so mixed-progress slots are safe.
    No dense save buffer: serving returns final states + stats.
    """
    ctrl = opts.controller or PIController.for_order(tab.embedded_order)
    bounded = opts.bounded_steps is not None

    def body(c):
        dtype = c["u"].dtype
        cshape = (c["u"].shape[-1],)
        inner = _make_adaptive_body(f, tab, opts, ctrl, event, True, dtype,
                                    cshape, 0, None, False, bounded)
        return inner(c)

    return body


# ----------------------------------------------------------------------------
# public single-trajectory reference solver
# ----------------------------------------------------------------------------

def solve_one(f, tab: Tableau, u0, p, t0, tf, dt0, saveat=None,
              rtol=1e-6, atol=1e-6, adaptive=True, max_iters=100_000,
              event=None, save="grid", controller=None, bounded_steps=None,
              checkpoint_every=None):
    opts = AdaptiveOptions(rtol=rtol, atol=atol, max_iters=max_iters,
                           adaptive=adaptive, save=save, controller=controller,
                           bounded_steps=bounded_steps,
                           checkpoint_every=checkpoint_every)
    return solve_adaptive(f, tab, u0, p, t0, tf, dt0, saveat=saveat, opts=opts,
                          event=event, lanes=False)
