"""Distributed ensemble solving — the paper's MPI composition (§6.3) on a mesh.

The trajectory axis is embarrassingly parallel: `shard_map` splits the ensemble
over the ("pod", "data") mesh axes, each shard runs the fused local solve
(zero collectives inside — same property the paper's CUDA-aware-MPI demo
exploits), and only moment reductions (`ensemble_moments`) communicate, via
psum. On the 2×16×16 production mesh this is 512-way trajectory parallelism;
the 2^30-trajectory configuration of §6.3 is exercised by the dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .ensemble import EnsembleResult, solve_ensemble_local
from .problem import EnsembleProblem

Array = Any


def _ensemble_axes(mesh: Mesh, shard_axes: Optional[Sequence[str]]):
    if shard_axes is None:
        shard_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return tuple(shard_axes)


def solve_ensemble(eprob: EnsembleProblem, mesh: Optional[Mesh] = None,
                   shard_axes: Optional[Sequence[str]] = None,
                   **kw) -> EnsembleResult:
    """Solve an ensemble, optionally sharded over `mesh`.

    This is the distributed face of the unified front door: `alg=` may be any
    registered method (erk / rosenbrock / sde — see `repro.core.methods`),
    dispatched through any `ensemble=`/`backend=` combination by
    `solve_ensemble_local`. Trajectories are split over `shard_axes` (default:
    every ensemble-capable axis present — "pod" and "data"); each device runs
    the fused kernel path on its local chunk. N must divide by the total shard
    count.

    SDE counter-RNG streams are GLOBAL: each shard's `lane_offset` (its first
    trajectory's global index) is threaded into the local solve, so shard k
    draws the (seed; step, row, k*n_local + i) stream — sharded and local
    solves produce bitwise-identical trajectories, and distinct shards never
    replay each other's noise.
    """
    if mesh is None:
        return solve_ensemble_local(eprob, **kw)

    axes = _ensemble_axes(mesh, shard_axes)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    u0s, ps = eprob.materialize()
    N = u0s.shape[0]
    assert N % nshards == 0, (
        f"trajectories {N} must divide over {nshards} shards")
    n_local = N // nshards
    prob = eprob.prob
    spec = P(axes)
    base_offset = kw.pop("lane_offset", 0)

    def local(u0c, pc):
        # linear shard index in the same axis order the PartitionSpec uses,
        # -> this shard's first global trajectory index
        idx = jnp.asarray(0, jnp.uint32)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a).astype(jnp.uint32)
        sub = EnsembleProblem(prob, u0c.shape[0], u0s=u0c, ps=pc)
        res = solve_ensemble_local(sub, lane_offset=base_offset + idx * n_local,
                                   **kw)
        # per-shard scalars -> global via psum (lightweight stats only)
        nf, njac, nfact = res.nf, res.njac, res.nfact
        for a in axes:
            nf = jax.lax.psum(nf, a)
            njac = jax.lax.psum(njac, a)
            nfact = jax.lax.psum(nfact, a)
        return res._replace(nf=nf, njac=njac, nfact=nfact)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(spec, spec),
                   out_specs=EnsembleResult(
                       ts=P(), us=spec, u_final=spec, t_final=spec,
                       naccept=spec, nreject=spec, nf=P(), status=P(),
                       njac=P(), nfact=P()),
                   check_rep=False)
    return fn(u0s, ps)


def ensemble_moments(us: Array, mesh: Optional[Mesh] = None,
                     shard_axes: Optional[Sequence[str]] = None):
    """Mean/variance over the (possibly sharded) trajectory axis — the SDE
    Monte-Carlo reduction (§6.8). us: (N, ...) sharded on axis 0."""
    if mesh is None:
        return jnp.mean(us, axis=0), jnp.var(us, axis=0)

    axes = _ensemble_axes(mesh, shard_axes)
    spec = P(axes)

    def local(u):
        n_local = u.shape[0]
        s1 = jnp.sum(u, axis=0)
        s2 = jnp.sum(u * u, axis=0)
        n = jnp.asarray(n_local, u.dtype)
        for a in axes:
            s1 = jax.lax.psum(s1, a)
            s2 = jax.lax.psum(s2, a)
            n = jax.lax.psum(n, a)
        mean = s1 / n
        var = s2 / n - mean * mean
        return mean, var

    fn = shard_map(local, mesh=mesh, in_specs=(spec,),
                   out_specs=(P(), P()), check_rep=False)
    return fn(us)
