"""Distributed ensemble solving — the paper's MPI composition (§6.3) on a mesh.

The trajectory axis is embarrassingly parallel: `shard_map` splits the ensemble
over the ("pod", "data") mesh axes, each shard runs the fused local solve
(zero collectives inside — same property the paper's CUDA-aware-MPI demo
exploits), and only moment reductions (`ensemble_moments`) communicate, via
psum. On the 2×16×16 production mesh this is 512-way trajectory parallelism;
the 2^30-trajectory configuration of §6.3 is exercised by the dry-run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .ensemble import EnsembleResult, solve_ensemble_local
from .interp import data_flatten, data_unflatten
from .problem import EnsembleProblem

Array = Any


def _ensemble_axes(mesh: Mesh, shard_axes: Optional[Sequence[str]]):
    if shard_axes is None:
        shard_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return tuple(shard_axes)


def solve_ensemble(eprob: EnsembleProblem, mesh: Optional[Mesh] = None,
                   shard_axes: Optional[Sequence[str]] = None,
                   **kw) -> EnsembleResult:
    """Solve an ensemble, optionally sharded over `mesh`.

    This is the distributed face of the unified front door: `alg=` may be any
    registered method (erk / rosenbrock / sde — see `repro.core.methods`),
    dispatched through any `ensemble=`/`backend=` combination by
    `solve_ensemble_local`. Trajectories are split over `shard_axes` (default:
    every ensemble-capable axis present — "pod" and "data"); each device runs
    the fused kernel path on its local chunk. N must divide by the total shard
    count.

    SDE counter-RNG streams are GLOBAL: each shard's `lane_offset` (its first
    trajectory's global index) is threaded into the local solve, so shard k
    draws the (seed; step, row, k*n_local + i) stream — sharded and local
    solves produce bitwise-identical trajectories, and distinct shards never
    replay each other's noise.

    Dataset tables (``prob.data``) are BROADCAST, never sharded: every shard
    receives the full table set as replicated shard_map inputs (in_specs=P())
    and solves its trajectory chunk against the identical dataset, so
    sharded == local holds for data-driven problems too — and gradients
    w.r.t. table values flow through the shard_map (each shard contributes
    its trajectories' table cotangents; a mean-reducing loss psums them in
    its own backward pass).

    Gradients compose with sharding: pass ``sensitivity="adjoint"`` (plus
    ``adjoint_steps`` for adaptive stepping — see `solve_ensemble_local`) and
    `jax.grad` of a scalar loss over the sharded result differentiates
    through the shard_map — each shard runs its local checkpointed adjoint
    over its own trajectories (states need no collectives; zero-collective
    property preserved), and the transposes of the stats psums are the only
    cross-shard traffic in the backward pass.  Per-shard gradient
    contributions are assembled on the same trajectory sharding as (u0s, ps);
    a loss that mean-reduces over trajectories psums gradient accumulators
    exactly once, in ITS backward pass.
    """
    if mesh is None:
        return solve_ensemble_local(eprob, **kw)

    axes = _ensemble_axes(mesh, shard_axes)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    u0s, ps = eprob.materialize()
    N = u0s.shape[0]
    assert N % nshards == 0, (
        f"trajectories {N} must divide over {nshards} shards")
    n_local = N // nshards
    prob = eprob.prob
    spec = P(axes)
    base_offset = kw.pop("lane_offset", 0)

    # Dataset tables are BROADCAST, not sharded: every shard solves against
    # the identical dataset, so the leaves enter shard_map as explicit
    # replicated inputs (in_specs=P()) and the problem is rebuilt per shard.
    # Explicit — rather than closure-captured — so sharded == local holds by
    # construction AND `jax.grad` w.r.t. table values differentiates through
    # the shard_map (closure-captured tracers would be rejected).
    data = getattr(prob, "data", None)
    dleaves, dtreedef = data_flatten(data)

    def _shard_prob(dlv):
        if data is None:
            return prob
        return dataclasses.replace(
            prob, data=data_unflatten(dtreedef, dlv))

    if kw.get("ensemble") == "auto":
        # resolve BEFORE shard_map: timing cannot run under tracing, and all
        # shards must dispatch one program.  Tune once per host on a
        # local-shard-sized slice (each device solves n_local trajectories,
        # so that is the N whose crossover matters), broadcast host 0's
        # winner, and hand every shard the explicit choice.
        from .autotune import broadcast_decision, resolve_auto
        from .methods import get_method
        u0_loc, ps_loc = u0s[:n_local], ps[:n_local]
        sub = EnsembleProblem(prob, n_local, u0s=u0_loc, ps=ps_loc)
        tune_args = ("t0", "tf", "dt0", "saveat", "rtol", "atol", "adaptive",
                     "n_steps", "save_every", "max_iters", "event", "key",
                     "seed", "noise_table", "error_est", "w_reuse",
                     "linsolve", "sensitivity")
        tune_kw = {k: v for k, v in kw.items() if k in tune_args}
        dec = broadcast_decision(
            resolve_auto(sub, get_method(kw.get("alg", "tsit5")), **tune_kw))
        kw = dict(kw, ensemble=dec.strategy, backend=dec.backend)
        if kw.get("lane_tile") is None:
            kw["lane_tile"] = dec.lane_tile

    # step counters are per-trajectory vectors under the kernel strategy but
    # batch scalars under vmap/array — probe the local solve's result ranks
    # (trace only, no compile) so the out_specs match whatever dispatch
    # (explicit or auto-resolved above) actually returns
    shard_shapes = jax.eval_shape(
        lambda u, p, *dlv: solve_ensemble_local(
            EnsembleProblem(_shard_prob(dlv), n_local, u0s=u, ps=p),
            lane_offset=base_offset, **kw),
        jax.ShapeDtypeStruct((n_local,) + u0s.shape[1:], u0s.dtype),
        jax.ShapeDtypeStruct((n_local,) + ps.shape[1:], ps.dtype),
        *dleaves)
    per_traj_counts = shard_shapes.naccept.ndim > 0

    def local(u0c, pc, *dlv):
        # linear shard index in the same axis order the PartitionSpec uses,
        # -> this shard's first global trajectory index
        idx = jnp.asarray(0, jnp.uint32)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a).astype(jnp.uint32)
        sub = EnsembleProblem(_shard_prob(dlv), u0c.shape[0], u0s=u0c, ps=pc)
        res = solve_ensemble_local(sub, lane_offset=base_offset + idx * n_local,
                                   **kw)
        # per-shard scalars -> global via psum (lightweight stats only)
        nf, njac, nfact = res.nf, res.njac, res.nfact
        nacc, nrej = res.naccept, res.nreject
        for a in axes:
            nf = jax.lax.psum(nf, a)
            njac = jax.lax.psum(njac, a)
            nfact = jax.lax.psum(nfact, a)
            if not per_traj_counts:
                nacc = jax.lax.psum(nacc, a)
                nrej = jax.lax.psum(nrej, a)
        return res._replace(nf=nf, njac=njac, nfact=nfact,
                            naccept=nacc, nreject=nrej)

    count_spec = spec if per_traj_counts else P()
    fn = shard_map(local, mesh=mesh,
                   in_specs=(spec, spec) + (P(),) * len(dleaves),
                   out_specs=EnsembleResult(
                       ts=P(), us=spec, u_final=spec, t_final=spec,
                       naccept=count_spec, nreject=count_spec, nf=P(),
                       status=P(), njac=P(), nfact=P()),
                   check_rep=False)
    if kw.get("sensitivity") is not None:
        # the bounded adjoint loop wraps segments in jax.checkpoint, which
        # lowers to closed_call — shard_map cannot evaluate that eagerly
        # ("Eager evaluation of closed_call inside a shard_map isn't yet
        # supported"), so stage the whole sharded solve through jit; under an
        # outer jit/grad this inlines and changes nothing
        fn = jax.jit(fn)
    return fn(u0s, ps, *dleaves)


def solve_ensemble_elastic(eprob: EnsembleProblem, alg="tsit5", *,
                           ckpt_dir: str, n_shards: int = 2,
                           resume: bool = False, chaos=None, **kw):
    """Fault-tolerant segmented ensemble solve — the elastic face of the
    front door.

    Wraps `repro.dist.elastic.ElasticSupervisor`: the run advances in
    bounded segments with periodic host-gathered carry snapshots through
    the atomic checkpoint layer, survives shard loss by re-sharding the
    unfinished tiles over the survivors (degradation ladder down to a
    single host, then a partial result with per-lane
    ``status == STATUS_SHARD_LOST``), and ``resume=True`` restores the
    newest snapshot — onto ANY `n_shards`, in the same process or a
    relaunched one.  A killed-and-resumed run is bitwise identical to an
    uninterrupted one (see the module docstring for the contract, and
    tests/test_elastic.py for the SIGKILL proof).

    Returns `repro.dist.elastic.ElasticResult` (host numpy per-lane finals
    + a fault-history report), not a device `EnsembleResult` — elasticity
    is a host-side supervision loop by construction.

    Keyword args beyond the supervisor's (tile_width, segment_steps,
    snapshot_every, max_failures, backoff_*, ...) mirror
    `solve_ensemble_local` (t0, tf, dt0, n_steps, adaptive, rtol, atol,
    event, seed, lane_offset, max_iters, ...).
    """
    from repro.dist.elastic import ElasticSupervisor
    sup = ElasticSupervisor(eprob, alg, ckpt_dir=ckpt_dir,
                            n_shards=n_shards, chaos=chaos, **kw)
    return sup.run(resume=resume)


def ensemble_moments(us: Array, mesh: Optional[Mesh] = None,
                     shard_axes: Optional[Sequence[str]] = None):
    """Mean/variance over the (possibly sharded) trajectory axis — the SDE
    Monte-Carlo reduction (§6.8). us: (N, ...) sharded on axis 0.

    Variance uses the centered two-pass form (psum the mean first, then psum
    the squared deviations): the textbook one-pass ``E[X²] − mean²`` loses
    ~2·log10(mean/std) digits to catastrophic cancellation — in f32 a GBM
    ensemble at drift 1.5 over a unit horizon (mean ≈ 4.5, std ≈ 0.05) has
    NO correct digits left and can even come back negative.  The clamp at 0
    guards the residual rounding of the centered sum."""
    if mesh is None:
        return jnp.mean(us, axis=0), jnp.maximum(jnp.var(us, axis=0), 0)

    axes = _ensemble_axes(mesh, shard_axes)
    spec = P(axes)

    def local(u):
        n_local = u.shape[0]
        s1 = jnp.sum(u, axis=0)
        n = jnp.asarray(n_local, u.dtype)
        for a in axes:
            s1 = jax.lax.psum(s1, a)
            n = jax.lax.psum(n, a)
        mean = s1 / n
        d = u - mean[None]
        s2c = jnp.sum(d * d, axis=0)
        for a in axes:
            s2c = jax.lax.psum(s2c, a)
        var = jnp.maximum(s2c / n, 0)
        return mean, var

    fn = shard_map(local, mesh=mesh, in_specs=(spec,),
                   out_specs=(P(), P()), check_rep=False)
    return fn(us)
