"""Autotuned dispatch: pick strategy/backend/lane_tile from measured time.

The paper's Fig. 4-6 crossovers (kernel overtakes array overtakes vmap as N
grows) move with method, state dim n, ensemble size N, dtype and device —
after PRs 1-5 the user had to hand-pick among 3 strategies x 2 backends x
`lane_tile` x `w_reuse` x `error_est`.  ``ensemble="auto"`` closes that gap:

  1. The solve's *configuration key* — ``(method, n, N-bucket, dtype,
     adaptive, events, w_reuse, error_est, device_kind)`` — is looked up in
     an in-memory + JSON profile cache (`default_cache_path`; see below).
  2. On a miss, a capability-pruned candidate set
     (`repro.core.methods.valid_dispatch`; vmap/array/kernel x xla/pallas x
     the `lane_tile` ladder from the §5.2 VMEM formula) is *timed on the
     real problem* at reduced N and a short horizon — median-of-k wall time
     with `block_until_ready` (`measure`, the same harness
     `benchmarks/common.py` re-exports, so tuner and paper figures share one
     methodology).
  3. The winner is persisted, so every later call — any process, including
     each host of a mesh-sharded `repro.core.api.solve_ensemble` —
     dispatches straight to it with one dict lookup of overhead.

Cache location: ``~/.cache/repro/autotune.json`` (respects
``XDG_CACHE_HOME``), overridable via ``REPRO_AUTOTUNE_CACHE`` or the
``cache_path=`` argument.  Entries are invalidated by construction when the
device changes (``device_kind`` is part of the key) and at lookup when the
recorded jax version differs.  ``REPRO_AUTOTUNE=0`` disables timing
entirely (CI / ``--dry`` runs): ``"auto"`` then falls back to the static
default (kernel/xla), as it also does under jit tracing, where wall time
cannot be measured — tune once eagerly and the cached winner is dispatched
even from inside jit, since the key is built from static shape/dtype data
only.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
import time
from typing import Any, Dict, Optional, Tuple

try:
    import fcntl
except ImportError:          # non-POSIX: single-process semantics only
    fcntl = None

import jax
import jax.numpy as jnp
import numpy as np

from .interp import data_signature, data_words
from .methods import MethodSpec, valid_dispatch
from .problem import EnsembleProblem

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
DISABLE_ENV = "REPRO_AUTOTUNE"
CACHE_VERSION = 1

# tuning cost knobs (env-overridable; see docs/architecture.md)
TUNE_MAX_N = int(os.environ.get("REPRO_AUTOTUNE_MAX_N", "4096"))
TUNE_REPEATS = int(os.environ.get("REPRO_AUTOTUNE_REPEATS", "3"))
TUNE_HORIZON_FRAC = float(os.environ.get("REPRO_AUTOTUNE_HORIZON", "0.25"))

DEFAULT_STRATEGY = ("kernel", "xla", None)   # the front door's static default


# ---------------------------------------------------------------------------
# timing harness — shared with benchmarks/common.py
# ---------------------------------------------------------------------------

def measure(fn, *args, repeats: int = 3, **kw) -> Dict[str, Any]:
    """Median-of-k wall timing with compile/warmup excluded.

    One untimed warmup call absorbs tracing + compilation; each timed repeat
    calls `jax.block_until_ready` on the result BEFORE the clock stops, so
    async dispatch cannot flatter the number.  Returns
    ``{"best", "median", "times"}`` in seconds — rank candidates by
    ``median`` (robust to scheduler noise), report ``best`` as the
    machine-capability figure.
    """
    jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(max(1, repeats)):
        tic = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - tic)
    times.sort()
    return {"best": times[0], "median": times[len(times) // 2],
            "times": times}


# ---------------------------------------------------------------------------
# configuration key
# ---------------------------------------------------------------------------

def device_kind() -> str:
    d = jax.devices()[0]
    return f"{d.platform}:{getattr(d, 'device_kind', '?')}".replace(" ", "_")


def bucket_n(N: int) -> int:
    """Next power of two — nearby ensemble sizes share one cache entry."""
    b = 1
    while b < N:
        b *= 2
    return b


def resolved_flags(spec: MethodSpec, prob, *, adaptive, w_reuse, error_est,
                   event) -> Tuple[bool, bool, bool, str]:
    """Normalize the front door's None-means-family-default knobs to the
    concrete values dispatch will run with — the key must not split on
    spellings of the same configuration."""
    if spec.family == "rosenbrock":
        ad = True                      # the stiff engine is always adaptive
    elif adaptive is None:
        ad = spec.family == "erk" and spec.adaptive
    else:
        ad = bool(adaptive) and spec.adaptive
    wr = spec.w_reuse if w_reuse is None else bool(w_reuse)
    ee = "none"
    if spec.family == "sde" and ad:
        if error_est is not None:
            ee = str(error_est)
        else:
            diag = getattr(prob, "noise", None) == "diagonal"
            ee = ("embedded" if ("embedded" in spec.error_est and diag)
                  else "doubling")
    return ad, event is not None, wr, ee


def config_key(spec: MethodSpec, *, n: int, N: int, dtype, adaptive: bool,
               events: bool, w_reuse: bool, error_est: str,
               device: Optional[str] = None,
               sensitivity: Optional[str] = None,
               data_sig: str = "none") -> str:
    """Deterministic cache key — a readable ``k=v|...`` string (field order
    fixed), hashable across processes and debuggable in the JSON by eye.
    ``data_sig`` is the dataset-shape signature
    (`repro.core.interp.data_signature`): VMEM-resident tables shift the
    kernel crossovers (and the auto lane_tile), so a data-driven solve must
    not reuse the data-free profile of the same method."""
    return "|".join((
        f"method={spec.name}",
        f"n={int(n)}",
        f"N={bucket_n(int(N))}",
        f"dtype={jnp.dtype(dtype).name}",
        f"adaptive={bool(adaptive)}",
        f"events={bool(events)}",
        f"w_reuse={bool(w_reuse)}",
        f"error_est={error_est}",
        f"sens={sensitivity or 'none'}",
        f"data={data_sig}",
        f"device={device_kind() if device is None else device}"))


# ---------------------------------------------------------------------------
# profile cache (JSON file + in-memory layer)
# ---------------------------------------------------------------------------

_MEM: Dict[str, Dict[str, Any]] = {}   # cache-file path -> entries
_MEM_LOCK = threading.Lock()           # concurrent tuners (serve pool pumps)


def default_cache_path() -> str:
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME", os.path.expanduser("~/.cache"))
    return os.path.join(base, "repro", "autotune.json")


def clear_memory_cache() -> None:
    """Drop the in-process cache layer (tests; the JSON file is untouched)."""
    with _MEM_LOCK:
        _MEM.clear()


def _read_file_entries(path: str) -> Dict[str, Any]:
    """Entries as currently on disk — never consults the in-memory layer."""
    entries: Dict[str, Any] = {}
    try:
        with open(path) as fh:
            data = json.load(fh)
        if isinstance(data, dict) and data.get("version") == CACHE_VERSION:
            entries = dict(data.get("entries", {}))
    except (OSError, ValueError):
        pass
    return entries


def _load_entries(path: str) -> Dict[str, Any]:
    with _MEM_LOCK:
        if path in _MEM:
            return _MEM[path]
    entries = _read_file_entries(path)
    with _MEM_LOCK:
        return _MEM.setdefault(path, entries)


def _save_entries(path: str, entries: Dict[str, Any]) -> None:
    """Persist `entries`, MERGING with concurrent writers.

    Two processes tuning different configs race on the JSON file: each did
    load -> add-own-key -> replace, and the last replace silently dropped the
    other's entry (a classic lost update).  The critical section below holds
    an `fcntl.flock` on a sidecar lock file while it re-reads the file,
    unions the disk entries under ours (our fresher timings win ties), and
    atomically replaces — so every writer's keys survive every interleaving.
    The merged view also refreshes the in-memory layer.
    """
    merged = dict(entries)
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        lock_fh = open(path + ".lock", "a+") if fcntl is not None else None
    except OSError:
        lock_fh = None
    try:
        if lock_fh is not None:
            try:
                fcntl.flock(lock_fh.fileno(), fcntl.LOCK_EX)
            except OSError:
                pass
        disk = _read_file_entries(path)
        merged = {**disk, **entries}
        payload = {"version": CACHE_VERSION, "entries": merged}
        try:
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                       suffix=".tmp")
            with os.fdopen(fd, "w") as fh:
                json.dump(payload, fh, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except OSError:
            pass   # read-only FS etc: the in-memory layer still serves us
    finally:
        if lock_fh is not None:
            lock_fh.close()          # releases the flock
    with _MEM_LOCK:
        _MEM[path] = merged


# ---------------------------------------------------------------------------
# candidates
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Candidate:
    strategy: str
    backend: str
    lane_tile: Optional[int]

    @property
    def label(self) -> str:
        t = "" if self.lane_tile is None else f"/t{self.lane_tile}"
        return f"{self.strategy}/{self.backend}{t}"


@dataclasses.dataclass(frozen=True)
class Decision:
    """What ``ensemble="auto"`` resolved to, and why.

    source: "cache" (profile-cache hit), "tuned" (measured this call),
    "default" (timing unavailable/disabled — static kernel/xla fallback),
    or "only" (capability pruning left a single candidate: nothing to time).
    """
    strategy: str
    backend: str
    lane_tile: Optional[int]
    source: str
    key: str = ""
    timings: Tuple[Tuple[str, float], ...] = ()


def _family_work_words(spec: MethodSpec, prob, n: int, m: int,
                       w_reuse: bool) -> int:
    from repro.kernels.ensemble_kernel import (erk_work_words,
                                               rosenbrock_work_words,
                                               sde_work_words)
    if spec.family == "erk":
        return erk_work_words(n, m, spec.tableau.stages)
    if spec.family == "rosenbrock":
        return rosenbrock_work_words(n, m, stages=spec.rtableau.stages,
                                     w_reuse=w_reuse)
    return sde_work_words(n, m, prob.noise_dim())


def candidates(spec: MethodSpec, *, n: int, m: int, n_save: int, N: int,
               dtype, adaptive: bool, events: bool, w_reuse: bool,
               error_est: str, allow_pallas: bool = True, sensitivity=None,
               data: bool = False, data_words: int = 0):
    """Capability-pruned candidate list: every entry would be accepted by
    `solve_ensemble_local` (never time a combination that raises).
    ``array_eager`` is never a candidate — it exists to *reproduce* dispatch
    overhead, not to win.  ``sensitivity`` prunes combinations the AD rules
    reject (e.g. forward-mode on the Pallas backend).  ``data``/``data_words``
    describe the problem's dataset tables: the flag prunes methods that
    declare ``data_rhs=False``, and the word count is charged to the §5.2
    VMEM budget as a fixed (per-tile, not per-lane) footprint so the
    lane_tile ladder stays honest for data-driven kernels."""
    ee = error_est if error_est != "none" else None
    out = []

    def ok(strategy, backend):
        valid, _ = valid_dispatch(spec, strategy, backend, adaptive=adaptive,
                                  events=events, w_reuse=w_reuse,
                                  error_est=ee, sensitivity=sensitivity,
                                  data=data)
        return valid

    for strategy in ("vmap", "array"):
        if ok(strategy, "xla"):
            out.append(Candidate(strategy, "xla", None))
    if ok("kernel", "xla"):
        from repro.kernels.ensemble_kernel import lane_tile_ladder
        ladder = lane_tile_ladder(
            n, m, max(1, n_save), itemsize=jnp.dtype(dtype).itemsize,
            work_words=_family_work_words(spec, None, n, m, w_reuse)
            if spec.family != "sde" else None, N=N,
            fixed_words=data_words)
        for backend in ("xla", "pallas"):
            if backend == "pallas" and (not allow_pallas
                                        or not ok("kernel", "pallas")):
                continue
            for tile in ladder:
                out.append(Candidate("kernel", backend, int(tile)))
    return out


# ---------------------------------------------------------------------------
# resolve
# ---------------------------------------------------------------------------

def _disabled() -> bool:
    return os.environ.get(DISABLE_ENV, "1").lower() in ("0", "off", "false",
                                                        "disabled")


def _is_traced(*vals) -> bool:
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree_util.tree_leaves(vals))


def _tuning_slice(u0s, ps, N: int):
    """Evenly-strided subsample of the real ensemble (parameter sweeps are
    usually ordered; a head slice would tune on an unrepresentative corner)."""
    full = u0s.shape[0]
    if N >= full:
        return u0s, ps
    idx = np.linspace(0, full - 1, N).round().astype(int)
    return u0s[idx], ps[idx]


def resolve_auto(eprob: EnsembleProblem, spec: MethodSpec, *, t0=None,
                 tf=None, dt0=1e-2, saveat=None, rtol=1e-6, atol=1e-6,
                 adaptive=None, n_steps=None, save_every=1, max_iters=100_000,
                 event=None, key=None, seed=None, noise_table=None,
                 error_est=None, w_reuse=None, linsolve="jnp",
                 sensitivity=None, cache_path: Optional[str] = None,
                 repeats: Optional[int] = None) -> Decision:
    """Resolve ``ensemble="auto"`` to a concrete (strategy, backend,
    lane_tile) `Decision` — cache hit, fresh micro-benchmark, or static
    fallback.  Accepts the front door's kwargs verbatim; see the module
    docstring for the mechanism and `solve_ensemble_local` for wiring."""
    prob = eprob.prob
    u0s, ps = eprob.materialize()
    t0 = prob.tspan[0] if t0 is None else t0
    tf = prob.tspan[1] if tf is None else tf
    N, n = u0s.shape
    m = ps.shape[1]
    ad, ev, wr, ee = resolved_flags(spec, prob, adaptive=adaptive,
                                    w_reuse=w_reuse, error_est=error_est,
                                    event=event)
    pdata = getattr(prob, "data", None)
    ckey = config_key(spec, n=n, N=N, dtype=u0s.dtype, adaptive=ad,
                      events=ev, w_reuse=wr, error_est=ee,
                      sensitivity=sensitivity,
                      data_sig=data_signature(pdata))
    path = cache_path or default_cache_path()

    # 1. cache (works under jit too: the key is static shape/dtype data).
    # A cached winner may predate an AD request — re-check it against the
    # sensitivity rules and fall through to a constrained re-tune if the
    # cached combination would be rejected by the front door.
    entries = _load_entries(path)
    hit = entries.get(ckey)
    if hit is not None and hit.get("jax") == jax.__version__:
        sens_ok, _ = valid_dispatch(spec, hit["strategy"], hit["backend"],
                                    adaptive=ad, events=ev, w_reuse=wr,
                                    error_est=ee if ee != "none" else None,
                                    sensitivity=sensitivity)
        if sens_ok:
            return Decision(hit["strategy"], hit["backend"], hit["lane_tile"],
                            source="cache", key=ckey)

    # 2. timing unavailable -> static default
    if (_disabled() or dt0 is None
            or _is_traced(u0s, ps, t0, tf, dt0, saveat, seed, key, pdata)):
        return Decision(*DEFAULT_STRATEGY, source="default", key=ckey)

    # 3. candidate set (capability-pruned)
    S_real = (int(np.asarray(saveat).shape[0]) if saveat is not None
              else max(1, (n_steps or 1) // max(1, save_every)))
    try:
        concrete_seed = 0 if seed is None and key is None else int(
            jnp.asarray(key)[-1] if seed is None else seed)
        allow_pallas = True
    except (TypeError, jax.errors.TracerIntegerConversionError,
            jax.errors.ConcretizationTypeError):
        concrete_seed, allow_pallas = 0, spec.family != "sde"
    cands = candidates(spec, n=n, m=m, n_save=S_real, N=min(N, TUNE_MAX_N),
                       dtype=u0s.dtype, adaptive=ad, events=ev, w_reuse=wr,
                       error_est=ee, allow_pallas=allow_pallas,
                       sensitivity=sensitivity, data=pdata is not None,
                       data_words=data_words(pdata))
    if not cands:
        return Decision(*DEFAULT_STRATEGY, source="default", key=ckey)
    if len(cands) == 1:
        c = cands[0]
        return Decision(c.strategy, c.backend, c.lane_tile, source="only",
                        key=ckey)

    # 4. reduced problem: real RHS/params, subsampled N, short horizon
    N_t = min(N, TUNE_MAX_N)
    u0s_t, ps_t = _tuning_slice(u0s, ps, N_t)
    sub = EnsembleProblem(prob, N_t, u0s=u0s_t, ps=ps_t)
    span = float(tf) - float(t0)
    fixed_dt = ((spec.family == "sde" and not ad)
                or (spec.family == "erk" and not ad))
    tune_kw = dict(t0=t0, rtol=rtol, atol=atol, adaptive=adaptive,
                   max_iters=min(max_iters, 20_000), event=event,
                   seed=concrete_seed, error_est=error_est, w_reuse=w_reuse,
                   linsolve=linsolve)
    if fixed_dt:
        ns_full = n_steps if n_steps is not None else max(
            1, int(round(span / float(dt0))))
        ns = max(1, int(round(ns_full * TUNE_HORIZON_FRAC)))
        tune_kw.update(dt0=dt0, n_steps=ns, save_every=ns, saveat=None,
                       tf=float(t0) + ns * float(dt0))
    else:
        tf_t = float(t0) + max(span * TUNE_HORIZON_FRAC,
                               min(span, 16.0 * float(dt0)))
        tune_kw.update(dt0=dt0, saveat=None, tf=tf_t, n_steps=None)

    # 5. time everything; median-of-k, block_until_ready inside the clock
    from .ensemble import solve_ensemble_local
    k = TUNE_REPEATS if repeats is None else repeats
    timings = []
    for c in cands:
        def run(u0s_, ps_, _c=c):
            ep = EnsembleProblem(prob, u0s_.shape[0], u0s=u0s_, ps=ps_)
            return solve_ensemble_local(ep, alg=spec, ensemble=_c.strategy,
                                        backend=_c.backend,
                                        lane_tile=_c.lane_tile,
                                        **tune_kw).u_final
        try:
            stat = measure(jax.jit(run), u0s_t, ps_t, repeats=k)
        except Exception:   # a candidate that fails to run can never win
            continue
        timings.append((c, stat["median"]))
    if not timings:
        return Decision(*DEFAULT_STRATEGY, source="default", key=ckey)
    winner, _ = min(timings, key=lambda ct: ct[1])

    # 6. persist
    entry = {"strategy": winner.strategy, "backend": winner.backend,
             "lane_tile": winner.lane_tile, "jax": jax.__version__,
             "tuned_at_N": int(N_t),
             "timings": {c.label: t for c, t in timings}}
    entries = dict(_load_entries(path))
    entries[ckey] = entry
    _save_entries(path, entries)
    return Decision(winner.strategy, winner.backend, winner.lane_tile,
                    source="tuned", key=ckey,
                    timings=tuple((c.label, t) for c, t in timings))


def broadcast_decision(dec: Decision) -> Decision:
    """Multi-host agreement: host 0's decision wins everywhere.  A sharded
    solve must dispatch identically on every host (shard_map traces one
    program); timing jitter could otherwise split the fleet.  Single-process
    runs return the decision unchanged."""
    if jax.process_count() == 1:
        return dec
    try:
        from jax.experimental import multihost_utils
        from .methods import BACKENDS, STRATEGIES
        payload = jnp.asarray([STRATEGIES.index(dec.strategy),
                               BACKENDS.index(dec.backend),
                               -1 if dec.lane_tile is None
                               else int(dec.lane_tile)], jnp.int32)
        got = np.asarray(multihost_utils.broadcast_one_to_all(payload))
        return Decision(STRATEGIES[int(got[0])], BACKENDS[int(got[1])],
                        None if int(got[2]) < 0 else int(got[2]),
                        source=dec.source, key=dec.key)
    except Exception:
        return dec
