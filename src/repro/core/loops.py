"""Bounded, checkpointed solver loops — the reverse-mode AD substrate.

`jax.lax.while_loop` is the right forward-mode shape for adaptive stepping
(it supports jvp, so forward sensitivities work out of the box) but it has no
transpose rule: reverse-mode AD cannot cross it.  Every adaptive engine body
in this repo is written so that a finished lane's iteration is an exact no-op
(all writes are masked by ``accept``/``active``), which buys the classic
substitution: run the SAME body for a fixed, static number of iterations and
the outputs are bitwise-identical to the while loop whenever the bound covers
the true iteration count — and a too-small bound surfaces as ``status == 1``
(max-iters semantics), never as silent wrong answers.

`solver_loop` is that substitution: with ``bounded_steps=None`` it IS
``lax.while_loop`` (the forward hot path, untouched); with an integer bound it
becomes a ``lax.scan`` over `jax.checkpoint`-wrapped segments of
``checkpoint_every`` body applications.  The scan is reverse-differentiable,
and the remat segments are the "periodic carry checkpoints" of the
checkpointed discrete adjoint: the forward pass stores one full carry
(u, t, dt, RNG counters, J/LU freshness — whatever the engine carries) per
segment boundary instead of per step, and the reverse pass recomputes each
segment from its checkpoint, so peak memory is
O(n_segments * carry + checkpoint_every * step_residuals) instead of
O(bounded_steps * step_residuals).

`checkpointed_fori` is the fixed-step sibling for ``fori_loop``-shaped paths
(the SDE reference kernel, the vmap fixed-dt SDE path).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Carry = Any


def default_checkpoint_every(bounded_steps: int) -> int:
    """sqrt-schedule: balances stored carries against recompute residuals."""
    return max(1, math.isqrt(max(1, int(bounded_steps))))


def solver_loop(cond: Callable[[Carry], Any], body: Callable[[Carry], Carry],
                carry0: Carry, *, bounded_steps: Optional[int] = None,
                checkpoint_every: Optional[int] = None) -> Carry:
    """while_loop, or its bounded reverse-differentiable substitute.

    bounded_steps=None  -> ``jax.lax.while_loop(cond, body, carry0)`` exactly.
    bounded_steps=K     -> ceil(K / checkpoint_every) scanned segments of
                           ``checkpoint_every`` unconditional body applications
                           (``cond`` is not consulted; at least K total).

    Contract on ``body`` (all engines in this repo satisfy it): an application
    on a carry whose lanes are all done must leave every observable output
    unchanged — then the bounded form is bitwise-equal to the while form
    whenever K covers the true iteration count, and K too small reproduces the
    max-iters outcome (lanes still marked not-done; engines report it as
    ``status == 1``).
    """
    if bounded_steps is None:
        return jax.lax.while_loop(cond, body, carry0)
    bounded = int(bounded_steps)
    if bounded <= 0:
        raise ValueError(f"bounded_steps must be positive, got {bounded}")
    every = (default_checkpoint_every(bounded) if checkpoint_every is None
             else max(1, int(checkpoint_every)))
    every = min(every, bounded)
    n_seg = -(-bounded // every)

    @jax.checkpoint
    def segment(c):
        return jax.lax.fori_loop(0, every, lambda _i, cc: body(cc), c)

    out, _ = jax.lax.scan(lambda c, _: (segment(c), None), carry0, None,
                          length=n_seg)
    return out


def checkpointed_fori(lower: int, upper: int, body: Callable[[Any, Carry], Carry],
                      init: Carry, *,
                      checkpoint_every: Optional[int] = None) -> Carry:
    """``fori_loop(lower, upper, body, init)`` with periodic remat checkpoints.

    Runs the identical body sequence (same indices, same order), so the primal
    is bitwise-equal to the plain fori_loop; reverse-mode AD stores one carry
    per segment and recomputes inside segments.  Static bounds required.
    """
    lower, upper = int(lower), int(upper)
    n = upper - lower
    if n <= 0:
        return init
    every = (default_checkpoint_every(n) if checkpoint_every is None
             else max(1, int(checkpoint_every)))
    every = min(every, n)
    n_seg, rem = divmod(n, every)

    @jax.checkpoint
    def segment(c, start):
        return jax.lax.fori_loop(0, every,
                                 lambda j, cc: body(start + j, cc), c)

    if n_seg:
        starts = lower + every * jnp.arange(n_seg)
        init, _ = jax.lax.scan(lambda c, s: (segment(c, s), None), init,
                               starts)
    if rem:
        tail = jax.checkpoint(
            lambda c: jax.lax.fori_loop(upper - rem, upper, body, c))
        init = tail(init)
    return init
