"""Problem definitions: the user-facing, solver-agnostic description of a DE.

Mirrors the paper's use of DifferentialEquations.jl `ODEProblem` / `SDEProblem`:
the user writes ``f(u, p, t)`` once, in plain ``jnp`` *component style* (index
``u[0], u[1], ...`` and combine with ``jnp.stack``).  The same definition is then
consumed unchanged by every execution strategy — per-trajectory (`solve_one`),
array-ensemble, vmap-ensemble, the fused-XLA lanes path and the Pallas TPU kernel —
because component style broadcasts identically over ``u: (n,)`` and ``u: (n, B)``.
This is the JAX analogue of the paper's "automated translation": no user code
changes between CPU, vmap and kernel execution.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax.numpy as jnp

Array = Any


@dataclasses.dataclass(frozen=True)
class ODEProblem:
    """du/dt = f(u, p, t) on t ∈ tspan, u(t0) = u0.

    f: component-style RHS, shape-polymorphic over trailing lane dims.
    u0: (n,) initial condition template.
    p:  (m,) parameter template.
    jac: optional analytic Jacobian ∂f/∂u, component-style like f: returns
        (n, n) for u (n,) and broadcasts to (n, n, B) for u (n, B) (build
        rows with jnp.stack exactly as in f).  Consumed by the stiff
        (Rosenbrock) engines on every strategy/backend; None means the
        solvers fall back to forward-mode AD (jacfwd) — the "automated
        translation" default where users never write Jacobians.
    data: optional dataset pytree (any nest of `repro.core.interp`
        UniformTable1D / UniformTable2D — the paper's texture-memory
        workloads: dosing schedules, forcing curves, market data).  When
        set, the callback contract grows a fourth argument: ``f(u, p, t,
        data)`` (and likewise ``jac``, and ``g`` on SDEProblem), with the
        dataset identical for every trajectory — tables are BROADCAST
        across lanes and shards, never sharded.  The dispatch layers pass
        the table values as real arguments (VMEM-resident BlockSpecs in
        the Pallas kernels, replicated shard_map inputs on a mesh), so
        `jax.grad` w.r.t. table values works end to end — see
        docs/architecture.md "Data-driven RHS".
    """

    f: Callable[[Array, Array, Array], Array]
    u0: Array
    p: Array
    tspan: Tuple[float, float]
    name: str = "ode"
    jac: Optional[Callable[[Array, Array, Array], Array]] = None
    data: Optional[Any] = None

    @property
    def n_states(self) -> int:
        return int(jnp.shape(self.u0)[0])

    @property
    def n_params(self) -> int:
        return int(jnp.shape(self.p)[0])


@dataclasses.dataclass(frozen=True)
class SDEProblem:
    """dX = f(X,p,t) dt + g(X,p,t) dW.

    noise:
      "diagonal":     g returns (n,)   — one Wiener process per state.
      "general":      g returns (n, m) — m Wiener processes, dense coupling.
    data: as on ODEProblem — when set, f and g take ``(u, p, t, data)``.
    """

    f: Callable[[Array, Array, Array], Array]
    g: Callable[[Array, Array, Array], Array]
    u0: Array
    p: Array
    tspan: Tuple[float, float]
    noise: str = "diagonal"
    n_noise: Optional[int] = None  # m; defaults to n for diagonal
    name: str = "sde"
    data: Optional[Any] = None

    @property
    def n_states(self) -> int:
        return int(jnp.shape(self.u0)[0])

    def noise_dim(self) -> int:
        if self.n_noise is not None:
            return self.n_noise
        return self.n_states


@dataclasses.dataclass(frozen=True)
class EnsembleProblem:
    """N independent copies of `prob`, varying (u0, p) per trajectory.

    u0s: (N, n) or None (broadcast prob.u0)
    ps:  (N, m) or None (broadcast prob.p)

    This is the paper's `EnsembleProblem(prob, prob_func)` after materializing
    the prob_func: we require the varied initial states / parameters as arrays
    up front (JAX-traceable; also what the paper's lower-level API does).
    """

    prob: Any  # ODEProblem | SDEProblem
    n_trajectories: int
    u0s: Optional[Array] = None
    ps: Optional[Array] = None

    def materialize(self):
        N = self.n_trajectories
        u0s = self.u0s
        ps = self.ps
        if u0s is None:
            u0s = jnp.broadcast_to(self.prob.u0, (N,) + jnp.shape(self.prob.u0))
        if ps is None:
            ps = jnp.broadcast_to(self.prob.p, (N,) + jnp.shape(self.prob.p))
        return u0s, ps


def bind_problem_data(prob, data=None):
    """Close the problem's callbacks over its dataset.

    Returns a problem whose f / g / jac are plain 3-argument ``(u, p, t)``
    callables again (``data=None``), with the dataset captured by closure.
    This is how every XLA execution path consumes a data-driven problem: the
    engines (`solvers`/`rosenbrock`/`sde`) never learn about data, and
    closure-captured tracers are fine under jit/vmap/while_loop/grad.  The
    Pallas paths cannot use this (kernel arguments must be explicit
    BlockSpecs, and custom_vjp closures must not capture tracers), so they
    instead pass `data`'s leaves as real kernel arguments and re-bind inside
    the kernel body — see `repro.kernels.ensemble_kernel`.

    `data` overrides `prob.data` when given (the kernel bodies re-bind with
    leaf-rebuilt tables); a problem without data is returned unchanged.
    """
    d = prob.data if data is None else data
    if d is None:
        return prob
    f = prob.f
    rep = {"data": None, "f": lambda u, p, t: f(u, p, t, d)}
    jac = getattr(prob, "jac", None)
    if jac is not None:
        rep["jac"] = lambda u, p, t: jac(u, p, t, d)
    g = getattr(prob, "g", None)
    if g is not None:
        rep["g"] = lambda u, p, t: g(u, p, t, d)
    return dataclasses.replace(prob, **rep)
