"""Butcher tableaus for embedded explicit Runge-Kutta pairs.

The solver engine (`repro.core.solvers`) is tableau-generic: a method is *data*.
We ship the pairs below with exact published coefficients; each is validated by
(a) algebraic order-condition unit tests and (b) empirical convergence-order
tests against closed-form solutions (tests/test_tableaus.py, test_solvers.py).

GPUTsit5 — the solver used in every benchmark figure of the paper — is `TSIT5`.

High-order pairs (the paper's GPUVern7/GPUVern9 roles):

* `VERN7` — Verner's "most efficient" 7(6) pair (10 stages).  The
  coefficients were recovered offline by Gauss–Newton projection of
  published-value data onto the order-condition manifold (c pinned at
  Verner's exact nodes) and are VERIFIED, not trusted: all 85 rooted-tree
  conditions through order 7 hold to ~4e-15 and the embedded weights satisfy
  order 6 (`repro.core.order_conditions`, exercised by tests/test_tableaus).
* `GBS10` — a 10(8) pair from Gragg–Bulirsch–Stoer midpoint extrapolation
  (sequence 2,4,6,8,10; 26 stages), CONSTRUCTED here from exact rational
  arithmetic, so its provenance is the code below rather than a constant
  table.  It fills the GPUVern9 high-order slot: Verner's 9(8) constants
  could not be verified offline, and this repo does not ship solver
  coefficients it cannot check (the order-condition suite would accept any
  future drop-in `Tableau` for the true Vern9 data).
"""
from __future__ import annotations

from fractions import Fraction
from typing import Callable, NamedTuple, Optional

import numpy as np


class Tableau(NamedTuple):
    name: str
    a: np.ndarray        # (s, s) strictly lower triangular
    b: np.ndarray        # (s,)  high-order weights
    btilde: np.ndarray   # (s,)  b - bhat  (error-estimate weights)
    c: np.ndarray        # (s,)  abscissae
    order: int           # order of the propagated solution
    embedded_order: int
    fsal: bool           # first-same-as-last: k[s-1] of step n == k[0] of step n+1
    # optional dense-output polynomial: theta -> (s,) weights; None => Hermite cubic
    interp_bpoly: Optional[Callable] = None

    @property
    def stages(self) -> int:
        return len(self.b)


def _tab(name, a_rows, b, bhat=None, btilde=None, c=None, order=0,
         embedded_order=0, fsal=False, interp_bpoly=None) -> Tableau:
    s = len(b)
    a = np.zeros((s, s), dtype=np.float64)
    for i, row in enumerate(a_rows):
        a[i + 1, : len(row)] = row
    b = np.asarray(b, dtype=np.float64)
    if btilde is None:
        btilde = b - np.asarray(bhat, dtype=np.float64)
    else:
        btilde = np.asarray(btilde, dtype=np.float64)
    if c is None:
        c = a.sum(axis=1)
    return Tableau(name, a, b, btilde, np.asarray(c, np.float64), order,
                   embedded_order, fsal, interp_bpoly)


# ----------------------------------------------------------------------------
# Tsitouras 5(4) — [Tsitouras 2011], coefficients as in OrdinaryDiffEq.jl.
# FSAL; 7 stages (6 effective); free 4th-order interpolant.
# ----------------------------------------------------------------------------
_TSIT5_A = [
    [0.161],
    [-0.008480655492356989, 0.335480655492357],
    [2.8971530571054935, -6.359448489975075, 4.3622954328695815],
    [5.325864828439257, -11.748883564062828, 7.4955393428898365,
     -0.09249506636175525],
    [5.86145544294642, -12.92096931784711, 8.159367898576159,
     -0.071584973281401006, -0.028269050394068383],
    [0.09646076681806523, 0.01, 0.4798896504144996, 1.379008574103742,
     -3.290069515436081, 2.324710524099774],
]
_TSIT5_B = [0.09646076681806523, 0.01, 0.4798896504144996, 1.379008574103742,
            -3.290069515436081, 2.324710524099774, 0.0]
# btilde = b - bhat (4th-order embedded), OrdinaryDiffEq.jl convention:
# error = dt * sum(btilde_i * k_i)
_TSIT5_BTILDE = [-0.00178001105222577714, -0.0008164344596567469,
                 0.007880878010261995, -0.1447110071732629,
                 0.5823571654525552, -0.45808210592918697,
                 0.015151515151515152]
_TSIT5_C = [0.0, 0.161, 0.327, 0.9, 0.9800255409045097, 1.0, 1.0]


def _tsit5_bpoly(theta):
    """Tsitouras free 4th-order interpolant: theta in [0,1] -> stage weights (7,).

    b_i(theta) polynomials from Tsitouras (2011) / OrdinaryDiffEq.jl Tsit5
    ConstantCache interpolation.  u(t+theta*h) = u + h * sum_i b_i(theta) k_i.
    Works on scalar or batched theta (trailing dims broadcast).
    """
    import jax.numpy as jnp
    t = theta
    b1 = -1.0530884977290216 * t * (t - 1.3299890189751412) * (
        t * t - 1.4364028541716351 * t + 0.7139816917074209)
    b2 = 0.1017 * t * t * (t * t - 2.1966568338249754 * t + 1.2949852507374631)
    b3 = 2.490627285651252793 * t * t * (
        t * t - 2.38535645472061657 * t + 1.57803468208092486)
    b4 = -16.54810288924490272 * (t - 1.21712927295533244) * (
        t - 0.61620406037800089) * t * t
    b5 = 47.37952196281928122 * (t - 1.203071208372362603) * (
        t - 0.658047292653547382) * t * t
    b6 = -34.87065786149660974 * (t - 1.2) * (t - 2.0 / 3.0) * t * t
    b7 = 2.5 * (t - 1.0) * (t - 0.6) * t * t
    return jnp.stack([b1, b2, b3, b4, b5, b6, b7])


TSIT5 = _tab("tsit5", _TSIT5_A, _TSIT5_B, btilde=_TSIT5_BTILDE, c=_TSIT5_C,
             order=5, embedded_order=4, fsal=True, interp_bpoly=_tsit5_bpoly)


# ----------------------------------------------------------------------------
# Dormand-Prince 5(4) — [Dormand & Prince 1980]; MATLAB ode45 / dopri5. FSAL.
# ----------------------------------------------------------------------------
_DOPRI5_A = [
    [1 / 5],
    [3 / 40, 9 / 40],
    [44 / 45, -56 / 15, 32 / 9],
    [19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729],
    [9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656],
    [35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84],
]
_DOPRI5_B = [35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0]
_DOPRI5_BHAT = [5179 / 57600, 0.0, 7571 / 16695, 393 / 640, -92097 / 339200,
                187 / 2100, 1 / 40]
DOPRI5 = _tab("dopri5", _DOPRI5_A, _DOPRI5_B, bhat=_DOPRI5_BHAT,
              c=[0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0],
              order=5, embedded_order=4, fsal=True)


# ----------------------------------------------------------------------------
# Cash-Karp 5(4) — the MPGOS comparison method in the paper's Fig. 5/6.
# ----------------------------------------------------------------------------
_RKCK_A = [
    [1 / 5],
    [3 / 40, 9 / 40],
    [3 / 10, -9 / 10, 6 / 5],
    [-11 / 54, 5 / 2, -70 / 27, 35 / 27],
    [1631 / 55296, 175 / 512, 575 / 13824, 44275 / 110592, 253 / 4096],
]
_RKCK_B = [37 / 378, 0.0, 250 / 621, 125 / 594, 0.0, 512 / 1771]
_RKCK_BHAT = [2825 / 27648, 0.0, 18575 / 48384, 13525 / 55296, 277 / 14336,
              1 / 4]
RKCK54 = _tab("rkck54", _RKCK_A, _RKCK_B, bhat=_RKCK_BHAT,
              c=[0, 1 / 5, 3 / 10, 3 / 5, 1.0, 7 / 8],
              order=5, embedded_order=4, fsal=False)


# ----------------------------------------------------------------------------
# Bogacki-Shampine 3(2) — MATLAB ode23. FSAL. Cheap low-accuracy option.
# ----------------------------------------------------------------------------
_BS3_A = [
    [1 / 2],
    [0.0, 3 / 4],
    [2 / 9, 1 / 3, 4 / 9],
]
_BS3_B = [2 / 9, 1 / 3, 4 / 9, 0.0]
_BS3_BHAT = [7 / 24, 1 / 4, 1 / 3, 1 / 8]
BS3 = _tab("bs3", _BS3_A, _BS3_B, bhat=_BS3_BHAT, c=[0, 1 / 2, 3 / 4, 1.0],
           order=3, embedded_order=2, fsal=True)


# ----------------------------------------------------------------------------
# Fehlberg 4(5) — classical RKF45.
# ----------------------------------------------------------------------------
_RKF45_A = [
    [1 / 4],
    [3 / 32, 9 / 32],
    [1932 / 2197, -7200 / 2197, 7296 / 2197],
    [439 / 216, -8.0, 3680 / 513, -845 / 4104],
    [-8 / 27, 2.0, -3544 / 2565, 1859 / 4104, -11 / 40],
]
_RKF45_B = [16 / 135, 0.0, 6656 / 12825, 28561 / 56430, -9 / 50, 2 / 55]
_RKF45_BHAT = [25 / 216, 0.0, 1408 / 2565, 2197 / 4104, -1 / 5, 0.0]
RKF45 = _tab("rkf45", _RKF45_A, _RKF45_B, bhat=_RKF45_BHAT,
             c=[0, 1 / 4, 3 / 8, 12 / 13, 1.0, 1 / 2],
             order=5, embedded_order=4, fsal=False)


# Classical RK4 (fixed-step only; btilde = 0 sentinel).
_RK4_A = [
    [1 / 2],
    [0.0, 1 / 2],
    [0.0, 0.0, 1.0],
]
RK4 = _tab("rk4", _RK4_A, [1 / 6, 1 / 3, 1 / 3, 1 / 6],
           btilde=[0.0, 0.0, 0.0, 0.0], c=[0, 1 / 2, 1 / 2, 1.0],
           order=4, embedded_order=4, fsal=False)


# ----------------------------------------------------------------------------
# Verner "most efficient" 7(6) — [Verner 2010], the paper's GPUVern7.
# 10 stages; b uses 9, stage 10 feeds only the order-6 error estimator.
# Recovered + verified against the full order-7 rooted-tree condition set
# (see module docstring); dense output falls back to Hermite cubic.
# ----------------------------------------------------------------------------
_VERN7_A = [
    [0.005],
    [-1.0767901234565735, 1.1856790123454624],
    [0.040833333333336864, 0.0, 0.12249999999999647],
    [0.6389139236256102, 0.0, -2.4556726382238203, 2.2722587145982103],
    [-2.6615773750273117, 0.0, 10.804513886491288, -8.353914657424742,
     0.8204875949589865],
    [6.067741434695297, 0.0, -24.711273635906824, 20.42751793078589,
     -1.9061579788134801, 1.0061722492391174],
    [12.054670076247431, 0.0, -49.754784950450635, 41.14288863859173,
     -4.4617601499684865, 2.0423348222341633, -0.0983484366541985],
    [10.138146522844547, 0.0, -42.64113603157068, 35.76384003980545,
     -4.348022840378171, 2.009862268369773, 0.3487490460336382,
     -0.2714390051045587],
    [-45.030072034298676, 0.0, 187.3272437654589, -154.02882369350186,
     18.56465306347536, -7.141809679295079, 1.3088085781613787, 0.0, 0.0],
]
_VERN7_B = [0.04715561848627767, 0.0, 0.0, 0.257505642984316,
            0.2621665397743865, 0.15216092656729885, 0.49399691700248516,
            -0.2943031171395947, 0.08131747232483061, 0.0]
_VERN7_BTILDE = [0.002548988715029059, 0.0, 0.0, -0.009665891129052029,
                 0.04209735781365781, -0.06673399842882516,
                 0.2652154308245583, -0.29453153722512393, 0.0813805859745605,
                 -0.02031093654480414]
_VERN7_C = [0.0, 0.005, 49.0 / 450.0, 49.0 / 300.0, 0.4555,
            0.6095094489982205, 0.884, 0.925, 1.0, 1.0]
VERN7 = _tab("vern7", _VERN7_A, _VERN7_B, btilde=_VERN7_BTILDE, c=_VERN7_C,
             order=7, embedded_order=6, fsal=False)


# ----------------------------------------------------------------------------
# GBS10: Gragg-Bulirsch-Stoer midpoint extrapolation as an embedded ERK pair.
# Gragg's theorem: for even n the explicit-midpoint result over n substeps
# has an error expansion in h^2, so polynomial extrapolation of the sequence
# (2, 4, 6, 8, 10) at h->0 kills h^2..h^8 and yields order 10; dropping the
# last sequence gives the embedded order-8 solution.  All coefficients are
# exact rationals (converted to float64 once, below) — provenance is this
# construction, verified by the order-condition tests.
# ----------------------------------------------------------------------------

def _build_gbs_tableau(ns=(2, 4, 6, 8, 10), name="gbs10"):
    F = Fraction
    stage_of = {}
    idx = 1
    for j, n in enumerate(ns):
        stage_of[(j, 0)] = 0          # f(y0) shared by every sequence
        for i in range(1, n):
            stage_of[(j, i)] = idx
            idx += 1
    s = idx
    A = [[F(0)] * s for _ in range(s)]
    c = [F(0)] * s
    yrow = {}
    for j, n in enumerate(ns):
        # midpoint chain y_{i+1} = y_{i-1} + (2h/n) f(y_i), Euler start
        y = {0: [F(0)] * s, 1: [F(0)] * s}
        y[1][stage_of[(j, 0)]] = F(1, n)
        for i in range(1, n):
            r = stage_of[(j, i)]
            A[r] = list(y[i])
            c[r] = F(i, n)
            y[i + 1] = list(y[i - 1])
            y[i + 1][r] += F(2, n)
        yrow[j] = y[n]                # increment coefficients of T_j = y_n

    def extrapolated_b(js):
        # Aitken-Neville to h^2 -> 0 through the points (1/n_j^2, T_j)
        xs = [F(1, ns[j] * ns[j]) for j in js]
        b = [F(0)] * s
        for a, j in enumerate(js):
            w = F(1)
            for l in range(len(js)):
                if l != a:
                    w *= xs[l] / (xs[l] - xs[a])
            for q in range(s):
                b[q] += w * yrow[j][q]
        return b

    b = extrapolated_b(range(len(ns)))
    bhat = extrapolated_b(range(len(ns) - 1))
    btilde = [x - y for x, y in zip(b, bhat)]
    as_f = lambda v: np.asarray([float(x) for x in v], np.float64)
    return Tableau(name, np.asarray([[float(x) for x in row] for row in A]),
                   as_f(b), as_f(btilde), as_f(c), order=2 * len(ns),
                   embedded_order=2 * (len(ns) - 1), fsal=False,
                   interp_bpoly=None)


GBS10 = _build_gbs_tableau()


TABLEAUS = {t.name: t for t in [TSIT5, DOPRI5, RKCK54, BS3, RKF45, RK4,
                                VERN7, GBS10]}


def get_tableau(name: str) -> Tableau:
    try:
        return TABLEAUS[name]
    except KeyError:
        raise KeyError(f"unknown tableau {name!r}; have {sorted(TABLEAUS)}")


# ============================================================================
# Rosenbrock (linearly-implicit W-method) tableaus — paper §5.1.3 methods.
#
# Implementation form (Hairer-Wanner IV.7 eq. 7.4 / the RODAS code): per step
# factor W = I - γh·J once, then for each stage i
#
#     g_i   = u0 + Σ_{j<i} a_ij U_j
#     W U_i = γh f(g_i, t + c_i h) + γ Σ_{j<i} C_ij U_j + γ d_i h² f_t
#     u1    = u0 + Σ b_i U_i,     err = Σ btilde_i U_i
#
# This is equivalent to the textbook k-form  k_i = h f(y0 + Σ α_ij k_j)
# + hJ Σ Γ_ij k_j + h² γ_i f_t  under U = Γk, a = αΓ⁻¹, C = 1/γ·I − Γ⁻¹,
# b = b_k Γ⁻¹ — the inverse transform is what the order-condition checker
# (`repro.core.order_conditions.rosenbrock_order_condition_residuals`)
# applies before evaluating the rooted-tree conditions, so every tableau
# below is VERIFIED against its claimed order, not trusted:
#
# * `ROS23W`  — Shampine's ode23s / OrdinaryDiffEq Rosenbrock23, CONSTRUCTED
#   here from its k-form (γ = 1/(2+√2), the same constants the previous
#   hard-coded 2-stage engine used — the generic engine reproduces its steps
#   to machine precision).  Order 2 with an order-3 embedded companion ŷ = y0 +
#   h/6·(k1 + 4k2 + k3) (Simpson weights).
# * `RODAS4`  — Hairer-Wanner's RODAS 4(3): 6 stages, stiffly accurate
#   (c5 = c6 = 1, err = U_6), L-stable.  All 8 conditions through order 4
#   hold to ~2e-15; the embedded weights satisfy order 3.  Ships the
#   stiffly-accurate dense-output weights (interp_h): u(θ) = (1−θ)u0 +
#   θ(u1 + (1−θ)(kd1 + θ·kd2)), a 3rd-order interpolant built from the
#   already-computed stages — no extra f evaluation.
# * `RODAS5P` — Steinebach's Rodas5p 5(4): 8 stages, stiffly accurate,
#   all 17 conditions through order 5 hold to ~2e-14, embedded order 4.
#   Dense output falls back to Hermite cubic (this repo does not ship
#   interpolation weights it cannot verify; the checker would accept a
#   future drop-in).
# ============================================================================


class RosenbrockTableau(NamedTuple):
    """Coefficients of an s-stage Rosenbrock W-method (implementation form)."""
    name: str
    gamma: float         # the single diagonal γ (one LU factorization/step)
    a: np.ndarray        # (s, s) strictly lower: stage-argument weights
    C: np.ndarray        # (s, s) strictly lower: in-solve stage coupling
    b: np.ndarray        # (s,)  solution weights
    btilde: np.ndarray   # (s,)  b - bhat (error-estimate weights)
    c: np.ndarray        # (s,)  abscissae (= row sums of the k-form α)
    d: np.ndarray        # (s,)  f_t weights (= row sums of the k-form Γ)
    order: int           # order of the propagated solution
    embedded_order: int
    # optional stiffly-accurate dense output: (L, s) weights; row l gives
    # kd_l = Σ_j interp_h[l, j] U_j and u(θ) = (1-θ)u0 + θ·u1
    # + θ(1-θ)(kd_1 + θ kd_2 + ...).  None => Hermite cubic (needs f(u1)).
    interp_h: Optional[np.ndarray] = None

    @property
    def stages(self) -> int:
        return len(self.b)

    @property
    def fnew_from_last_stage(self) -> bool:
        """True when the last stage argument IS the step solution (g_s = u1,
        c_s = 1), so f(u1) for Hermite dense output is the stage's own f
        evaluation — no extra RHS call (holds for ROS23W)."""
        s = self.stages
        return (self.b[s - 1] == 0.0 and float(self.c[s - 1]) == 1.0
                and bool(np.allclose(self.a[s - 1, : s - 1],
                                     self.b[: s - 1], atol=1e-14)))


def _lower(s, rows):
    M = np.zeros((s, s), np.float64)
    for i, row in enumerate(rows):
        M[i + 1, : len(row)] = row
    return M


def _build_ros23w() -> RosenbrockTableau:
    """ode23s from its k-form: provenance is this transformation, verified by
    the Rosenbrock order-condition tests and by agreement with the previous
    hard-coded 2-stage engine to machine precision."""
    d = 1.0 / (2.0 + np.sqrt(2.0))
    e32 = 6.0 + np.sqrt(2.0)
    alpha = np.array([[0.0, 0.0, 0.0], [0.5, 0.0, 0.0], [0.0, 1.0, 0.0]])
    Gamma = np.array([[d, 0.0, 0.0], [-d, d, 0.0],
                      [d * (e32 - 2.0), -d * e32, d]])
    b_k = np.array([0.0, 1.0, 0.0])
    btilde_k = np.array([-1.0 / 6.0, 1.0 / 3.0, -1.0 / 6.0])  # b - Simpson ŷ
    Ginv = np.linalg.inv(Gamma)
    return RosenbrockTableau(
        name="rosenbrock23", gamma=d, a=alpha @ Ginv,
        C=np.eye(3) / d - Ginv, b=b_k @ Ginv, btilde=btilde_k @ Ginv,
        c=alpha.sum(axis=1), d=Gamma.sum(axis=1), order=2, embedded_order=3)


ROS23W = _build_ros23w()


def _build_rodas4() -> RosenbrockTableau:
    a51, a52, a53, a54 = (1.221224509226641, 6.019134481288629,
                          12.53708332932087, -0.6878860361058950)
    a = _lower(6, [
        [1.544000000000000],
        [0.9466785280815826, 0.2557011698983284],
        [3.314825187068521, 2.896124015972201, 0.9986419139977817],
        [a51, a52, a53, a54],
        [a51, a52, a53, a54, 1.0],          # g6 = g5-solution + U5
    ])
    C = _lower(6, [
        [-5.668800000000000],
        [-2.430093356833875, -0.2063599157091915],
        [-0.1073529058151375, -9.594562251023355, -20.47028614809616],
        [7.496443313967647, -10.24680431464352, -33.99990352819905,
         11.70890893206160],
        [8.083246795921522, -7.981132988064893, -31.52159432874371,
         16.31930543123136, -6.058818238834054],
    ])
    b = np.array([a51, a52, a53, a54, 1.0, 1.0])   # stiffly accurate
    btilde = np.array([0.0, 0.0, 0.0, 0.0, 0.0, 1.0])   # err = U_6
    interp_h = np.array([
        [10.12623508344586, -7.487995877610167, -34.80091861555747,
         -7.992771707568823, 1.025137723295662, 0.0],
        [-0.6762803392801253, 6.087714651680015, 16.43084320892478,
         24.76722511418386, -6.594389125716872, 0.0],
    ])
    return RosenbrockTableau(
        name="rodas4", gamma=0.25, a=a, C=C, b=b, btilde=btilde,
        c=np.array([0.0, 0.386, 0.21, 0.63, 1.0, 1.0]),
        d=np.array([0.25, -0.1043, 0.1035, -0.03620000000000023, 0.0, 0.0]),
        order=4, embedded_order=3, interp_h=interp_h)


RODAS4 = _build_rodas4()


def _build_rodas5p() -> RosenbrockTableau:
    a61, a62, a63, a64, a65 = (-7.502846399306121, 2.561846144803919,
                               -11.627539656261098, -0.18268767659942256,
                               0.030198172008377946)
    a = _lower(8, [
        [3.0],
        [2.849394379747939, 0.45842242204463923],
        [-6.954028509809101, 2.489845061869568, -10.358996098473584],
        [2.8029986275628964, 0.5072464736228206, -0.3988312541770524,
         -0.04721187230404641],
        [a61, a62, a63, a64, a65],
        [a61, a62, a63, a64, a65, 1.0],
        [a61, a62, a63, a64, a65, 1.0, 1.0],
    ])
    C = _lower(8, [
        [-14.155112264123755],
        [-17.97296035885952, -2.859693295451294],
        [147.12150275711716, -1.41221402718213, 71.68940251302358],
        [165.43517024871676, -0.4592823456491126, 42.90938336958603,
         -5.961986721573306],
        [24.854864614690072, -3.0009227002832186, 47.4931110020768,
         5.5814197821558125, -0.6610691825249471],
        [30.91273214028599, -3.1208243349937974, 77.79954646070892,
         34.28646028294783, -19.097331116725623, -28.087943162872662],
        [37.80277123390563, -3.2571969029072276, 112.26918849496327,
         66.9347231244047, -40.06618937091002, -54.66780262877968,
         -9.48861652309627],
    ])
    b = np.array([a61, a62, a63, a64, a65, 1.0, 1.0, 1.0])
    btilde = np.array([0.0] * 7 + [1.0])           # err = U_8
    return RosenbrockTableau(
        name="rodas5p", gamma=0.21193756319429014, a=a, C=C, b=b,
        btilde=btilde,
        c=np.array([0.0, 0.6358126895828704, 0.4095798393397535,
                    0.9769306725060716, 0.4288403609558664, 1.0, 1.0, 1.0]),
        d=np.array([0.21193756319429014, -0.42387512638858027,
                    -0.3384627126235924, 1.8046452872882734,
                    2.325825639765069, 0.0, 0.0, 0.0]),
        order=5, embedded_order=4, interp_h=None)


RODAS5P = _build_rodas5p()


ROSENBROCK_TABLEAUS = {t.name: t for t in [ROS23W, RODAS4, RODAS5P]}


def get_rosenbrock_tableau(name: str) -> RosenbrockTableau:
    try:
        return ROSENBROCK_TABLEAUS[name]
    except KeyError:
        raise KeyError(f"unknown Rosenbrock tableau {name!r}; "
                       f"have {sorted(ROSENBROCK_TABLEAUS)}")
