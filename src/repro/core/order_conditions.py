"""Butcher order conditions via rooted trees — the tableau verifier.

A Runge-Kutta pair (A, b, c) has order p iff for every rooted tree t with
order r(t) <= p the elementary weight matches the tree density:

    Phi(t) = b . u(t) = 1 / gamma(t),   u([t1..tk])_i = prod_j (A u(tj))_i,
    u(tau) = 1,   gamma(tau) = 1,   gamma(t) = r(t) * prod_j gamma(tj).

(Butcher 1963; Hairer-Norsett-Wanner I.II.2.)  This module enumerates the
trees (1, 1, 2, 4, 9, 20, 48, 115, 286 trees for orders 1..9) and evaluates
every condition numerically, which is how the shipped high-order tableaus
(the 10-stage Vern7 and the 26-stage extrapolation pair GBS10) are
*verified* rather than trusted: a single wrong coefficient breaks dozens of
the nonlinear conditions at once.

The same machinery doubles as a data-driven consistency check for user
tableaus registered through `repro.core.methods.register_method`.

>>> from repro.core.tableaus import TSIT5
>>> max_order_condition_residual(TSIT5, 5) < 1e-12
True
>>> count_trees(7)      # number of order conditions for a 7th-order method
85
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, Dict, List, Tuple

import numpy as np

# A rooted tree is a canonical (sorted) tuple of its root's subtrees; the
# single-node tree is the empty tuple ().
Tree = Tuple[Any, ...]


@lru_cache(maxsize=None)
def _forests(total: int) -> Tuple[Tree, ...]:
    """All multisets of rooted trees whose orders sum to `total` (each multiset
    sorted canonically so duplicates collapse)."""
    if total == 0:
        return ((),)
    out = set()
    for k in range(1, total + 1):
        for t in rooted_trees(k):
            for rest in _forests(total - k):
                out.add(tuple(sorted((t,) + rest)))
    return tuple(sorted(out))


@lru_cache(maxsize=None)
def rooted_trees(order: int) -> Tuple[Tree, ...]:
    """All rooted trees with exactly `order` nodes (canonical form)."""
    if order < 1:
        return ()
    return tuple(_forests(order - 1))


def count_trees(max_order: int) -> int:
    """Total number of order conditions for a method of order `max_order`."""
    return sum(len(rooted_trees(r)) for r in range(1, max_order + 1))


def tree_order(t: Tree) -> int:
    return 1 + sum(tree_order(s) for s in t)


def tree_density(t: Tree) -> int:
    g = tree_order(t)
    for s in t:
        g *= tree_density(s)
    return g


def _stage_vector(t: Tree, A: np.ndarray,
                  cache: Dict[Tree, np.ndarray]) -> np.ndarray:
    """u(t): the per-stage elementary-weight vector (Phi(t) = b . u(t)).
    Only A enters — the nodes c appear implicitly as A's row sums."""
    if t in cache:
        return cache[t]
    u = np.ones(A.shape[0])
    for s in t:
        u = u * (A @ _stage_vector(s, A, cache))
    cache[t] = u
    return u


def order_condition_residuals(A, b, c, order: int):
    """[(tree, b.u(t) - 1/gamma(t))] for every tree of order <= `order`."""
    A = np.asarray(A, np.float64)
    b = np.asarray(b, np.float64)
    cache: Dict[Tree, np.ndarray] = {}
    out = []
    for r in range(1, order + 1):
        for t in rooted_trees(r):
            phi = float(b @ _stage_vector(t, A, cache))
            out.append((t, phi - 1.0 / tree_density(t)))
    return out


def max_order_condition_residual(tab, order: int, embedded: bool = False):
    """Largest |Phi(t) - 1/gamma(t)| over all trees of order <= `order`.

    embedded=True checks the lower-order weights bhat = b - btilde instead
    (the error-estimator solution of the pair).
    """
    b = tab.b - tab.btilde if embedded else tab.b
    res = order_condition_residuals(tab.a, b, tab.c, order)
    return max(abs(r) for _, r in res)


def stage_consistency_residual(tab) -> float:
    """max_i |c_i - sum_j a_ij|: the row-sum (internal consistency) condition
    every shipped tableau satisfies by construction."""
    return float(np.max(np.abs(np.asarray(tab.c)
                               - np.asarray(tab.a).sum(axis=1))))


# ---------------------------------------------------------------------------
# Rosenbrock (W-method) order conditions — the stiff-family verifier.
#
# A Rosenbrock method in k-form,
#
#     k_i = h f(y0 + Σ_j α_ij k_j) + h J Σ_j Γ_ij k_j + h² γ_i f_t,
#     y1  = y0 + Σ_i b_i k_i,          J = f'(y0),   Γ_ii = γ,
#
# has order p iff  b · φ(t) = 1/γ(t)  for every rooted tree of order ≤ p,
# where the stage vectors φ follow the RK recursion EXCEPT that singly-
# branched nodes also pick up the Jacobian term (Hairer-Wanner IV.7):
#
#     φ(τ) = 1
#     φ([t1])        = (α + Γ) φ(t1)        (f'-chains see β = α + Γ)
#     φ([t1..tk]), k≥2 = Π_l (α φ(t_l))     (higher derivatives: α only)
#
# Shipped tableaus are stored in the IMPLEMENTATION form (a, C, b, d) that
# the engine executes (one factorization of W = I − γh·J per step); the
# checker inverts that transform —  Γ = (I/γ − C)⁻¹, α = a Γ, b_k = b Γ —
# so what is verified is exactly what runs.  Non-autonomous correctness
# reduces to the autonomous conditions iff c = rowsum(α) and d = rowsum(Γ)
# (autonomization invariance), checked by `rosenbrock_consistency_residual`.
# ---------------------------------------------------------------------------


def rosenbrock_kform(rtab) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                    np.ndarray]:
    """Invert the implementation transform: returns (alpha, Gamma, b_k,
    btilde_k) of the textbook k-form."""
    a = np.asarray(rtab.a, np.float64)
    C = np.asarray(rtab.C, np.float64)
    s = a.shape[0]
    Gamma = np.linalg.inv(np.eye(s) / rtab.gamma - C)
    return (a @ Gamma, Gamma, np.asarray(rtab.b, np.float64) @ Gamma,
            np.asarray(rtab.btilde, np.float64) @ Gamma)


def _rb_stage_vector(t: Tree, alpha: np.ndarray, beta: np.ndarray,
                     cache: Dict[Tree, np.ndarray]) -> np.ndarray:
    if t in cache:
        return cache[t]
    if len(t) == 1:
        u = beta @ _rb_stage_vector(t[0], alpha, beta, cache)
    else:
        u = np.ones(alpha.shape[0])
        for s in t:
            u = u * (alpha @ _rb_stage_vector(s, alpha, beta, cache))
    cache[t] = u
    return u


def rosenbrock_order_condition_residuals(rtab, order: int,
                                         embedded: bool = False):
    """[(tree, b·φ(t) − 1/γ(t))] over every rooted tree of order ≤ `order`."""
    alpha, Gamma, b_k, btilde_k = rosenbrock_kform(rtab)
    b = b_k - btilde_k if embedded else b_k
    beta = alpha + Gamma
    cache: Dict[Tree, np.ndarray] = {}
    out = []
    for r in range(1, order + 1):
        for t in rooted_trees(r):
            phi = float(b @ _rb_stage_vector(t, alpha, beta, cache))
            out.append((t, phi - 1.0 / tree_density(t)))
    return out


def max_rosenbrock_condition_residual(rtab, order: int,
                                      embedded: bool = False) -> float:
    """Largest Rosenbrock order-condition residual over trees of order ≤
    `order` (embedded=True checks the error-estimator weights b − btilde).

    >>> from repro.core.tableaus import RODAS4, RODAS5P
    >>> max_rosenbrock_condition_residual(RODAS4, 4) < 1e-12
    True
    >>> max_rosenbrock_condition_residual(RODAS5P, 5) < 1e-12
    True
    >>> max_rosenbrock_condition_residual(RODAS4, 3, embedded=True) < 1e-12
    True
    """
    res = rosenbrock_order_condition_residuals(rtab, order, embedded)
    return max(abs(r) for _, r in res)


def rosenbrock_consistency_residual(rtab) -> float:
    """max of |c − rowsum(α)| and |d − rowsum(Γ)| — the autonomization
    conditions that make the f_t/abscissae data consistent with the
    autonomous order conditions."""
    alpha, Gamma, _, _ = rosenbrock_kform(rtab)
    return float(max(
        np.max(np.abs(np.asarray(rtab.c) - alpha.sum(axis=1))),
        np.max(np.abs(np.asarray(rtab.d) - Gamma.sum(axis=1)))))


def elementary_weight_matrix(A, c, order: int) -> Tuple[np.ndarray, np.ndarray,
                                                        List[Tree]]:
    """(U, rhs, trees) with U[k] = u(t_k) and rhs[k] = 1/gamma(t_k) for every
    tree of order <= `order` — the order conditions as a LINEAR system in the
    quadrature weights b.  Used to cross-validate shipped b/btilde data: with
    A and c fixed, `U b = rhs` pins b down completely (least squares residual
    ~0 iff (A, c) genuinely admit a method of that order)."""
    A = np.asarray(A, np.float64)
    cache: Dict[Tree, np.ndarray] = {}
    rows, rhs, ts = [], [], []
    for r in range(1, order + 1):
        for t in rooted_trees(r):
            rows.append(_stage_vector(t, A, cache))
            rhs.append(1.0 / tree_density(t))
            ts.append(t)
    return np.asarray(rows), np.asarray(rhs), ts
