"""Sensitivity analysis / automatic differentiation through the solvers (§6.6).

The paper demonstrates forward AND reverse (adjoint) differentiation through
the GPU kernels.  Here both are capabilities of the unified front door
(`repro.core.ensemble.solve_ensemble_local` / `repro.core.api.solve_ensemble`,
``sensitivity=``) — this module is the convenience layer on top:

  forward_sensitivity      — du(t)/dθ for every trajectory and save point:
                             one jvp pass per parameter column through the
                             while-loop engines (forward mode crosses
                             lax.while_loop, so ADAPTIVE solves differentiate
                             without any bound).
  ensemble_value_and_grad  — loss(EnsembleResult) and its gradient w.r.t.
                             (u0s, ps) via reverse AD through the bounded,
                             checkpointed discrete adjoint
                             (``sensitivity="adjoint"`` — see
                             `repro.core.loops`): memory O(sqrt-steps),
                             exact gradient of the realized discretization.
  suggest_adjoint_steps    — probe the forward solve for the attempt-count
                             bound the adaptive adjoint needs.
  adjoint_continuous       — continuous adjoint λ' = -(∂f/∂u)ᵀλ on a backward
                             replay: O(1)-in-steps memory, gradient accurate
                             to O(dt^order).  Kept as the independent
                             mathematical oracle the discrete adjoint is
                             tested against.

Everything composes with vmap/shard_map for GPU-parallel parameter estimation
(examples/parameter_estimation.py reproduces the paper's calibration demo).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from .ensemble import solve_ensemble_local
from .problem import EnsembleProblem
from .solvers import solve_fixed
from .tableaus import Tableau

Array = Any


def _resolve(eprob: EnsembleProblem, u0s, ps) -> EnsembleProblem:
    return EnsembleProblem(eprob.prob, u0s.shape[0], u0s=u0s, ps=ps)


def forward_sensitivity(eprob: EnsembleProblem, *, wrt: str = "ps",
                        **solve_kw) -> Array:
    """Forward-mode sensitivities du(t)/dθ through the front door.

    One `jax.jvp` pass per column of ``wrt`` ("ps" or "u0s") — the
    GPU-parallel direction the paper uses: each pass is a full ensemble solve
    carrying one tangent, and forward mode crosses the adaptive
    ``lax.while_loop`` hot path untouched (no step bound needed).

    Returns ``(N, S, n, k)``: d ``us[i, s, :]`` / d ``θ[i, j]`` for each
    trajectory i — per-trajectory sensitivities (trajectory i's output w.r.t.
    trajectory i's own parameters).

    ``solve_kw`` are `solve_ensemble_local` kwargs (alg/ensemble/backend/
    saveat/rtol/...).  ``sensitivity="forward"`` is implied (and validated).
    """
    if wrt not in ("ps", "u0s"):
        raise ValueError(f"wrt must be 'ps' or 'u0s', got {wrt!r}")
    u0s, ps = eprob.materialize()
    kw = dict(solve_kw, sensitivity="forward")

    def us_of(u, p):
        return solve_ensemble_local(_resolve(eprob, u, p), **kw).us

    target = ps if wrt == "ps" else u0s
    cols = []
    for j in range(target.shape[1]):
        tangent = jnp.zeros_like(target).at[:, j].set(1.0)
        if wrt == "ps":
            _, dus = jax.jvp(lambda p_: us_of(u0s, p_), (ps,), (tangent,))
        else:
            _, dus = jax.jvp(lambda u_: us_of(u_, ps), (u0s,), (tangent,))
        cols.append(dus)
    return jnp.stack(cols, axis=-1)


def suggest_adjoint_steps(eprob: EnsembleProblem, *, margin: float = 0.25,
                          **solve_kw) -> int:
    """Attempt-count bound for ``sensitivity="adjoint"`` on adaptive solves.

    Runs the forward solve once (while-loop hot path, no AD) and returns the
    worst-case ``naccept + nreject`` over the ensemble plus ``margin``
    headroom.  The bound is safe by construction: if a later solve under the
    returned bound still runs out (different parameters, tighter tolerance),
    it reports ``status == 1`` — never a silently truncated gradient.
    """
    res = solve_ensemble_local(eprob, **solve_kw)
    worst = int(jnp.max(res.naccept + res.nreject))
    return worst + max(4, int(math.ceil(worst * float(margin))))


def ensemble_value_and_grad(loss_fn: Callable, eprob: EnsembleProblem,
                            **solve_kw) -> Tuple[Array, Tuple[Array, Array]]:
    """``(loss, (dL/du0s, dL/dps))`` through the checkpointed discrete adjoint.

    ``loss_fn`` maps the `EnsembleResult` to a scalar (use ``res.us`` /
    ``res.u_final``; solver statistics and event times are non-differentiable
    outputs).  ``solve_kw`` are `solve_ensemble_local` kwargs — pass
    ``adjoint_steps=`` for adaptive solves (see `suggest_adjoint_steps`);
    ``sensitivity="adjoint"`` is implied.
    """
    u0s, ps = eprob.materialize()
    kw = dict(solve_kw, sensitivity="adjoint")

    def L(u, p):
        return loss_fn(solve_ensemble_local(_resolve(eprob, u, p), **kw))

    return jax.value_and_grad(L, argnums=(0, 1))(u0s, ps)


def adjoint_continuous(loss_of_uf: Callable, f, tab: Tableau, u0, p, t0, dt,
                       n_steps):
    """Continuous adjoint for terminal-state losses: O(1)-in-steps memory.

    Forward: integrate u to tf (no history). Backward: integrate the augmented
    system (u, λ, μ) from tf to t0 with the same RK method:
        u'  = f(u)          (replayed backwards)
        λ' = -(∂f/∂u)ᵀ λ
        μ' = -(∂f/∂p)ᵀ λ
    Returns (loss, dL/du0, dL/dp).  The gradient differs from the discrete
    adjoint by the discretization error O(dt^order) — which is exactly why it
    stays: an INDEPENDENT oracle for gradcheck (`tests/test_grad_parity.py`),
    agreeing with reverse AD as dt → 0 without sharing a code path with it.
    """
    res = solve_fixed(f, tab, u0, p, t0, dt, n_steps, save_every=n_steps)
    u_f = res.u_final
    loss, dL_duf = jax.value_and_grad(loss_of_uf)(u_f)

    tf_ = t0 + dt * n_steps

    def aug_rhs(state, p_, s):
        # backward pseudo-time s in [0, tf-t0]; physical time t = tf - s
        t = tf_ - s
        n = u0.shape[0]
        u = state[:n]
        lam = state[n:2 * n]
        _, vjp = jax.vjp(lambda uu, pp: f(uu, pp, t), u, p_)
        du = f(u, p_, t)
        dlam, dmu = vjp(lam)
        return jnp.concatenate([-du, dlam, dmu])

    n = u0.shape[0]
    aug0 = jnp.concatenate([u_f, dL_duf, jnp.zeros_like(p)])
    back = solve_fixed(aug_rhs, tab, aug0, p, 0.0, dt, n_steps,
                       save_every=n_steps)
    out = back.u_final
    dL_du0 = out[n:2 * n]
    dL_dp = out[2 * n:]
    return loss, dL_du0, dL_dp
