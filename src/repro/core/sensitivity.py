"""Sensitivity analysis / automatic differentiation through the solvers (§6.6).

The paper demonstrates forward AND reverse (adjoint) differentiation through the
GPU kernels. In JAX:

  forward_sensitivity  — jvp/jacfwd through any solver (works through
                         lax.while_loop, so ADAPTIVE solves differentiate too).
  grad (discrete adjoint) — reverse AD through the fixed-step scan solver with
                         per-chunk rematerialization (jax.checkpoint): memory
                         O(S + save_every), exact gradient of the discretization.
  adjoint_continuous   — continuous adjoint: solve λ' = -(∂f/∂u)ᵀ λ backwards
                         alongside a backward replay of u, accumulating
                         ∂L/∂p = ∫ λᵀ ∂f/∂p dt. Memory O(1) in steps; gradient
                         accurate to O(dt^order).

All three are exposed per-trajectory and compose with vmap/shard_map for
GPU-parallel parameter estimation (examples/parameter_estimation.py reproduces
the paper's minibatched-AD tutorial).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .solvers import rk_step, solve_fixed
from .tableaus import Tableau

Array = Any


def forward_sensitivity(f, tab: Tableau, u0, p, t0, dt, n_steps,
                        save_every=1):
    """du(t)/dp for all save points via jacfwd (forward-mode, one pass per
    parameter column — the GPU-parallel direction the paper uses)."""

    def final_us(p_):
        return solve_fixed(f, tab, u0, p_, t0, dt, n_steps, save_every).us

    return jax.jacfwd(final_us)(p)


def solve_fixed_remat(f, tab: Tableau, u0, p, t0, dt, n_steps, save_every=1):
    """Fixed-step solve whose scan body is rematerialized: reverse AD stores
    only the S chunk boundaries, recomputing the inner save_every steps in the
    backward pass (the standard checkpointed discrete adjoint)."""
    assert n_steps % save_every == 0
    S = n_steps // save_every
    dt = jnp.asarray(dt, u0.dtype)

    @jax.checkpoint
    def chunk(u, t):
        def one(i, uk):
            u, t = uk
            k1 = f(u, p, t)
            u2, _, _ = rk_step(f, tab, u, p, t, dt, k1)
            return (u2, t + dt)

        return jax.lax.fori_loop(0, save_every, one, (u, t))

    def body(carry, _):
        u, t = carry
        u, t = chunk(u, t)
        return (u, t), u

    (u_f, _), us = jax.lax.scan(body, (u0, jnp.asarray(t0, u0.dtype)), None,
                                length=S)
    return us, u_f


def grad_discrete_adjoint(loss_of_us: Callable, f, tab, u0, p, t0, dt,
                          n_steps, save_every=1):
    """∂/∂(u0, p) of loss(us) via reverse AD over the rematerialized solve."""

    def L(u0_, p_):
        us, _ = solve_fixed_remat(f, tab, u0_, p_, t0, dt, n_steps, save_every)
        return loss_of_us(us)

    return jax.value_and_grad(L, argnums=(0, 1))(u0, p)


def adjoint_continuous(loss_of_uf: Callable, f, tab: Tableau, u0, p, t0, dt,
                       n_steps):
    """Continuous adjoint for terminal-state losses: O(1)-in-steps memory.

    Forward: integrate u to tf (no history). Backward: integrate the augmented
    system (u, λ, μ) from tf to t0 with the same RK method:
        u'  = f(u)          (replayed backwards)
        λ' = -(∂f/∂u)ᵀ λ
        μ' = -(∂f/∂p)ᵀ λ
    Returns (loss, dL/du0, dL/dp).
    """
    res = solve_fixed(f, tab, u0, p, t0, dt, n_steps, save_every=n_steps)
    u_f = res.u_final
    loss, dL_duf = jax.value_and_grad(loss_of_uf)(u_f)

    tf_ = t0 + dt * n_steps

    def aug_rhs(state, p_, s):
        # backward pseudo-time s in [0, tf-t0]; physical time t = tf - s
        t = tf_ - s
        n = u0.shape[0]
        u = state[:n]
        lam = state[n:2 * n]
        _, vjp = jax.vjp(lambda uu, pp: f(uu, pp, t), u, p_)
        du = f(u, p_, t)
        dlam, dmu = vjp(lam)
        return jnp.concatenate([-du, dlam, dmu])

    n = u0.shape[0]
    aug0 = jnp.concatenate([u_f, dL_duf, jnp.zeros_like(p)])
    tf = t0 + dt * n_steps
    back = solve_fixed(aug_rhs, tab, aug0, p, 0.0, dt, n_steps,
                       save_every=n_steps)
    out = back.u_final
    dL_du0 = out[n:2 * n]
    dL_dp = out[2 * n:]
    return loss, dL_du0, dL_dp
