"""Dataset interpolation — the TPU adaptation of the paper's texture memory (§6.7).

GPUs give hardware linear interpolation + boundary handling on uniform grids via
texture units. TPUs have no texture hardware; the native equivalents are:

  mode="gather"  — index computation + jnp.take (general; XLA gather).
  mode="onehot"  — interpolation weights as a (…, K) one-hot-pair matrix
                   contracted with the table: a matmul, i.e. MXU work. Inside a
                   Pallas kernel the table is VMEM-resident (BlockSpec broadcast
                   to every trajectory tile), so a lookup costs one small matmul
                   and zero HBM traffic — the same "single memory read" economy
                   texture memory buys on NVIDIA.

Both modes clamp out-of-range queries to the boundary (texture
address-mode=clamp) and require uniformly spaced data, exactly like the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

Array = Any


@dataclasses.dataclass(frozen=True)
class UniformTable1D:
    """values[i] sampled at x0 + i*dx, i in [0, K)."""
    values: Array   # (K,)
    x0: float
    dx: float

    @property
    def K(self) -> int:
        return self.values.shape[0]


@dataclasses.dataclass(frozen=True)
class UniformTable2D:
    """values[i, j] sampled at (x0 + i*dx, y0 + j*dy)."""
    values: Array   # (Kx, Ky)
    x0: float
    dx: float
    y0: float
    dy: float


def _locate(x, x0, dx, K):
    """Clamped cell index + fractional offset."""
    s = (x - x0) / dx
    s = jnp.clip(s, 0.0, float(K - 1))
    i = jnp.clip(jnp.floor(s).astype(jnp.int32), 0, K - 2)
    w = s - i  # in [0, 1]; w == 1 exactly at the last node
    return i, w


def interp1d(table: UniformTable1D, x, mode: str = "gather"):
    """Linear interpolation at x (any shape). Clamped boundaries."""
    K = table.K
    i, w = _locate(x, table.x0, table.dx, K)
    if mode == "gather":
        v0 = jnp.take(table.values, i)
        v1 = jnp.take(table.values, i + 1)
        return v0 * (1.0 - w) + v1 * w
    if mode == "onehot":
        # weights (…, K): (1-w) at i, w at i+1 — contraction is a matmul (MXU)
        iota = jnp.arange(K, dtype=jnp.int32)
        xsh = jnp.shape(x)
        ii = i.reshape(xsh + (1,))
        ww = w.reshape(xsh + (1,))
        wmat = (jnp.where(iota == ii, 1.0 - ww, 0.0)
                + jnp.where(iota == ii + 1, ww, 0.0))
        return wmat @ table.values
    raise ValueError(f"unknown mode {mode!r}")


def interp2d(table: UniformTable2D, x, y, mode: str = "gather"):
    """Bilinear interpolation at (x, y) (broadcast shapes). Clamped."""
    Kx, Ky = table.values.shape
    i, wx = _locate(x, table.x0, table.dx, Kx)
    j, wy = _locate(y, table.y0, table.dy, Ky)
    if mode == "gather":
        flat = table.values.reshape(-1)
        idx = i * Ky + j
        v00 = jnp.take(flat, idx)
        v01 = jnp.take(flat, idx + 1)
        v10 = jnp.take(flat, idx + Ky)
        v11 = jnp.take(flat, idx + Ky + 1)
        return (v00 * (1 - wx) * (1 - wy) + v01 * (1 - wx) * wy
                + v10 * wx * (1 - wy) + v11 * wx * wy)
    if mode == "onehot":
        # separable one-hot pair per axis; two small matmuls
        ix = jnp.arange(Kx, dtype=jnp.int32)
        iy = jnp.arange(Ky, dtype=jnp.int32)
        xsh = jnp.shape(x)
        ie = i.reshape(xsh + (1,))
        je = j.reshape(xsh + (1,))
        wxe = wx.reshape(xsh + (1,))
        wye = wy.reshape(xsh + (1,))
        wmx = (jnp.where(ix == ie, 1.0 - wxe, 0.0)
               + jnp.where(ix == ie + 1, wxe, 0.0))         # (…, Kx)
        wmy = (jnp.where(iy == je, 1.0 - wye, 0.0)
               + jnp.where(iy == je + 1, wye, 0.0))         # (…, Ky)
        rows = wmx @ table.values                            # (…, Ky)
        return jnp.sum(rows * wmy, axis=-1)
    raise ValueError(f"unknown mode {mode!r}")
