"""Dataset interpolation — the TPU adaptation of the paper's texture memory (§6.7).

GPUs give hardware linear interpolation + boundary handling on uniform grids via
texture units. TPUs have no texture hardware; the native equivalents are:

  mode="gather"  — index computation + jnp.take (general; XLA gather).
  mode="onehot"  — interpolation weights as a (…, K) one-hot-pair matrix
                   contracted with the table: a matmul, i.e. MXU work. Inside a
                   Pallas kernel the table is VMEM-resident (BlockSpec broadcast
                   to every trajectory tile), so a lookup costs one small matmul
                   and zero HBM traffic — the same "single memory read" economy
                   texture memory buys on NVIDIA.
  mode="cubic"   — Catmull–Rom cubic convolution (Keys a = -1/2): the OTHER
                   texture-unit operation (CUDA's tex1D cubic filtering is
                   built from linear fetches the same way).  Four-point gather
                   per query, C1-continuous, reproduces polynomials up to
                   degree 2 exactly (third-order accurate).

All modes clamp out-of-range queries to the boundary (texture
address-mode=clamp) and require uniformly spaced data, exactly like the paper.

Tables are registered JAX pytrees whose only leaf is ``values`` (``x0``/``dx``
ride the treedef as static metadata).  That single fact is what lets a
``prob.data`` pytree of tables be traced by `jax.grad` (calibrating a forcing
curve from data), broadcast — not sharded — through `shard_map`, and passed
into the fused Pallas kernels as real BlockSpec arguments
(`repro.kernels.ensemble_kernel`, extra kind "table").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = Any

MODES = ("gather", "onehot", "cubic")


@dataclasses.dataclass(frozen=True)
class UniformTable1D:
    """values[i] sampled at x0 + i*dx, i in [0, K)."""
    values: Array   # (K,)
    x0: float
    dx: float

    @property
    def K(self) -> int:
        return self.values.shape[0]


@dataclasses.dataclass(frozen=True)
class UniformTable2D:
    """values[i, j] sampled at (x0 + i*dx, y0 + j*dy)."""
    values: Array   # (Kx, Ky)
    x0: float
    dx: float
    y0: float
    dy: float


# Tables are pytrees: `values` is the (traceable, differentiable) leaf; the
# grid origin/spacing are static aux data.  This is the contract the whole
# data-driven-RHS capability rests on — see the module docstring.
jax.tree_util.register_pytree_node(
    UniformTable1D,
    lambda t: ((t.values,), (t.x0, t.dx)),
    lambda aux, ch: UniformTable1D(ch[0], *aux))
jax.tree_util.register_pytree_node(
    UniformTable2D,
    lambda t: ((t.values,), (t.x0, t.dx, t.y0, t.dy)),
    lambda aux, ch: UniformTable2D(ch[0], *aux))


def _locate(x, x0, dx, K):
    """Clamped cell index + fractional offset."""
    s = (x - x0) / dx
    s = jnp.clip(s, 0.0, float(K - 1))
    i = jnp.clip(jnp.floor(s).astype(jnp.int32), 0, K - 2)
    w = s - i  # in [0, 1]; w == 1 exactly at the last node
    return i, w


def _catmull_rom_weights(w):
    """Keys cubic-convolution weights (a = -1/2) for nodes i-1, i, i+1, i+2."""
    w2 = w * w
    w3 = w2 * w
    return (0.5 * (-w3 + 2.0 * w2 - w),
            0.5 * (3.0 * w3 - 5.0 * w2 + 2.0),
            0.5 * (-3.0 * w3 + 4.0 * w2 + w),
            0.5 * (w3 - w2))


def interp1d(table: UniformTable1D, x, mode: str = "gather"):
    """Interpolation at x (any shape). Clamped boundaries, all modes."""
    K = table.K
    i, w = _locate(x, table.x0, table.dx, K)
    if mode == "gather":
        v0 = jnp.take(table.values, i)
        v1 = jnp.take(table.values, i + 1)
        return v0 * (1.0 - w) + v1 * w
    if mode == "onehot":
        # weights (…, K): (1-w) at i, w at i+1 — contraction is a matmul (MXU)
        iota = jnp.arange(K, dtype=jnp.int32)
        xsh = jnp.shape(x)
        ii = i.reshape(xsh + (1,))
        ww = w.reshape(xsh + (1,))
        wmat = (jnp.where(iota == ii, 1.0 - ww, 0.0)
                + jnp.where(iota == ii + 1, ww, 0.0))
        return wmat @ table.values
    if mode == "cubic":
        # Catmull–Rom over the 4-point stencil {i-1, i, i+1, i+2}; stencil
        # indices clamp to [0, K-1] — node replication at the edges, the same
        # address-mode=clamp semantics as the linear modes (queries outside
        # the grid keep returning the boundary value exactly: w there is 0/1
        # and the replicated stencil collapses the cubic onto that node).
        ws = _catmull_rom_weights(w)
        out = None
        for off, wk in zip((-1, 0, 1, 2), ws):
            idx = jnp.clip(i + off, 0, K - 1)
            term = wk * jnp.take(table.values, idx)
            out = term if out is None else out + term
        return out
    raise ValueError(f"unknown mode {mode!r} (one of {MODES})")


def interp2d(table: UniformTable2D, x, y, mode: str = "gather"):
    """Bilinear/bicubic interpolation at (x, y) (broadcast shapes). Clamped."""
    Kx, Ky = table.values.shape
    i, wx = _locate(x, table.x0, table.dx, Kx)
    j, wy = _locate(y, table.y0, table.dy, Ky)
    if mode == "gather":
        flat = table.values.reshape(-1)
        idx = i * Ky + j
        v00 = jnp.take(flat, idx)
        v01 = jnp.take(flat, idx + 1)
        v10 = jnp.take(flat, idx + Ky)
        v11 = jnp.take(flat, idx + Ky + 1)
        return (v00 * (1 - wx) * (1 - wy) + v01 * (1 - wx) * wy
                + v10 * wx * (1 - wy) + v11 * wx * wy)
    if mode == "onehot":
        # separable one-hot pair per axis; two small matmuls
        ix = jnp.arange(Kx, dtype=jnp.int32)
        iy = jnp.arange(Ky, dtype=jnp.int32)
        xsh = jnp.shape(x)
        ie = i.reshape(xsh + (1,))
        je = j.reshape(xsh + (1,))
        wxe = wx.reshape(xsh + (1,))
        wye = wy.reshape(xsh + (1,))
        wmx = (jnp.where(ix == ie, 1.0 - wxe, 0.0)
               + jnp.where(ix == ie + 1, wxe, 0.0))         # (…, Kx)
        wmy = (jnp.where(iy == je, 1.0 - wye, 0.0)
               + jnp.where(iy == je + 1, wye, 0.0))         # (…, Ky)
        rows = wmx @ table.values                            # (…, Ky)
        return jnp.sum(rows * wmy, axis=-1)
    if mode == "cubic":
        # separable Catmull–Rom: 4x4 clamped stencil, tensor-product weights
        flat = table.values.reshape(-1)
        wxs = _catmull_rom_weights(wx)
        wys = _catmull_rom_weights(wy)
        out = None
        for ox, wkx in zip((-1, 0, 1, 2), wxs):
            ii = jnp.clip(i + ox, 0, Kx - 1)
            for oy, wky in zip((-1, 0, 1, 2), wys):
                jj = jnp.clip(j + oy, 0, Ky - 1)
                term = wkx * wky * jnp.take(flat, ii * Ky + jj)
                out = term if out is None else out + term
        return out
    raise ValueError(f"unknown mode {mode!r} (one of {MODES})")


# ---------------------------------------------------------------------------
# `prob.data` pytree helpers — the dispatch layers (ensemble/api/autotune/
# kernel factory) handle data through these three functions only.
# ---------------------------------------------------------------------------

def data_flatten(data) -> Tuple[list, Any]:
    """(leaves, treedef) of a `prob.data` pytree — leaves are the table value
    arrays (tables are registered pytree nodes), in deterministic order."""
    return jax.tree_util.tree_flatten(data)


def data_unflatten(treedef, leaves):
    return jax.tree_util.tree_unflatten(treedef, list(leaves))


def data_words(data) -> int:
    """Total elements across all table leaves — the VMEM footprint (in words)
    a broadcast-resident copy of the dataset costs each lane tile.  Charged
    as `fixed_words` against the §5.2 budget by the kernel factory."""
    if data is None:
        return 0
    return int(sum(int(jnp.size(leaf))
                   for leaf in jax.tree_util.tree_leaves(data)))


def data_signature(data) -> str:
    """Compact shape/dtype signature of a data pytree — the autotune
    configuration-key component ("none" without data): different table
    geometries cost differently in the kernels, so they must not share a
    profile-cache entry."""
    if data is None:
        return "none"
    leaves = jax.tree_util.tree_leaves(data)
    if not leaves:
        return "empty"
    return "+".join(
        "x".join(str(int(s)) for s in jnp.shape(leaf))
        + jnp.dtype(jnp.result_type(leaf)).name
        for leaf in leaves)
