"""Method registry: every solver algorithm the front door can dispatch.

The paper's central claim is that ONE kernel-generation pipeline ("automated
translation") serves every method family.  This module is the data model for
that claim: a `MethodSpec` describes an algorithm — its family (explicit RK,
Rosenbrock-stiff, or SDE stepper), the tableau or stepper function that
parameterizes the shared engine, and its capabilities (adaptive stepping,
stiffness, supported noise types).  `repro.core.ensemble.solve_ensemble_local`
and the Pallas kernel factory (`repro.kernels.ensemble_kernel`) consume specs
instead of hard-coding per-method entry points, so registering a method here is
all it takes to reach every execution strategy (vmap / array / kernel) and
backend (xla / pallas).

Families:
  "erk"        — embedded explicit Runge-Kutta; `tableau` drives
                 `repro.core.solvers` (scalar / array / lanes modes).
  "rosenbrock" — linearly-implicit stiff methods; batched block-diagonal
                 W = I - γh·J solves (paper §5.1.3) via `repro.core.rosenbrock`.
  "sde"        — fixed-dt stochastic steppers; `stepper` drives
                 `repro.core.sde` (and the fused SDE kernel).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

from .tableaus import (ROSENBROCK_TABLEAUS, TABLEAUS, RosenbrockTableau,
                       Tableau)

FAMILIES = ("erk", "rosenbrock", "sde")

# the dispatch axes `solve_ensemble_local` accepts (docs/architecture.md's
# matrix); `valid_dispatch` below is the single machine-readable predicate
STRATEGIES = ("vmap", "array", "array_eager", "kernel")
BACKENDS = ("xla", "pallas")


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """Declarative description of one solver algorithm.

    name:      canonical registry key.
    family:    one of FAMILIES.
    tableau:   Butcher tableau (erk only).
    rtableau:  Rosenbrock W-method tableau (rosenbrock only) — drives the
               s-stage stiff engine (`repro.core.rosenbrock`) on every
               strategy/backend, including the fused Pallas body.
    stepper:   stepper fn `(f, g, u, p, t, dt, dW, noise) -> u_new` (sde only).
    embedded:  `repro.core.sde.EmbeddedPair` — the stepper's embedded error
               pair (sde only): one-pass companion-difference estimator,
               ~1.5x the stepper cost instead of step doubling's ~3x.
    error_est: error estimators the adaptive SDE engine may run for this
               method.  Derived at registration: ("embedded", "doubling")
               when an `embedded` pair ships, ("doubling",) otherwise —
               step doubling works for ANY stepper, so every adaptive SDE
               method keeps it as the A/B reference and general-noise path.
    order:     order of the propagated solution (strong order for sde).
    adaptive:  the method supports adaptive stepping — an embedded error pair
               (erk/rosenbrock) or, for sde, one of the `error_est`
               estimators with virtual-Brownian-tree noise.
    w_reuse:   rosenbrock only — the method's DEFAULT for the lazy-W hot path
               (Jacobian & LU(W) reuse across steps under a
               `repro.core.controller.WReusePolicy`).  The safe default is
               False: every-step re-evaluation/re-factorization, bitwise
               today's behaviour.  Callers override per solve with
               ``solve_ensemble_local(..., w_reuse=True | WReusePolicy(...))``.
    events:    the method's engines support zero-crossing event handling with
               per-lane termination (`repro.core.events`).  True for every
               built-in family; a capability flag so the front door can reject
               unsupported combinations up front instead of deep in dispatch.
    data_rhs:  the method's engines accept data-driven problems
               (`ODEProblem.data` / `SDEProblem.data` — a pytree of
               interpolation tables the RHS consumes as a fourth argument,
               the paper's texture-memory workloads).  True for every
               built-in family: the XLA engines see data through bound
               closures (`repro.core.problem.bind_problem_data`) and the
               Pallas bodies re-bind from VMEM-resident table arguments.  A
               method whose engine bypasses both mechanisms (e.g. a
               hand-rolled kernel with a baked-in RHS) declares False and
               the front door rejects data-driven problems up front.
    differentiable: the method's engines satisfy the AD contract
               (docs/adding-a-method.md): pure-JAX step math, so forward-mode
               sensitivities flow through the while-loop hot path and
               reverse-mode (checkpointed discrete adjoint) through the
               bounded loop substitute.  True for every built-in family; a
               method whose stepper leaves JAX (callbacks, host code) must
               declare False and the front door rejects `sensitivity=` up
               front.  The derived `sensitivity` property lists the modes.
    stiff:     suitable for stiff problems (implicit/semi-implicit).
    resumable: the method's engine exposes the per-lane segment carry
               (`repro.core.ensemble.make_resumable_engine`) that the
               continuous-batching service (`repro.serve`) slots lanes in and
               out of: every per-lane quantity (state, t, dt, controller
               memory, RNG counters) lives in the carry, and applying the
               loop body to a retired lane is an exact no-op — so a slot can
               be recycled mid-stream, bitwise-identically to a fresh solve.
               True for erk (fixed + adaptive) and for sde fixed-dt
               stepping.  False for rosenbrock: the lazy-W freshness gates
               are psum-reduced BATCH predicates (`lax.cond` on
               any-lane-stale), which couples lanes across the slot axis —
               the service runs non-resumable methods as coalesced one-shot
               batches instead.
    noise:     supported SDEProblem.noise kinds (sde only).
    aliases:   alternative lookup names (paper-facing spellings).

    Capability checks are data, not code paths: `solve_ensemble_local`
    consults these flags, so a newly registered method states what it supports
    and immediately gets the matching dispatch behaviour on every
    strategy/backend (see docs/adding-a-method.md).

    >>> get_method("tsit5").family
    'erk'
    >>> get_method("em").adaptive       # embedded pair + Brownian tree
    True
    >>> get_method("em").error_est      # EM/Milstein-difference pair ships
    ('embedded', 'doubling')
    >>> get_method("heun_strat").error_est   # no pair: doubling only
    ('doubling',)
    >>> sorted(get_method("gpuem").noise)
    ['diagonal', 'general']
    >>> get_method("tsit5").sensitivity   # AD capability, derived
    ('forward', 'adjoint')
    """

    name: str
    family: str
    order: float
    tableau: Optional[Tableau] = None
    rtableau: Optional[RosenbrockTableau] = None
    stepper: Optional[Callable] = None
    embedded: Optional[Any] = None
    error_est: Tuple[str, ...] = ()
    adaptive: bool = True
    events: bool = True
    stiff: bool = False
    resumable: bool = False
    w_reuse: bool = False
    data_rhs: bool = True
    differentiable: bool = True
    noise: Tuple[str, ...] = ()
    aliases: Tuple[str, ...] = ()

    @property
    def sensitivity(self) -> Tuple[str, ...]:
        """Supported sensitivity modes, derived from `differentiable`."""
        return ("forward", "adjoint") if self.differentiable else ()

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(
                f"family {self.family!r} not one of {FAMILIES}")
        if self.family == "erk" and self.tableau is None:
            raise ValueError(f"erk method {self.name!r} needs a tableau")
        if self.family == "rosenbrock" and self.rtableau is None:
            raise ValueError(
                f"rosenbrock method {self.name!r} needs an rtableau")
        if self.family == "sde" and self.stepper is None:
            raise ValueError(f"sde method {self.name!r} needs a stepper")
        if self.w_reuse and self.family != "rosenbrock":
            raise ValueError(
                f"method {self.name!r}: `w_reuse` is a rosenbrock-family "
                "capability (there is no W = I − γh·J to reuse elsewhere)")
        if self.embedded is not None and self.family != "sde":
            raise ValueError(
                f"method {self.name!r}: `embedded` pairs are an sde-family "
                "capability (erk/rosenbrock embed via their tableaus)")
        if self.family == "sde" and self.adaptive and not self.error_est:
            # capability tuple derived from what actually shipped
            object.__setattr__(
                self, "error_est",
                ("embedded", "doubling") if self.embedded is not None
                else ("doubling",))
        if "embedded" in self.error_est and self.embedded is None:
            raise ValueError(
                f"method {self.name!r} declares error_est='embedded' but "
                "ships no embedded pair (see repro.core.sde.SDE_EMBEDDED)")


_REGISTRY: Dict[str, MethodSpec] = {}


def register_method(spec: MethodSpec, overwrite: bool = False) -> MethodSpec:
    """Register `spec` under its name and every alias."""
    for key in (spec.name,) + spec.aliases:
        if key in _REGISTRY and not overwrite:
            raise ValueError(f"method {key!r} already registered")
        _REGISTRY[key] = spec
    return spec


def get_method(alg: Any) -> MethodSpec:
    """Resolve `alg` (name, Tableau, or MethodSpec) to a MethodSpec.

    A bare Tableau is wrapped as an ad-hoc erk spec, so user-supplied tableaus
    keep working without registration.
    """
    if isinstance(alg, MethodSpec):
        return alg
    if isinstance(alg, Tableau):
        return MethodSpec(name=alg.name, family="erk", order=alg.order,
                          tableau=alg, adaptive=bool((alg.btilde != 0).any()),
                          resumable=True)
    if isinstance(alg, RosenbrockTableau):
        return MethodSpec(name=alg.name, family="rosenbrock", order=alg.order,
                          rtableau=alg, stiff=True,
                          adaptive=bool((alg.btilde != 0).any()))
    try:
        return _REGISTRY[alg]
    except (KeyError, TypeError):
        raise KeyError(
            f"unknown method {alg!r}; registered: {sorted(set(_REGISTRY))}")


def valid_dispatch(spec: MethodSpec, ensemble: str, backend: str = "xla", *,
                   adaptive: Optional[bool] = None, events: bool = False,
                   w_reuse: bool = False,
                   error_est: Optional[str] = None,
                   sensitivity: Optional[str] = None,
                   data: bool = False) -> Tuple[bool, str]:
    """Is (strategy, backend) a combination the front door would accept?

    Returns ``(ok, reason)`` — the same capability rules
    `repro.core.ensemble.solve_ensemble_local` enforces with exceptions, as a
    boolean predicate, so the autotuner (`repro.core.autotune`) can prune its
    candidate set up front and never spend wall time compiling a combination
    that would raise (events-on-array_eager, non-rosenbrock w_reuse,
    pallas-without-kernel, ...).  Capability checks stay data, not code
    paths: the rules read off the `MethodSpec` flags.
    """
    if ensemble not in STRATEGIES:
        return False, f"unknown ensemble strategy {ensemble!r}"
    if backend not in BACKENDS:
        return False, f"unknown backend {backend!r}"
    if backend == "pallas" and ensemble != "kernel":
        return False, "backend='pallas' is kernel-strategy only"
    if spec.family != "erk" and ensemble == "array_eager":
        return False, f"array_eager is erk-only ({spec.family} family)"
    if events and not spec.events:
        return False, f"method {spec.name!r} declares events=False"
    if events and ensemble == "array_eager":
        return False, "events are not supported on array_eager"
    if w_reuse and spec.family != "rosenbrock":
        return False, "w_reuse is rosenbrock-only (no W to reuse)"
    if data and not spec.data_rhs:
        return False, (f"method {spec.name!r} declares data_rhs=False "
                       "(no data-driven RHS support)")
    if spec.family == "rosenbrock" and not spec.adaptive:
        return False, "rosenbrock engine requires an embedded pair"
    if adaptive and not spec.adaptive:
        return False, f"method {spec.name!r} has no adaptive step control"
    if error_est is not None:
        if spec.family != "sde":
            return False, "error_est is an adaptive-SDE knob"
        if error_est not in spec.error_est:
            return False, (f"method {spec.name!r} supports error_est "
                           f"{spec.error_est}, not {error_est!r}")
    if sensitivity is not None:
        if sensitivity not in ("forward", "adjoint"):
            return False, (f"unknown sensitivity {sensitivity!r} "
                           "(use 'forward' or 'adjoint')")
        if sensitivity not in spec.sensitivity:
            return False, (f"method {spec.name!r} declares "
                           "differentiable=False")
        if ensemble == "array_eager":
            return False, ("array_eager is a host-driven python loop — "
                           "not traceable, so not differentiable")
        if sensitivity == "forward" and backend == "pallas":
            return False, ("forward sensitivities ride jvp through the "
                           "while-loop engines; the Pallas kernels support "
                           "sensitivity='adjoint' (custom_vjp boundary) only")
    return True, "ok"


def list_methods(family: Optional[str] = None):
    """Canonical (deduplicated) specs, optionally filtered by family."""
    seen = {}
    for spec in _REGISTRY.values():
        if family is None or spec.family == family:
            seen[spec.name] = spec
    return [seen[k] for k in sorted(seen)]


# ---------------------------------------------------------------------------
# built-in registrations
# ---------------------------------------------------------------------------

def _register_builtins():
    # every shipped tableau is an erk method (RK4 has btilde == 0: fixed-only);
    # paper-facing "gpu<name>" aliases for the methods the paper benchmarks
    paper_alias = {"tsit5": ("gputsit5",), "vern7": ("gpuvern7",)}
    for tab in TABLEAUS.values():
        register_method(MethodSpec(
            name=tab.name, family="erk", order=tab.order, tableau=tab,
            adaptive=bool((tab.btilde != 0).any()), resumable=True,
            aliases=paper_alias.get(tab.name, ())))

    # Rosenbrock stiff family: every tableau in ROSENBROCK_TABLEAUS reaches
    # every strategy/backend through the same s-stage W-method engine
    # (paper §5.1.3 — GPURosenbrock23 / GPURodas4 / GPURodas5P).
    rb_alias = {"rosenbrock23": ("rb23", "ode23s", "gpurosenbrock23"),
                "rodas4": ("gpurodas4",),
                "rodas5p": ("gpurodas5p", "rodas5")}
    for rtab in ROSENBROCK_TABLEAUS.values():
        register_method(MethodSpec(
            name=rtab.name, family="rosenbrock", order=rtab.order,
            rtableau=rtab, adaptive=bool((rtab.btilde != 0).any()),
            stiff=True, aliases=rb_alias.get(rtab.name, ())))

    # SDE steppers. Fixed-dt by default (the paper's GPU kernel set);
    # adaptive=True records that EVERY stepper gains adaptive error control
    # through the shared engine (`core.sde.sde_solve_adaptive`) when the
    # caller opts in with adaptive=True: an embedded pair where one ships
    # (SDE_EMBEDDED — em, milstein), step doubling everywhere (no per-method
    # pair needed; also the general-noise path).
    from .sde import (SDE_EMBEDDED, em_step, heun_strat_step, milstein_step,
                      platen_w2_step)
    register_method(MethodSpec(
        name="em", family="sde", order=0.5, stepper=em_step, adaptive=True,
        embedded=SDE_EMBEDDED["em"], resumable=True,
        noise=("diagonal", "general"), aliases=("gpuem", "euler_maruyama")))
    register_method(MethodSpec(
        name="platen_w2", family="sde", order=2.0, stepper=platen_w2_step,
        adaptive=True, resumable=True,
        noise=("diagonal",), aliases=("siea", "gpusiea")))
    register_method(MethodSpec(
        name="heun_strat", family="sde", order=0.5, stepper=heun_strat_step,
        adaptive=True, resumable=True, noise=("diagonal", "general")))
    register_method(MethodSpec(
        name="milstein", family="sde", order=1.0, stepper=milstein_step,
        adaptive=True, embedded=SDE_EMBEDDED["milstein"], resumable=True,
        noise=("diagonal",)))


_register_builtins()
