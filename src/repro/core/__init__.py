# The paper's primary contribution — massively-parallel ensemble ODE/SDE
# solving with two strategies (array lock-step vs fused whole-integration
# kernel), adaptive embedded RK with dense output, family-agnostic events,
# fixed-dt AND adaptive SDE steppers, sensitivity analysis and a distributed
# front door (api.solve_ensemble).  See docs/architecture.md for the map.
from .problem import (EnsembleProblem, ODEProblem, SDEProblem,
                      bind_problem_data)
from .interp import UniformTable1D, UniformTable2D, interp1d, interp2d
from .tableaus import (ROSENBROCK_TABLEAUS, TABLEAUS, RosenbrockTableau,
                       get_rosenbrock_tableau, get_tableau)
from .controller import (STATUS_DTMIN_EXHAUSTED, STATUS_MAX_ITERS,
                         STATUS_SUCCESS, PIController, WReusePolicy,
                         hairer_norm, initial_dt)
from .methods import (MethodSpec, get_method, list_methods, register_method,
                      valid_dispatch)
from .autotune import Decision, measure, resolve_auto
from .events import Event
from .solvers import (AdaptiveOptions, SolveResult, interp_step,
                      rk_step, solve_adaptive, solve_fixed, solve_one)
from .ensemble import EnsembleResult, solve_ensemble_local

__all__ = [
    "EnsembleProblem", "ODEProblem", "SDEProblem", "bind_problem_data",
    "UniformTable1D", "UniformTable2D", "interp1d", "interp2d",
    "TABLEAUS", "get_tableau", "ROSENBROCK_TABLEAUS", "RosenbrockTableau",
    "get_rosenbrock_tableau", "PIController", "WReusePolicy", "hairer_norm",
    "initial_dt", "STATUS_SUCCESS", "STATUS_MAX_ITERS",
    "STATUS_DTMIN_EXHAUSTED",
    "MethodSpec", "get_method", "list_methods", "register_method",
    "valid_dispatch", "Decision", "measure", "resolve_auto",
    "AdaptiveOptions", "Event", "SolveResult", "interp_step", "rk_step",
    "solve_adaptive", "solve_fixed", "solve_one",
    "EnsembleResult", "solve_ensemble_local",
]
