"""Rosenbrock stiff ensemble engine — tableau-generic W-methods (paper §5.1.3).

The paper (§7) lists stiff ODEs as unsupported by EnsembleGPUKernel and
describes the enabling primitive (§5.1.3): the block-diagonal W = I - γh·J
solved as N independent small LU factorizations.  This module is the s-stage
generalization of that idea: ONE engine, driven by a `RosenbrockTableau`
(`repro.core.tableaus` — implementation-form γ, a, C, b, b̂, c, d), executes
Rosenbrock23 (2 effective stages), Rodas4 (6) and Rodas5P (8) — and any
future tableau that passes the Rosenbrock order-condition checker
(`repro.core.order_conditions`).

Per step the engine factors W = I − γh·J once and back-substitutes s times —
and with `w_reuse` (the lazy-W hot path) it goes further: J, the factored
LU(W) and the dt it was factored at ride the while_loop carry, refreshed per
lane only when the `WReusePolicy` freshness controller asks (rejection with a
reused J, accepted-error growth, γ-scaled dt drift, age), with an
extrapolated-secant rank-1 touch-up keeping the cached J honest in between
(`repro.core.controller.WReusePolicy`).  The stage solves are:

    g_i   = u + Σ_{j<i} a_ij U_j
    W U_i = γh f(g_i, t + c_i h) + γ Σ_{j<i} C_ij U_j + γ d_i h² f_t
    u1    = u + Σ b_i U_i,    err = Σ btilde_i U_i

The Jacobian comes from the analytic `jac(u, p, t)` hook when the problem
supplies one (`ODEProblem.jac`, threaded through MethodSpec dispatch) and
falls back to forward-mode AD (`jacfwd` — the "automated translation": users
never *have* to write Jacobians).  Linear solves go through the batched-LU
Pallas kernel in lanes mode (`linsolve="pallas"`), the kernel *body* inlined
for fused kernels (`"lanes"`), or vmapped LAPACK (`"jnp"`).

Shape-polymorphic like the RK engine: scalar mode u (n,), lanes mode u (n, B).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .controller import (STATUS_DTMIN_EXHAUSTED, PIController, WReusePolicy,
                         hairer_norm, pi_propose, w_dt_blame, w_mark_stale,
                         w_refresh)
from .events import Event, handle_event, hermite_interp
from .loops import solver_loop
from .solvers import SolveResult
from .tableaus import ROS23W, RosenbrockTableau


def _jac_lanes(f, u, p, t, jac=None):
    """Per-lane Jacobian: u (n, B) -> J (B, n, n).

    Analytic hook: component-style `jac(u, p, t)` broadcasts over the lane
    axis and returns (n, n, B); AD fallback is vmap(jacfwd)."""
    if jac is not None:
        return jnp.moveaxis(jac(u, p, t), -1, 0)
    t_ax = 0 if jnp.ndim(t) else None
    return jax.vmap(jax.jacfwd(f), in_axes=(-1, -1, t_ax))(u, p, t)


# ---------------------------------------------------------------------------
# lazy-W adapters: build / factor / resolve / masked-select per linsolve mode.
# The factored state is an ordinary pytree of arrays, so it can live in the
# adaptive while_loop carry and be refreshed per lane under a mask — the
# "lazy about its linear algebra" hot path (Jacobian & LU(W) reuse ACROSS
# steps, not just across the s stages of one step).
# ---------------------------------------------------------------------------

def _w_build(J, dt, gam, lanes, dtype):
    """W = I − γ·dt·J, same expressions as the eager step (bitwise-stable)."""
    n = J.shape[-1]
    if lanes:
        eye = jnp.eye(n, dtype=dtype)[None]
        gdt = (dt * gam)[:, None, None] if jnp.ndim(dt) else dt * gam
        return eye - gdt * J                               # (B, n, n)
    return jnp.eye(n, dtype=dtype) - dt * gam * J          # (n, n)


def _w_factor(W, mode, lanes):
    """Mode-specific factorization -> carry-able pytree.

    "jnp"/scalar: LAPACK (lu, piv); "lanes": the pivoted lanes-LU kernel body
    (rows/swaps/mults/pivmin lists — a pytree); "pallas": the factorization
    cannot persist across a `pallas_call` boundary, so the carried state is W
    itself and each resolve launches the batched kernel (J reuse still saves
    the expensive jac/jacfwd passes; `nfact` then counts W rebuilds)."""
    if not lanes or mode in ("jnp", None):
        return jax.scipy.linalg.lu_factor(W)
    if mode == "lanes":
        from repro.kernels.lu.kernel import lu_factor_lanes
        return lu_factor_lanes(jnp.moveaxis(W, 0, -1))
    if mode == "pallas":
        return W
    raise ValueError(f"unknown linsolve mode {mode!r}")


def _w_resolve(fac, rhs, mode, lanes, lane_tile):
    """Back-substitute one right-hand side against a `_w_factor` state."""
    if not lanes:
        return jax.scipy.linalg.lu_solve(fac, rhs)
    if mode in ("jnp", None):
        return jax.scipy.linalg.lu_solve(fac, rhs.T[..., None])[..., 0].T
    if mode == "lanes":
        from repro.kernels.lu.kernel import lu_resolve_lanes
        return lu_resolve_lanes(fac, rhs)
    if mode == "pallas":
        from repro.kernels.lu.ops import batched_solve
        return batched_solve(fac, rhs.T, lane_tile=lane_tile).T
    raise ValueError(f"unknown linsolve mode {mode!r}")


def _secant_update(J, du, dF, gain, mask, lanes):
    """Extrapolated-secant (Broyden) touch-up of the cached Jacobian.

    J ← J + gain·(ΔF − J·Δu)·Δuᵀ/(Δuᵀ·Δu) on lanes where `mask` holds —
    rank-1, O(n²), no RHS evaluations (ΔF reuses the f(u) values the stage
    loop computes anyway).  gain=2 extrapolates the secant midpoint to the
    endpoint state (exact along Δu for J affine in u — quadratic RHS).
    Skipped where Δu = 0 or the correction is non-finite."""
    if lanes:
        nn = jnp.sum(du * du, axis=0)                      # (B,)
        Jdu = jnp.sum(J * du.T[:, None, :], axis=-1).T     # (n, B)
        r = dF - Jdu
        corr = (r.T[:, :, None] * du.T[:, None, :]
                / jnp.where(nn > 0, nn, 1.0)[:, None, None])   # (B, n, n)
        ok = (mask & (nn > 0)
              & jnp.all(jnp.isfinite(corr), axis=(1, 2)))[:, None, None]
    else:
        nn = jnp.sum(du * du)
        corr = (jnp.outer(dF - J @ du, du)
                / jnp.where(nn > 0, nn, 1.0))
        ok = mask & (nn > 0) & jnp.all(jnp.isfinite(corr))
    return jnp.where(ok, J + gain * corr, J)


def _w_select(mask, fac_new, fac_old, mode, lanes):
    """Per-lane masked refresh of the factored state (mask: scalar or (B,))."""
    if not lanes or mode == "lanes":
        # scalar mode: scalar mask; "lanes" leaves are (n, B)/(B,) —
        # trailing-lane axis, so a (B,) mask broadcasts as-is
        sel = lambda a, b: jnp.where(mask, a, b)
    else:
        # "jnp" (lu (B,n,n), piv (B,n)) and "pallas" (W (B,n,n)): leading-B
        sel = lambda a, b: jnp.where(
            mask.reshape(mask.shape + (1,) * (a.ndim - mask.ndim)), a, b)
    return jax.tree_util.tree_map(sel, fac_new, fac_old)


def rosenbrock_nf_per_step(rtab: RosenbrockTableau) -> int:
    """RHS evaluations per step: one per stage, plus f(u1) for Hermite dense
    output unless the tableau ships interpolation weights or its last stage
    argument already IS u1 (ROS23W).  Jacobian/f_t AD passes are not counted
    (same convention as the previous 2-stage engine)."""
    extra = 0 if (rtab.interp_h is not None or rtab.fnew_from_last_stage) else 1
    return rtab.stages + extra


def rosenbrock_step(f, rtab: RosenbrockTableau, u, p, t, dt, *, lanes=False,
                    linsolve="jnp", lane_tile=None, jac=None):
    """One s-stage W-method step.

    Returns (u_new, err, F0, F_new, kds): F_new is f(u_new, t+dt) (reused from
    the last stage when the tableau is stiffly accurate with g_s = u1, or
    None when the tableau interpolates from its own stages); kds are the
    dense-output vectors kd_l = Σ_j interp_h[l, j] U_j (empty tuple if none).
    """
    dtype = u.dtype
    gam = rtab.gamma
    if lanes:
        J = _jac_lanes(f, u, p, t, jac)                 # (B, n, n)
    else:
        J = (jac(u, p, t) if jac is not None
             else jax.jacfwd(lambda uu: f(uu, p, t))(u))  # (n, n)
    # ONE factorization per step, s resolves — the same build/factor/resolve
    # adapters the lazy-W carry uses, so eager and lazy stay one dispatch
    fac = _w_factor(_w_build(J, dt, gam, lanes, dtype), linsolve, lanes)
    return _stage_loop(f, rtab, u, p, t, dt,
                       lambda rhs: _w_resolve(fac, rhs, linsolve, lanes,
                                              lane_tile))


def _stage_loop(f, rtab: RosenbrockTableau, u, p, t, dt, solve, F0=None):
    """The s per-stage solves against an already-factored W (`solve` is a
    rhs -> x closure).  Shared by the eager step above and the lazy-W
    while_loop body (which carries the factorization across steps and passes
    the f(u) it already computed for the secant touch-up as `F0`)."""
    s = rtab.stages
    gam = rtab.gamma
    a, C, d = rtab.a, rtab.C, rtab.d
    dtb = dt if jnp.ndim(dt) == 0 else dt[None]
    Td = jax.jvp(lambda tt: f(u, p, tt), (t,),
                 (jnp.ones_like(t),))[1]                # df/dt
    if F0 is None:
        F0 = f(u, p, t)
    Us = []
    F_last = F0
    for i in range(s):
        if i == 0:
            Fi = F0
        else:
            g = u
            for j in range(i):
                if a[i, j] != 0.0:
                    g = g + a[i, j] * Us[j]
            Fi = f(g, p, t + rtab.c[i] * dt)
        rhs = (gam * dtb) * Fi
        for j in range(i):
            if C[i, j] != 0.0:
                rhs = rhs + (gam * C[i, j]) * Us[j]
        if d[i] != 0.0:
            rhs = rhs + (gam * d[i]) * dtb * dtb * Td
        Us.append(solve(rhs))
        F_last = Fi
    u_new = u
    err = jnp.zeros_like(u)
    for i in range(s):
        if rtab.b[i] != 0.0:
            u_new = u_new + rtab.b[i] * Us[i]
        if rtab.btilde[i] != 0.0:
            err = err + rtab.btilde[i] * Us[i]
    if rtab.interp_h is not None:
        kds = tuple(
            sum((rtab.interp_h[l, j] * Us[j] for j in range(s)
                 if rtab.interp_h[l, j] != 0.0), jnp.zeros_like(u))
            for l in range(rtab.interp_h.shape[0]))
        F_new = None
    else:
        kds = ()
        F_new = (F_last if rtab.fnew_from_last_stage
                 else f(u_new, p, t + dt))
    return u_new, err, F0, F_new, kds


def rosenbrock23_step(f, u, p, t, dt, *, lanes=False, linsolve="jnp",
                      lane_tile=None):
    """Backwards-compatible ROS23 step. Returns (u_new, err, F0, F2)."""
    u_new, err, F0, F_new, _ = rosenbrock_step(
        f, ROS23W, u, p, t, dt, lanes=lanes, linsolve=linsolve,
        lane_tile=lane_tile)
    return u_new, err, F0, F_new


def _dense_eval(rtab, th, u_old, u_cand, F0, F_new, kds, dtb):
    """Dense output at pre-broadcast theta `th` (same rank as the states).

    Stiffly-accurate tableau weights when the tableau ships them:
        u(θ) = (1−θ)·u0 + θ·u1 + θ(1−θ)·(kd1 + θ·kd2 + ...)
    else cubic Hermite on (u0, F0, u1, F_new) — the shared basis from
    `repro.core.events` (lanes=False: th/dtb arrive pre-broadcast)."""
    if rtab.interp_h is not None:
        inner = kds[-1]
        for kd in kds[-2::-1]:
            inner = kd + th * inner
        return (1.0 - th) * u_old + th * u_cand + th * (1.0 - th) * inner
    return hermite_interp(u_old, F0, u_cand, F_new, dtb, th, lanes=False)


def solve_rosenbrock(f, rtab: RosenbrockTableau, u0, p, t0, tf, dt0, *,
                     rtol=1e-6, atol=1e-6, saveat=None, max_iters=100_000,
                     lanes=False, linsolve="jnp", lane_tile=None, jac=None,
                     controller: Optional[PIController] = None,
                     event: Optional[Event] = None, w_reuse=None,
                     batch_axis: Optional[str] = None, bounded_steps=None,
                     checkpoint_every=None):
    """Adaptive s-stage Rosenbrock solve with dense output.

    `jac` is the analytic-Jacobian hook (component-style (u, p, t) -> (n, n)
    resp. (n, n, B)); None falls back to `jacfwd`.  `event` threads the shared
    event machinery (`repro.core.events`) through the stiff family: detection
    + bisection refinement run on the method's dense output (the tableau's
    stiffly-accurate interpolant when it ships one, Hermite cubic otherwise)
    with per-lane termination masks in lanes mode.  When an event is supplied
    the return value is ``(SolveResult, {"event_t", "event_count"})`` — the
    same contract as `solve_adaptive`.

    `w_reuse` makes the step loop lazy about its linear algebra: the current
    Jacobian, the factored LU(W) and the dt it was factored at ride in the
    while_loop carry, and J is only re-evaluated / W only re-factored when
    the `WReusePolicy` freshness controller asks (see
    `repro.core.controller`).  ``None``/``False`` keeps today's eager
    every-step behaviour bitwise (the carry does not even contain the lazy
    state); ``True`` enables the default policy; a `WReusePolicy` instance
    customizes the thresholds.  `SolveResult.njac`/`nfact` report the work
    either way (eager: both equal naccept + nreject).

    The refresh runs under an any()-gated `lax.cond`, so the counter savings
    are real wall time on every path.  On the lanes paths (array / kernel)
    `jnp.any` already reduces over the batch.  Under `vmap` a plain
    `jnp.any` predicate is per-trajectory — BATCHED — and vmap lowers a
    batched cond to a select that executes BOTH branches every step; callers
    that vmap this solver must bind an axis name
    (``jax.vmap(one, axis_name=ax)``) and pass it as ``batch_axis=ax``: the
    predicates are then `psum`-reduced over the vmap axis, which yields an
    UNBATCHED boolean, keeps the cond a genuine branch, and makes the
    refresh genuinely skippable (jacfwd + O(n³) elimination not executed)
    whenever no trajectory in the batch asked for it.
    `repro.core.ensemble.solve_ensemble_local` wires this automatically for
    ``ensemble="vmap"``.

    ``bounded_steps``/``checkpoint_every`` select the reverse-differentiable
    bounded loop (`repro.core.loops.solver_loop`) with the frozen-step
    discrete adjoint: the controller/freshness chain is severed from the
    autodiff graph and the differentiated stage solves re-run at
    ``where(accept, dt, 0)``, so the reverse pass only transposes accepted
    steps.  Same step sequence as the while path whenever the bound covers
    the true iteration count (too small => ``status == 1``).
    """
    policy = (None if (w_reuse is None or w_reuse is False)
              else (w_reuse if isinstance(w_reuse, WReusePolicy)
                    else WReusePolicy()))
    dtype = u0.dtype
    q = min(rtab.order, rtab.embedded_order)  # order the estimator measures
    ctrl = controller or PIController.for_order(q)
    nf_step = rosenbrock_nf_per_step(rtab)
    cshape = (u0.shape[-1],) if lanes else ()
    axes = 0 if lanes else None
    t0 = jnp.asarray(t0, dtype)
    tf = jnp.asarray(tf, dtype)
    if saveat is None:
        saveat = jnp.asarray([tf], dtype)
    saveat = jnp.asarray(saveat, dtype)
    S = saveat.shape[0]
    us0 = jnp.zeros((S,) + u0.shape, dtype)
    pre = (saveat <= t0).reshape((S,) + (1,) * u0.ndim)
    us0 = jnp.where(pre, u0[None], us0)

    gam = rtab.gamma

    def jac_eval(u, t):
        if lanes:
            return _jac_lanes(f, u, p, t, jac)
        return (jac(u, p, t) if jac is not None
                else jax.jacfwd(lambda uu: f(uu, p, t))(u))

    def any_lane(x):
        # cond predicate that is UNIFORM over the whole ensemble batch.
        # In lanes mode jnp.any already reduces over the (B,) lane axis;
        # under vmap it is a per-trajectory (batched) bool, and a batched
        # cond lowers to a select executing both branches — psum over the
        # caller-bound vmap axis returns an unbatched scalar, keeping the
        # refresh cond a real branch (see the docstring).
        a = jnp.any(x)
        if batch_axis is not None:
            a = jax.lax.psum(a.astype(jnp.int32), batch_axis) > 0
        return a

    carry0 = dict(
        t=jnp.broadcast_to(t0, cshape), u=u0,
        dt=jnp.broadcast_to(jnp.asarray(dt0, dtype), cshape),
        enorm_prev=jnp.ones(cshape, dtype),
        done=jnp.zeros(cshape, bool), us=us0,
        naccept=jnp.zeros(cshape, jnp.int32),
        nreject=jnp.zeros(cshape, jnp.int32),
        status=jnp.zeros(cshape, jnp.int32),
        iters=jnp.asarray(0, jnp.int32),
        event_t=jnp.full(cshape, jnp.inf, dtype),
        event_count=jnp.zeros(cshape, jnp.int32))
    if policy is not None:
        # lazy-W state: everything the freshness controller needs to decide,
        # per lane, whether this step may ride on last step's linear algebra
        J0 = jac_eval(u0, carry0["t"])
        fac0 = _w_factor(_w_build(J0, carry0["dt"], gam, lanes, dtype),
                         linsolve, lanes)
        carry0.update(
            J=J0, fac=fac0, dt_fact=carry0["dt"],
            age=jnp.zeros(cshape, jnp.int32),
            jac_stale=jnp.zeros(cshape, bool),
            u_prev=u0, F_prev=jnp.zeros_like(u0),
            was_accept=jnp.zeros(cshape, bool),
            njac=jnp.ones(cshape, jnp.int32),
            nfact=jnp.ones(cshape, jnp.int32))

    def _bc(v):
        return v if jnp.ndim(v) == 0 else v[None]

    def cond(c):
        return (c["iters"] < max_iters) & jnp.any(~c["done"])

    bounded = bounded_steps is not None

    def body(c):
        t, u, dt = c["t"], c["u"], c["dt"]
        active = ~c["done"]
        # done lanes step at dt = 0 — an exact no-op of the stage solves
        # (output-invariant either way, but nonzero dt lets finished lanes
        # synthesize garbage that would poison the reverse pass via 0 * inf)
        dt_step = jnp.where(active, jnp.minimum(dt, tf - t),
                            jnp.asarray(0.0, dtype))
        if policy is None:
            u_cand, err, F0, F_new, kds = rosenbrock_step(
                f, rtab, u, p, t, dt_step, lanes=lanes, linsolve=linsolve,
                lane_tile=lane_tile, jac=jac)
        else:
            need_jac, drift_fact = w_refresh(policy, gam, dt_step,
                                             c["dt_fact"], c["jac_stale"])
            need_jac = need_jac & active
            F0 = f(u, p, t)
            if policy.secant:
                # keep the cached J alive: extrapolated-secant touch-up from
                # the accepted step's own states/RHS values (rank-1, O(n²))
                upd = c["was_accept"] & ~need_jac & active
                J_base = _secant_update(c["J"], u - c["u_prev"],
                                        F0 - c["F_prev"], policy.secant,
                                        upd, lanes)
            else:
                upd = jnp.zeros(cshape, bool)
                J_base = c["J"]
            need_fact = (drift_fact | upd) & active
            # without secant updates, dt freezes AT dt_fact between
            # refreshes (the LSODA/BDF amortization pattern): the factored W
            # is reused VERBATIM and the PI proposal takes effect —
            # quantized — once it drifts out of the γ-scaled band
            dt_step = jnp.where(
                need_fact, dt_step,
                jnp.where(active, jnp.minimum(c["dt_fact"], tf - t),
                          jnp.asarray(0.0, dtype)))

            def refresh(state):
                J_old, fac_old, dtf_old = state
                J_new = jax.lax.cond(any_lane(need_jac),
                                     lambda: jac_eval(u, t), lambda: J_old)
                jmask = (need_jac[:, None, None] if lanes else need_jac)
                J_sel = jnp.where(jmask, J_new, J_old)
                fac_new = _w_factor(_w_build(J_sel, dt_step, gam, lanes,
                                             dtype), linsolve, lanes)
                fac_sel = _w_select(need_fact, fac_new, fac_old,
                                    linsolve, lanes)
                return (J_sel, fac_sel,
                        jnp.where(need_fact, dt_step, dtf_old))

            J, fac, dt_fact = jax.lax.cond(
                any_lane(need_fact), refresh, lambda s: s,
                (J_base, c["fac"], c["dt_fact"]))
            u_cand, err, _, F_new, kds = _stage_loop(
                f, rtab, u, p, t, dt_step,
                lambda rhs: _w_resolve(fac, rhs, linsolve, lanes, lane_tile),
                F0=F0)
        enorm = hairer_norm(err, u, u_cand, atol, rtol, axes=axes)
        if bounded:
            # Frozen-step discrete adjoint: the controller/freshness chain is
            # severed from the autodiff graph — we differentiate the realized
            # step sequence, not the step-size policy.
            enorm = jax.lax.stop_gradient(enorm)
        finite = jnp.isfinite(u_cand)
        finite = jnp.all(finite, axis=0) if lanes else jnp.all(finite)
        accept = (enorm <= 1.0) & finite & active
        dt_next, enorm_prev = pi_propose(ctrl, dt, enorm, c["enorm_prev"],
                                         accept)
        if policy is not None and not policy.secant:
            # frozen-J rejection: refresh and retry at the SAME dt before
            # blaming (and slashing) the step size.  With secant updates the
            # cached J already tracks the state, so a rejection is a genuine
            # dt problem and the PI shrink stands.
            dt_next = w_dt_blame(accept, need_jac, dt_step, dt_next)
        dt_try = dt_step   # pre-adjoint-mask attempt size (dtmin-floor check)
        if bounded:
            # Adjoint-safe second pass (same pattern as solvers.solve_adaptive):
            # the cascade above was a primal-only probe; re-run the stage
            # solves at where(accept, dt, 0) — an exact no-op on rejected
            # attempts — so the reverse pass never transposes a stage solve
            # at an off-trajectory (possibly overflowed) rejected candidate.
            dt_step = jnp.where(accept, dt_step, jnp.asarray(0.0, dtype))
            if policy is None:
                u_cand, err, F0, F_new, kds = rosenbrock_step(
                    f, rtab, u, p, t, dt_step, lanes=lanes, linsolve=linsolve,
                    lane_tile=lane_tile, jac=jac)
            else:
                u_cand, err, _, F_new, kds = _stage_loop(
                    f, rtab, u, p, t, dt_step,
                    lambda rhs: _w_resolve(fac, rhs, linsolve, lanes,
                                           lane_tile),
                    F0=F0)
        t_new = jnp.where(accept, t + dt_step, t)

        # ---- events: shared machinery on the method's dense output ---------
        if event is not None:
            def interp_fn(theta):
                th = theta[None] if lanes else theta
                return _dense_eval(rtab, th, u, u_cand, F0, F_new, kds,
                                   dt_step if jnp.ndim(dt_step) == 0
                                   else dt_step[None])

            u_next, t_new, ev_t, ev_n, term = handle_event(
                event, interp_fn, u, u_cand, p, t, dt_step, t_new, accept,
                c["event_t"], c["event_count"], lanes=lanes)
        else:
            u_next = u_cand
            ev_t, ev_n = c["event_t"], c["event_count"]
            term = jnp.zeros(cshape, bool)

        u_new = jnp.where(_bc(accept), u_next, u)

        # dense-output grid save
        eps = 1e-7 * jnp.maximum(jnp.abs(t_new), 1.0)
        if lanes:
            crossed = ((saveat[:, None] > t[None]) &
                       (saveat[:, None] <= t_new[None] + eps[None]) &
                       accept[None])
            theta = jnp.clip((saveat[:, None] - t[None])
                             / jnp.where(dt_step[None] == 0, 1.0,
                                         dt_step[None]), 0.0, 1.0)
            th = theta[:, None, :]
            dtb = dt_step[None, None, :]
            mask = crossed[:, None, :]
        else:
            crossed = (saveat > t) & (saveat <= t_new + eps) & accept
            theta = jnp.clip((saveat - t)
                             / jnp.where(dt_step == 0, 1.0, dt_step),
                             0.0, 1.0)
            sh = (S,) + (1,) * u.ndim
            th = theta.reshape(sh)
            dtb = dt_step
            mask = crossed.reshape(sh)
        vals = _dense_eval(rtab, th, u[None], u_cand[None],
                           None if F0 is None else F0[None],
                           None if F_new is None else F_new[None],
                           tuple(kd[None] for kd in kds), dtb)
        us = jnp.where(mask, vals, c["us"])

        # dt pinned at the controller floor and still rejecting: the retry is
        # bit-identical, so the lane can never recover — terminate with a
        # distinct status instead of spinning silently to max_iters.  On the
        # lazy path a rejection taken on a REUSED J is exempt: the next
        # attempt refreshes J (w_mark_stale / w_dt_blame), so its retry is
        # NOT identical and may well accept at the same dt.
        hopeless = active & ~accept & ~(dt_try > ctrl.dtmin)
        if policy is not None:
            hopeless = hopeless & need_jac
        statusv = jnp.where(hopeless,
                            jnp.asarray(STATUS_DTMIN_EXHAUSTED, jnp.int32),
                            c["status"])
        done = (c["done"] | term | hopeless
                | (t_new >= tf - 1e-7 * jnp.maximum(jnp.abs(tf), 1.0)))
        out = dict(t=t_new, u=u_new, dt=dt_next, enorm_prev=enorm_prev,
                   done=done, us=us,
                   naccept=c["naccept"] + accept.astype(jnp.int32),
                   nreject=c["nreject"] + (active & ~accept).astype(jnp.int32),
                   status=statusv, iters=c["iters"] + 1,
                   event_t=ev_t, event_count=ev_n)
        if policy is not None:
            fresh = need_jac
            age = jnp.where(need_jac, 0, c["age"]) + accept.astype(jnp.int32)
            out.update(
                J=J, fac=fac, dt_fact=dt_fact, age=age,
                jac_stale=w_mark_stale(policy, accept, enorm,
                                       c["enorm_prev"], age, fresh),
                u_prev=jnp.where(_bc(accept), u, c["u_prev"]),
                F_prev=jnp.where(_bc(accept), F0, c["F_prev"]),
                was_accept=accept,
                njac=c["njac"] + need_jac.astype(jnp.int32),
                nfact=c["nfact"] + need_fact.astype(jnp.int32))
        return out

    out = solver_loop(cond, body, carry0, bounded_steps=bounded_steps,
                      checkpoint_every=checkpoint_every)
    nsteps = out["naccept"] + out["nreject"]
    res = SolveResult(
        ts=saveat, us=out["us"], t_final=out["t"], u_final=out["u"],
        naccept=out["naccept"], nreject=out["nreject"],
        status=jnp.where(out["status"] > 0, out["status"],
                         jnp.where(out["done"], 0, 1)).astype(jnp.int32),
        nf=nsteps * nf_step,
        njac=out["njac"] if policy is not None else nsteps,
        nfact=out["nfact"] if policy is not None else nsteps)
    if event is not None:
        return res, dict(event_t=out["event_t"], event_count=out["event_count"])
    return res


def solve_rosenbrock23(f, u0, p, t0, tf, dt0, *, rtol=1e-6, atol=1e-6,
                       saveat=None, max_iters=100_000, lanes=False,
                       linsolve="jnp", lane_tile=None,
                       controller: Optional[PIController] = None,
                       event: Optional[Event] = None):
    """Rosenbrock23 through the generic engine (backwards-compatible entry)."""
    return solve_rosenbrock(f, ROS23W, u0, p, t0, tf, dt0, rtol=rtol,
                            atol=atol, saveat=saveat, max_iters=max_iters,
                            lanes=lanes, linsolve=linsolve,
                            lane_tile=lane_tile, controller=controller,
                            event=event)
