"""Rosenbrock stiff ensemble engine — tableau-generic W-methods (paper §5.1.3).

The paper (§7) lists stiff ODEs as unsupported by EnsembleGPUKernel and
describes the enabling primitive (§5.1.3): the block-diagonal W = I - γh·J
solved as N independent small LU factorizations.  This module is the s-stage
generalization of that idea: ONE engine, driven by a `RosenbrockTableau`
(`repro.core.tableaus` — implementation-form γ, a, C, b, b̂, c, d), executes
Rosenbrock23 (2 effective stages), Rodas4 (6) and Rodas5P (8) — and any
future tableau that passes the Rosenbrock order-condition checker
(`repro.core.order_conditions`).

Per step the engine factors W = I − γh·J once and back-substitutes s times:

    g_i   = u + Σ_{j<i} a_ij U_j
    W U_i = γh f(g_i, t + c_i h) + γ Σ_{j<i} C_ij U_j + γ d_i h² f_t
    u1    = u + Σ b_i U_i,    err = Σ btilde_i U_i

The Jacobian comes from the analytic `jac(u, p, t)` hook when the problem
supplies one (`ODEProblem.jac`, threaded through MethodSpec dispatch) and
falls back to forward-mode AD (`jacfwd` — the "automated translation": users
never *have* to write Jacobians).  Linear solves go through the batched-LU
Pallas kernel in lanes mode (`linsolve="pallas"`), the kernel *body* inlined
for fused kernels (`"lanes"`), or vmapped LAPACK (`"jnp"`).

Shape-polymorphic like the RK engine: scalar mode u (n,), lanes mode u (n, B).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .controller import PIController, hairer_norm, pi_propose
from .events import Event, handle_event, hermite_interp
from .solvers import SolveResult
from .tableaus import ROS23W, RosenbrockTableau


def _jac_lanes(f, u, p, t, jac=None):
    """Per-lane Jacobian: u (n, B) -> J (B, n, n).

    Analytic hook: component-style `jac(u, p, t)` broadcasts over the lane
    axis and returns (n, n, B); AD fallback is vmap(jacfwd)."""
    if jac is not None:
        return jnp.moveaxis(jac(u, p, t), -1, 0)
    t_ax = 0 if jnp.ndim(t) else None
    return jax.vmap(jax.jacfwd(f), in_axes=(-1, -1, t_ax))(u, p, t)


def _make_linsolver(W, mode, lane_tile):
    """Factor W ONCE, return a rhs -> x closure for the s per-stage solves.

    W (n, n) scalar mode or (B, n, n) lanes mode; rhs/x are (n,) resp.
    (n, B).  modes: "jnp" (LAPACK lu_factor, batched over B), "lanes" (the
    pivoted LU kernel *body* factored in place — no nested pallas_call, used
    when the whole Rosenbrock integration already runs inside a fused
    kernel), "pallas" (batched-LU Pallas kernel launch; one launch per
    stage — a kernel boundary cannot hold factored state)."""
    if W.ndim == 2 or mode == "jnp" or mode is None:
        lu_piv = jax.scipy.linalg.lu_factor(W)      # batched over leading dim
        if W.ndim == 2:
            return lambda rhs: jax.scipy.linalg.lu_solve(lu_piv, rhs)
        return lambda rhs: jax.scipy.linalg.lu_solve(
            lu_piv, rhs.T[..., None])[..., 0].T
    if mode == "lanes":
        from repro.kernels.lu.kernel import lu_factor_lanes, lu_resolve_lanes
        fac = lu_factor_lanes(jnp.moveaxis(W, 0, -1))
        return lambda rhs: lu_resolve_lanes(fac, rhs)
    if mode == "pallas":
        from repro.kernels.lu.ops import batched_solve
        return lambda rhs: batched_solve(W, rhs.T, lane_tile=lane_tile).T
    raise ValueError(f"unknown linsolve mode {mode!r}")


def rosenbrock_nf_per_step(rtab: RosenbrockTableau) -> int:
    """RHS evaluations per step: one per stage, plus f(u1) for Hermite dense
    output unless the tableau ships interpolation weights or its last stage
    argument already IS u1 (ROS23W).  Jacobian/f_t AD passes are not counted
    (same convention as the previous 2-stage engine)."""
    extra = 0 if (rtab.interp_h is not None or rtab.fnew_from_last_stage) else 1
    return rtab.stages + extra


def rosenbrock_step(f, rtab: RosenbrockTableau, u, p, t, dt, *, lanes=False,
                    linsolve="jnp", lane_tile=None, jac=None):
    """One s-stage W-method step.

    Returns (u_new, err, F0, F_new, kds): F_new is f(u_new, t+dt) (reused from
    the last stage when the tableau is stiffly accurate with g_s = u1, or
    None when the tableau interpolates from its own stages); kds are the
    dense-output vectors kd_l = Σ_j interp_h[l, j] U_j (empty tuple if none).
    """
    dtype = u.dtype
    n = u.shape[0]
    s = rtab.stages
    gam = rtab.gamma
    a, C, d = rtab.a, rtab.C, rtab.d
    dtb = dt if jnp.ndim(dt) == 0 else dt[None]
    if lanes:
        J = _jac_lanes(f, u, p, t, jac)                 # (B, n, n)
        eye = jnp.eye(n, dtype=dtype)[None]
        gdt = (dt * gam)[:, None, None] if jnp.ndim(dt) else dt * gam
        W = eye - gdt * J
    else:
        J = (jac(u, p, t) if jac is not None
             else jax.jacfwd(lambda uu: f(uu, p, t))(u))  # (n, n)
        W = jnp.eye(n, dtype=dtype) - dt * gam * J
    Td = jax.jvp(lambda tt: f(u, p, tt), (t,),
                 (jnp.ones_like(t),))[1]                # df/dt
    F0 = f(u, p, t)
    solve = _make_linsolver(W, linsolve, lane_tile)     # ONE factorization
    Us = []
    F_last = F0
    for i in range(s):
        if i == 0:
            Fi = F0
        else:
            g = u
            for j in range(i):
                if a[i, j] != 0.0:
                    g = g + a[i, j] * Us[j]
            Fi = f(g, p, t + rtab.c[i] * dt)
        rhs = (gam * dtb) * Fi
        for j in range(i):
            if C[i, j] != 0.0:
                rhs = rhs + (gam * C[i, j]) * Us[j]
        if d[i] != 0.0:
            rhs = rhs + (gam * d[i]) * dtb * dtb * Td
        Us.append(solve(rhs))
        F_last = Fi
    u_new = u
    err = jnp.zeros_like(u)
    for i in range(s):
        if rtab.b[i] != 0.0:
            u_new = u_new + rtab.b[i] * Us[i]
        if rtab.btilde[i] != 0.0:
            err = err + rtab.btilde[i] * Us[i]
    if rtab.interp_h is not None:
        kds = tuple(
            sum((rtab.interp_h[l, j] * Us[j] for j in range(s)
                 if rtab.interp_h[l, j] != 0.0), jnp.zeros_like(u))
            for l in range(rtab.interp_h.shape[0]))
        F_new = None
    else:
        kds = ()
        F_new = (F_last if rtab.fnew_from_last_stage
                 else f(u_new, p, t + dt))
    return u_new, err, F0, F_new, kds


def rosenbrock23_step(f, u, p, t, dt, *, lanes=False, linsolve="jnp",
                      lane_tile=None):
    """Backwards-compatible ROS23 step. Returns (u_new, err, F0, F2)."""
    u_new, err, F0, F_new, _ = rosenbrock_step(
        f, ROS23W, u, p, t, dt, lanes=lanes, linsolve=linsolve,
        lane_tile=lane_tile)
    return u_new, err, F0, F_new


def _dense_eval(rtab, th, u_old, u_cand, F0, F_new, kds, dtb):
    """Dense output at pre-broadcast theta `th` (same rank as the states).

    Stiffly-accurate tableau weights when the tableau ships them:
        u(θ) = (1−θ)·u0 + θ·u1 + θ(1−θ)·(kd1 + θ·kd2 + ...)
    else cubic Hermite on (u0, F0, u1, F_new) — the shared basis from
    `repro.core.events` (lanes=False: th/dtb arrive pre-broadcast)."""
    if rtab.interp_h is not None:
        inner = kds[-1]
        for kd in kds[-2::-1]:
            inner = kd + th * inner
        return (1.0 - th) * u_old + th * u_cand + th * (1.0 - th) * inner
    return hermite_interp(u_old, F0, u_cand, F_new, dtb, th, lanes=False)


def solve_rosenbrock(f, rtab: RosenbrockTableau, u0, p, t0, tf, dt0, *,
                     rtol=1e-6, atol=1e-6, saveat=None, max_iters=100_000,
                     lanes=False, linsolve="jnp", lane_tile=None, jac=None,
                     controller: Optional[PIController] = None,
                     event: Optional[Event] = None):
    """Adaptive s-stage Rosenbrock solve with dense output.

    `jac` is the analytic-Jacobian hook (component-style (u, p, t) -> (n, n)
    resp. (n, n, B)); None falls back to `jacfwd`.  `event` threads the shared
    event machinery (`repro.core.events`) through the stiff family: detection
    + bisection refinement run on the method's dense output (the tableau's
    stiffly-accurate interpolant when it ships one, Hermite cubic otherwise)
    with per-lane termination masks in lanes mode.  When an event is supplied
    the return value is ``(SolveResult, {"event_t", "event_count"})`` — the
    same contract as `solve_adaptive`.
    """
    dtype = u0.dtype
    q = min(rtab.order, rtab.embedded_order)  # order the estimator measures
    ctrl = controller or PIController.for_order(q)
    nf_step = rosenbrock_nf_per_step(rtab)
    cshape = (u0.shape[-1],) if lanes else ()
    axes = 0 if lanes else None
    t0 = jnp.asarray(t0, dtype)
    tf = jnp.asarray(tf, dtype)
    if saveat is None:
        saveat = jnp.asarray([tf], dtype)
    saveat = jnp.asarray(saveat, dtype)
    S = saveat.shape[0]
    us0 = jnp.zeros((S,) + u0.shape, dtype)
    pre = (saveat <= t0).reshape((S,) + (1,) * u0.ndim)
    us0 = jnp.where(pre, u0[None], us0)

    carry0 = dict(
        t=jnp.broadcast_to(t0, cshape), u=u0,
        dt=jnp.broadcast_to(jnp.asarray(dt0, dtype), cshape),
        enorm_prev=jnp.ones(cshape, dtype),
        done=jnp.zeros(cshape, bool), us=us0,
        naccept=jnp.zeros(cshape, jnp.int32),
        nreject=jnp.zeros(cshape, jnp.int32),
        iters=jnp.asarray(0, jnp.int32),
        event_t=jnp.full(cshape, jnp.inf, dtype),
        event_count=jnp.zeros(cshape, jnp.int32))

    def _bc(v):
        return v if jnp.ndim(v) == 0 else v[None]

    def cond(c):
        return (c["iters"] < max_iters) & jnp.any(~c["done"])

    def body(c):
        t, u, dt = c["t"], c["u"], c["dt"]
        active = ~c["done"]
        dt_step = jnp.where(active, jnp.minimum(dt, tf - t),
                            jnp.asarray(1.0, dtype))
        u_cand, err, F0, F_new, kds = rosenbrock_step(
            f, rtab, u, p, t, dt_step, lanes=lanes, linsolve=linsolve,
            lane_tile=lane_tile, jac=jac)
        enorm = hairer_norm(err, u, u_cand, atol, rtol, axes=axes)
        finite = jnp.isfinite(u_cand)
        finite = jnp.all(finite, axis=0) if lanes else jnp.all(finite)
        accept = (enorm <= 1.0) & finite & active
        dt_next, enorm_prev = pi_propose(ctrl, dt, enorm, c["enorm_prev"],
                                         accept)
        t_new = jnp.where(accept, t + dt_step, t)

        # ---- events: shared machinery on the method's dense output ---------
        if event is not None:
            def interp_fn(theta):
                th = theta[None] if lanes else theta
                return _dense_eval(rtab, th, u, u_cand, F0, F_new, kds,
                                   dt_step if jnp.ndim(dt_step) == 0
                                   else dt_step[None])

            u_next, t_new, ev_t, ev_n, term = handle_event(
                event, interp_fn, u, u_cand, p, t, dt_step, t_new, accept,
                c["event_t"], c["event_count"], lanes=lanes)
        else:
            u_next = u_cand
            ev_t, ev_n = c["event_t"], c["event_count"]
            term = jnp.zeros(cshape, bool)

        u_new = jnp.where(_bc(accept), u_next, u)

        # dense-output grid save
        eps = 1e-7 * jnp.maximum(jnp.abs(t_new), 1.0)
        if lanes:
            crossed = ((saveat[:, None] > t[None]) &
                       (saveat[:, None] <= t_new[None] + eps[None]) &
                       accept[None])
            theta = jnp.clip((saveat[:, None] - t[None]) / dt_step[None],
                             0.0, 1.0)
            th = theta[:, None, :]
            dtb = dt_step[None, None, :]
            mask = crossed[:, None, :]
        else:
            crossed = (saveat > t) & (saveat <= t_new + eps) & accept
            theta = jnp.clip((saveat - t) / dt_step, 0.0, 1.0)
            sh = (S,) + (1,) * u.ndim
            th = theta.reshape(sh)
            dtb = dt_step
            mask = crossed.reshape(sh)
        vals = _dense_eval(rtab, th, u[None], u_cand[None],
                           None if F0 is None else F0[None],
                           None if F_new is None else F_new[None],
                           tuple(kd[None] for kd in kds), dtb)
        us = jnp.where(mask, vals, c["us"])

        done = (c["done"] | term
                | (t_new >= tf - 1e-7 * jnp.maximum(jnp.abs(tf), 1.0)))
        return dict(t=t_new, u=u_new, dt=dt_next, enorm_prev=enorm_prev,
                    done=done, us=us,
                    naccept=c["naccept"] + accept.astype(jnp.int32),
                    nreject=c["nreject"] + (active & ~accept).astype(jnp.int32),
                    iters=c["iters"] + 1,
                    event_t=ev_t, event_count=ev_n)

    out = jax.lax.while_loop(cond, body, carry0)
    res = SolveResult(
        ts=saveat, us=out["us"], t_final=out["t"], u_final=out["u"],
        naccept=out["naccept"], nreject=out["nreject"],
        status=jnp.where(out["done"], 0, 1).astype(jnp.int32),
        nf=(out["naccept"] + out["nreject"]) * nf_step)
    if event is not None:
        return res, dict(event_t=out["event_t"], event_count=out["event_count"])
    return res


def solve_rosenbrock23(f, u0, p, t0, tf, dt0, *, rtol=1e-6, atol=1e-6,
                       saveat=None, max_iters=100_000, lanes=False,
                       linsolve="jnp", lane_tile=None,
                       controller: Optional[PIController] = None,
                       event: Optional[Event] = None):
    """Rosenbrock23 through the generic engine (backwards-compatible entry)."""
    return solve_rosenbrock(f, ROS23W, u0, p, t0, tf, dt0, rtol=rtol,
                            atol=atol, saveat=saveat, max_iters=max_iters,
                            lanes=lanes, linsolve=linsolve,
                            lane_tile=lane_tile, controller=controller,
                            event=event)
