"""Rosenbrock23 stiff ensemble solver — beyond-paper feature.

The paper (§7) lists stiff ODEs as unsupported by EnsembleGPUKernel and
describes the enabling primitive (§5.1.3): the block-diagonal W = I - γh·J
solved as N independent small LU factorizations. We implement exactly that:
a Rosenbrock-W 2(3) method (Shampine ode23s / OrdinaryDiffEq Rosenbrock23)
whose per-trajectory Jacobian comes from forward-mode AD (jacfwd — the
"automated translation" again: users never write Jacobians), and whose linear
solves go through the batched-LU Pallas kernel in lanes mode
(`linsolve="pallas"`) or vmapped LAPACK (`"jnp"`).

Shape-polymorphic like the RK engine: scalar mode u (n,), lanes mode u (n, B).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .controller import PIController, hairer_norm, pi_propose
from .events import Event, handle_event, hermite_interp
from .solvers import SolveResult

_D = 1.0 / (2.0 + 2.0 ** 0.5)
_E32 = 6.0 + 2.0 ** 0.5


def _jac_lanes(f, u, p, t):
    """Per-lane Jacobian: u (n, B) -> J (B, n, n) via vmap(jacfwd)."""
    def f1(u1, p1, t1):
        return f(u1, p1, t1)

    return jax.vmap(jax.jacfwd(f1), in_axes=(-1, -1, None))(u, p, t)


def _linsolve(W, rhs, mode, lane_tile):
    """W (B, n, n), rhs (n, B) -> (n, B) [lanes] or W (n,n), rhs (n,) [scalar].

    modes: "jnp" (vmapped LAPACK), "pallas" (batched-LU Pallas kernel launch),
    "lanes" (the LU kernel *body* inlined — no nested pallas_call, used when
    the whole Rosenbrock integration already runs inside a fused kernel).
    """
    if W.ndim == 2:
        return jnp.linalg.solve(W, rhs)
    if mode == "pallas":
        from repro.kernels.lu.ops import batched_solve
        x = batched_solve(W, rhs.T, lane_tile=lane_tile)  # (B, n)
        return x.T
    if mode == "lanes":
        from repro.kernels.lu.kernel import lu_solve_lanes
        return lu_solve_lanes(jnp.moveaxis(W, 0, -1), rhs)
    return jnp.linalg.solve(W, rhs.T[..., None])[..., 0].T


def rosenbrock23_step(f, u, p, t, dt, *, lanes=False, linsolve="jnp",
                      lane_tile=128):
    """One Rosenbrock23 step. Returns (u_new, err, F0, F2)."""
    dtype = u.dtype
    n = u.shape[0]
    dtb = dt if jnp.ndim(dt) == 0 else dt[None]
    # Jacobian and time-derivative via AD
    if lanes:
        J = _jac_lanes(f, u, p, t)                      # (B, n, n)
        eye = jnp.eye(n, dtype=dtype)[None]
        gam = (dt * _D)[:, None, None] if jnp.ndim(dt) else dt * _D
        W = eye - gam * J
    else:
        J = jax.jacfwd(lambda uu: f(uu, p, t))(u)       # (n, n)
        W = jnp.eye(n, dtype=dtype) - dt * _D * J
    Td = jax.jvp(lambda tt: f(u, p, tt), (t,),
                 (jnp.ones_like(t),))[1]                # df/dt
    F0 = f(u, p, t)
    k1 = _linsolve(W, F0 + (_D * dtb) * Td, linsolve, lane_tile)
    F1 = f(u + 0.5 * dtb * k1, p, t + 0.5 * dt)
    k2 = _linsolve(W, F1 - k1, linsolve, lane_tile) + k1
    u_new = u + dtb * k2
    F2 = f(u_new, p, t + dt)
    k3 = _linsolve(W, F2 - _E32 * (k2 - F1) - 2.0 * (k1 - F0)
                   + (_D * dtb) * Td, linsolve, lane_tile)
    err = (dtb / 6.0) * (k1 - 2.0 * k2 + k3)
    return u_new, err, F0, F2


def solve_rosenbrock23(f, u0, p, t0, tf, dt0, *, rtol=1e-6, atol=1e-6,
                       saveat=None, max_iters=100_000, lanes=False,
                       linsolve="jnp", lane_tile=128,
                       controller: Optional[PIController] = None,
                       event: Optional[Event] = None):
    """Adaptive Rosenbrock23 with Hermite-cubic dense output.

    `event` threads the shared event machinery (`repro.core.events`) through
    the stiff family: detection + bisection refinement run on the
    Hermite-cubic interpolant the method's dense output already uses, with
    per-lane termination masks in lanes mode.  When an event is supplied the
    return value is ``(SolveResult, {"event_t", "event_count"})`` — the same
    contract as `solve_adaptive`.
    """
    dtype = u0.dtype
    ctrl = controller or PIController.for_order(3)
    cshape = (u0.shape[-1],) if lanes else ()
    axes = 0 if lanes else None
    t0 = jnp.asarray(t0, dtype)
    tf = jnp.asarray(tf, dtype)
    if saveat is None:
        saveat = jnp.asarray([tf], dtype)
    saveat = jnp.asarray(saveat, dtype)
    S = saveat.shape[0]
    us0 = jnp.zeros((S,) + u0.shape, dtype)
    pre = (saveat <= t0).reshape((S,) + (1,) * u0.ndim)
    us0 = jnp.where(pre, u0[None], us0)

    carry0 = dict(
        t=jnp.broadcast_to(t0, cshape), u=u0,
        dt=jnp.broadcast_to(jnp.asarray(dt0, dtype), cshape),
        enorm_prev=jnp.ones(cshape, dtype),
        done=jnp.zeros(cshape, bool), us=us0,
        naccept=jnp.zeros(cshape, jnp.int32),
        nreject=jnp.zeros(cshape, jnp.int32),
        iters=jnp.asarray(0, jnp.int32),
        event_t=jnp.full(cshape, jnp.inf, dtype),
        event_count=jnp.zeros(cshape, jnp.int32))

    def _bc(v):
        return v if jnp.ndim(v) == 0 else v[None]

    def cond(c):
        return (c["iters"] < max_iters) & jnp.any(~c["done"])

    def body(c):
        t, u, dt = c["t"], c["u"], c["dt"]
        active = ~c["done"]
        dt_step = jnp.where(active, jnp.minimum(dt, tf - t),
                            jnp.asarray(1.0, dtype))
        u_cand, err, F0, F2 = rosenbrock23_step(
            f, u, p, t, dt_step, lanes=lanes, linsolve=linsolve,
            lane_tile=lane_tile)
        enorm = hairer_norm(err, u, u_cand, atol, rtol, axes=axes)
        finite = jnp.isfinite(u_cand)
        finite = jnp.all(finite, axis=0) if lanes else jnp.all(finite)
        accept = (enorm <= 1.0) & finite & active
        dt_next, enorm_prev = pi_propose(ctrl, dt, enorm, c["enorm_prev"],
                                         accept)
        t_new = jnp.where(accept, t + dt_step, t)

        # ---- events: shared machinery on the Hermite-cubic interpolant -----
        if event is not None:
            def interp_fn(theta):
                return hermite_interp(u, F0, u_cand, F2, dt_step, theta,
                                      lanes=lanes)

            u_next, t_new, ev_t, ev_n, term = handle_event(
                event, interp_fn, u, u_cand, p, t, dt_step, t_new, accept,
                c["event_t"], c["event_count"], lanes=lanes)
        else:
            u_next = u_cand
            ev_t, ev_n = c["event_t"], c["event_count"]
            term = jnp.zeros(cshape, bool)

        u_new = jnp.where(_bc(accept), u_next, u)

        # Hermite-cubic grid save
        eps = 1e-7 * jnp.maximum(jnp.abs(t_new), 1.0)
        if lanes:
            crossed = ((saveat[:, None] > t[None]) &
                       (saveat[:, None] <= t_new[None] + eps[None]) &
                       accept[None])
            theta = jnp.clip((saveat[:, None] - t[None]) / dt_step[None],
                             0.0, 1.0)
            th = theta[:, None, :]
            dtb = dt_step[None, None, :]
            mask = crossed[:, None, :]
        else:
            crossed = (saveat > t) & (saveat <= t_new + eps) & accept
            theta = jnp.clip((saveat - t) / dt_step, 0.0, 1.0)
            sh = (S,) + (1,) * u.ndim
            th = theta.reshape(sh)
            dtb = dt_step
            mask = crossed.reshape(sh)
        h00 = (1 + 2 * th) * (1 - th) ** 2
        h10 = th * (1 - th) ** 2
        h01 = th ** 2 * (3 - 2 * th)
        h11 = th ** 2 * (th - 1)
        vals = (h00 * u[None] + h10 * dtb * F0[None]
                + h01 * u_cand[None] + h11 * dtb * F2[None])
        us = jnp.where(mask, vals, c["us"])

        done = (c["done"] | term
                | (t_new >= tf - 1e-7 * jnp.maximum(jnp.abs(tf), 1.0)))
        return dict(t=t_new, u=u_new, dt=dt_next, enorm_prev=enorm_prev,
                    done=done, us=us,
                    naccept=c["naccept"] + accept.astype(jnp.int32),
                    nreject=c["nreject"] + (active & ~accept).astype(jnp.int32),
                    iters=c["iters"] + 1,
                    event_t=ev_t, event_count=ev_n)

    out = jax.lax.while_loop(cond, body, carry0)
    res = SolveResult(
        ts=saveat, us=out["us"], t_final=out["t"], u_final=out["u"],
        naccept=out["naccept"], nreject=out["nreject"],
        status=jnp.where(out["done"], 0, 1).astype(jnp.int32),
        nf=(out["naccept"] + out["nreject"]) * 3)
    if event is not None:
        return res, dict(event_t=out["event_t"], event_count=out["event_count"])
    return res
