"""Ensemble execution strategies (paper §5) on a single device.

Strategies (``ensemble=``):

  "array"       EnsembleGPUArray semantics (§5.1): the whole ensemble is ONE
                state matrix stepped in lock-step with a single global dt chosen
                by an ensemble-wide error norm. One slow trajectory stalls all N.
  "array_eager" As above but stepped from Python with un-jitted array ops —
                faithfully reproduces the per-op dispatch overhead of the
                array-abstraction frameworks the paper benchmarks (PyTorch
                eager; each jnp op is a separate dispatch, i.e. "kernel launch").
  "vmap"        The JAX/Diffrax baseline the paper compares against:
                ``vmap(solve_one)`` — per-trajectory dt, but vmap-of-while lowers
                to masked lock-step iteration over the WHOLE batch: every
                trajectory pays max-steps-of-any.
  "kernel"      The paper's contribution (§5.2) adapted to TPU: trajectories are
                vector lanes; the full integration loop is fused into one
                computation per lane-tile; tiles retire independently.
                backend="xla"    — fused lax.while_loop per tile (lax.map over
                                   tiles); measured-benchmark path on CPU.
                backend="pallas" — the Pallas TPU kernel (kernels/tsit5) with
                                   VMEM-resident state; the deployment path.

Distribution over a mesh (the paper's MPI composition, §6.3) lives in
`repro.core.api.solve_ensemble` via shard_map over the trajectory axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .controller import PIController
from .problem import EnsembleProblem, ODEProblem
from .solvers import (AdaptiveOptions, Event, SolveResult, rk_step,
                      solve_adaptive, solve_fixed, solve_one)
from .tableaus import Tableau, get_tableau

Array = Any


class EnsembleResult(NamedTuple):
    # NamedTuple (a pytree): results flow through jit/shard_map boundaries
    ts: Array        # (S,)
    us: Array        # (N, S, n)
    u_final: Array   # (N, n)
    t_final: Array   # (N,)
    naccept: Array   # per-trajectory or broadcast scalar
    nreject: Array
    nf: Array        # total RHS evaluations (work proxy; paper's overhead story)
    status: Array


def _as_tab(alg) -> Tableau:
    return alg if isinstance(alg, Tableau) else get_tableau(alg)


def _pad_to(x, n_target, axis=0):
    pad = n_target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, mode="edge")


# ----------------------------------------------------------------------------
# strategy: vmap (the JAX-baseline the paper beats 20-100x)
# ----------------------------------------------------------------------------

def solve_vmap(prob: ODEProblem, u0s, ps, tab, t0, tf, dt0, saveat,
               rtol, atol, adaptive, max_iters, event=None) -> EnsembleResult:
    def one(u0, p):
        return solve_one(prob.f, tab, u0, p, t0, tf, dt0, saveat=saveat,
                         rtol=rtol, atol=atol, adaptive=adaptive,
                         max_iters=max_iters, event=event)

    res = jax.vmap(one)(u0s, ps)
    if event is not None:
        res, _ = res
    return EnsembleResult(ts=saveat, us=res.us, u_final=res.u_final,
                          t_final=res.t_final, naccept=res.naccept,
                          nreject=res.nreject, nf=jnp.sum(res.nf),
                          status=jnp.max(res.status))


# ----------------------------------------------------------------------------
# strategy: array (EnsembleGPUArray semantics: lock-step global dt)
# ----------------------------------------------------------------------------

def solve_array(prob: ODEProblem, u0s, ps, tab, t0, tf, dt0, saveat,
                rtol, atol, adaptive, max_iters, event=None) -> EnsembleResult:
    # stack to (n, N): component-style f broadcasts over the trailing lane axis,
    # scalar-control mode gives ONE dt + ensemble-wide norm == §5.1 semantics.
    U0 = u0s.T
    P = ps.T
    opts = AdaptiveOptions(rtol=rtol, atol=atol, max_iters=max_iters,
                           adaptive=adaptive)
    res = solve_adaptive(prob.f, tab, U0, P, t0, tf, dt0, saveat=saveat,
                         opts=opts, event=event, lanes=False)
    if event is not None:
        res, _ = res
    N = u0s.shape[0]
    return EnsembleResult(
        ts=saveat, us=jnp.moveaxis(res.us, -1, 0),       # (S,n,N)->(N,S,n)
        u_final=res.u_final.T, t_final=jnp.broadcast_to(res.t_final, (N,)),
        naccept=res.naccept, nreject=res.nreject,
        nf=res.nf * N,  # every global step evaluates f for all N columns
        status=res.status)


def solve_array_eager(prob: ODEProblem, u0s, ps, tab, t0, tf, dt0, saveat,
                      rtol, atol, adaptive, max_steps=100_000) -> EnsembleResult:
    """Python-driven lock-step loop with per-op dispatch (no jit around the
    step). This is the honest analogue of the eager array-abstraction overhead
    the paper attributes 10-100x to: every jnp op below is a separate dispatch
    ("kernel launch"), every step a host-device synchronization."""
    ctrl = PIController.for_order(tab.embedded_order)
    U = u0s.T
    P = ps.T
    t = float(t0)
    dt = float(dt0)
    enorm_prev = 1.0
    saveat_np = np.asarray(saveat)
    S = len(saveat_np)
    us = np.zeros((S,) + U.shape, dtype=np.asarray(U).dtype)
    sidx = 0
    naccept = nreject = 0
    U_prev = U
    while t < float(tf) - 1e-12 and (naccept + nreject) < max_steps:
        dt_step = min(dt, float(tf) - t)
        k1 = prob.f(U, P, t)
        U_new, err, ks = rk_step(prob.f, tab, U, P, t, dt_step, k1)
        if adaptive:
            scale = atol + np.maximum(np.abs(U), np.abs(U_new)) * rtol
            enorm = float(jnp.sqrt(jnp.mean((err / scale) ** 2)))
            accept = enorm <= 1.0
            e = max(enorm, 1e-10)
            if accept:
                fac = float(np.clip(ctrl.safety * e ** (-ctrl.beta1)
                                    * max(enorm_prev, 1e-10) ** ctrl.beta2,
                                    ctrl.qmin, ctrl.qmax))
                enorm_prev = e
            else:
                fac = float(np.clip(ctrl.safety * e ** (-ctrl.beta1),
                                    ctrl.qmin, 1.0))
            dt = dt_step * fac
        else:
            accept = True
        if accept:
            t_new = t + dt_step
            while sidx < S and saveat_np[sidx] <= t_new + 1e-12:
                theta = np.clip((saveat_np[sidx] - t) / dt_step, 0.0, 1.0)
                from .solvers import interp_step
                us[sidx] = np.asarray(
                    interp_step(prob.f, tab, U, U_new, ks, P, t, dt_step,
                                jnp.asarray(theta, U.dtype)))
                sidx += 1
            U = U_new
            t = t_new
            naccept += 1
        else:
            nreject += 1
    N = u0s.shape[0]
    return EnsembleResult(
        ts=saveat, us=jnp.moveaxis(jnp.asarray(us), -1, 0),
        u_final=U.T, t_final=jnp.full((N,), t),
        naccept=jnp.asarray(naccept), nreject=jnp.asarray(nreject),
        nf=jnp.asarray((naccept + nreject) * tab.stages * N),
        status=jnp.asarray(0 if t >= float(tf) - 1e-9 else 1))


# ----------------------------------------------------------------------------
# strategy: kernel (paper §5.2) — fused whole-integration per lane-tile
# ----------------------------------------------------------------------------

def solve_kernel_xla(prob: ODEProblem, u0s, ps, tab, t0, tf, dt0, saveat,
                     rtol, atol, adaptive, max_iters, lane_tile=256,
                     event=None) -> EnsembleResult:
    """Fused-integration lanes path expressed in pure XLA.

    Trajectories are packed into (n, B) tiles; each tile runs ONE while_loop to
    completion (per-lane dt/accept masks), and tiles are processed by lax.map —
    the exact control structure of the Pallas kernel, so this backend doubles
    as its oracle and as the measured-CPU-benchmark path.
    """
    N, n = u0s.shape
    B = min(lane_tile, N)
    T = -(-N // B)
    u0p = _pad_to(u0s, T * B).reshape(T, B, n)
    psp = _pad_to(ps, T * B).reshape(T, B, ps.shape[1])
    opts = AdaptiveOptions(rtol=rtol, atol=atol, max_iters=max_iters,
                           adaptive=adaptive)

    def tile(args):
        u0t, pt = args  # (B,n), (B,m)
        res = solve_adaptive(prob.f, tab, u0t.T, pt.T, t0, tf, dt0,
                             saveat=saveat, opts=opts, event=event, lanes=True)
        if event is not None:
            res, _ = res
        return res

    res = jax.lax.map(tile, (u0p, psp))
    # res.us: (T, S, n, B) -> (N, S, n)
    us = jnp.moveaxis(res.us, -1, 1).reshape(T * B, res.us.shape[1], n)[:N]
    u_final = jnp.moveaxis(res.u_final, -1, 1).reshape(T * B, n)[:N]
    return EnsembleResult(
        ts=saveat, us=us, u_final=u_final,
        t_final=res.t_final.reshape(-1)[:N],
        naccept=res.naccept.reshape(-1)[:N],
        nreject=res.nreject.reshape(-1)[:N],
        nf=jnp.sum(res.nf.reshape(-1)[:N]),
        status=jnp.max(res.status))


def solve_kernel_fixed(prob: ODEProblem, u0s, ps, tab, t0, dt, n_steps,
                       save_every, lane_tile=1024) -> EnsembleResult:
    """Fixed-dt fused path: scan-of-steps over (n, N) lanes — single fused
    computation, O(1) state traffic per step (the paper's fixed-dt kernel)."""
    N, n = u0s.shape
    res = solve_fixed(prob.f, tab, u0s.T, ps.T, t0, dt, n_steps, save_every)
    ts = res.ts
    return EnsembleResult(
        ts=ts, us=jnp.moveaxis(res.us, -1, 0),
        u_final=res.u_final.T,
        t_final=jnp.broadcast_to(res.t_final, (N,)),
        naccept=jnp.broadcast_to(res.naccept, (N,)),
        nreject=jnp.zeros((N,), jnp.int32),
        nf=res.nf * N, status=res.status)


# ----------------------------------------------------------------------------
# front door
# ----------------------------------------------------------------------------

def solve_ensemble_local(eprob: EnsembleProblem, alg="tsit5",
                         ensemble: str = "kernel", backend: str = "xla",
                         t0=None, tf=None, dt0=1e-2, saveat=None,
                         rtol=1e-6, atol=1e-6, adaptive=True,
                         n_steps=None, save_every=1, lane_tile=256,
                         max_iters=100_000, event=None) -> EnsembleResult:
    """Single-device ensemble solve. See module docstring for strategies."""
    prob = eprob.prob
    tab = _as_tab(alg)
    u0s, ps = eprob.materialize()
    t0 = prob.tspan[0] if t0 is None else t0
    tf = prob.tspan[1] if tf is None else tf
    if saveat is None:
        saveat = jnp.asarray([tf], u0s.dtype)
    saveat = jnp.asarray(saveat, u0s.dtype)

    if not adaptive and n_steps is None:
        n_steps = int(round((tf - t0) / dt0))

    if ensemble == "vmap":
        return solve_vmap(prob, u0s, ps, tab, t0, tf, dt0, saveat, rtol, atol,
                          adaptive, max_iters, event)
    if ensemble == "array":
        return solve_array(prob, u0s, ps, tab, t0, tf, dt0, saveat, rtol, atol,
                           adaptive, max_iters, event)
    if ensemble == "array_eager":
        return solve_array_eager(prob, u0s, ps, tab, t0, tf, dt0, saveat,
                                 rtol, atol, adaptive)
    if ensemble == "kernel":
        if backend == "pallas":
            from repro.kernels.tsit5 import ops as tsit5_ops
            return tsit5_ops.solve_ensemble_pallas(
                prob, u0s, ps, tab, t0, tf, dt0, saveat, rtol, atol, adaptive,
                lane_tile=lane_tile, max_iters=max_iters)
        if not adaptive:
            return solve_kernel_fixed(prob, u0s, ps, tab, t0, dt0, n_steps,
                                      save_every, lane_tile)
        return solve_kernel_xla(prob, u0s, ps, tab, t0, tf, dt0, saveat,
                                rtol, atol, adaptive, max_iters, lane_tile,
                                event)
    raise ValueError(f"unknown ensemble strategy {ensemble!r}")
