"""Ensemble execution strategies (paper §5) on a single device — all families.

`solve_ensemble_local` is the single front door: ANY registered method
(`repro.core.methods` — explicit RK, Rosenbrock-stiff, SDE steppers) through
ANY strategy and backend.

Strategies (``ensemble=``):

  "array"       EnsembleGPUArray semantics (§5.1): the whole ensemble is ONE
                state matrix stepped in lock-step with a single global dt chosen
                by an ensemble-wide error norm. One slow trajectory stalls all N.
  "array_eager" As above but stepped from Python with un-jitted array ops —
                faithfully reproduces the per-op dispatch overhead of the
                array-abstraction frameworks the paper benchmarks (PyTorch
                eager; each jnp op is a separate dispatch, i.e. "kernel launch").
  "vmap"        The JAX/Diffrax baseline the paper compares against:
                ``vmap(solve_one)`` — per-trajectory dt, but vmap-of-while lowers
                to masked lock-step iteration over the WHOLE batch: every
                trajectory pays max-steps-of-any.
  "kernel"      The paper's contribution (§5.2) adapted to TPU: trajectories are
                vector lanes; the full integration loop is fused into one
                computation per lane-tile; tiles retire independently.
                backend="xla"    — fused lax.while_loop per tile (lax.map over
                                   tiles); measured-benchmark path on CPU.
                backend="pallas" — the generic ensemble Pallas kernel
                                   (kernels/ensemble_kernel) with VMEM-resident
                                   state; the deployment path. lane_tile=None
                                   derives the tile from the §5.2 VMEM formula.

Method families (``alg=`` resolves via the registry; full matrix in
docs/architecture.md):

  erk         — all strategies/backends; adaptive or fixed dt; events.
  rosenbrock  — "vmap", "array" (one lanes tile) and "kernel" (xla/pallas);
                the W = I - γh·J solves (paper §5.1.3) run batched per lane,
                inlined inside the Pallas kernel; events on every path.
  sde         — "vmap", "array" and "kernel" (xla/pallas); fixed-dt
                counter-RNG steppers (§5.2.2) or, with adaptive=True,
                per-trajectory error control driven by a virtual Brownian
                tree (rejection-safe noise): an embedded pair where one is
                registered (error_est="embedded", the default) or step
                doubling (error_est="doubling"). Pass `seed=` (or `key=`) — the
                SAME (seed; step, row, GLOBAL lane) Threefry stream is
                replayed on every strategy/backend, so paths agree bitwise
                across dispatch targets (and across mesh shards via
                `lane_offset`); or inject `noise_table=` (n_steps, m, N).
                Events run with per-lane termination on every path.

Distribution over a mesh (the paper's MPI composition, §6.3) lives in
`repro.core.api.solve_ensemble` via shard_map over the trajectory axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .controller import PIController
from .interp import data_flatten, data_unflatten, data_words
from .methods import MethodSpec, get_method
from .problem import (EnsembleProblem, ODEProblem, SDEProblem,
                      bind_problem_data)
from .solvers import (AdaptiveOptions, Event, SolveResult, interp_step,
                      rk_step, solve_adaptive, solve_fixed, solve_one)
from .tableaus import Tableau

Array = Any

# default lane tile for the XLA lanes path (the Pallas path derives its tile
# from the VMEM formula instead — see kernels/ensemble_kernel.auto_lane_tile)
XLA_LANE_TILE = 256


class EnsembleResult(NamedTuple):
    # NamedTuple (a pytree): results flow through jit/shard_map boundaries
    ts: Array        # (S,)
    us: Array        # (N, S, n)
    u_final: Array   # (N, n)
    t_final: Array   # (N,)
    naccept: Array   # per-trajectory or broadcast scalar
    nreject: Array
    nf: Array        # total RHS evaluations (work proxy; paper's overhead story)
    status: Array
    njac: Array = 0  # total Jacobian evaluations (stiff family; 0 elsewhere)
    nfact: Array = 0  # total W = I − γh·J factorizations (stiff family)


def _pad_to(x, n_target, axis=0):
    pad = n_target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, mode="edge")


def _tile_lanes(u0s, ps, lane_tile):
    """(N, k)-major arrays -> (T, B, k) tiles for the XLA lanes path.

    The vector width matches `kernels.ensemble_kernel.padded_lane_width`
    exactly: XLA codegen is width-sensitive at the ulp level (FMA/SIMD
    contraction), so the lanes oracle and the Pallas kernel must run the
    SAME width to stay bitwise-comparable.  (`array` strategy passes
    lane_tile == N and keeps the whole-ensemble width.)"""
    from repro.kernels.ensemble_kernel import padded_lane_width
    N = u0s.shape[0]
    B = padded_lane_width(N, lane_tile)
    T = -(-N // B)
    u0p = _pad_to(u0s, T * B).reshape(T, B, u0s.shape[1])
    psp = _pad_to(ps, T * B).reshape(T, B, ps.shape[1])
    return u0p, psp, T, B


def _untile(res, N, n):
    """Invert _tile_lanes on a lanes-mode SolveResult mapped over tiles."""
    us = jnp.moveaxis(res.us, -1, 1).reshape(-1, res.us.shape[1], n)[:N]
    u_final = jnp.moveaxis(res.u_final, -1, 1).reshape(-1, n)[:N]

    def total(v):
        # per-lane (T, B) work counters -> padded-lane-free total; scalar
        # defaults (non-stiff families leave njac/nfact at 0) pass through
        if jnp.ndim(v) == 0:
            return jnp.asarray(v)
        return jnp.sum(v.reshape(-1)[:N])

    return EnsembleResult(
        ts=res.ts[0], us=us, u_final=u_final,
        t_final=res.t_final.reshape(-1)[:N],
        naccept=res.naccept.reshape(-1)[:N],
        nreject=res.nreject.reshape(-1)[:N],
        nf=total(res.nf),
        status=jnp.max(res.status),
        njac=total(res.njac), nfact=total(res.nfact))


# ----------------------------------------------------------------------------
# strategy: vmap (the JAX-baseline the paper beats 20-100x)
# ----------------------------------------------------------------------------

def solve_vmap(prob: ODEProblem, u0s, ps, tab, t0, tf, dt0, saveat,
               rtol, atol, adaptive, max_iters, event=None,
               bounded_steps=None, checkpoint_every=None) -> EnsembleResult:
    def one(u0, p):
        return solve_one(prob.f, tab, u0, p, t0, tf, dt0, saveat=saveat,
                         rtol=rtol, atol=atol, adaptive=adaptive,
                         max_iters=max_iters, event=event,
                         bounded_steps=bounded_steps,
                         checkpoint_every=checkpoint_every)

    res = jax.vmap(one)(u0s, ps)
    if event is not None:
        res, _ = res
    return EnsembleResult(ts=saveat, us=res.us, u_final=res.u_final,
                          t_final=res.t_final, naccept=res.naccept,
                          nreject=res.nreject, nf=jnp.sum(res.nf),
                          status=jnp.max(res.status))


# ----------------------------------------------------------------------------
# strategy: array (EnsembleGPUArray semantics: lock-step global dt)
# ----------------------------------------------------------------------------

def solve_array(prob: ODEProblem, u0s, ps, tab, t0, tf, dt0, saveat,
                rtol, atol, adaptive, max_iters, event=None,
                bounded_steps=None, checkpoint_every=None) -> EnsembleResult:
    # stack to (n, N): component-style f broadcasts over the trailing lane axis,
    # scalar-control mode gives ONE dt + ensemble-wide norm == §5.1 semantics.
    U0 = u0s.T
    P = ps.T
    opts = AdaptiveOptions(rtol=rtol, atol=atol, max_iters=max_iters,
                           adaptive=adaptive, bounded_steps=bounded_steps,
                           checkpoint_every=checkpoint_every)
    res = solve_adaptive(prob.f, tab, U0, P, t0, tf, dt0, saveat=saveat,
                         opts=opts, event=event, lanes=False)
    if event is not None:
        res, _ = res
    N = u0s.shape[0]
    return EnsembleResult(
        ts=saveat, us=jnp.moveaxis(res.us, -1, 0),       # (S,n,N)->(N,S,n)
        u_final=res.u_final.T, t_final=jnp.broadcast_to(res.t_final, (N,)),
        naccept=res.naccept, nreject=res.nreject,
        nf=res.nf * N,  # every global step evaluates f for all N columns
        status=res.status)


def solve_array_eager(prob: ODEProblem, u0s, ps, tab, t0, tf, dt0, saveat,
                      rtol, atol, adaptive, max_steps=100_000) -> EnsembleResult:
    """Python-driven lock-step loop with per-op dispatch (no jit around the
    step). This is the honest analogue of the eager array-abstraction overhead
    the paper attributes 10-100x to: every jnp op below is a separate dispatch
    ("kernel launch"), every step a host-device synchronization."""
    ctrl = PIController.for_order(tab.embedded_order)
    U = u0s.T
    P = ps.T
    t = float(t0)
    dt = float(dt0)
    enorm_prev = 1.0
    saveat_np = np.asarray(saveat)
    S = len(saveat_np)
    us = np.zeros((S,) + U.shape, dtype=np.asarray(U).dtype)
    sidx = 0
    naccept = nreject = 0
    U_prev = U
    while t < float(tf) - 1e-12 and (naccept + nreject) < max_steps:
        dt_step = min(dt, float(tf) - t)
        k1 = prob.f(U, P, t)
        U_new, err, ks = rk_step(prob.f, tab, U, P, t, dt_step, k1)
        if adaptive:
            scale = atol + np.maximum(np.abs(U), np.abs(U_new)) * rtol
            enorm = float(jnp.sqrt(jnp.mean((err / scale) ** 2)))
            accept = enorm <= 1.0
            e = max(enorm, 1e-10)
            if accept:
                fac = float(np.clip(ctrl.safety * e ** (-ctrl.beta1)
                                    * max(enorm_prev, 1e-10) ** ctrl.beta2,
                                    ctrl.qmin, ctrl.qmax))
                enorm_prev = e
            else:
                fac = float(np.clip(ctrl.safety * e ** (-ctrl.beta1),
                                    ctrl.qmin, 1.0))
            dt = dt_step * fac
        else:
            accept = True
        if accept:
            t_new = t + dt_step
            while sidx < S and saveat_np[sidx] <= t_new + 1e-12:
                theta = np.clip((saveat_np[sidx] - t) / dt_step, 0.0, 1.0)
                us[sidx] = np.asarray(
                    interp_step(prob.f, tab, U, U_new, ks, P, t, dt_step,
                                jnp.asarray(theta, U.dtype)))
                sidx += 1
            U = U_new
            t = t_new
            naccept += 1
        else:
            nreject += 1
    N = u0s.shape[0]
    return EnsembleResult(
        ts=saveat, us=jnp.moveaxis(jnp.asarray(us), -1, 0),
        u_final=U.T, t_final=jnp.full((N,), t),
        naccept=jnp.asarray(naccept), nreject=jnp.asarray(nreject),
        nf=jnp.asarray((naccept + nreject) * tab.stages * N),
        status=jnp.asarray(0 if t >= float(tf) - 1e-9 else 1))


# ----------------------------------------------------------------------------
# strategy: kernel (paper §5.2) — fused whole-integration per lane-tile
# ----------------------------------------------------------------------------

def solve_kernel_xla(prob: ODEProblem, u0s, ps, tab, t0, tf, dt0, saveat,
                     rtol, atol, adaptive, max_iters, lane_tile=XLA_LANE_TILE,
                     event=None, bounded_steps=None,
                     checkpoint_every=None) -> EnsembleResult:
    """Fused-integration lanes path expressed in pure XLA.

    Trajectories are packed into (n, B) tiles; each tile runs ONE while_loop to
    completion (per-lane dt/accept masks), and tiles are processed by lax.map —
    the exact control structure of the Pallas kernel, so this backend doubles
    as its oracle and as the measured-CPU-benchmark path.
    """
    N, n = u0s.shape
    u0p, psp, T, B = _tile_lanes(u0s, ps, lane_tile)
    opts = AdaptiveOptions(rtol=rtol, atol=atol, max_iters=max_iters,
                           adaptive=adaptive, bounded_steps=bounded_steps,
                           checkpoint_every=checkpoint_every)

    def tile(args):
        u0t, pt = args  # (B,n), (B,m)
        res = solve_adaptive(prob.f, tab, u0t.T, pt.T, t0, tf, dt0,
                             saveat=saveat, opts=opts, event=event, lanes=True)
        if event is not None:
            res, _ = res
        return res

    return _untile(jax.lax.map(tile, (u0p, psp)), N, n)


def solve_kernel_fixed(prob: ODEProblem, u0s, ps, tab, t0, dt, n_steps,
                       save_every, lane_tile=1024, remat=False,
                       checkpoint_every=None) -> EnsembleResult:
    """Fixed-dt fused path: scan-of-steps over (n, N) lanes — single fused
    computation, O(1) state traffic per step (the paper's fixed-dt kernel)."""
    N, n = u0s.shape
    res = solve_fixed(prob.f, tab, u0s.T, ps.T, t0, dt, n_steps, save_every,
                      remat=remat, checkpoint_every=checkpoint_every)
    ts = res.ts
    return EnsembleResult(
        ts=ts, us=jnp.moveaxis(res.us, -1, 0),
        u_final=res.u_final.T,
        t_final=jnp.broadcast_to(res.t_final, (N,)),
        naccept=jnp.broadcast_to(res.naccept, (N,)),
        nreject=jnp.zeros((N,), jnp.int32),
        nf=res.nf * N, status=res.status)


# ----------------------------------------------------------------------------
# sensitivity plumbing shared by the family dispatchers
# ----------------------------------------------------------------------------

def _resolve_adjoint(sensitivity, adaptive, adjoint_steps, n_steps):
    """(bounded_steps, remat) for the engines under sensitivity='adjoint'.

    Adaptive stepping has no static iteration count, so reverse mode needs an
    explicit ``adjoint_steps`` bound (probe the forward solve:
    ``naccept + nreject``; a bound that turns out too small reports
    ``status == 1`` — never a silently wrong gradient).  Fixed-dt stepping
    derives the bound from ``n_steps`` (one attempt per step) and asks the
    scan-shaped paths for segment remat instead.
    """
    if sensitivity != "adjoint":
        return None, False
    if adjoint_steps is not None:
        return int(adjoint_steps), True
    if adaptive:
        raise ValueError(
            "sensitivity='adjoint' with adaptive stepping needs an explicit "
            "adjoint_steps bound on the attempt count (run the forward solve "
            "once and use naccept + nreject plus margin; a too-small bound "
            "surfaces as status == 1, never as a wrong gradient)")
    # fixed-accept stepping: exactly one attempt per step
    return int(n_steps) + 1, True


# ----------------------------------------------------------------------------
# family dispatch: erk
# ----------------------------------------------------------------------------

def _solve_erk(spec: MethodSpec, prob, u0s, ps, *, ensemble, backend, t0, tf,
               dt0, saveat, rtol, atol, adaptive, n_steps, save_every,
               lane_tile, max_iters, event, sensitivity=None,
               adjoint_steps=None, checkpoint_every=None, raw_prob=None):
    # `prob` arrives with any dataset CLOSED OVER its callbacks
    # (bind_problem_data) — every XLA path below consumes it unchanged.  The
    # Pallas branch instead needs the RAW 4-arg callbacks plus the dataset
    # leaves as real kernel/custom_vjp arguments, hence `raw_prob`.
    data = getattr(raw_prob, "data", None)
    dleaves, dtreedef = data_flatten(data)
    tab = spec.tableau
    if adaptive is None:
        adaptive = True   # family default: embedded-error stepping
    if not spec.adaptive:
        adaptive = False  # e.g. rk4: no embedded error estimate
    explicit_saveat = saveat is not None
    if not adaptive and n_steps is None:
        n_steps = int(round((tf - t0) / dt0))
    bounded, remat = _resolve_adjoint(sensitivity, adaptive, adjoint_steps,
                                      n_steps)
    if saveat is None:
        if not adaptive and ensemble == "kernel" and event is None:
            # mirror solve_kernel_fixed's save_every grid so the pallas and
            # xla fixed-step paths produce identical snapshots
            if n_steps % save_every != 0:
                raise ValueError(
                    f"save_every={save_every} must divide n_steps={n_steps}")
            saveat = t0 + dt0 * save_every * jnp.arange(
                1, n_steps // save_every + 1)
        else:
            saveat = [tf]
    saveat = jnp.asarray(saveat, u0s.dtype)

    if ensemble == "vmap":
        return solve_vmap(prob, u0s, ps, tab, t0, tf, dt0, saveat, rtol, atol,
                          adaptive, max_iters, event, bounded_steps=bounded,
                          checkpoint_every=checkpoint_every)
    if ensemble == "array":
        return solve_array(prob, u0s, ps, tab, t0, tf, dt0, saveat, rtol, atol,
                           adaptive, max_iters, event, bounded_steps=bounded,
                           checkpoint_every=checkpoint_every)
    if ensemble == "array_eager":
        if event is not None:
            raise NotImplementedError(
                "events are not supported on the array_eager strategy")
        return solve_array_eager(prob, u0s, ps, tab, t0, tf, dt0, saveat,
                                 rtol, atol, adaptive)
    if ensemble == "kernel":
        if backend == "pallas":
            from repro.kernels.tsit5 import ops as erk_ops
            kprob = raw_prob if data is not None else prob

            def run(u, p, *lv):
                d = data_unflatten(dtreedef, lv) if data is not None else None
                return erk_ops.solve_ensemble_pallas(
                    kprob, u, p, tab, t0, tf, dt0, saveat, rtol, atol,
                    adaptive, lane_tile=lane_tile, max_iters=max_iters,
                    event=event, data=d)

            if sensitivity == "adjoint":
                from repro.kernels.ensemble_kernel import kernel_adjoint

                def replay(u, p, *lv):
                    bp = (bind_problem_data(raw_prob,
                                            data_unflatten(dtreedef, lv))
                          if data is not None else prob)
                    return solve_kernel_xla(
                        bp, u, p, tab, t0, tf, dt0, saveat, rtol, atol,
                        adaptive, max_iters, lane_tile or XLA_LANE_TILE,
                        event, bounded_steps=bounded,
                        checkpoint_every=checkpoint_every)

                return kernel_adjoint(run, replay)(u0s, ps, *dleaves)
            return run(u0s, ps, *dleaves)
        if not adaptive and event is None and not explicit_saveat:
            return solve_kernel_fixed(prob, u0s, ps, tab, t0, dt0, n_steps,
                                      save_every,
                                      lane_tile or XLA_LANE_TILE, remat=remat,
                                      checkpoint_every=checkpoint_every)
        # fixed dt with a user saveat: lanes path with adaptive=False honours
        # the requested grid via dense output
        return solve_kernel_xla(prob, u0s, ps, tab, t0, tf, dt0, saveat,
                                rtol, atol, adaptive, max_iters,
                                lane_tile or XLA_LANE_TILE, event,
                                bounded_steps=bounded,
                                checkpoint_every=checkpoint_every)
    raise ValueError(f"unknown ensemble strategy {ensemble!r}")


# ----------------------------------------------------------------------------
# family dispatch: rosenbrock (stiff, paper §5.1.3 + §7)
# ----------------------------------------------------------------------------

def _solve_rosenbrock(spec: MethodSpec, prob, u0s, ps, *, ensemble, backend,
                      t0, tf, dt0, saveat, rtol, atol, lane_tile, max_iters,
                      linsolve, event, w_reuse, sensitivity=None,
                      adjoint_steps=None, checkpoint_every=None,
                      raw_prob=None):
    from .rosenbrock import solve_rosenbrock

    # dataset plumbing mirrors _solve_erk: bound closures (f AND jac) on the
    # XLA paths, raw callbacks + leaf arguments on the Pallas/adjoint ones
    data = getattr(raw_prob, "data", None)
    dleaves, dtreedef = data_flatten(data)

    # the stiff engine is always adaptive: adjoint mode needs the explicit
    # attempt bound (see _resolve_adjoint)
    bounded, _ = _resolve_adjoint(sensitivity, True, adjoint_steps, None)

    rtab = spec.rtableau
    if not spec.adaptive:
        # btilde == 0: no embedded error estimate.  The stiff engine has no
        # fixed-dt path, and running the PI controller on err ≡ 0 would
        # accept every step at max growth — reject loudly instead.
        raise ValueError(
            f"rosenbrock method {spec.name!r} has no embedded error weights "
            "(btilde == 0); the stiff engine requires an adaptive pair")
    if w_reuse is None:
        w_reuse = spec.w_reuse   # method default; False = eager every step
    jac = getattr(prob, "jac", None)  # analytic-Jacobian hook (jacfwd if None)
    if saveat is None:
        saveat = jnp.asarray([tf], u0s.dtype)
    saveat = jnp.asarray(saveat, u0s.dtype)
    N, n = u0s.shape

    if ensemble == "vmap":
        # bind an axis name so the lazy-W refresh conds stay REAL branches:
        # solve_rosenbrock psum-reduces its predicates over this axis
        # (unbatched bool), instead of vmap lowering them to both-branch
        # selects — w_reuse then saves wall time under vmap too.
        ax = "_repro_vmap_lanes"

        def one(u0, p):
            return solve_rosenbrock(prob.f, rtab, u0, p, t0, tf, dt0,
                                    rtol=rtol, atol=atol, saveat=saveat,
                                    max_iters=max_iters, jac=jac, event=event,
                                    w_reuse=w_reuse, batch_axis=ax,
                                    bounded_steps=bounded,
                                    checkpoint_every=checkpoint_every)

        res = jax.vmap(one, axis_name=ax)(u0s, ps)
        if event is not None:
            res, _ = res
        return EnsembleResult(ts=saveat, us=res.us, u_final=res.u_final,
                              t_final=res.t_final, naccept=res.naccept,
                              nreject=res.nreject, nf=jnp.sum(res.nf),
                              status=jnp.max(res.status),
                              njac=jnp.sum(res.njac),
                              nfact=jnp.sum(res.nfact))

    if ensemble in ("array", "kernel"):
        # "array": whole ensemble as ONE lanes tile. A lock-step scalar-dt
        # Rosenbrock would need an (N·n)-sized Jacobian per global step, so
        # the array strategy keeps the one-state-matrix memory layout but
        # per-lane step control — preserving the cross-strategy trajectory
        # parity contract (identical per-trajectory dt sequences).
        tile_n = N if ensemble == "array" else (lane_tile or XLA_LANE_TILE)

        def lanes_run(u, p, *lv):
            # `*lv` = dataset leaves when replaying a data-driven Pallas
            # solve under kernel_adjoint (grads must reach the tables); a
            # direct XLA solve closes over them via `prob`/`jac` instead
            if lv:
                bp = bind_problem_data(raw_prob, data_unflatten(dtreedef, lv))
                f_loc, jac_loc = bp.f, getattr(bp, "jac", None)
            else:
                f_loc, jac_loc = prob.f, jac
            u0p, psp, T, B = _tile_lanes(u, p, tile_n)

            def tile(args):
                u0t, pt = args
                res = solve_rosenbrock(f_loc, rtab, u0t.T, pt.T, t0, tf, dt0,
                                       rtol=rtol, atol=atol, saveat=saveat,
                                       max_iters=max_iters, lanes=True,
                                       linsolve=linsolve, lane_tile=B,
                                       jac=jac_loc,
                                       event=event, w_reuse=w_reuse,
                                       bounded_steps=bounded,
                                       checkpoint_every=checkpoint_every)
                if event is not None:
                    res, _ = res
                return res

            return _untile(jax.lax.map(tile, (u0p, psp)), N, n)

        if ensemble == "kernel" and backend == "pallas":
            from repro.kernels.ensemble_kernel import (kernel_adjoint,
                                                       rosenbrock_body,
                                                       rosenbrock_work_words,
                                                       run_ensemble_kernel)
            kf = raw_prob.f if data is not None else prob.f
            kjac = (getattr(raw_prob, "jac", None) if data is not None
                    else jac)
            body = rosenbrock_body(kf, rtab, jac=kjac, t0=float(t0),
                                   tf=float(tf), dt0=float(dt0),
                                   rtol=float(rtol), atol=float(atol),
                                   max_iters=max_iters, event=event,
                                   w_reuse=w_reuse, data=data)

            def run(u, p, *lv):
                return run_ensemble_kernel(
                    body, u, p, ts=saveat,
                    extras=([("broadcast", saveat)]
                            + [("table", leaf) for leaf in lv]),
                    lane_tile=lane_tile,
                    work_words=rosenbrock_work_words(
                        n, ps.shape[1], stages=rtab.stages,
                        w_reuse=bool(w_reuse)),
                    fixed_words=data_words(data))

            if sensitivity == "adjoint":
                return kernel_adjoint(run, lanes_run)(u0s, ps, *dleaves)
            return run(u0s, ps, *dleaves)

        return lanes_run(u0s, ps)

    raise NotImplementedError(
        f"rosenbrock methods do not support ensemble={ensemble!r} "
        "(use 'vmap', 'array' or 'kernel')")


# ----------------------------------------------------------------------------
# family dispatch: sde (fixed-dt counter-RNG steppers, paper §5.2.2)
# ----------------------------------------------------------------------------

def _concrete_seed(seed):
    try:
        return int(seed)
    except (TypeError, jax.errors.TracerIntegerConversionError,
            jax.errors.ConcretizationTypeError):
        raise ValueError(
            "backend='pallas' specializes the RNG seed into the kernel; "
            "pass a concrete `seed=` (python int) outside of jit")


def _solve_sde(spec: MethodSpec, prob: SDEProblem, u0s, ps, *, ensemble,
               backend, t0, tf, dt0, saveat, n_steps, save_every, lane_tile,
               key, seed, noise_table, event, adaptive, rtol, atol, max_iters,
               lane_offset, brownian_depth, error_est, sensitivity=None,
               adjoint_steps=None, checkpoint_every=None, raw_prob=None):
    from .sde import (SDE_STEPPERS, default_bridge_depth, sde_event_state0,
                      sde_nf_per_step, sde_save_grid, sde_solve_adaptive,
                      sde_step_and_save, sde_step_save_event)

    # dataset plumbing mirrors _solve_erk: bound closures (f AND g) on the
    # XLA paths, raw callbacks + leaf arguments on the Pallas/adjoint ones
    data = getattr(raw_prob, "data", None)
    dleaves, dtreedef = data_flatten(data)

    if prob.noise not in spec.noise:
        raise ValueError(
            f"method {spec.name!r} supports noise {spec.noise}, "
            f"problem has {prob.noise!r}")
    if adaptive is None:
        adaptive = False  # family default: the paper's kernels are fixed-dt
    if adaptive and not spec.adaptive:
        raise ValueError(
            f"method {spec.name!r} has no adaptive step control; "
            "pass adaptive=False or pick an adaptive-capable stepper")
    if not adaptive and error_est is not None:
        raise ValueError(
            "error_est selects the adaptive SDE error estimator; it has no "
            "meaning for fixed-dt stepping (pass adaptive=True)")
    if seed is None:
        # keep the seed traceable (jit-able) on the XLA paths; the Pallas
        # kernel bakes it into the kernel closure and concretizes below
        seed = jnp.asarray(key)[-1] if key is not None else 0
    N, n = u0s.shape
    m = prob.noise_dim()
    stepper = SDE_STEPPERS[spec.name]
    nf_per_step = sde_nf_per_step(spec.name)

    # ---- adaptive: embedded-pair / step-doubling error + Brownian tree ----
    if adaptive:
        if noise_table is not None:
            raise NotImplementedError(
                "adaptive SDE draws from the virtual Brownian tree; "
                "noise_table injection is fixed-dt only")
        # estimator resolution: the registered embedded pair is the default
        # wherever it applies (diagonal noise); doubling everywhere else, and
        # always available explicitly for A/B comparison.
        if error_est is None:
            error_est = ("embedded"
                         if ("embedded" in spec.error_est
                             and prob.noise == "diagonal") else "doubling")
        if error_est not in spec.error_est:
            raise ValueError(
                f"method {spec.name!r} supports error_est {spec.error_est}, "
                f"got {error_est!r}")
        if error_est == "embedded" and prob.noise != "diagonal":
            raise ValueError(
                "embedded SDE pairs are diagonal-noise only (Levy-area-free "
                "estimators); pass error_est='doubling' for general noise")
        pair = spec.embedded if error_est == "embedded" else None
        est_order = (pair.est_order if pair is not None
                     else max(1, int(round(spec.order))))
        nf_att = (pair.nf_per_attempt if pair is not None
                  else 3 * nf_per_step)
        depth = (brownian_depth if brownian_depth is not None
                 else default_bridge_depth(t0, tf, dt0))
        if saveat is None:
            saveat = [tf]
        saveat = jnp.asarray(saveat, u0s.dtype)
        bounded, _ = _resolve_adjoint(sensitivity, True, adjoint_steps, None)
        kw = dict(seed=seed, m_noise=m, saveat=saveat, rtol=rtol, atol=atol,
                  max_iters=max_iters, event=event, depth=depth,
                  order=spec.order, nf_per_step=nf_per_step,
                  error_est=error_est,
                  embedded=pair.fn if pair is not None else None,
                  est_order=est_order, nf_per_attempt=nf_att,
                  bounded_steps=bounded, checkpoint_every=checkpoint_every)

        if ensemble == "vmap":
            def one(u0, p, lane):
                res = sde_solve_adaptive(prob.f, prob.g, stepper, prob.noise,
                                         u0, p, t0, tf, dt0, lane_idx=lane,
                                         lanes=False, **kw)
                if event is not None:
                    res, _ = res
                return res

            lanes_ix = (jnp.arange(N, dtype=jnp.uint32)
                        + jnp.asarray(lane_offset, jnp.uint32))
            res = jax.vmap(one)(u0s, ps, lanes_ix)
            return EnsembleResult(ts=saveat, us=res.us, u_final=res.u_final,
                                  t_final=res.t_final, naccept=res.naccept,
                                  nreject=res.nreject, nf=jnp.sum(res.nf),
                                  status=jnp.max(res.status))

        if ensemble in ("array", "kernel"):
            # "array": the whole ensemble as ONE lanes tile (one state
            # matrix); per-lane step control is kept so trajectories agree
            # bitwise with the vmap/kernel strategies.
            tile_n = N if ensemble == "array" else (lane_tile or XLA_LANE_TILE)

            def lanes_run(u, p, *lv):
                if lv:
                    bp = bind_problem_data(raw_prob,
                                           data_unflatten(dtreedef, lv))
                    f_loc, g_loc = bp.f, bp.g
                else:
                    f_loc, g_loc = prob.f, prob.g
                u0p, psp, T, B = _tile_lanes(u, p, tile_n)
                lanes_all = ((jnp.arange(T * B, dtype=jnp.uint32)
                              + jnp.asarray(lane_offset, jnp.uint32))
                             .reshape(T, B))

                def tile(args):
                    u0t, pt, lt = args
                    res = sde_solve_adaptive(f_loc, g_loc, stepper,
                                             prob.noise, u0t.T, pt.T, t0, tf,
                                             dt0, lane_idx=lt, lanes=True,
                                             **kw)
                    if event is not None:
                        res, _ = res
                    return res

                return _untile(jax.lax.map(tile, (u0p, psp, lanes_all)), N, n)

            if ensemble == "kernel" and backend == "pallas":
                from repro.kernels.ensemble_kernel import (kernel_adjoint,
                                                           run_ensemble_kernel,
                                                           sde_adaptive_body,
                                                           sde_work_words)
                kf = raw_prob.f if data is not None else prob.f
                kg = raw_prob.g if data is not None else prob.g
                body = sde_adaptive_body(
                    kf, kg, stepper, prob.noise, t0=float(t0),
                    tf=float(tf), dt0=float(dt0), rtol=float(rtol),
                    atol=float(atol), max_iters=max_iters, m_noise=m,
                    seed=_concrete_seed(seed), depth=depth, order=spec.order,
                    nf_per_step=nf_per_step, event=event, error_est=error_est,
                    embedded=pair.fn if pair is not None else None,
                    est_order=est_order, nf_per_attempt=nf_att, data=data)
                off = jnp.asarray([lane_offset], jnp.uint32)

                def run(u, p, *lv):
                    return run_ensemble_kernel(
                        body, u, p, ts=saveat,
                        extras=([("broadcast", saveat), ("broadcast", off)]
                                + [("table", leaf) for leaf in lv]),
                        lane_tile=lane_tile,
                        work_words=2 * sde_work_words(n, ps.shape[1], m)
                        + 8 * m, fixed_words=data_words(data))

                if sensitivity == "adjoint":
                    return kernel_adjoint(run, lanes_run)(u0s, ps, *dleaves)
                return run(u0s, ps, *dleaves)

            return lanes_run(u0s, ps)

        raise NotImplementedError(
            f"sde methods do not support ensemble={ensemble!r} "
            "(use 'vmap', 'array' or 'kernel')")

    # ---- fixed-dt: the paper's counter-RNG kernels -------------------------
    if saveat is not None:
        raise NotImplementedError(
            "fixed-dt SDE snapshots land on the save_every grid (pass "
            "n_steps/save_every); use adaptive=True for saveat-grid output")
    if n_steps is None:
        n_steps = int(round((tf - t0) / dt0))
    assert n_steps % save_every == 0
    _, remat = _resolve_adjoint(sensitivity, False, adjoint_steps, n_steps)

    ts = sde_save_grid(t0, dt0, n_steps, save_every, u0s.dtype)

    def ref_run(u, p, *lv):
        # XLA lanes path replaying the kernel's exact Threefry counter stream
        # (global lane indices) — the Pallas oracle, bitwise on every backend.
        # "array" is the same lock-step state matrix over the WHOLE ensemble
        # (for fixed dt the §5.1 array semantics and per-lane stepping agree).
        # `*lv` = dataset leaves when replaying for the data-driven adjoint.
        from repro.kernels.em.ref import ref_solve
        bp = (bind_problem_data(raw_prob, data_unflatten(dtreedef, lv))
              if lv else prob)
        us, uf, estate = ref_solve(bp, u, p, t0=t0, dt=dt0,
                                   n_steps=n_steps, method=spec.name,
                                   save_every=save_every, seed=seed,
                                   noise_table=noise_table, event=event,
                                   lane_offset=lane_offset, remat=remat,
                                   checkpoint_every=checkpoint_every)
        return _assemble_sde_result(ts, jnp.moveaxis(us, -1, 0), uf.T, N,
                                    n_steps, nf_per_step, t0, dt0, u0s.dtype,
                                    estate)

    if ensemble == "kernel" and backend == "pallas":
        from repro.kernels.em.ops import solve_sde_ensemble_kernel
        kprob = raw_prob if data is not None else prob

        def run(u, p, *lv):
            d = data_unflatten(dtreedef, lv) if data is not None else None
            return solve_sde_ensemble_kernel(
                kprob, u, p, t0=t0, dt=dt0, n_steps=n_steps,
                method=spec.name, save_every=save_every, lane_tile=lane_tile,
                seed=_concrete_seed(seed), noise_table=noise_table,
                event=event, lane_offset=lane_offset, data=d)

        if sensitivity == "adjoint":
            from repro.kernels.ensemble_kernel import kernel_adjoint
            return kernel_adjoint(run, ref_run)(u0s, ps, *dleaves)
        return run(u0s, ps, *dleaves)

    if ensemble in ("array", "kernel"):
        return ref_run(u0s, ps)

    if ensemble == "vmap":
        from repro.kernels.rng import counter_normals_threefry

        if remat:
            from .loops import checkpointed_fori
            loop = partial(checkpointed_fori, checkpoint_every=checkpoint_every)
        else:
            loop = jax.lax.fori_loop

        def one(u0, p, lane, table_col):
            lane_v = jnp.full((m,), lane, jnp.uint32)
            rows = jnp.arange(m, dtype=jnp.uint32)
            S = n_steps // save_every

            def noise_fn(k, udtype):
                if noise_table is not None:
                    return jax.lax.dynamic_slice(
                        table_col, (k, 0), (1, m))[0].astype(udtype)
                return counter_normals_threefry(seed, k, lane_v, rows, udtype)

            us0 = jnp.zeros((S, n), u0.dtype)
            if event is None:
                def step(k, carry):
                    u, us = carry
                    return sde_step_and_save(
                        stepper, prob.f, prob.g, prob.noise, u, us, p, t0,
                        dt0, k, noise_fn(k, u.dtype), save_every)

                return loop(0, n_steps, step, (u0, us0)) + (None,)

            def step(k, carry):
                u, us, estate = carry
                return sde_step_save_event(
                    stepper, prob.f, prob.g, prob.noise, event, u, us, estate,
                    p, t0, dt0, k, noise_fn(k, u.dtype), save_every)

            estate0 = sde_event_state0((), t0, u0.dtype)
            return loop(0, n_steps, step, (u0, us0, estate0))

        lanes = (jnp.arange(N, dtype=jnp.uint32)
                 + jnp.asarray(lane_offset, jnp.uint32))
        if noise_table is not None:
            table_cols = jnp.moveaxis(noise_table, -1, 0)    # (N, steps, m)
            uf, us, estate = jax.vmap(one)(u0s, ps, lanes, table_cols)
        else:
            uf, us, estate = jax.vmap(
                partial(one, table_col=None))(u0s, ps, lanes)
        return _assemble_sde_result(ts, us, uf, N, n_steps, nf_per_step,
                                    t0, dt0, u0s.dtype, estate)

    raise NotImplementedError(
        f"sde methods do not support ensemble={ensemble!r} "
        "(use 'vmap', 'array' or 'kernel')")


def _assemble_sde_result(ts, us, uf, N, n_steps, nf_per_step, t0, dt,
                         dtype, estate=None) -> EnsembleResult:
    if estate is None:
        t_final = jnp.full((N,), t0 + n_steps * dt, dtype)
        naccept = jnp.full((N,), n_steps, jnp.int32)
    else:
        # terminal events freeze lanes early: report the true per-lane step
        # count and the located event time, not the nominal grid end
        t_final = jnp.broadcast_to(estate["t_out"], (N,)).astype(dtype)
        naccept = jnp.broadcast_to(estate["naccept"], (N,))
    return EnsembleResult(
        ts=ts, us=us, u_final=uf, t_final=t_final, naccept=naccept,
        nreject=jnp.zeros((N,), jnp.int32),
        nf=jnp.asarray(n_steps * nf_per_step * N),
        status=jnp.asarray(0, jnp.int32))


# ----------------------------------------------------------------------------
# resumable segment engine (continuous-batching substrate — repro.serve)
# ----------------------------------------------------------------------------

class ResumableEngine:
    """Fixed-shape slot stepper: ONE compiled program per (body, widths).

    Wraps a per-lane resume body (`repro.core.solvers.erk_resume_body` /
    `repro.core.sde.sde_resume_body`) in a bounded while segment over a
    B-wide carry whose per-lane constants (p, tf / n_steps, lane, ...) live
    IN the carry.  `step_segment(carry, refill_mask, refill)` first merges
    refill columns into the carry — a full-width ``jnp.where`` over the
    trailing lane axis, so the jitted program is independent of WHICH slots
    refill — then advances every active lane by at most `segment_steps`
    attempts.  Applying the body to a done lane is an exact no-op (dt = 0 /
    write-masked), so mixed-progress slots cost nothing but the lane; the
    serve layer harvests done lanes between segments and refills their slots
    from the request queue without ever recompiling.
    """

    def __init__(self, init_fn, body_fn, segment_steps: int = 64):
        self.segment_steps = int(segment_steps)
        K = jnp.asarray(self.segment_steps, jnp.int32)

        def cond(c):
            return (c["iters"] < K) & jnp.any(~c["done"])

        def _segment(carry, refill_mask, refill):
            merged = {}
            for k, old in carry.items():
                if k == "iters":
                    # segment-local bound; per-request budgets are enforced
                    # host-side from naccept + nreject at harvest
                    merged[k] = jnp.asarray(0, jnp.int32)
                    continue
                m = refill_mask[None, :] if jnp.ndim(old) == 2 else refill_mask
                merged[k] = jnp.where(m, refill[k], old)
            return jax.lax.while_loop(cond, body_fn, merged)

        self._fresh = jax.jit(init_fn)
        self._segment = jax.jit(_segment)

    def fresh(self, *args):
        """Build a full-width carry (every column a fresh lane).  Used both
        for the initial pool state and — masked through `step_segment` — to
        stage refill columns: non-refilled columns are computed on filler
        values and discarded by the merge."""
        return self._fresh(*args)

    def step_segment(self, carry, refill_mask, refill):
        """Merge `refill` columns where `refill_mask` is set, then run one
        bounded segment.  `refill_mask` all-False (with `refill=carry`) is a
        pure advance."""
        return self._segment(carry, refill_mask, refill)

    def export_carry(self, carry):
        """Host-gather a carry for snapshotting (see `export_resume_carry`)."""
        return export_resume_carry(carry)

    def import_carry(self, host_carry):
        """Re-device a host carry exported by `export_carry`."""
        return import_resume_carry(host_carry)


def export_resume_carry(carry) -> dict:
    """Host-gather a resumable carry into plain numpy (dtype-preserving).

    The carry is the COMPLETE per-lane solver state — u, t, dt, counters,
    per-lane constants (p, tf / n_steps, lane index), done/status flags —
    so an exported carry is a restart point: re-devicing it and continuing
    with the same engine replays exactly the remaining body applications.
    This is what `repro.dist.elastic` snapshots through `checkpoint/ckpt.py`
    (host-gathered, so restore may re-shard onto any new mesh shape).
    """
    host = jax.device_get(carry)
    return {k: np.asarray(v) for k, v in host.items()}


def import_resume_carry(host_carry: dict):
    """Inverse of `export_resume_carry`: numpy host carry -> device arrays.
    Dtypes are preserved verbatim (bitwise-resume depends on it)."""
    return {k: jnp.asarray(v) for k, v in host_carry.items()}


def make_resumable_engine(spec: MethodSpec, prob, *, adaptive=None,
                          rtol=1e-6, atol=1e-6, event=None, seed=0,
                          m_noise=None, segment_steps: int = 64):
    """Build the (init, body) pair for a resumable method and wrap it in a
    `ResumableEngine`.

    erk:  ``engine.fresh(u0, p, t0, tf, dt0)`` — u0 (n, B), p (k, B), rest
          scalars or (B,).  The body is `solve_adaptive`'s own loop body
          (shared `_make_adaptive_body`) with p/tf carry-resident.
    sde (fixed-dt): ``engine.fresh(u0, p, t0, dt, n_steps, lane)`` — per-lane
          step counts and GLOBAL lane indices; noise replays the same
          (seed; step, lane, row) Threefry counters as the fresh kernels.

    Raises ValueError for non-resumable methods (`MethodSpec.resumable` is
    False — e.g. rosenbrock's lazy-W refresh gates are batch-reduced
    predicates that couple lanes): the serve layer runs those as coalesced
    one-shot batches instead (`repro.serve.slots.BatchPool`).
    """
    if not spec.resumable:
        raise ValueError(
            f"method {spec.name!r} declares resumable=False; serve it via "
            "coalesced one-shot batches (repro.serve.slots.BatchPool)")
    if spec.family == "sde":
        from .sde import sde_resume_body, sde_resume_init
        if adaptive:
            raise ValueError(
                "adaptive SDE stepping is not slot-resumable (Brownian-tree "
                "left-endpoint state is dt-path dependent); fixed-dt only")
        if m_noise is None:
            m_noise = prob.noise_dim()
        body = sde_resume_body(prob.f, prob.g, spec.name, prob.noise,
                               m_noise, seed, event=event)
        return ResumableEngine(sde_resume_init, body, segment_steps)
    if spec.family == "erk":
        from .solvers import erk_resume_body, erk_resume_init
        tab = spec.tableau
        if adaptive is None:
            adaptive = spec.adaptive
        opts = AdaptiveOptions(rtol=rtol, atol=atol, adaptive=adaptive)
        body = erk_resume_body(prob.f, tab, opts, event=event)
        init = partial(erk_resume_init, prob.f, tab)
        return ResumableEngine(init, body, segment_steps)
    raise ValueError(f"no resumable engine for family {spec.family!r}")


# ----------------------------------------------------------------------------
# front door
# ----------------------------------------------------------------------------

def solve_ensemble_local(eprob: EnsembleProblem, alg="tsit5",
                         ensemble: str = "kernel", backend: str = "xla",
                         t0=None, tf=None, dt0=1e-2, saveat=None,
                         rtol=1e-6, atol=1e-6, adaptive=None,
                         n_steps=None, save_every=1, lane_tile=None,
                         max_iters=100_000, event=None, key=None, seed=None,
                         noise_table=None, linsolve="jnp", lane_offset=0,
                         brownian_depth=None, error_est=None,
                         w_reuse=None, sensitivity=None, adjoint_steps=None,
                         checkpoint_every=None) -> EnsembleResult:
    """Single-device ensemble solve — ANY registered method through ANY
    strategy and backend (the unified front door; see docs/architecture.md).

    Args:
      eprob: `EnsembleProblem` wrapping an ODEProblem or SDEProblem with the
        per-trajectory (u0s, ps) variations materialized.  A problem with a
        dataset (``prob.data`` — tables consumed by 4-arg callbacks
        ``f(u, p, t, data)``; the texture-memory analog) dispatches through
        every strategy/backend below identically: XLA paths bind the tables
        over the callbacks, the Pallas kernels hold one VMEM-resident copy
        per lane tile (broadcast BlockSpec, footprint charged to the §5.2
        budget), and ``sensitivity="adjoint"`` reaches the table values
        (forcing-curve calibration) — see docs/architecture.md
        "Data-driven RHS".
      alg: a registry name (``"tsit5"``, ``"rosenbrock23"``, ``"em"``, ...),
        a `MethodSpec`, or a bare `Tableau` (auto-wrapped as an erk method).
      ensemble: execution strategy — ``"vmap"`` (per-trajectory baseline),
        ``"array"`` (one ensemble state matrix, paper §5.1),
        ``"array_eager"`` (un-jitted dispatch-overhead reproduction, erk
        only), ``"kernel"`` (fused whole-integration tiles, paper §5.2) or
        ``"auto"`` — measured dispatch: `repro.core.autotune` picks
        strategy/backend/lane_tile from the persisted profile cache, timing
        the capability-pruned candidates on this problem on first sight
        (see docs/architecture.md, "Autotuned dispatch").
      backend: ``"xla"`` (fused lax loops) or ``"pallas"`` (the generic
        ensemble Pallas kernel) — kernel strategy only.
      t0, tf, dt0: time span (defaults from ``prob.tspan``) and initial step.
        ``dt0=None`` (erk/rosenbrock only) derives the initial step from
        Hairer's two-evaluation heuristic (`repro.core.controller.initial_dt`)
        per trajectory, takes the ensemble minimum, and — unlike naive
        auto-dt wiring — COUNTS the 2·N probe RHS evaluations in the
        returned ``nf`` so work-precision sweeps stay honest.
      saveat: snapshot time grid (S,). Adaptive paths interpolate dense
        output onto it; fixed-dt SDE uses ``n_steps``/``save_every`` instead.
      rtol, atol: adaptive error-control tolerances.
      adaptive: None picks the family default (erk/rosenbrock: embedded
        adaptive stepping; sde: the paper's fixed-dt kernels).  Explicit
        ``True`` on an SDE method enables adaptive error control with
        rejection-safe virtual-Brownian-tree noise; explicit ``False`` forces
        fixed-dt stepping.
      error_est: adaptive-SDE error estimator — ``"embedded"`` (the method's
        registered embedded pair: one stepper pass + companion difference,
        ~2x cheaper per attempt) or ``"doubling"`` (step doubling: any
        stepper, general noise, 3x stepper cost).  None picks the embedded
        pair where one ships and the noise is diagonal, doubling otherwise.
        Both estimators draw from the same Brownian tree, so either choice
        is bitwise-reproducible across every strategy/backend/shard.
      n_steps, save_every: fixed-dt step count and snapshot stride.
      lane_tile: trajectories per fused tile (kernel strategy).  None derives
        the Pallas tile from the §5.2 VMEM formula (see docs/kernels.md).
      max_iters: adaptive-loop iteration cap (status=1 when exhausted).
      event: `repro.core.events.Event` — zero-crossing detection, bisection
        refinement and per-lane termination on EVERY family/strategy/backend.
      key, seed: SDE noise stream key — the same (seed; step, row, lane)
        Threefry stream is replayed on every strategy/backend, so SDE paths
        agree bitwise across dispatch targets.
      noise_table: optional pre-drawn (n_steps, m, N) N(0,1) table (fixed-dt
        SDE only), bypassing the counter RNG.
      linsolve: Rosenbrock W-solve mode ("jnp" | "pallas" | "lanes").
      w_reuse: Rosenbrock lazy-W control — ``None`` takes the method's
        `MethodSpec.w_reuse` default, ``False`` forces today's eager
        every-step Jacobian + factorization (bitwise-identical to the
        pre-lazy engine), ``True`` enables the default
        `repro.core.controller.WReusePolicy`, and a `WReusePolicy` instance
        customizes the freshness thresholds.  Reuse-on trajectories satisfy
        the same cross-strategy/backend parity contract; `njac`/`nfact`
        report the (much smaller) linear-algebra work.  The refresh is an
        any()-gated `lax.cond` on every strategy — the vmap path binds an
        axis name and psum-reduces the gate to an ensemble-uniform
        predicate, so the cond survives vmap batching as a real branch and
        the savings are wall time everywhere, not just counted work.
      lane_offset: GLOBAL index of this shard's first trajectory — keeps
        counter-RNG streams disjoint when `repro.core.api.solve_ensemble`
        splits an SDE ensemble over a mesh.  Local solves leave it 0.
      brownian_depth: dyadic resolution of the adaptive-SDE Brownian tree
        (default: `repro.core.sde.default_bridge_depth`).
      sensitivity: gradient capability (docs/architecture.md, "Gradients").
        ``None`` keeps the while-loop hot paths untouched.  ``"forward"``
        validates that forward-mode (jvp) sensitivities flow — they ride the
        while-loop engines as-is (XLA strategies only; the Pallas kernels
        have no jvp rule).  ``"adjoint"`` swaps the adaptive loops for the
        bounded, checkpointed reverse-differentiable substitute
        (`repro.core.loops.solver_loop`) so ``jax.grad``/``jax.vjp`` work
        through the solve: same accept/reject sequence, states agree with
        the while path to ulp, O(sqrt-steps) adjoint memory.  On
        ``backend="pallas"`` the forward solve still runs the fused kernel;
        a `jax.custom_vjp` on the kernel boundary replays the bitwise XLA
        twin under the bounded loop for the reverse pass.  Gradients flow
        through ``us``/``u_final`` w.r.t. (u0s, ps); solver statistics and
        event times are non-differentiable outputs.  SDE solves get pathwise
        gradients (the counter-RNG noise replays bitwise under vjp
        recomputation).
      adjoint_steps: static bound on the adaptive attempt count for
        ``sensitivity="adjoint"`` (required for adaptive stepping: probe the
        forward solve and use ``naccept + nreject`` plus margin; too small a
        bound reports ``status == 1``).  Fixed-dt paths derive it.
      checkpoint_every: steps per remat segment of the bounded adjoint loop
        (default sqrt(adjoint_steps) — `repro.core.loops`).

    Returns:
      `EnsembleResult` with trajectory-major ``us (N, S, n)``, per-trajectory
      final states/times and step statistics.  Terminal events record the
      located event time in ``t_final``.
    """
    spec = get_method(alg)
    prob = eprob.prob
    u0s, ps = eprob.materialize()
    t0 = prob.tspan[0] if t0 is None else t0
    tf = prob.tspan[1] if tf is None else tf

    # data-driven RHS (`prob.data`, the texture-memory analog): a capability
    # like events/w_reuse/sensitivity.  Validate it against the method, then
    # bind the dataset over the callbacks once — every XLA path downstream
    # sees a plain 3-arg problem; the Pallas branches receive `raw_prob`
    # (4-arg callbacks) and pass the table leaves as real kernel arguments.
    raw_prob = prob
    if getattr(prob, "data", None) is not None:
        if not spec.data_rhs:
            raise ValueError(
                f"method {spec.name!r} declares data_rhs=False; its engines "
                "cannot consume data-driven problems (prob.data)")
        prob = bind_problem_data(prob)

    if ensemble == "auto":
        # measured dispatch (repro.core.autotune): profile-cache hit or a
        # one-off micro-benchmark of the capability-pruned candidate set on
        # this very problem; near-zero overhead once the cache is warm.
        from .autotune import resolve_auto
        dec = resolve_auto(eprob, spec, t0=t0, tf=tf, dt0=dt0, saveat=saveat,
                           rtol=rtol, atol=atol, adaptive=adaptive,
                           n_steps=n_steps, save_every=save_every,
                           max_iters=max_iters, event=event, key=key,
                           seed=seed, noise_table=noise_table,
                           error_est=error_est, w_reuse=w_reuse,
                           linsolve=linsolve, sensitivity=sensitivity)
        ensemble, backend = dec.strategy, dec.backend
        if lane_tile is None:
            lane_tile = dec.lane_tile   # an explicit user tile always wins

    if event is not None and not spec.events:
        raise ValueError(
            f"method {spec.name!r} declares events=False; pick a method whose "
            "MethodSpec supports event handling")

    if sensitivity is not None:
        # same rules as methods.valid_dispatch(sensitivity=...) — kept in
        # sync so the autotuner prunes exactly what would raise here
        if sensitivity not in ("forward", "adjoint"):
            raise ValueError(f"unknown sensitivity {sensitivity!r} "
                             "(use 'forward' or 'adjoint')")
        if sensitivity not in spec.sensitivity:
            raise ValueError(
                f"method {spec.name!r} declares differentiable=False; its "
                "engines do not satisfy the AD contract "
                "(docs/adding-a-method.md)")
        if ensemble == "array_eager":
            raise ValueError(
                "sensitivity through ensemble='array_eager' is not possible: "
                "the eager loop is host-driven python, not traceable")
        if sensitivity == "forward" and backend == "pallas":
            raise ValueError(
                "forward sensitivities ride jvp through the while-loop "
                "engines; the Pallas kernels support sensitivity='adjoint' "
                "(custom_vjp boundary) only — use backend='xla' for jvp")

    if w_reuse and spec.family != "rosenbrock":
        # only a truthy request is an error: w_reuse=False/None stays the
        # documented universal no-op, so generic sweeps can pass it blindly
        raise ValueError(
            "w_reuse controls the Rosenbrock lazy-W hot path; "
            f"{spec.name!r} ({spec.family}) has no W = I − γh·J to reuse")

    auto_dt_nf = 0
    if dt0 is None:
        # Hairer auto-dt: two probe f evaluations PER TRAJECTORY, charged to
        # nf below so auto-dt runs stop flattering work-precision plots
        if spec.family == "sde":
            raise ValueError(
                "dt0=None (automatic initial step) is erk/rosenbrock only; "
                "SDE stepping needs an explicit dt0")
        from .controller import initial_dt
        order = max(1, int(round(spec.order)))
        h = jax.vmap(lambda u0, pp: initial_dt(prob.f, u0, pp, t0, tf, order,
                                               atol, rtol))(u0s, ps)
        dt0 = jnp.min(h)
        if backend == "pallas":
            # the fused kernel bakes dt0 into its closure (same constraint
            # as t0/tf/seed) — surface the jit limitation clearly instead of
            # crashing at float() deep inside the kernel factory
            try:
                dt0 = float(dt0)
            except jax.errors.ConcretizationTypeError:
                raise ValueError(
                    "dt0=None with backend='pallas' requires eager dispatch "
                    "(the kernel closure specializes dt0, like t0/tf/seed); "
                    "compute initial_dt outside jit or use backend='xla'")
        auto_dt_nf = 2 * u0s.shape[0]

    if spec.family == "sde":
        if not isinstance(prob, SDEProblem):
            raise TypeError(
                f"method {spec.name!r} is an SDE stepper but the problem is "
                f"{type(prob).__name__}")
        return _solve_sde(spec, prob, u0s, ps, ensemble=ensemble,
                          backend=backend, t0=t0, tf=tf, dt0=dt0,
                          saveat=saveat, n_steps=n_steps,
                          save_every=save_every, lane_tile=lane_tile, key=key,
                          seed=seed, noise_table=noise_table, event=event,
                          adaptive=adaptive, rtol=rtol, atol=atol,
                          max_iters=max_iters, lane_offset=lane_offset,
                          brownian_depth=brownian_depth, error_est=error_est,
                          sensitivity=sensitivity,
                          adjoint_steps=adjoint_steps,
                          checkpoint_every=checkpoint_every,
                          raw_prob=raw_prob)

    if error_est is not None:
        raise ValueError(
            "error_est selects the adaptive SDE error estimator; "
            f"{spec.name!r} ({spec.family}) embeds via its tableau")

    if isinstance(prob, SDEProblem):
        raise TypeError(
            f"problem {prob.name!r} is stochastic; pick an sde method "
            f"(e.g. alg='em'), not {spec.name!r}")

    if spec.family == "rosenbrock":
        res = _solve_rosenbrock(spec, prob, u0s, ps, ensemble=ensemble,
                                backend=backend, t0=t0, tf=tf, dt0=dt0,
                                saveat=saveat, rtol=rtol, atol=atol,
                                lane_tile=lane_tile, max_iters=max_iters,
                                linsolve=linsolve, event=event,
                                w_reuse=w_reuse, sensitivity=sensitivity,
                                adjoint_steps=adjoint_steps,
                                checkpoint_every=checkpoint_every,
                                raw_prob=raw_prob)
    else:
        res = _solve_erk(spec, prob, u0s, ps, ensemble=ensemble,
                         backend=backend, t0=t0, tf=tf, dt0=dt0,
                         saveat=saveat, rtol=rtol, atol=atol,
                         adaptive=adaptive, n_steps=n_steps,
                         save_every=save_every, lane_tile=lane_tile,
                         max_iters=max_iters, event=event,
                         sensitivity=sensitivity,
                         adjoint_steps=adjoint_steps,
                         checkpoint_every=checkpoint_every,
                         raw_prob=raw_prob)
    if auto_dt_nf:
        res = res._replace(nf=res.nf + auto_dt_nf)
    return res
