"""Adaptive step-size control: Hairer scaled error norm + PI controller (paper §3.1).

All functions are shape-polymorphic: scalar control state for per-trajectory
solving, `(B,)` vectors for the per-lane fused-kernel path, and scalar control
over an `(N, n)` super-state for the lock-step EnsembleGPUArray semantics.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

# Adaptive-loop status codes (SolveResult.status / EnsembleResult.status).
# Every engine (ERK / Rosenbrock / SDE) reports the same vocabulary:
STATUS_SUCCESS = 0          # reached tf (or a terminal event)
STATUS_MAX_ITERS = 1        # iteration cap hit with lanes still running
STATUS_DTMIN_EXHAUSTED = 2  # dt pinned at the controller floor and the step
#                             still rejects: retrying the identical step is a
#                             deterministic live-lock, so the lane terminates
#                             with this code instead of spinning to max_iters


class PIController(NamedTuple):
    """Proportional-integral step controller (Hairer PI; paper eq. 4 + PI update).

    dt_new = dt * clip(safety * err^(-beta1) * err_prev^(beta2), qmin, qmax)
    Defaults follow the OrdinaryDiffEq convention beta1 = 7/(10k), beta2 = 2/(5k)
    with k = embedded_order + 1 (scaled-error exponent).

    For the adaptive SDE engine, `for_order` receives the dt-order of the
    ERROR ESTIMATOR: an embedded pair passes its `EmbeddedPair.est_order`
    (1 for both shipped pairs), step doubling passes the stepper's rounded
    strong order `max(1, round(order))` (1 for em/heun_strat, 2 for
    platen_w2) — see `repro.core.sde.SDE_EMBEDDED` and
    `sde_solve_adaptive(est_order=...)`.
    """

    beta1: float
    beta2: float
    safety: float = 0.9
    qmin: float = 0.2
    qmax: float = 10.0
    dtmin: float = 1e-12
    dtmax: float = jnp.inf

    @staticmethod
    def for_order(embedded_order: int, **kw) -> "PIController":
        k = float(embedded_order + 1)
        return PIController(beta1=0.7 / k, beta2=0.4 / k, **kw)


def hairer_norm(err, u_old, u_new, atol, rtol, axes=None):
    """RMS of componentwise error scaled by atol + rtol*max(|u_old|,|u_new|).

    axes: reduction axes. None => reduce everything (scalar norm: per-trajectory
    and EnsembleArray lock-step semantics). For the lanes path pass axes=0 to
    reduce only the state-component axis, keeping one norm per lane.
    err <= 1  <=>  accept.
    """
    scale = atol + jnp.maximum(jnp.abs(u_old), jnp.abs(u_new)) * rtol
    r = err / scale
    return jnp.sqrt(jnp.mean(r * r, axis=axes))


def pi_propose(ctrl: PIController, dt, enorm, enorm_prev, accept):
    """One controller update. Returns (dt_next, enorm_prev_next).

    On accept: PI formula with history term.
    On reject: pure P shrink (history term dropped, growth capped at 1).
    All args broadcast; `accept` may be a per-lane boolean mask.

    A non-finite error norm (NaN/inf candidate state) is treated as a huge
    error: maximum shrink.  Without this, the NaN would propagate into dt
    itself and the lane could never recover — it would spin rejecting at a
    NaN step size until max_iters instead of shrinking toward dtmin (where
    the engines' DTMIN_EXHAUSTED detection terminates it).
    """
    e = jnp.where(jnp.isfinite(enorm), jnp.maximum(enorm, 1e-10), 1e10)
    ep = jnp.maximum(enorm_prev, 1e-10)
    fac_pi = ctrl.safety * e ** (-ctrl.beta1) * ep ** ctrl.beta2
    fac_acc = jnp.clip(fac_pi, ctrl.qmin, ctrl.qmax)
    fac_rej = jnp.clip(ctrl.safety * e ** (-ctrl.beta1), ctrl.qmin, 1.0)
    fac = jnp.where(accept, fac_acc, fac_rej)
    dt_next = jnp.clip(dt * fac, ctrl.dtmin, ctrl.dtmax)
    enorm_prev_next = jnp.where(accept, e, enorm_prev)
    return dt_next, enorm_prev_next


class WReusePolicy(NamedTuple):
    """Freshness controller for lazy-W stiff stepping (sibling of PIController).

    W-methods are order-robust to stale Jacobians by construction: the order
    conditions of a W-method hold for an ARBITRARY matrix W, so reusing J (and
    the factored W) across steps trades nothing but step-acceptance efficiency
    for a large cut in linear-algebra work — exactly where batched stiff
    solvers win or lose their throughput (MPGOS, torchode).  This policy
    decides, per step attempt and per lane, two independent freshness levels:

      * re-evaluate J (``need_jac`` — the expensive ``jac``/``jacfwd`` pass):
        after a rejection taken with a reused J (with secant updates off, the
        retry then runs at the SAME dt — blame the linearization before
        punishing the step size), when the error norm of an accepted step
        grew past the predictive ``enorm_limit`` or by more than ``growth``
        versus the previous accepted step (refresh BEFORE the controller
        starts rejecting or shrinking dt), or after ``max_age`` accepted
        steps on the same J;
      * re-factor W = I − γh·J from the CACHED J (``need_fact`` — cheap, one
        batched LU): whenever J refreshes, and additionally when the step size
        drifted from the dt the factorization was built at by more than the
        γ-scaled threshold  γ·|dt − dt_fact| > dt_rtol·dt_fact  (larger γ
        makes W more sensitive to dt, so the trigger tightens with γ).

    Between full refreshes the cached J is kept alive by an EXTRAPOLATED
    SECANT (Broyden) update per accepted step — rank-1, O(n²), zero extra
    RHS evaluations (it reuses the step's own f(u) that the stage loop needs
    anyway):

        J ← J + secant · (Δf − J·Δu)·Δuᵀ / (Δuᵀ·Δu)

    ``secant = 1`` is the classical good-Broyden update and reconstructs the
    MIDPOINT Jacobian along the step direction; the default ``secant = 2``
    extrapolates to the ENDPOINT state — exact (along Δu) whenever J is
    affine in u, i.e. for every quadratic RHS: mass-action chemical kinetics
    (ROBER, OREGO), Riccati terms, advection-with-quadratic-reaction.  On
    ROBER this turns a ~3x per-step stale-J error inflation into ~1.0 out to
    ages beyond 16 steps, which is what lets the lazy path cut `njac` by an
    order of magnitude at unchanged step counts.  ``secant = 0`` disables
    the touch-up (pure frozen-J reuse).

    The decision is a pure function of per-lane quantities (dt, dt_fact,
    enorm, accept, age) that are identical on every strategy (vmap / array /
    kernel) and backend (xla / pallas), so reuse-on trajectories satisfy the
    same cross-strategy parity contract as reuse-off ones.
    """

    dt_rtol: float = 0.005    # γ-scaled dt-drift refactor threshold
    growth: float = 4.0       # accepted-enorm growth ratio forcing a J refresh
    #                           (loose on purpose: a reused J settles at a
    #                           benign ~2-3x enorm equilibrium on a W-method —
    #                           a tight ratio would re-trigger on that jump
    #                           every other step and thrash)
    enorm_limit: float = 0.9  # predictive refresh: accepted enorm above this
    #                           means the reused linearization is running out
    #                           of headroom — refresh before steps reject
    max_age: int = 20         # accepted steps per Jacobian, hard cap
    secant: float = 2.0       # extrapolated-secant gain (0 = disable; 1 =
    #                           classical Broyden midpoint; 2 = endpoint)


def w_refresh(policy: WReusePolicy, gamma, dt, dt_fact, jac_stale):
    """Pre-step freshness decision. Returns (need_jac, need_fact).

    `jac_stale` is the flag carried from `w_mark_stale` on the previous
    attempt; `dt` is the dt about to be used, `dt_fact` the dt W was last
    factored at.  All args broadcast (scalar or per-lane (B,))."""
    drift = gamma * jnp.abs(dt - dt_fact) > policy.dt_rtol * dt_fact
    return jac_stale, jac_stale | drift


def w_mark_stale(policy: WReusePolicy, accept, enorm, enorm_prev, age, fresh):
    """Post-step staleness signal for the NEXT attempt's `need_jac`.

    accept/enorm are this attempt's outcome, `enorm_prev` the previous
    ACCEPTED error norm (pre-update), `age` the accepted-step age of J after
    this attempt, `fresh` whether J was re-evaluated for this attempt (a
    rejection taken with a fresh J is a dt problem, not a J problem)."""
    rej_stale = ~accept & ~fresh
    grew = accept & ((enorm > policy.growth * enorm_prev)
                     | (enorm > policy.enorm_limit))
    return rej_stale | grew | (age >= policy.max_age)


def w_dt_blame(accept, fresh, dt, dt_proposed):
    """Rejection triage (secant updates OFF only): a step rejected on a
    frozen reused J retries at the same dt with a fresh J — the
    linearization, not the step size, is the prime suspect; without this,
    every reuse run would end by slashing dt and paying many small steps to
    regrow it.  Fresh-J rejections keep the PI controller's shrink.  With
    secant updates on, the cached J tracks the state well enough that a
    rejection IS a dt problem, so the engine skips this triage (the retry
    would reproduce the same candidate and reject again)."""
    return jnp.where(~accept & ~fresh, dt, dt_proposed)


def initial_dt(f, u0, p, t0, tf, order, atol, rtol):
    """Hairer's automatic initial step size (Solving ODEs I, II.4), simplified.

    Cheap two-evaluation heuristic; the controller recovers quickly from a
    conservative guess, so we favour robustness.

    Stiff problems stress this heuristic: ROBER's f(u0) mixes component
    magnitudes across ~9 orders, so d0/d1 ratios can underflow h0 toward 0 or
    (for vanishing derivatives) blow h1 up past the horizon.  The result is
    therefore clamped to [1e-12·span, span] and any non-finite intermediate
    collapses to the conservative 1e-6·span fallback — the heuristic may be
    *suboptimal* under extreme norm ratios, but it can never return 0, inf or
    NaN (regression: tests/test_stiff.py::test_initial_dt_guard).
    """
    span = tf - t0
    sc = atol + jnp.abs(u0) * rtol
    f0 = f(u0, p, t0)
    d0 = jnp.sqrt(jnp.mean((u0 / sc) ** 2))
    d1 = jnp.sqrt(jnp.mean((f0 / sc) ** 2))
    h0 = jnp.where((d0 < 1e-5) | (d1 < 1e-5), 1e-6, 0.01 * d0 / d1)
    # the probe step itself must stay usable under huge |f0| / tiny h0
    h0 = jnp.clip(h0, 1e-12 * span, span)
    u1 = u0 + h0 * f0
    f1 = f(u1, p, t0 + h0)
    d2 = jnp.sqrt(jnp.mean(((f1 - f0) / sc) ** 2)) / h0
    dmax = jnp.maximum(d1, d2)
    h1 = jnp.where(dmax <= 1e-15,
                   jnp.maximum(1e-6, h0 * 1e-3),
                   (0.01 / dmax) ** (1.0 / order))
    dt = jnp.minimum(100.0 * h0, jnp.minimum(h1, span))
    dt = jnp.where(jnp.isfinite(dt) & (dt > 0), dt, 1e-6 * span)
    return jnp.clip(dt, 1e-12 * span, span)
