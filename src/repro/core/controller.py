"""Adaptive step-size control: Hairer scaled error norm + PI controller (paper §3.1).

All functions are shape-polymorphic: scalar control state for per-trajectory
solving, `(B,)` vectors for the per-lane fused-kernel path, and scalar control
over an `(N, n)` super-state for the lock-step EnsembleGPUArray semantics.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class PIController(NamedTuple):
    """Proportional-integral step controller (Hairer PI; paper eq. 4 + PI update).

    dt_new = dt * clip(safety * err^(-beta1) * err_prev^(beta2), qmin, qmax)
    Defaults follow the OrdinaryDiffEq convention beta1 = 7/(10k), beta2 = 2/(5k)
    with k = embedded_order + 1 (scaled-error exponent).

    For the adaptive SDE engine, `for_order` receives the dt-order of the
    ERROR ESTIMATOR: an embedded pair passes its `EmbeddedPair.est_order`
    (1 for both shipped pairs), step doubling passes the stepper's rounded
    strong order `max(1, round(order))` (1 for em/heun_strat, 2 for
    platen_w2) — see `repro.core.sde.SDE_EMBEDDED` and
    `sde_solve_adaptive(est_order=...)`.
    """

    beta1: float
    beta2: float
    safety: float = 0.9
    qmin: float = 0.2
    qmax: float = 10.0
    dtmin: float = 1e-12
    dtmax: float = jnp.inf

    @staticmethod
    def for_order(embedded_order: int, **kw) -> "PIController":
        k = float(embedded_order + 1)
        return PIController(beta1=0.7 / k, beta2=0.4 / k, **kw)


def hairer_norm(err, u_old, u_new, atol, rtol, axes=None):
    """RMS of componentwise error scaled by atol + rtol*max(|u_old|,|u_new|).

    axes: reduction axes. None => reduce everything (scalar norm: per-trajectory
    and EnsembleArray lock-step semantics). For the lanes path pass axes=0 to
    reduce only the state-component axis, keeping one norm per lane.
    err <= 1  <=>  accept.
    """
    scale = atol + jnp.maximum(jnp.abs(u_old), jnp.abs(u_new)) * rtol
    r = err / scale
    return jnp.sqrt(jnp.mean(r * r, axis=axes))


def pi_propose(ctrl: PIController, dt, enorm, enorm_prev, accept):
    """One controller update. Returns (dt_next, enorm_prev_next).

    On accept: PI formula with history term.
    On reject: pure P shrink (history term dropped, growth capped at 1).
    All args broadcast; `accept` may be a per-lane boolean mask.
    """
    e = jnp.maximum(enorm, 1e-10)  # guard err==0 (exact step) -> max growth
    ep = jnp.maximum(enorm_prev, 1e-10)
    fac_pi = ctrl.safety * e ** (-ctrl.beta1) * ep ** ctrl.beta2
    fac_acc = jnp.clip(fac_pi, ctrl.qmin, ctrl.qmax)
    fac_rej = jnp.clip(ctrl.safety * e ** (-ctrl.beta1), ctrl.qmin, 1.0)
    fac = jnp.where(accept, fac_acc, fac_rej)
    dt_next = jnp.clip(dt * fac, ctrl.dtmin, ctrl.dtmax)
    enorm_prev_next = jnp.where(accept, e, enorm_prev)
    return dt_next, enorm_prev_next


def initial_dt(f, u0, p, t0, tf, order, atol, rtol):
    """Hairer's automatic initial step size (Solving ODEs I, II.4), simplified.

    Cheap two-evaluation heuristic; the controller recovers quickly from a
    conservative guess, so we favour robustness.

    Stiff problems stress this heuristic: ROBER's f(u0) mixes component
    magnitudes across ~9 orders, so d0/d1 ratios can underflow h0 toward 0 or
    (for vanishing derivatives) blow h1 up past the horizon.  The result is
    therefore clamped to [1e-12·span, span] and any non-finite intermediate
    collapses to the conservative 1e-6·span fallback — the heuristic may be
    *suboptimal* under extreme norm ratios, but it can never return 0, inf or
    NaN (regression: tests/test_stiff.py::test_initial_dt_guard).
    """
    span = tf - t0
    sc = atol + jnp.abs(u0) * rtol
    f0 = f(u0, p, t0)
    d0 = jnp.sqrt(jnp.mean((u0 / sc) ** 2))
    d1 = jnp.sqrt(jnp.mean((f0 / sc) ** 2))
    h0 = jnp.where((d0 < 1e-5) | (d1 < 1e-5), 1e-6, 0.01 * d0 / d1)
    # the probe step itself must stay usable under huge |f0| / tiny h0
    h0 = jnp.clip(h0, 1e-12 * span, span)
    u1 = u0 + h0 * f0
    f1 = f(u1, p, t0 + h0)
    d2 = jnp.sqrt(jnp.mean(((f1 - f0) / sc) ** 2)) / h0
    dmax = jnp.maximum(d1, d2)
    h1 = jnp.where(dmax <= 1e-15,
                   jnp.maximum(1e-6, h0 * 1e-3),
                   (0.01 / dmax) ** (1.0 / order))
    dt = jnp.minimum(100.0 * h0, jnp.minimum(h1, span))
    dt = jnp.where(jnp.isfinite(dt) & (dt > 0), dt, 1e-6 * span)
    return jnp.clip(dt, 1e-12 * span, span)
