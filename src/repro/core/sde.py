"""SDE steppers (paper §3.2, §5.2.2, §6.8): fixed-dt, kernel-shaped.

Methods (matching the paper's GPU kernel set):
  em         — GPUEM: Euler-Maruyama, Ito; diagonal AND general (n×m) noise.
  platen_w2  — GPUSIEA role: explicit weak-order-2 Platen scheme
               (Kloeden & Platen §14.2), diagonal noise only — the weak-order-2
               stochastic generalization of the midpoint/improved-Euler family.
  heun_strat — Stratonovich Heun (extra, beyond paper).

Noise is counter-based: dW for step k is drawn from fold_in(key, k), so the
stepper needs no noise storage (the paper's per-thread PRNG state), trajectories
are independent across lanes, and any step's noise can be replayed (used by the
pathwise tests and by the pallas/XLA cross-validation).

All steppers are shape-polymorphic like the ODE engine: u (n,) scalar-mode or
(n, B) lanes-mode; the SAME definition runs vmapped, lane-fused, and inside the
Pallas EM kernel (kernels/em).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .controller import (STATUS_DTMIN_EXHAUSTED, PIController, hairer_norm,
                         pi_propose)
from .events import Event, handle_event, linear_interp
from .loops import solver_loop
from .problem import EnsembleProblem, SDEProblem
from .solvers import SolveResult

Array = Any


def _sqrt_dt(dt, dtype):
    return jnp.sqrt(jnp.asarray(dt, dtype))


def apply_noise(g_val, dW, noise: str):
    """g(u)·dW with g_val (n,[B]) diagonal or (n,m,[B]) general; dW (m,[B])."""
    if noise == "diagonal":
        return g_val * dW
    # general: contract the noise axis (axis 1 of g_val)
    return jnp.einsum("nm...,m...->n...", g_val, dW)


def em_step(f, g, u, p, t, dt, dW, noise="diagonal"):
    """X' = X + f dt + g dW  (Ito; strong 0.5 / weak 1)."""
    return u + f(u, p, t) * dt + apply_noise(g(u, p, t), dW, noise)


def heun_strat_step(f, g, u, p, t, dt, dW, noise="diagonal"):
    """Stratonovich Heun (strong 0.5 / weak 1 in Stratonovich sense)."""
    du1 = f(u, p, t) * dt + apply_noise(g(u, p, t), dW, noise)
    ub = u + du1
    du2 = f(ub, p, t + dt) * dt + apply_noise(g(ub, p, t + dt), dW, noise)
    return u + 0.5 * (du1 + du2)


def platen_w2_step(f, g, u, p, t, dt, dW, noise="diagonal"):
    """Explicit weak-order-2 Platen scheme, diagonal noise (Kloeden & Platen
    (15.1.1)/(14.2.4) family). Supporting values:
        ubar = u + a dt + b dW ;  u± = u + a dt ± b sqrt(dt)
        u'   = u + dt/2 (a(ubar)+a(u))
                 + dW/4 (b(u+)+b(u-)+2 b(u))
                 + (dW^2-dt)/(4 sqrt(dt)) (b(u+)-b(u-))
    """
    if noise != "diagonal":
        raise ValueError("platen_w2 supports diagonal noise only (as the "
                         "paper's GPUSIEA)")
    a0 = f(u, p, t)
    b0 = g(u, p, t)
    sdt = _sqrt_dt(dt, u.dtype)
    drift = u + a0 * dt
    ubar = drift + b0 * dW
    up = drift + b0 * sdt
    um = drift - b0 * sdt
    t1 = t + dt
    a1 = f(ubar, p, t1)
    bp = g(up, p, t1)
    bm = g(um, p, t1)
    return (u + 0.5 * dt * (a1 + a0)
            + 0.25 * dW * (bp + bm + 2.0 * b0)
            + 0.25 * (dW * dW - dt) / sdt * (bp - bm))


def milstein_step(f, g, u, p, t, dt, dW, noise="diagonal"):
    """Milstein (diagonal noise): strong order 1.0 — beyond the paper's kernel
    set (GPUEM is strong 0.5). The derivative term comes from forward-mode AD
    on the user's diffusion (automated translation again: no hand Jacobians).
        X' = X + a dt + b dW + 1/2 ((∂b/∂x)·b) (dW² - dt)
    Exact for componentwise diffusions g_i(u_i) (GBM, CLE birth/death terms);
    cross-component ∂g_i/∂u_j would need Lévy-area terms (not included).
    """
    if noise != "diagonal":
        raise ValueError("milstein currently supports diagonal noise")
    a0 = f(u, p, t)
    b0, db = jax.jvp(lambda uu: g(uu, p, t), (u,), (g(u, p, t),))
    # db = (∂b/∂u)·b elementwise along the diagonal-noise structure
    return u + a0 * dt + b0 * dW + 0.5 * db * (dW * dW - dt)


SDE_STEPPERS = {
    "em": em_step,
    "heun_strat": heun_strat_step,
    "platen_w2": platen_w2_step,
    "siea": platen_w2_step,  # paper-facing alias
    "milstein": milstein_step,
}


# ----------------------------------------------------------------------------
# embedded error pairs (RSwM-style rejection sampling, no step doubling)
# ----------------------------------------------------------------------------
#
# An embedded pair returns (u_prop, err) from ONE pass over the interval: the
# propagated solution plus a local error estimate built from a cheap companion
# scheme on the SAME Brownian increment.  Against step doubling (three stepper
# evaluations + an extra Brownian-tree descent per attempted step) this costs
# ~1 stepper evaluation and ONE descent — the ~2x adaptive-SDE win recorded in
# ROADMAP.md.  Rejection stays exact for free: increments come from the
# virtual Brownian tree, a pure function of (seed; lane, row, dyadic time),
# so a rejected step retried with a smaller dt replays the path bitwise.

def em_embedded_step(f, g, u, p, t, dt, dW, noise="diagonal"):
    """Euler-Maruyama propagation + embedded tamed-Milstein-difference error.

    The companion is the drift-tamed (Hutzenthaler-Jentzen) diagonal Milstein
    scheme; since it shares the drift and diffusion increments with EM, the
    pair difference is the Milstein correction plus the drift-taming term:

        err = 1/2 ((∂b/∂x)·b) (dW² - dt)  +  (a - a/(1 + dt|a|)) dt

    The first term — O(dt) in the strong sense — is the leading term EM omits
    relative to strong order 1 and dominates for genuinely stochastic steps;
    the second, O(dt²), keeps the estimator drift-aware so the controller
    still resolves the deterministic dynamics when the diffusion is locally
    negligible (a pure Milstein difference is blind there).  Diagonal noise
    only (a general-noise companion would need Lévy areas — use
    ``error_est="doubling"`` there).

    The pair deliberately propagates the PLAIN EM solution, not the
    Milstein-corrected one: acceptance conditions on |dW² - dt|, and adding
    the correction only on accepted steps would accumulate the truncated
    tail of the chi-square as a systematic bias (the classic hazard of
    noise-adapted step sizes).  EM's own missing term telescopes against the
    true path regardless of the acceptance rule.
    """
    if noise != "diagonal":
        raise ValueError("em embedded pair supports diagonal noise only; "
                         "use error_est='doubling' for general noise")
    a0 = f(u, p, t)
    b0, db = jax.jvp(lambda uu: g(uu, p, t), (u,), (g(u, p, t),))
    err = (0.5 * db * (dW * dW - dt)
           + (a0 - a0 / (1.0 + dt * jnp.abs(a0))) * dt)
    return u + a0 * dt + b0 * dW, err


def milstein_embedded_step(f, g, u, p, t, dt, dW, noise="diagonal"):
    """Milstein propagation + deterministic embedded companion error.

    Two estimator terms, both deterministic in dW (so acceptance never
    conditions on the realized increments — no truncation-bias floor, unlike
    the em pair):

    * drift: the increment-tamed companion (Hutzenthaler & Jentzen taming,
      a -> a / (1 + dt|a|)), whose difference
      ``(a - a/(1 + dt|a|)) dt = a|a| dt²/(1+dt|a|)`` (O(dt²)) tracks the
      deterministic-Taylor remainder and drift-explosion regimes;
    * diffusion: the rms of the leading neglected Ito-Taylor term
      L¹L¹b · I₍₁,₁,₁₎ — ``|∂((∂b)·b)·b| · dt^1.5 / sqrt(6)`` (E[I₁₁₁²] =
      dt³/6) via a nested diffusion JVP.  Without it the estimator is blind
      on diffusion-dominated problems (zero-drift SDEs would accept any dt).

    Extra cost over the plain stepper: diffusion JVPs only — no drift
    evaluations, so nf_per_attempt stays 1.
    """
    if noise != "diagonal":
        raise ValueError("milstein currently supports diagonal noise")
    a0 = f(u, p, t)

    def db_of(uu):
        bb = g(uu, p, t)
        return jax.jvp(lambda w: g(w, p, t), (uu,), (bb,))[1]

    b0 = g(u, p, t)
    db, ddb = jax.jvp(db_of, (u,), (b0,))      # (∂b)·b and ∂((∂b)·b)·b
    u_new = u + a0 * dt + b0 * dW + 0.5 * db * (dW * dW - dt)
    dt15 = dt * _sqrt_dt(dt, u.dtype)
    err = ((a0 - a0 / (1.0 + dt * jnp.abs(a0))) * dt
           + jnp.abs(ddb) * dt15 / jnp.sqrt(jnp.asarray(6.0, u.dtype)))
    return u_new, err


class EmbeddedPair(NamedTuple):
    """An SDE embedded error pair as registered on a `MethodSpec`.

    fn:             (f, g, u, p, t, dt, dW, noise) -> (u_prop, err)
    est_order:      dt-order of the estimator (PI controller exponents)
    nf_per_attempt: drift evaluations charged to `nf` per attempted step
    """
    fn: Callable
    est_order: int
    nf_per_attempt: int


# name -> EmbeddedPair.  Steppers absent here support error_est="doubling"
# only (the registry derives the capability tuple from this).
SDE_EMBEDDED = {
    "em": EmbeddedPair(em_embedded_step, est_order=1, nf_per_attempt=1),
    # estimator leading term is O(dt^1.5) (the L¹L¹b proxy); est_order=1 is
    # the conservative integer controller exponent for it
    "milstein": EmbeddedPair(milstein_embedded_step, est_order=1,
                             nf_per_attempt=1),
}


def counter_normals(key, step, shape, dtype):
    """Counter-based N(0,1) draw for a given step index (replayable)."""
    return jax.random.normal(jax.random.fold_in(key, step), shape, dtype)


def sde_nf_per_step(method: str) -> int:
    """Drift evaluations per step (the nf work proxy), per method.

    em and milstein evaluate the drift once (milstein's extra work is a
    diffusion JVP, not an RHS call); the two-stage schemes evaluate it twice.
    """
    return 1 if method in ("em", "milstein") else 2


def sde_save_grid(t0, dt, n_steps: int, save_every: int, dtype):
    """The fixed-step snapshot times: t0 + dt*save_every*(1..S)."""
    return jnp.asarray(t0, dtype) + jnp.asarray(dt, dtype) * save_every \
        * jnp.arange(1, n_steps // save_every + 1, dtype=dtype)


def _sde_snapshot(us, u, k, save_every: int):
    """Masked snapshot write for step k (shared by the fixed-dt loop bodies)."""
    s = (k + 1) // save_every - 1
    return jax.lax.cond(
        (k + 1) % save_every == 0,
        lambda us: jax.lax.dynamic_update_slice(
            us, u[None], (s,) + (0,) * (us.ndim - 1)),
        lambda us: us, us)


def sde_step_and_save(stepper, f, g, noise: str, u, us, p, t0, dt, k, z,
                      save_every: int):
    """ONE fixed-dt step + masked snapshot write — the loop body every SDE
    execution path shares (vmap, XLA lanes, Pallas kernel), so the
    (step, save-index) plumbing that bitwise cross-backend parity depends on
    exists exactly once.  Layout-polymorphic: u (n,)/(n, B) with us
    (S, n)/(S, n, B); z is the N(0,1) draw for step k."""
    dtv = jnp.asarray(dt, u.dtype)
    t = t0 + k * dtv
    u = stepper(f, g, u, p, t, dtv, z * jnp.sqrt(dtv), noise)
    us = _sde_snapshot(us, u, k, save_every)
    return u, us


def sde_event_state0(cshape, t0, dtype):
    """Initial per-control-element event/termination state for the fixed-dt
    event-aware loop body: (done, t_out, naccept, event_t, event_count)."""
    return dict(done=jnp.zeros(cshape, bool),
                t_out=jnp.broadcast_to(jnp.asarray(t0, dtype), cshape),
                naccept=jnp.zeros(cshape, jnp.int32),
                event_t=jnp.full(cshape, jnp.inf, dtype),
                event_count=jnp.zeros(cshape, jnp.int32))


def sde_step_save_event(stepper, f, g, noise: str, ev: Event, u, us, estate,
                        p, t0, dt, k, z, save_every: int):
    """Event-aware variant of `sde_step_and_save` — the shared fixed-dt loop
    body with per-lane termination (paper §6.6 on the SDE family).

    Event times are located by bisection on the piecewise-linear path output
    (`repro.core.events`).  Terminal hits freeze the element's state/lane; a
    non-terminal affect is applied at the event point and integration resumes
    at the step's grid end (the fixed grid is never rewound).  estate is the
    dict from `sde_event_state0`.  Layout-polymorphic like the no-event body,
    so the vmap / XLA-lanes / Pallas paths stay bitwise-identical.
    """
    dtv = jnp.asarray(dt, u.dtype)
    t = t0 + k * dtv
    lanes = u.ndim == 2
    active = ~estate["done"]
    u_new = stepper(f, g, u, p, t, dtv, z * jnp.sqrt(dtv), noise)

    def interp_fn(theta):
        return linear_interp(u, u_new, theta, lanes=lanes)

    u_next, t_next, ev_t, ev_n, term = handle_event(
        ev, interp_fn, u, u_new, p, t, dtv, t + dtv, active,
        estate["event_t"], estate["event_count"], lanes=lanes)
    act_e = active[None] if lanes else active
    u = jnp.where(act_e, u_next, u)
    # terminal: report the located event time; otherwise the grid time
    t_out = jnp.where(term, t_next, jnp.where(active, t + dtv,
                                              estate["t_out"]))
    us = _sde_snapshot(us, u, k, save_every)
    estate = dict(done=estate["done"] | term, t_out=t_out,
                  naccept=estate["naccept"] + active.astype(jnp.int32),
                  event_t=ev_t, event_count=ev_n)
    return u, us, estate


def sde_resume_init(u0, p, t0, dt, n_steps, lane):
    """Fresh per-lane resume carry for the fixed-dt SDE loop (lanes mode).

    u0 (n, B); p (k, B); t0/dt scalars or (B,); n_steps scalar or (B,) int32
    per-lane step counts; lane scalar or (B,) uint32 GLOBAL lane indices —
    the counter-RNG stream key.  The stream key travels WITH the carry, so a
    recycled slot keeps its request's noise stream: `sde_resume_body` draws
    step k of lane g from counter_normals_threefry(seed, k, g, row) exactly
    like `repro.kernels.em.ref.ref_solve` does, making slot recycling
    bitwise-invisible.
    """
    dtype = u0.dtype
    cshape = (u0.shape[-1],)
    tv = jnp.broadcast_to(jnp.asarray(t0, dtype), cshape).astype(dtype)
    dtv = jnp.broadcast_to(jnp.asarray(dt, dtype), cshape).astype(dtype)
    return dict(
        u=u0, p=p,
        k=jnp.zeros(cshape, jnp.int32),
        n_steps=jnp.broadcast_to(jnp.asarray(n_steps, jnp.int32), cshape),
        t0=tv, dt=dtv,
        lane=jnp.broadcast_to(jnp.asarray(lane, jnp.uint32), cshape),
        done=jnp.zeros(cshape, bool),
        t_out=tv,
        naccept=jnp.zeros(cshape, jnp.int32),
        nf=jnp.zeros(cshape, jnp.int32),
        status=jnp.zeros(cshape, jnp.int32),
        event_t=jnp.full(cshape, jnp.inf, dtype),
        event_count=jnp.zeros(cshape, jnp.int32),
        iters=jnp.asarray(0, jnp.int32),
    )


def sde_resume_body(f, g, method: str, noise: str, m_noise: int, seed,
                    event: Optional[Event] = None):
    """Per-lane resumable fixed-dt SDE step body over the carry from
    `sde_resume_init` — the ops of `sde_step_and_save` (or
    `sde_step_save_event`) with per-lane (k, t0, dt, n_steps, lane) instead
    of shared scalars, and no snapshot buffer (serving returns final states).
    Done lanes are write-masked, so mixed-progress slots are exact no-ops;
    active lanes realize elementwise the SAME expressions as the fresh loop
    body on the same (seed; step, lane, row) counters — bitwise recycling.
    """
    stepper = SDE_STEPPERS[method]
    nfps = sde_nf_per_step(method)

    def body(c):
        from repro.kernels.rng import counter_normals_threefry
        u, p = c["u"], c["p"]
        dtype = u.dtype
        B = u.shape[-1]
        active = ~c["done"]
        k, dtv = c["k"], c["dt"]
        t = c["t0"] + k * dtv
        lane = jnp.broadcast_to(c["lane"][None, :], (m_noise, B))
        rows = jax.lax.broadcasted_iota(jnp.uint32, (m_noise, B), 0)
        z = counter_normals_threefry(seed, k, lane, rows, dtype)
        u_new = stepper(f, g, u, p, t, dtv, z * jnp.sqrt(dtv), noise)
        if event is not None:
            def interp_fn(theta):
                return linear_interp(u, u_new, theta, lanes=True)

            u_next, t_next, ev_t, ev_n, term = handle_event(
                event, interp_fn, u, u_new, p, t, dtv, t + dtv, active,
                c["event_t"], c["event_count"], lanes=True)
        else:
            u_next = u_new
            t_next = t + dtv
            ev_t, ev_n = c["event_t"], c["event_count"]
            term = jnp.zeros((B,), bool)
        u_out = jnp.where(active[None], u_next, u)
        t_out = jnp.where(term, t_next,
                          jnp.where(active, t + dtv, c["t_out"]))
        k_new = k + active.astype(jnp.int32)
        done = c["done"] | term | (k_new >= c["n_steps"])
        return dict(
            u=u_out, p=p, k=k_new, n_steps=c["n_steps"], t0=c["t0"], dt=dtv,
            lane=c["lane"], done=done, t_out=t_out,
            naccept=c["naccept"] + active.astype(jnp.int32),
            nf=c["nf"] + active.astype(jnp.int32) * nfps,
            status=c["status"], event_t=ev_t, event_count=ev_n,
            iters=c["iters"] + 1,
        )

    return body


def sde_solve_fixed(prob: SDEProblem, u0, p, t0, dt, n_steps: int, key,
                    method: str = "em", save_every: int = 1,
                    noise_table: Optional[Array] = None,
                    remat: bool = False) -> SolveResult:
    """Fixed-dt SDE integration as scan(fori(step)); kernel-shaped state flow.

    u0: (n,) or (n, B) lanes. Noise per step: (m,) / (m, B).
    noise_table: optional (n_steps, m[, B]) pre-drawn N(0,1) (pathwise tests).
    remat=True checkpoints each save segment for reverse-mode AD: bitwise
    the same primal, the backward pass replays the counter-RNG increments
    from the segment-boundary carry instead of storing every step
    (O(S + save_every) adjoint memory; pathwise replay is exact because the
    noise is a pure function of the step index).
    """
    assert n_steps % save_every == 0
    S = n_steps // save_every
    stepper = SDE_STEPPERS[method]
    dtype = u0.dtype
    dt = jnp.asarray(dt, dtype)
    sdt = _sqrt_dt(dt, dtype)
    m = prob.noise_dim()
    nshape = (m,) + u0.shape[1:]

    def one(k, uk):
        u, t = uk
        if noise_table is not None:
            z = noise_table[k].astype(dtype)
        else:
            z = counter_normals(key, k, nshape, dtype)
        u = stepper(prob.f, prob.g, u, p, t, dt, z * sdt, prob.noise)
        return (u, t + dt)

    def inner(carry, s):
        u, t = carry
        k0 = s * save_every

        def body(i, uk):
            return one(k0 + i, uk)

        u, t = jax.lax.fori_loop(0, save_every, body, (u, t))
        return (u, t), u

    if remat:
        inner = jax.checkpoint(inner)
    (u_f, t_f), us = jax.lax.scan(inner, (u0, jnp.asarray(t0, dtype)),
                                  jnp.arange(S))
    ts = jnp.asarray(t0, dtype) + dt * save_every * jnp.arange(1, S + 1,
                                                               dtype=dtype)
    return SolveResult(ts=ts, us=us, t_final=t_f, u_final=u_f,
                       naccept=jnp.asarray(n_steps), nreject=jnp.asarray(0),
                       status=jnp.asarray(0),
                       nf=jnp.asarray(n_steps * (2 if method != "em" else 1)))


# ----------------------------------------------------------------------------
# adaptive driver (while_loop): embedded-pair or step-doubling error + virtual
# Brownian tree (RSwM-style rejection-safe noise), scalar/lanes polymorphic
# ----------------------------------------------------------------------------

def default_bridge_depth(t0, tf, dt0, min_depth: int = 6,
                         max_depth: int = 22) -> int:
    """Dyadic resolution of the virtual Brownian tree for adaptive stepping.

    Depth D puts the finest grid at (tf-t0)/2**D; the controller can shrink
    steps to 2 grid cells, so the default gives ~64x refinement headroom below
    dt0 (steps at the floor force-accept — raise the depth for very tight
    tolerances).  Static (python) arithmetic: the depth is part of the
    compiled program, identical on every strategy/backend.
    """
    import math
    n0 = max(1.0, (float(tf) - float(t0)) / float(dt0))
    return int(min(max_depth, max(min_depth, math.ceil(math.log2(n0)) + 6)))


def sde_solve_adaptive(f, g, stepper, noise: str, u0, p, t0, tf, dt0, *,
                       seed, lane_idx, m_noise: int, saveat=None,
                       rtol=1e-2, atol=1e-4, max_iters: int = 100_000,
                       event: Optional[Event] = None, lanes: bool = False,
                       depth: Optional[int] = None, order: float = 0.5,
                       nf_per_step: int = 1,
                       error_est: str = "doubling",
                       embedded: Optional[Callable] = None,
                       est_order: Optional[int] = None,
                       nf_per_attempt: Optional[int] = None,
                       controller: Optional["PIController"] = None,
                       bounded_steps: Optional[int] = None,
                       checkpoint_every: Optional[int] = None):
    """Adaptive SDE integration with per-element dt control and events.

    The missing half of the paper's "fully featured" claim for the SDE family:

    * **Local error** per attempted step, one of two estimators
      (``error_est``):

      - ``"embedded"`` — an embedded pair (`embedded`, e.g.
        `em_embedded_step`): ONE pass over the interval returns the
        propagated solution plus a companion-difference error estimate.
        ~1 stepper evaluation and one Brownian-tree descent per attempt —
        the default for steppers that ship a pair (see `SDE_EMBEDDED`).
      - ``"doubling"`` — step doubling: integrate once with dt and once as
        two dt/2 substeps *driven by the same Brownian path*; their
        difference is the error estimate and the finer solution propagates
        (local extrapolation).  Three stepper evaluations and two descents
        per attempt, but works for every registered stepper — no per-method
        pair needed.  Kept as the A/B reference and the general-noise path.
    * **Rejection-safe noise** (RSwM property): increments come from the
      virtual Brownian tree (`repro.kernels.rng.brownian_bridge_point`) — a
      pure function of (seed; lane, row, dyadic time) — so a rejected step
      retried with smaller dt sees exactly the same path, bitwise, on every
      strategy and backend.  Step sizes are quantized to whole cells of the
      depth-D dyadic grid (D = `depth`, default `default_bridge_depth`); the
      doubling estimator additionally rounds to an EVEN cell count so its
      half-steps land on grid points.
    * **Events** run the shared machinery (`repro.core.events`) on the
      piecewise-linear path output, with per-lane termination masks.
      Terminal hits freeze the lane at the located event time; a
      non-terminal affect is applied at the event point and integration
      resumes from the dyadic grid cell that re-anchors the located event
      time (NOT the step's grid end — the rejection machinery makes the
      rewind free: the bridge replays W at the re-anchored index bitwise).
    * **saveat** dense output: snapshots land on an arbitrary time grid via
      linear interpolation over accepted steps.

    `est_order` is the dt-order of the error estimator (PI controller
    exponents); `nf_per_attempt` the drift-evaluation count charged to `nf`
    per attempted step (defaults: 3 stepper evaluations for doubling, the
    `SDE_EMBEDDED` entry for pairs).

    Shape contract (same as the ERK engine): lanes=False integrates one
    trajectory u0 (n,) with scalar control and a scalar `lane_idx` (the
    trajectory's GLOBAL index — the RNG stream key); lanes=True integrates
    u0 (n, B) with per-lane control and lane_idx (B,).  Returns SolveResult,
    or (SolveResult, {"event_t", "event_count"}) when an event is supplied.

    ``bounded_steps``/``checkpoint_every`` select the reverse-differentiable
    bounded loop (`repro.core.loops.solver_loop`), enabling pathwise
    gradients through the accepted step sequence.  The step-size chain here
    is ALREADY gradient-frozen by construction — dt is consumed through a
    uint32 grid-cell count, and the Brownian increments are pure functions
    of integer indices, so vjp recomputation replays the virtual tree
    bitwise; the only extra severing needed is ``stop_gradient`` on the
    error norm (zero-cotangent sqrt hazard).  Too-small bound surfaces as
    ``status == 1``.
    """
    dtype = u0.dtype
    if error_est not in ("embedded", "doubling"):
        raise ValueError(f"unknown error_est {error_est!r} "
                         "(use 'embedded' or 'doubling')")
    use_pair = error_est == "embedded"
    if use_pair and embedded is None:
        raise ValueError("error_est='embedded' needs an embedded pair fn "
                         "(see repro.core.sde.SDE_EMBEDDED)")
    if est_order is None:
        est_order = max(1, int(round(order)))
    if nf_per_attempt is None:
        nf_per_attempt = 3 * nf_per_step
    ctrl = controller or PIController.for_order(int(est_order))
    cshape = (u0.shape[-1],) if lanes else ()
    axes = 0 if lanes else None
    t0 = jnp.asarray(t0, dtype)
    tf = jnp.asarray(tf, dtype)
    if depth is None:
        raise ValueError("sde_solve_adaptive needs a static `depth` "
                         "(see default_bridge_depth)")
    n_total = 2 ** depth
    h_res = (tf - t0) / n_total
    t_total = tf - t0

    from repro.kernels.rng import brownian_bridge_point

    if lanes:
        B = u0.shape[-1]
        lane_m = jnp.broadcast_to(
            jnp.asarray(lane_idx, jnp.uint32)[None, :], (m_noise, B))
        rows = jax.lax.broadcasted_iota(jnp.uint32, (m_noise, B), 0)

        def w_at(idx_c):                      # (B,) grid index -> (m, B)
            return brownian_bridge_point(
                seed, jnp.broadcast_to(idx_c[None, :], (m_noise, B)), lane_m,
                rows, depth=depth, t_total=t_total, dtype=dtype)
    else:
        lane_m = jnp.full((m_noise,), jnp.asarray(lane_idx, jnp.uint32))
        rows = jnp.arange(m_noise, dtype=jnp.uint32)

        def w_at(idx_c):                      # scalar grid index -> (m,)
            return brownian_bridge_point(
                seed, jnp.full((m_noise,), idx_c), lane_m, rows, depth=depth,
                t_total=t_total, dtype=dtype)

    if saveat is None:
        saveat = jnp.asarray([tf], dtype)
    saveat = jnp.asarray(saveat, dtype)
    S = saveat.shape[0]
    us0 = jnp.zeros((S,) + u0.shape, dtype)
    pre = (saveat <= t0).reshape((S,) + (1,) * u0.ndim)
    us0 = jnp.where(pre, u0[None], us0)

    n_total_u = jnp.asarray(n_total, jnp.uint32)
    nshape = (m_noise,) + cshape
    carry0 = dict(
        w_l=jnp.zeros(nshape, dtype),        # W(idx): W(0) = 0 exactly
        idx=jnp.zeros(cshape, jnp.uint32), u=u0,
        dt=jnp.broadcast_to(jnp.asarray(dt0, dtype), cshape),
        enorm_prev=jnp.ones(cshape, dtype),
        done=jnp.zeros(cshape, bool), us=us0,
        t_out=jnp.broadcast_to(t0, cshape),
        naccept=jnp.zeros(cshape, jnp.int32),
        nreject=jnp.zeros(cshape, jnp.int32),
        nf=jnp.zeros(cshape, jnp.int32),
        status=jnp.zeros(cshape, jnp.int32),
        iters=jnp.asarray(0, jnp.int32),
        event_t=jnp.full(cshape, jnp.inf, dtype),
        event_count=jnp.zeros(cshape, jnp.int32),
    )

    def cond(c):
        return (c["iters"] < max_iters) & jnp.any(~c["done"])

    def body(c):
        u, dt = c["u"], c["dt"]
        active = ~c["done"]
        idx = jnp.where(active, c["idx"], jnp.zeros_like(c["idx"]))
        t = t0 + idx.astype(dtype) * h_res
        # quantize the proposed dt to whole dyadic grid cells; the doubling
        # estimator needs an EVEN count so its half-steps land on grid points
        want = (jnp.minimum(dt, t_total) / h_res).astype(jnp.uint32)
        min_cells = jnp.uint32(1 if use_pair else 2)
        # resolution floor: the controller asked for < min_cells cells — no
        # finer path information exists at this depth, so the step
        # force-accepts (raise `depth`/brownian_depth for tighter tolerances)
        at_floor = want < min_cells
        m = (want if use_pair else (want >> 1) << 1)
        m = jnp.clip(m, min_cells, n_total_u - idx)
        dt_step = m.astype(dtype) * h_res

        # W at the left endpoint is carried from the previous iteration (it
        # equals last step's right endpoint on accept and is unchanged on
        # reject — the bridge is a pure function of idx, so this is exact,
        # and it saves one tree descent per attempted step)
        w_l = c["w_l"]
        w_r = w_at(idx + m)
        dWf = w_r - w_l

        if use_pair:
            # embedded pair: one pass gives the propagated solution AND the
            # companion-difference error — no midpoint descent, no half steps
            u_2, err = embedded(f, g, u, p, t, dt_step, dWf, noise)
        else:
            mh = m >> 1
            dt_half = mh.astype(dtype) * h_res
            t_mid = t0 + (idx + mh).astype(dtype) * h_res
            w_m = w_at(idx + mh)
            dW1, dW2 = w_m - w_l, w_r - w_m
            # one coarse step vs two half steps on the SAME path; keep finer
            u_c = stepper(f, g, u, p, t, dt_step, dWf, noise)
            u_h = stepper(f, g, u, p, t, dt_half, dW1, noise)
            u_2 = stepper(f, g, u_h, p, t_mid, dt_half, dW2, noise)
            # Richardson: the raw difference understates the error of the
            # PROPAGATED (finer) solution by (2^q - 1), q the stepper's
            # strong order — rescale so both estimators target the same
            # local error for the solution they actually advance
            err = (u_2 - u_c) * (1.0 / (2.0 ** order - 1.0))
        enorm = hairer_norm(err, u, u_2, atol, rtol, axes=axes)
        if bounded_steps is not None:
            # pathwise discrete adjoint: the controller chain is primal-only
            # (dt is consumed via an integer cell count anyway); this severs
            # the hairer_norm sqrt from the transpose so a zero local error
            # cannot inject NaN through sqrt'(0)
            enorm = jax.lax.stop_gradient(enorm)
        finite = jnp.isfinite(u_2)
        finite = jnp.all(finite, axis=0) if lanes else jnp.all(finite)
        accept = ((enorm <= 1.0) | at_floor) & finite & active
        dt_next, enorm_prev = pi_propose(ctrl, dt_step, enorm,
                                         c["enorm_prev"], accept)

        idx_new = jnp.where(accept, idx + m, idx)
        t_new = t0 + idx_new.astype(dtype) * h_res

        if event is not None:
            def interp_fn(theta):
                return linear_interp(u, u_2, theta, lanes=lanes)

            u_next, t_ev, ev_t, ev_n, term = handle_event(
                event, interp_fn, u, u_2, p, t, dt_step, t_new, accept,
                c["event_t"], c["event_count"], lanes=lanes)
            # non-terminal hit: the affected state lives at the located event
            # time t_ev, NOT the step's grid end — re-anchor onto the dyadic
            # grid (first cell boundary at/after t_ev) so integration resumes
            # where the affect was applied.  The rewind is free: the Brownian
            # tree replays W at the re-anchored index bitwise (the same
            # machinery that makes rejected steps exact).
            hit_nt = (ev_n > c["event_count"]) & ~term
            cells = jnp.clip(
                jnp.ceil((t_ev - t) / h_res - 1e-6).astype(jnp.uint32),
                jnp.uint32(1), m)
            idx_new = jnp.where(hit_nt, idx + cells, idx_new)
            t_new = t0 + idx_new.astype(dtype) * h_res
        else:
            u_next = u_2
            t_ev = t_new
            ev_t, ev_n = c["event_t"], c["event_count"]
            term = jnp.zeros(cshape, bool)
            hit_nt = term

        acc_e = accept[None] if lanes else accept
        u_new = jnp.where(acc_e, u_next, u)
        # reported time: located event time for terminal hits, grid otherwise
        t_out = jnp.where(term, t_ev, jnp.where(accept, t_new, c["t_out"]))
        t_lim = jnp.where(term, t_ev, t_new)

        # ---- linear dense save on the accepted step ------------------------
        eps = jnp.asarray(1e-7, dtype) * jnp.maximum(jnp.abs(t_lim), 1.0)
        if lanes:
            crossed = ((saveat[:, None] > t[None, :])
                       & (saveat[:, None] <= t_lim[None, :] + eps[None, :])
                       & accept[None, :])
            theta = jnp.clip((saveat[:, None] - t[None, :])
                             / dt_step[None, :], 0.0, 1.0)
            vals = u[None] + theta[:, None, :] * (u_2 - u)[None]
            us = jnp.where(crossed[:, None, :], vals, c["us"])
        else:
            crossed = (saveat > t) & (saveat <= t_lim + eps) & accept
            theta = jnp.clip((saveat - t) / dt_step, 0.0, 1.0)
            sh = (S,) + (1,) * u0.ndim
            vals = u[None] + theta.reshape(sh) * (u_2 - u)[None]
            us = jnp.where(crossed.reshape(sh), vals, c["us"])

        # rejecting at the dyadic resolution floor (can only mean non-finite
        # states there — at_floor otherwise force-accepts) or with dt pinned
        # at the controller floor: the retry is bit-identical, so terminate
        # the lane with a distinct status instead of spinning to max_iters
        hopeless = (active & ~accept
                    & (at_floor | ~(dt_step > ctrl.dtmin)))
        statusv = jnp.where(hopeless,
                            jnp.asarray(STATUS_DTMIN_EXHAUSTED, jnp.int32),
                            c["status"])
        done = c["done"] | term | (idx_new >= n_total_u) | hopeless
        acc_m = accept[None] if lanes else accept
        w_l_new = jnp.where(acc_m, w_r, w_l)
        if event is not None:
            # re-anchored lanes restart mid-step: their left-endpoint W is at
            # idx_new, not idx + m.  In lanes mode the scalar any() predicate
            # makes lax.cond a real branch — the extra descent is paid only
            # on iterations where a non-terminal event actually fired.  In
            # scalar mode (vmapped per-trajectory) the predicate is batched
            # and cond would lower to select anyway, so compute it directly.
            hit_m = hit_nt[None] if lanes else hit_nt

            def _refresh():
                return jnp.where(hit_m, w_at(idx_new), w_l_new)

            if lanes:
                w_l_new = jax.lax.cond(jnp.any(hit_nt), _refresh,
                                       lambda: w_l_new)
            else:
                w_l_new = _refresh()
        return dict(
            w_l=w_l_new,
            idx=idx_new, u=u_new, dt=dt_next, enorm_prev=enorm_prev,
            done=done, us=us, t_out=t_out,
            naccept=c["naccept"] + accept.astype(jnp.int32),
            nreject=c["nreject"] + (active & ~accept).astype(jnp.int32),
            nf=c["nf"] + active.astype(jnp.int32) * nf_per_attempt,
            status=statusv, iters=c["iters"] + 1,
            event_t=ev_t, event_count=ev_n)

    out = solver_loop(cond, body, carry0, bounded_steps=bounded_steps,
                      checkpoint_every=checkpoint_every)
    res = SolveResult(
        ts=saveat, us=out["us"], t_final=out["t_out"], u_final=out["u"],
        naccept=out["naccept"], nreject=out["nreject"],
        status=jnp.where(out["status"] > 0, out["status"],
                         jnp.where(out["done"], 0, 1)).astype(jnp.int32),
        nf=out["nf"])
    if event is not None:
        return res, dict(event_t=out["event_t"], event_count=out["event_count"])
    return res


def solve_sde_ensemble(eprob: EnsembleProblem, key, dt, n_steps=None,
                       method="em", ensemble="kernel", backend="xla",
                       save_every=1, t0=None, tf=None,
                       lane_tile=None) -> "EnsembleSDEResult":
    """Legacy SDE-facing wrapper over the unified front door
    (`repro.core.ensemble.solve_ensemble_local`): same fixed-dt kernels
    (paper §5.2.2), dispatched through the method registry, result adapted to
    the SDE-shaped tuple.  New code should call the front door directly with
    ``alg=method``."""
    from .ensemble import solve_ensemble_local

    res = solve_ensemble_local(
        eprob, alg=method, ensemble=ensemble, backend=backend, t0=t0, tf=tf,
        dt0=dt, n_steps=n_steps, save_every=save_every, lane_tile=lane_tile,
        key=key)
    return EnsembleSDEResult(ts=res.ts, us=res.us, u_final=res.u_final,
                             nf=res.nf)


class EnsembleSDEResult(NamedTuple):
    ts: Array
    us: Array        # (N, S, n)
    u_final: Array   # (N, n)
    nf: Array
