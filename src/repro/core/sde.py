"""SDE steppers (paper §3.2, §5.2.2, §6.8): fixed-dt, kernel-shaped.

Methods (matching the paper's GPU kernel set):
  em         — GPUEM: Euler-Maruyama, Ito; diagonal AND general (n×m) noise.
  platen_w2  — GPUSIEA role: explicit weak-order-2 Platen scheme
               (Kloeden & Platen §14.2), diagonal noise only — the weak-order-2
               stochastic generalization of the midpoint/improved-Euler family.
  heun_strat — Stratonovich Heun (extra, beyond paper).

Noise is counter-based: dW for step k is drawn from fold_in(key, k), so the
stepper needs no noise storage (the paper's per-thread PRNG state), trajectories
are independent across lanes, and any step's noise can be replayed (used by the
pathwise tests and by the pallas/XLA cross-validation).

All steppers are shape-polymorphic like the ODE engine: u (n,) scalar-mode or
(n, B) lanes-mode; the SAME definition runs vmapped, lane-fused, and inside the
Pallas EM kernel (kernels/em).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .problem import EnsembleProblem, SDEProblem
from .solvers import SolveResult

Array = Any


def _sqrt_dt(dt, dtype):
    return jnp.sqrt(jnp.asarray(dt, dtype))


def apply_noise(g_val, dW, noise: str):
    """g(u)·dW with g_val (n,[B]) diagonal or (n,m,[B]) general; dW (m,[B])."""
    if noise == "diagonal":
        return g_val * dW
    # general: contract the noise axis (axis 1 of g_val)
    return jnp.einsum("nm...,m...->n...", g_val, dW)


def em_step(f, g, u, p, t, dt, dW, noise="diagonal"):
    """X' = X + f dt + g dW  (Ito; strong 0.5 / weak 1)."""
    return u + f(u, p, t) * dt + apply_noise(g(u, p, t), dW, noise)


def heun_strat_step(f, g, u, p, t, dt, dW, noise="diagonal"):
    """Stratonovich Heun (strong 0.5 / weak 1 in Stratonovich sense)."""
    du1 = f(u, p, t) * dt + apply_noise(g(u, p, t), dW, noise)
    ub = u + du1
    du2 = f(ub, p, t + dt) * dt + apply_noise(g(ub, p, t + dt), dW, noise)
    return u + 0.5 * (du1 + du2)


def platen_w2_step(f, g, u, p, t, dt, dW, noise="diagonal"):
    """Explicit weak-order-2 Platen scheme, diagonal noise (Kloeden & Platen
    (15.1.1)/(14.2.4) family). Supporting values:
        ubar = u + a dt + b dW ;  u± = u + a dt ± b sqrt(dt)
        u'   = u + dt/2 (a(ubar)+a(u))
                 + dW/4 (b(u+)+b(u-)+2 b(u))
                 + (dW^2-dt)/(4 sqrt(dt)) (b(u+)-b(u-))
    """
    if noise != "diagonal":
        raise ValueError("platen_w2 supports diagonal noise only (as the "
                         "paper's GPUSIEA)")
    a0 = f(u, p, t)
    b0 = g(u, p, t)
    sdt = _sqrt_dt(dt, u.dtype)
    drift = u + a0 * dt
    ubar = drift + b0 * dW
    up = drift + b0 * sdt
    um = drift - b0 * sdt
    t1 = t + dt
    a1 = f(ubar, p, t1)
    bp = g(up, p, t1)
    bm = g(um, p, t1)
    return (u + 0.5 * dt * (a1 + a0)
            + 0.25 * dW * (bp + bm + 2.0 * b0)
            + 0.25 * (dW * dW - dt) / sdt * (bp - bm))


def milstein_step(f, g, u, p, t, dt, dW, noise="diagonal"):
    """Milstein (diagonal noise): strong order 1.0 — beyond the paper's kernel
    set (GPUEM is strong 0.5). The derivative term comes from forward-mode AD
    on the user's diffusion (automated translation again: no hand Jacobians).
        X' = X + a dt + b dW + 1/2 ((∂b/∂x)·b) (dW² - dt)
    Exact for componentwise diffusions g_i(u_i) (GBM, CLE birth/death terms);
    cross-component ∂g_i/∂u_j would need Lévy-area terms (not included).
    """
    if noise != "diagonal":
        raise ValueError("milstein currently supports diagonal noise")
    a0 = f(u, p, t)
    b0, db = jax.jvp(lambda uu: g(uu, p, t), (u,), (g(u, p, t),))
    # db = (∂b/∂u)·b elementwise along the diagonal-noise structure
    return u + a0 * dt + b0 * dW + 0.5 * db * (dW * dW - dt)


SDE_STEPPERS = {
    "em": em_step,
    "heun_strat": heun_strat_step,
    "platen_w2": platen_w2_step,
    "siea": platen_w2_step,  # paper-facing alias
    "milstein": milstein_step,
}


def counter_normals(key, step, shape, dtype):
    """Counter-based N(0,1) draw for a given step index (replayable)."""
    return jax.random.normal(jax.random.fold_in(key, step), shape, dtype)


def sde_nf_per_step(method: str) -> int:
    """Drift evaluations per step (the nf work proxy), per method."""
    return 2 if method != "em" else 1


def sde_save_grid(t0, dt, n_steps: int, save_every: int, dtype):
    """The fixed-step snapshot times: t0 + dt*save_every*(1..S)."""
    return jnp.asarray(t0, dtype) + jnp.asarray(dt, dtype) * save_every \
        * jnp.arange(1, n_steps // save_every + 1, dtype=dtype)


def sde_step_and_save(stepper, f, g, noise: str, u, us, p, t0, dt, k, z,
                      save_every: int):
    """ONE fixed-dt step + masked snapshot write — the loop body every SDE
    execution path shares (vmap, XLA lanes, Pallas kernel), so the
    (step, save-index) plumbing that bitwise cross-backend parity depends on
    exists exactly once.  Layout-polymorphic: u (n,)/(n, B) with us
    (S, n)/(S, n, B); z is the N(0,1) draw for step k."""
    dtv = jnp.asarray(dt, u.dtype)
    t = t0 + k * dtv
    u = stepper(f, g, u, p, t, dtv, z * jnp.sqrt(dtv), noise)
    s = (k + 1) // save_every - 1
    us = jax.lax.cond(
        (k + 1) % save_every == 0,
        lambda us: jax.lax.dynamic_update_slice(
            us, u[None], (s,) + (0,) * (us.ndim - 1)),
        lambda us: us, us)
    return u, us


def sde_solve_fixed(prob: SDEProblem, u0, p, t0, dt, n_steps: int, key,
                    method: str = "em", save_every: int = 1,
                    noise_table: Optional[Array] = None) -> SolveResult:
    """Fixed-dt SDE integration as scan(fori(step)); kernel-shaped state flow.

    u0: (n,) or (n, B) lanes. Noise per step: (m,) / (m, B).
    noise_table: optional (n_steps, m[, B]) pre-drawn N(0,1) (pathwise tests).
    """
    assert n_steps % save_every == 0
    S = n_steps // save_every
    stepper = SDE_STEPPERS[method]
    dtype = u0.dtype
    dt = jnp.asarray(dt, dtype)
    sdt = _sqrt_dt(dt, dtype)
    m = prob.noise_dim()
    nshape = (m,) + u0.shape[1:]

    def one(k, uk):
        u, t = uk
        if noise_table is not None:
            z = noise_table[k].astype(dtype)
        else:
            z = counter_normals(key, k, nshape, dtype)
        u = stepper(prob.f, prob.g, u, p, t, dt, z * sdt, prob.noise)
        return (u, t + dt)

    def inner(carry, s):
        u, t = carry
        k0 = s * save_every

        def body(i, uk):
            return one(k0 + i, uk)

        u, t = jax.lax.fori_loop(0, save_every, body, (u, t))
        return (u, t), u

    (u_f, t_f), us = jax.lax.scan(inner, (u0, jnp.asarray(t0, dtype)),
                                  jnp.arange(S))
    ts = jnp.asarray(t0, dtype) + dt * save_every * jnp.arange(1, S + 1,
                                                               dtype=dtype)
    return SolveResult(ts=ts, us=us, t_final=t_f, u_final=u_f,
                       naccept=jnp.asarray(n_steps), nreject=jnp.asarray(0),
                       status=jnp.asarray(0),
                       nf=jnp.asarray(n_steps * (2 if method != "em" else 1)))


def solve_sde_ensemble(eprob: EnsembleProblem, key, dt, n_steps=None,
                       method="em", ensemble="kernel", backend="xla",
                       save_every=1, t0=None, tf=None,
                       lane_tile=None) -> "EnsembleSDEResult":
    """Legacy SDE-facing wrapper over the unified front door
    (`repro.core.ensemble.solve_ensemble_local`): same fixed-dt kernels
    (paper §5.2.2), dispatched through the method registry, result adapted to
    the SDE-shaped tuple.  New code should call the front door directly with
    ``alg=method``."""
    from .ensemble import solve_ensemble_local

    res = solve_ensemble_local(
        eprob, alg=method, ensemble=ensemble, backend=backend, t0=t0, tf=tf,
        dt0=dt, n_steps=n_steps, save_every=save_every, lane_tile=lane_tile,
        key=key)
    return EnsembleSDEResult(ts=res.ts, us=res.us, u_final=res.u_final,
                             nf=res.nf)


class EnsembleSDEResult(NamedTuple):
    ts: Array
    us: Array        # (N, S, n)
    u_final: Array   # (N, n)
    nf: Array
