"""Family-agnostic event handling (paper §6.6) — shared by every solver family.

The paper's feature matrix claims event handling on every backend.  PR 1 only
wired events through the explicit-RK engine; this module is the extraction
that makes events a *capability of the dispatch layer* instead of an ERK
special: detection (sign change of the condition over an accepted step),
refinement (bisection on a dense-output closure), and application (affect +
per-lane termination masks) are written once, against an abstract interpolant,
and reused by

  * `repro.core.solvers.solve_adaptive`      (ERK: tableau dense output),
  * `repro.core.rosenbrock.solve_rosenbrock23` (Hermite-cubic dense output),
  * `repro.core.sde.sde_solve_adaptive` and the fixed-dt SDE loop body
    (piecewise-linear dense output — the standard strong-order-consistent
    output for SDE paths).

Everything is shape-polymorphic over the control shape: scalar control for
per-trajectory solves, `(B,)` per-lane masks for the fused-kernel paths — the
same polymorphism contract as the step controllers, so any future family gets
events for free by providing a `theta -> state` closure.

The condition g(u, p, t) must return one value per control element (scalar in
scalar mode, `(B,)` in lanes mode); a zero crossing of g triggers the event.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = Any


class Event(NamedTuple):
    """condition g(u,p,t) crossing zero triggers affect h (paper §6.6).

    direction: -1 (+ -> -), +1 (- -> +), 0 (any crossing).
    terminal:  stop integration (the lane) at the event.
    affect:    (u, p, t) -> u_new  applied at the event point.
    bisect_iters: bisection refinement steps for the event time.

    Example — the paper's bouncing ball (Fig. 8): bounce when the height
    u[0] crosses zero downwards, flipping the velocity::

        Event(condition=lambda u, p, t: u[0],
              affect=lambda u, p, t: jnp.stack([u[0] * 0, -p[1] * u[1]]),
              direction=-1)
    """
    condition: Callable[[Array, Array, Array], Array]
    affect: Optional[Callable[[Array, Array, Array], Array]] = None
    terminal: bool = False
    direction: int = 0
    bisect_iters: int = 30


def event_crossing(ev: Event, g_old: Array, g_new: Array) -> Array:
    """Directional sign-change mask for g over one step (per control element)."""
    sgn_change = jnp.sign(g_old) * jnp.sign(g_new) < 0
    if ev.direction == -1:
        sgn_change &= g_new < g_old
    elif ev.direction == 1:
        sgn_change &= g_new > g_old
    return sgn_change


def bisect_event(ev: Event, interp_fn: Callable[[Array], Array], p, t_old,
                 dt_step, g_old):
    """Bisection for g=0 inside an accepted step using a dense-output closure.

    interp_fn(theta) must return the interpolated state at t_old +
    theta*dt_step, with theta shaped like g_old (one value per control
    element).  Returns (theta_star, u_star); only meaningful where the
    caller's `hit` mask is true.
    """
    lo = jnp.zeros_like(g_old)
    hi = jnp.ones_like(g_old)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        g_mid = ev.condition(interp_fn(mid), p, t_old + mid * dt_step)
        # root in [lo, mid] iff sign change between g_old and g_mid
        left = jnp.sign(g_old) * jnp.sign(g_mid) <= 0
        lo = jnp.where(left, lo, mid)
        hi = jnp.where(left, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, ev.bisect_iters, body, (lo, hi))
    theta = hi  # first point past the root: g has crossed
    return theta, interp_fn(theta)


def handle_event(ev: Event, interp_fn: Callable[[Array], Array], u_old, u_cand,
                 p, t_old, dt_step, t_new, accept, event_t, event_count, *,
                 lanes: bool = False):
    """Detect, locate, and apply `ev` over one accepted step — all families.

    interp_fn(theta) -> state at t_old + theta*dt_step (dense output closure;
    theta is control-shaped: scalar or (B,)).  accept is the step's acceptance
    mask; event_t/event_count are the running per-control-element logs.

    Returns (u_next, t_next, event_t, event_count, term) where `term` is the
    per-control-element termination mask (true only for terminal hits) the
    caller ORs into its `done` mask.
    """
    dtype = jnp.result_type(dt_step)
    g_old = ev.condition(u_old, p, t_old)
    g_new = ev.condition(u_cand, p, t_new)
    # an affect applied exactly at a root leaves g_old == 0 and would mask
    # every later crossing; re-anchor the sign just inside the step
    # (theta = 1e-4) in that case.
    theta_eps = (jnp.full_like(g_old, 1e-4) if lanes
                 else jnp.asarray(1e-4, dtype))
    g_eps = ev.condition(interp_fn(theta_eps), p, t_old + 1e-4 * dt_step)
    g_old = jnp.where(g_old == 0, g_eps, g_old)
    hit = event_crossing(ev, g_old, g_new) & accept
    theta_star, u_star = bisect_event(ev, interp_fn, p, t_old, dt_step, g_old)
    t_star = t_old + theta_star * dt_step
    if ev.affect is not None:
        u_aff = ev.affect(u_star, p, t_star)
    else:
        u_aff = u_star
    hit_e = hit[None] if lanes else hit
    u_next = jnp.where(hit_e, u_aff, u_cand)
    t_next = jnp.where(hit, t_star, t_new)
    ev_t = jnp.where(hit, t_star, event_t)
    ev_n = event_count + hit.astype(jnp.int32)
    term = hit if ev.terminal else jnp.zeros_like(hit)
    return u_next, t_next, ev_t, ev_n, term


# ---------------------------------------------------------------------------
# dense-output closures for families without a tableau interpolant
# ---------------------------------------------------------------------------

def hermite_interp(u_old, f_old, u_new, f_new, dt, theta, lanes: bool = False):
    """Cubic Hermite dense output on one step — u(t_old + theta*dt).

    The interpolant used by the Rosenbrock family (paper §5.1.3 methods carry
    the step-endpoint derivatives F0, F2 anyway).  theta control-shaped:
    scalar, or (B,) against u (n, B) in lanes mode.
    """
    if lanes:
        th = theta[None]
        dtb = dt[None]
    else:
        th = theta
        dtb = dt
    h00 = (1 + 2 * th) * (1 - th) ** 2
    h10 = th * (1 - th) ** 2
    h01 = th ** 2 * (3 - 2 * th)
    h11 = th ** 2 * (th - 1)
    return (h00 * u_old + h10 * dtb * f_old + h01 * u_new + h11 * dtb * f_new)


def linear_interp(u_old, u_new, theta, lanes: bool = False):
    """Piecewise-linear dense output — the standard SDE path output (linear
    interpolation is strong-order-1/2 consistent; higher-order interpolants
    would claim accuracy the Brownian path does not have)."""
    th = theta[None] if lanes else theta
    return u_old + th * (u_new - u_old)
