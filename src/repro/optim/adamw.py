"""AdamW + global-norm clip + LR schedules, pure-pytree (no optax dependency).

Master optimizer state in f32 regardless of (bf16) param dtype; update math in
f32; params cast back to their stored dtype. State shards like the params
(same tree structure), giving ZeRO-style optimizer-state sharding for free
when the caller pjits with param specs applied to the state tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = Any


class AdamWState(NamedTuple):
    step: Array
    mu: Any       # f32 pytree
    nu: Any       # f32 pytree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[Array], Array]          # step -> lr (or float)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=z,
                          nu=jax.tree.map(jnp.copy, z))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state, metrics)."""
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9)) \
            if self.clip_norm else 1.0
        step = state.step + 1
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return newp.astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step, new_m, new_v), {
            "grad_norm": gnorm, "lr": lr}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, s / max(warmup, 1))
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(
            jnp.pi * prog))
        return jnp.where(s < warmup, warm, peak_lr * cos)

    return lr
