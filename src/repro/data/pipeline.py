"""Deterministic synthetic data pipeline: seeded, shardable, checkpointable.

Every batch is a pure function of (seed, step) — the data "cursor" in a
checkpoint is just the step integer, so restart/elastic-rescale resume
exactly (fault tolerance requirement). Host-side prefetch runs a background
thread computing the next batch while the device steps (overlap requirement).

Token streams are Zipf-distributed over the true vocab (so losses are
non-degenerate); modality stubs (whisper frames / VLM patches) are unit
Gaussians, matching input_specs().
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def synth_batch(cfg: ModelConfig, seed: int, step: int, batch: int,
                seq_len: int, dtype=jnp.float32) -> Dict[str, Any]:
    """Pure (seed, step) -> batch. NumPy-side to mimic a host input pipeline."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    V = cfg.vocab_size
    # Zipf-ish: sample ranks then map through a fixed permutation
    ranks = rng.zipf(1.3, size=(batch, seq_len)).astype(np.int64)
    toks = (ranks - 1) % V
    out = {"tokens": jnp.asarray(toks, jnp.int32),
           "labels": jnp.asarray(toks, jnp.int32)}
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.enc_seq, cfg.d_model),
                                dtype=np.float32))
    if cfg.family == "vlm":
        out["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.vis_seq, cfg.vis_dim),
                                dtype=np.float32))
    return out


class DataPipeline:
    """Checkpointable iterator with background prefetch."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        s = self.step
        while not self._stop.is_set():
            b = synth_batch(self.cfg, self.seed, s, self.batch, self.seq_len)
            try:
                self._q.put((s, b), timeout=1.0)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self):
        s, b = self._q.get()
        self.step = s + 1
        return b

    def cursor(self) -> int:
        """Checkpointable position: next step to be consumed."""
        return self.step

    def close(self):
        self._stop.set()
