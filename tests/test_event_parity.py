"""Cross-backend event parity — the acceptance bar of the family-agnostic
event machinery (`repro.core.events`): the SAME termination time and final
state on every strategy (vmap / array / kernel) and backend (xla / pallas),
for every method family.  Terminal events record the located event time in
`EnsembleResult.t_final`, so t_final parity IS event-time parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EnsembleProblem, solve_ensemble_local
from repro.core.events import Event
from repro.core.problem import ODEProblem
from repro.configs.de_problems import (bouncing_ball_event,
                                       bouncing_ball_problem, gbm_problem)


def decay_ensemble(N=6, dtype=jnp.float64):
    """u' = -lam*u, u0 = 1: crossing u = 1/2 at t* = ln2/lam, per lane."""
    prob = ODEProblem(lambda u, p, t: -p[0] * u, jnp.asarray([1.0], dtype),
                      jnp.asarray([1.0], dtype), (0.0, 3.0))
    lams = jnp.linspace(0.5, 2.0, N, dtype=dtype)
    return EnsembleProblem(prob, N, ps=lams[:, None]), np.log(2.0) / np.asarray(lams)


HALF_EVENT = Event(condition=lambda u, p, t: u[0] - 0.5, terminal=True,
                   direction=-1)


# ---------------------------------------------------------------------------
# erk: terminal events on all four dispatch targets
# ---------------------------------------------------------------------------

def test_erk_terminal_event_parity_all_strategies():
    ens, exact = decay_ensemble()
    kw = dict(alg="tsit5", t0=0.0, tf=3.0, dt0=1e-3,
              saveat=jnp.asarray([3.0]), rtol=1e-9, atol=1e-9,
              event=HALF_EVENT)
    rv = solve_ensemble_local(ens, ensemble="vmap", **kw)
    np.testing.assert_allclose(np.asarray(rv.t_final), exact, atol=1e-7)
    rx = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                              lane_tile=3, **kw)
    rp = solve_ensemble_local(ens, ensemble="kernel", backend="pallas",
                              lane_tile=3, **kw)
    for name, r in (("xla", rx), ("pallas", rp)):
        np.testing.assert_allclose(np.asarray(rv.t_final),
                                   np.asarray(r.t_final), rtol=1e-9,
                                   err_msg=name)
        np.testing.assert_allclose(np.asarray(rv.u_final),
                                   np.asarray(r.u_final), rtol=1e-7,
                                   atol=1e-9, err_msg=name)


# ---------------------------------------------------------------------------
# rosenbrock: events were an ERK special before this PR
# ---------------------------------------------------------------------------

def test_rosenbrock_terminal_event_parity_and_exactness():
    ens, exact = decay_ensemble()
    kw = dict(alg="rosenbrock23", t0=0.0, tf=3.0, dt0=1e-3,
              saveat=jnp.asarray([3.0]), rtol=1e-9, atol=1e-9,
              event=HALF_EVENT)
    rv = solve_ensemble_local(ens, ensemble="vmap", **kw)
    np.testing.assert_allclose(np.asarray(rv.t_final), exact, atol=1e-6)
    ra = solve_ensemble_local(ens, ensemble="array", **kw)
    rx = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                              lane_tile=3, **kw)
    rp = solve_ensemble_local(ens, ensemble="kernel", backend="pallas",
                              lane_tile=3, **kw)
    for name, r in (("array", ra), ("xla", rx), ("pallas", rp)):
        np.testing.assert_allclose(np.asarray(rv.t_final),
                                   np.asarray(r.t_final), rtol=1e-9,
                                   atol=1e-9, err_msg=name)
        np.testing.assert_allclose(np.asarray(rv.u_final),
                                   np.asarray(r.u_final), rtol=1e-6,
                                   atol=1e-8, err_msg=name)


def test_rosenbrock_nonterminal_affect_bounces():
    """Non-terminal affect through the stiff family: bouncing ball on
    rosenbrock23 keeps the ball above the floor on every backend."""
    prob = bouncing_ball_problem(e=0.9, dtype=jnp.float64)
    ens = EnsembleProblem(prob, 4)
    kw = dict(alg="rosenbrock23", t0=0.0, tf=2.0, dt0=1e-3,
              saveat=jnp.linspace(0.5, 2.0, 4), rtol=1e-8, atol=1e-8,
              event=bouncing_ball_event())
    rv = solve_ensemble_local(ens, ensemble="vmap", **kw)
    rx = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                              lane_tile=4, **kw)
    assert float(jnp.min(rx.us[:, :, 0])) > -1e-6   # bounced, never sank
    np.testing.assert_allclose(np.asarray(rv.us), np.asarray(rx.us),
                               rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# sde: events with per-lane termination, fixed-dt AND adaptive — bitwise
# ---------------------------------------------------------------------------

SDE_EV = Event(condition=lambda u, p, t: u[0] - 0.18, terminal=True,
               direction=1)


@pytest.fixture(scope="module")
def sde_ens():
    return EnsembleProblem(gbm_problem(r=1.5, v=0.2, dtype=jnp.float64), 10)


def _all_four(ens, **kw):
    rv = solve_ensemble_local(ens, ensemble="vmap", **kw)
    ra = solve_ensemble_local(ens, ensemble="array", **kw)
    rx = solve_ensemble_local(ens, ensemble="kernel", backend="xla", **kw)
    rp = solve_ensemble_local(ens, ensemble="kernel", backend="pallas",
                              lane_tile=4, **kw)
    return rv, [("array", ra), ("xla", rx), ("pallas", rp)]


def test_sde_fixed_dt_event_parity_bitwise(sde_ens):
    rv, others = _all_four(sde_ens, alg="em", t0=0.0, tf=1.0, dt0=0.025,
                           save_every=8, seed=11, event=SDE_EV)
    # events actually fired (GBM with r=1.5 grows through the barrier)
    assert np.all(np.asarray(rv.t_final) < 1.0)
    for name, r in others:
        np.testing.assert_array_equal(np.asarray(rv.t_final),
                                      np.asarray(r.t_final), err_msg=name)
        np.testing.assert_array_equal(np.asarray(rv.u_final),
                                      np.asarray(r.u_final), err_msg=name)
        np.testing.assert_array_equal(np.asarray(rv.us), np.asarray(r.us),
                                      err_msg=name)


def test_sde_adaptive_event_parity_bitwise(sde_ens):
    """The ISSUE acceptance bar: SDE + event + adaptive=True is
    bitwise-identical (trajectories AND event times) across
    vmap/array/kernel x xla/pallas."""
    rv, others = _all_four(sde_ens, alg="em", t0=0.0, tf=1.0, dt0=0.05,
                           adaptive=True, rtol=1e-3, atol=1e-5,
                           saveat=jnp.linspace(0.25, 1.0, 4), seed=11,
                           event=SDE_EV)
    assert np.all(np.asarray(rv.t_final) < 1.0)
    for name, r in others:
        np.testing.assert_array_equal(np.asarray(rv.t_final),
                                      np.asarray(r.t_final), err_msg=name)
        np.testing.assert_array_equal(np.asarray(rv.u_final),
                                      np.asarray(r.u_final), err_msg=name)
        np.testing.assert_array_equal(np.asarray(rv.us), np.asarray(r.us),
                                      err_msg=name)


def test_sde_terminal_event_state_near_threshold(sde_ens):
    """Bisection refinement: the frozen state sits at the barrier (to the
    linear-interpolant tolerance), not at a whole-step overshoot."""
    res = solve_ensemble_local(sde_ens, alg="em", ensemble="kernel",
                               backend="xla", t0=0.0, tf=1.0, dt0=0.05,
                               adaptive=True, rtol=1e-3, atol=1e-5, seed=11,
                               event=SDE_EV)
    np.testing.assert_allclose(np.asarray(res.u_final)[:, 0], 0.18,
                               atol=1e-6)


def test_sde_nonterminal_event_resumes_at_event_time_not_grid_end():
    """Regression (ISSUE 4): a non-terminal affect used to resume at the
    step's grid end, silently dropping the dynamics over (t_event, t_end].
    The engine now re-anchors the post-event state onto the dyadic grid cell
    at the located event time.

    Probe: constant drift u' = c (EM is drift-exact for ANY dt, so the ONLY
    error source left is event-resume bookkeeping) with a sawtooth event —
    cross 0.15 upward, drop by 0.1.  Crossings land every 0.1 time units:
    9 in [0, 1], so u(1) = c·1 - 9·0.1 = 0.1 exactly.  Grid-end resume
    loses ~dt/2 of drift per event (and misses late crossings entirely),
    which fails the bound below by an order of magnitude."""
    from repro.core.problem import SDEProblem
    prob = SDEProblem(lambda u, p, t: jnp.ones_like(u) * p[0],
                      lambda u, p, t: p[1] * u,
                      jnp.asarray([0.0], jnp.float64),
                      jnp.asarray([1.0, 1e-10], jnp.float64),
                      (0.0, 1.0), noise="diagonal", name="ramp")
    ens = EnsembleProblem(prob, 4)
    saw = Event(condition=lambda u, p, t: u[0] - 0.15, direction=1,
                affect=lambda u, p, t: u - 0.1)
    kw = dict(alg="em", t0=0.0, tf=1.0, dt0=0.05, adaptive=True,
              rtol=1e-3, atol=1e-5, seed=11, event=saw)
    for error_est in ("embedded", "doubling"):
        rv = solve_ensemble_local(ens, ensemble="vmap", error_est=error_est,
                                  **kw)
        # re-anchoring quantizes the resume to one dyadic cell past the
        # event: total drift loss <= 9 events * h_res (h_res = 2^-11 here)
        np.testing.assert_allclose(np.asarray(rv.u_final)[:, 0], 0.1,
                                   atol=9 * 2.0 ** -11 + 1e-4,
                                   err_msg=error_est)
        # and the re-anchored path stays bitwise-identical across backends
        rx = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                                  error_est=error_est, **kw)
        rp = solve_ensemble_local(ens, ensemble="kernel", backend="pallas",
                                  lane_tile=4, error_est=error_est, **kw)
        for name, r in (("xla", rx), ("pallas", rp)):
            np.testing.assert_array_equal(
                np.asarray(rv.u_final), np.asarray(r.u_final),
                err_msg=f"{error_est}/{name}")


def test_event_capability_flag_enforced():
    from repro.core.methods import MethodSpec
    from repro.core.tableaus import TSIT5
    from repro.configs.de_problems import lorenz_ensemble
    spec = MethodSpec(name="noev", family="erk", order=5, tableau=TSIT5,
                      events=False)
    ens = lorenz_ensemble(2, dtype=jnp.float64)
    with pytest.raises(ValueError, match="events"):
        solve_ensemble_local(ens, alg=spec, t0=0.0, tf=0.1, dt0=1e-3,
                             event=HALF_EVENT)
