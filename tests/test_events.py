"""Event handling (paper §6.6, Fig. 8): bouncing ball vs closed-form impacts."""
import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveOptions, get_tableau, solve_adaptive
from repro.configs.de_problems import (bouncing_ball_event,
                                       bouncing_ball_problem)

TAB = get_tableau("tsit5")


def test_first_impact_time_and_velocity():
    prob = bouncing_ball_problem(e=0.8, x0=10.0)
    ev = bouncing_ball_event()
    t1 = np.sqrt(2 * 10.0 / 9.8)  # first impact
    res, evlog = solve_adaptive(prob.f, TAB, prob.u0, prob.p, 0.0, t1 + 0.3,
                                1e-3, saveat=jnp.asarray([t1 + 0.3]),
                                opts=AdaptiveOptions(rtol=1e-9, atol=1e-9),
                                event=ev)
    assert int(evlog["event_count"]) == 1
    np.testing.assert_allclose(float(evlog["event_t"]), t1, atol=1e-6)
    # post-bounce upward velocity at impact: e * g * t1
    # and x stays non-negative afterwards
    assert float(res.u_final[0]) >= -1e-6


def test_bounce_sequence_geometric():
    """Impact times follow t_{k+1} = t_k + 2 e^k t_1 (geometric flight times)."""
    e = 0.5
    prob = bouncing_ball_problem(e=e, x0=10.0)
    ev = bouncing_ball_event()
    t1 = np.sqrt(2 * 10.0 / 9.8)
    impacts = [t1]
    for k in range(1, 4):
        impacts.append(impacts[-1] + 2 * e**k * t1)
    # integrate past the 4th impact; count events
    tf = impacts[-1] + 0.05
    res, evlog = solve_adaptive(prob.f, TAB, prob.u0, prob.p, 0.0, tf, 1e-3,
                                saveat=jnp.asarray([tf]),
                                opts=AdaptiveOptions(rtol=1e-10, atol=1e-10,
                                                     max_iters=200_000),
                                event=ev)
    assert int(evlog["event_count"]) == 4
    np.testing.assert_allclose(float(evlog["event_t"]), impacts[-1], atol=1e-4)


def test_terminal_event_stops_integration():
    from repro.core.solvers import Event
    prob = bouncing_ball_problem(e=0.9, x0=10.0)
    ev = Event(condition=lambda u, p, t: u[0], affect=None, terminal=True,
               direction=-1)
    t1 = np.sqrt(2 * 10.0 / 9.8)
    res, evlog = solve_adaptive(prob.f, TAB, prob.u0, prob.p, 0.0, 15.0, 1e-3,
                                saveat=jnp.asarray([15.0]),
                                opts=AdaptiveOptions(rtol=1e-9, atol=1e-9),
                                event=ev)
    np.testing.assert_allclose(float(res.t_final), t1, atol=1e-6)
    assert int(evlog["event_count"]) == 1


def test_events_lanes_mode_per_lane_restitution():
    """Per-lane events in the fused-kernel path: different e per trajectory."""
    prob = bouncing_ball_problem()
    ev = bouncing_ball_event()
    B = 5
    es = jnp.linspace(0.3, 0.9, B, dtype=jnp.float64)
    ps = jnp.stack([jnp.full((B,), 9.8), es])          # (2, B)
    u0 = jnp.stack([jnp.full((B,), 10.0), jnp.zeros(B)])  # (2, B)
    t1 = float(np.sqrt(2 * 10.0 / 9.8))
    tf = t1 + 0.2
    res, evlog = solve_adaptive(prob.f, TAB, u0, ps, 0.0, tf, 1e-3,
                                saveat=jnp.asarray([tf]),
                                opts=AdaptiveOptions(rtol=1e-9, atol=1e-9),
                                event=ev, lanes=True)
    assert evlog["event_count"].shape == (B,)
    np.testing.assert_array_equal(np.asarray(evlog["event_count"]),
                                  np.ones(B, np.int32))
    np.testing.assert_allclose(np.asarray(evlog["event_t"]),
                               np.full(B, t1), atol=1e-6)
    # velocity right after bounce scales with e: check ordering
    v_after = np.asarray(res.u_final)[1]
    assert np.all(np.diff(v_after) != 0)
