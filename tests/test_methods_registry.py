"""Method registry + unified front-door dispatch mechanics."""
import jax.numpy as jnp
import pytest

from repro.core import (EnsembleProblem, MethodSpec, get_method, get_tableau,
                        list_methods, register_method, solve_ensemble_local)
from repro.core.methods import _REGISTRY
from repro.configs.de_problems import gbm_problem, lorenz_ensemble, sho_problem


def test_builtin_families_registered():
    fams = {s.family for s in list_methods()}
    assert fams == {"erk", "rosenbrock", "sde"}
    assert get_method("tsit5").family == "erk"
    assert get_method("rosenbrock23").stiff
    assert get_method("em").family == "sde"
    # every sde stepper supports step-doubling adaptive control + events
    assert get_method("em").adaptive and get_method("em").events


def test_aliases_resolve_to_same_spec():
    assert get_method("siea") is get_method("platen_w2")
    assert get_method("ode23s") is get_method("rosenbrock23")
    assert get_method("gputsit5") is get_method("tsit5")


def test_bare_tableau_wrapped_as_erk():
    spec = get_method(get_tableau("dopri5"))
    assert spec.family == "erk" and spec.tableau is get_tableau("dopri5")
    # rk4 has no embedded error estimate => not adaptive
    assert not get_method(get_tableau("rk4")).adaptive


def test_unknown_method_raises_with_inventory():
    with pytest.raises(KeyError, match="registered"):
        get_method("nope5")


def test_register_rejects_duplicates_and_bad_family():
    with pytest.raises(ValueError, match="already registered"):
        register_method(get_method("tsit5"))
    with pytest.raises(ValueError, match="family"):
        MethodSpec(name="x", family="dae", order=1)
    # custom registration reaches the front door, then clean up
    spec = register_method(MethodSpec(
        name="my_dopri", family="erk", order=5,
        tableau=get_tableau("dopri5")))
    try:
        ens = lorenz_ensemble(4, dtype=jnp.float64)
        res = solve_ensemble_local(ens, alg="my_dopri", ensemble="vmap",
                                   t0=0.0, tf=0.5, dt0=1e-3)
        assert int(res.status) == 0
    finally:
        del _REGISTRY["my_dopri"]


def test_sde_method_on_ode_problem_rejected():
    ens = lorenz_ensemble(4, dtype=jnp.float64)
    with pytest.raises(TypeError, match="SDE stepper"):
        solve_ensemble_local(ens, alg="em")


def test_ode_method_on_sde_problem_rejected():
    ens = EnsembleProblem(gbm_problem(dtype=jnp.float64), 4)
    with pytest.raises(TypeError, match="stochastic"):
        solve_ensemble_local(ens, alg="tsit5")


def test_noise_kind_capability_checked():
    from repro.configs.de_problems import crn_problem
    ens = EnsembleProblem(crn_problem(tspan=(0.0, 1.0), dtype=jnp.float64), 4)
    with pytest.raises(ValueError, match="noise"):
        solve_ensemble_local(ens, alg="platen_w2", dt0=0.1)  # diagonal-only


def test_unsupported_strategy_raises_not_silently_ignores():
    ens = lorenz_ensemble(4, dtype=jnp.float64)
    with pytest.raises(NotImplementedError, match="rosenbrock"):
        solve_ensemble_local(ens, alg="rosenbrock23", ensemble="array_eager",
                             t0=0.0, tf=0.5, dt0=1e-3)
    sde_ens = EnsembleProblem(gbm_problem(dtype=jnp.float64), 4)
    with pytest.raises(NotImplementedError, match="sde"):
        solve_ensemble_local(sde_ens, alg="em", ensemble="array_eager",
                             dt0=0.1)
    # adaptive SDE draws noise from the Brownian tree; tables are fixed-dt
    import jax.numpy as jnp2
    Z = jnp2.zeros((10, 3, 4))
    with pytest.raises(NotImplementedError, match="Brownian tree"):
        solve_ensemble_local(sde_ens, alg="em", dt0=0.1, adaptive=True,
                             noise_table=Z)
    # fixed-dt SDE snapshots land on the save_every grid, not saveat
    with pytest.raises(NotImplementedError, match="save_every"):
        solve_ensemble_local(sde_ens, alg="em", dt0=0.1,
                             saveat=jnp2.asarray([1.0]))


def test_auto_lane_tile_vmem_formula():
    from repro.kernels.ensemble_kernel import (DEFAULT_VMEM_BUDGET,
                                               auto_lane_tile,
                                               rosenbrock_work_words)
    # tiles are 128-multiples, shrink as per-lane state grows, stay in budget
    small = auto_lane_tile(3, 3, 10, itemsize=4)
    big_state = auto_lane_tile(64, 8, 500, itemsize=4)
    assert small % 128 == 0 and big_state % 128 == 0
    assert big_state < small
    per_lane = 4 * (2 * 500 * 64 + 12 * 64 + 8 + 16)
    assert big_state * per_lane <= DEFAULT_VMEM_BUDGET or big_state == 128
    # rosenbrock carries an n x n Jacobian per lane => smaller tiles
    rb = auto_lane_tile(64, 8, 500, itemsize=4,
                        work_words=rosenbrock_work_words(64, 8))
    assert rb <= big_state


def test_auto_tile_pallas_path_runs_without_explicit_tile():
    prob = sho_problem(dtype=jnp.float32)
    ens = EnsembleProblem(prob, 5)
    res = solve_ensemble_local(ens, alg="tsit5", ensemble="kernel",
                               backend="pallas", t0=0.0, tf=1.0, dt0=1e-2,
                               rtol=1e-5, atol=1e-5)
    assert res.u_final.shape == (5, 2)
    assert int(res.status) == 0
