"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over state dims, lane tiles, trajectory counts (incl. ragged), dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EnsembleProblem
from repro.core.ensemble import solve_ensemble_local
from repro.configs.de_problems import (gbm_problem, lorenz_ensemble,
                                       lorenz_problem, sho_problem)

# ---------------------------------------------------------------------------
# tsit5 fused-integration kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,tile", [(8, 4), (13, 4), (16, 8), (5, 8)])
def test_tsit5_kernel_vs_oracle_lorenz(N, tile):
    ep = lorenz_ensemble(N, dtype=jnp.float32)
    saveat = jnp.linspace(0.0, 1.0, 5, dtype=jnp.float32)
    kw = dict(t0=0.0, tf=1.0, dt0=1e-3, saveat=saveat, rtol=1e-5, atol=1e-5)
    rp = solve_ensemble_local(ep, ensemble="kernel", backend="pallas",
                              lane_tile=tile, **kw)
    rx = solve_ensemble_local(ep, ensemble="kernel", backend="xla",
                              lane_tile=tile, **kw)
    np.testing.assert_allclose(np.asarray(rp.us), np.asarray(rx.us),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(rp.naccept),
                                  np.asarray(rx.naccept))
    # and against the independent scalar-mode oracle
    from repro.kernels.tsit5.ref import ref_solve
    from repro.core import get_tableau
    u0s, ps = ep.materialize()
    us_ref, *_ = ref_solve(lorenz_problem(jnp.float32).f, get_tableau("tsit5"),
                           u0s, ps, 0.0, 1.0, 1e-3, saveat, 1e-5, 1e-5)
    np.testing.assert_allclose(np.asarray(rp.us), np.asarray(us_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_tsit5_kernel_dtype_sweep(dtype):
    prob = sho_problem(dtype=dtype)
    N = 6
    u0s = jnp.broadcast_to(prob.u0, (N, 2))
    om = jnp.linspace(1.0, 3.0, N, dtype=dtype)
    ps = om[:, None]
    ep = EnsembleProblem(prob, N, u0s=u0s, ps=ps)
    saveat = jnp.asarray([3.0], dtype)
    r = solve_ensemble_local(ep, ensemble="kernel", backend="pallas",
                             lane_tile=2, t0=0.0, tf=3.0, dt0=0.01,
                             saveat=saveat, rtol=1e-6, atol=1e-6)
    assert r.us.dtype == dtype
    want = np.cos(np.asarray(om) * 3.0)
    np.testing.assert_allclose(np.asarray(r.u_final)[:, 0], want,
                               atol=5e-4 if dtype == jnp.float32 else 1e-6)


def test_tsit5_kernel_fixed_step_mode():
    ep = lorenz_ensemble(8, dtype=jnp.float32)
    saveat = jnp.linspace(0.1, 1.0, 10, dtype=jnp.float32)
    rp = solve_ensemble_local(ep, ensemble="kernel", backend="pallas",
                              lane_tile=4, t0=0.0, tf=1.0, dt0=1e-2,
                              saveat=saveat, adaptive=False, max_iters=150)
    rx = solve_ensemble_local(ep, ensemble="vmap", t0=0.0, tf=1.0, dt0=1e-2,
                              saveat=saveat, adaptive=False, max_iters=150)
    np.testing.assert_allclose(np.asarray(rp.us), np.asarray(rx.us),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# EM / Platen SDE kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["em", "platen_w2", "heun_strat"])
def test_em_kernel_pathwise_vs_ref_counter_rng(method):
    """Kernel and oracle replay the SAME threefry counter stream => exact."""
    from repro.kernels.em.ops import solve_sde_ensemble_pallas
    from repro.kernels.em.ref import ref_solve
    prob = gbm_problem(r=1.5, v=0.2, dtype=jnp.float32)
    N, n_steps, dt = 12, 40, 0.025
    u0s = jnp.broadcast_to(prob.u0, (N, 3))
    ps = jnp.broadcast_to(prob.p, (N, 2))
    rp = solve_sde_ensemble_pallas(prob, u0s, ps, key=None, t0=0.0, dt=dt,
                                   n_steps=n_steps, method=method,
                                   save_every=10, lane_tile=4, seed=7)
    us_ref, uf_ref, _ = ref_solve(prob, u0s, ps, t0=0.0, dt=dt, n_steps=n_steps,
                               method=method, save_every=10, seed=7)
    np.testing.assert_allclose(np.asarray(rp.u_final), np.asarray(uf_ref.T),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(rp.us),
                               np.moveaxis(np.asarray(us_ref), -1, 0),
                               rtol=1e-6)


def test_padded_lane_width_is_128_multiple_for_oversized_tiles():
    """Regression: an explicit lane_tile > N with N % 128 != 0 used to run a
    non-LANE_WIDTH-multiple vector width (B = min(lane_tile, N) = N).  The
    padded width must round UP to a 128 multiple; explicit small tiles stay
    honoured (tests drive 3-5-lane tiles through the interpreter)."""
    from repro.kernels.ensemble_kernel import LANE_WIDTH, padded_lane_width
    assert padded_lane_width(130, 256) == 256        # the reported bug
    assert padded_lane_width(130, 256) % LANE_WIDTH == 0
    assert padded_lane_width(130, 128) == 128        # two tiles of 128
    assert padded_lane_width(3, 256) == 3            # small N: exact width
    assert padded_lane_width(8, 4) == 4              # explicit small tile
    assert padded_lane_width(300, 4096) == 384       # ceil(300/128)*128
    # functional: N=130 with lane_tile=256 runs and matches the XLA oracle
    ep = lorenz_ensemble(130, dtype=jnp.float32)
    saveat = jnp.linspace(0.0, 0.5, 3, dtype=jnp.float32)
    kw = dict(t0=0.0, tf=0.5, dt0=1e-3, saveat=saveat, rtol=1e-5, atol=1e-5)
    rp = solve_ensemble_local(ep, ensemble="kernel", backend="pallas",
                              lane_tile=256, **kw)
    rx = solve_ensemble_local(ep, ensemble="kernel", backend="xla",
                              lane_tile=256, **kw)
    assert rp.us.shape == (130, 3, 3)
    np.testing.assert_allclose(np.asarray(rp.us), np.asarray(rx.us),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(rp.naccept),
                                  np.asarray(rx.naccept))


@pytest.mark.parametrize("N,tile", [(8, 4), (11, 4)])
def test_em_kernel_noise_table_pathwise(N, tile):
    """Injected common noise: kernel == closed-form GBM-EM product, exactly."""
    from repro.kernels.em.ops import solve_sde_ensemble_pallas
    prob = gbm_problem(r=1.5, v=0.2, dtype=jnp.float64)
    n_steps, dt = 20, 0.05
    u0s = jnp.broadcast_to(prob.u0, (N, 3))
    ps = jnp.broadcast_to(prob.p, (N, 2))
    Z = jax.random.normal(jax.random.PRNGKey(0), (n_steps, 3, N), jnp.float64)
    rp = solve_sde_ensemble_pallas(prob, u0s, ps, key=None, t0=0.0, dt=dt,
                                   n_steps=n_steps, method="em",
                                   save_every=n_steps, lane_tile=tile,
                                   noise_table=Z)
    X = np.broadcast_to(np.asarray(prob.u0), (N, 3)).copy()
    for k in range(n_steps):
        X = X * (1 + 1.5 * dt + 0.2 * np.sqrt(dt) * np.asarray(Z[k]).T)
    np.testing.assert_allclose(np.asarray(rp.u_final), X, rtol=1e-12)


def test_em_kernel_moments():
    """Counter-RNG statistical sanity: discrete-EM closed-form moments."""
    from repro.kernels.em.ops import solve_sde_ensemble_pallas
    prob = gbm_problem(r=1.5, v=0.2, dtype=jnp.float32)
    N, n_steps, dt = 4096, 20, 0.05
    u0s = jnp.broadcast_to(prob.u0, (N, 3))
    ps = jnp.broadcast_to(prob.p, (N, 2))
    rp = solve_sde_ensemble_pallas(prob, u0s, ps, key=None, t0=0.0, dt=dt,
                                   n_steps=n_steps, method="em",
                                   save_every=n_steps, lane_tile=256, seed=3)
    X = np.asarray(rp.u_final)[:, 0].astype(np.float64)
    mean_exact = 0.1 * (1 + 1.5 * dt) ** n_steps
    se = X.std() / np.sqrt(N)
    assert abs(X.mean() - mean_exact) < 5 * se + 1e-7


# ---------------------------------------------------------------------------
# batched LU kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("N,tile", [(16, 8), (13, 8)])
def test_lu_kernel_vs_lapack(n, N, tile):
    from repro.kernels.lu.ops import batched_solve
    from repro.kernels.lu.ref import ref_solve
    key = jax.random.PRNGKey(n * 100 + N)
    J = jax.random.normal(key, (N, n, n), jnp.float64)
    # the paper's structure: W = -gamma I + J, diagonally dominated
    W = J - 5.0 * jnp.eye(n)[None]
    b = jax.random.normal(jax.random.fold_in(key, 1), (N, n), jnp.float64)
    x = batched_solve(W, b, lane_tile=tile)
    x_ref = ref_solve(W, b)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_ref),
                               rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.float64, 1e-10)])
def test_lu_kernel_dtype(dtype, tol):
    from repro.kernels.lu.ops import batched_solve
    from repro.kernels.lu.ref import ref_solve
    N, n = 8, 3
    key = jax.random.PRNGKey(0)
    W = (jax.random.normal(key, (N, n, n)) - 4.0 * jnp.eye(n)[None]).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(key, 1), (N, n)).astype(dtype)
    np.testing.assert_allclose(np.asarray(batched_solve(W, b, lane_tile=4)),
                               np.asarray(ref_solve(W, b)), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# Rosenbrock23 stiff solver on the batched LU (beyond-paper)
# ---------------------------------------------------------------------------


def vdp_rhs(u, p, t):
    mu = p[0]
    return jnp.stack([u[1], mu * ((1 - u[0] ** 2) * u[1]) - u[0]])


def test_rosenbrock23_stiff_vdp_scalar():
    from repro.core.rosenbrock import solve_rosenbrock23
    from repro.core import get_tableau, solve_one
    u0 = jnp.asarray([2.0, 0.0])
    p = jnp.asarray([10.0])
    res = solve_rosenbrock23(vdp_rhs, u0, p, 0.0, 3.0, 1e-3,
                             rtol=1e-6, atol=1e-6)
    assert int(res.status) == 0
    ref = solve_one(vdp_rhs, get_tableau("tsit5"), u0, p, 0.0, 3.0, 1e-3,
                    rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(np.asarray(res.u_final),
                               np.asarray(ref.u_final), atol=2e-3)


@pytest.mark.parametrize("linsolve", ["jnp", "pallas"])
def test_rosenbrock23_lanes_batched_lu(linsolve):
    from repro.core.rosenbrock import solve_rosenbrock23
    B = 4
    mus = jnp.linspace(5.0, 20.0, B, dtype=jnp.float64)
    u0 = jnp.broadcast_to(jnp.asarray([2.0, 0.0])[:, None], (2, B))
    ps = mus[None, :]
    res = solve_rosenbrock23(vdp_rhs, u0, ps, 0.0, 1.0, 1e-3,
                             rtol=1e-6, atol=1e-6, lanes=True,
                             linsolve=linsolve, lane_tile=4)
    assert int(jnp.max(res.status)) == 0
    # per-lane result equals scalar-mode solves
    for j in [0, B - 1]:
        rs = solve_rosenbrock23(vdp_rhs, u0[:, j], ps[:, j], 0.0, 1.0, 1e-3,
                                rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(res.u_final[:, j]),
                                   np.asarray(rs.u_final), rtol=1e-6,
                                   atol=1e-8)


def test_rosenbrock_beats_explicit_on_stiff_work():
    """On a genuinely stiff problem the implicit method needs far fewer steps
    than Tsit5 — the reason the paper's §5.1.3 matters."""
    from repro.core.rosenbrock import solve_rosenbrock23
    from repro.core import get_tableau, solve_one

    def stiff_rhs(u, p, t):
        return jnp.stack([-p[0] * (u[0] - jnp.cos(t))])

    u0 = jnp.asarray([0.0])
    p = jnp.asarray([1e5])
    rr = solve_rosenbrock23(stiff_rhs, u0, p, 0.0, 1.0, 1e-6, rtol=1e-4,
                            atol=1e-7)
    rt = solve_one(stiff_rhs, get_tableau("tsit5"), u0, p, 0.0, 1.0, 1e-6,
                   rtol=1e-4, atol=1e-7, max_iters=1_000_000)
    assert int(rr.naccept + rr.nreject) * 20 < int(rt.naccept + rt.nreject)


# ---------------------------------------------------------------------------
# double-buffered save staging (repro.kernels.ensemble_kernel staged driver)
# ---------------------------------------------------------------------------

def test_save_chunk_count_and_ladder():
    from repro.kernels.ensemble_kernel import (LANE_WIDTH, auto_lane_tile,
                                               lane_tile_ladder,
                                               save_chunk_count)
    # small save grids fit in one segment
    assert save_chunk_count(3, 3, 5) == 1
    # a save grid too large for VMEM even at the minimum tile must split
    big = save_chunk_count(64, 3, 4096, itemsize=8)
    assert big > 1
    # segments cover the grid: ceil semantics
    assert big * (4096 // big + 1) >= 4096
    ladder = lane_tile_ladder(3, 3, 8)
    assert auto_lane_tile(3, 3, 8) in ladder
    assert LANE_WIDTH in ladder and list(ladder) == sorted(set(ladder))
    assert lane_tile_ladder(3, 3, 8, N=64) == (64,)


def test_staged_erk_fixed_dt_is_bitwise():
    """Fixed-dt staging with dyadic dt and chunk-aligned saveat: the restart
    t equals the accumulated t exactly, so every segment reproduces the
    unstaged kernel's float sequence bit for bit."""
    from repro.kernels.tsit5.ops import solve_ensemble_pallas

    ep = lorenz_ensemble(8, dtype=jnp.float32)
    u0s, ps = ep.materialize()
    from repro.core import get_tableau
    tab = get_tableau("tsit5")
    saveat = jnp.asarray([0.25, 0.5, 0.75, 1.0], jnp.float32)
    kw = dict(t0=0.0, tf=1.0, dt0=float(2.0 ** -6), saveat=saveat,
              rtol=1e-5, atol=1e-5, adaptive=False, lane_tile=8)
    one = solve_ensemble_pallas(ep.prob, u0s, ps, tab, save_chunks=1, **kw)
    four = solve_ensemble_pallas(ep.prob, u0s, ps, tab, save_chunks=4, **kw)
    np.testing.assert_array_equal(np.asarray(one.us), np.asarray(four.us))
    np.testing.assert_array_equal(np.asarray(one.u_final),
                                  np.asarray(four.u_final))
    # counters thread across segments: accepted steps agree exactly; nf pays
    # only the per-launch FSAL/startup re-seed on each extra segment
    np.testing.assert_array_equal(np.asarray(one.naccept),
                                  np.asarray(four.naccept))
    extra_nf = int(np.asarray(four.nf)) - int(np.asarray(one.nf))
    assert 0 <= extra_nf <= 3 * (tab.stages + 2)


def test_staged_erk_adaptive_matches_to_solver_accuracy():
    """Adaptive staging restarts the controller per segment — agreement is
    to solver accuracy (the documented contract), not bitwise."""
    from repro.kernels.tsit5.ops import solve_ensemble_pallas

    ep = lorenz_ensemble(8, dtype=jnp.float32)
    u0s, ps = ep.materialize()
    from repro.core import get_tableau
    tab = get_tableau("tsit5")
    saveat = jnp.linspace(0.1, 1.0, 10, dtype=jnp.float32)
    kw = dict(t0=0.0, tf=1.0, dt0=1e-3, saveat=saveat, rtol=1e-6, atol=1e-6,
              adaptive=True, lane_tile=8)
    one = solve_ensemble_pallas(ep.prob, u0s, ps, tab, save_chunks=1, **kw)
    three = solve_ensemble_pallas(ep.prob, u0s, ps, tab, save_chunks=3, **kw)
    np.testing.assert_allclose(np.asarray(one.us), np.asarray(three.us),
                               rtol=1e-3, atol=1e-3)
    assert np.asarray(three.status).max() == 0


def test_staged_refuses_unstageable_grids():
    """Pre-t0 / unsorted / traced save grids and events fall back to the
    single launch (forced save_chunks is ignored when unstageable)."""
    from repro.kernels.tsit5.ops import solve_ensemble_pallas

    ep = lorenz_ensemble(4, dtype=jnp.float32)
    u0s, ps = ep.materialize()
    from repro.core import get_tableau
    tab = get_tableau("tsit5")
    kw = dict(t0=0.0, tf=1.0, dt0=1e-3, rtol=1e-5, atol=1e-5, adaptive=True,
              lane_tile=4, save_chunks=2)
    # grid starting AT t0: unstageable, must still solve correctly
    saveat = jnp.linspace(0.0, 1.0, 5, dtype=jnp.float32)
    res = solve_ensemble_pallas(ep.prob, u0s, ps, tab, saveat=saveat, **kw)
    ref = solve_ensemble_local(ep, ensemble="kernel", backend="pallas",
                               lane_tile=4, t0=0.0, tf=1.0, dt0=1e-3,
                               saveat=saveat, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(res.us), np.asarray(ref.us))
