"""Algebraic order conditions + empirical convergence order for every tableau."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_tableau, solve_fixed
from repro.core.tableaus import TABLEAUS
from repro.configs.de_problems import sho_problem

ADAPTIVE_TABS = ["tsit5", "dopri5", "rkck54", "bs3", "rkf45"]


@pytest.mark.parametrize("name", sorted(TABLEAUS))
def test_row_sum_consistency(name):
    tab = get_tableau(name)
    np.testing.assert_allclose(tab.a.sum(axis=1), tab.c, atol=5e-15)


@pytest.mark.parametrize("name", sorted(TABLEAUS))
def test_order_conditions(name):
    tab = get_tableau(name)
    b, c, a = tab.b, tab.c, tab.a
    # order 1..4 conditions (all shipped methods are >= order 3)
    assert abs(b.sum() - 1.0) < 1e-13
    assert abs(b @ c - 0.5) < 1e-13
    if tab.order >= 3:
        assert abs(b @ c**2 - 1 / 3) < 1e-12
        assert abs(b @ (a @ c) - 1 / 6) < 1e-12
    if tab.order >= 4:
        assert abs(b @ c**3 - 1 / 4) < 1e-12
        assert abs((b * c) @ (a @ c) - 1 / 8) < 1e-12
        assert abs(b @ (a @ c**2) - 1 / 12) < 1e-12
        assert abs(b @ (a @ (a @ c)) - 1 / 24) < 1e-12
    if tab.order >= 5:
        assert abs(b @ c**4 - 1 / 5) < 1e-12


@pytest.mark.parametrize("name", ADAPTIVE_TABS)
def test_error_weights_consistent(name):
    # btilde = b - bhat with bhat a consistent (sum=1) lower-order method
    tab = get_tableau(name)
    assert abs(tab.btilde.sum()) < 1e-12
    bhat = tab.b - tab.btilde
    assert abs(bhat.sum() - 1.0) < 1e-12
    # embedded method should satisfy order-2 condition at least
    assert abs(bhat @ tab.c - 0.5) < 1e-10


@pytest.mark.parametrize("name", ["tsit5", "dopri5"])
def test_fsal(name):
    tab = get_tableau(name)
    assert tab.fsal
    np.testing.assert_allclose(tab.a[-1, :-1], tab.b[:-1], atol=1e-15)
    assert tab.c[-1] == 1.0


@pytest.mark.parametrize("name", ADAPTIVE_TABS + ["rk4"])
def test_empirical_convergence_order(name):
    """Fixed-dt self-convergence on the harmonic oscillator: the observed
    order of the propagated solution must match the tableau's claim."""
    tab = get_tableau(name)
    prob = sho_problem(omega=2.0)
    exact = jnp.asarray([jnp.cos(2.0 * 1.0), -2.0 * jnp.sin(2.0 * 1.0)])

    def err_at(n_steps):
        res = solve_fixed(prob.f, tab, prob.u0, prob.p, 0.0, 1.0 / n_steps,
                          n_steps, save_every=n_steps)
        return float(jnp.linalg.norm(res.u_final - exact))

    e1, e2 = err_at(64), err_at(128)
    order = np.log2(e1 / e2)
    assert order > tab.order - 0.5, f"{name}: measured order {order:.2f}"


def test_tsit5_interpolant_order():
    """The free interpolant must be ~4th order accurate at the step midpoint."""
    from repro.core import rk_step, interp_step
    tab = get_tableau("tsit5")
    prob = sho_problem(omega=2.0)
    errs = []
    for dt in (0.1, 0.05):
        k1 = prob.f(prob.u0, prob.p, 0.0)
        u_new, _, ks = rk_step(prob.f, tab, prob.u0, prob.p, 0.0, dt, k1)
        u_mid = interp_step(prob.f, tab, prob.u0, u_new, ks, prob.p, 0.0, dt,
                            jnp.asarray(0.5))
        exact = jnp.asarray([jnp.cos(2 * dt / 2), -2 * jnp.sin(2 * dt / 2)])
        errs.append(float(jnp.linalg.norm(u_mid - exact)))
    order = np.log2(errs[0] / errs[1])
    assert order > 3.5, f"interpolant order {order:.2f}"
    # endpoints must be exact
    dt = 0.1
    k1 = prob.f(prob.u0, prob.p, 0.0)
    u_new, _, ks = rk_step(prob.f, tab, prob.u0, prob.p, 0.0, dt, k1)
    u0i = interp_step(prob.f, tab, prob.u0, u_new, ks, prob.p, 0.0, dt,
                      jnp.asarray(0.0))
    u1i = interp_step(prob.f, tab, prob.u0, u_new, ks, prob.p, 0.0, dt,
                      jnp.asarray(1.0))
    np.testing.assert_allclose(u0i, prob.u0, atol=1e-12)
    np.testing.assert_allclose(u1i, u_new, atol=1e-9)
