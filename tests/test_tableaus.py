"""Algebraic order conditions + empirical convergence order for every tableau."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_tableau, solve_fixed
from repro.core.tableaus import TABLEAUS
from repro.configs.de_problems import sho_problem

ADAPTIVE_TABS = ["tsit5", "dopri5", "rkck54", "bs3", "rkf45", "vern7",
                 "gbs10"]


@pytest.mark.parametrize("name", sorted(TABLEAUS))
def test_row_sum_consistency(name):
    tab = get_tableau(name)
    np.testing.assert_allclose(tab.a.sum(axis=1), tab.c, atol=5e-15)


@pytest.mark.parametrize("name", sorted(TABLEAUS))
def test_order_conditions(name):
    tab = get_tableau(name)
    b, c, a = tab.b, tab.c, tab.a
    # order 1..4 conditions (all shipped methods are >= order 3)
    assert abs(b.sum() - 1.0) < 1e-13
    assert abs(b @ c - 0.5) < 1e-13
    if tab.order >= 3:
        assert abs(b @ c**2 - 1 / 3) < 1e-12
        assert abs(b @ (a @ c) - 1 / 6) < 1e-12
    if tab.order >= 4:
        assert abs(b @ c**3 - 1 / 4) < 1e-12
        assert abs((b * c) @ (a @ c) - 1 / 8) < 1e-12
        assert abs(b @ (a @ c**2) - 1 / 12) < 1e-12
        assert abs(b @ (a @ (a @ c)) - 1 / 24) < 1e-12
    if tab.order >= 5:
        assert abs(b @ c**4 - 1 / 5) < 1e-12


@pytest.mark.parametrize("name", ADAPTIVE_TABS)
def test_error_weights_consistent(name):
    # btilde = b - bhat with bhat a consistent (sum=1) lower-order method
    tab = get_tableau(name)
    assert abs(tab.btilde.sum()) < 1e-12
    bhat = tab.b - tab.btilde
    assert abs(bhat.sum() - 1.0) < 1e-12
    # embedded method should satisfy order-2 condition at least
    assert abs(bhat @ tab.c - 0.5) < 1e-10


@pytest.mark.parametrize("name", ["tsit5", "dopri5"])
def test_fsal(name):
    tab = get_tableau(name)
    assert tab.fsal
    np.testing.assert_allclose(tab.a[-1, :-1], tab.b[:-1], atol=1e-15)
    assert tab.c[-1] == 1.0


@pytest.mark.parametrize("name", ADAPTIVE_TABS + ["rk4"])
def test_empirical_convergence_order(name):
    """Fixed-dt self-convergence on the harmonic oscillator: the observed
    order of the propagated solution must match the tableau's claim.
    High-order pairs use coarser grids so the error stays above the f64
    roundoff floor."""
    tab = get_tableau(name)
    prob = sho_problem(omega=2.0)
    exact = jnp.asarray([jnp.cos(2.0 * 1.0), -2.0 * jnp.sin(2.0 * 1.0)])

    def err_at(n_steps):
        res = solve_fixed(prob.f, tab, prob.u0, prob.p, 0.0, 1.0 / n_steps,
                          n_steps, save_every=n_steps)
        return float(jnp.linalg.norm(res.u_final - exact))

    n1, n2 = (4, 8) if tab.order >= 7 else (64, 128)
    e1, e2 = err_at(n1), err_at(n2)
    order = np.log2(e1 / e2)
    assert order > tab.order - 0.5, f"{name}: measured order {order:.2f}"


# ---------------------------------------------------------------------------
# full rooted-tree verification (repro.core.order_conditions): every shipped
# tableau satisfies ALL conditions of its claimed order, its embedded weights
# satisfy the embedded order, and the claimed order is sharp.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TABLEAUS))
def test_all_rooted_tree_conditions(name):
    from repro.core.order_conditions import max_order_condition_residual
    tab = get_tableau(name)
    assert max_order_condition_residual(tab, tab.order) < 1e-11
    if (tab.btilde != 0).any():
        assert max_order_condition_residual(
            tab, tab.embedded_order, embedded=True) < 1e-11


@pytest.mark.parametrize("name,sharp", [("tsit5", True), ("vern7", True),
                                        ("gbs10", True), ("rk4", True)])
def test_claimed_order_is_sharp(name, sharp):
    """At least one condition of order+1 must FAIL — the claim is not an
    undersell (catches e.g. a tableau accidentally of higher order)."""
    from repro.core.order_conditions import max_order_condition_residual
    tab = get_tableau(name)
    assert max_order_condition_residual(tab, tab.order + 1) > 1e-8


def test_tree_enumeration_counts():
    # A000081: rooted trees per order — the condition counts the checker runs
    from repro.core.order_conditions import count_trees, rooted_trees
    assert [len(rooted_trees(r)) for r in range(1, 10)] == \
        [1, 1, 2, 4, 9, 20, 48, 115, 286]
    assert count_trees(7) == 85


def test_vern7_reaches_every_strategy():
    """The shipped Vern7 is a first-class registry method: it dispatches
    through the front door and beats tsit5's accuracy at equal tolerance."""
    from repro.core import EnsembleProblem, solve_ensemble_local
    prob = sho_problem(omega=2.0)
    ens = EnsembleProblem(prob, 4)
    exact = np.asarray([np.cos(2.0 * 3.0), -2.0 * np.sin(2.0 * 3.0)])
    for strategy, backend in (("vmap", "xla"), ("kernel", "xla"),
                              ("kernel", "pallas")):
        res = solve_ensemble_local(ens, alg="vern7", ensemble=strategy,
                                   backend=backend, t0=0.0, tf=3.0, dt0=1e-2,
                                   rtol=1e-10, atol=1e-10, lane_tile=4)
        assert int(res.status) == 0
        np.testing.assert_allclose(np.asarray(res.u_final),
                                   np.broadcast_to(exact, (4, 2)), atol=1e-7)


def test_tsit5_interpolant_order():
    """The free interpolant must be ~4th order accurate at the step midpoint."""
    from repro.core import rk_step, interp_step
    tab = get_tableau("tsit5")
    prob = sho_problem(omega=2.0)
    errs = []
    for dt in (0.1, 0.05):
        k1 = prob.f(prob.u0, prob.p, 0.0)
        u_new, _, ks = rk_step(prob.f, tab, prob.u0, prob.p, 0.0, dt, k1)
        u_mid = interp_step(prob.f, tab, prob.u0, u_new, ks, prob.p, 0.0, dt,
                            jnp.asarray(0.5))
        exact = jnp.asarray([jnp.cos(2 * dt / 2), -2 * jnp.sin(2 * dt / 2)])
        errs.append(float(jnp.linalg.norm(u_mid - exact)))
    order = np.log2(errs[0] / errs[1])
    assert order > 3.5, f"interpolant order {order:.2f}"
    # endpoints must be exact
    dt = 0.1
    k1 = prob.f(prob.u0, prob.p, 0.0)
    u_new, _, ks = rk_step(prob.f, tab, prob.u0, prob.p, 0.0, dt, k1)
    u0i = interp_step(prob.f, tab, prob.u0, u_new, ks, prob.p, 0.0, dt,
                      jnp.asarray(0.0))
    u1i = interp_step(prob.f, tab, prob.u0, u_new, ks, prob.p, 0.0, dt,
                      jnp.asarray(1.0))
    np.testing.assert_allclose(u0i, prob.u0, atol=1e-12)
    np.testing.assert_allclose(u1i, u_new, atol=1e-9)
