"""Continuous-batching service (repro.serve): bitwise slot recycling + API.

The serving bar: a request solved in RECYCLED slots — admitted mid-stream
while other requests are in flight, at its own counter-RNG lane_offset — must
return results bitwise-identical to a fresh
`solve_ensemble_local(..., ensemble="kernel", backend="xla")` of the same
request.  Widths are multiples of 4 throughout (pool width 8, requests of 4,
fresh references at lane_tile=4): XLA codegen is width-sensitive at the ulp
level, and multiple-of-4 widths are the measured bitwise-compatible set
(docs/architecture.md).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.de_problems import gbm_problem, lorenz_ensemble
from repro.core import EnsembleProblem, solve_ensemble_local
from repro.core.events import Event
from repro.core.methods import get_method
from repro.serve import Backpressure, EnsembleService

F32 = jnp.float32


def _lorenz_requests():
    ep = lorenz_ensemble(12, dtype=F32)
    u0s, ps = (np.asarray(a) for a in ep.materialize())
    subs = [EnsembleProblem(ep.prob, 4, u0s=u0s[4 * i:4 * i + 4],
                            ps=ps[4 * i:4 * i + 4]) for i in range(3)]
    return ep.prob, subs


def _fresh_erk(sub, tf):
    return solve_ensemble_local(sub, alg="tsit5", ensemble="kernel",
                                backend="xla", t0=0.0, tf=tf, dt0=1e-2,
                                rtol=1e-6, atol=1e-6, lane_tile=4)


# ---------------------------------------------------------------------------
# the recycling bar: ODE
# ---------------------------------------------------------------------------

def test_ode_recycled_slot_bitwise():
    """A (short) retires early; C refills A's slots while B (long) is
    mid-flight.  All three must equal their fresh solves bitwise."""
    prob, (sa, sb, sc) = _lorenz_requests()
    svc = EnsembleService(slot_width=8, segment_steps=20)
    ta = svc.submit(sa, alg="tsit5", tf=0.5, dt0=1e-2)
    tb = svc.submit(sb, alg="tsit5", tf=2.0, dt0=1e-2)
    while not ta.done:
        svc.pump()
    assert not tb.done, "B must still be mid-flight when C is admitted"
    tc = svc.submit(sc, alg="tsit5", tf=1.5, dt0=1e-2)
    svc.drain()

    for tkt, sub, tf in ((ta, sa, 0.5), (tb, sb, 2.0), (tc, sc, 1.5)):
        ref = _fresh_erk(sub, tf)
        assert np.array_equal(tkt.result.u_final, np.asarray(ref.u_final))
        assert np.array_equal(tkt.result.naccept,
                              np.asarray(ref.naccept).astype(np.int64))
        assert tkt.result.nf == int(ref.nf)
        assert tkt.result.status == 0

    # the whole run shared ONE compiled segment program (no recompiles)
    pool = next(iter(svc._pools.values()))
    assert pool.engine._segment._cache_size() == 1


def test_ode_event_recycled_bitwise():
    """Terminal events through the serving path.  The recycling invariant is
    asserted bitwise: a request solved in RECYCLED slots (after another
    request retired from them) equals the same request served alone in a
    fresh service.  Against the offline kernel path, event results agree to
    analytic accuracy but not always bitwise — closure constants (p, tf)
    constant-fold into the fused event-bisection code, while the resumable
    carry keeps them as runtime arrays, and XLA may fuse the two differently
    at the ulp level (the non-event ERK and all SDE paths are bitwise)."""
    from repro.core.problem import ODEProblem

    def mk():
        return ODEProblem(lambda u, p, t: -p[0] * u, jnp.asarray([1.0], F32),
                          jnp.asarray([1.0], F32), (0.0, 3.0))

    lams = np.linspace(0.5, 2.0, 8, dtype=np.float32)
    ev = Event(condition=lambda u, p, t: u[0] - 0.5, terminal=True,
               direction=-1)
    prob = mk()
    sa = EnsembleProblem(prob, 4, ps=lams[:4, None])
    sb = EnsembleProblem(prob, 4, ps=lams[4:, None])
    svc = EnsembleService(slot_width=4, segment_steps=16)
    ta = svc.submit(sa, alg="tsit5", t0=0.0, tf=3.0, dt0=1e-3, event=ev)
    while not ta.done:
        svc.pump()
    tb = svc.submit(sb, alg="tsit5", t0=0.0, tf=3.0, dt0=1e-3, event=ev)
    svc.drain()

    # recycling is a bitwise no-op: B in A's recycled slots == B served alone
    svc2 = EnsembleService(slot_width=4, segment_steps=16)
    tb2 = svc2.submit(EnsembleProblem(mk(), 4, ps=lams[4:, None]),
                      alg="tsit5", t0=0.0, tf=3.0, dt0=1e-3, event=ev)
    svc2.drain()
    assert np.array_equal(tb.result.u_final, tb2.result.u_final)
    assert np.array_equal(tb.result.t_final, tb2.result.t_final)
    assert np.array_equal(tb.result.event_t, tb2.result.event_t)
    assert np.array_equal(tb.result.naccept, tb2.result.naccept)

    # and both requests locate the analytic event time ln2/lam
    for tkt, sl in ((ta, slice(0, 4)), (tb, slice(4, 8))):
        ref = solve_ensemble_local(
            EnsembleProblem(mk(), 4, ps=lams[sl, None]), alg="tsit5",
            ensemble="kernel", backend="xla", t0=0.0, tf=3.0, dt0=1e-3,
            event=ev, lane_tile=4)
        np.testing.assert_allclose(tkt.result.u_final,
                                   np.asarray(ref.u_final), rtol=1e-6)
        np.testing.assert_allclose(tkt.result.event_t,
                                   np.log(2.0) / lams[sl], rtol=1e-4)
        assert np.all(tkt.result.event_count == 1)


# ---------------------------------------------------------------------------
# the recycling bar: SDE (counter-RNG stream keyed by GLOBAL lane index)
# ---------------------------------------------------------------------------

def _gbm_sub(N=4):
    prob = gbm_problem(dtype=F32)
    u0 = np.full((N, 3), 1.0, np.float32)
    p = np.tile(np.asarray([1.5, 0.1], np.float32), (N, 1))
    return EnsembleProblem(prob, N, u0s=u0, ps=p)


def _fresh_sde(sub, n_steps, offset, seed, event=None):
    return solve_ensemble_local(sub, alg="em", ensemble="kernel",
                                backend="xla", t0=0.0, tf=n_steps * 1e-2,
                                dt0=1e-2, n_steps=n_steps,
                                save_every=n_steps, seed=seed,
                                lane_offset=offset, event=event)


def test_sde_recycled_slot_bitwise():
    """Recycled SDE slots keep their request's Threefry stream: results
    equal a fresh solve at the service-assigned lane_offset, bitwise."""
    svc = EnsembleService(seed=13, slot_width=8, segment_steps=16)
    sa, sb, sc = _gbm_sub(), _gbm_sub(), _gbm_sub()
    ta = svc.submit(sa, alg="em", t0=0.0, tf=0.32, dt0=1e-2, n_steps=32)
    tb = svc.submit(sb, alg="em", t0=0.0, tf=2.56, dt0=1e-2, n_steps=256)
    while not ta.done:
        svc.pump()
    assert not tb.done
    tc = svc.submit(sc, alg="em", t0=0.0, tf=1.28, dt0=1e-2, n_steps=128)
    svc.drain()
    for tkt, sub, n_steps in ((ta, sa, 32), (tb, sb, 256), (tc, sc, 128)):
        ref = _fresh_sde(sub, n_steps, tkt._req.lane_offset, 13)
        assert np.array_equal(tkt.result.u_final, np.asarray(ref.u_final))
        assert tkt.result.nf == int(ref.nf)
    assert ta._req.lane_offset != tc._req.lane_offset


def test_sde_event_recycled_bitwise():
    prob = gbm_problem(dtype=F32)
    ev = Event(condition=lambda u, p, t: u[0] - 1.3, terminal=True,
               direction=1)
    svc = EnsembleService(seed=3, slot_width=8, segment_steps=16)
    sa, sb = _gbm_sub(), _gbm_sub()
    ta = svc.submit(sa, alg="em", t0=0.0, tf=0.32, dt0=1e-2, n_steps=32,
                    event=ev)
    while not ta.done:
        svc.pump()
    tb = svc.submit(sb, alg="em", t0=0.0, tf=2.56, dt0=1e-2, n_steps=256,
                    event=ev)
    svc.drain()
    for tkt, sub, n_steps in ((ta, sa, 32), (tb, sb, 256)):
        ref = _fresh_sde(sub, n_steps, tkt._req.lane_offset, 3, event=ev)
        assert np.array_equal(tkt.result.u_final, np.asarray(ref.u_final))
        assert np.array_equal(tkt.result.t_final, np.asarray(ref.t_final))


# ---------------------------------------------------------------------------
# service behavior: coalescing, accounting, backpressure, budgets, batches
# ---------------------------------------------------------------------------

def test_heterogeneous_requests_share_one_pool_and_program():
    prob, subs = _lorenz_requests()
    svc = EnsembleService(slot_width=8, segment_steps=32)
    tkts = [svc.submit(s, alg="tsit5", tf=tf, dt0=1e-2)
            for s, tf in zip(subs, (0.4, 0.9, 1.3))]
    svc.drain()
    assert all(t.done for t in tkts)
    assert len(svc._pools) == 1          # one coalesce key
    pool = next(iter(svc._pools.values()))
    assert pool.engine._segment._cache_size() == 1


def test_per_tenant_accounting():
    prob, subs = _lorenz_requests()
    svc = EnsembleService(slot_width=8)
    ta = svc.submit(subs[0], alg="tsit5", tf=0.5, tenant="alice")
    tb = svc.submit(subs[1], alg="tsit5", tf=0.5, tenant="bob")
    tc = svc.submit(subs[2], alg="tsit5", tf=0.5, tenant="alice")
    svc.drain()
    acct = svc.accounting
    assert acct["alice"]["requests"] == 2 and acct["bob"]["requests"] == 1
    assert acct["alice"]["lanes"] == 8 and acct["bob"]["lanes"] == 4
    assert acct["alice"]["nf"] == ta.result.nf + tc.result.nf
    assert acct["bob"]["nf"] == tb.result.nf


def test_backpressure_and_release():
    prob, subs = _lorenz_requests()
    svc = EnsembleService(slot_width=8, max_pending=2)
    svc.submit(subs[0], alg="tsit5", tf=0.3)
    svc.submit(subs[1], alg="tsit5", tf=0.3)
    with pytest.raises(Backpressure):
        svc.submit(subs[2], alg="tsit5", tf=0.3)
    svc.drain()
    t3 = svc.submit(subs[2], alg="tsit5", tf=0.3)   # capacity freed
    svc.drain()
    assert t3.done and t3.result.status == 0


def test_attempt_budget_evicts_lane():
    """A lane that exhausts its per-request attempt budget is force-retired
    with status 1 and its slot is reusable (the front door's max_iters
    contract, enforced host-side at harvest)."""
    prob, subs = _lorenz_requests()
    svc = EnsembleService(slot_width=8, segment_steps=16)
    t1 = svc.submit(subs[0], alg="tsit5", tf=50.0, dt0=1e-2, max_iters=40)
    svc.drain()
    assert t1.done and t1.result.status == 1
    t2 = svc.submit(subs[1], alg="tsit5", tf=0.5, dt0=1e-2)
    svc.drain()
    ref = _fresh_erk(subs[1], 0.5)
    assert np.array_equal(t2.result.u_final, np.asarray(ref.u_final))


def test_batch_pool_coalesces_rosenbrock(monkeypatch):
    from repro.configs.de_problems import rober_problem
    from repro.serve import slots as slots_mod
    rp = rober_problem(dtype=jnp.float64)
    u0 = np.tile(np.asarray([1.0, 0.0, 0.0]), (4, 1))
    p = np.tile(np.asarray([0.04, 3e7, 1e4]), (4, 1))
    svc = EnsembleService()
    kw = dict(alg="rosenbrock23", t0=0.0, tf=1.0, dt0=1e-6, rtol=1e-5,
              atol=1e-8)
    solves = []
    orig_solve = slots_mod.solve_ensemble_local
    monkeypatch.setattr(
        slots_mod, "solve_ensemble_local",
        lambda ep, **k: (solves.append(ep.n_trajectories),
                         orig_solve(ep, **k))[1])
    ta = svc.submit(EnsembleProblem(rp, 4, u0s=u0, ps=p), tenant="a", **kw)
    tb = svc.submit(EnsembleProblem(rp, 4, u0s=u0, ps=p), tenant="b", **kw)
    svc.drain()
    assert solves == [8]                 # same full signature -> one batch
    assert ta.done and tb.done
    # one-shot batch pools are dropped after their solve (no per-key leak)
    assert not any(k[0] == "batch" for k in svc._pools)
    ep = EnsembleProblem(rp, 8, u0s=np.tile(u0, (2, 1)),
                         ps=np.tile(p, (2, 1)))
    ref = solve_ensemble_local(ep, ensemble="kernel", backend="xla", **kw)
    got = np.concatenate([ta.result.u_final, tb.result.u_final])
    np.testing.assert_allclose(got, np.asarray(ref.u_final), rtol=1e-6)
    assert svc.accounting["a"]["njac"] > 0
    # total work is attributed, not duplicated (±1 from share rounding)
    total = svc.accounting["a"]["njac"] + svc.accounting["b"]["njac"]
    assert abs(total - int(ref.njac)) <= 1


def test_inflight_request_survives_lease_timeout():
    """A request whose solve outlasts queue_timeout must NOT be re-admitted
    by later pumps: exactly one completion, accounting counts it once, and
    _pending returns to 0 (regression: duplicated lanes + KeyError in
    _finish + negative _pending)."""
    prob, subs = _lorenz_requests()
    # queue_timeout far below the first pump's compile time: every claim
    # round sees the in-flight lease as expired
    svc = EnsembleService(slot_width=8, segment_steps=8, queue_timeout=1e-9)
    t1 = svc.submit(subs[0], alg="tsit5", tf=1.0, dt0=1e-2)
    svc.drain()
    assert t1.done and t1.result.status == 0
    assert svc.accounting["default"]["requests"] == 1
    assert svc.accounting["default"]["lanes"] == 4
    assert svc._pending == 0 and not svc._inflight
    ref = _fresh_erk(subs[0], 1.0)
    assert np.array_equal(t1.result.u_final, np.asarray(ref.u_final))
    assert t1.result.nf == int(ref.nf)


def test_rejected_submit_does_not_consume_capacity():
    """Validation failures must not leak pending slots (regression: repeated
    bad submits wedged the service into permanent Backpressure)."""
    prob, subs = _lorenz_requests()
    svc = EnsembleService(slot_width=8, max_pending=2)
    for _ in range(4):
        with pytest.raises(KeyError):
            svc.submit(subs[0], alg="no-such-method")
    assert svc._pending == 0
    ta = svc.submit(subs[0], alg="tsit5", tf=0.3)
    tb = svc.submit(subs[1], alg="tsit5", tf=0.3)
    svc.drain()
    assert ta.done and tb.done


def test_batch_pool_status_is_per_lane(monkeypatch):
    """One tenant's failing lane must not mark coalesced tenants failed."""
    from types import SimpleNamespace
    from repro.serve import slots as slots_mod
    from repro.serve.service import SolveRequest

    def fake_solve(ep, **kw):
        n = ep.n_trajectories
        return SimpleNamespace(
            u_final=np.zeros((n, 3)), t_final=np.ones(n),
            naccept=np.full(n, 10), nreject=np.zeros(n),
            nf=np.asarray(60), njac=np.asarray(20), nfact=np.asarray(20),
            status=np.asarray([0, 0, 2, 2]))   # only tenant b's lanes fail
    monkeypatch.setattr(slots_mod, "solve_ensemble_local", fake_solve)

    done = []
    pool = slots_mod.BatchPool(
        get_method("rosenbrock23"), object(), solve_kwargs={},
        on_complete=done.append)

    def req(tenant):
        return SolveRequest(
            prob=None, alg="rosenbrock23", u0s=np.zeros((2, 3)),
            ps=np.zeros((2, 1)), t0=0.0, tf=1.0, dt0=1e-3, n_steps=None,
            adaptive=True, rtol=1e-6, atol=1e-6, max_iters=100,
            event=None, tenant=tenant, lane_offset=0, n_lanes=2)
    ra, rb = req("a"), req("b")
    pool.admit(ra)
    pool.admit(rb)
    assert pool.pump()
    assert [r.tenant for r in done] == ["a", "b"]
    assert ra.assemble().status == 0       # a is NOT poisoned by b's lanes
    assert rb.assemble().status == 2


def test_filler_staged_when_scrubbed_slots_exceed_refills():
    """Budget-evicted carry columns must be force-retired even when fewer
    staged lanes than scrubbed slots arrive (regression: the leftover column
    ran full segments forever)."""
    import jax as _jax
    ep = lorenz_ensemble(8, dtype=F32)
    u0s, ps = (np.asarray(a) for a in ep.materialize())
    big = EnsembleProblem(ep.prob, 8, u0s=u0s, ps=ps)
    small = EnsembleProblem(ep.prob, 4, u0s=u0s[:4], ps=ps[:4])
    svc = EnsembleService(slot_width=8, segment_steps=16)
    t1 = svc.submit(big, alg="tsit5", tf=50.0, dt0=1e-2, max_iters=40)
    svc.drain()
    assert t1.done and t1.result.status == 1   # all 8 lanes evicted
    t2 = svc.submit(small, alg="tsit5", tf=0.5, dt0=1e-2)
    svc.drain()
    assert t2.done and t2.result.status == 0
    pool = next(iter(svc._pools.values()))
    h = _jax.device_get(pool.carry)
    # 4 slots were refilled by t2, the other 4 got fillers: every carry
    # column is retired, none keeps consuming segment work
    assert bool(np.all(h["done"]))
    assert not pool._scrub


def test_background_thread_serving():
    prob, subs = _lorenz_requests()
    svc = EnsembleService(slot_width=8, segment_steps=32)
    svc.start()
    try:
        tkts = [svc.submit(s, alg="tsit5", tf=0.5) for s in subs]
        for t in tkts:
            assert t.wait(timeout=120.0)
    finally:
        svc.stop()
    ref = _fresh_erk(subs[0], 0.5)
    assert np.array_equal(tkts[0].result.u_final, np.asarray(ref.u_final))
    assert all(t.latency is not None and t.latency >= 0 for t in tkts)


def test_resumable_capability_flags():
    assert get_method("tsit5").resumable
    assert get_method("em").resumable
    assert not get_method("rosenbrock23").resumable


# ---------------------------------------------------------------------------
# failure accounting: degraded-but-serving vs healthy
# ---------------------------------------------------------------------------

def test_pump_failure_counter_and_last_error_per_tenant():
    """A request whose RHS raises at trace time must not take the service
    down: the failure is charged to ITS tenant (`failures` counter +
    `last_error` in accounting), retried up to max_request_retries, then
    failed permanently (ticket.error set, result None, capacity released) —
    while another tenant's healthy request completes normally."""
    from repro.core.problem import ODEProblem

    def bad_rhs(u, p, t):
        raise RuntimeError("boom rhs")

    bad_prob = ODEProblem(bad_rhs, jnp.asarray([1.0], F32),
                          jnp.asarray([1.0], F32), (0.0, 1.0))
    bad = EnsembleProblem(bad_prob, 4, ps=np.ones((4, 1), np.float32))
    prob, (sa, *_rest) = _lorenz_requests()

    svc = EnsembleService(slot_width=4, segment_steps=16,
                          max_request_retries=2)
    tb = svc.submit(bad, alg="tsit5", tf=1.0, tenant="chaos")
    th = svc.submit(sa, alg="tsit5", tf=0.5, tenant="steady")
    svc.drain()

    # failing tenant: retried max_request_retries times, then failed for good
    assert tb.done and tb.result is None
    assert "boom rhs" in tb.error
    chaos = svc.accounting["chaos"]
    assert chaos["failures"] == 3            # initial attempt + 2 retries
    assert "boom rhs" in chaos["last_error"]
    assert chaos["requests"] == 0            # never completed

    # healthy tenant: served, and visibly healthy in accounting
    assert th.done and th.result is not None and th.result.status == 0
    ref = _fresh_erk(sa, 0.5)
    assert np.array_equal(th.result.u_final, np.asarray(ref.u_final))
    steady = svc.accounting["steady"]
    assert steady["failures"] == 0 and steady["last_error"] is None

    # capacity was released: the service is drained, not wedged
    assert svc._pending == 0 and svc._wq.finished
