"""Autotuned dispatch (`ensemble="auto"`, repro.core.autotune): key schema,
profile-cache round-trips, capability pruning, bitwise parity with explicit
dispatch, and the graceful static fallback when timing is unavailable.

The CI bench-smoke job runs exactly this module as its autotune leg: every
test tunes into a pytest tmpdir cache (never ~/.cache), and the round-trip
test asserts the second resolve is a PURE cache hit — zero timing calls.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.de_problems import lorenz_ensemble
from repro.core import EnsembleProblem, get_method, solve_ensemble_local
from repro.core import autotune as at
from repro.core.api import solve_ensemble
from repro.core.methods import valid_dispatch

SOLVE_KW = dict(t0=0.0, tf=0.5, dt0=1e-2, adaptive=True, rtol=1e-5,
                atol=1e-5)


@pytest.fixture
def cache(tmp_path):
    at.clear_memory_cache()
    yield str(tmp_path / "autotune.json")
    at.clear_memory_cache()


@pytest.fixture
def counted_measure(monkeypatch):
    calls = {"n": 0}
    real = at.measure

    def counting(fn, *a, **k):
        calls["n"] += 1
        return real(fn, *a, **k)

    monkeypatch.setattr(at, "measure", counting)
    return calls


# ---------------------------------------------------------------------------
# key schema
# ---------------------------------------------------------------------------

def test_config_key_deterministic_and_bucketed():
    spec = get_method("tsit5")
    kw = dict(n=3, dtype=jnp.float32, adaptive=True, events=False,
              w_reuse=False, error_est="none", device="cpu:x")
    k1 = at.config_key(spec, N=1000, **kw)
    assert k1 == at.config_key(spec, N=1000, **kw)   # deterministic
    assert k1 == at.config_key(spec, N=600, **kw)    # same power-of-2 bucket
    assert k1 != at.config_key(spec, N=5000, **kw)   # different bucket
    kw64 = dict(kw, dtype=jnp.float64)
    assert k1 != at.config_key(spec, N=1000, **kw64)  # dtype splits the key
    assert "method=tsit5" in k1 and "device=cpu:x" in k1


def test_resolved_flags_normalize_family_defaults():
    erk, rb, sde = (get_method(a) for a in ("tsit5", "rodas4", "em"))
    prob = lorenz_ensemble(4).prob
    # erk: None means adaptive; rk4 (no pair) cannot be adaptive
    assert at.resolved_flags(erk, prob, adaptive=None, w_reuse=None,
                             error_est=None, event=None)[0] is True
    rk4 = get_method("rk4")
    assert at.resolved_flags(rk4, prob, adaptive=None, w_reuse=None,
                             error_est=None, event=None)[0] is False
    # rosenbrock: always adaptive; sde: fixed-dt by default
    assert at.resolved_flags(rb, prob, adaptive=None, w_reuse=None,
                             error_est=None, event=None)[0] is True
    assert at.resolved_flags(sde, prob, adaptive=None, w_reuse=None,
                             error_est=None, event=None)[0] is False


# ---------------------------------------------------------------------------
# cache round-trip
# ---------------------------------------------------------------------------

def test_tune_then_pure_cache_hits(cache, counted_measure):
    ep = lorenz_ensemble(32)
    spec = get_method("tsit5")
    dec = at.resolve_auto(ep, spec, cache_path=cache, **SOLVE_KW)
    assert dec.source == "tuned"
    assert counted_measure["n"] > 1          # several candidates were timed
    n_timed = counted_measure["n"]

    # in-memory hit: no re-timing
    dec2 = at.resolve_auto(ep, spec, cache_path=cache, **SOLVE_KW)
    assert dec2.source == "cache"
    assert counted_measure["n"] == n_timed

    # cold-process reload from the JSON file: still no re-timing
    at.clear_memory_cache()
    dec3 = at.resolve_auto(ep, spec, cache_path=cache, **SOLVE_KW)
    assert dec3.source == "cache"
    assert counted_measure["n"] == n_timed
    assert (dec3.strategy, dec3.backend, dec3.lane_tile) == (
        dec.strategy, dec.backend, dec.lane_tile)

    with open(cache) as fh:
        data = json.load(fh)
    assert data["version"] == at.CACHE_VERSION
    entry = data["entries"][dec.key]
    assert entry["jax"] == jax.__version__
    assert entry["timings"]                  # medians persisted per candidate


def test_stale_jax_version_invalidates(cache, monkeypatch):
    ep = lorenz_ensemble(32)
    spec = get_method("tsit5")
    dec = at.resolve_auto(ep, spec, cache_path=cache, **SOLVE_KW)
    with open(cache) as fh:
        data = json.load(fh)
    data["entries"][dec.key]["jax"] = "0.0.stale"
    with open(cache, "w") as fh:
        json.dump(data, fh)
    at.clear_memory_cache()
    monkeypatch.setenv(at.DISABLE_ENV, "0")   # timing off: a stale entry must
    dec2 = at.resolve_auto(ep, spec, cache_path=cache, **SOLVE_KW)
    assert dec2.source == "default"           # NOT be served as a cache hit


# ---------------------------------------------------------------------------
# auto == explicit dispatch, bitwise
# ---------------------------------------------------------------------------

def test_auto_bitwise_equals_explicit_winner(cache, monkeypatch):
    monkeypatch.setenv(at.CACHE_ENV, cache)
    ep = lorenz_ensemble(48)
    saveat = jnp.asarray([0.25, 0.5])
    kw = dict(t0=0.0, tf=0.5, dt0=1e-2, saveat=saveat, rtol=1e-5, atol=1e-5)
    r_auto = solve_ensemble_local(ep, alg="tsit5", ensemble="auto", **kw)
    dec = at.resolve_auto(ep, get_method("tsit5"), cache_path=cache,
                          **dict(kw, saveat=saveat))
    assert dec.source == "cache"              # the solve above tuned it
    r_exp = solve_ensemble_local(ep, alg="tsit5", ensemble=dec.strategy,
                                 backend=dec.backend,
                                 lane_tile=dec.lane_tile, **kw)
    assert np.array_equal(np.asarray(r_auto.us), np.asarray(r_exp.us))
    assert np.array_equal(np.asarray(r_auto.u_final),
                          np.asarray(r_exp.u_final))
    assert np.array_equal(np.asarray(r_auto.t_final),
                          np.asarray(r_exp.t_final))


def test_warm_cache_auto_dispatches_inside_jit(cache, monkeypatch,
                                               counted_measure):
    monkeypatch.setenv(at.CACHE_ENV, cache)
    ep = lorenz_ensemble(32)
    prob = ep.prob
    u0s, ps = ep.materialize()
    kw = dict(t0=0.0, tf=0.5, dt0=1e-2, rtol=1e-5, atol=1e-5)
    # tune once, eagerly
    solve_ensemble_local(ep, alg="tsit5", ensemble="auto", **kw)
    n_timed = counted_measure["n"]
    assert n_timed > 0

    def run(u0s_, ps_):
        sub = EnsembleProblem(prob, u0s_.shape[0], u0s=u0s_, ps=ps_)
        return solve_ensemble_local(sub, alg="tsit5", ensemble="auto",
                                    **kw).u_final

    out = jax.jit(run)(u0s, ps)               # key is static: cache hit works
    assert counted_measure["n"] == n_timed    # ... with zero timing under jit
    dec = at.resolve_auto(ep, get_method("tsit5"), cache_path=cache, **kw)
    ref = solve_ensemble_local(ep, alg="tsit5", ensemble=dec.strategy,
                               backend=dec.backend, lane_tile=dec.lane_tile,
                               **kw).u_final
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_mesh_solve_ensemble_accepts_auto(cache, monkeypatch):
    monkeypatch.setenv(at.CACHE_ENV, cache)
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    ep = lorenz_ensemble(32)
    kw = dict(t0=0.0, tf=0.5, dt0=1e-2, rtol=1e-5, atol=1e-5)
    r = solve_ensemble(ep, mesh=mesh, ensemble="auto", **kw)
    dec = at.resolve_auto(ep, get_method("tsit5"), cache_path=cache, **kw)
    assert dec.source == "cache"              # tuned once, before shard_map
    ref = solve_ensemble_local(ep, ensemble=dec.strategy,
                               backend=dec.backend,
                               lane_tile=dec.lane_tile, **kw)
    np.testing.assert_allclose(np.asarray(r.u_final),
                               np.asarray(ref.u_final), rtol=1e-12)


# ---------------------------------------------------------------------------
# capability pruning
# ---------------------------------------------------------------------------

def test_candidates_are_all_dispatchable():
    cases = [
        (get_method("tsit5"), dict(adaptive=True, events=False,
                                   w_reuse=False, error_est="none")),
        (get_method("rodas4"), dict(adaptive=True, events=False,
                                    w_reuse=True, error_est="none")),
        (get_method("em"), dict(adaptive=False, events=False,
                                w_reuse=False, error_est="none")),
        (get_method("em"), dict(adaptive=True, events=True,
                                w_reuse=False, error_est="embedded")),
    ]
    for spec, flags in cases:
        cands = at.candidates(spec, n=3, m=3, n_save=4, N=64,
                              dtype=jnp.float32, **flags)
        assert cands, f"no candidates for {spec.name} {flags}"
        for c in cands:
            assert c.strategy != "array_eager"   # never a tuning candidate
            ok, why = valid_dispatch(
                spec, c.strategy, c.backend, adaptive=flags["adaptive"],
                events=flags["events"], w_reuse=flags["w_reuse"],
                error_est=None if flags["error_est"] == "none"
                else flags["error_est"])
            assert ok, f"{spec.name}: {c.label} invalid: {why}"
            if c.backend == "pallas":
                assert c.strategy == "kernel"


def test_pruning_rejects_impossible_combos():
    # non-rosenbrock w_reuse: nothing to tune
    assert at.candidates(get_method("tsit5"), n=3, m=3, n_save=1, N=64,
                         dtype=jnp.float32, adaptive=True, events=False,
                         w_reuse=True, error_est="none") == []
    # estimator the method does not ship
    assert at.candidates(get_method("heun_strat"), n=2, m=2, n_save=1, N=64,
                         dtype=jnp.float32, adaptive=True, events=False,
                         w_reuse=False, error_est="embedded") == []
    ok, _ = valid_dispatch(get_method("tsit5"), "array", "pallas")
    assert not ok                              # pallas is kernel-only
    ok, _ = valid_dispatch(get_method("rodas4"), "array_eager")
    assert not ok                              # array_eager is erk-only


def test_lane_tile_ladder_brackets_formula():
    from repro.kernels.ensemble_kernel import (LANE_WIDTH, auto_lane_tile,
                                               lane_tile_ladder)
    ladder = lane_tile_ladder(3, 3, 8)
    auto = auto_lane_tile(3, 3, 8)
    assert auto in ladder and LANE_WIDTH in ladder
    assert list(ladder) == sorted(set(ladder))   # deduped, ascending
    # clamped to the padded ensemble width: a small N collapses the ladder
    assert lane_tile_ladder(3, 3, 8, N=64) == (64,)


# ---------------------------------------------------------------------------
# graceful fallback
# ---------------------------------------------------------------------------

def test_disabled_env_falls_back_to_static_default(cache, monkeypatch,
                                                   counted_measure):
    monkeypatch.setenv(at.DISABLE_ENV, "0")
    ep = lorenz_ensemble(32)
    dec = at.resolve_auto(ep, get_method("tsit5"), cache_path=cache,
                          **SOLVE_KW)
    assert (dec.strategy, dec.backend, dec.lane_tile) == at.DEFAULT_STRATEGY
    assert dec.source == "default"
    assert counted_measure["n"] == 0           # nothing was timed
    # the front door still works end to end with timing disabled
    r = solve_ensemble_local(ep, alg="tsit5", ensemble="auto", **SOLVE_KW)
    assert int(r.status) == 0


def test_cold_cache_under_jit_falls_back(cache, monkeypatch,
                                         counted_measure):
    monkeypatch.setenv(at.CACHE_ENV, cache)
    ep = lorenz_ensemble(32)
    prob = ep.prob
    u0s, ps = ep.materialize()

    def run(u0s_, ps_):
        sub = EnsembleProblem(prob, u0s_.shape[0], u0s=u0s_, ps=ps_)
        return solve_ensemble_local(sub, alg="tsit5", ensemble="auto",
                                    t0=0.0, tf=0.5, dt0=1e-2).u_final

    out = jax.jit(run)(u0s, ps)                # cold cache + tracers: no
    assert counted_measure["n"] == 0           # timing, static default
    ref = solve_ensemble_local(ep, alg="tsit5", ensemble=at.DEFAULT_STRATEGY[0],
                               backend=at.DEFAULT_STRATEGY[1],
                               t0=0.0, tf=0.5, dt0=1e-2).u_final
    assert np.array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# concurrent writers (the serve/mesh multi-process tuning scenario)
# ---------------------------------------------------------------------------

_WRITER_SCRIPT = r"""
import os, sys, time
from repro.core import autotune as at

path, key, order = sys.argv[1], sys.argv[2], sys.argv[3]
sdir = os.path.dirname(path)

def wait_for(*names, timeout=60.0):
    t0 = time.monotonic()
    while not all(os.path.exists(os.path.join(sdir, n)) for n in names):
        if time.monotonic() - t0 > timeout:
            sys.exit(3)
        time.sleep(0.01)

# classic lost-update shape: BOTH processes read the (empty) file, then each
# adds its own key and replaces.  The barrier files make the interleaving
# deterministic: loads strictly before either save, saves strictly ordered.
entries = dict(at._load_entries(path))
entries[key] = {"strategy": "kernel", "backend": "xla", "lane_tile": None,
                "jax": "test", "tuned_at_N": 1, "timings": {}}
open(os.path.join(sdir, "ready_" + key), "w").close()
wait_for("ready_cfgA", "ready_cfgB")
if order == "second":
    wait_for("saved_first")
at._save_entries(path, entries)
if order == "first":
    open(os.path.join(sdir, "saved_first"), "w").close()
"""


def test_concurrent_writers_merge_not_last_wins(tmp_path):
    """Two processes tune different configs; the later writer must MERGE,
    not clobber — both entries survive in the JSON."""
    import subprocess
    import sys

    path = str(tmp_path / "autotune.json")
    src = os.path.join(os.path.dirname(at.__file__), "..", "..")
    env = {**os.environ,
           "PYTHONPATH": os.path.abspath(src)
           + os.pathsep + os.environ.get("PYTHONPATH", "")}
    procs = [subprocess.Popen(
        [sys.executable, "-c", _WRITER_SCRIPT, path, key, order], env=env)
        for key, order in (("cfgA", "first"), ("cfgB", "second"))]
    for p in procs:
        assert p.wait(timeout=300) == 0
    with open(path) as fh:
        data = json.load(fh)
    assert set(data["entries"]) == {"cfgA", "cfgB"}, (
        "last writer dropped the concurrent entry")
    # a fresh in-process load (cold memory layer) sees the union too
    at.clear_memory_cache()
    assert set(at._load_entries(path)) == {"cfgA", "cfgB"}
