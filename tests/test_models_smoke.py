"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train-grad step + one prefill->decode chain on CPU; shape + finiteness
asserts. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS, get_arch
from repro.models.model import build_model

ARCH_IDS = sorted(ARCHS)


def make_batch(cfg, key, B=2, T=32):
    kt, kf = jax.random.split(key)
    toks = jax.random.randint(kt, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(kf, (B, cfg.enc_seq, cfg.d_model),
                                            jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(kf, (B, cfg.vis_seq, cfg.vis_dim),
                                             jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_arch(arch + "-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = make_batch(cfg, key)

    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), \
        f"{arch}: non-finite grads"
    assert float(loss) > 0
    # loss should be near ln(V) at random init (uniform prediction)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab_size)) < 2.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    """prefill(T tokens) then decode 1 more == forward(T+1) last logits."""
    cfg = get_arch(arch + "-smoke")
    kw = {"moe_cf": None} if cfg.family == "moe" else {}  # no-drop oracle
    model = build_model(cfg, dtype=jnp.float32, **kw)
    key = jax.random.PRNGKey(1)
    params = model.init_params(key)
    B, T = 2, 16
    batch = make_batch(cfg, key, B=B, T=T + 1)
    toks = batch["tokens"]

    pre_batch = dict(batch, tokens=toks[:, :T], labels=toks[:, :T])
    extra = cfg.vis_seq if cfg.family == "vlm" else 0  # image tokens in cache
    logits_pre, cache = model.prefill(params, pre_batch,
                                      cache_len=T + extra + 4)
    logits_dec, cache = model.decode_step(params, cache, toks[:, T:T + 1])
    assert logits_dec.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits_dec[..., :cfg.vocab_size])))

    # oracle: full forward over T+1 tokens (teacher forcing)
    if cfg.family == "vlm":
        h0 = model._embed_multimodal(params, toks, batch["patches"])
        x, _ = model.lm.forward(params, None, h0=h0)
    elif cfg.family == "encdec":
        x = model.forward(params, toks, batch["frames"])
    elif cfg.family == "moe" or cfg.family == "dense":
        x, _ = model.forward(params, toks)
    else:
        x = model.forward(params, toks)
    from repro.models.lm import _logits
    want = _logits(x[:, -1:], params, cfg)
    got = logits_dec
    if cfg.family == "vlm":
        # decode path has image tokens in cache; forward oracle covers them
        pass
    V = cfg.vocab_size
    np.testing.assert_allclose(
        np.asarray(got[..., :V], np.float32),
        np.asarray(want[..., :V], np.float32), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "mamba2-2.7b",
                                  "recurrentgemma-9b", "gemma3-1b"])
def test_smoke_multi_token_decode(arch):
    """Greedy decode 4 tokens step-by-step stays finite and deterministic."""
    cfg = get_arch(arch + "-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(2))
    B, T = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    logits, cache = model.prefill(params, batch, cache_len=T + 8)
    cur = jnp.argmax(logits[..., :cfg.vocab_size], axis=-1)
    outs = []
    for _ in range(4):
        logits, cache = model.decode_step(params, cache, cur)
        cur = jnp.argmax(logits[..., :cfg.vocab_size], axis=-1)
        outs.append(cur)
    seq = jnp.concatenate(outs, axis=1)
    assert seq.shape == (B, 4)
    assert bool(jnp.all(seq >= 0)) and bool(jnp.all(seq < cfg.vocab_size))


def test_full_configs_param_counts():
    """Sanity: analytic parameter counts are in the advertised ballpark."""
    import math
    expect = {
        "grok-1-314b": (250e9, 380e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "command-r-35b": (30e9, 40e9),
        "qwen2.5-32b": (28e9, 36e9),
        "internlm2-1.8b": (1.5e9, 2.2e9),
        "gemma3-1b": (0.7e9, 1.3e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "recurrentgemma-9b": (7e9, 11e9),
        "internvl2-26b": (18e9, 27e9),  # LM backbone only (ViT stubbed)
        "whisper-tiny": (2e7, 8e7),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].n_params()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
