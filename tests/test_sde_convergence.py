"""Empirical strong-order convergence for EVERY registered SDE stepper, and
the embedded-vs-doubling estimator comparison (ISSUE 4 satellite).

All runs are driven by the SAME Brownian paths read from the virtual Brownian
tree (`kernels/rng.brownian_bridge_point`), so the reference solution is the
closed-form GBM endpoint on the identical path — a pathwise (strong) test,
not a statistical one.  Coarse-grid increments are tree increments over
coarser dyadic spacings, i.e. exactly the increments the adaptive engine
would use at those step sizes.

Expected strong orders on diagonal-noise GBM:
  em         0.5   (Ito)
  milstein   1.0   (Ito; exact diagonal Milstein correction)
  platen_w2  1.0   (generic strong order is 0.5, but for LINEAR diagonal
                    noise its (dW²-dt)(b(u+)-b(u-))/(4√dt) term reproduces
                    the Milstein correction exactly)
  heun_strat 1.0, against the STRATONOVICH solution (no -v^2/2 drift shift;
                    commutative linear noise upgrades Heun the same way)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.methods import list_methods
from repro.core.sde import (SDE_EMBEDDED, SDE_STEPPERS, em_step,
                            sde_solve_fixed)
from repro.core.problem import SDEProblem
from repro.kernels.rng import brownian_bridge_point

R, V, T = 1.2, 0.5, 1.0
DEPTH = 11                     # fine grid: 2**11 cells
NPATH = 4000
SEED = 29


def _tree_W(idx):
    """W at dyadic grid index/indices for NPATH lanes, one noise row."""
    idx = jnp.asarray(idx, jnp.uint32)
    lanes = jnp.broadcast_to(jnp.arange(NPATH, dtype=jnp.uint32)[None, :],
                             idx.shape[:1] + (NPATH,))
    rows = jnp.zeros_like(lanes)
    return brownian_bridge_point(SEED, idx[:, None], lanes, rows, depth=DEPTH,
                                 t_total=T, dtype=jnp.float64)


@pytest.fixture(scope="module")
def wt():
    """W_T on every path (the exact-solution driver)."""
    return np.asarray(_tree_W(jnp.asarray([2 ** DEPTH]))[0])


def _gbm_prob():
    return SDEProblem(lambda u, p, t: p[0] * u, lambda u, p, t: p[1] * u,
                      jnp.asarray([1.0], jnp.float64),
                      jnp.asarray([R, V], jnp.float64), (0.0, T),
                      noise="diagonal", name="gbm_conv")


def _strong_err(method, n_steps, wt):
    """RMS endpoint error vs the closed form on the SAME tree paths."""
    stride = 2 ** DEPTH // n_steps
    knots = _tree_W(jnp.arange(n_steps + 1, dtype=jnp.uint32) * stride)
    dt = T / n_steps
    Z = (knots[1:] - knots[:-1]) / np.sqrt(dt)      # (n_steps, NPATH)
    prob = _gbm_prob()
    u0 = jnp.broadcast_to(jnp.asarray([1.0]), (1, NPATH)).astype(jnp.float64)
    ps = jnp.broadcast_to(prob.p[:, None], (2, NPATH))
    res = sde_solve_fixed(prob, u0, ps, 0.0, dt, n_steps, key=None,
                          method=method, save_every=n_steps,
                          noise_table=Z[:, None, :])
    if method == "heun_strat":     # Stratonovich: no Ito drift correction
        exact = np.exp(R * T + V * wt)
    else:
        exact = np.exp((R - 0.5 * V * V) * T + V * wt)
    return float(np.sqrt(np.mean((np.asarray(res.u_final)[0] - exact) ** 2)))


def _slope(method, wt, levels=(64, 128, 256)):
    errs = [_strong_err(method, n, wt) for n in levels]
    fits = np.polyfit(np.log2(levels), np.log2(errs), 1)
    return -fits[0], errs


EXPECTED_ORDER = {"em": 0.5, "milstein": 1.0, "platen_w2": 1.0,
                  "heun_strat": 1.0}


def test_every_registered_sde_stepper_is_covered():
    """The table above IS the registry — a new stepper must add its expected
    strong order here (and the parametrized test below picks it up)."""
    assert {s.name for s in list_methods("sde")} == set(EXPECTED_ORDER)


@pytest.mark.parametrize("method", sorted(EXPECTED_ORDER))
def test_strong_order_slope(method, wt):
    want = EXPECTED_ORDER[method]
    slope, errs = _slope(method, wt)
    assert all(e2 < e1 for e1, e2 in zip(errs, errs[1:])), errs
    assert want - 0.17 < slope < want + 0.4, (
        f"{method}: strong-order slope {slope:.2f}, expected ~{want}")


def test_milstein_beats_em_on_the_same_paths(wt):
    assert _strong_err("milstein", 256, wt) < 0.5 * _strong_err("em", 256, wt)


# ---------------------------------------------------------------------------
# embedded estimate vs step-doubling estimate on linear-SDE steps
# ---------------------------------------------------------------------------

def _single_step_estimates(n_steps):
    """Both error estimates over one step of size T/n_steps from the same
    tree increments, starting from the exact path state at the step's left
    endpoint (linear SDE => closed form)."""
    stride = 2 ** DEPTH // n_steps
    k = n_steps // 2                     # a generic interior step
    knots = _tree_W(jnp.asarray([k * stride, k * stride + stride // 2,
                                 (k + 1) * stride], jnp.uint32))
    dt = T / n_steps
    t = k * dt
    w_l, w_m, w_r = knots
    u = jnp.exp((R - 0.5 * V * V) * t + V * w_l)[None, :]   # exact state (1,N)
    prob = _gbm_prob()
    ps = jnp.broadcast_to(prob.p[:, None], (2, NPATH))
    dW1, dW2, dWf = (w_m - w_l)[None], (w_r - w_m)[None], (w_r - w_l)[None]

    _, emb = SDE_EMBEDDED["em"].fn(prob.f, prob.g, u, ps, t, dt, dWf,
                                   "diagonal")
    u_c = em_step(prob.f, prob.g, u, ps, t, dt, dWf, "diagonal")
    u_h = em_step(prob.f, prob.g, u, ps, t, 0.5 * dt, dW1, "diagonal")
    u_2 = em_step(prob.f, prob.g, u_h, ps, t + 0.5 * dt, 0.5 * dt, dW2,
                  "diagonal")
    dbl = (u_2 - u_c) / (2.0 ** 0.5 - 1.0)   # Richardson, as the engine does
    return np.asarray(emb)[0], np.asarray(dbl)[0]


def test_embedded_estimate_within_constant_factor_of_doubling():
    """The two estimators target the same local error: their ensemble-mean
    magnitudes agree within a constant factor across step sizes (so swapping
    estimators rescales tolerances by O(1), it does not change the method)."""
    for n_steps in (32, 128):
        emb, dbl = _single_step_estimates(n_steps)
        m_emb, m_dbl = np.mean(np.abs(emb)), np.mean(np.abs(dbl))
        assert 0.1 < m_emb / m_dbl < 10.0, (n_steps, m_emb, m_dbl)
        # and both shrink ~linearly with dt on the stochastic-dominated GBM
    e32, d32 = (np.mean(np.abs(x)) for x in _single_step_estimates(32))
    e256, d256 = (np.mean(np.abs(x)) for x in _single_step_estimates(256))
    assert 4.0 < e32 / e256 < 16.0
    assert 4.0 < d32 / d256 < 16.0
