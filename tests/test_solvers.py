"""Adaptive driver behaviour: accuracy-vs-tolerance, saveat, lanes==scalar, statuses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveOptions, get_tableau, solve_adaptive,
                        solve_fixed, solve_one)
from repro.configs.de_problems import (linear_decay_problem, lorenz_problem,
                                       sho_problem)


@pytest.mark.parametrize("tol", [1e-4, 1e-7, 1e-10])
def test_accuracy_tracks_tolerance(tol):
    prob = linear_decay_problem()
    tab = get_tableau("tsit5")
    res = solve_one(prob.f, tab, prob.u0, prob.p, 0.0, 2.0, 0.01,
                    saveat=jnp.asarray([2.0]), rtol=tol, atol=tol)
    err = float(abs(res.u_final[0] - jnp.exp(-2.0)))
    assert err < 100 * tol
    assert int(res.status) == 0


def test_tighter_tol_more_steps():
    prob = sho_problem()
    tab = get_tableau("tsit5")
    n = []
    for tol in (1e-4, 1e-8):
        res = solve_one(prob.f, tab, prob.u0, prob.p, 0.0, 3.0, 0.01,
                        rtol=tol, atol=tol)
        n.append(int(res.naccept))
    assert n[1] > n[0]


def test_saveat_dense_output_accuracy():
    prob = sho_problem(omega=2.0)
    tab = get_tableau("tsit5")
    saveat = jnp.linspace(0.0, 3.0, 33)
    res = solve_one(prob.f, tab, prob.u0, prob.p, 0.0, 3.0, 0.01,
                    saveat=saveat, rtol=1e-8, atol=1e-8)
    exact = jnp.cos(2.0 * saveat)
    np.testing.assert_allclose(res.us[:, 0], exact, atol=1e-5)
    # saveat[0] == t0 must be prefilled with u0
    np.testing.assert_allclose(res.us[0], prob.u0, atol=0)


def test_lanes_mode_matches_vmap_of_scalar():
    """Per-lane adaptive control must reproduce per-trajectory solves exactly."""
    prob = lorenz_problem(jnp.float64)
    tab = get_tableau("tsit5")
    B = 7
    rho = jnp.linspace(5.0, 28.0, B, dtype=jnp.float64)
    ps = jnp.stack([jnp.full((B,), 10.0), rho, jnp.full((B,), 8.0 / 3.0)])
    u0 = jnp.broadcast_to(jnp.asarray([1.0, 0.0, 0.0])[:, None], (3, B))
    saveat = jnp.linspace(0.0, 1.0, 5)
    opts = AdaptiveOptions(rtol=1e-7, atol=1e-7)
    lanes = solve_adaptive(prob.f, tab, u0, ps, 0.0, 1.0, 1e-3,
                           saveat=saveat, opts=opts, lanes=True)

    def one(p):
        return solve_adaptive(prob.f, tab, jnp.asarray([1.0, 0.0, 0.0]), p,
                              0.0, 1.0, 1e-3, saveat=saveat, opts=opts)

    ref = jax.vmap(one)(ps.T)
    np.testing.assert_allclose(np.moveaxis(np.asarray(lanes.us), -1, 0),
                               np.asarray(ref.us), rtol=1e-12, atol=1e-12)
    np.testing.assert_array_equal(np.asarray(lanes.naccept),
                                  np.asarray(ref.naccept))


def test_fixed_equals_adaptive_fixed_mode():
    prob = sho_problem()
    tab = get_tableau("rk4")
    n_steps = 64
    rf = solve_fixed(prob.f, tab, prob.u0, prob.p, 0.0, 1.0 / n_steps, n_steps,
                     save_every=n_steps)
    opts = AdaptiveOptions(adaptive=False, max_iters=n_steps + 2)
    ra = solve_adaptive(prob.f, tab, prob.u0, prob.p, 0.0, 1.0, 1.0 / n_steps,
                        saveat=jnp.asarray([1.0]), opts=opts)
    np.testing.assert_allclose(rf.u_final, ra.u_final, rtol=1e-12)


def test_max_iters_status():
    prob = sho_problem()
    tab = get_tableau("tsit5")
    res = solve_one(prob.f, tab, prob.u0, prob.p, 0.0, 1000.0, 1e-5,
                    rtol=1e-10, atol=1e-10, max_iters=10)
    assert int(res.status) == 1


def test_f32_pipeline():
    prob = sho_problem(dtype=jnp.float32)
    tab = get_tableau("tsit5")
    res = solve_one(prob.f, tab, prob.u0, prob.p, 0.0, 3.0, 0.01,
                    rtol=1e-5, atol=1e-5)
    assert res.u_final.dtype == jnp.float32
    assert abs(float(res.u_final[0]) - float(np.cos(6.0))) < 1e-3


def test_nonfinite_rejection_recovers():
    """A blow-up candidate step must be rejected, not propagated."""
    def f(u, p, t):
        # stiff-ish: large negative eigenvalue; big dt0 causes overflow risk
        return -p[0] * u * (1.0 + 1e3 * jnp.tanh(u))

    tab = get_tableau("tsit5")
    res = solve_one(f, tab, jnp.asarray([1.0]), jnp.asarray([1.0]),
                    0.0, 0.1, 0.05, rtol=1e-6, atol=1e-6, max_iters=20000)
    assert bool(jnp.all(jnp.isfinite(res.u_final)))
