"""Property-based tests for the virtual Brownian tree
(`kernels/rng.brownian_bridge_point`) — the noise source the adaptive SDE
engine's rejection sampling stands on (see the rejection/replay contract in
the `brownian_bridge_point` docstring).

Three properties, hypothesis-driven over (seed, depth, index choices):

  1. bridge interpolation consistency: conditioned on W(l) and W(r), an
     interior point has mean W(l) + θ (W(r) - W(l)) (θ the time fraction),
     with residuals uncorrelated with the enclosing increment;
  2. correct conditional variance θ(1-θ)(t_r - t_l) of that residual;
  3. bitwise replay: any reject -> shrink -> redraw sequence returns
     identical increments (W is a pure function of the dyadic index, never
     of query order or query shape).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional property-test dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels.rng import brownian_bridge_point

N_LANES = 4000
T_TOTAL = 1.0


def _W(seed, idx, depth, n_lanes=N_LANES):
    """W at grid index (scalar or (K,)) for n_lanes lanes, one noise row."""
    idx = jnp.atleast_1d(jnp.asarray(idx, jnp.uint32))
    lanes = jnp.broadcast_to(jnp.arange(n_lanes, dtype=jnp.uint32)[None, :],
                             (idx.shape[0], n_lanes))
    rows = jnp.zeros_like(lanes)
    return np.asarray(brownian_bridge_point(
        seed, idx[:, None], lanes, rows, depth=depth, t_total=T_TOTAL,
        dtype=jnp.float64))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), depth=st.integers(4, 10),
       data=st.data())
def test_bridge_interpolation_mean_and_variance(seed, depth, data):
    """W(s) | W(l), W(r): mean is the linear interpolant, variance is
    θ(1-θ)(t_r - t_l), and the residual is uncorrelated with the enclosing
    increment — for ARBITRARY (not necessarily dyadic-aligned) l < s < r."""
    n = 2 ** depth
    l = data.draw(st.integers(0, n - 2), label="l")
    r = data.draw(st.integers(l + 2, n), label="r")
    s = data.draw(st.integers(l + 1, r - 1), label="s")
    wl, ws, wr = _W(seed, [l, s, r], depth)
    theta = (s - l) / (r - l)
    dt_lr = (r - l) / n * T_TOTAL
    resid = ws - (wl + theta * (wr - wl))
    var_want = theta * (1.0 - theta) * dt_lr
    sd = np.sqrt(var_want)
    # N_LANES independent samples: mean ~ N(0, sd/sqrt(N)), var ~ +-5 rel sd
    assert abs(np.mean(resid)) < 5.0 * sd / np.sqrt(N_LANES)
    assert abs(np.var(resid) / var_want - 1.0) < 0.25
    inc = wr - wl
    corr = np.mean(resid * inc) / (sd * np.std(inc))
    assert abs(corr) < 0.1


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), depth=st.integers(4, 10),
       data=st.data())
def test_bridge_endpoint_and_increment_statistics(seed, depth, data):
    """Unconditionally, W(i) ~ N(0, t_i) and disjoint increments are
    independent — the tree is a genuine Wiener path on its grid."""
    n = 2 ** depth
    i = data.draw(st.integers(1, n - 1), label="i")
    w0, wi, wn = _W(seed, [0, i, n], depth)
    assert np.all(w0 == 0.0)
    t_i = i / n * T_TOTAL
    assert abs(np.var(wi) / t_i - 1.0) < 0.2
    assert abs(np.var(wn) / T_TOTAL - 1.0) < 0.2
    inc = wn - wi
    assert abs(np.mean(wi * inc)) < 0.1 * np.sqrt(t_i * (T_TOTAL - t_i))


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), depth=st.integers(4, 12),
       data=st.data())
def test_reject_redraw_replays_increments_bitwise(seed, depth, data):
    """The RSwM property as a query-sequence test: attempt a step over
    [i, i+m], 'reject' it, redraw the sub-increments at any partition, then
    re-query the original endpoints — every value is bitwise identical and
    the sub-increments telescope exactly to the rejected one."""
    n = 2 ** depth
    i = data.draw(st.integers(0, n - 2), label="i")
    m = data.draw(st.integers(2, min(n - i, 64)), label="m")
    k = data.draw(st.integers(1, 6), label="k")       # partition granularity
    cuts = sorted({i, i + m}
                  | {i + data.draw(st.integers(1, m - 1), label=f"c{j}")
                     for j in range(k)})
    # 1) the attempted (rejected) step
    w_i, w_im = _W(seed, [i, i + m], depth, n_lanes=64)
    # 2) redraw at the finer partition (different query SHAPE and order)
    fine = _W(seed, list(reversed(cuts)), depth, n_lanes=64)[::-1]
    # 3) re-query the original endpoints
    w_i2, w_im2 = _W(seed, [i, i + m], depth, n_lanes=64)
    np.testing.assert_array_equal(w_i, w_i2)
    np.testing.assert_array_equal(w_im, w_im2)
    np.testing.assert_array_equal(fine[0], w_i)
    np.testing.assert_array_equal(fine[-1], w_im)
    # sub-increments telescope to the rejected increment (float-exactly up to
    # summation associativity; they are literally differences of the same
    # pure-function values, so sum in index order)
    total = fine[-1] - fine[0]
    acc = np.zeros_like(total)
    for a, b in zip(fine, fine[1:]):
        acc = acc + (b - a)
    np.testing.assert_allclose(acc, total, rtol=0, atol=1e-12)
