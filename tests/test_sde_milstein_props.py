"""Milstein strong order + ensemble permutation-invariance property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional property-test dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import EnsembleProblem
from repro.core.ensemble import solve_ensemble_local
from repro.core.sde import sde_solve_fixed
from repro.configs.de_problems import gbm_problem, lorenz_problem

R, V = 1.2, 0.5


def _strong_err(method, n_steps, Zfine, nf):
    """Mean |X_N - X_exact| with a COMMON Brownian path (Zfine at dt_fine);
    coarse levels sum consecutive fine increments."""
    prob = gbm_problem(r=R, v=V, dtype=jnp.float64)
    N = Zfine.shape[-1]
    T = 1.0
    dtf = T / nf
    step = nf // n_steps
    # aggregate fine normals to the coarse grid: sum/sqrt(step)
    Z = Zfine.reshape(n_steps, step, 1, N).sum(axis=1) / np.sqrt(step)
    u0 = jnp.broadcast_to(jnp.asarray([1.0]), (1, N)).astype(jnp.float64)
    res = sde_solve_fixed(
        type(prob)(prob.f, prob.g, jnp.asarray([1.0]), prob.p, (0.0, T),
                   noise="diagonal", name="gbm1"),
        u0, jnp.broadcast_to(prob.p[:, None], (2, N)), 0.0, T / n_steps,
        n_steps, key=None, method=method, save_every=n_steps,
        noise_table=jnp.asarray(Z))
    W_T = float(np.sqrt(dtf)) * Zfine.sum(axis=0)[0]          # (N,)
    exact = np.exp((R - V * V / 2) * T + V * np.asarray(W_T))
    return float(np.mean(np.abs(np.asarray(res.u_final)[0] - exact)))


def test_milstein_strong_order_one_vs_em_half():
    N, nf = 4000, 256
    rng = np.random.default_rng(0)
    Zfine = rng.standard_normal((nf, 1, N))
    e_m1 = _strong_err("milstein", 32, Zfine, nf)
    e_m2 = _strong_err("milstein", 64, Zfine, nf)
    e_e1 = _strong_err("em", 32, Zfine, nf)
    e_e2 = _strong_err("em", 64, Zfine, nf)
    p_mil = np.log2(e_m1 / e_m2)
    p_em = np.log2(e_e1 / e_e2)
    assert p_mil > 0.8, f"milstein strong order {p_mil:.2f}"
    assert p_em < 0.8, f"em strong order {p_em:.2f} (expected ~0.5)"
    assert e_m2 < 0.8 * e_e2  # milstein strictly more accurate


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_ensemble_permutation_invariance(seed):
    """Permuting trajectories permutes results exactly — catches any
    cross-lane mixing in the fused kernel path."""
    N = 12
    prob = lorenz_problem(jnp.float64)
    rng = np.random.default_rng(seed)
    rho = jnp.asarray(rng.uniform(2.0, 25.0, N))
    ps = jnp.stack([jnp.full((N,), 10.0), rho, jnp.full((N,), 8 / 3)], axis=1)
    perm = rng.permutation(N)
    ep1 = EnsembleProblem(prob, N, ps=ps)
    ep2 = EnsembleProblem(prob, N, ps=ps[perm])
    kw = dict(ensemble="kernel", lane_tile=4, t0=0.0, tf=0.5, dt0=1e-3,
              saveat=jnp.asarray([0.5]), rtol=1e-7, atol=1e-7)
    r1 = solve_ensemble_local(ep1, **kw)
    r2 = solve_ensemble_local(ep2, **kw)
    np.testing.assert_allclose(np.asarray(r1.u_final)[perm],
                               np.asarray(r2.u_final), rtol=1e-12, atol=0)
