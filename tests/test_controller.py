"""PI controller + error-norm invariants (hypothesis property tests)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional property-test dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import PIController, hairer_norm
from repro.core.controller import pi_propose

CTRL = PIController.for_order(4, dtmin=1e-12, dtmax=10.0)

pos_floats = st.floats(min_value=1e-8, max_value=1e6, allow_nan=False)
errs = st.floats(min_value=1e-8, max_value=1e4, allow_nan=False)


@settings(max_examples=50, deadline=None)
@given(dt=pos_floats, e=errs, ep=errs, accept=st.booleans())
def test_dt_within_clamps(dt, e, ep, accept):
    dt = min(dt, 5.0)
    dt_next, _ = pi_propose(CTRL, jnp.asarray(dt), jnp.asarray(e),
                            jnp.asarray(ep), jnp.asarray(accept))
    assert CTRL.dtmin <= float(dt_next) <= CTRL.dtmax
    # growth/shrink bounded by controller limits
    assert float(dt_next) <= dt * CTRL.qmax + 1e-12
    if not accept:
        assert float(dt_next) <= dt * 1.0 + 1e-12  # rejection never grows dt


@settings(max_examples=50, deadline=None)
@given(dt=st.floats(1e-6, 1.0), e1=errs, e2=errs, ep=errs)
def test_monotone_in_error(dt, e1, e2, ep):
    """Larger error => no larger proposed dt (accept branch)."""
    lo, hi = sorted((e1, e2))
    d_lo, _ = pi_propose(CTRL, jnp.asarray(dt), jnp.asarray(lo),
                         jnp.asarray(ep), jnp.asarray(True))
    d_hi, _ = pi_propose(CTRL, jnp.asarray(dt), jnp.asarray(hi),
                         jnp.asarray(ep), jnp.asarray(True))
    assert float(d_hi) <= float(d_lo) + 1e-12


@settings(max_examples=50, deadline=None)
@given(scale=st.floats(1e-3, 1e3),
       e=st.lists(st.floats(-10, 10), min_size=3, max_size=3))
def test_norm_homogeneous_in_err(scale, e):
    u = jnp.asarray([1.0, -2.0, 3.0])
    err = jnp.asarray(e)
    n1 = float(hairer_norm(err, u, u, 0.0, 1e-3))
    n2 = float(hairer_norm(scale * err, u, u, 0.0, 1e-3))
    np.testing.assert_allclose(n2, scale * n1, rtol=1e-6)


@settings(max_examples=50, deadline=None)
@given(e=st.lists(st.floats(-1, 1), min_size=4, max_size=4))
def test_norm_nonnegative_and_axes(e):
    err = jnp.asarray(e).reshape(2, 2)
    u = jnp.ones((2, 2))
    full = hairer_norm(err, u, u, 1e-6, 1e-3)
    per_lane = hairer_norm(err, u, u, 1e-6, 1e-3, axes=0)
    assert float(full) >= 0
    assert per_lane.shape == (2,)
    # full norm is the RMS of the per-lane norms
    np.testing.assert_allclose(float(full),
                               float(jnp.sqrt(jnp.mean(per_lane ** 2))),
                               rtol=1e-6)


def test_accept_iff_enorm_below_one_semantics():
    """The driver accepts exactly when scaled err <= 1; spot-check the scale."""
    u = jnp.asarray([2.0])
    err = jnp.asarray([0.002])
    # scale = atol + |u| rtol = 1e-3 + 2*1e-3 = 3e-3 -> norm = 2/3 < 1
    n = float(hairer_norm(err, u, u, 1e-3, 1e-3))
    np.testing.assert_allclose(n, 2 / 3, rtol=1e-6)
