"""PI controller + error-norm invariants, dt-underflow status codes, and
auto-initial-dt nf accounting.  (Property tests need hypothesis — optional
dependency, requirements-dev.txt; the status and accounting tests at the
bottom run everywhere.)"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PIController, hairer_norm
from repro.core.controller import pi_propose

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

    def given(*a, **k):          # keep decorator sites importable
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

CTRL = PIController.for_order(4, dtmin=1e-12, dtmax=10.0)

pos_floats = st.floats(min_value=1e-8, max_value=1e6, allow_nan=False)
errs = st.floats(min_value=1e-8, max_value=1e4, allow_nan=False)


@settings(max_examples=50, deadline=None)
@given(dt=pos_floats, e=errs, ep=errs, accept=st.booleans())
def test_dt_within_clamps(dt, e, ep, accept):
    dt = min(dt, 5.0)
    dt_next, _ = pi_propose(CTRL, jnp.asarray(dt), jnp.asarray(e),
                            jnp.asarray(ep), jnp.asarray(accept))
    assert CTRL.dtmin <= float(dt_next) <= CTRL.dtmax
    # growth/shrink bounded by controller limits
    assert float(dt_next) <= dt * CTRL.qmax + 1e-12
    if not accept:
        assert float(dt_next) <= dt * 1.0 + 1e-12  # rejection never grows dt


@settings(max_examples=50, deadline=None)
@given(dt=st.floats(1e-6, 1.0), e1=errs, e2=errs, ep=errs)
def test_monotone_in_error(dt, e1, e2, ep):
    """Larger error => no larger proposed dt (accept branch)."""
    lo, hi = sorted((e1, e2))
    d_lo, _ = pi_propose(CTRL, jnp.asarray(dt), jnp.asarray(lo),
                         jnp.asarray(ep), jnp.asarray(True))
    d_hi, _ = pi_propose(CTRL, jnp.asarray(dt), jnp.asarray(hi),
                         jnp.asarray(ep), jnp.asarray(True))
    assert float(d_hi) <= float(d_lo) + 1e-12


@settings(max_examples=50, deadline=None)
@given(scale=st.floats(1e-3, 1e3),
       e=st.lists(st.floats(-10, 10), min_size=3, max_size=3))
def test_norm_homogeneous_in_err(scale, e):
    u = jnp.asarray([1.0, -2.0, 3.0])
    err = jnp.asarray(e)
    n1 = float(hairer_norm(err, u, u, 0.0, 1e-3))
    n2 = float(hairer_norm(scale * err, u, u, 0.0, 1e-3))
    np.testing.assert_allclose(n2, scale * n1, rtol=1e-6)


@settings(max_examples=50, deadline=None)
@given(e=st.lists(st.floats(-1, 1), min_size=4, max_size=4))
def test_norm_nonnegative_and_axes(e):
    err = jnp.asarray(e).reshape(2, 2)
    u = jnp.ones((2, 2))
    full = hairer_norm(err, u, u, 1e-6, 1e-3)
    per_lane = hairer_norm(err, u, u, 1e-6, 1e-3, axes=0)
    assert float(full) >= 0
    assert per_lane.shape == (2,)
    # full norm is the RMS of the per-lane norms
    np.testing.assert_allclose(float(full),
                               float(jnp.sqrt(jnp.mean(per_lane ** 2))),
                               rtol=1e-6)


def test_accept_iff_enorm_below_one_semantics():
    """The driver accepts exactly when scaled err <= 1; spot-check the scale."""
    u = jnp.asarray([2.0])
    err = jnp.asarray([0.002])
    # scale = atol + |u| rtol = 1e-3 + 2*1e-3 = 3e-3 -> norm = 2/3 < 1
    n = float(hairer_norm(err, u, u, 1e-3, 1e-3))
    np.testing.assert_allclose(n, 2 / 3, rtol=1e-6)


# ---------------------------------------------------------------------------
# dt-underflow: dt pinned at dtmin while rejecting must terminate with a
# distinct status (STATUS_DTMIN_EXHAUSTED) on every engine, not spin silently
# to max_iters
# ---------------------------------------------------------------------------

def _nan_rhs(u, p, t):
    # every candidate step is non-finite => rejected forever; dt shrinks to
    # the controller floor and the retry becomes a deterministic live-lock
    return jnp.full_like(u, jnp.nan)


def test_dtmin_exhausted_status_erk():
    from repro.core import STATUS_DTMIN_EXHAUSTED, get_tableau
    from repro.core.solvers import solve_one
    res = solve_one(_nan_rhs, get_tableau("tsit5"), jnp.asarray([1.0]),
                    jnp.asarray([0.0]), 0.0, 1.0, 1e-3, rtol=1e-6, atol=1e-8)
    assert int(res.status) == STATUS_DTMIN_EXHAUSTED
    # the loop terminated promptly instead of burning max_iters rejections
    assert int(res.nreject) < 200
    assert int(res.naccept) == 0


def test_dtmin_exhausted_status_rosenbrock():
    from repro.core import STATUS_DTMIN_EXHAUSTED
    from repro.core.rosenbrock import solve_rosenbrock
    from repro.core.tableaus import ROS23W
    res = solve_rosenbrock(_nan_rhs, ROS23W, jnp.asarray([1.0]),
                           jnp.asarray([0.0]), 0.0, 1.0, 1e-3,
                           rtol=1e-6, atol=1e-8)
    assert int(res.status) == STATUS_DTMIN_EXHAUSTED
    assert int(res.nreject) < 200
    # the lazy-W path reports the same verdict
    res = solve_rosenbrock(_nan_rhs, ROS23W, jnp.asarray([1.0]),
                           jnp.asarray([0.0]), 0.0, 1.0, 1e-3,
                           rtol=1e-6, atol=1e-8, w_reuse=True)
    assert int(res.status) == STATUS_DTMIN_EXHAUSTED


def test_dtmin_exhausted_status_sde():
    from repro.core import STATUS_DTMIN_EXHAUSTED
    from repro.core.sde import em_step, sde_solve_adaptive

    def g(u, p, t):
        return jnp.ones_like(u)

    res = sde_solve_adaptive(_nan_rhs, g, em_step, "diagonal",
                             jnp.asarray([1.0]), jnp.asarray([0.0]),
                             0.0, 1.0, 1e-2, seed=0, lane_idx=0, m_noise=1,
                             depth=8, order=0.5, nf_per_step=1,
                             rtol=1e-3, atol=1e-5)
    assert int(res.status) == STATUS_DTMIN_EXHAUSTED
    assert int(res.nreject) < 200


def test_dtmin_exhausted_only_marks_hopeless_lanes():
    """Lanes mode: one poisoned lane terminates with status 2, the healthy
    lane finishes with status 0 — and the loop ends without max_iters."""
    from repro.core import STATUS_DTMIN_EXHAUSTED, get_tableau
    from repro.core.solvers import AdaptiveOptions, solve_adaptive

    def f(u, p, t):
        # lane 0: harmless decay; lane 1: NaN (p flags the poisoned lane)
        return jnp.where(p[0] > 0, jnp.nan, -u)

    u0 = jnp.ones((1, 2))
    p = jnp.asarray([[0.0, 1.0]])
    res = solve_adaptive(f, get_tableau("tsit5"), u0, p, 0.0, 1.0, 1e-2,
                         opts=AdaptiveOptions(rtol=1e-6, atol=1e-8),
                         lanes=True)
    assert res.status.shape == (2,)
    assert int(res.status[0]) == 0
    assert int(res.status[1]) == STATUS_DTMIN_EXHAUSTED


# ---------------------------------------------------------------------------
# automatic initial dt (dt0=None): the two probe f evaluations per trajectory
# must be charged to nf — auto-dt runs no longer flatter work-precision plots
# ---------------------------------------------------------------------------

def test_auto_dt0_counts_probe_evaluations_in_nf():
    import jax

    from repro.core import EnsembleProblem, initial_dt, solve_ensemble_local
    from repro.configs.de_problems import lorenz_problem
    prob = lorenz_problem(jnp.float32)
    N = 4
    ens = EnsembleProblem(prob, N)
    kw = dict(ensemble="kernel", backend="xla", t0=0.0, tf=0.3,
              rtol=1e-5, atol=1e-7)
    auto = solve_ensemble_local(ens, alg="tsit5", dt0=None, **kw)
    # reproduce the dispatch's guess by hand and run with it explicitly
    u0s, ps = ens.materialize()
    h = jax.vmap(lambda u0, pp: initial_dt(prob.f, u0, pp, 0.0, 0.3, 5,
                                           1e-7, 1e-5))(u0s, ps)
    manual = solve_ensemble_local(ens, alg="tsit5",
                                  dt0=float(jnp.min(h)), **kw)
    np.testing.assert_allclose(np.asarray(auto.u_final),
                               np.asarray(manual.u_final), rtol=1e-6)
    assert int(auto.nf) == int(manual.nf) + 2 * N
    # SDE steppers have no auto-dt path: explicit dt0 required
    from repro.configs.de_problems import gbm_problem
    gens = EnsembleProblem(gbm_problem(dtype=jnp.float32), 2)
    with pytest.raises(ValueError, match="dt0"):
        solve_ensemble_local(gens, alg="em", dt0=None, seed=0)
