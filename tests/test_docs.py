"""Docs suite integrity (CI "docs" job runs exactly this module + doctests):
every intra-repo markdown link resolves, every code path the docs name
exists, and the three docs pages cover what they promise."""
import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"
MD_FILES = sorted(REPO.glob("*.md")) + sorted(DOCS.glob("*.md"))
# [text](target) — target up to ')' or '#anchor'
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")


def test_docs_pages_exist():
    for page in ("architecture.md", "adding-a-method.md", "kernels.md"):
        assert (DOCS / page).is_file(), f"docs/{page} missing"


def test_intra_repo_markdown_links_resolve():
    bad = []
    for md in MD_FILES:
        for m in LINK_RE.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not (md.parent / target).resolve().exists():
                bad.append(f"{md.relative_to(REPO)} -> {target}")
    assert not bad, "broken intra-repo links:\n" + "\n".join(bad)


def test_docs_reference_real_code_paths():
    """Every `src/...` / `tests/...` path in backticks must exist — docs rot
    the moment a referenced module moves."""
    path_re = re.compile(r"`((?:src|tests|benchmarks|examples)/[\w/\.-]+)`")
    bad = []
    for md in MD_FILES:
        for m in path_re.finditer(md.read_text()):
            if not (REPO / m.group(1)).exists():
                bad.append(f"{md.relative_to(REPO)} -> {m.group(1)}")
    assert not bad, "docs reference missing paths:\n" + "\n".join(bad)


def test_docs_reference_real_python_symbols():
    """Dotted repro.* references in the docs must import — catches renames."""
    import importlib
    sym_re = re.compile(r"`(repro(?:\.\w+)+)`")
    bad = []
    for md in sorted(DOCS.glob("*.md")):
        for m in sym_re.finditer(md.read_text()):
            dotted = m.group(1)
            mod, ok = dotted, False
            while "." in mod:
                try:
                    importlib.import_module(mod)
                    rest = dotted[len(mod):].lstrip(".")
                    obj = importlib.import_module(mod)
                    ok = True
                    for part in [p for p in rest.split(".") if p]:
                        if not hasattr(obj, part):
                            ok = False
                            break
                        obj = getattr(obj, part)
                    break
                except ImportError:
                    mod = mod.rsplit(".", 1)[0]
            if not ok:
                bad.append(f"{md.name} -> {dotted}")
    assert not bad, "docs reference missing symbols:\n" + "\n".join(bad)


def test_architecture_doc_matrix_matches_registry():
    """The dispatch-matrix families in docs/architecture.md must be exactly
    the registered families — the doc is a contract, not prose."""
    from repro.core.methods import FAMILIES
    text = (DOCS / "architecture.md").read_text()
    for fam in FAMILIES:
        assert fam in text, f"family {fam!r} missing from architecture.md"
