"""Elastic fault-tolerant sharded ensembles (repro.dist.elastic).

The acceptance bar: a run interrupted by SIGKILL on one shard, re-sharded
over a DIFFERENT number of survivors and resumed from the latest snapshot,
produces trajectories bitwise identical to an uninterrupted run — ODE
(adaptive tsit5) and SDE (counter-RNG em) both, via a sacrificial
subprocess.  In-process tests cover the same contract for clean shard loss
(ShardFailure), one-shot methods (rosenbrock's batch-coupled gates, the
adaptive-SDE Brownian tree), checkpoint-write crashes, disk resume onto a
different shard count, and the degradation ladder's partial results.

Everything is float64 (conftest enables x64) with tile_width=4 — the
measured bitwise-compatible width family (docs/architecture.md); the
reference is always `solve_ensemble_local(..., ensemble="kernel",
backend="xla", lane_tile=4)`, the exact program the tiles run.
"""
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.de_problems import (gbm_problem, lorenz_ensemble,
                                       rober_ensemble)
from repro.core import EnsembleProblem, solve_ensemble_local
from repro.core.api import solve_ensemble_elastic
from repro.dist.chaos import ChaosMonkey
from repro.dist.elastic import STATUS_SHARD_LOST, ElasticSupervisor

F64 = jnp.float64

ODE_KW = dict(tile_width=4, segment_steps=32, t0=0.0, tf=2.0, dt0=1e-2,
              rtol=1e-6, atol=1e-6, backoff_base=0.0)
SDE_KW = dict(tile_width=4, segment_steps=64, t0=0.0, tf=1.0, dt0=1.0 / 256,
              n_steps=256, seed=7, backoff_base=0.0)


def _lorenz():
    return lorenz_ensemble(12, dtype=F64)


def _gbm(n=12):
    return EnsembleProblem(gbm_problem(r=1.5, v=0.2, dtype=F64), n)


def _ref_ode(ep):
    return solve_ensemble_local(ep, alg="tsit5", ensemble="kernel",
                                backend="xla", t0=0.0, tf=2.0, dt0=1e-2,
                                rtol=1e-6, atol=1e-6, lane_tile=4)


def _ref_sde(ep):
    return solve_ensemble_local(ep, alg="em", ensemble="kernel",
                                backend="xla", t0=0.0, tf=1.0, dt0=1.0 / 256,
                                n_steps=256, seed=7, lane_tile=4)


def _assert_bitwise(res, ref):
    np.testing.assert_array_equal(res.u_final, np.asarray(ref.u_final))
    np.testing.assert_array_equal(res.t_final, np.asarray(ref.t_final))
    np.testing.assert_array_equal(res.naccept, np.asarray(ref.naccept))
    np.testing.assert_array_equal(res.nreject, np.asarray(ref.nreject))
    assert (res.status == 0).all()


# ---------------------------------------------------------------------------
# clean-run parity: elastic == the front-door kernel solve, bitwise
# ---------------------------------------------------------------------------

def test_elastic_clean_parity_ode(tmp_path):
    """No failures injected: the segmented, sharded, snapshotting run is
    bitwise identical to one `solve_ensemble_local` kernel call — the
    supervision machinery is invisible in the numbers."""
    ep = _lorenz()
    res = solve_ensemble_elastic(ep, "tsit5", ckpt_dir=str(tmp_path),
                                 n_shards=3, **ODE_KW)
    ref = _ref_ode(ep)
    _assert_bitwise(res, ref)
    assert res.nf == int(np.asarray(ref.nf).sum())
    assert res.report["mode"] == "segment"
    assert res.report["snapshots"] >= 1 and res.report["failures"] == []


# ---------------------------------------------------------------------------
# kill a shard in-process (ShardFailure): re-shard, roll back, stay bitwise
# ---------------------------------------------------------------------------

def test_kill_reshard_bitwise_ode(tmp_path):
    """Shard 1 dies at epoch 2; its tiles roll back to the epoch-1 snapshot
    and are re-dealt over the two survivors.  Replayed segments are exact
    no-ops on already-done lanes and identical programs on live ones, so the
    final state carries no trace of the failure."""
    ep = _lorenz()
    chaos = ChaosMonkey(schedule=[(2, 1, "kill")])
    sup = ElasticSupervisor(ep, "tsit5", ckpt_dir=str(tmp_path), n_shards=3,
                            chaos=chaos, **ODE_KW)
    res = sup.run()
    _assert_bitwise(res, _ref_ode(ep))
    assert [f["kind"] for f in res.report["failures"]] == ["kill"]
    assert res.report["reshards"] >= 1
    assert res.report["restored_tiles"] >= 1
    assert 1 not in res.report["alive_shards"]


def test_kill_reshard_bitwise_sde(tmp_path):
    """Same bar for the fixed-dt SDE engine: counter-RNG streams are keyed
    by GLOBAL lane index, so a lane replayed on a different shard redraws
    exactly the noise increments it would have drawn anywhere."""
    ep = _gbm()
    chaos = ChaosMonkey(schedule=[(2, 0, "kill")])
    sup = ElasticSupervisor(ep, "em", ckpt_dir=str(tmp_path), n_shards=3,
                            chaos=chaos, **SDE_KW)
    res = sup.run()
    _assert_bitwise(res, _ref_sde(ep))
    assert res.report["failures"] and res.report["reshards"] >= 1


# ---------------------------------------------------------------------------
# one-shot methods: lost shards re-run whole tiles, results identical
# ---------------------------------------------------------------------------

def test_oneshot_rosenbrock_kill_bitwise(tmp_path):
    """Rosenbrock's lazy-W gates are batch-coupled, so it runs tiles
    one-shot.  A kill costs only the in-flight tile; the re-run is the same
    program over the same lane content — clean and killed runs agree
    bitwise, dense saves included."""
    ep = rober_ensemble(8)
    kw = dict(tile_width=4, segment_steps=32, dt0=1e-6, rtol=1e-6, atol=1e-8,
              backoff_base=0.0)
    sup = ElasticSupervisor(ep, "rosenbrock23", ckpt_dir=str(tmp_path / "a"),
                            n_shards=2, **kw)
    clean = sup.run()
    assert clean.report["mode"] == "oneshot"
    chaos = ChaosMonkey(schedule=[(1, 1, "kill")])
    sup2 = ElasticSupervisor(ep, "rosenbrock23", ckpt_dir=str(tmp_path / "b"),
                             n_shards=2, chaos=chaos, **kw)
    killed = sup2.run()
    np.testing.assert_array_equal(killed.u_final, clean.u_final)
    np.testing.assert_array_equal(killed.naccept, clean.naccept)
    np.testing.assert_array_equal(killed.status, clean.status)
    assert killed.njac == clean.njac and killed.nfact == clean.nfact
    assert killed.us is not None and clean.us is not None
    np.testing.assert_array_equal(killed.us, clean.us)
    assert killed.report["failures"]


def test_oneshot_adaptive_sde_kill_bitwise(tmp_path):
    """Adaptive SDE (dt-path-dependent Brownian tree) also rides the
    one-shot path; a killed-and-retried tile re-quantizes onto the same
    global tree, so killed == clean bitwise."""
    ep = _gbm(8)
    kw = dict(tile_width=4, t0=0.0, tf=1.0, dt0=0.05, adaptive=True,
              rtol=1e-3, atol=1e-5, seed=3, error_est="embedded",
              backoff_base=0.0)
    sup = ElasticSupervisor(ep, "em", ckpt_dir=str(tmp_path / "a"),
                            n_shards=2, **kw)
    clean = sup.run()
    assert clean.report["mode"] == "oneshot"
    chaos = ChaosMonkey(schedule=[(1, 0, "kill")])
    sup2 = ElasticSupervisor(ep, "em", ckpt_dir=str(tmp_path / "b"),
                             n_shards=2, chaos=chaos, **kw)
    killed = sup2.run()
    np.testing.assert_array_equal(killed.u_final, clean.u_final)
    np.testing.assert_array_equal(killed.naccept, clean.naccept)
    assert killed.report["failures"]


# ---------------------------------------------------------------------------
# checkpoint-write crash: previous snapshot stays the restore point
# ---------------------------------------------------------------------------

def test_ckpt_crash_skips_one_snapshot_stays_bitwise(tmp_path):
    """A crash during the epoch-2 snapshot write loses that snapshot only:
    the atomic layer leaves epoch 1 restorable, the supervisor records the
    failure and keeps solving — the result is untouched."""
    ep = _lorenz()
    chaos = ChaosMonkey(schedule=[(2, -1, "ckpt_crash")])
    sup = ElasticSupervisor(ep, "tsit5", ckpt_dir=str(tmp_path), n_shards=2,
                            chaos=chaos, **ODE_KW)
    res = sup.run()
    _assert_bitwise(res, _ref_ode(ep))
    assert [f["kind"] for f in res.report["failures"]] == ["ckpt_crash"]
    assert res.report["snapshots"] == res.report["epochs"] - 1


# ---------------------------------------------------------------------------
# disk resume: restore the newest snapshot onto a DIFFERENT shard count
# ---------------------------------------------------------------------------

def test_disk_resume_different_shard_count_bitwise(tmp_path):
    """Snapshots are unsharded (host-gathered full tile carries), so a run
    stopped after 2 epochs on 3 shards resumes on 2 shards — and the
    stitched run equals an uninterrupted one bitwise."""
    ep = _lorenz()
    part = ElasticSupervisor(ep, "tsit5", ckpt_dir=str(tmp_path), n_shards=3,
                             max_epochs=2, **ODE_KW).run()
    assert (part.status == 1).any()      # genuinely unfinished mid-run
    sup2 = ElasticSupervisor(ep, "tsit5", ckpt_dir=str(tmp_path), n_shards=2,
                             **ODE_KW)
    res = sup2.run(resume=True)
    assert res.report["resumed_from_epoch"] == 2
    _assert_bitwise(res, _ref_ode(ep))


def test_resume_identity_mismatch_rejected(tmp_path):
    """Tile width is part of the run identity (XLA codegen is
    width-sensitive at the ulp level): resuming a B=4 snapshot with B=8
    must be refused, not silently re-tiled."""
    ep = _lorenz()
    ElasticSupervisor(ep, "tsit5", ckpt_dir=str(tmp_path), n_shards=2,
                      max_epochs=1, **ODE_KW).run()
    bad = dict(ODE_KW, tile_width=8)
    sup = ElasticSupervisor(ep, "tsit5", ckpt_dir=str(tmp_path), n_shards=2,
                            **bad)
    with pytest.raises(ValueError, match="tile_width"):
        sup.run(resume=True)


# ---------------------------------------------------------------------------
# degradation ladder: bail past max_failures with a PARTIAL result
# ---------------------------------------------------------------------------

def test_degradation_ladder_partial_result(tmp_path):
    """Every epoch kills a shard (p_kill=1): the ladder walks down to a
    single revived host and, past max_failures, bails to a partial result —
    unfinished lanes carry STATUS_SHARD_LOST instead of the run hanging or
    raising."""
    ep = _lorenz()
    chaos = ChaosMonkey(seed=1, p_kill=1.0)
    kw = dict(ODE_KW, segment_steps=8)
    sup = ElasticSupervisor(ep, "tsit5", ckpt_dir=str(tmp_path), n_shards=2,
                            max_failures=3, chaos=chaos, **kw)
    res = sup.run()
    assert res.report["bailed"]
    assert res.report["degraded_single_host"]
    assert res.report["ladder"] and res.report["ladder"][-1] == 1
    got = set(np.unique(res.status).tolist())
    assert STATUS_SHARD_LOST in got
    assert got <= {0, STATUS_SHARD_LOST}


# ---------------------------------------------------------------------------
# the acceptance bar: SIGKILL a real process mid-run, resume, diff bitwise
# ---------------------------------------------------------------------------

ELASTIC_SCRIPT = r"""
import sys
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.configs.de_problems import gbm_problem, lorenz_ensemble
from repro.core import EnsembleProblem, solve_ensemble_local
from repro.dist.chaos import ChaosMonkey
from repro.dist.elastic import ElasticSupervisor

phase, case, ckpt_dir = sys.argv[1], sys.argv[2], sys.argv[3]

if case == "ode":
    ep = lorenz_ensemble(12, dtype=jnp.float64)
    alg = "tsit5"
    kw = dict(tile_width=4, segment_steps=32, t0=0.0, tf=2.0, dt0=1e-2,
              rtol=1e-6, atol=1e-6, backoff_base=0.0)
    ref_kw = dict(alg=alg, ensemble="kernel", backend="xla", t0=0.0, tf=2.0,
                  dt0=1e-2, rtol=1e-6, atol=1e-6, lane_tile=4)
else:
    ep = EnsembleProblem(gbm_problem(r=1.5, v=0.2, dtype=jnp.float64), 12)
    alg = "em"
    kw = dict(tile_width=4, segment_steps=64, t0=0.0, tf=1.0, dt0=1.0 / 256,
              n_steps=256, seed=7, backoff_base=0.0)
    ref_kw = dict(alg=alg, ensemble="kernel", backend="xla", t0=0.0, tf=1.0,
                  dt0=1.0 / 256, n_steps=256, seed=7, lane_tile=4)

if phase == "kill":
    # epoch 1 commits + snapshots, then shard 0's first tile of epoch 2
    # SIGKILLs the whole process — an uncatchable hard kill
    chaos = ChaosMonkey(schedule=[(2, 0, "sigkill")])
    sup = ElasticSupervisor(ep, alg, ckpt_dir=ckpt_dir, n_shards=3,
                            chaos=chaos, **kw)
    sup.run()
    print("UNREACHABLE")                 # parent asserts we never got here
else:
    sup = ElasticSupervisor(ep, alg, ckpt_dir=ckpt_dir, n_shards=2, **kw)
    res = sup.run(resume=True)
    assert res.report["resumed_from_epoch"] >= 1, res.report
    ref = solve_ensemble_local(ep, **ref_kw)
    assert np.array_equal(res.u_final, np.asarray(ref.u_final))
    assert np.array_equal(res.t_final, np.asarray(ref.t_final))
    assert np.array_equal(res.naccept, np.asarray(ref.naccept))
    assert np.array_equal(res.nreject, np.asarray(ref.nreject))
    assert (res.status == 0).all()
    print("ELASTIC-RESUME-OK")
"""


def _run_phase(phase, case, ckpt_dir):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT, phase, case, ckpt_dir],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.mark.parametrize("case", ["ode", "sde"])
def test_sigkill_resume_bitwise_subprocess(case, tmp_path):
    """THE acceptance test.  Phase 1: a 3-shard run is SIGKILLed (real
    signal 9, no cleanup) mid-epoch.  Phase 2: a NEW process resumes the
    on-disk snapshot onto 2 shards and finishes; the stitched trajectories
    are bitwise identical to an uninterrupted single-call reference —
    adaptive ODE and fixed-dt counter-RNG SDE both."""
    ckpt = str(tmp_path / "ck")
    kill = _run_phase("kill", case, ckpt)
    assert kill.returncode == -9, (
        kill.returncode, kill.stdout, kill.stderr[-2000:])
    assert "UNREACHABLE" not in kill.stdout
    assert os.path.isdir(ckpt), "SIGKILL landed before the first snapshot"
    resume = _run_phase("resume", case, ckpt)
    assert resume.returncode == 0, resume.stderr[-4000:]
    assert "ELASTIC-RESUME-OK" in resume.stdout
