"""SDE stepper validation: exact pathwise structure, scheme moments, weak order."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EnsembleProblem
from repro.core.sde import sde_solve_fixed, solve_sde_ensemble
from repro.configs.de_problems import crn_problem, gbm_problem

R, V = 1.5, 0.2


def test_em_pathwise_exact_structure():
    """EM on GBM has the closed form X_{k+1} = X_k (1 + r dt + V dW_k).
    With an injected noise table the solver must reproduce it exactly."""
    prob = gbm_problem(r=R, v=V, dtype=jnp.float64)
    n_steps, dt = 50, 0.02
    key = jax.random.PRNGKey(0)
    Z = jax.random.normal(key, (n_steps, 3), jnp.float64)
    res = sde_solve_fixed(prob, prob.u0, prob.p, 0.0, dt, n_steps,
                          key=None, method="em", save_every=n_steps,
                          noise_table=Z)
    X = np.asarray(prob.u0, np.float64)
    for k in range(n_steps):
        X = X * (1.0 + R * dt + V * np.sqrt(dt) * np.asarray(Z[k]))
    np.testing.assert_allclose(np.asarray(res.u_final), X, rtol=1e-12)


def test_em_ensemble_moments_match_discrete_closed_form():
    """E[X_n] = X0 (1+r dt)^n and E[X_n^2] = X0^2 ((1+r dt)^2 + V^2 dt)^n are
    the EXACT moments of the EM chain — the MC ensemble must match them."""
    prob = gbm_problem(r=R, v=V, dtype=jnp.float64)
    N, n_steps, dt = 20000, 20, 0.05
    ens = EnsembleProblem(prob, N)
    res = solve_sde_ensemble(ens, jax.random.PRNGKey(1), dt, n_steps,
                             method="em", ensemble="kernel",
                             save_every=n_steps)
    X = np.asarray(res.u_final)[:, 0]
    mean_exact = 0.1 * (1 + R * dt) ** n_steps
    m2_exact = 0.01 * ((1 + R * dt) ** 2 + V * V * dt) ** n_steps
    # MC standard errors
    se_mean = X.std() / np.sqrt(N)
    assert abs(X.mean() - mean_exact) < 5 * se_mean + 1e-12
    se_m2 = (X**2).std() / np.sqrt(N)
    assert abs((X**2).mean() - m2_exact) < 5 * se_m2 + 1e-12


def test_platen_weak_order_two_vs_em():
    """Weak error of E[X(1)] vs analytic X0 e^r: Platen's bias must shrink
    ~quadratically and be far below EM's O(dt) bias at the same dt."""
    prob = gbm_problem(r=R, v=V, dtype=jnp.float64)
    N = 40000
    exact = 0.1 * np.exp(R)
    key = jax.random.PRNGKey(2)

    def mean_final(method, n_steps):
        ens = EnsembleProblem(prob, N)
        res = solve_sde_ensemble(ens, key, 1.0 / n_steps, n_steps,
                                 method=method, ensemble="kernel",
                                 save_every=n_steps)
        return float(np.asarray(res.u_final)[:, 0].mean())

    em_bias = abs(mean_final("em", 20) - exact)
    pl_bias = abs(mean_final("platen_w2", 20) - exact)
    assert pl_bias < 0.3 * em_bias, f"platen {pl_bias} vs em {em_bias}"
    # deterministic part of EM bias is known: X0[(1+r dt)^n - e^r]
    det = abs(0.1 * ((1 + R / 20) ** 20 - np.exp(R)))
    assert abs(em_bias - det) < 0.3 * det + 5e-4


def test_vmap_vs_kernel_same_law():
    """Different lane packing => different noise draws, same distribution."""
    prob = gbm_problem(r=R, v=V, dtype=jnp.float64)
    N, n_steps, dt = 8000, 20, 0.05
    ens = EnsembleProblem(prob, N)
    rk = solve_sde_ensemble(ens, jax.random.PRNGKey(3), dt, n_steps,
                            method="em", ensemble="kernel",
                            save_every=n_steps)
    rv = solve_sde_ensemble(ens, jax.random.PRNGKey(4), dt, n_steps,
                            method="em", ensemble="vmap", save_every=n_steps)
    a = np.asarray(rk.u_final)[:, 0]
    b = np.asarray(rv.u_final)[:, 0]
    se = np.hypot(a.std(), b.std()) / np.sqrt(N)
    assert abs(a.mean() - b.mean()) < 5 * se


def test_crn_general_noise_runs_finite():
    """The paper's 4-state/8-noise CRN (general noise matrix) integrates."""
    prob = crn_problem(tspan=(0.0, 10.0), dtype=jnp.float64)
    ens = EnsembleProblem(prob, 64)
    res = solve_sde_ensemble(ens, jax.random.PRNGKey(5), 0.1, 100,
                             method="em", ensemble="kernel", save_every=10)
    assert res.us.shape == (64, 10, 4)
    assert bool(jnp.all(jnp.isfinite(res.us)))


def test_heun_stratonovich_drift_correction():
    """For GBM, Stratonovich Heun converges to the Stratonovich solution,
    whose mean is X0 e^{(r+V^2/2)t} — distinguishable from the Ito mean."""
    prob = gbm_problem(r=R, v=0.8, dtype=jnp.float64)  # big V to separate
    N, n_steps = 40000, 400
    ens = EnsembleProblem(prob, N)
    res = solve_sde_ensemble(ens, jax.random.PRNGKey(6), 1.0 / n_steps,
                             n_steps, method="heun_strat", ensemble="kernel",
                             save_every=n_steps)
    X = np.asarray(res.u_final)[:, 0]
    strat_mean = 0.1 * np.exp(R + 0.5 * 0.64)
    ito_mean = 0.1 * np.exp(R)
    assert abs(X.mean() - strat_mean) < abs(X.mean() - ito_mean)
