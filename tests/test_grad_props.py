"""Property-based gradcheck for the bounded adjoint engines (§6.6).

Hypothesis-driven over random linear problems u' = A u (A drawn with a
negative-definite symmetric part so solves stay tame): on the SAME bounded
program that ``sensitivity="adjoint"`` builds,

  1. vjp-jvp transpose consistency: <v, J·w> == <Jᵀ·v, w> for random
     tangent/cotangent pairs — reverse mode through the checkpointed scan is
     the exact transpose of forward mode through it;
  2. linearity: for a linear ODE the map u0 -> u(T) is linear, so the jvp at
     any base point equals the map's own increment;
  3. grad additivity over the ensemble axis (trajectories are independent).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional property-test dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import EnsembleProblem, ODEProblem
from repro.core.ensemble import solve_ensemble_local

DIM = 3
N_TRAJ = 2
T = 1.0
BOUND = 512


def _linear_problem(rng):
    """u' = A u with A = S - Q Qᵀ (skew + negative semidefinite): decaying."""
    S = rng.standard_normal((DIM, DIM))
    A = (S - S.T) / 2 - 0.5 * (S @ S.T) / DIM - 0.1 * np.eye(DIM)

    def f(u, p, t):
        return p.reshape(DIM, DIM) @ u

    u0 = jnp.asarray(rng.standard_normal(DIM))
    p = jnp.asarray(A.reshape(-1))
    return ODEProblem(f, u0, p, (0.0, T), name="randlin")


def _solve_uf(prob, u0s, ps):
    ep = EnsembleProblem(prob, u0s.shape[0], u0s=u0s, ps=ps)
    res = solve_ensemble_local(ep, alg="tsit5", ensemble="vmap", t0=0.0,
                               tf=T, dt0=1e-2, rtol=1e-8, atol=1e-8,
                               saveat=jnp.asarray([T]),
                               sensitivity="adjoint", adjoint_steps=BOUND)
    return res.u_final


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_vjp_is_transpose_of_jvp(seed):
    rng = np.random.default_rng(seed)
    prob = _linear_problem(rng)
    u0s = jnp.asarray(rng.standard_normal((N_TRAJ, DIM)))
    ps = jnp.tile(prob.p[None], (N_TRAJ, 1))

    fn = lambda u, p: _solve_uf(prob, u, p)
    w = (jnp.asarray(rng.standard_normal(u0s.shape)),
         jnp.asarray(rng.standard_normal(ps.shape)))
    v = jnp.asarray(rng.standard_normal((N_TRAJ, DIM)))

    _, jvp_out = jax.jvp(fn, (u0s, ps), w)
    _, vjp_fn = jax.vjp(fn, u0s, ps)
    vjp_out = vjp_fn(v)

    lhs = float(jnp.vdot(v, jvp_out))
    rhs = float(sum(jnp.vdot(a, b) for a, b in zip(vjp_out, w)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-10)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_linear_ode_jvp_equals_increment(seed):
    """For u' = A u the solution map is linear in u0, so the u0-jvp equals
    the frozen-step-sequence map applied to the tangent — and for a linear
    problem the accept sequence is u0-independent in exact arithmetic, so
    FD at a small-enough eps agrees tightly too."""
    rng = np.random.default_rng(seed)
    prob = _linear_problem(rng)
    u0s = jnp.asarray(rng.standard_normal((N_TRAJ, DIM)))
    ps = jnp.tile(prob.p[None], (N_TRAJ, 1))
    du = jnp.asarray(rng.standard_normal(u0s.shape))

    _, dout = jax.jvp(lambda u: _solve_uf(prob, u, ps), (u0s,), (du,))
    # linearity: J(u0)·du == uf(du) under the same step sequence only in
    # exact arithmetic; compare against central FD instead (robust form)
    eps = 1e-6
    fd = (_solve_uf(prob, u0s + eps * du, ps)
          - _solve_uf(prob, u0s - eps * du, ps)) / (2 * eps)
    np.testing.assert_allclose(np.asarray(dout), np.asarray(fd),
                               rtol=1e-5, atol=1e-8)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_grad_additivity_over_trajectories(seed):
    """Trajectories are independent: the gradient of the summed loss equals
    the per-trajectory gradients computed separately (bit-for-bit is not
    required across different batch extents — allclose is)."""
    rng = np.random.default_rng(seed)
    prob = _linear_problem(rng)
    u0s = jnp.asarray(rng.standard_normal((N_TRAJ, DIM)))
    ps = jnp.tile(prob.p[None], (N_TRAJ, 1))

    g_joint = jax.grad(
        lambda u: jnp.sum(_solve_uf(prob, u, ps) ** 2))(u0s)
    for i in range(N_TRAJ):
        g_i = jax.grad(
            lambda u: jnp.sum(_solve_uf(prob, u, ps[i:i + 1]) ** 2))(
                u0s[i:i + 1])
        np.testing.assert_allclose(np.asarray(g_joint[i]),
                                   np.asarray(g_i[0]), rtol=1e-9, atol=1e-12)
