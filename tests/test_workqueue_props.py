"""Property tests for the lease-token WorkQueue (`repro.dist.fault`).

The queue is the scheduler under `repro.serve`: multiple pump threads claim
requests under lease, stragglers expire, and stale completions must never
retire an item a live worker re-claimed.  These tests drive randomized
claim/expire/complete interleavings (seeded — deterministic in CI) and check
the invariants the serve layer depends on:

  I1  an item is retired by exactly ONE completion, and that completion's
      token is the item's latest issued lease generation at retire time;
  I2  a completion with a stale token is rejected and changes nothing;
  I3  no two live (unexpired) leases for the same item coexist;
  I4  the queue always drains: with workers that eventually complete,
      `finished` goes True and every item was retired exactly once.
"""
import random
import threading
import time

from repro.dist.fault import WorkQueue


def test_random_interleavings_single_thread():
    """Exhaustive-ish seeded fuzz of claim/expire/complete sequences."""
    for seed in range(40):
        rng = random.Random(seed)
        n = rng.randint(1, 6)
        q = WorkQueue(n_items=n, tile=1, timeout=0.0)  # every lease expired
        outstanding = []        # (idx, token) leases held by "workers"
        retired = {}            # idx -> token that retired it
        issued = {i: 0 for i in range(n)}   # latest generation per item

        for _ in range(200):
            op = rng.random()
            if op < 0.5:
                got = q.claim()
                if got is None:
                    assert q.finished
                    break
                idx, _, tok = got
                assert idx not in retired                      # I2 for claims
                assert tok == issued[idx] + 1, "generation must bump"
                issued[idx] = tok
                outstanding.append((idx, tok))
            elif outstanding:
                pick = rng.randrange(len(outstanding))
                idx, tok = outstanding.pop(pick)
                ok = q.complete(idx, tok)
                stale = tok != issued[idx] or idx in retired
                assert ok == (not stale)                       # I1 + I2
                if ok:
                    retired[idx] = tok

        # drain: complete everything via fresh claims
        while (got := q.claim()) is not None:
            idx, _, tok = got
            assert q.complete(idx, tok)
            retired[idx] = tok
        assert q.finished and len(retired) == n                # I4


def test_stale_straggler_cannot_retire_reclaimed_item():
    q = WorkQueue(n_items=1, tile=1, timeout=0.05)
    i1, _, t1 = q.claim()
    time.sleep(0.06)                 # lease expires
    i2, _, t2 = q.claim()            # live worker re-claims
    assert (i1, t2) == (i2, t1 + 1)
    assert not q.complete(i1, t1)    # straggler wakes up late: rejected
    assert not q.finished            # the live worker still owns it
    assert q.complete(i2, t2)
    assert q.finished


def test_live_lease_not_double_claimed():
    q = WorkQueue(n_items=2, tile=1, timeout=60.0)
    a = q.claim()
    b = q.claim()
    assert a[0] != b[0]              # I3: distinct items while leases live
    assert q.claim() is None


def test_renew_keeps_inflight_lease_alive():
    """An actively-renewed lease never expires: a worker solving past the
    timeout keeps its item, and its original token still completes."""
    q = WorkQueue(n_items=1, tile=1, timeout=0.05)
    idx, _, tok = q.claim()
    for _ in range(3):
        time.sleep(0.03)
        assert q.renew(idx, tok)
        assert q.claim() is None         # never re-leased while renewed
    assert q.complete(idx, tok)
    assert q.finished
    # stale/retired renews are rejected without side effects
    assert not q.renew(idx, tok)


def test_retired_prefix_is_compacted_and_payloads_released():
    """Completed items are garbage-collected (payload freed, done prefix
    dropped) while indices stay valid and late stale calls are no-ops."""
    q = WorkQueue(timeout=60.0)
    idxs = [q.push(f"req-{i}") for i in range(50)]
    assert idxs == list(range(50))
    leases = {}
    for _ in range(50):
        idx, payload, tok = q.claim()
        assert payload == f"req-{idx}"
        leases[idx] = tok
    for idx in idxs[:49]:
        assert q.complete(idx, leases[idx])
    q.claim()                            # triggers prefix compaction
    assert len(q._done) <= 2             # history dropped, not retained
    assert q.pending == 1 and not q.finished
    # retired-and-compacted indices reject late completes/releases/renews
    assert not q.complete(idxs[0], leases[idxs[0]])
    assert not q.release(idxs[0], leases[idxs[0]])
    assert not q.renew(idxs[0], leases[idxs[0]])
    # the survivor's global index still works, and new pushes stay global
    new_idx = q.push("req-50")
    assert new_idx == 50
    assert q.complete(idxs[-1], leases[idxs[-1]])
    i, p, t = q.claim()
    assert (i, p) == (50, "req-50")
    assert q.complete(i, t)
    assert q.finished and q.pending == 0


def test_threaded_workers_retire_each_item_exactly_once():
    """8 threads hammer a 60-item queue with a tiny lease timeout (forced
    re-leases) and randomized delays; every item must end up retired exactly
    once and every completion outcome must be consistent with token
    freshness."""
    n = 60
    q = WorkQueue(n_items=n, tile=1, timeout=0.002)
    accepted = [0] * n
    lock = threading.Lock()

    def worker(wid):
        rng = random.Random(wid)
        idle = 0
        while idle < 50:
            got = q.claim()
            if got is None:
                if q.finished:
                    return
                idle += 1
                time.sleep(0.001)
                continue
            idle = 0
            idx, _, tok = got
            if rng.random() < 0.3:
                time.sleep(0.004)    # straggle past the lease timeout
            if q.complete(idx, tok):
                with lock:
                    accepted[idx] += 1

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert q.finished
    assert accepted == [1] * n       # exactly-once retirement
