"""Property tests for the lease-token WorkQueue (`repro.dist.fault`).

The queue is the scheduler under `repro.serve`: multiple pump threads claim
requests under lease, stragglers expire, and stale completions must never
retire an item a live worker re-claimed.  These tests drive randomized
claim/expire/complete interleavings (seeded — deterministic in CI) and check
the invariants the serve layer depends on:

  I1  an item is retired by exactly ONE completion, and that completion's
      token is the item's latest issued lease generation at retire time;
  I2  a completion with a stale token is rejected and changes nothing;
  I3  no two live (unexpired) leases for the same item coexist;
  I4  the queue always drains: with workers that eventually complete,
      `finished` goes True and every item was retired exactly once.
"""
import random
import threading
import time

from repro.dist.fault import WorkQueue


def test_random_interleavings_single_thread():
    """Exhaustive-ish seeded fuzz of claim/expire/complete sequences."""
    for seed in range(40):
        rng = random.Random(seed)
        n = rng.randint(1, 6)
        q = WorkQueue(n_items=n, tile=1, timeout=0.0)  # every lease expired
        outstanding = []        # (idx, token) leases held by "workers"
        retired = {}            # idx -> token that retired it
        issued = {i: 0 for i in range(n)}   # latest generation per item

        for _ in range(200):
            op = rng.random()
            if op < 0.5:
                got = q.claim()
                if got is None:
                    assert q.finished
                    break
                idx, _, tok = got
                assert idx not in retired                      # I2 for claims
                assert tok == issued[idx] + 1, "generation must bump"
                issued[idx] = tok
                outstanding.append((idx, tok))
            elif outstanding:
                pick = rng.randrange(len(outstanding))
                idx, tok = outstanding.pop(pick)
                ok = q.complete(idx, tok)
                stale = tok != issued[idx] or idx in retired
                assert ok == (not stale)                       # I1 + I2
                if ok:
                    retired[idx] = tok

        # drain: complete everything via fresh claims
        while (got := q.claim()) is not None:
            idx, _, tok = got
            assert q.complete(idx, tok)
            retired[idx] = tok
        assert q.finished and len(retired) == n                # I4


def test_stale_straggler_cannot_retire_reclaimed_item():
    q = WorkQueue(n_items=1, tile=1, timeout=0.05)
    i1, _, t1 = q.claim()
    time.sleep(0.06)                 # lease expires
    i2, _, t2 = q.claim()            # live worker re-claims
    assert (i1, t2) == (i2, t1 + 1)
    assert not q.complete(i1, t1)    # straggler wakes up late: rejected
    assert not q.finished            # the live worker still owns it
    assert q.complete(i2, t2)
    assert q.finished


def test_live_lease_not_double_claimed():
    q = WorkQueue(n_items=2, tile=1, timeout=60.0)
    a = q.claim()
    b = q.claim()
    assert a[0] != b[0]              # I3: distinct items while leases live
    assert q.claim() is None


def test_renew_keeps_inflight_lease_alive():
    """An actively-renewed lease never expires: a worker solving past the
    timeout keeps its item, and its original token still completes."""
    q = WorkQueue(n_items=1, tile=1, timeout=0.05)
    idx, _, tok = q.claim()
    for _ in range(3):
        time.sleep(0.03)
        assert q.renew(idx, tok)
        assert q.claim() is None         # never re-leased while renewed
    assert q.complete(idx, tok)
    assert q.finished
    # stale/retired renews are rejected without side effects
    assert not q.renew(idx, tok)


def test_retired_prefix_is_compacted_and_payloads_released():
    """Completed items are garbage-collected (payload freed, done prefix
    dropped) while indices stay valid and late stale calls are no-ops."""
    q = WorkQueue(timeout=60.0)
    idxs = [q.push(f"req-{i}") for i in range(50)]
    assert idxs == list(range(50))
    leases = {}
    for _ in range(50):
        idx, payload, tok = q.claim()
        assert payload == f"req-{idx}"
        leases[idx] = tok
    for idx in idxs[:49]:
        assert q.complete(idx, leases[idx])
    q.claim()                            # triggers prefix compaction
    assert len(q._done) <= 2             # history dropped, not retained
    assert q.pending == 1 and not q.finished
    # retired-and-compacted indices reject late completes/releases/renews
    assert not q.complete(idxs[0], leases[idxs[0]])
    assert not q.release(idxs[0], leases[idxs[0]])
    assert not q.renew(idxs[0], leases[idxs[0]])
    # the survivor's global index still works, and new pushes stay global
    new_idx = q.push("req-50")
    assert new_idx == 50
    assert q.complete(idxs[-1], leases[idxs[-1]])
    i, p, t = q.claim()
    assert (i, p) == (50, "req-50")
    assert q.complete(i, t)
    assert q.finished and q.pending == 0


def test_threaded_workers_retire_each_item_exactly_once():
    """8 threads hammer a 60-item queue with a tiny lease timeout (forced
    re-leases) and randomized delays; every item must end up retired exactly
    once and every completion outcome must be consistent with token
    freshness."""
    n = 60
    q = WorkQueue(n_items=n, tile=1, timeout=0.002)
    accepted = [0] * n
    lock = threading.Lock()

    def worker(wid):
        rng = random.Random(wid)
        idle = 0
        while idle < 50:
            got = q.claim()
            if got is None:
                if q.finished:
                    return
                idle += 1
                time.sleep(0.001)
                continue
            idle = 0
            idx, _, tok = got
            if rng.random() < 0.3:
                time.sleep(0.004)    # straggle past the lease timeout
            if q.complete(idx, tok):
                with lock:
                    accepted[idx] += 1

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert q.finished
    assert accepted == [1] * n       # exactly-once retirement


# ---------------------------------------------------------------------------
# expiry-reclaim backoff (I5): dead-worker items must not thrash
# ---------------------------------------------------------------------------

def _clocked_queue(**kw):
    """Queue on an injected manual clock — backoff schedules without sleep."""
    t = [0.0]
    q = WorkQueue(clock=lambda: t[0], **kw)
    return q, t


def test_expiry_reclaim_backs_off_exponentially():
    """I5: the FIRST expiry reclaims at the base timeout; every further
    expiry of the same item multiplies its effective lease timeout by
    backoff_factor, capped at backoff_max_mult x base."""
    q, t = _clocked_queue(n_items=1, tile=1, timeout=1.0, backoff_factor=2.0,
                          backoff_max_mult=8.0, backoff_jitter=0.0)
    assert q.claim() is not None          # fresh lease at t=0
    t[0] = 0.99
    assert q.claim() is None              # not yet expired
    t[0] = 1.0
    assert q.claim() is not None          # expiry #1: base timeout
    t[0] += 1.99
    assert q.claim() is None              # now needs 2x base
    t[0] += 0.01
    assert q.claim() is not None          # expiry #2 at 2x
    t[0] += 3.99
    assert q.claim() is None              # now needs 4x base
    t[0] += 0.01
    assert q.claim() is not None          # expiry #3 at 4x
    t[0] += 7.99
    assert q.claim() is None              # 8x base
    t[0] += 0.01
    assert q.claim() is not None          # expiry #4 at 8x
    t[0] += 7.99
    assert q.claim() is None              # capped: STILL 8x, not 16x
    t[0] += 0.01
    got = q.claim()
    assert got is not None
    idx, _, tok = got
    assert q.complete(idx, tok)
    assert q.finished


def test_backoff_jitter_is_bounded_and_deterministic():
    """Jitter stretches the backed-off timeout by at most backoff_jitter x,
    never shrinks it, and is a pure function of (seed, item, attempt):
    two queues replaying the same sequence agree exactly."""
    waits = []
    for _ in range(2):
        q, t = _clocked_queue(n_items=1, tile=1, timeout=1.0,
                              backoff_factor=2.0, backoff_max_mult=8.0,
                              backoff_jitter=0.25, jitter_seed=7)
        assert q.claim() is not None
        t[0] = 1.0
        assert q.claim() is not None      # first expiry: base, jitter-free
        run = []
        for mult in (2.0, 4.0):
            lo, hi = mult, mult * 1.25
            t[0] += lo - 1e-9
            assert q.claim() is None      # below the un-jittered floor: never
            lo_probe = t[0]
            while q.claim() is None:      # scan to the jittered deadline
                t[0] += mult / 256.0
            run.append(t[0] - lo_probe)
            assert t[0] - lo_probe <= hi - lo + mult / 128.0
        waits.append(run)
    assert waits[0] == waits[1]           # deterministic across queues


def test_release_resets_backoff():
    """A voluntary release (live worker handing the item back) resets the
    expiry ladder: the next lease expires at the base timeout again."""
    q, t = _clocked_queue(n_items=1, tile=1, timeout=1.0, backoff_factor=2.0,
                          backoff_jitter=0.0)
    q.claim()
    t[0] = 1.0
    q.claim()                             # expiry #1
    t[0] += 2.0
    idx, _, tok = q.claim()               # expiry #2 (2x)
    assert q.release(idx, tok)
    got = q.claim()                       # immediate: released, not expired
    assert got is not None
    idx, _, tok = got
    t[0] += 0.999
    assert q.claim() is None
    t[0] += 0.001
    assert q.claim() is not None          # base timeout again, not 4x
    assert not q.complete(idx, tok)       # stale after the re-lease


def test_zero_timeout_stays_immediate_under_backoff():
    """timeout=0 ("every lease already expired" test mode) is unaffected by
    backoff: 0 x anything = 0, so reclaim stays immediate at every attempt."""
    q = WorkQueue(n_items=1, tile=1, timeout=0.0)
    toks = [q.claim()[2] for _ in range(5)]
    assert toks == [1, 2, 3, 4, 5]


def test_lease_expiry_storm_reclaims_all():
    """`chaos.force_lease_expiry` (mass worker death) makes every live lease
    reclaimable at once; generation tokens still fence the dead cohort."""
    from repro.dist.chaos import force_lease_expiry
    q = WorkQueue(n_items=4, tile=1, timeout=3600.0)
    dead = [q.claim() for _ in range(4)]
    assert q.claim() is None              # all leased, nothing expired
    assert force_lease_expiry(q) == 4
    live = [q.claim() for _ in range(4)]
    assert all(c is not None for c in live)
    for (idx, _, tok) in dead:
        assert not q.complete(idx, tok)   # dead cohort fenced out
    for (idx, _, tok) in live:
        assert q.complete(idx, tok)
    assert q.finished
