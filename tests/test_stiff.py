"""Stiff subsystem: s-stage W-method engine, Rodas tableaus, pivoted LU,
analytic-Jacobian hook, and the ROBER cross-strategy/backend parity bar.

ROBER's rate constants span ~9 orders of magnitude, so everything here is
float64 (conftest enables jax_enable_x64; CI additionally runs this file in a
dedicated x64 leg)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.de_problems import (orego_problem, rober_ensemble,
                                       rober_jac, rober_problem, rober_rhs)
from repro.core import (EnsembleProblem, get_method, initial_dt,
                        solve_ensemble_local)
from repro.core.order_conditions import (max_rosenbrock_condition_residual,
                                         rosenbrock_consistency_residual)
from repro.core.rosenbrock import rosenbrock_step, solve_rosenbrock
from repro.core.tableaus import RODAS4, RODAS5P, ROS23W, RosenbrockTableau

RB_TABS = [ROS23W, RODAS4, RODAS5P]


# ---------------------------------------------------------------------------
# tableau verification: algebraic order conditions + empirical convergence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rtab", RB_TABS, ids=lambda t: t.name)
def test_rosenbrock_order_conditions(rtab):
    # propagated weights satisfy every rooted-tree condition of the claimed
    # order; the first condition of order+1 fails (the order is sharp)
    assert max_rosenbrock_condition_residual(rtab, rtab.order) < 1e-12
    assert max_rosenbrock_condition_residual(rtab, rtab.order + 1) > 1e-4
    # embedded weights hold their claimed order
    assert max_rosenbrock_condition_residual(
        rtab, rtab.embedded_order, embedded=True) < 1e-12
    # c = rowsum(alpha), d = rowsum(Gamma): non-autonomous consistency
    assert rosenbrock_consistency_residual(rtab) < 1e-12


@pytest.mark.parametrize("rtab,expected", [(ROS23W, 2), (RODAS4, 4),
                                           (RODAS5P, 5)],
                         ids=lambda v: getattr(v, "name", v))
def test_rosenbrock_empirical_convergence(rtab, expected):
    # u' = lam*(u - sin t) + cos t, u(0)=0  =>  u = sin t: non-autonomous
    # (exercises the c/d data), stiff-ish lam, known solution.
    p = jnp.asarray([-5.0])

    def f(u, p_, t):
        return p_[0] * (u - jnp.sin(t)) + jnp.cos(t)

    def endpoint_err(n):
        u = jnp.asarray([0.0])
        t = jnp.asarray(0.0)
        dt = jnp.asarray(1.5 / n)
        for _ in range(n):
            u, _, _, _, _ = rosenbrock_step(f, rtab, u, p, t, dt)
            t = t + dt
        return abs(float(u[0]) - np.sin(1.5))

    errs = [endpoint_err(n) for n in (20, 40, 80)]
    slopes = [np.log2(errs[i] / errs[i + 1]) for i in range(2)]
    assert min(slopes) > expected - 0.35, (errs, slopes)


def test_rodas4_dense_output_is_third_order():
    # the stiffly-accurate interp_h weights: interpolated mid-step values
    # converge one order above cubic-accurate (O(h^4) local error)
    p = jnp.asarray([-5.0])

    def f(u, p_, t):
        return p_[0] * (u - jnp.sin(t)) + jnp.cos(t)

    def interp_err(h):
        u = jnp.asarray([np.sin(0.4)])
        t = jnp.asarray(0.4)
        u1, _, _, _, kds = rosenbrock_step(f, RODAS4, u, p, t, jnp.asarray(h))
        errs = []
        for th in (0.3, 0.5, 0.7):
            ui = (1 - th) * u + th * (u1 + (1 - th) * (kds[0] + th * kds[1]))
            errs.append(abs(float(ui[0]) - np.sin(0.4 + th * h)))
        return max(errs)

    e1, e2 = interp_err(0.2), interp_err(0.1)
    assert np.log2(e1 / e2) > 3.3, (e1, e2)


def test_registry_has_rodas_methods():
    for name, order in (("rodas4", 4), ("rodas5p", 5)):
        spec = get_method(name)
        assert spec.family == "rosenbrock" and spec.stiff
        assert spec.order == order and spec.rtableau is not None
    assert get_method("gpurodas4") is get_method("rodas4")
    assert get_method("rodas5") is get_method("rodas5p")
    assert get_method("gpurosenbrock23") is get_method("ode23s")
    # a bare RosenbrockTableau is auto-wrapped like a bare Butcher Tableau
    spec = get_method(RODAS4)
    assert spec.family == "rosenbrock" and spec.rtableau is RODAS4
    # family capability validation
    with pytest.raises(ValueError, match="rtableau"):
        from repro.core import MethodSpec
        MethodSpec(name="bad_rb", family="rosenbrock", order=3)
    # a tableau without embedded weights cannot drive the adaptive engine:
    # rejected loudly, not silently integrated with err == 0
    no_pair = RODAS4._replace(name="rodas4_nopair",
                              btilde=np.zeros_like(RODAS4.btilde))
    assert not get_method(no_pair).adaptive
    ens = rober_ensemble(2, tspan=(0.0, 1.0))
    with pytest.raises(ValueError, match="btilde"):
        solve_ensemble_local(ens, alg=no_pair, ensemble="vmap", dt0=1e-6)


# ---------------------------------------------------------------------------
# ROBER: the acceptance bar — every strategy/backend matches the jnp
# reference solve (vmap + LAPACK linsolve) to rtol 1e-6 in f64
# ---------------------------------------------------------------------------

ROBER_SAVEAT = jnp.asarray([1e-2, 1.0, 1e2, 1e4])


def _rober_solve(alg, ensemble, backend, linsolve="jnp", analytic_jac=True,
                 w_reuse=None):
    ens = rober_ensemble(3, tspan=(0.0, 1e4), analytic_jac=analytic_jac)
    return solve_ensemble_local(ens, alg=alg, ensemble=ensemble,
                                backend=backend, dt0=1e-6, rtol=1e-8,
                                atol=1e-10, saveat=ROBER_SAVEAT,
                                linsolve=linsolve, w_reuse=w_reuse)


@pytest.mark.parametrize("w_reuse", [None, True],
                         ids=["eager", "lazy-W"])
@pytest.mark.parametrize("alg", ["rodas4", "rodas5p"])
@pytest.mark.parametrize("ensemble,backend,linsolve", [
    ("vmap", "xla", "jnp"),
    ("array", "xla", "jnp"),
    ("array", "xla", "pallas"),      # batched-LU Pallas kernel launch
    ("kernel", "xla", "jnp"),
    ("kernel", "pallas", "jnp"),     # fused kernel: LU body inlined ("lanes")
])
def test_rober_cross_strategy_backend_parity(alg, ensemble, backend, linsolve,
                                             w_reuse):
    # the SAME parity bar with the lazy-W hot path on: the WReusePolicy is a
    # pure function of per-lane quantities, so reuse-on trajectories agree
    # across every strategy/backend/linsolver like reuse-off ones
    ref = _rober_solve(alg, "vmap", "xla", w_reuse=w_reuse)  # jnp reference
    res = _rober_solve(alg, ensemble, backend, linsolve, w_reuse=w_reuse)
    assert int(res.status) == 0
    for got, want in ((res.us, ref.us), (res.u_final, ref.u_final)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-14)
    # y1 + y2 + y3 is conserved by ROBER; 1e-8-tolerance solves hold it tight
    totals = np.asarray(res.u_final).sum(axis=1)
    np.testing.assert_allclose(totals, 1.0, rtol=1e-7)


# ---------------------------------------------------------------------------
# lazy-W hot path: njac/nfact accounting and the reuse win (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------

def _rober_reuse_solve(backend, w_reuse, rtol=1e-6):
    ens = rober_ensemble(4, tspan=(0.0, 1e4))
    return solve_ensemble_local(ens, alg="rosenbrock23", ensemble="kernel",
                                backend=backend, dt0=1e-6, rtol=rtol,
                                atol=rtol * 1e-2, w_reuse=w_reuse)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_w_reuse_cuts_njac_at_matched_accuracy(backend):
    """The acceptance regression: ROBER ensemble at rtol 1e-6, reuse on must
    cut njac >= 2x versus reuse off at indistinguishable accuracy — on the
    XLA lanes path AND the fused Pallas kernel (interpret on CPU)."""
    ens = rober_ensemble(4, tspan=(0.0, 1e4))
    ref = solve_ensemble_local(ens, alg="rodas5p", ensemble="vmap",
                               backend="xla", dt0=1e-6, rtol=1e-10,
                               atol=1e-12).u_final
    scale = np.abs(np.asarray(ref)) + 1e-30
    off = _rober_reuse_solve(backend, False)
    on = _rober_reuse_solve(backend, True)
    assert int(off.status) == 0 and int(on.status) == 0
    # >= 2x fewer Jacobian evaluations (measured: ~10x with the secant-update
    # policy; the bar is deliberately conservative)
    assert int(off.njac) >= 2 * int(on.njac), (int(off.njac), int(on.njac))
    # ... at indistinguishable accuracy: both solves sit at the tolerance's
    # error level, within a small factor of each other
    e_off = np.max(np.abs(np.asarray(off.u_final) - ref) / scale)
    e_on = np.max(np.abs(np.asarray(on.u_final) - ref) / scale)
    assert e_on < 10 * max(e_off, 1e-7), (e_on, e_off)
    # the reuse also wins the combined rhs+jac work metric (nf + n*njac)
    n = 3
    work_off = int(off.nf) + n * int(off.njac)
    work_on = int(on.nf) + n * int(on.njac)
    assert work_off >= 1.3 * work_on, (work_off, work_on)


def test_w_reuse_off_is_eager_every_step():
    """Reuse off must reproduce today's every-step behaviour: one Jacobian
    evaluation and one factorization per ATTEMPTED step, observable through
    the new work counters."""
    off = _rober_reuse_solve("xla", False)
    steps = int(np.sum(np.asarray(off.naccept) + np.asarray(off.nreject)))
    assert int(off.njac) == steps
    assert int(off.nfact) == steps
    # and w_reuse=False is the registered default (spec.w_reuse False)
    default = _rober_reuse_solve("xla", None)
    assert int(default.njac) == int(off.njac)
    np.testing.assert_array_equal(np.asarray(default.u_final),
                                  np.asarray(off.u_final))


def test_w_reuse_policy_knobs_and_frozen_mode():
    """A custom WReusePolicy threads through; secant=0 (frozen-J mode with
    dt-blame retries) still converges and still saves Jacobian work."""
    from repro.core import WReusePolicy
    ens = rober_ensemble(2, tspan=(0.0, 1e3))
    kw = dict(alg="rosenbrock23", ensemble="kernel", backend="xla", dt0=1e-6,
              rtol=1e-6, atol=1e-8)
    off = solve_ensemble_local(ens, w_reuse=False, **kw)
    frozen = solve_ensemble_local(
        ens, w_reuse=WReusePolicy(secant=0.0, max_age=10), **kw)
    assert int(frozen.status) == 0
    assert int(frozen.njac) < int(off.njac)
    # stats flow through vmap dispatch too (scalar-mode engine)
    on_v = solve_ensemble_local(ens, ensemble="vmap", alg="rosenbrock23",
                                backend="xla", dt0=1e-6, rtol=1e-6,
                                atol=1e-8, w_reuse=True)
    assert int(on_v.status) == 0 and int(on_v.njac) > 0
    # non-stiff families reject a truthy knob loudly ...
    from repro.configs.de_problems import rober_problem
    from repro.core import EnsembleProblem
    with pytest.raises(ValueError, match="w_reuse"):
        solve_ensemble_local(EnsembleProblem(rober_problem(), 2), alg="tsit5",
                             w_reuse=True)
    # ... but w_reuse=False stays the documented universal no-op, so generic
    # A/B sweeps can pass it to every method
    res = solve_ensemble_local(EnsembleProblem(rober_problem(), 2),
                               alg="tsit5", tf=1.0, dt0=1e-3, w_reuse=False)
    assert int(res.status) == 0


def test_rober_analytic_jac_matches_jacfwd():
    # the hook changes HOW J is computed, not its value: identical solves
    res_an = _rober_solve("rodas4", "kernel", "xla", analytic_jac=True)
    res_ad = _rober_solve("rodas4", "kernel", "xla", analytic_jac=False)
    np.testing.assert_allclose(np.asarray(res_an.u_final),
                               np.asarray(res_ad.u_final), rtol=1e-12)
    u = jnp.asarray([0.7, 2e-5, 0.3])
    p = rober_problem().p
    J_ad = jax.jacfwd(lambda uu: rober_rhs(uu, p, 0.0))(u)
    np.testing.assert_allclose(np.asarray(rober_jac(u, p, 0.0)),
                               np.asarray(J_ad), rtol=1e-15)


def test_orego_solves_on_fused_kernel():
    ens = EnsembleProblem(orego_problem(), 2)
    res = solve_ensemble_local(ens, alg="rodas5p", ensemble="kernel",
                               backend="pallas", dt0=1e-4, rtol=1e-7,
                               atol=1e-8)
    assert int(res.status) == 0
    assert np.all(np.asarray(res.u_final) > 0)        # concentrations stay +


def test_rodas_event_handling_uses_tableau_dense_output():
    # threshold crossing located on the stiffly-accurate interpolant
    from repro.core.events import Event
    prob = rober_problem(tspan=(0.0, 1e4))
    ev = Event(condition=lambda u, p, t: u[2] - 0.5, terminal=True,
               direction=1)
    res, einfo = solve_rosenbrock(prob.f, RODAS4, prob.u0, prob.p, 0.0, 1e4,
                                  1e-6, rtol=1e-8, atol=1e-10, jac=prob.jac,
                                  event=ev)
    t_star = float(einfo["event_t"])
    assert np.isfinite(t_star) and 0 < t_star < 1e4
    # the located state sits on the threshold
    assert abs(float(res.u_final[2]) - 0.5) < 1e-6


# ---------------------------------------------------------------------------
# initial_dt: the Hairer heuristic may be conservative but never 0/inf/NaN
# ---------------------------------------------------------------------------

def test_initial_dt_guard():
    prob = rober_problem()
    dt0 = initial_dt(prob.f, prob.u0, prob.p, 0.0, 1e5, 5, 1e-8, 1e-8)
    assert np.isfinite(float(dt0)) and 0 < float(dt0) <= 1e5
    # the produced step actually starts a converging Rodas solve
    res = solve_rosenbrock(prob.f, RODAS4, prob.u0, prob.p, 0.0, 1e3,
                           float(dt0), rtol=1e-6, atol=1e-8, jac=prob.jac)
    assert int(res.status) == 0

    # pathological norm ratios: huge |f|, tiny state — and the reverse
    def f_huge(u, p, t):
        return 1e300 * jnp.ones_like(u)

    def f_flat(u, p, t):
        return jnp.zeros_like(u)

    for f in (f_huge, f_flat):
        dt = initial_dt(f, jnp.asarray([1e-30, 1.0]), jnp.asarray([0.0]),
                        0.0, 10.0, 5, 1e-12, 1e-12)
        assert np.isfinite(float(dt)) and 0 < float(dt) <= 10.0, f


# ---------------------------------------------------------------------------
# pivoted batched LU: the contract the docstring promises
# ---------------------------------------------------------------------------

def _nondominant_batch():
    rng = np.random.default_rng(0)
    W_bad = np.array([[0.0, 2.0, 1.0],      # zero pivot: needs a row swap
                      [1.0, 0.0, 3.0],
                      [2.0, 1.0, 0.0]])
    W_ok = rng.normal(size=(3, 3)) + 5.0 * np.eye(3)
    W = jnp.asarray(np.stack([W_bad, W_ok]))
    b = jnp.asarray(rng.normal(size=(2, 3)))
    return W, b


def test_lu_pivoting_fixes_nondominant_systems():
    from repro.kernels.lu.kernel import lu_solve_lanes
    from repro.kernels.lu.ops import batched_solve
    from repro.kernels.lu.ref import ref_solve
    W, b = _nondominant_batch()
    ref = np.asarray(ref_solve(W, b))
    # the no-pivot kernel body fails this case (division by the zero pivot)
    x_nopiv = np.asarray(lu_solve_lanes(jnp.moveaxis(W, 0, -1), b.T,
                                        pivot=False))
    assert not np.all(np.isfinite(x_nopiv[:, 0]))
    # ... the pivoted kernel body solves it in-kernel, matching LAPACK
    x_piv = np.asarray(lu_solve_lanes(jnp.moveaxis(W, 0, -1), b.T)).T
    np.testing.assert_allclose(x_piv, ref, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(batched_solve(W, b)), ref,
                               rtol=1e-12, atol=1e-12)
    # even pivot=False is rescued at the ops layer now: the zero pivot is
    # flagged by the min-|pivot| output and routed to the jnp reference
    np.testing.assert_allclose(np.asarray(batched_solve(W, b, pivot=False)),
                               ref, rtol=1e-12, atol=1e-12)


def test_lu_singular_system_falls_back_to_jnp_reference():
    from repro.kernels.lu.kernel import lu_solve_pallas
    from repro.kernels.lu.ops import batched_solve
    from repro.kernels.lu.ref import ref_solve
    rng = np.random.default_rng(1)
    W_sing = np.array([[1.0, 2.0, 3.0],      # rank 2: elimination hits an
                       [2.0, 4.0, 6.0],      # exactly-zero pivot even after
                       [1.0, 1.0, 1.0]])     # row pivoting
    W_ok = rng.normal(size=(3, 3)) + 5.0 * np.eye(3)
    W = jnp.asarray(np.stack([W_sing, W_ok]))
    b = jnp.asarray(rng.normal(size=(2, 3)))
    # the raw kernel flags the singular lane (pivmin not > 0: zero or NaN
    # once a zero pivot poisons later rows) and emits a garbage column that
    # DIFFERS from the jnp reference (±inf vs LAPACK's all-NaN) ...
    x_raw, pivmin = lu_solve_pallas(jnp.moveaxis(W, 0, -1), b.T, lane_tile=2)
    assert not bool(pivmin[0] > 0) and bool(pivmin[1] > 0)
    assert np.any(np.isinf(np.asarray(x_raw)[:, 0]))
    x = np.asarray(batched_solve(W, b))
    ref = np.asarray(ref_solve(W, b))
    # ... so the fallback is observable: batched_solve returns the jnp
    # reference's pattern for the singular lane, not the kernel's
    np.testing.assert_array_equal(x[0], ref[0])
    assert not np.any(np.isinf(x[0]))
    # and the healthy lane is untouched by the fallback
    np.testing.assert_allclose(x[1], ref[1], rtol=1e-12)
    # the zero matrix (pivmin NaN-poisoned at step 0) is also caught: the
    # ops layer may not return the kernel's raw garbage for it
    W0 = jnp.asarray(np.stack([np.zeros((3, 3)), W_ok]))
    x0 = np.asarray(batched_solve(W0, b))
    np.testing.assert_array_equal(x0[0], np.asarray(ref_solve(W0, b))[0])
    assert not np.any(np.isinf(x0[0]))


def test_lu_auto_lane_tile_shares_vmem_formula():
    from repro.kernels.ensemble_kernel import auto_lane_tile
    from repro.kernels.lu.ops import batched_solve, lu_lane_tile
    from repro.kernels.lu.ref import ref_solve
    # same §5.2 budget formula: tiles shrink as n^2 grows, 128-multiples
    assert lu_lane_tile(64) == auto_lane_tile(
        64, 0, 0, work_words=2 * 64 * 64 + 4 * 64)
    assert lu_lane_tile(3) % 128 == 0
    assert lu_lane_tile(96) < lu_lane_tile(8)
    # lane_tile=None (the auto path) solves a non-multiple-of-128 batch
    rng = np.random.default_rng(2)
    W = jnp.asarray(rng.normal(size=(37, 4, 4)) + 6.0 * np.eye(4))
    b = jnp.asarray(rng.normal(size=(37, 4)))
    np.testing.assert_allclose(np.asarray(batched_solve(W, b)),
                               np.asarray(ref_solve(W, b)),
                               rtol=1e-10, atol=1e-12)


def test_lu_kernel_docstring_matches_contract():
    # the bug this PR fixes: kernel.py promised an ops-layer singular
    # fallback that did not exist.  Keep code and docs agreeing.
    import inspect

    from repro.kernels.lu import kernel, ops
    assert "falls back to the jnp" in inspect.getdoc(kernel)
    assert "fall back" in inspect.getdoc(ops.batched_solve).replace(
        "falls back", "fall back")
    assert "pivot" in inspect.getdoc(ops.batched_solve)


# ---------------------------------------------------------------------------
# vmap lazy-W: the any()-gated refresh must survive batching
# ---------------------------------------------------------------------------

def _count_cond_eqns(jaxpr) -> int:
    """Recursively count `cond` primitives (vmap lowers an unreduced batched
    predicate to `select_n` — the cond disappears from the jaxpr entirely)."""
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "cond":
            total += 1
        for val in eqn.params.values():
            subs = val if isinstance(val, (tuple, list)) else (val,)
            for sub in subs:
                inner = getattr(sub, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    total += _count_cond_eqns(inner)
    return total


def test_vmap_lazy_w_refresh_cond_survives_batching():
    """With `batch_axis` bound, the J/W refresh predicates are psum-reduced
    to batch scalars, so both refresh `lax.cond`s survive vmap as real
    branches; without it they are select-lowered (both branches always
    execute) and the njac savings are bookkeeping fiction."""
    prob = rober_problem()
    ep = rober_ensemble(4)
    u0s, ps = ep.materialize()

    def traced(batch_axis):
        def one(u0, p):
            return solve_rosenbrock(prob.f, RODAS4, u0, p, 0.0, 1.0, 1e-6,
                                    rtol=1e-4, atol=1e-6, jac=prob.jac,
                                    w_reuse=True, max_iters=2000,
                                    batch_axis=batch_axis).u_final
        vkw = {} if batch_axis is None else {"axis_name": batch_axis}
        return jax.make_jaxpr(jax.vmap(one, **vkw))(u0s, ps)

    assert _count_cond_eqns(traced("lanes").jaxpr) >= 2   # jac + refactor
    assert _count_cond_eqns(traced(None).jaxpr) == 0      # the old wart


def test_vmap_lazy_w_executes_fewer_jac_evals():
    """The njac counter reduction must correspond to fewer *executed*
    Jacobian applications under vmap, not just a smaller number."""
    import dataclasses

    counts = {"eager": 0, "lazy": 0}
    ens = rober_ensemble(4)
    _, ps = ens.materialize()

    def with_counting_jac(tag):
        def counting_jac(u, p, t):
            def bump(_):
                counts[tag] += 1
            jax.debug.callback(bump, t)
            return rober_jac(u, p, t)
        return EnsembleProblem(dataclasses.replace(ens.prob, jac=counting_jac),
                               4, ps=ps)

    kw = dict(alg="rodas4", ensemble="vmap", t0=0.0, tf=1.0, dt0=1e-6,
              rtol=1e-4, atol=1e-6)
    njac = {}
    for tag, wr in (("eager", False), ("lazy", True)):
        res = solve_ensemble_local(with_counting_jac(tag), w_reuse=wr, **kw)
        jax.block_until_ready(res.u_final)
        njac[tag] = int(np.max(np.asarray(res.njac)))
    jax.effects_barrier()
    assert counts["lazy"] < 0.7 * counts["eager"], counts
    assert njac["lazy"] < njac["eager"]
