"""Gradcheck suite: gradients as a dispatch capability (§6.6).

``sensitivity="adjoint"`` swaps the while-loop engines for the bounded,
checkpointed reverse-differentiable substitute; ``sensitivity="forward"``
rides jvp through the untouched hot paths.  Contracts proven here:

  * per family, `jax.grad` through `solve_ensemble_local` matches central
    finite differences (f64, rtol <= 1e-4);
  * vmap-XLA, kernel-XLA and kernel-Pallas gradients agree to ~1e-10 (the
    Pallas path forward-runs the fused kernel and reverse-replays its
    bitwise XLA twin via `jax.custom_vjp`);
  * SDE gradients are PATHWISE: the counter-RNG/Brownian-tree noise replays
    bitwise under vjp recomputation, so the GBM gradient hits the per-path
    closed form dS_T/ds0 = S_T/s0 at machine precision, sharded == local;
  * a too-small ``adjoint_steps`` bound surfaces as ``status == 1``, never a
    silently truncated gradient;
  * checkpointing demonstrably bounds the reverse-pass memory (XLA
    compiled-memory proxy).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EnsembleProblem
from repro.core.ensemble import solve_ensemble_local
from repro.core.sensitivity import (adjoint_continuous, ensemble_value_and_grad,
                                    suggest_adjoint_steps)
from repro.core.tableaus import get_tableau
from repro.configs.de_problems import (gbm_problem, lorenz_problem,
                                       vdp_problem)

STRATEGIES = [("vmap", "xla"), ("kernel", "xla"), ("kernel", "pallas")]


def lorenz_ens(N=4):
    prob = lorenz_problem(jnp.float64)
    rng = np.random.default_rng(0)
    u0s = jnp.asarray(np.array([-8.0, 7.0, 27.0])
                      + 0.1 * rng.standard_normal((N, 3)))
    ps = jnp.asarray(np.array([10.0, 28.0, 8.0 / 3.0])
                     + 0.05 * rng.standard_normal((N, 3)))
    return prob, u0s, ps


LORENZ_KW = dict(alg="tsit5", t0=0.0, tf=1.5, dt0=1e-2, rtol=1e-8, atol=1e-8,
                 saveat=jnp.linspace(0.0, 1.5, 4))


def loss_of(res):
    return jnp.sum(res.us ** 2) + jnp.sum(res.u_final ** 2)


# ---------------------------------------------------------------------------
# per-family jax.grad vs central finite differences (f64)
# ---------------------------------------------------------------------------

def test_erk_adaptive_grad_matches_fd():
    prob, u0s, ps = lorenz_ens()
    ep = EnsembleProblem(prob, u0s.shape[0], u0s=u0s, ps=ps)
    bound = suggest_adjoint_steps(ep, ensemble="vmap", **LORENZ_KW)

    def L(p):
        sub = EnsembleProblem(prob, u0s.shape[0], u0s=u0s, ps=p)
        return loss_of(solve_ensemble_local(sub, ensemble="vmap",
                                            sensitivity="adjoint",
                                            adjoint_steps=bound, **LORENZ_KW))

    g = jax.grad(L)(ps)
    eps = 1e-6
    for i, j in [(0, 0), (1, 1), (2, 2), (3, 0)]:
        d = jnp.zeros_like(ps).at[i, j].set(eps)
        fd = (L(ps + d) - L(ps - d)) / (2 * eps)
        np.testing.assert_allclose(float(g[i, j]), float(fd), rtol=1e-4)


def test_rosenbrock_grad_matches_fd():
    prob = vdp_problem()
    N = 3
    rng = np.random.default_rng(1)
    u0s = jnp.asarray(np.array([2.0, 0.0])
                      + 0.05 * rng.standard_normal((N, 2)))
    ps = jnp.asarray(np.array([5.0]) + 0.2 * rng.standard_normal((N, 1)))
    kw = dict(alg="rosenbrock23", t0=0.0, tf=3.0, dt0=1e-3, rtol=1e-7,
              atol=1e-9, saveat=jnp.linspace(0.0, 3.0, 4))
    ep = EnsembleProblem(prob, N, u0s=u0s, ps=ps)
    bound = suggest_adjoint_steps(ep, ensemble="kernel", backend="xla", **kw)

    def L(p):
        sub = EnsembleProblem(prob, N, u0s=u0s, ps=p)
        return loss_of(solve_ensemble_local(sub, ensemble="kernel",
                                            backend="xla",
                                            sensitivity="adjoint",
                                            adjoint_steps=bound, **kw))

    g = jax.grad(L)(ps)
    eps = 1e-6
    for i in range(N):
        d = jnp.zeros_like(ps).at[i, 0].set(eps)
        fd = (L(ps + d) - L(ps - d)) / (2 * eps)
        np.testing.assert_allclose(float(g[i, 0]), float(fd), rtol=1e-4)


def test_discrete_adjoint_matches_continuous_adjoint_oracle():
    """Front-door reverse AD vs the independent continuous-adjoint ODE."""
    prob = lorenz_problem(jnp.float64)
    tab = get_tableau("tsit5")
    dt, n = 0.001, 400
    loss_c, gu_c, gp_c = adjoint_continuous(
        lambda uf: jnp.sum(uf ** 2), prob.f, tab, prob.u0, prob.p, 0.0, dt, n)

    ep = EnsembleProblem(prob, 1, u0s=prob.u0[None], ps=prob.p[None])
    loss_d, (gu_d, gp_d) = ensemble_value_and_grad(
        lambda r: jnp.sum(r.u_final ** 2), ep, alg="tsit5", ensemble="vmap",
        t0=0.0, tf=dt * n, dt0=dt, rtol=1e-9, atol=1e-9,
        saveat=jnp.asarray([dt * n]), adjoint_steps=2 * n)
    np.testing.assert_allclose(float(loss_c), float(loss_d), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gp_c), np.asarray(gp_d)[0],
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(gu_c), np.asarray(gu_d)[0],
                               rtol=2e-3)


# ---------------------------------------------------------------------------
# cross-strategy / cross-backend gradient parity
# ---------------------------------------------------------------------------

def _strategy_grads(prob, u0s, ps, kw, bound):
    out = {}
    for strat, back in STRATEGIES:
        def L(u, p, strat=strat, back=back):
            sub = EnsembleProblem(prob, u0s.shape[0], u0s=u, ps=p)
            return loss_of(solve_ensemble_local(
                sub, ensemble=strat, backend=back, sensitivity="adjoint",
                adjoint_steps=bound, **kw))
        out[(strat, back)] = jax.value_and_grad(L, argnums=(0, 1))(u0s, ps)
    return out


def test_erk_grad_parity_vmap_kernel_pallas():
    prob, u0s, ps = lorenz_ens()
    ep = EnsembleProblem(prob, u0s.shape[0], u0s=u0s, ps=ps)
    bound = suggest_adjoint_steps(ep, ensemble="vmap", **LORENZ_KW)
    grads = _strategy_grads(prob, u0s, ps, LORENZ_KW, bound)
    v_ref, g_ref = grads[("vmap", "xla")]
    for key, (v, g) in grads.items():
        np.testing.assert_allclose(float(v), float(v_ref), rtol=1e-12)
        for a, b in zip(g_ref, g):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-10, atol=1e-12)


def test_rosenbrock_grad_parity_vmap_kernel_pallas():
    prob = vdp_problem()
    N = 3
    u0s = jnp.tile(jnp.asarray([2.0, 0.0]), (N, 1))
    ps = jnp.linspace(4.0, 6.0, N)[:, None]
    kw = dict(alg="rosenbrock23", t0=0.0, tf=2.0, dt0=1e-3, rtol=1e-7,
              atol=1e-9, saveat=jnp.linspace(0.0, 2.0, 3))
    ep = EnsembleProblem(prob, N, u0s=u0s, ps=ps)
    bound = suggest_adjoint_steps(ep, ensemble="kernel", backend="xla", **kw)
    grads = _strategy_grads(prob, u0s, ps, kw, bound)
    v_ref, g_ref = grads[("kernel", "xla")]
    for key, (v, g) in grads.items():
        np.testing.assert_allclose(float(v), float(v_ref), rtol=1e-12)
        for a, b in zip(g_ref, g):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-10, atol=1e-12)


# ---------------------------------------------------------------------------
# SDE pathwise gradients: bitwise noise replay, closed forms, sharding
# ---------------------------------------------------------------------------

GBM_KW = dict(alg="em", t0=0.0, tf=1.0, n_steps=128, save_every=32, seed=7)


def _gbm_ens(N, r=0.05, v=0.2):
    prob = gbm_problem(dtype=jnp.float64)
    s0 = jnp.full((N, 3), 1.0, jnp.float64)
    ps = jnp.tile(jnp.asarray([[r, v]], jnp.float64), (N, 1))
    return prob, s0, ps


def test_sde_pathwise_grad_closed_form_and_parity():
    """GBM is linear: dS_T/ds0 = S_T/s0 exactly, per path, per scheme."""
    prob, s0, ps = _gbm_ens(64)
    grads = {}
    for strat, back in STRATEGIES:
        def L(u, strat=strat, back=back):
            sub = EnsembleProblem(prob, u.shape[0], u0s=u, ps=ps)
            res = solve_ensemble_local(sub, ensemble=strat, backend=back,
                                       sensitivity="adjoint", **GBM_KW)
            return jnp.sum(res.u_final)
        grads[(strat, back)] = jax.grad(L)(s0)

    res = solve_ensemble_local(EnsembleProblem(prob, s0.shape[0], u0s=s0,
                                               ps=ps),
                               ensemble="vmap", **GBM_KW)
    exact = res.u_final / s0            # pathwise delta of the EM scheme
    for key, g in grads.items():
        np.testing.assert_allclose(np.asarray(g), np.asarray(exact),
                                   rtol=1e-12)


def test_sde_gbm_expected_delta_matches_black_scholes():
    """E[dS_T/ds0] = e^{rT} up to EM bias + MC error (the §6.8 greek)."""
    r = 0.05
    prob, s0, ps = _gbm_ens(512, r=r)
    ep = EnsembleProblem(prob, 512, u0s=s0, ps=ps)

    _, (g_u0, _) = ensemble_value_and_grad(
        lambda res: jnp.mean(res.u_final), ep, ensemble="kernel",
        backend="xla", **GBM_KW)
    delta = float(jnp.sum(g_u0))        # mean over (512 lanes x 3 components)
    np.testing.assert_allclose(delta, float(jnp.exp(r * 1.0)), rtol=0.05)


def test_sde_sharded_grad_equals_local_via_lane_offset():
    """Counter-RNG streams are global: grad(half at lane_offset) == the
    corresponding rows of grad(full) bitwise — shard-invariant gradients."""
    prob, s0, ps = _gbm_ens(8)

    def grad_slab(u0_slab, ps_slab, offset):
        def L(u):
            sub = EnsembleProblem(prob, u.shape[0], u0s=u, ps=ps_slab)
            res = solve_ensemble_local(sub, ensemble="kernel", backend="xla",
                                       sensitivity="adjoint",
                                       lane_offset=offset, **GBM_KW)
            return jnp.sum(res.u_final)
        return jax.grad(L)(u0_slab)

    g_full = grad_slab(s0, ps, 0)
    g_lo = grad_slab(s0[:4], ps[:4], 0)
    g_hi = grad_slab(s0[4:], ps[4:], 4)
    assert jnp.array_equal(jnp.concatenate([g_lo, g_hi]), g_full)


def test_sde_adaptive_pathwise_grad():
    """The virtual-Brownian-tree adaptive path is differentiable too: the
    uint32 cell-count dt quantization freezes the step sequence, noise
    replays bitwise, and GBM linearity again gives dS_T/ds0 = S_T/s0."""
    prob, s0, ps = _gbm_ens(16)
    kw = dict(alg="em", t0=0.0, tf=1.0, dt0=1e-2, adaptive=True, rtol=1e-3,
              atol=1e-4, seed=11, saveat=jnp.linspace(0.0, 1.0, 3))
    ep = EnsembleProblem(prob, 16, u0s=s0, ps=ps)
    bound = suggest_adjoint_steps(ep, ensemble="vmap", **kw)

    def L(u):
        sub = EnsembleProblem(prob, u.shape[0], u0s=u, ps=ps)
        res = solve_ensemble_local(sub, ensemble="vmap",
                                   sensitivity="adjoint",
                                   adjoint_steps=bound, **kw)
        return jnp.sum(res.u_final), res

    g, res = jax.grad(L, has_aux=True)(s0)
    assert int(jnp.max(res.status)) == 0
    np.testing.assert_allclose(np.asarray(g), np.asarray(res.u_final / s0),
                               rtol=1e-12)


# ---------------------------------------------------------------------------
# failure modes and memory bounds
# ---------------------------------------------------------------------------

def test_too_small_adjoint_steps_reports_status():
    prob, u0s, ps = lorenz_ens()
    ep = EnsembleProblem(prob, u0s.shape[0], u0s=u0s, ps=ps)
    res = solve_ensemble_local(ep, ensemble="vmap", sensitivity="adjoint",
                               adjoint_steps=8, **LORENZ_KW)
    assert int(jnp.max(res.status)) == 1


def test_checkpointing_bounds_reverse_memory():
    """XLA compiled-memory proxy: the sqrt-checkpointed adjoint's temp
    allocation must be well below the single-segment (store-everything
    inside one remat block) variant on a long fixed-dt solve."""
    prob, u0s, ps = lorenz_ens()
    n_steps = 4096

    def make_grad(every):
        def L(p):
            sub = EnsembleProblem(prob, u0s.shape[0], u0s=u0s, ps=p)
            res = solve_ensemble_local(
                sub, alg="tsit5", ensemble="kernel", backend="xla",
                t0=0.0, tf=1.0, adaptive=False, n_steps=n_steps,
                save_every=n_steps, sensitivity="adjoint",
                checkpoint_every=every)
            return jnp.sum(res.u_final ** 2)
        return jax.jit(jax.grad(L))

    def temp_bytes(fn):
        mem = fn.lower(ps).compile().memory_analysis()
        if mem is None or not hasattr(mem, "temp_size_in_bytes"):
            pytest.skip("memory_analysis unavailable on this backend")
        return mem.temp_size_in_bytes

    sqrt_ck = temp_bytes(make_grad(None))              # default: sqrt(bound)
    unrolled = temp_bytes(make_grad(n_steps + 1))      # one giant segment
    assert sqrt_ck * 4 < unrolled, (sqrt_ck, unrolled)


def test_grad_capability_validation():
    prob, u0s, ps = lorenz_ens()
    ep = EnsembleProblem(prob, u0s.shape[0], u0s=u0s, ps=ps)
    with pytest.raises(ValueError, match="array_eager"):
        solve_ensemble_local(ep, ensemble="array_eager",
                             sensitivity="adjoint", **LORENZ_KW)
    with pytest.raises(ValueError, match="[Pp]allas"):
        solve_ensemble_local(ep, ensemble="kernel", backend="pallas",
                             sensitivity="forward", **LORENZ_KW)
    with pytest.raises(ValueError, match="adjoint_steps"):
        solve_ensemble_local(ep, ensemble="vmap", sensitivity="adjoint",
                             **LORENZ_KW)
    with pytest.raises(ValueError, match="sensitivity"):
        solve_ensemble_local(ep, ensemble="vmap", sensitivity="backprop",
                             **LORENZ_KW)


def test_mesh_adjoint_grad_matches_local():
    # the mesh front door must stage the checkpointed adjoint through jit
    # (shard_map cannot eagerly evaluate jax.checkpoint's closed_call) and
    # its gradients must match the local dispatcher exactly
    from repro.core.api import solve_ensemble
    from repro.launch.mesh import make_local_mesh

    prob, u0s, ps = lorenz_ens()
    ep = EnsembleProblem(prob, u0s.shape[0], u0s=u0s, ps=ps)
    kw = dict(LORENZ_KW, ensemble="kernel", backend="xla")
    bound = suggest_adjoint_steps(ep, **kw)
    mesh = make_local_mesh()

    # eager sharded solve with sensitivity set (the closed_call trap)
    res = solve_ensemble(ep, mesh=mesh, sensitivity="adjoint",
                         adjoint_steps=bound, **kw)
    assert int(jnp.max(res.status)) == 0

    def L_mesh(p):
        sub = EnsembleProblem(prob, u0s.shape[0], u0s=u0s, ps=p)
        return loss_of(solve_ensemble(sub, mesh=mesh, sensitivity="adjoint",
                                      adjoint_steps=bound, **kw))

    def L_local(p):
        sub = EnsembleProblem(prob, u0s.shape[0], u0s=u0s, ps=p)
        return loss_of(solve_ensemble_local(sub, sensitivity="adjoint",
                                            adjoint_steps=bound, **kw))

    g_mesh = jax.grad(L_mesh)(ps)
    g_local = jax.grad(L_local)(ps)
    np.testing.assert_allclose(np.asarray(g_mesh), np.asarray(g_local),
                               rtol=1e-12, atol=0)


# ---------------------------------------------------------------------------
# example rides the front door (satellite: examples/parameter_estimation.py)
# ---------------------------------------------------------------------------

def test_parameter_estimation_example_smoke():
    import importlib.util
    import pathlib
    path = (pathlib.Path(__file__).resolve().parent.parent / "examples"
            / "parameter_estimation.py")
    spec = importlib.util.spec_from_file_location("parameter_estimation", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    data = mod.make_data()
    rhos, _ = mod.fit(jnp.asarray([14.0, 22.0]), data, iters=25, lr=0.15)
    assert np.allclose(np.asarray(rhos), mod.TRUE_RHO, atol=0.5)
