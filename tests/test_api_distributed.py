"""shard_map distribution of the ensemble axis (paper §6.3) on the local mesh."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.de_problems import lorenz_ensemble
from repro.core.api import ensemble_moments, solve_ensemble
from repro.launch.mesh import make_local_mesh


def test_distributed_equals_local():
    ep = lorenz_ensemble(64, dtype=jnp.float64)
    mesh = make_local_mesh()
    kw = dict(ensemble="kernel", adaptive=False, dt0=1e-3, t0=0.0, tf=1.0,
              save_every=1000, lane_tile=32)
    r_mesh = solve_ensemble(ep, mesh=mesh, shard_axes=("data",), **kw)
    r_local = solve_ensemble(ep, mesh=None, **kw)
    np.testing.assert_allclose(np.asarray(r_mesh.u_final),
                               np.asarray(r_local.u_final), rtol=1e-12)


def test_ensemble_moments_psum():
    mesh = make_local_mesh()
    us = jnp.arange(32.0).reshape(32, 1)
    m1, v1 = ensemble_moments(us, mesh=mesh, shard_axes=("data",))
    m0, v0 = ensemble_moments(us)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m0), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v0), rtol=1e-9)


def test_solve_ensemble_requires_divisibility():
    ep = lorenz_ensemble(7, dtype=jnp.float64)
    mesh = make_local_mesh()  # 1 device: 7 % 1 == 0 fine
    r = solve_ensemble(ep, mesh=mesh, ensemble="kernel", adaptive=False,
                       dt0=1e-3, t0=0.0, tf=1.0, save_every=1000, lane_tile=4)
    assert r.u_final.shape == (7, 3)


def test_ensemble_moments_f32_large_mean_regression():
    """Centered two-pass variance: the old one-pass `E[X2] - mean**2` form
    cancels catastrophically in f32 when mean >> std (a GBM ensemble at
    large drift) — it lost every correct digit and could even come back
    negative.  Bar: match an f64 numpy reference on the same samples."""
    from repro.configs.de_problems import gbm_problem
    from repro.core import EnsembleProblem, solve_ensemble_local

    # GBM at large drift: mean e^{r*tf} ~ 8e2, std/mean ~ v*sqrt(tf) ~ 1e-3
    prob = gbm_problem(r=6.7, v=0.001, dtype=jnp.float32)
    N = 4096
    ep = EnsembleProblem(prob, N,
                         u0s=np.full((N, 3), 1.0, np.float32),
                         ps=np.tile(np.asarray([6.7, 0.001], np.float32),
                                    (N, 1)))
    res = solve_ensemble_local(ep, alg="em", ensemble="kernel", backend="xla",
                               t0=0.0, tf=1.0, dt0=1e-2, n_steps=100,
                               save_every=100, seed=11)
    us = res.u_final                                   # (N, 1) f32, mean>>std
    ref_mean = np.asarray(us, np.float64).mean(axis=0)
    ref_var = np.asarray(us, np.float64).var(axis=0)
    assert float(ref_mean[0]) / np.sqrt(float(ref_var[0])) > 300.0

    for mesh, axes in ((None, None), (make_local_mesh(), ("data",))):
        mean, var = ensemble_moments(us, mesh=mesh, shard_axes=axes)
        assert np.all(np.asarray(var) >= 0.0)
        np.testing.assert_allclose(np.asarray(mean, np.float64), ref_mean,
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(var, np.float64), ref_var,
                                   rtol=5e-2)
