"""shard_map distribution of the ensemble axis (paper §6.3) on the local mesh."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.de_problems import lorenz_ensemble
from repro.core.api import ensemble_moments, solve_ensemble
from repro.launch.mesh import make_local_mesh


def test_distributed_equals_local():
    ep = lorenz_ensemble(64, dtype=jnp.float64)
    mesh = make_local_mesh()
    kw = dict(ensemble="kernel", adaptive=False, dt0=1e-3, t0=0.0, tf=1.0,
              save_every=1000, lane_tile=32)
    r_mesh = solve_ensemble(ep, mesh=mesh, shard_axes=("data",), **kw)
    r_local = solve_ensemble(ep, mesh=None, **kw)
    np.testing.assert_allclose(np.asarray(r_mesh.u_final),
                               np.asarray(r_local.u_final), rtol=1e-12)


def test_ensemble_moments_psum():
    mesh = make_local_mesh()
    us = jnp.arange(32.0).reshape(32, 1)
    m1, v1 = ensemble_moments(us, mesh=mesh, shard_axes=("data",))
    m0, v0 = ensemble_moments(us)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m0), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v0), rtol=1e-9)


def test_solve_ensemble_requires_divisibility():
    ep = lorenz_ensemble(7, dtype=jnp.float64)
    mesh = make_local_mesh()  # 1 device: 7 % 1 == 0 fine
    r = solve_ensemble(ep, mesh=mesh, ensemble="kernel", adaptive=False,
                       dt0=1e-3, t0=0.0, tf=1.0, save_every=1000, lane_tile=4)
    assert r.u_final.shape == (7, 3)
