"""Checkpoint round-trip, atomicity, restart-from-latest, elastic restore,
data-pipeline determinism, straggler work queue."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs.archs import get_arch
from repro.data.pipeline import DataPipeline, synth_batch
from repro.dist.fault import TrainSupervisor, WorkQueue


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones(5, jnp.int32), "d": jnp.asarray(2.5)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt_lib.save(str(tmp_path), 7, t, extra={"cursor": 42})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, extra = ckpt_lib.restore(str(tmp_path), 7, like)
    assert extra["cursor"] == 42
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    t = _tree()
    h1 = ckpt_lib.save(str(tmp_path), 10, t, async_write=True)
    h1.join()
    t2 = jax.tree.map(lambda x: x + 1, t)
    ckpt_lib.save(str(tmp_path), 20, t2)
    step, out, _ = ckpt_lib.restore_latest(str(tmp_path), t)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(t["a"]) + 1)


def test_atomic_no_partial_dirs(tmp_path):
    ckpt_lib.save(str(tmp_path), 5, _tree())
    assert ckpt_lib.available_steps(str(tmp_path)) == [5]
    # a stale tmp dir must be invisible
    os.makedirs(tmp_path / ".tmp_step_9")
    assert ckpt_lib.available_steps(str(tmp_path)) == [5]


def test_leaf_count_mismatch_rejected(tmp_path):
    ckpt_lib.save(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((3, 4))}
    try:
        ckpt_lib.restore(str(tmp_path), 1, bad)
        assert False, "should have raised"
    except AssertionError as e:
        assert "mismatch" in str(e)


def test_supervisor_restart_resumes(tmp_path):
    """Simulated failure: a new supervisor resumes from the last checkpoint."""
    sup = TrainSupervisor(str(tmp_path), save_every=2, async_save=False)
    state = {"w": jnp.zeros(3)}
    step, state, _ = sup.resume_or_init(lambda: state, state)
    assert step == 0
    for s in range(1, 5):
        state = {"w": state["w"] + 1}
        sup.maybe_save(s, state, {"cursor": s})
    # "crash" — new supervisor instance
    sup2 = TrainSupervisor(str(tmp_path), save_every=2)
    step2, state2, extra = sup2.resume_or_init(lambda: {"w": jnp.zeros(3)},
                                               state)
    assert step2 == 4 and extra["cursor"] == 4
    np.testing.assert_array_equal(np.asarray(state2["w"]), np.full(3, 4.0))


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoints are sharding-free: restore onto a different layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    t = {"w": jnp.arange(8.0)}
    ckpt_lib.save(str(tmp_path), 3, t)
    sh = {"w": NamedSharding(mesh, P("data"))}
    out, _ = ckpt_lib.restore(str(tmp_path), 3, t, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8.0))


# ---------------------------------------------------------------------------


def test_data_determinism_and_cursor():
    cfg = get_arch("internlm2-1.8b-smoke")
    b1 = synth_batch(cfg, seed=3, step=17, batch=4, seq_len=16)
    b2 = synth_batch(cfg, seed=3, step=17, batch=4, seq_len=16)
    b3 = synth_batch(cfg, seed=3, step=18, batch=4, seq_len=16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < cfg.vocab_size

    pipe = DataPipeline(cfg, batch=2, seq_len=8, seed=0, start_step=5)
    first = next(pipe)
    np.testing.assert_array_equal(
        np.asarray(first["tokens"]),
        np.asarray(synth_batch(cfg, 0, 5, 2, 8)["tokens"]))
    assert pipe.cursor() == 6
    pipe.close()


def test_supervisor_skips_step_zero(tmp_path):
    """0 % save_every == 0 used to checkpoint the untouched init state."""
    sup = TrainSupervisor(str(tmp_path), save_every=2)
    assert not sup.maybe_save(0, {"w": jnp.zeros(2)})
    assert ckpt_lib.available_steps(str(tmp_path)) == []
    assert sup.maybe_save(2, {"w": jnp.ones(2)})
    assert ckpt_lib.available_steps(str(tmp_path)) == [2]


def test_supervisor_finalize_offgrid(tmp_path):
    """Loop exit off the save_every grid still persists the final state."""
    sup = TrainSupervisor(str(tmp_path), save_every=10, async_save=True)
    state = {"w": jnp.zeros(3)}
    for s in range(1, 8):   # never hits the grid
        state = {"w": state["w"] + 1}
        assert not sup.maybe_save(s, state)
    assert sup.finalize(7, state, {"cursor": 7})
    step, out, extra = ckpt_lib.restore_latest(str(tmp_path), state)
    assert step == 7 and extra["cursor"] == 7
    np.testing.assert_array_equal(np.asarray(out["w"]), np.full(3, 7.0))
    # finalize on an already-saved grid step is a no-op (no duplicate write)
    sup2 = TrainSupervisor(str(tmp_path), save_every=7)
    sup2.maybe_save(14, state)
    assert not sup2.finalize(14, state)
    assert ckpt_lib.available_steps(str(tmp_path)) == [7, 14]


def test_work_queue_straggler_reassignment():
    q = WorkQueue(n_items=100, tile=30, timeout=0.0)  # immediate timeout
    a = q.claim()
    assert a is not None
    b = q.claim()  # timeout=0 => the same tile is reassignable immediately
    assert b[0] == a[0]
    # the straggler's token went stale the moment the tile was re-leased
    assert not q.complete(a[0], a[2])
    assert q.complete(b[0], b[2])
    c = q.claim()
    assert c[0] != a[0]
    while (nxt := q.claim()) is not None:
        q.complete(nxt[0], nxt[2])
    q.complete(c[0], c[2])
    assert q.finished


def test_work_queue_push_dynamic():
    q = WorkQueue(timeout=60.0)
    assert q.claim() is None
    i = q.push(("req", 7))
    idx, payload, tok = q.claim()
    assert idx == i and payload == ("req", 7)
    # a live lease is not reassignable before timeout
    assert q.claim() is None
    assert q.complete(idx, tok)
    assert q.finished


# ---------------------------------------------------------------------------
# crash-mid-save atomicity: SIGKILL a real writer at each stage of `save`
# ---------------------------------------------------------------------------

CRASH_SCRIPT = r"""
import sys
import jax.numpy as jnp
from repro.checkpoint import ckpt as ckpt_lib
from repro.dist.chaos import install_ckpt_write_crash

ckpt_dir, stage, mode, tear = sys.argv[1:5]
tree = {"w": jnp.arange(6.0), "s": jnp.asarray(1)}
ckpt_lib.save(ckpt_dir, 1, tree, extra={"tag": "clean"})
if stage == "pre_rename":
    # publish step 2 once, so the crash lands mid same-step OVERWRITE —
    # after the predecessor was renamed aside, before the replacement landed
    ckpt_lib.save(ckpt_dir, 2, {"w": jnp.full(6, 2.0), "s": jnp.asarray(2)},
                  extra={"tag": "first"})
install_ckpt_write_crash(stage=stage, tear_arrays=(tear == "tear"))
bad = {"w": jnp.full(6, 9.0), "s": jnp.asarray(9)}
h = ckpt_lib.save(ckpt_dir, 2, bad, extra={"tag": "doomed"},
                  async_write=(mode == "async"))
if h is not None:
    h.join()
print("SURVIVED")
"""


def _crash_save(ckpt_dir, stage, mode, tear="no"):
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-c", CRASH_SCRIPT, ckpt_dir, stage, mode, tear],
        capture_output=True, text=True, timeout=300, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _assert_previous_step_survives(ckpt_dir, out):
    assert out.returncode == -9, (out.returncode, out.stdout,
                                  out.stderr[-2000:])
    assert "SURVIVED" not in out.stdout
    assert ckpt_lib.available_steps(ckpt_dir) == [1]
    like = {"w": np.zeros(6), "s": np.asarray(0)}
    step, tree, extra = ckpt_lib.restore_latest(ckpt_dir, like)
    assert step == 1 and extra["tag"] == "clean"
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.arange(6.0))
    # the dead writer's debris is uniquely named and prunable
    ckpt_lib.prune(ckpt_dir, keep=2)
    assert all(not d.startswith((".tmp_step_", ".old_step_"))
               for d in os.listdir(ckpt_dir))
    assert ckpt_lib.available_steps(ckpt_dir) == [1]


def test_crash_mid_save_sync_modes(tmp_path):
    """SIGKILL the writer process at every save stage (sync mode): payload
    written but unpublished ("arrays"), tmp complete with a TORN arrays file
    ("meta" + tear), and mid same-step overwrite after the predecessor was
    moved aside ("pre_rename").  In every case `restore_latest` returns the
    previous COMPLETE step, bitwise intact."""
    for stage, tear in (("arrays", "no"), ("meta", "tear"),
                        ("pre_rename", "no")):
        d = str(tmp_path / f"{stage}_{tear}")
        _assert_previous_step_survives(d, _crash_save(d, stage, "sync", tear))


def test_crash_mid_save_async_mode(tmp_path):
    """Same contract in async mode: the background writer thread dies with
    the process; the host-memory snapshot it was flushing is lost, the
    previous on-disk step is not."""
    for stage in ("arrays", "pre_rename"):
        d = str(tmp_path / stage)
        _assert_previous_step_survives(d, _crash_save(d, stage, "async"))


def test_prune_keeps_newest_and_clears_debris(tmp_path):
    t = {"w": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ckpt_lib.save(str(tmp_path), s, t)
    os.makedirs(tmp_path / ".tmp_step_9_123_deadbeef")
    os.makedirs(tmp_path / ".old_step_3_cafef00d")
    ckpt_lib.prune(str(tmp_path), keep=2)
    assert ckpt_lib.available_steps(str(tmp_path)) == [3, 4]
    assert sorted(os.listdir(tmp_path)) == ["step_3", "step_4"]
    ckpt_lib.prune(str(tmp_path), keep=0)
    assert ckpt_lib.available_steps(str(tmp_path)) == []
