"""AD through solvers (§6.6): forward sens vs FD, discrete vs continuous adjoint."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_tableau, solve_fixed, solve_one
from repro.core.sensitivity import (adjoint_continuous, forward_sensitivity,
                                    grad_discrete_adjoint, solve_fixed_remat)
from repro.configs.de_problems import linear_decay_problem, lorenz_problem

TAB = get_tableau("tsit5")


def test_forward_sensitivity_vs_analytic():
    """d/dλ e^{-λ t} = -t e^{-λ t} for the decay problem."""
    prob = linear_decay_problem(lam=0.7)
    sens = forward_sensitivity(prob.f, TAB, prob.u0, prob.p, 0.0, 0.01, 200,
                               save_every=200)
    # sens: (S=1, n=1, m=1)
    t = 2.0
    want = -t * np.exp(-0.7 * t)
    np.testing.assert_allclose(float(sens[0, 0, 0]), want, rtol=1e-6)


def test_jvp_through_adaptive_solver():
    """Forward-mode works through the adaptive while_loop too."""
    prob = linear_decay_problem(lam=0.7)

    def uf(p):
        res = solve_one(prob.f, TAB, prob.u0, p, 0.0, 2.0, 0.01,
                        saveat=jnp.asarray([2.0]), rtol=1e-10, atol=1e-10)
        return res.u_final[0]

    g = jax.jacfwd(uf)(prob.p)
    np.testing.assert_allclose(float(g[0]), -2.0 * np.exp(-1.4), rtol=1e-5)


def test_discrete_adjoint_vs_finite_difference_lorenz():
    prob = lorenz_problem(jnp.float64)
    dt, n = 0.002, 250

    def loss_of_us(us):
        return jnp.sum(us[-1] ** 2)

    val, (g_u0, g_p) = grad_discrete_adjoint(loss_of_us, prob.f, TAB,
                                             prob.u0, prob.p, 0.0, dt, n,
                                             save_every=50)
    # FD check on rho (param index 1)
    eps = 1e-6

    def L(p):
        us, _ = solve_fixed_remat(prob.f, TAB, prob.u0, p, 0.0, dt, n,
                                  save_every=50)
        return float(loss_of_us(us))

    p = np.asarray(prob.p)
    fd = (L(jnp.asarray(p + np.array([0, eps, 0]))) -
          L(jnp.asarray(p - np.array([0, eps, 0])))) / (2 * eps)
    np.testing.assert_allclose(float(g_p[1]), fd, rtol=1e-4)


def test_continuous_adjoint_matches_discrete():
    prob = lorenz_problem(jnp.float64)
    dt, n = 0.001, 400

    def loss_uf(uf):
        return jnp.sum(uf ** 2)

    loss_c, gu_c, gp_c = adjoint_continuous(loss_uf, prob.f, TAB, prob.u0,
                                            prob.p, 0.0, dt, n)

    def loss_of_us(us):
        return jnp.sum(us[-1] ** 2)

    loss_d, (gu_d, gp_d) = grad_discrete_adjoint(loss_of_us, prob.f, TAB,
                                                 prob.u0, prob.p, 0.0, dt, n,
                                                 save_every=n)
    np.testing.assert_allclose(float(loss_c), float(loss_d), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(gp_c), np.asarray(gp_d), rtol=2e-3)
    np.testing.assert_allclose(np.asarray(gu_c), np.asarray(gu_d), rtol=2e-3)


def test_vmapped_gradients_gpu_parallel_param_estimation_shape():
    """The paper's minibatched-AD pattern: vmap gradients over an ensemble."""
    prob = lorenz_problem(jnp.float64)

    def loss(p):
        res = solve_fixed(prob.f, TAB, prob.u0, p, 0.0, 0.01, 50,
                          save_every=50)
        return jnp.sum(res.u_final ** 2)

    rhos = jnp.linspace(5.0, 25.0, 8)
    ps = jnp.stack([jnp.full((8,), 10.0), rhos, jnp.full((8,), 8 / 3)], axis=1)
    grads = jax.vmap(jax.grad(loss))(ps)
    assert grads.shape == (8, 3)
    assert bool(jnp.all(jnp.isfinite(grads)))
