"""AD through solvers (§6.6) — the sensitivity convenience layer, through the
unified front door: forward sensitivities vs analytic/FD oracles, forward
mode through the adaptive while_loop, and the vmapped-gradients pattern."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EnsembleProblem, get_tableau, solve_fixed, solve_one
from repro.core.sensitivity import (adjoint_continuous, ensemble_value_and_grad,
                                    forward_sensitivity, suggest_adjoint_steps)
from repro.configs.de_problems import linear_decay_problem, lorenz_problem

TAB = get_tableau("tsit5")


def decay_ensemble(lams, lam0=0.7):
    prob = linear_decay_problem(lam=lam0)
    lams = jnp.asarray(lams, jnp.float64)
    N = lams.shape[0]
    return prob, EnsembleProblem(prob, N, u0s=jnp.tile(prob.u0[None], (N, 1)),
                                 ps=lams[:, None])


def test_forward_sensitivity_vs_analytic():
    """d/dλ e^{-λ t} = -t e^{-λ t}, per trajectory, through the front door."""
    lams = [0.4, 0.7, 1.3]
    prob, ep = decay_ensemble(lams)
    t = 2.0
    sens = forward_sensitivity(ep, wrt="ps", ensemble="vmap", alg="tsit5",
                               t0=0.0, tf=t, dt0=0.01, rtol=1e-10, atol=1e-10,
                               saveat=jnp.asarray([t]))
    assert sens.shape == (3, 1, 1, 1)     # (N, S, n, k)
    for i, lam in enumerate(lams):
        want = -t * np.exp(-lam * t)
        np.testing.assert_allclose(float(sens[i, 0, 0, 0]), want, rtol=1e-6)


def test_forward_sensitivity_wrt_u0():
    """d/du0 [u0 e^{-λ t}] = e^{-λ t}."""
    prob, ep = decay_ensemble([0.7, 1.1])
    t = 1.5
    sens = forward_sensitivity(ep, wrt="u0s", ensemble="vmap", alg="tsit5",
                               t0=0.0, tf=t, dt0=0.01, rtol=1e-10, atol=1e-10,
                               saveat=jnp.asarray([t]))
    for i, lam in enumerate([0.7, 1.1]):
        np.testing.assert_allclose(float(sens[i, 0, 0, 0]),
                                   np.exp(-lam * t), rtol=1e-6)


def test_jvp_through_adaptive_solver():
    """Forward-mode works through the adaptive while_loop too."""
    prob = linear_decay_problem(lam=0.7)

    def uf(p):
        res = solve_one(prob.f, TAB, prob.u0, p, 0.0, 2.0, 0.01,
                        saveat=jnp.asarray([2.0]), rtol=1e-10, atol=1e-10)
        return res.u_final[0]

    g = jax.jacfwd(uf)(prob.p)
    np.testing.assert_allclose(float(g[0]), -2.0 * np.exp(-1.4), rtol=1e-5)


def test_adjoint_grad_vs_analytic_decay():
    """Reverse mode through the front door against the closed form:
    L = u(T)^2 has dL/dλ = -2 T u(T)^2 and dL/du0 = 2 u(T)^2 (u0 = 1)."""
    lams = [0.4, 0.9]
    prob, ep = decay_ensemble(lams)
    T = 2.0
    kw = dict(alg="tsit5", ensemble="vmap", t0=0.0, tf=T, dt0=0.01,
              rtol=1e-10, atol=1e-10, saveat=jnp.asarray([T]))
    bound = suggest_adjoint_steps(ep, **kw)
    _, (g_u0, g_p) = ensemble_value_and_grad(
        lambda r: jnp.sum(r.u_final ** 2), ep, adjoint_steps=bound, **kw)
    for i, lam in enumerate(lams):
        uT = np.exp(-lam * T)
        np.testing.assert_allclose(float(g_p[i, 0]), -2 * T * uT ** 2,
                                   rtol=1e-6)
        np.testing.assert_allclose(float(g_u0[i, 0]), 2 * uT ** 2, rtol=1e-6)


def test_continuous_adjoint_oracle_lorenz():
    """The O(1)-memory continuous adjoint agrees with front-door reverse AD
    to the discretization error (the independent-oracle contract)."""
    prob = lorenz_problem(jnp.float64)
    dt, n = 0.001, 400

    loss_c, gu_c, gp_c = adjoint_continuous(
        lambda uf: jnp.sum(uf ** 2), prob.f, TAB, prob.u0, prob.p, 0.0, dt, n)

    ep = EnsembleProblem(prob, 1, u0s=prob.u0[None], ps=prob.p[None])
    loss_d, (gu_d, gp_d) = ensemble_value_and_grad(
        lambda r: jnp.sum(r.u_final ** 2), ep, alg="tsit5", ensemble="kernel",
        backend="xla", t0=0.0, tf=dt * n, dt0=dt, adaptive=False, n_steps=n,
        save_every=n)
    np.testing.assert_allclose(float(loss_c), float(loss_d), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gp_c), np.asarray(gp_d)[0],
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(gu_c), np.asarray(gu_d)[0],
                               rtol=2e-3)


def test_vmapped_gradients_gpu_parallel_param_estimation_shape():
    """The paper's minibatched-AD pattern: vmap gradients over an ensemble."""
    prob = lorenz_problem(jnp.float64)

    def loss(p):
        res = solve_fixed(prob.f, TAB, prob.u0, p, 0.0, 0.01, 50,
                          save_every=50)
        return jnp.sum(res.u_final ** 2)

    rhos = jnp.linspace(5.0, 25.0, 8)
    ps = jnp.stack([jnp.full((8,), 10.0), rhos, jnp.full((8,), 8 / 3)], axis=1)
    grads = jax.vmap(jax.grad(loss))(ps)
    assert grads.shape == (8, 3)
    assert bool(jnp.all(jnp.isfinite(grads)))
