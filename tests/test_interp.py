"""Texture-memory analogue (§6.7): uniform-grid interpolation, both TPU modes."""
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional dev dependency: only the property tests skip
# without it — the deterministic interp contracts below always run (they
# back the repro.core.interp leg of the CI coverage gate).
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*a, **kw):
        def deco(fn):
            return pytest.mark.skip(
                reason="optional property-test dependency "
                       "(requirements-dev.txt)")(fn)
        return deco

    def settings(*a, **kw):
        return lambda fn: fn

    class st:  # noqa: N801 — mirrors the hypothesis namespace
        @staticmethod
        def lists(*a, **kw):
            return None

        @staticmethod
        def floats(*a, **kw):
            return None

from repro.core.interp import (UniformTable1D, UniformTable2D, interp1d,
                               interp2d)


def _tab1(fn, K=33, x0=-2.0, dx=0.25):
    xs = x0 + dx * jnp.arange(K)
    return UniformTable1D(fn(xs), x0, dx), xs


def test_exact_at_nodes():
    tab, xs = _tab1(jnp.sin)
    for mode in ("gather", "onehot"):
        np.testing.assert_allclose(np.asarray(interp1d(tab, xs, mode)),
                                   np.sin(np.asarray(xs)), atol=1e-12)


def test_linear_function_exact_everywhere():
    tab, _ = _tab1(lambda x: 3.0 * x - 1.0)
    q = jnp.linspace(-2.0, 6.0 - 1e-6, 57)
    for mode in ("gather", "onehot"):
        np.testing.assert_allclose(np.asarray(interp1d(tab, q, mode)),
                                   3.0 * np.asarray(q) - 1.0, atol=1e-10)


def test_clamped_boundaries():
    tab, xs = _tab1(jnp.sin)
    lo = float(interp1d(tab, jnp.asarray(-100.0)))
    hi = float(interp1d(tab, jnp.asarray(100.0)))
    np.testing.assert_allclose(lo, np.sin(-2.0), atol=1e-12)
    np.testing.assert_allclose(hi, float(jnp.sin(xs[-1])), atol=1e-12)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-10, 10), min_size=1, max_size=8))
def test_gather_equals_onehot_1d(qs):
    tab, _ = _tab1(jnp.cos, K=17, x0=-1.0, dx=0.5)
    q = jnp.asarray(qs)
    np.testing.assert_allclose(np.asarray(interp1d(tab, q, "gather")),
                               np.asarray(interp1d(tab, q, "onehot")),
                               atol=1e-12)


def test_bilinear_2d_exact_on_bilinear_fn():
    K = 9
    x0, dx, y0, dy = 0.0, 0.5, -1.0, 0.25
    xs = x0 + dx * jnp.arange(K)
    ys = y0 + dy * jnp.arange(K)
    V = 2.0 * xs[:, None] + 3.0 * ys[None, :] + 0.5 * xs[:, None] * ys[None, :]
    tab = UniformTable2D(V, x0, dx, y0, dy)
    qx = jnp.linspace(0.0, 3.99, 23)
    qy = jnp.linspace(-1.0, 0.99, 23)
    want = 2 * qx + 3 * qy + 0.5 * qx * qy
    for mode in ("gather", "onehot"):
        got = interp2d(tab, qx, qy, mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-10)


@settings(max_examples=30, deadline=None)
@given(st.floats(-5, 10), st.floats(-5, 5))
def test_gather_equals_onehot_2d(x, y):
    K = 7
    xs = jnp.arange(K) * 0.5
    V = jnp.sin(xs[:, None]) * jnp.cos(xs[None, :])
    tab = UniformTable2D(V, 0.0, 0.5, 0.0, 0.5)
    a = float(interp2d(tab, jnp.asarray(x), jnp.asarray(y), "gather"))
    b = float(interp2d(tab, jnp.asarray(x), jnp.asarray(y), "onehot"))
    np.testing.assert_allclose(a, b, atol=1e-12)


def test_cubic_exact_at_nodes():
    tab, xs = _tab1(jnp.sin)
    np.testing.assert_allclose(np.asarray(interp1d(tab, xs, "cubic")),
                               np.sin(np.asarray(xs)), atol=1e-12)


def test_cubic_reproduces_quadratics():
    """Catmull-Rom (Keys a=-1/2) is third-order: exact on polynomials up to
    degree 2 over interior cells (the 4-point stencil must not clamp)."""
    tab, _ = _tab1(lambda x: 0.5 * x * x - 2.0 * x + 1.0, K=33, x0=-2.0,
                   dx=0.25)
    # stay one full cell away from both edges so the stencil is interior
    q = jnp.linspace(-2.0 + 0.25, 6.0 - 0.5, 91)
    want = 0.5 * np.asarray(q) ** 2 - 2.0 * np.asarray(q) + 1.0
    np.testing.assert_allclose(np.asarray(interp1d(tab, q, "cubic")), want,
                               atol=1e-10)


def test_cubic_clamp_matches_linear_clamp():
    """Outside the grid every mode returns the edge node value — the clamp
    address-mode contract must not depend on the interpolation order."""
    tab, xs = _tab1(jnp.sin)
    for q in (-100.0, 100.0):
        lin = float(interp1d(tab, jnp.asarray(q), "gather"))
        cub = float(interp1d(tab, jnp.asarray(q), "cubic"))
        np.testing.assert_allclose(cub, lin, atol=1e-12)


def test_cubic_continuous_across_cells():
    """C1 continuity at knots: approaching a knot from either side agrees."""
    tab, xs = _tab1(jnp.sin, K=17, x0=0.0, dx=0.5)
    eps = 1e-9
    for k in (3, 8, 12):
        x = float(xs[k])
        lo = float(interp1d(tab, jnp.asarray(x - eps), "cubic"))
        hi = float(interp1d(tab, jnp.asarray(x + eps), "cubic"))
        np.testing.assert_allclose(lo, hi, atol=1e-7)


def test_cubic_2d_reproduces_biquadratic():
    K = 13
    x0, dx, y0, dy = 0.0, 0.5, -1.0, 0.25
    xs = x0 + dx * jnp.arange(K)
    ys = y0 + dy * jnp.arange(K)
    V = (xs[:, None] ** 2) * 0.3 + 2.0 * ys[None, :] ** 2 - xs[:, None] * \
        ys[None, :]
    tab = UniformTable2D(V, x0, dx, y0, dy)
    qx = jnp.linspace(x0 + dx, x0 + (K - 2.5) * dx, 17)
    qy = jnp.linspace(y0 + dy, y0 + (K - 2.5) * dy, 17)
    want = 0.3 * qx ** 2 + 2.0 * qy ** 2 - qx * qy
    np.testing.assert_allclose(np.asarray(interp2d(tab, qx, qy, "cubic")),
                               np.asarray(want), atol=1e-9)


def test_grad_flows_to_table_values():
    """d interp1d / d values matches central finite differences — the table
    is a pytree leaf, so jax.grad must reach it."""
    import jax
    tab, _ = _tab1(jnp.sin, K=17, x0=0.0, dx=0.5)
    q = jnp.asarray([0.3, 2.71, 7.9])

    for mode in ("gather", "onehot", "cubic"):
        def loss(vals):
            return jnp.sum(interp1d(UniformTable1D(vals, tab.x0, tab.dx), q,
                                    mode) ** 2)
        g = np.asarray(jax.grad(loss)(tab.values))
        h = 1e-6
        for i in (0, 5, 11):
            e = jnp.zeros_like(tab.values).at[i].set(h)
            fd = (float(loss(tab.values + e))
                  - float(loss(tab.values - e))) / (2 * h)
            np.testing.assert_allclose(g[i], fd, rtol=1e-5, atol=1e-9)


def test_interp_inside_ode_rhs():
    """A wind-field drag table consumed inside the RHS (the paper's use-case):
    solver integrates with a table-dependent force, both modes agree."""
    from repro.core import get_tableau, solve_fixed
    wind, _ = _tab1(lambda x: 0.1 * jnp.sin(x), K=65, x0=0.0, dx=0.25)

    def make_rhs(mode):
        def rhs(u, p, t):
            drag = interp1d(wind, u[0], mode)
            return jnp.stack([u[1], -9.8 - drag * u[1]])
        return rhs

    tab = get_tableau("tsit5")
    u0 = jnp.asarray([10.0, 0.0])
    p = jnp.zeros(1)
    ra = solve_fixed(make_rhs("gather"), tab, u0, p, 0.0, 0.01, 100,
                     save_every=100)
    rb = solve_fixed(make_rhs("onehot"), tab, u0, p, 0.0, 0.01, 100,
                     save_every=100)
    np.testing.assert_allclose(np.asarray(ra.u_final), np.asarray(rb.u_final),
                               rtol=1e-10)
