"""Dataset tables as a dispatch capability (§6.7 tentpole).

`ODEProblem.data` / `SDEProblem.data` carry a pytree of UniformTable1D/2D
leaves through EVERY dispatch path.  Contracts proven here:

  * fixed-dt parity is exact across {vmap, array, kernel} x {xla, pallas}
    for a data-driven RHS (same step sequence everywhere — only the data
    plumbing differs);
  * adaptive parity holds at the kink-limited tolerance: a piecewise-linear
    forcing is only C0 at knots, so the embedded estimator cannot see the
    local error there and ULP-level fusion differences may legitimately
    shift accept/reject decisions — paths agree to ~the true kink error,
    not to roundoff;
  * sharded == local bitwise (tables BROADCAST as replicated shard_map
    inputs, never sharded);
  * `jax.grad` w.r.t. TABLE VALUES agrees across vmap/kernel-xla/
    kernel-pallas and with central finite differences (f64, <=1e-4) —
    the forced-oscillator calibration loop of the acceptance bar;
  * SDE drift/diffusion tables replay bitwise across strategies (pathwise
    counter-RNG noise is data-independent);
  * events compose with data on every path;
  * a method declaring ``data_rhs=False`` is rejected by `valid_dispatch`
    and by the front door;
  * the autotune key grows a dataset-shape component, so data-driven and
    data-free solves of the same method never share a profile entry.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EnsembleProblem, ODEProblem, SDEProblem,
                        UniformTable1D, bind_problem_data, get_method,
                        interp1d, solve_ensemble_local, valid_dispatch)
from repro.core.events import Event
from repro.configs.de_problems import forced_oscillator_problem

ALL_PATHS = [("vmap", "xla"), ("array", "xla"),
             ("kernel", "xla"), ("kernel", "pallas")]
GRAD_PATHS = [("vmap", "xla"), ("kernel", "xla"), ("kernel", "pallas")]


def osc_ens(N=8, dtype=jnp.float64):
    prob = forced_oscillator_problem(dtype=dtype)
    u0s = jnp.stack([prob.u0] * N) * jnp.linspace(
        0.5, 1.5, N, dtype=dtype)[:, None]
    ps = jnp.stack([prob.p] * N)
    return prob, EnsembleProblem(prob, N, u0s=u0s, ps=ps)


# ---------------------------------------------------------------------------
# parity bar
# ---------------------------------------------------------------------------

def test_fixed_dt_parity_all_paths():
    _, ep = osc_ens()
    res = {}
    for strat, backend in ALL_PATHS:
        r = solve_ensemble_local(ep, alg="tsit5", ensemble=strat,
                                 backend=backend, adaptive=False, dt0=0.01,
                                 saveat=jnp.linspace(1.0, 5.0, 5))
        res[(strat, backend)] = (np.asarray(r.us), np.asarray(r.u_final))
    us0, uf0 = res[("vmap", "xla")]
    for k, (us, uf) in res.items():
        np.testing.assert_allclose(us, us0, atol=1e-12, err_msg=str(k))
        np.testing.assert_allclose(uf, uf0, atol=1e-12, err_msg=str(k))


def test_adaptive_parity_kink_limited():
    _, ep = osc_ens()
    kw = dict(alg="tsit5", saveat=jnp.linspace(0.0, 5.0, 11), dt0=1e-2,
              rtol=1e-8, atol=1e-8)
    ref = solve_ensemble_local(ep, ensemble="vmap", backend="xla", **kw)
    for strat, backend in ALL_PATHS[1:]:
        r = solve_ensemble_local(ep, ensemble=strat, backend=backend, **kw)
        np.testing.assert_allclose(np.asarray(r.u_final),
                                   np.asarray(ref.u_final), atol=2e-5,
                                   err_msg=f"{strat}/{backend}")
    # within the kernel family the two backends ARE bitwise twins
    rx = solve_ensemble_local(ep, ensemble="kernel", backend="xla", **kw)
    rp = solve_ensemble_local(ep, ensemble="kernel", backend="pallas", **kw)
    np.testing.assert_allclose(np.asarray(rp.u_final),
                               np.asarray(rx.u_final), atol=1e-12)


def test_gather_onehot_modes_agree_in_kernel():
    prob, _ = osc_ens()
    tab = prob.data["force"]
    N = 4
    u0s = jnp.stack([prob.u0] * N)
    ps = jnp.stack([prob.p] * N)
    out = {}
    for mode in ("gather", "onehot"):
        def rhs(u, p, t, data, _m=mode):
            return jnp.stack([u[1], -p[0] * u[0] - p[1] * u[1]
                              + interp1d(data["force"], t, _m)])
        pm = dataclasses.replace(prob, f=rhs)
        ep = EnsembleProblem(pm, N, u0s=u0s, ps=ps)
        r = solve_ensemble_local(ep, alg="tsit5", ensemble="kernel",
                                 backend="pallas", adaptive=False, dt0=0.01,
                                 n_steps=200, save_every=200)
        out[mode] = np.asarray(r.u_final)
    np.testing.assert_allclose(out["gather"], out["onehot"], atol=1e-12)


def test_rosenbrock_data_parity():
    def stiff_rhs(u, p, t, data):
        return jnp.stack([u[1], -p[0] * u[0] - p[1] * u[1]
                          + interp1d(data["force"], t)])
    base = forced_oscillator_problem()
    prob = dataclasses.replace(base, f=stiff_rhs,
                               p=jnp.asarray([50.0, 2.0], jnp.float64),
                               tspan=(0.0, 3.0))
    N = 6
    u0s = jnp.stack([prob.u0] * N) * jnp.linspace(0.5, 1.5, N)[:, None]
    ps = jnp.stack([prob.p] * N)
    ep = EnsembleProblem(prob, N, u0s=u0s, ps=ps)
    kw = dict(alg="rosenbrock23", saveat=jnp.linspace(0.0, 3.0, 7), dt0=1e-3,
              rtol=1e-8, atol=1e-8)
    ref = solve_ensemble_local(ep, ensemble="vmap", backend="xla", **kw)
    for strat, backend in ALL_PATHS[1:]:
        r = solve_ensemble_local(ep, ensemble=strat, backend=backend, **kw)
        np.testing.assert_allclose(np.asarray(r.u_final),
                                   np.asarray(ref.u_final), atol=2e-5,
                                   err_msg=f"{strat}/{backend}")


def test_sde_data_bitwise_parity():
    ts = np.linspace(0.0, 2.0, 33)
    rate = UniformTable1D(jnp.asarray(0.02 + 0.01 * np.sin(ts)), 0.0,
                          float(ts[1] - ts[0]))

    def drift(u, p, t, d):
        return interp1d(d["rate"], t) * u

    def diffusion(u, p, t, d):
        return p[0] * u

    prob = SDEProblem(f=drift, g=diffusion, u0=jnp.ones(1),
                      p=jnp.asarray([0.2]), tspan=(0.0, 1.0),
                      noise="diagonal", data={"rate": rate})
    N = 8
    ep = EnsembleProblem(prob, N, u0s=jnp.ones((N, 1)),
                         ps=jnp.full((N, 1), 0.2))
    kw = dict(alg="em", dt0=1e-3, n_steps=500, save_every=250, seed=7)
    ref = solve_ensemble_local(ep, ensemble="vmap", backend="xla", **kw)
    for strat, backend in ALL_PATHS[1:]:
        r = solve_ensemble_local(ep, ensemble=strat, backend=backend, **kw)
        np.testing.assert_allclose(np.asarray(r.u_final),
                                   np.asarray(ref.u_final), atol=1e-14,
                                   err_msg=f"{strat}/{backend}")
    # adaptive SDE engine sees the dataset too
    ra = solve_ensemble_local(ep, ensemble="kernel", backend="pallas",
                              alg="em", adaptive=True, dt0=1e-3,
                              saveat=jnp.linspace(0.0, 1.0, 5), rtol=1e-4,
                              atol=1e-6, seed=7)
    rv = solve_ensemble_local(ep, ensemble="vmap", backend="xla", alg="em",
                              adaptive=True, dt0=1e-3,
                              saveat=jnp.linspace(0.0, 1.0, 5), rtol=1e-4,
                              atol=1e-6, seed=7)
    np.testing.assert_allclose(np.asarray(ra.u_final),
                               np.asarray(rv.u_final), atol=1e-12)


def test_events_compose_with_data():
    def rhs(u, p, t, data):
        return jnp.stack([u[1], -p[0] * u[0] + interp1d(data["force"], t)])
    base = forced_oscillator_problem()
    prob = dataclasses.replace(base, f=rhs, u0=jnp.asarray([0.0, 2.0]),
                               p=jnp.asarray([1.0, 0.0]))
    N = 4
    u0s = jnp.stack([prob.u0] * N) * jnp.linspace(0.8, 1.2, N)[:, None]
    ps = jnp.stack([prob.p] * N)
    ep = EnsembleProblem(prob, N, u0s=u0s, ps=ps)
    ev = Event(condition=lambda u, p, t: u[0] - 1.5, direction=1,
               terminal=True)
    kw = dict(alg="tsit5", saveat=jnp.linspace(0.0, 5.0, 6), dt0=1e-2,
              rtol=1e-8, atol=1e-8, event=ev)
    ref = solve_ensemble_local(ep, ensemble="vmap", backend="xla", **kw)
    for strat, backend in (("kernel", "xla"), ("kernel", "pallas")):
        r = solve_ensemble_local(ep, ensemble=strat, backend=backend, **kw)
        np.testing.assert_allclose(np.asarray(r.t_final),
                                   np.asarray(ref.t_final), atol=1e-9,
                                   err_msg=f"{strat}/{backend}")


# ---------------------------------------------------------------------------
# sharded == local
# ---------------------------------------------------------------------------

def test_sharded_equals_local_with_data():
    from jax.sharding import Mesh
    from repro.core.api import solve_ensemble
    _, ep = osc_ens()
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    kw = dict(alg="tsit5", saveat=jnp.linspace(0.0, 5.0, 6), dt0=1e-2,
              rtol=1e-7, atol=1e-7, ensemble="kernel", backend="pallas")
    rl = solve_ensemble_local(ep, **kw)
    rm = solve_ensemble(ep, mesh=mesh, **kw)
    np.testing.assert_array_equal(np.asarray(rl.u_final),
                                  np.asarray(rm.u_final))
    np.testing.assert_array_equal(np.asarray(rl.us), np.asarray(rm.us))


# ---------------------------------------------------------------------------
# gradients reach table values (the calibration acceptance bar)
# ---------------------------------------------------------------------------

def test_grad_wrt_table_values_matches_fd_all_paths():
    prob, ep = osc_ens()
    tab = prob.data["force"]
    N = ep.n_trajectories
    u0s, ps = ep.materialize()
    kw = dict(alg="tsit5", adaptive=False, dt0=0.01,
              saveat=jnp.linspace(1.0, 5.0, 5))

    def L(vals, ensemble, backend):
        p2 = dataclasses.replace(
            prob, data={"force": UniformTable1D(vals, tab.x0, tab.dx)})
        ep2 = EnsembleProblem(p2, N, u0s=u0s, ps=ps)
        r = solve_ensemble_local(ep2, ensemble=ensemble, backend=backend,
                                 sensitivity="adjoint", adjoint_steps=520,
                                 **kw)
        return jnp.sum(r.u_final ** 2) + jnp.sum(r.us ** 2)

    v0 = tab.values
    grads = {sb: np.asarray(jax.grad(lambda v: L(v, *sb))(v0))
             for sb in GRAD_PATHS}
    g0 = grads[("vmap", "xla")]
    for sb, g in grads.items():
        np.testing.assert_allclose(g, g0, atol=1e-10, err_msg=str(sb))

    # central FD on both required backends (f64, rel <= 1e-4)
    h = 1e-6
    for backend in ("xla", "pallas"):
        sb = ("vmap", "xla") if backend == "xla" else ("kernel", "pallas")
        g = grads[sb]
        for i in (int(np.argmax(np.abs(g))), 5, 20):
            e = jnp.zeros_like(v0).at[i].set(h)
            fd = (float(L(v0 + e, *sb)) - float(L(v0 - e, *sb))) / (2 * h)
            np.testing.assert_allclose(float(g[i]), fd, rtol=1e-4,
                                       err_msg=f"{sb} i={i}")


# ---------------------------------------------------------------------------
# capability flag + autotune key
# ---------------------------------------------------------------------------

def test_valid_dispatch_rejects_data_incapable_method():
    spec = get_method("tsit5")
    assert valid_dispatch(spec, "vmap", "xla", data=True)[0]
    nodata = dataclasses.replace(spec, name="nodata", data_rhs=False)
    ok, why = valid_dispatch(nodata, "vmap", "xla", data=True)
    assert not ok and "data_rhs" in why
    # without data the same method stays dispatchable
    assert valid_dispatch(nodata, "vmap", "xla", data=False)[0]


def test_front_door_rejects_data_incapable_method():
    prob, ep = osc_ens(N=2)
    spec = dataclasses.replace(get_method("tsit5"), name="nodata_tsit5",
                               data_rhs=False)
    with pytest.raises(ValueError, match="data_rhs"):
        solve_ensemble_local(ep, alg=spec, ensemble="vmap",
                             saveat=jnp.asarray([5.0]), dt0=1e-2)


def test_bind_problem_data_closes_over_tables():
    prob, _ = osc_ens(N=2)
    bound = bind_problem_data(prob)
    assert bound.data is None
    u = jnp.asarray([1.0, 0.0])
    want = prob.f(u, prob.p, 0.37, prob.data)
    np.testing.assert_allclose(np.asarray(bound.f(u, prob.p, 0.37)),
                               np.asarray(want), atol=0)


def test_autotune_key_has_data_component():
    from repro.core.autotune import config_key
    from repro.core.interp import data_signature
    prob, _ = osc_ens(N=2)
    spec = get_method("tsit5")
    kw = dict(n=2, N=8, dtype=jnp.float64, adaptive=True, events=False,
              w_reuse=False, error_est="none")
    k_free = config_key(spec, **kw)
    k_data = config_key(spec, data_sig=data_signature(prob.data), **kw)
    assert "data=none" in k_free
    assert "data=" in k_data and k_free != k_data
    # signature tracks shape AND dtype, so retuning triggers on either
    assert data_signature(prob.data) != "none"


def test_resolve_auto_key_distinguishes_data(tmp_path):
    from repro.core.autotune import clear_memory_cache, resolve_auto
    prob, ep = osc_ens(N=4)
    clear_memory_cache()
    cache = str(tmp_path / "tune.json")
    spec = get_method("tsit5")
    dec_data = resolve_auto(ep, spec, dt0=1e-2,
                            saveat=jnp.linspace(0.0, 5.0, 6),
                            cache_path=cache, repeats=1)
    free = EnsembleProblem(
        dataclasses.replace(bind_problem_data(prob), name="free"),
        4, u0s=ep.materialize()[0], ps=ep.materialize()[1])
    dec_free = resolve_auto(free, spec, dt0=1e-2,
                            saveat=jnp.linspace(0.0, 5.0, 6),
                            cache_path=cache, repeats=1)
    assert dec_data.key != dec_free.key
    assert "data=" in dec_data.key
