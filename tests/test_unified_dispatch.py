"""Cross-strategy parity through the unified front door (the tentpole claim):
one stiff problem (rosenbrock23) and one SDE problem (em) each solved via
vmap, kernel/xla and kernel/pallas (interpret mode), trajectories agreeing to
tolerance. Plus the routing bugfixes: events reach the Pallas ERK kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EnsembleProblem, solve_ensemble_local
from repro.configs.de_problems import (bouncing_ball_event,
                                       bouncing_ball_problem, gbm_problem,
                                       vdp_ensemble)

# ---------------------------------------------------------------------------
# stiff: rosenbrock23 (batched-LU W = I - γh·J inside every path)
# ---------------------------------------------------------------------------

SAVEAT = jnp.linspace(0.25, 1.0, 4)
RB_KW = dict(alg="rosenbrock23", t0=0.0, tf=1.0, dt0=1e-3, saveat=SAVEAT,
             rtol=1e-6, atol=1e-6)


@pytest.fixture(scope="module")
def stiff_ens():
    return vdp_ensemble(11, mu_range=(5.0, 20.0), dtype=jnp.float64)


def test_rosenbrock_vmap_vs_kernel_xla(stiff_ens):
    rv = solve_ensemble_local(stiff_ens, ensemble="vmap", **RB_KW)
    rx = solve_ensemble_local(stiff_ens, ensemble="kernel", backend="xla",
                              lane_tile=4, **RB_KW)
    assert int(rx.status) == 0
    np.testing.assert_allclose(np.asarray(rv.us), np.asarray(rx.us),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_array_equal(np.asarray(rv.naccept),
                                  np.asarray(rx.naccept))


def test_rosenbrock_kernel_pallas_vs_xla(stiff_ens):
    """Acceptance: alg="rosenbrock23", ensemble="kernel", backend="pallas"
    through the front door matches the XLA oracle to <= 1e-5."""
    rx = solve_ensemble_local(stiff_ens, ensemble="kernel", backend="xla",
                              lane_tile=4, **RB_KW)
    rp = solve_ensemble_local(stiff_ens, ensemble="kernel", backend="pallas",
                              lane_tile=4, **RB_KW)
    assert int(rp.status) == 0
    np.testing.assert_allclose(np.asarray(rp.us), np.asarray(rx.us),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(rp.u_final), np.asarray(rx.u_final),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(rp.naccept),
                                  np.asarray(rx.naccept))


def test_rosenbrock_pallas_ragged_and_tile_sweep(stiff_ens):
    rv = solve_ensemble_local(stiff_ens, ensemble="vmap", **RB_KW)
    for tile in (2, 8):  # 11 % 2 != 0 and tile > remainder
        rp = solve_ensemble_local(stiff_ens, ensemble="kernel",
                                  backend="pallas", lane_tile=tile, **RB_KW)
        assert rp.us.shape == (11, len(SAVEAT), 2)
        np.testing.assert_allclose(np.asarray(rp.us), np.asarray(rv.us),
                                   rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# SDE: em — the SAME (seed; step, row, lane) Threefry stream on every path
# ---------------------------------------------------------------------------

SDE_KW = dict(alg="em", t0=0.0, tf=1.0, dt0=0.025, save_every=8, seed=11)


@pytest.fixture(scope="module")
def sde_ens():
    return EnsembleProblem(gbm_problem(r=1.5, v=0.2, dtype=jnp.float64), 10)


def test_sde_vmap_vs_kernel_xla_pathwise(sde_ens):
    rv = solve_ensemble_local(sde_ens, ensemble="vmap", **SDE_KW)
    rx = solve_ensemble_local(sde_ens, ensemble="kernel", backend="xla",
                              **SDE_KW)
    np.testing.assert_allclose(np.asarray(rv.us), np.asarray(rx.us),
                               rtol=1e-12)
    np.testing.assert_allclose(np.asarray(rv.ts), np.asarray(rx.ts))


def test_sde_kernel_pallas_vs_xla_pathwise(sde_ens):
    """Acceptance: alg="em", ensemble="kernel", backend="pallas" through the
    front door matches the XLA oracle to <= 1e-5 (bitwise, in fact: same
    counter stream)."""
    rx = solve_ensemble_local(sde_ens, ensemble="kernel", backend="xla",
                              **SDE_KW)
    rp = solve_ensemble_local(sde_ens, ensemble="kernel", backend="pallas",
                              lane_tile=4, **SDE_KW)
    np.testing.assert_allclose(np.asarray(rp.us), np.asarray(rx.us),
                               rtol=1e-12)
    np.testing.assert_allclose(np.asarray(rp.u_final), np.asarray(rx.u_final),
                               rtol=1e-12)


def test_sde_noise_table_parity_all_three(sde_ens):
    """Injected common noise table => all three strategies integrate the SAME
    paths, independent of RNG plumbing."""
    n_steps, m, N = 40, 3, 10
    Z = jax.random.normal(jax.random.PRNGKey(2), (n_steps, m, N), jnp.float64)
    kw = dict(alg="em", t0=0.0, tf=1.0, dt0=0.025, save_every=8,
              noise_table=Z)
    rv = solve_ensemble_local(sde_ens, ensemble="vmap", **kw)
    rx = solve_ensemble_local(sde_ens, ensemble="kernel", backend="xla", **kw)
    rp = solve_ensemble_local(sde_ens, ensemble="kernel", backend="pallas",
                              lane_tile=4, **kw)
    np.testing.assert_allclose(np.asarray(rv.us), np.asarray(rx.us),
                               rtol=1e-12)
    np.testing.assert_allclose(np.asarray(rp.us), np.asarray(rx.us),
                               rtol=1e-12)
    # and the table is actually used: closed-form EM product for GBM
    X = np.broadcast_to(np.asarray(sde_ens.prob.u0), (N, 3)).copy()
    dt = 0.025
    for k in range(n_steps):
        X = X * (1 + 1.5 * dt + 0.2 * np.sqrt(dt) * np.asarray(Z[k]).T)
    np.testing.assert_allclose(np.asarray(rp.u_final), X, rtol=1e-12)


def test_sde_unified_result_statistics(sde_ens):
    res = solve_ensemble_local(sde_ens, ensemble="kernel", backend="pallas",
                               **SDE_KW)
    assert int(res.status) == 0
    assert int(res.nf) == 40 * 10          # em: 1 drift eval/step/trajectory
    np.testing.assert_allclose(np.asarray(res.t_final), 1.0)


# ---------------------------------------------------------------------------
# routing bugfixes: events + fixed-step reach the Pallas ERK kernel
# ---------------------------------------------------------------------------

def test_event_routed_through_pallas_kernel():
    """Events used to be silently dropped on backend="pallas"."""
    prob = bouncing_ball_problem(e=0.9, dtype=jnp.float64)
    ens = EnsembleProblem(prob, 5)
    kw = dict(alg="tsit5", t0=0.0, tf=2.0, dt0=1e-3,
              saveat=jnp.linspace(0.5, 2.0, 4), rtol=1e-7, atol=1e-7,
              event=bouncing_ball_event())
    rx = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                              lane_tile=5, **kw)
    rp = solve_ensemble_local(ens, ensemble="kernel", backend="pallas",
                              lane_tile=5, **kw)
    # the ball must have bounced (x stays above the floor, velocity flipped)
    assert float(jnp.min(rp.us[:, :, 0])) > -1e-6
    np.testing.assert_allclose(np.asarray(rp.us), np.asarray(rx.us),
                               rtol=1e-9, atol=1e-9)


def test_fixed_step_routed_through_pallas_kernel():
    from repro.configs.de_problems import lorenz_ensemble
    ens = lorenz_ensemble(8, dtype=jnp.float64)
    rp = solve_ensemble_local(ens, alg="tsit5", ensemble="kernel",
                              backend="pallas", adaptive=False, t0=0.0,
                              tf=1.0, dt0=1e-2, save_every=50, lane_tile=4)
    rx = solve_ensemble_local(ens, alg="tsit5", ensemble="kernel",
                              backend="xla", adaptive=False, t0=0.0, tf=1.0,
                              dt0=1e-2, save_every=50)
    assert rp.us.shape == rx.us.shape == (8, 2, 3)
    np.testing.assert_allclose(np.asarray(rp.u_final), np.asarray(rx.u_final),
                               rtol=1e-9, atol=1e-9)
