"""Trainer/optimizer behaviour: loss decreases, accumulation equivalence,
schedule sanity — on a tiny CPU model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import get_arch
from repro.data.pipeline import synth_batch
from repro.models.model import build_model
from repro.optim.adamw import AdamW, cosine_schedule, global_norm
from repro.train.trainer import make_train_step, pick_accum


def _setup(accum=1, lr=1e-3):
    cfg = get_arch("internlm2-1.8b-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = AdamW(lr=lr, weight_decay=0.0)
    plan = make_train_step(model, opt, mesh=None, accum=accum, donate=False)
    opt_state = opt.init(params)
    return cfg, model, params, opt, opt_state, plan


def test_loss_decreases_over_steps():
    cfg, model, params, opt, opt_state, plan = _setup()
    losses = []
    for s in range(8):
        batch = synth_batch(cfg, seed=0, step=s % 2, batch=4, seq_len=32)
        params, opt_state, m = plan.step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_grad_accum_equivalence():
    """accum=2 over batch 8 == accum=1 over the same batch 8 (same update)."""
    cfg, model, params, opt, opt_state, plan1 = _setup(accum=1)
    _, _, _, _, _, plan2 = _setup(accum=2)
    batch = synth_batch(cfg, seed=1, step=0, batch=8, seq_len=32)
    p1, o1, m1 = plan1.step_fn(params, opt_state, batch)
    p2, o2, m2 = plan2.step_fn(params, opt_state, batch)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    # f32 reduction-order noise through AdamW rsqrt => ~1e-5 tolerance
    assert d < 1e-4, f"accum changed the update by {d}"


def test_adamw_against_manual_step():
    opt = AdamW(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                clip_norm=0.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st = opt.init(p)
    newp, st, _ = opt.update(g, st, p)
    # bias-corrected first step: delta = g/(|g|+eps) => p - lr*sign-ish
    want = 1.0 - 0.1 * (0.5 / (0.5 + 1e-8))
    np.testing.assert_allclose(float(newp["w"][0]), want, rtol=1e-5)


def test_clip_norm_applies():
    opt = AdamW(lr=0.0, clip_norm=1.0, weight_decay=0.0)
    p = {"w": jnp.ones(4)}
    g = {"w": jnp.full(4, 100.0)}
    st = opt.init(p)
    _, _, m = opt.update(g, st, p)
    assert float(m["grad_norm"]) > 1.0  # reported norm is pre-clip


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110, floor_frac=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert 0.09 < float(lr(jnp.asarray(110))) < 0.12
    assert float(lr(jnp.asarray(60))) < 1.0


def test_pick_accum_scales_with_size():
    cfg_big = get_arch("grok-1-314b")
    cfg_small = get_arch("internlm2-1.8b")
    assert pick_accum(cfg_big, 16, 4096) > pick_accum(cfg_small, 16, 4096)
    assert pick_accum(cfg_small, 1, 128) == 1
