"""Flash-attention Pallas kernel vs dense oracle: shapes/dtypes/GQA/block sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flashattn.ops import flash_attention
from repro.kernels.flashattn.ref import ref_attention


def _mk(B, T, S, H, KV, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("T,S,bq,bk", [(64, 64, 16, 16), (64, 64, 32, 16),
                                       (48, 48, 16, 16), (128, 128, 64, 32)])
def test_flash_matches_dense_causal(T, S, bq, bk):
    q, k, v = _mk(2, T, S, 4, 2, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("H,KV", [(4, 4), (4, 1), (8, 2)])
def test_flash_gqa_mappings(H, KV):
    q, k, v = _mk(1, 32, 32, H, KV, 16, jnp.float32, seed=1)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    want = ref_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    q, k, v = _mk(1, 32, 32, 2, 2, 16, jnp.float32, seed=2)
    out = flash_attention(q, k, v, causal=False, block_q=16, block_k=16)
    want = ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_f64():
    q, k, v = _mk(1, 32, 32, 2, 1, 16, jnp.float64, seed=3)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    want = ref_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_flash_ragged_T_padding():
    q, k, v = _mk(1, 40, 40, 2, 2, 16, jnp.float64, seed=4)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    want = ref_attention(q, k, v)
    assert out.shape == want.shape == (1, 40, 2, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
