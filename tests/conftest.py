import jax

# Tests validate numerics against f64 references; smoke tests and benches must
# see exactly ONE device (dry-run sets XLA_FLAGS itself, in its own process).
jax.config.update("jax_enable_x64", True)
