"""Component-level model tests: SSD vs naive recurrence oracle, RG-LRU vs
naive scan, MoE routing conservation, attention causality (property)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional property-test dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models.ssm import ssd_chunked
from repro.models.rglru import rglru_train, rglru_decode, rglru_params
from repro.models.moe import moe_ffn, moe_params
from repro.models.layers import attention_train, attn_params


def naive_ssd(xh, dt, B_in, C_in, A, h0=None):
    """Per-step recurrence oracle (f64): h' = exp(dt A) h + dt x⊗B; y = C·h."""
    Bsz, T, H, P = xh.shape
    N = B_in.shape[-1]
    h = np.zeros((Bsz, H, P, N)) if h0 is None else np.asarray(h0, np.float64)
    ys = np.zeros((Bsz, T, H, P))
    xh, dt, B_in, C_in, A = map(lambda a: np.asarray(a, np.float64),
                                (xh, dt, B_in, C_in, A))
    for t in range(T):
        dA = np.exp(dt[:, t] * A)                           # (B,H)
        h = h * dA[..., None, None] + np.einsum(
            "bh,bhp,bn->bhpn", dt[:, t], xh[:, t], B_in[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", C_in[:, t], h)
    return ys, h


@pytest.mark.parametrize("T,chunk", [(8, 4), (16, 8), (12, 12), (16, 4)])
def test_ssd_chunked_matches_naive(T, chunk):
    key = jax.random.PRNGKey(0)
    Bsz, H, P, N = 2, 3, 4, 5
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (Bsz, T, H, P), jnp.float64)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, T, H), jnp.float64))
    B_in = jax.random.normal(ks[2], (Bsz, T, N), jnp.float64)
    C_in = jax.random.normal(ks[3], (Bsz, T, N), jnp.float64)
    A = -jnp.exp(jnp.linspace(-1.0, 0.5, H))
    y, h = ssd_chunked(xh, dt, B_in, C_in, A, chunk)
    y_ref, h_ref = naive_ssd(xh, dt, B_in, C_in, A)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-9, atol=1e-9)


def test_ssd_carried_state_prefill_decode_split():
    """Integrating [0,T) then [T,2T) with carried state == one [0,2T) pass."""
    key = jax.random.PRNGKey(1)
    Bsz, T, H, P, N, chunk = 2, 8, 2, 4, 3, 4
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (Bsz, 2 * T, H, P), jnp.float64)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, 2 * T, H),
                                           jnp.float64))
    B_in = jax.random.normal(ks[2], (Bsz, 2 * T, N), jnp.float64)
    C_in = jax.random.normal(ks[3], (Bsz, 2 * T, N), jnp.float64)
    A = -jnp.exp(jnp.linspace(-1.0, 0.0, H))
    y_full, h_full = ssd_chunked(xh, dt, B_in, C_in, A, chunk)
    y1, h1 = ssd_chunked(xh[:, :T], dt[:, :T], B_in[:, :T], C_in[:, :T], A,
                         chunk)
    y2, h2 = ssd_chunked(xh[:, T:], dt[:, T:], B_in[:, T:], C_in[:, T:], A,
                         chunk, h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, T:]), np.asarray(y2),
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               rtol=1e-9, atol=1e-9)


def test_rglru_train_decode_agree():
    """Recurrent training scan == step-by-step decode."""
    key = jax.random.PRNGKey(2)
    D, W, K, B, T = 8, 8, 4, 2, 6
    p = rglru_params(key, D, W, K, jnp.float64)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, T, D), jnp.float64)
    y_train, st = rglru_train(x, p)
    state = {"h": jnp.zeros((B, W), jnp.float64),
             "conv": jnp.zeros((B, K - 1, W), jnp.float64)}
    ys = []
    for t in range(T):
        y, state = rglru_decode(x[:, t:t + 1], p, state)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    # associative_scan reassociates the recurrence: f32-rounded gate inputs
    # give ~1e-7 differences even under f64 math
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(state["h"]),
                               rtol=1e-5, atol=1e-6)


def test_moe_combine_weights_sum():
    """With no capacity drops, each token's combine weights sum to 1 and the
    output is a convex combination of expert outputs (checked via linearity:
    identical experts => MoE == plain FFN)."""
    key = jax.random.PRNGKey(3)
    D, F, E, k = 8, 16, 4, 2
    p = moe_params(key, D, F, E, 0, jnp.float64)
    # make all experts identical
    for nm in ("wi", "wg", "wo"):
        p[nm] = jnp.broadcast_to(p[nm][0:1], p[nm].shape)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, D), jnp.float64)
    y, aux = moe_ffn(x, p, topk=k, n_experts=E, capacity_factor=None,
                     group_size=16)
    # plain FFN with expert-0 weights
    ref = (jax.nn.silu(x @ p["wg"][0]) * (x @ p["wi"][0])) @ p["wo"][0]
    # router/dispatch weights are f32 by design => ~1e-7 tolerance
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                               atol=1e-6)
    assert float(aux) > 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), t_cut=st.integers(1, 7))
def test_attention_causality_property(seed, t_cut):
    """Changing tokens at positions > t_cut must not change outputs <= t_cut."""
    key = jax.random.PRNGKey(seed)
    B, T, D, H, KV, hd = 1, 8, 16, 4, 2, 4
    w = attn_params(key, D, H, KV, hd, jnp.float64)
    x1 = jax.random.normal(jax.random.fold_in(key, 1), (B, T, D), jnp.float64)
    x2 = x1.at[:, t_cut:].set(
        jax.random.normal(jax.random.fold_in(key, 2), (B, T - t_cut, D),
                          jnp.float64))
    kw = dict(n_heads=H, n_kv=KV, hd=hd, rope_theta=1e4)
    y1 = attention_train(x1, w, **kw)
    y2 = attention_train(x2, w, **kw)
    np.testing.assert_allclose(np.asarray(y1[:, :t_cut]),
                               np.asarray(y2[:, :t_cut]), rtol=1e-9,
                               atol=1e-9)


def test_sliding_window_restricts_reach():
    """With window w, output at position t is unaffected by tokens < t - w."""
    key = jax.random.PRNGKey(5)
    B, T, D, H, KV, hd, w_sz = 1, 12, 16, 2, 1, 8, 4
    w = attn_params(key, D, H, KV, hd, jnp.float64)
    x1 = jax.random.normal(jax.random.fold_in(key, 1), (B, T, D), jnp.float64)
    # perturb position 0; outputs at t >= 0 + window must be unchanged
    x2 = x1.at[:, 0].set(jax.random.normal(jax.random.fold_in(key, 2), (B, D),
                                           jnp.float64))
    kw = dict(n_heads=H, n_kv=KV, hd=hd, rope_theta=1e4, window=w_sz,
              is_global=False)
    y1 = attention_train(x1, w, **kw)
    y2 = attention_train(x2, w, **kw)
    np.testing.assert_allclose(np.asarray(y1[:, w_sz:]),
                               np.asarray(y2[:, w_sz:]), rtol=1e-9, atol=1e-9)
    # and position 1 IS affected (sanity that the perturbation propagates)
    assert not np.allclose(np.asarray(y1[:, 1]), np.asarray(y2[:, 1]))

def test_q_chunked_attention_equals_dense():
    """Memory-efficient (q-chunked) attention == dense attention exactly,
    across causal/window/gemma-flag combinations."""
    key = jax.random.PRNGKey(7)
    B, T, D, H, KV, hd = 2, 32, 16, 4, 2, 4
    w = attn_params(key, D, H, KV, hd, jnp.float64)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, T, D), jnp.float64)
    for kw in (dict(), dict(window=8, is_global=False),
               dict(window=8, is_global=True), dict(softcap=30.0),
               dict(causal=False)):
        base = dict(n_heads=H, n_kv=KV, hd=hd, rope_theta=1e4, **kw)
        y_dense = attention_train(x, w, **base)
        y_chunk = attention_train(x, w, q_chunk=8, **base)
        np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_chunk),
                                   rtol=1e-12, atol=1e-12)
