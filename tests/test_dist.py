"""Distributed-optimization helpers: compression, bucketing, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.archs import ARCHS
from repro.dist.collectives import (EFState, _quant_int8, bucketize, ef_init)
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.models.sharding import cache_specs, param_specs
from repro.configs.archs import get_arch


def test_int8_quant_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, scale = _quant_int8(x)
    err = jnp.abs(q.astype(jnp.float32) * scale - x)
    assert float(jnp.max(err)) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    """EF property: sum of dequantized updates converges to sum of true
    gradients (bias is carried, not lost)."""
    key = jax.random.PRNGKey(1)
    g = jax.random.normal(key, (256,)) * 0.01
    r = jnp.zeros(256)
    total_sent = jnp.zeros(256)
    for _ in range(50):
        x = g + r
        q, s = _quant_int8(x)
        deq = q.astype(jnp.float32) * s
        r = x - deq
        total_sent = total_sent + deq
    true_total = 50 * g
    rel = float(jnp.linalg.norm(total_sent - true_total)
                / jnp.linalg.norm(true_total))
    assert rel < 0.05, rel


def test_bucketize_roundtrip():
    tree = {"a": jnp.arange(10.0).reshape(2, 5),
            "b": jnp.arange(7.0), "c": {"d": jnp.ones((3, 3))}}
    buckets, unpack = bucketize(tree, bucket_bytes=40)
    assert len(buckets) > 1
    out = unpack(buckets)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# sharding-rule shape discipline for every architecture
# ---------------------------------------------------------------------------


def test_param_specs_rank_match_all_archs():
    for name in ARCHS:
        cfg = get_arch(name + "-smoke")
        model = build_model(cfg, dtype=jnp.float32)
        ap = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        specs = param_specs(ap, cfg)
        flat_p = jax.tree.leaves(ap)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for p, s in zip(flat_p, flat_s):
            assert len(s) <= len(p.shape), (name, p.shape, s)


def test_param_specs_shard_the_big_dims():
    cfg = get_arch("qwen2.5-32b")
    model = build_model(cfg, dtype=jnp.bfloat16)
    ap = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    specs = param_specs(ap, cfg)
    # embeddings vocab-sharded
    assert specs["embed"] == P("model", None)
    # attn out projection contracts the sharded feature dim
    assert specs["blocks"]["attn"]["wo"] == P(None, "model", None)
    # mlp F dims sharded
    assert specs["blocks"]["mlp"]["wi"][-1] == "model"
    assert specs["blocks"]["mlp"]["wo"][-2] == "model"


def test_param_specs_fsdp_axis_added():
    cfg = get_arch("grok-1-314b")
    model = build_model(cfg, dtype=jnp.bfloat16)
    ap = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    specs = param_specs(ap, cfg, fsdp_axis="data", fsdp_size=16)
    wi = specs["blocks"]["moe"]["wi"]          # (L, E, D, F)
    assert "data" in wi and "model" in wi
    # tiny leaves stay replicated over data
    assert "data" not in specs["blocks"]["ln1"]


def test_moe_expert_parallel_vs_tp():
    dsk = get_arch("deepseek-moe-16b")
    mdl = build_model(dsk, dtype=jnp.bfloat16)
    ap = jax.eval_shape(mdl.init_params, jax.random.PRNGKey(0))
    specs = param_specs(ap, dsk)
    # 64 experts % 16 == 0 => expert-parallel: E axis sharded
    assert specs["blocks"]["moe"]["wi"][1] == "model"
    grok = get_arch("grok-1-314b")
    mdl2 = build_model(grok, dtype=jnp.bfloat16)
    ap2 = jax.eval_shape(mdl2.init_params, jax.random.PRNGKey(0))
    specs2 = param_specs(ap2, grok)
    # 8 experts: TP within expert (F axis)
    assert specs2["blocks"]["moe"]["wi"][-1] == "model"


def test_cache_specs_long_context_seq_sharding():
    """batch=1 (long_500k) => KV cache sequence axis sharded over data.
    cache_specs only reads mesh.axis_names/.shape, so a production-shaped
    stand-in exercises the real decision on a 1-device host."""
    from types import SimpleNamespace
    cfg = get_arch("gemma3-1b-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    mesh = SimpleNamespace(axis_names=("data", "model"),
                           shape={"data": 16, "model": 16})
    ac = jax.eval_shape(lambda: model.init_cache(1, 64))
    specs = cache_specs(ac, cfg, mesh, batch=1)
    assert specs["k"][2] == "data"      # sequence axis sharded
    assert specs["k"][1] is None        # batch=1 unsharded
    # batch divisible => batch sharding instead (+ model on kv/hd axis)
    specs2 = cache_specs(ac, cfg, mesh, batch=32)
    assert specs2["k"][1] in ("data", ("data",))
    assert "model" in (specs2["k"][3], specs2["k"][4])
