"""Adaptive SDE stepping (embedded pairs / step doubling + virtual Brownian
tree) and mesh-sharded stream disjointness.

The load-bearing properties:
  * the Brownian path is a pure function of (seed; lane, row, dyadic time):
    rejected/resized steps replay identical increments (RSwM property);
  * trajectories are BITWISE identical across vmap/array/kernel x xla/pallas
    for BOTH error estimators (embedded pair and step doubling);
  * the integrator actually adapts (per-trajectory step counts differ, steps
    are rejected, tighter tolerances take more steps);
  * strong accuracy against the closed-form GBM solution ON THE SAME PATH;
  * the embedded pair does the same job with measurably fewer drift
    evaluations than step doubling (the ISSUE 4 tentpole win);
  * `lane_offset` makes shard-local solves equal slices of the global solve,
    so mesh shards never replay each other's noise streams.
"""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EnsembleProblem, solve_ensemble_local
from repro.core.api import solve_ensemble
from repro.configs.de_problems import gbm_problem
from repro.kernels.rng import brownian_bridge_point

R, V = 1.5, 0.2


@pytest.fixture(scope="module")
def ens():
    return EnsembleProblem(gbm_problem(r=R, v=V, dtype=jnp.float64), 10)


ADAPT_KW = dict(alg="em", t0=0.0, tf=1.0, dt0=0.05, adaptive=True,
                rtol=1e-3, atol=1e-5, seed=11)


# ---------------------------------------------------------------------------
# virtual Brownian tree
# ---------------------------------------------------------------------------

def test_bridge_is_pure_and_telescoping():
    D, n = 12, 2 ** 12
    lanes = jnp.arange(64, dtype=jnp.uint32)
    rows = jnp.zeros_like(lanes)

    def W(i):
        return brownian_bridge_point(7, jnp.full_like(lanes, i), lanes, rows,
                                     depth=D, t_total=1.0, dtype=jnp.float64)

    np.testing.assert_array_equal(np.asarray(W(777)), np.asarray(W(777)))
    assert np.all(np.asarray(W(0)) == 0.0)
    # increments over any partition telescope exactly to the endpoint value
    q = [np.asarray(W(i * n // 4)) for i in range(5)]
    np.testing.assert_allclose(sum(q[i + 1] - q[i] for i in range(4)), q[4],
                               atol=1e-12)


def test_bridge_statistics():
    D = 12
    lanes = jnp.arange(20000, dtype=jnp.uint32)
    rows = jnp.zeros_like(lanes)

    def W(i):
        return brownian_bridge_point(3, jnp.full_like(lanes, i), lanes, rows,
                                     depth=D, t_total=1.0, dtype=jnp.float64)

    wf, wh = np.asarray(W(2 ** D)), np.asarray(W(2 ** D // 2))
    assert abs(np.var(wf) - 1.0) < 0.05          # Var W(1) = 1
    assert abs(np.var(wh) - 0.5) < 0.03          # Var W(1/2) = 1/2
    inc = wf - wh
    assert abs(np.mean(wh * inc)) < 0.02         # independent increments


# ---------------------------------------------------------------------------
# adaptivity + cross-strategy bitwise parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("error_est", ["embedded", "doubling"])
def test_adaptive_sde_bitwise_parity_all_strategies(ens, error_est):
    saveat = jnp.linspace(0.25, 1.0, 4)
    kw = dict(ADAPT_KW, saveat=saveat, error_est=error_est)
    rv = solve_ensemble_local(ens, ensemble="vmap", **kw)
    ra = solve_ensemble_local(ens, ensemble="array", **kw)
    rx = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                              lane_tile=4, **kw)
    rp = solve_ensemble_local(ens, ensemble="kernel", backend="pallas",
                              lane_tile=4, **kw)
    for name, r in (("array", ra), ("xla", rx), ("pallas", rp)):
        np.testing.assert_array_equal(np.asarray(rv.us), np.asarray(r.us),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(rv.u_final),
                                      np.asarray(r.u_final), err_msg=name)
        np.testing.assert_array_equal(np.asarray(rv.naccept),
                                      np.asarray(r.naccept), err_msg=name)
        np.testing.assert_array_equal(np.asarray(rv.nreject),
                                      np.asarray(r.nreject), err_msg=name)


def test_estimator_choice_changes_trajectories_but_not_contract(ens):
    """embedded and doubling are different estimators (different accepted
    partitions => different EM endpoints on the same path), yet both finish
    and stay within tolerance-scale agreement of each other."""
    re = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                              error_est="embedded", **ADAPT_KW)
    rd = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                              error_est="doubling", **ADAPT_KW)
    assert int(re.status) == 0 and int(rd.status) == 0
    assert not np.array_equal(np.asarray(re.u_final), np.asarray(rd.u_final))
    np.testing.assert_allclose(np.asarray(re.u_final),
                               np.asarray(rd.u_final), rtol=0.1)


def test_embedded_pair_is_cheaper_than_doubling_at_same_tolerance(ens):
    """The tentpole economics: the embedded pair spends >= 1.5x fewer drift
    evaluations than step doubling at the same tolerance (it is ~3x per
    attempted step; step-count differences eat some of that)."""
    kw = dict(ADAPT_KW, rtol=1e-4, atol=1e-6)
    nf_e = int(solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                                    error_est="embedded", **kw).nf)
    nf_d = int(solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                                    error_est="doubling", **kw).nf)
    assert nf_d >= 1.5 * nf_e, (nf_d, nf_e)


def test_milstein_embedded_not_diffusion_blind():
    """Regression: milstein's embedded estimator once had only the
    drift-taming term, which is identically zero for zero drift — the
    controller accepted arbitrarily large steps on diffusion-dominated
    SDEs.  The L¹L¹b rms term makes it resolve pure-diffusion problems."""
    from repro.core.problem import SDEProblem
    prob = SDEProblem(lambda u, p, t: jnp.zeros_like(u),
                      lambda u, p, t: p[0] * u,
                      jnp.asarray([1.0], jnp.float64),
                      jnp.asarray([0.5], jnp.float64), (0.0, 1.0),
                      noise="diagonal", name="zerodrift")
    ens0 = EnsembleProblem(prob, 8)
    res = solve_ensemble_local(ens0, alg="milstein", ensemble="kernel",
                               backend="xla", t0=0.0, tf=1.0, dt0=0.05,
                               adaptive=True, rtol=1e-4, atol=1e-6, seed=3,
                               error_est="embedded", brownian_depth=14)
    assert int(res.status) == 0
    # a blind estimator finishes in a handful of qmax-growth steps
    assert int(np.asarray(res.naccept).min()) > 50


def test_error_est_validation(ens):
    with pytest.raises(ValueError, match="error_est"):
        solve_ensemble_local(ens, ensemble="vmap", error_est="magic",
                             **ADAPT_KW)
    with pytest.raises(ValueError, match="adaptive"):
        solve_ensemble_local(ens, alg="em", t0=0.0, tf=1.0, dt0=0.05,
                             seed=1, save_every=20, error_est="embedded")
    with pytest.raises(ValueError, match="doubling"):
        # heun_strat ships no embedded pair
        solve_ensemble_local(ens, ensemble="vmap",
                             **dict(ADAPT_KW, alg="heun_strat",
                                    error_est="embedded"))
    with pytest.raises(ValueError, match="estimator"):
        # erk methods embed via their tableau; error_est is SDE-only
        from repro.configs.de_problems import lorenz_ensemble
        solve_ensemble_local(lorenz_ensemble(2, dtype=jnp.float64),
                             alg="tsit5", t0=0.0, tf=0.1, dt0=1e-3,
                             error_est="embedded")


def test_adaptivity_is_per_trajectory_and_tolerance_driven(ens):
    loose = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                                 **ADAPT_KW)
    assert int(loose.status) == 0
    # per-trajectory control: different paths take different step counts
    assert len(np.unique(np.asarray(loose.naccept))) > 1
    # the controller actually rejects steps on rough paths
    assert int(np.asarray(loose.nreject).sum()) > 0
    tight = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                                 **dict(ADAPT_KW, rtol=1e-5, atol=1e-7))
    # tighter tolerance costs more steps overall (per-trajectory counts can
    # saturate at the dyadic grid floor, so compare the ensemble total)
    assert (int(np.asarray(tight.naccept).sum())
            > int(np.asarray(loose.naccept).sum()))


@pytest.mark.parametrize("error_est", ["embedded", "doubling"])
def test_adaptive_strong_accuracy_against_closed_form_same_path(ens,
                                                                error_est):
    """GBM has the exact solution X_T = X_0 exp((r - v^2/2)T + v W_T) with
    W_T readable from the SAME virtual Brownian tree the solver integrates —
    a strong (pathwise) accuracy test, not a statistical one, and it holds
    for both error estimators."""
    from repro.core.sde import default_bridge_depth
    depth = default_bridge_depth(0.0, 1.0, 0.05)
    res = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                               error_est=error_est,
                               **dict(ADAPT_KW, rtol=1e-4, atol=1e-6))
    N, n = 10, 3
    lanes = jnp.broadcast_to(jnp.arange(N, dtype=jnp.uint32)[None], (n, N))
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.uint32)[:, None], (n, N))
    WT = brownian_bridge_point(11, jnp.full((n, N), 2 ** depth), lanes, rows,
                               depth=depth, t_total=1.0, dtype=jnp.float64)
    exact = 0.1 * np.exp((R - 0.5 * V * V) * 1.0 + V * np.asarray(WT))
    np.testing.assert_allclose(np.asarray(res.u_final), exact.T, rtol=2e-2)


def test_adaptive_saveat_grid_output(ens):
    """saveat dense output for SDE: snapshots on an arbitrary grid, endpoint
    consistent with the final state."""
    saveat = jnp.asarray([0.1, 0.33, 0.77, 1.0])
    res = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                               **dict(ADAPT_KW, saveat=saveat))
    assert res.us.shape == (10, 4, 3)
    np.testing.assert_allclose(np.asarray(res.us[:, -1]),
                               np.asarray(res.u_final), rtol=1e-12)
    assert np.all(np.asarray(res.us) > 0)        # GBM stays positive


def test_milstein_and_heun_adaptive_dispatch(ens):
    """Step doubling upgrades EVERY registered stepper, not just em."""
    for alg in ("milstein", "heun_strat"):
        res = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                                   **dict(ADAPT_KW, alg=alg))
        assert int(res.status) == 0
        assert np.all(np.asarray(res.naccept) > 0)


# ---------------------------------------------------------------------------
# sharded-SDE stream disjointness (lane_offset)
# ---------------------------------------------------------------------------

def _halves(ens):
    u0s, ps = ens.materialize()
    h0 = EnsembleProblem(ens.prob, 5, u0s=u0s[:5], ps=ps[:5])
    h1 = EnsembleProblem(ens.prob, 5, u0s=u0s[5:], ps=ps[5:])
    return h0, h1


@pytest.mark.parametrize("extra", [
    dict(save_every=40),
    dict(adaptive=True, rtol=1e-3, atol=1e-5, saveat=jnp.asarray([1.0]),
         error_est="embedded"),
    dict(adaptive=True, rtol=1e-3, atol=1e-5, saveat=jnp.asarray([1.0]),
         error_est="doubling"),
], ids=["fixed", "adaptive-embedded", "adaptive-doubling"])
def test_lane_offset_shards_equal_global_slices(ens, extra):
    kw = dict(alg="em", t0=0.0, tf=1.0, dt0=0.025, seed=3,
              ensemble="kernel", backend="xla", **extra)
    full = solve_ensemble_local(ens, **kw)
    h0, h1 = _halves(ens)
    r0 = solve_ensemble_local(h0, lane_offset=0, **kw)
    r1 = solve_ensemble_local(h1, lane_offset=5, **kw)
    np.testing.assert_array_equal(
        np.asarray(full.u_final),
        np.concatenate([np.asarray(r0.u_final), np.asarray(r1.u_final)]))
    # WITHOUT the offset the second shard replays shard 0's streams
    r1_replay = solve_ensemble_local(h1, lane_offset=0, **kw)
    assert not np.array_equal(np.asarray(r1.u_final),
                              np.asarray(r1_replay.u_final))


def test_lane_offset_pallas_kernel(ens):
    kw = dict(alg="em", t0=0.0, tf=1.0, dt0=0.025, save_every=40, seed=3,
              ensemble="kernel", backend="pallas", lane_tile=5)
    full = solve_ensemble_local(ens, **kw)
    _, h1 = _halves(ens)
    r1 = solve_ensemble_local(h1, lane_offset=5, **kw)
    np.testing.assert_array_equal(np.asarray(full.u_final)[5:],
                                  np.asarray(r1.u_final))


def test_mesh_sde_equals_local_single_device(ens):
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    kw = dict(alg="em", t0=0.0, tf=1.0, dt0=0.025, save_every=40, seed=3,
              ensemble="kernel", backend="xla")
    r_mesh = solve_ensemble(ens, mesh=mesh, shard_axes=("data",), **kw)
    r_local = solve_ensemble(ens, mesh=None, **kw)
    np.testing.assert_array_equal(np.asarray(r_mesh.u_final),
                                  np.asarray(r_local.u_final))


TWO_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=2")
import jax, numpy as np, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import EnsembleProblem, solve_ensemble_local
from repro.core.api import solve_ensemble
from repro.configs.de_problems import gbm_problem
from repro.launch.mesh import make_local_mesh

assert len(jax.devices()) == 2
ens = EnsembleProblem(gbm_problem(r=1.5, v=0.2, dtype=jnp.float64), 10)
kw = dict(alg="em", t0=0.0, tf=1.0, dt0=0.025, save_every=40, seed=3,
          ensemble="kernel", backend="xla")
r2 = solve_ensemble(ens, mesh=make_local_mesh(), shard_axes=("data",), **kw)
r1 = solve_ensemble_local(ens, **kw)
np.testing.assert_array_equal(np.asarray(r2.u_final), np.asarray(r1.u_final))
# the two shards produced DISTINCT trajectories (disjoint streams)
a, b = np.asarray(r2.u_final)[:5], np.asarray(r2.u_final)[5:]
assert not np.array_equal(a, b)
# adaptive embedded-pair estimator: same sharded == local bitwise bar (each
# shard quantizes its lanes' steps onto the same global Brownian tree)
kwa = dict(alg="em", t0=0.0, tf=1.0, dt0=0.05, seed=3, adaptive=True,
           rtol=1e-3, atol=1e-5, error_est="embedded",
           ensemble="kernel", backend="xla")
a2 = solve_ensemble(ens, mesh=make_local_mesh(), shard_axes=("data",), **kwa)
a1 = solve_ensemble_local(ens, **kwa)
np.testing.assert_array_equal(np.asarray(a2.u_final), np.asarray(a1.u_final))
np.testing.assert_array_equal(np.asarray(a2.naccept), np.asarray(a1.naccept))
assert not np.array_equal(np.asarray(a2.u_final)[:5],
                          np.asarray(a2.u_final)[5:])
print("TWO-SHARD-OK")
"""


def test_two_shard_streams_disjoint_subprocess():
    """Genuine 2-shard run (forced 2 host devices in a subprocess so the
    single-device contract of this test session is untouched): the sharded
    solve equals the local solve bitwise — for the fixed-dt counter stream
    AND the adaptive embedded-pair estimator — and the shards' trajectories
    differ: each shard draws its own global stream slice."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", TWO_SHARD_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "TWO-SHARD-OK" in out.stdout
