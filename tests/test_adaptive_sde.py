"""Adaptive SDE stepping (embedded step-doubling + virtual Brownian tree) and
mesh-sharded stream disjointness — the other half of the tentpole.

The load-bearing properties:
  * the Brownian path is a pure function of (seed; lane, row, dyadic time):
    rejected/resized steps replay identical increments (RSwM property);
  * trajectories are BITWISE identical across vmap/array/kernel x xla/pallas;
  * the integrator actually adapts (per-trajectory step counts differ, steps
    are rejected, tighter tolerances take more steps);
  * strong accuracy against the closed-form GBM solution ON THE SAME PATH;
  * `lane_offset` makes shard-local solves equal slices of the global solve,
    so mesh shards never replay each other's noise streams.
"""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EnsembleProblem, solve_ensemble_local
from repro.core.api import solve_ensemble
from repro.configs.de_problems import gbm_problem
from repro.kernels.rng import brownian_bridge_point

R, V = 1.5, 0.2


@pytest.fixture(scope="module")
def ens():
    return EnsembleProblem(gbm_problem(r=R, v=V, dtype=jnp.float64), 10)


ADAPT_KW = dict(alg="em", t0=0.0, tf=1.0, dt0=0.05, adaptive=True,
                rtol=1e-3, atol=1e-5, seed=11)


# ---------------------------------------------------------------------------
# virtual Brownian tree
# ---------------------------------------------------------------------------

def test_bridge_is_pure_and_telescoping():
    D, n = 12, 2 ** 12
    lanes = jnp.arange(64, dtype=jnp.uint32)
    rows = jnp.zeros_like(lanes)

    def W(i):
        return brownian_bridge_point(7, jnp.full_like(lanes, i), lanes, rows,
                                     depth=D, t_total=1.0, dtype=jnp.float64)

    np.testing.assert_array_equal(np.asarray(W(777)), np.asarray(W(777)))
    assert np.all(np.asarray(W(0)) == 0.0)
    # increments over any partition telescope exactly to the endpoint value
    q = [np.asarray(W(i * n // 4)) for i in range(5)]
    np.testing.assert_allclose(sum(q[i + 1] - q[i] for i in range(4)), q[4],
                               atol=1e-12)


def test_bridge_statistics():
    D = 12
    lanes = jnp.arange(20000, dtype=jnp.uint32)
    rows = jnp.zeros_like(lanes)

    def W(i):
        return brownian_bridge_point(3, jnp.full_like(lanes, i), lanes, rows,
                                     depth=D, t_total=1.0, dtype=jnp.float64)

    wf, wh = np.asarray(W(2 ** D)), np.asarray(W(2 ** D // 2))
    assert abs(np.var(wf) - 1.0) < 0.05          # Var W(1) = 1
    assert abs(np.var(wh) - 0.5) < 0.03          # Var W(1/2) = 1/2
    inc = wf - wh
    assert abs(np.mean(wh * inc)) < 0.02         # independent increments


# ---------------------------------------------------------------------------
# adaptivity + cross-strategy bitwise parity
# ---------------------------------------------------------------------------

def test_adaptive_sde_bitwise_parity_all_strategies(ens):
    saveat = jnp.linspace(0.25, 1.0, 4)
    kw = dict(ADAPT_KW, saveat=saveat)
    rv = solve_ensemble_local(ens, ensemble="vmap", **kw)
    ra = solve_ensemble_local(ens, ensemble="array", **kw)
    rx = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                              lane_tile=4, **kw)
    rp = solve_ensemble_local(ens, ensemble="kernel", backend="pallas",
                              lane_tile=4, **kw)
    for name, r in (("array", ra), ("xla", rx), ("pallas", rp)):
        np.testing.assert_array_equal(np.asarray(rv.us), np.asarray(r.us),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(rv.u_final),
                                      np.asarray(r.u_final), err_msg=name)
        np.testing.assert_array_equal(np.asarray(rv.naccept),
                                      np.asarray(r.naccept), err_msg=name)
        np.testing.assert_array_equal(np.asarray(rv.nreject),
                                      np.asarray(r.nreject), err_msg=name)


def test_adaptivity_is_per_trajectory_and_tolerance_driven(ens):
    loose = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                                 **ADAPT_KW)
    assert int(loose.status) == 0
    # per-trajectory control: different paths take different step counts
    assert len(np.unique(np.asarray(loose.naccept))) > 1
    # the controller actually rejects steps on rough paths
    assert int(np.asarray(loose.nreject).sum()) > 0
    tight = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                                 **dict(ADAPT_KW, rtol=1e-5, atol=1e-7))
    # tighter tolerance costs more steps overall (per-trajectory counts can
    # saturate at the dyadic grid floor, so compare the ensemble total)
    assert (int(np.asarray(tight.naccept).sum())
            > int(np.asarray(loose.naccept).sum()))


def test_adaptive_strong_accuracy_against_closed_form_same_path(ens):
    """GBM has the exact solution X_T = X_0 exp((r - v^2/2)T + v W_T) with
    W_T readable from the SAME virtual Brownian tree the solver integrates —
    a strong (pathwise) accuracy test, not a statistical one."""
    from repro.core.sde import default_bridge_depth
    depth = default_bridge_depth(0.0, 1.0, 0.05)
    res = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                               **dict(ADAPT_KW, rtol=1e-4, atol=1e-6))
    N, n = 10, 3
    lanes = jnp.broadcast_to(jnp.arange(N, dtype=jnp.uint32)[None], (n, N))
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.uint32)[:, None], (n, N))
    WT = brownian_bridge_point(11, jnp.full((n, N), 2 ** depth), lanes, rows,
                               depth=depth, t_total=1.0, dtype=jnp.float64)
    exact = 0.1 * np.exp((R - 0.5 * V * V) * 1.0 + V * np.asarray(WT))
    np.testing.assert_allclose(np.asarray(res.u_final), exact.T, rtol=2e-2)


def test_adaptive_saveat_grid_output(ens):
    """saveat dense output for SDE: snapshots on an arbitrary grid, endpoint
    consistent with the final state."""
    saveat = jnp.asarray([0.1, 0.33, 0.77, 1.0])
    res = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                               **dict(ADAPT_KW, saveat=saveat))
    assert res.us.shape == (10, 4, 3)
    np.testing.assert_allclose(np.asarray(res.us[:, -1]),
                               np.asarray(res.u_final), rtol=1e-12)
    assert np.all(np.asarray(res.us) > 0)        # GBM stays positive


def test_milstein_and_heun_adaptive_dispatch(ens):
    """Step doubling upgrades EVERY registered stepper, not just em."""
    for alg in ("milstein", "heun_strat"):
        res = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                                   **dict(ADAPT_KW, alg=alg))
        assert int(res.status) == 0
        assert np.all(np.asarray(res.naccept) > 0)


# ---------------------------------------------------------------------------
# sharded-SDE stream disjointness (lane_offset)
# ---------------------------------------------------------------------------

def _halves(ens):
    u0s, ps = ens.materialize()
    h0 = EnsembleProblem(ens.prob, 5, u0s=u0s[:5], ps=ps[:5])
    h1 = EnsembleProblem(ens.prob, 5, u0s=u0s[5:], ps=ps[5:])
    return h0, h1


@pytest.mark.parametrize("extra", [
    dict(save_every=40),
    dict(adaptive=True, rtol=1e-3, atol=1e-5, saveat=jnp.asarray([1.0])),
], ids=["fixed", "adaptive"])
def test_lane_offset_shards_equal_global_slices(ens, extra):
    kw = dict(alg="em", t0=0.0, tf=1.0, dt0=0.025, seed=3,
              ensemble="kernel", backend="xla", **extra)
    full = solve_ensemble_local(ens, **kw)
    h0, h1 = _halves(ens)
    r0 = solve_ensemble_local(h0, lane_offset=0, **kw)
    r1 = solve_ensemble_local(h1, lane_offset=5, **kw)
    np.testing.assert_array_equal(
        np.asarray(full.u_final),
        np.concatenate([np.asarray(r0.u_final), np.asarray(r1.u_final)]))
    # WITHOUT the offset the second shard replays shard 0's streams
    r1_replay = solve_ensemble_local(h1, lane_offset=0, **kw)
    assert not np.array_equal(np.asarray(r1.u_final),
                              np.asarray(r1_replay.u_final))


def test_lane_offset_pallas_kernel(ens):
    kw = dict(alg="em", t0=0.0, tf=1.0, dt0=0.025, save_every=40, seed=3,
              ensemble="kernel", backend="pallas", lane_tile=5)
    full = solve_ensemble_local(ens, **kw)
    _, h1 = _halves(ens)
    r1 = solve_ensemble_local(h1, lane_offset=5, **kw)
    np.testing.assert_array_equal(np.asarray(full.u_final)[5:],
                                  np.asarray(r1.u_final))


def test_mesh_sde_equals_local_single_device(ens):
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    kw = dict(alg="em", t0=0.0, tf=1.0, dt0=0.025, save_every=40, seed=3,
              ensemble="kernel", backend="xla")
    r_mesh = solve_ensemble(ens, mesh=mesh, shard_axes=("data",), **kw)
    r_local = solve_ensemble(ens, mesh=None, **kw)
    np.testing.assert_array_equal(np.asarray(r_mesh.u_final),
                                  np.asarray(r_local.u_final))


TWO_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=2")
import jax, numpy as np, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import EnsembleProblem, solve_ensemble_local
from repro.core.api import solve_ensemble
from repro.configs.de_problems import gbm_problem
from repro.launch.mesh import make_local_mesh

assert len(jax.devices()) == 2
ens = EnsembleProblem(gbm_problem(r=1.5, v=0.2, dtype=jnp.float64), 10)
kw = dict(alg="em", t0=0.0, tf=1.0, dt0=0.025, save_every=40, seed=3,
          ensemble="kernel", backend="xla")
r2 = solve_ensemble(ens, mesh=make_local_mesh(), shard_axes=("data",), **kw)
r1 = solve_ensemble_local(ens, **kw)
np.testing.assert_array_equal(np.asarray(r2.u_final), np.asarray(r1.u_final))
# the two shards produced DISTINCT trajectories (disjoint streams)
a, b = np.asarray(r2.u_final)[:5], np.asarray(r2.u_final)[5:]
assert not np.array_equal(a, b)
print("TWO-SHARD-OK")
"""


def test_two_shard_streams_disjoint_subprocess():
    """Genuine 2-shard run (forced 2 host devices in a subprocess so the
    single-device contract of this test session is untouched): the sharded
    solve equals the local solve bitwise, and the shards' trajectories
    differ — each shard draws its own global stream slice."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", TWO_SHARD_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "TWO-SHARD-OK" in out.stdout
