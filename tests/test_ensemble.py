"""Ensemble strategy equivalences + the paper's algorithmic claims (§5, Table 1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EnsembleProblem
from repro.core.ensemble import solve_ensemble_local
from repro.configs.de_problems import lorenz_ensemble, lorenz_problem

SAVEAT = jnp.linspace(0.0, 1.0, 6)
KW = dict(t0=0.0, tf=1.0, dt0=1e-3, saveat=SAVEAT, rtol=1e-7, atol=1e-7)


@pytest.fixture(scope="module")
def ens():
    return lorenz_ensemble(19, dtype=jnp.float64)


def test_vmap_equals_kernel_xla(ens):
    """Per-trajectory adaptivity: vmap baseline and fused-kernel path must be
    numerically identical (same per-trajectory dt sequences)."""
    rv = solve_ensemble_local(ens, ensemble="vmap", **KW)
    rk = solve_ensemble_local(ens, ensemble="kernel", backend="xla",
                              lane_tile=8, **KW)
    np.testing.assert_allclose(np.asarray(rv.us), np.asarray(rk.us),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_array_equal(np.asarray(rv.naccept),
                                  np.asarray(rk.naccept))


def test_array_lockstep_close_but_different(ens):
    """EnsembleGPUArray semantics: same solution within tolerance, but a
    DIFFERENT dt sequence (global lock-step norm)."""
    rv = solve_ensemble_local(ens, ensemble="vmap", **KW)
    ra = solve_ensemble_local(ens, ensemble="array", **KW)
    np.testing.assert_allclose(np.asarray(rv.us), np.asarray(ra.us),
                               atol=5e-4)


def test_array_eager_matches_array_jit(ens):
    """The eager (per-op dispatch) loop implements identical lock-step
    semantics to the fused array path."""
    ra = solve_ensemble_local(ens, ensemble="array", **KW)
    re = solve_ensemble_local(ens, ensemble="array_eager", **KW)
    np.testing.assert_allclose(np.asarray(ra.us), np.asarray(re.us),
                               rtol=1e-9, atol=1e-9)
    assert int(ra.naccept) == int(re.naccept)


def test_lockstep_work_amplification():
    """Paper Table 1's root cause: one hard trajectory forces small lock-step
    dt for the WHOLE ensemble; per-trajectory (kernel) adaptivity does not.
    Work is measured in RHS evaluations (hardware-independent)."""
    prob = lorenz_problem(jnp.float64)
    N = 16
    # 15 easy (rho=2, decays to fixed point) + 1 chaotic/fast (rho=350)
    rho = jnp.asarray([2.0] * (N - 1) + [350.0], dtype=jnp.float64)
    ps = jnp.stack([jnp.full((N,), 10.0), rho, jnp.full((N,), 8.0 / 3.0)],
                   axis=1)
    ens = EnsembleProblem(prob, N, ps=ps)
    ra = solve_ensemble_local(ens, ensemble="array", **KW)
    rk = solve_ensemble_local(ens, ensemble="kernel", lane_tile=4, **KW)
    assert float(ra.nf) > 2.0 * float(rk.nf), (
        f"array work {float(ra.nf)} vs kernel {float(rk.nf)}")


def test_ragged_trajectory_count_padding():
    ens = lorenz_ensemble(13, dtype=jnp.float64)  # 13 % 4 != 0
    rk = solve_ensemble_local(ens, ensemble="kernel", lane_tile=4, **KW)
    rv = solve_ensemble_local(ens, ensemble="vmap", **KW)
    np.testing.assert_allclose(np.asarray(rk.us), np.asarray(rv.us),
                               rtol=1e-12, atol=1e-12)
    assert rk.us.shape == (13, len(SAVEAT), 3)


def test_fixed_dt_kernel_path(ens):
    r = solve_ensemble_local(ens, ensemble="kernel", adaptive=False,
                             dt0=1e-3, t0=0.0, tf=1.0, save_every=200)
    assert r.us.shape == (19, 5, 3)
    assert bool(jnp.all(jnp.isfinite(r.us)))
    # cross-check against adaptive at tight tol
    ra = solve_ensemble_local(ens, ensemble="vmap", t0=0.0, tf=1.0, dt0=1e-3,
                              saveat=r.ts, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(r.u_final), np.asarray(ra.u_final),
                               atol=1e-3)
