"""Quickstart: the paper's headline workflow in ~30 lines.

Define an ODE once in plain component-style jnp; solve a 10k-member parameter
ensemble three ways (array / vmap / fused-kernel) and see that the answer is
identical while the work is not.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import EnsembleProblem, ODEProblem
from repro.core.ensemble import solve_ensemble_local


def lorenz(u, p, t):
    s, r, b = p[0], p[1], p[2]
    return jnp.stack([s * (u[1] - u[0]),
                      r * u[0] - u[1] - u[0] * u[2],
                      u[0] * u[1] - b * u[2]])


prob = ODEProblem(lorenz, jnp.asarray([1.0, 0.0, 0.0], jnp.float32),
                  jnp.asarray([10.0, 21.0, 8 / 3], jnp.float32), (0.0, 1.0))
N = 10_000
rho = jnp.linspace(0.0, 21.0, N, dtype=jnp.float32)
ps = jnp.stack([jnp.full((N,), 10.0), rho, jnp.full((N,), 8 / 3)], axis=1)
ens = EnsembleProblem(prob, N, ps=ps)

saveat = jnp.linspace(0.0, 1.0, 11, dtype=jnp.float32)
for strategy in ("array", "vmap", "kernel"):
    t0 = time.perf_counter()
    res = solve_ensemble_local(ens, alg="tsit5", ensemble=strategy,
                               t0=0.0, tf=1.0, dt0=1e-3, saveat=saveat,
                               rtol=1e-6, atol=1e-6, lane_tile=1024)
    jax.block_until_ready(res.u_final)
    dt = time.perf_counter() - t0
    print(f"{strategy:>7}: {dt:7.2f}s  (incl. compile)   "
          f"RHS evals = {int(res.nf):>10,}   "
          f"u_final[0] = {res.u_final[0]}")
print("\nSame physics, same answers — the kernel strategy does per-trajectory"
      "\nadaptive stepping with tile-local termination (paper §5.2), the"
      "\narray strategy lock-steps the whole ensemble (paper §5.1).")
